"""Quickstart: token pooling end to end in ~a minute on CPU — entirely
through the public ``repro.Retriever`` facade.

    PYTHONPATH=src python examples/quickstart.py

1. Build a synthetic retrieval corpus.
2. ``Retriever.build``: encode with a small ColBERT encoder, TOKEN-POOL
   the vectors (the paper's technique) at factor 2, index (PLAID 2-bit).
3. Search, and compare quality + footprint against the unpooled
   baseline — the paper's headline tradeoff, in one typed spec knob.
"""
import sys

import jax

import repro
from repro.data.corpus import DatasetSpec, SyntheticRetrievalCorpus
from repro.retrieval.metrics import ndcg_at_k


def main():
    cfg = repro.get_smoke_config("colbertv2")
    params = repro.init_colbert(jax.random.PRNGKey(0), cfg)
    print(f"encoder: {cfg.trunk.n_layers}L d={cfg.trunk.d_model} "
          f"proj={cfg.proj_dim}")

    spec = DatasetSpec("quickstart", n_docs=150, n_queries=24, n_topics=8,
                       doc_len_mean=40, doc_len_std=8, seed=7)
    corpus = SyntheticRetrievalCorpus(spec, vocab_size=cfg.trunk.vocab_size)
    toks = corpus.doc_token_batch(cfg.doc_maxlen - 2)
    q = corpus.query_token_batch(cfg.query_maxlen - 2)
    print(f"corpus: {len(corpus.docs)} docs, {len(corpus.queries)} queries")

    def build(factor):
        # ONE typed spec drives encode -> pool -> index (-> save/serve)
        r = repro.Retriever.build(params, cfg, toks, repro.RetrieverSpec(
            pooling=repro.PoolingSpec(method="ward", factor=factor),
            index=repro.IndexSpec.from_config(cfg, backend="plaid")))
        metric = ndcg_at_k(r.rankings(q, k=10), corpus.qrels, 10)
        return r, metric

    baseline, m_base = build(1)
    pooled, m_pool = build(2)

    print(f"\n{'':12s} {'vectors':>8s} {'bytes':>9s} {'ndcg@10':>8s}")
    for name, r, m in (("unpooled", baseline, m_base),
                       ("ward f=2", pooled, m_pool)):
        print(f"{name:12s} {r.stats.n_vectors_stored:8d} "
              f"{r.stats.index_bytes:9d} {m:8.4f}")
    rel = 100.0 * m_pool / m_base if m_base else 0.0
    print(f"\nhierarchical pooling @ factor 2: "
          f"{pooled.stats.vector_reduction:.0%} fewer vectors at "
          f"{rel:.1f}% relative NDCG@10 (the paper's headline result)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
