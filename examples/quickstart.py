"""Quickstart: token pooling end to end in ~a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. Build a synthetic retrieval corpus.
2. Encode documents with a small ColBERT encoder.
3. TOKEN-POOL the vectors (the paper's technique) at factor 2.
4. Index (PLAID 2-bit), search, and compare against the unpooled index.
"""
import sys

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data.corpus import DatasetSpec, SyntheticRetrievalCorpus
from repro.models.colbert import init_colbert
from repro.retrieval.evaluate import evaluate_pooling


def main():
    cfg = get_smoke_config("colbertv2")
    params = init_colbert(jax.random.PRNGKey(0), cfg)
    print(f"encoder: {cfg.trunk.n_layers}L d={cfg.trunk.d_model} "
          f"proj={cfg.proj_dim}")

    spec = DatasetSpec("quickstart", n_docs=150, n_queries=24, n_topics=8,
                       doc_len_mean=40, doc_len_std=8, seed=7)
    corpus = SyntheticRetrievalCorpus(spec, vocab_size=cfg.trunk.vocab_size)
    print(f"corpus: {len(corpus.docs)} docs, {len(corpus.queries)} queries")

    report = evaluate_pooling(params, cfg, corpus,
                              methods=("ward", "sequential"),
                              factors=(2, 4), backend="plaid",
                              metric_name="ndcg@10")
    print()
    print(report.table())
    print()
    c = report.cell("ward", 2)
    print(f"hierarchical pooling @ factor 2: {c.vector_reduction:.0%} "
          f"fewer vectors at {c.relative:.1f}% relative NDCG@10 "
          f"(the paper's headline result)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
