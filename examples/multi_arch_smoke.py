"""Run one reduced-config train step for EVERY assigned architecture
(--arch all), or a single one:

    PYTHONPATH=src python examples/multi_arch_smoke.py --arch dimenet
    PYTHONPATH=src python examples/multi_arch_smoke.py --arch all
"""
import argparse
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_smoke_config
from repro.configs.base import (DimeNetConfig, RecsysConfig,
                                TransformerConfig)


def run_arch(arch: str) -> float:
    cfg = get_smoke_config(arch)
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    t0 = time.time()
    if isinstance(cfg, TransformerConfig):
        from repro.models.transformer import init_transformer, lm_loss
        params = init_transformer(key, cfg)
        toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
        loss, _ = jax.value_and_grad(
            lambda p: lm_loss(p, toks, toks, cfg, moe_impl="dense")[0]
        )(params)
    elif isinstance(cfg, DimeNetConfig):
        from repro.models.gnn.dimenet import (build_triplets, dimenet_loss,
                                              init_dimenet)
        N, E = 12, 30
        src = rng.integers(0, N, E)
        dst = (src + 1 + rng.integers(0, N - 1, E)) % N
        ei = np.stack([src, dst]).astype(np.int32)
        t_in, t_out, t_mask = build_triplets(ei, N, cfg.triplet_cap)
        inputs = dict(pos=jnp.asarray(rng.normal(size=(N, 3)), jnp.float32),
                      edge_index=jnp.asarray(ei), t_in=jnp.asarray(t_in),
                      t_out=jnp.asarray(t_out), t_mask=jnp.asarray(t_mask),
                      node_mask=jnp.ones(N, bool),
                      edge_mask=jnp.ones(E, bool),
                      z=jnp.asarray(rng.integers(1, 9, N), jnp.int32),
                      graph_ids=jnp.zeros(N, jnp.int32))
        params = init_dimenet(key, cfg)
        loss = jax.value_and_grad(lambda p: dimenet_loss(
            p, inputs, jnp.zeros((1, 1)), cfg))(params)[0]
    elif isinstance(cfg, RecsysConfig):
        from repro.models.recsys import init_recsys, recsys_loss
        params = init_recsys(key, cfg)
        B = 16
        batch = {"sparse_ids": jnp.asarray(
            rng.integers(0, 50, (B, cfg.n_sparse, cfg.multi_hot)),
            jnp.int32),
            "label": jnp.asarray(rng.integers(0, 2, B), jnp.float32)}
        if cfg.n_dense:
            batch["dense"] = jnp.asarray(
                rng.normal(size=(B, cfg.n_dense)), jnp.float32)
        loss = jax.value_and_grad(
            lambda p: recsys_loss(p, batch, cfg)[0])(params)[0]
    else:
        raise TypeError(type(cfg))
    lv = float(loss if not isinstance(loss, tuple) else loss[0])
    assert np.isfinite(lv), arch
    print(f"  {arch:24s} loss {lv:8.4f}  ({time.time()-t0:.1f}s)")
    return lv


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    args = ap.parse_args(argv)
    archs = ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    print(f"running {len(archs)} architecture(s):")
    for a in archs:
        run_arch(a)
    print("all architectures: forward+grad OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
