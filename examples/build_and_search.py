"""Index lifecycle example, through the public ``repro.Retriever``
facade: build a pooled index, search it, persist + reload it, then
exercise CRUD (add new documents, delete stale ones) — the paper's §5
motivation: pooling makes ColBERT viable on CRUD-friendly indexes like
HNSW.

    PYTHONPATH=src python examples/build_and_search.py --backend hnsw
"""
import argparse
import sys
import tempfile

import jax

import repro
from repro.data.corpus import DatasetSpec, SyntheticRetrievalCorpus


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="hnsw",
                    choices=repro.backend_names())
    ap.add_argument("--pool-factor", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = repro.get_smoke_config("colbertv2")
    params = repro.init_colbert(jax.random.PRNGKey(0), cfg)
    spec = DatasetSpec("crud-demo", n_docs=120, n_queries=16, n_topics=6,
                       doc_len_mean=36, doc_len_std=6, seed=11)
    corpus = SyntheticRetrievalCorpus(spec, vocab_size=cfg.trunk.vocab_size)
    toks = corpus.doc_token_batch(cfg.doc_maxlen - 2)

    # 1. build with the first 100 docs — one typed spec, one call
    r = repro.Retriever.build(params, cfg, toks[:100], repro.RetrieverSpec(
        pooling=repro.PoolingSpec(method="ward", factor=args.pool_factor),
        index=repro.IndexSpec.from_config(cfg, backend=args.backend)))
    stats = r.stats
    print(f"built {args.backend} index: {stats.n_docs} docs, "
          f"{stats.n_vectors_stored} vectors "
          f"({stats.vector_reduction:.0%} reduction), "
          f"{stats.index_bytes/2**10:.0f} KiB")

    q = corpus.query_token_batch(cfg.query_maxlen - 2)[:4]
    scores, ids = r.search(q, k=5)
    print("initial top-5 ids:", ids.tolist())

    # 2. persist + reload: the spec rides the artifact manifest
    with tempfile.TemporaryDirectory() as d:
        r.save(d)
        r2 = repro.Retriever.load(params, cfg, d)
        assert r2.spec.index == r.spec.index
        print(f"reloaded from {d}: spec round-tripped, "
              f"{r2.index.n_docs} docs served from mmap")

    # 3. CRUD add: the remaining 20 docs arrive later
    new_ids = r.add(toks[100:])
    print(f"added docs {new_ids[0]}..{new_ids[-1]}")

    # 4. CRUD delete: remove the current best hit of query 0, re-search
    victim = int(ids[0][0])
    r.delete([victim])
    scores2, ids2 = r.search(q[:1], k=5)
    assert victim not in ids2[0].tolist()
    print(f"deleted doc {victim}; new top-5 for q0: {ids2[0].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
