"""Index lifecycle example: build a pooled index, search it, then exercise
CRUD (add new documents, delete stale ones) — the paper's §5 motivation:
pooling makes ColBERT viable on CRUD-friendly indexes like HNSW.

    PYTHONPATH=src python examples/build_and_search.py --backend hnsw
"""
import argparse
import sys

import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.data.corpus import DatasetSpec, SyntheticRetrievalCorpus
from repro.models.colbert import init_colbert
from repro.retrieval.indexer import Indexer
from repro.retrieval.searcher import Searcher


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="hnsw",
                    choices=("flat", "hnsw", "plaid"))
    ap.add_argument("--pool-factor", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = get_smoke_config("colbertv2")
    params = init_colbert(jax.random.PRNGKey(0), cfg)
    spec = DatasetSpec("crud-demo", n_docs=120, n_queries=16, n_topics=6,
                       doc_len_mean=36, doc_len_std=6, seed=11)
    corpus = SyntheticRetrievalCorpus(spec, vocab_size=cfg.trunk.vocab_size)
    toks = corpus.doc_token_batch(cfg.doc_maxlen - 2)

    # 1. build with the first 100 docs
    indexer = Indexer(params, cfg, pool_method="ward",
                      pool_factor=args.pool_factor, backend=args.backend)
    index, stats = indexer.build(toks[:100])
    print(f"built {args.backend} index: {stats.n_docs} docs, "
          f"{stats.n_vectors_stored} vectors "
          f"({stats.vector_reduction:.0%} reduction), "
          f"{stats.index_bytes/2**10:.0f} KiB")

    searcher = Searcher(params, cfg, index)
    q = corpus.query_token_batch(cfg.query_maxlen - 2)[:4]
    scores, ids = searcher.search(q, k=5)
    print("initial top-5 ids:", ids.tolist())

    # 2. CRUD add: the remaining 20 docs arrive later
    new_vecs = indexer.encode_and_pool(toks[100:])
    new_ids = index.add(new_vecs)
    print(f"added docs {new_ids[0]}..{new_ids[-1]}")

    # 3. CRUD delete: remove the current best hit of query 0, re-search
    victim = int(ids[0][0])
    index.delete([victim])
    scores2, ids2 = searcher.search(q[:1], k=5)
    assert victim not in ids2[0].tolist()
    print(f"deleted doc {victim}; new top-5 for q0: {ids2[0].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
