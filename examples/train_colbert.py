"""End-to-end driver: contrastively train a ColBERT encoder, checkpoint,
then index with token pooling and evaluate relative performance.

Default (CPU-friendly):
    PYTHONPATH=src python examples/train_colbert.py --steps 80

~100M-parameter configuration (the paper-scale trunk; slow on CPU):
    PYTHONPATH=src python examples/train_colbert.py \
        --full --steps 300 --batch 8
"""
import argparse
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.data.corpus import DATASET_SPECS, SyntheticRetrievalCorpus
from repro.eval import QualitySweep, synthetic_dataset
from repro.models.colbert import colbert_loss, init_colbert
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import cosine_schedule, make_optimizer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full ColBERTv2 trunk (110M params)")
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--checkpoint-dir", default="/tmp/colbert_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config("colbertv2") if args.full \
        else get_smoke_config("colbertv2")
    params = init_colbert(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"ColBERT encoder: {n_params/1e6:.1f}M params "
          f"(doc_maxlen={cfg.doc_maxlen})")

    opt = make_optimizer("adamw",
                         cosine_schedule(args.lr, 10, args.steps))
    state = opt.init(params)
    ckpt = CheckpointManager(args.checkpoint_dir)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        start, tree, _ = ckpt.restore()
        params = jax.tree_util.tree_map(jnp.asarray, tree["params"])
        state = jax.tree_util.tree_map(jnp.asarray, tree["opt_state"])
        print(f"resumed from step {start}")

    corpus = SyntheticRetrievalCorpus(DATASET_SPECS["scidocs"],
                                      vocab_size=cfg.trunk.vocab_size)
    qs, ds = corpus.train_pairs(args.steps * args.batch, seed=1)

    @jax.jit
    def step(params, state, q, d):
        (loss, m), grads = jax.value_and_grad(colbert_loss, has_aux=True)(
            params, q, d, cfg)
        params, state = opt.update(params, grads, state)
        return params, state, loss, m["acc"]

    qlen, dlen = cfg.query_maxlen - 2, min(cfg.doc_maxlen - 2, 64)
    t0 = time.time()
    for s in range(start, args.steps):
        q = np.zeros((args.batch, qlen), np.int32)
        d = np.zeros((args.batch, dlen), np.int32)
        for b in range(args.batch):
            qq = qs[s * args.batch + b][:qlen]
            dd = corpus.docs[ds[s * args.batch + b]][:dlen]
            q[b, :len(qq)], d[b, :len(dd)] = qq, dd
        params, state, loss, acc = step(params, state, jnp.asarray(q),
                                        jnp.asarray(d))
        if (s + 1) % 20 == 0:
            print(f"step {s+1:4d}: loss {float(loss):.4f} "
                  f"in-batch acc {float(acc):.2f} "
                  f"({(time.time()-t0)/(s+1-start):.2f}s/step)")
        if (s + 1) % 50 == 0:
            ckpt.save(s + 1, {"params": params, "opt_state": state})
    ckpt.save(args.steps, {"params": params, "opt_state": state})
    ckpt.wait()

    print("\nevaluating token pooling with the trained encoder...")
    dataset = synthetic_dataset("scifact", vocab_size=cfg.trunk.vocab_size,
                                doc_maxlen=cfg.doc_maxlen - 2,
                                query_maxlen=cfg.query_maxlen - 2)
    report = QualitySweep(params, cfg, dataset, methods=("ward",),
                          factors=(1, 2, 3, 4), backends=("plaid",),
                          metrics=("ndcg@10",)).run(verbose=True)
    print(report.markdown_table("ndcg@10", backend="plaid", quant_bits=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
