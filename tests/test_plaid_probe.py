"""Device-resident PLAID candidate generation (PR 9): the fused probe
kernel + on-device IVF gather must be indistinguishable from the host
reference path — same survivor doc ids, same survivor ORDER, same
validity mask, bitwise-identical final search scores — monolithic,
sharded, and replicated, while the transfer-guard proves the device
pipeline moves zero bytes device->host between the encoded queries and
the final top-k.

Also pins the PR's satellite bugfix: a fully-masked query token used to
probe anyway (``top_k`` over an all--inf centroid row picks centroids
0..nprobe-1 and walks their lists into the candidate set); masked
tokens must now contribute ZERO candidates on both paths.

Hypothesis sweep gated on ``hypothesis`` (PR 1 convention: skip, don't
fail, in containers without it; CI installs it).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.index import MultiVectorIndex
from repro.core.ivf import build_device_inverted_lists
from repro.core.plaid import device_probe_plan, plaid_candidates
from repro.core.replicated import ReplicatedIndex
from repro.core.sharded import ShardedIndex

DIM = 16
KW = dict(doc_maxlen=24, n_centroids=16)


def unit_docs(rng, n=40, dim=DIM, lo=4, hi=20):
    docs = []
    for _ in range(n):
        v = rng.normal(size=(rng.integers(lo, hi), dim)).astype(np.float32)
        docs.append(v / np.linalg.norm(v, axis=-1, keepdims=True))
    return docs


def unit_queries(rng, n, lq=5, dim=DIM):
    q = rng.normal(size=(n, lq, dim)).astype(np.float32)
    return q / np.linalg.norm(q, axis=-1, keepdims=True)


def build(rng, n=40, ndocs=16, **over):
    kw = dict(KW, ndocs=ndocs)
    kw.update(over)
    idx = MultiVectorIndex(dim=DIM, backend="plaid", **kw)
    idx.add(unit_docs(rng, n=n))
    return idx


def survivors(cand, mask):
    """Per-row (ordered survivor ids, count) — the candidate contract:
    pad geometry may differ between paths, survivors must not."""
    cand, mask = np.asarray(cand), np.asarray(mask)
    return [cand[r][mask[r]].tolist() for r in range(len(cand))]


def assert_candidates_equal(idx, qs, q_mask=None):
    """Host vs device candidates + bitwise search parity on one index.

    Returns False (without asserting) when ``device_probe_plan``
    declines the geometry — the caller decides whether engagement is
    required for its cell.
    """
    use_dev, _ = device_probe_plan(idx._plaid, np.asarray(qs).shape[1],
                                   idx.nprobe, idx.ndocs, "device")
    idx.probe_kernel = "host"
    c0, m0 = idx.candidates(qs, q_mask=q_mask)
    S0, I0 = idx.search_batch(qs, k=7, q_mask=q_mask)
    if not use_dev:
        return False
    idx.probe_kernel = "device"
    c1, m1 = idx.candidates(qs, q_mask=q_mask)
    S1, I1 = idx.search_batch(qs, k=7, q_mask=q_mask)
    idx.probe_kernel = "auto"
    assert isinstance(c1, jax.Array), "device path returned host arrays"
    assert survivors(c0, m0) == survivors(c1, m1)
    np.testing.assert_array_equal(I0, I1)
    assert np.array_equal(np.asarray(S0, np.float32).view(np.int32),
                          np.asarray(S1, np.float32).view(np.int32)), \
        "scores drifted bitwise between device and host candidate paths"
    return True


# ------------------------------------------------------------------ parity
# (ndocs, corpus) pairs where the plan's static proof engages: a tight
# budget on a small corpus (runtime prune branch) and a loose budget on
# a corpus wide enough that the gather ladder stays below n_docs
# (runtime unpruned branch) — both sides of the traced lax.cond
@pytest.mark.parametrize("ndocs,n", [(8, 40), (64, 200)])
@pytest.mark.parametrize("nprobe", [1, 4])
def test_device_matches_host_monolithic(nprobe, ndocs, n):
    rng = np.random.default_rng(nprobe * 100 + ndocs)
    idx = build(rng, n=n, ndocs=ndocs, nprobe=nprobe)
    assert assert_candidates_equal(idx, unit_queries(rng, 6)), \
        "device path must engage on this geometry"


def test_device_matches_host_with_deletes_and_adds():
    """Parity must survive the mutation path: add/delete invalidate the
    cached device IVF + live mask, and deleted docs never reappear."""
    rng = np.random.default_rng(7)
    idx = build(rng, n=40)
    qs = unit_queries(rng, 4)
    assert assert_candidates_equal(idx, qs)
    idx.delete([0, 5, 11])
    assert assert_candidates_equal(idx, qs)
    idx.probe_kernel = "device"
    c, m = idx.candidates(qs)
    for row in survivors(c, m):
        assert not {0, 5, 11} & set(row)
    idx.add(unit_docs(rng, n=6))
    idx.probe_kernel = "auto"
    assert assert_candidates_equal(idx, qs)


def test_single_centroid_and_empty_lists():
    """Edges: K=1 (every token probes the one list) and K >> vectors
    (most IVF lists empty; probed empty lists add nothing)."""
    rng = np.random.default_rng(11)
    one = build(rng, n=100, n_centroids=1, nprobe=4)
    assert assert_candidates_equal(one, unit_queries(rng, 3))
    # guaranteed-empty list: docs biased to the +x0 half-space, codec
    # centroid 0 pinned at -x0 — max-cosine assignment never picks it,
    # while unbiased queries still probe it
    from repro.core.ivf import train_centroids
    from repro.core.quantization import train_codec
    docs = []
    for _ in range(40):
        v = rng.normal(size=(rng.integers(4, 20), DIM)).astype(np.float32)
        v[:, 0] += 3.0
        docs.append(v / np.linalg.norm(v, axis=-1, keepdims=True))
    flat = np.concatenate(docs)
    far = np.zeros((1, DIM), np.float32)
    far[0, 0] = -1.0
    cents = np.concatenate([far, np.asarray(train_centroids(flat, 15))])
    sparse = MultiVectorIndex(dim=DIM, backend="plaid", nprobe=8,
                              **dict(KW, ndocs=16))
    sparse.set_codec(train_codec(flat, cents, bits=2))
    sparse.add(docs)
    assert (np.diff(sparse._plaid.ivf.offsets) == 0).any(), \
        "edge not exercised: no empty IVF list"
    assert assert_candidates_equal(sparse, unit_queries(rng, 3))


# ------------------------------------------------------- masked-token pin
def test_fully_masked_token_adds_zero_candidates():
    """Satellite bugfix pin: a masked query token must contribute ZERO
    candidates. The query is built so the masked token is the ONLY one
    near its nearest centroids — before the fix, ``top_k`` over its
    all--inf score row probed centroids 0..nprobe-1 regardless, leaking
    their lists into the candidate set on both paths."""
    rng = np.random.default_rng(23)
    idx = build(rng, n=40, nprobe=2)
    qs = unit_queries(rng, 2, lq=6)
    masked = np.ones((2, 6), bool)
    masked[:, -1] = False
    assert device_probe_plan(idx._plaid, 6, idx.nprobe, idx.ndocs,
                             "device")[0]
    for pk in ("host", "device"):
        idx.probe_kernel = pk
        c_full, m_full = idx.candidates(qs[:, :5], q_mask=None)
        c_mask, m_mask = idx.candidates(qs, q_mask=masked)
        assert survivors(c_full, m_full) == survivors(c_mask, m_mask), \
            f"{pk}: masked token changed the candidate set"
    idx.probe_kernel = "auto"


def test_fully_masked_query_has_no_candidates():
    """A row whose tokens are ALL masked yields an empty candidate set
    (and -inf/-1 search results) on both paths."""
    rng = np.random.default_rng(29)
    idx = build(rng, n=40)
    qs = unit_queries(rng, 3)
    qm = np.ones(qs.shape[:2], bool)
    qm[1] = False
    for pk in ("host", "device"):
        idx.probe_kernel = pk
        c, m = idx.candidates(qs, q_mask=qm)
        rows = survivors(c, m)
        assert rows[1] == [], f"{pk}: fully-masked query gained candidates"
        assert rows[0] and rows[2]
        S, I = idx.search_batch(qs, k=5, q_mask=qm)
        assert (np.asarray(I)[1] == -1).all()
    idx.probe_kernel = "auto"


# ------------------------------------------------------------ device IVF
def test_device_ivf_overflow_accounting():
    """``list_cap`` truncation keeps each list's LOWEST doc ids, counts
    every drop in ``overflow``, and a capped (inexact) build disqualifies
    the device path via ``device_probe_plan``."""
    rng = np.random.default_rng(31)
    idx = build(rng, n=40, n_centroids=4)
    p = idx._plaid
    exact = build_device_inverted_lists(p.ivf, p.vec2doc, p.n_docs)
    assert exact.overflow == 0
    # padded view vs CSR ground truth, per centroid
    for c in range(p.ivf.n_centroids):
        want = np.unique(p.vec2doc[p.ivf.list_for(c)])
        row = np.asarray(exact.doc_lists[c])[np.asarray(exact.doc_valid[c])]
        np.testing.assert_array_equal(row, want)
        np.testing.assert_array_equal(
            np.flatnonzero(np.asarray(exact.doc_member[c])), want)
    capped = build_device_inverted_lists(p.ivf, p.vec2doc, p.n_docs,
                                         list_cap=2)
    assert capped.list_cap == 2 and capped.overflow > 0
    for c in range(p.ivf.n_centroids):
        want = np.unique(p.vec2doc[p.ivf.list_for(c)])[:2]
        row = np.asarray(capped.doc_lists[c])[np.asarray(capped.doc_valid[c])]
        np.testing.assert_array_equal(row, want)
    p._device_ivf = capped
    use_dev, _ = device_probe_plan(p, 5, idx.nprobe, idx.ndocs, "device")
    assert not use_dev, "overflowed IVF must disqualify the device path"
    p._device_ivf = None


def test_device_bytes_counts_ivf_tables():
    rng = np.random.default_rng(37)
    idx = build(rng, n=20)
    p = idx._plaid
    base = p.device_bytes_detail()
    assert base["ivf"] == 0                 # lazy: not built yet
    div = p.device_ivf()
    detail = p.device_bytes_detail()
    assert detail["ivf"] == div.device_bytes() > 0
    assert p.device_bytes() == sum(detail.values())


# ----------------------------------------------------- sharded/replicated
def test_sharded_and_replicated_parity():
    """set_probe_kernel fans the runtime-only toggle across shards and
    replica lanes; every combination stays bitwise-identical."""
    rng = np.random.default_rng(41)
    docs = unit_docs(rng, n=120)
    qs = unit_queries(rng, 4)
    total = sum(len(d) for d in docs)
    cap = max(total // 3, max(len(d) for d in docs), 1)
    sh = ShardedIndex(dim=DIM, backend="plaid", shard_max_vectors=cap,
                      **dict(KW, ndocs=16))
    sh.add(docs)
    assert sh.n_shards >= 2
    sh.set_probe_kernel("host")
    S0, I0 = sh.search_batch(qs, k=8)
    sh.set_probe_kernel("device")
    assert any(device_probe_plan(s._plaid, qs.shape[1], s.nprobe,
                                 s.ndocs, "device")[0] for s in sh.shards)
    S1, I1 = sh.search_batch(qs, k=8)
    np.testing.assert_array_equal(I0, I1)
    assert np.array_equal(np.asarray(S0, np.float32).view(np.int32),
                          np.asarray(S1, np.float32).view(np.int32))
    rep = ReplicatedIndex.replicate(sh, 2)
    rep.set_probe_kernel("device")
    for r in range(2):
        S2, I2 = rep.search_batch_on(r, qs, k=8)
        np.testing.assert_array_equal(I0, I2)
    rep.set_probe_kernel("auto")


# ------------------------------------------------------------- zero hops
def test_zero_host_transfers_probe_to_rerank():
    """With the device path engaged, candidates -> rerank -> device
    top-k run under a device->host transfer guard: the only host copy
    is the final [Nq, k] result, taken after the guard exits."""
    rng = np.random.default_rng(43)
    idx = build(rng, n=40)
    idx.probe_kernel = "device"
    qs = unit_queries(rng, 4)
    idx.search_batch(qs, k=5)               # warm traces outside guard
    with jax.transfer_guard_device_to_host("disallow"):
        scores, cand = idx.scored_candidates(qs)
        top_s, top_i = jax.lax.top_k(scores, 5)
        top_ids = jnp.take_along_axis(cand, top_i, axis=1)
    jax.block_until_ready((top_s, top_ids))
    idx.probe_kernel = "auto"


def test_no_retrace_through_mixed_shape_stream():
    """One executable per (Nq, Lq): after warm_shapes, a mixed-batch
    stream through the device pipeline compiles NOTHING new."""
    from repro.launch.engine import CompileCounter
    rng = np.random.default_rng(47)
    idx = build(rng, n=40)
    idx.probe_kernel = "device"
    assert idx._probe_plan(5)[0]
    qa, qb = unit_queries(rng, 8), unit_queries(rng, 3)
    idx.warm_shapes(qa, k=5)
    idx.warm_shapes(qb, k=5)
    with CompileCounter() as c:
        for _ in range(3):
            idx.search_batch(qa, k=5)
            idx.search_batch(qb, k=5)
    assert c.count == 0, f"{c.count} re-traces in device probe stream"
    idx.probe_kernel = "auto"


# ------------------------------------------------------- kernel vs ref
def test_probe_kernel_matches_reference():
    """Pallas fused probe cell (interpret mode on CPU) vs the jnp
    reference: same -inf prune pattern, scores equal to float tolerance
    (reduction order differs inside the tile loop)."""
    from repro.kernels.plaid_probe.ops import plaid_probe_scores
    rng = np.random.default_rng(53)
    nq, lq, c, l, k = 2, 5, 64, 6, 16      # C block-padded, like stage 3
    q = jnp.asarray(rng.normal(size=(nq, lq, DIM)), jnp.float32)
    qm = jnp.asarray(rng.random((nq, lq)) > 0.2)
    cents = jnp.asarray(rng.normal(size=(k, DIM)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, k, size=(nq, c, l)), jnp.int32)
    cm = jnp.asarray(rng.random((nq, c, l)) > 0.3)
    vm = jnp.asarray(rng.random((nq, c)) > 0.2)
    for t_cs in (0.0, 0.3, 0.9):
        ref = np.asarray(plaid_probe_scores(q, qm, cents, codes, cm, vm,
                                            t_cs=t_cs, impl="ref"))
        ker = np.asarray(plaid_probe_scores(q, qm, cents, codes, cm, vm,
                                            t_cs=t_cs, impl="kernel"))
        np.testing.assert_array_equal(np.isneginf(ref), np.isneginf(ker))
        fin = np.isfinite(ref)
        np.testing.assert_allclose(ker[fin], ref[fin], rtol=1e-5,
                                   atol=1e-5)


# --------------------------------------------------------- property sweep
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                         # pragma: no cover
    HAVE_HYP = False


if HAVE_HYP:
    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 10 ** 6),
           nprobe=st.integers(1, 6),
           t_cs=st.sampled_from([0.0, 0.3, 0.9]),
           ndocs=st.sampled_from([4, 16, 4096]),
           n_docs=st.integers(6, 60),
           mask=st.sampled_from(["none", "partial", "fullrow"]),
           deletes=st.booleans())
    def test_device_equals_host_property(seed, nprobe, t_cs, ndocs,
                                         n_docs, mask, deletes):
        rng = np.random.default_rng(seed)
        idx = build(rng, n=n_docs, ndocs=ndocs, nprobe=nprobe, t_cs=t_cs)
        if deletes and n_docs > 4:
            idx.delete(list(rng.choice(n_docs, size=2, replace=False)))
        qs = unit_queries(rng, 3)
        qm = None
        if mask != "none":
            qm = np.asarray(rng.random(qs.shape[:2]) > 0.3)
            qm[0, 0] = True                 # keep row 0 probing
            if mask == "fullrow":
                qm[1] = False
        assert_candidates_equal(idx, qs, q_mask=qm)
else:                                       # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_device_equals_host_property():
        pass
