"""ReplicatedIndex (core/replicated.py) + the engine's replica router:
every lane is bitwise-identical to the wrapped index, the forced
shard_map flat path matches the dispatch path, warmed lanes re-trace
nothing, and hot-swapping sharded generations through a multi-lane
engine leaks no probe-pool threads.

Parity regime follows tests/test_sharded.py: exhaustive candidate
budgets, unit vectors, np.array_equal on scores AND ids.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.index import MultiVectorIndex
from repro.core.replicated import ReplicatedIndex
from repro.core.sharded import ShardedIndex
from repro.launch.engine import CompileCounter, ServingEngine

BACKENDS = ["flat", "hnsw", "plaid"]
KW = dict(doc_maxlen=24, n_centroids=16, ndocs=4096, hnsw_candidates=8192)
DIM = 16


def unit_docs(rng, n=40, dim=DIM, lo=4, hi=20):
    docs = []
    for _ in range(n):
        v = rng.normal(size=(rng.integers(lo, hi), dim)).astype(np.float32)
        docs.append(v / np.linalg.norm(v, axis=-1, keepdims=True))
    return docs


def unit_queries(rng, n=6, lq=5, dim=DIM):
    q = rng.normal(size=(n, lq, dim)).astype(np.float32)
    return q / np.linalg.norm(q, axis=-1, keepdims=True)


def build_inner(backend, docs, sharded=True, cap=160):
    if sharded:
        ix = ShardedIndex(dim=DIM, backend=backend,
                          shard_max_vectors=cap, **KW)
    else:
        ix = MultiVectorIndex(dim=DIM, backend=backend, **KW)
    ix.add(docs)
    return ix


# ------------------------------------------------------------------- parity
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("sharded", [False, True])
def test_every_lane_matches_wrapped_index(backend, sharded):
    rng = np.random.default_rng(0)
    inner = build_inner(backend, unit_docs(rng), sharded=sharded)
    qs = unit_queries(rng)
    S0, I0 = inner.search_batch(qs, k=7)
    for n_replicas in (1, 3):
        rep = ReplicatedIndex.replicate(inner, n_replicas)
        for r in range(n_replicas):
            S, I = rep.search_batch_on(r, qs, k=7)
            assert np.array_equal(S, S0)
            assert np.array_equal(I, I0)
        # the parity surface routes through lane 0
        S, I = rep.search_batch(qs, k=7)
        assert np.array_equal(S, S0) and np.array_equal(I, I0)


def test_forced_shard_map_matches_dispatch():
    """The SPMD flat path (one shard_map program per replica group) must
    be bitwise-identical to the per-shard dispatch merge — including on
    a single device, where the mesh degenerates to one cell."""
    rng = np.random.default_rng(1)
    sh = build_inner("flat", unit_docs(rng, n=50), cap=120)
    assert sh.n_shards >= 2
    qs = unit_queries(rng)
    S0, I0 = sh.search_batch(qs, k=9)
    rep = ReplicatedIndex.replicate(sh, 2, use_shard_map=True)
    for r in range(2):
        S, I = rep.search_batch_on(r, qs, k=9)
        assert np.array_equal(S, S0)
        assert np.array_equal(I, I0)
    # monolithic flat: a one-part plan is still a valid program
    mono = build_inner("flat", unit_docs(rng, n=50), sharded=False)
    S0, I0 = mono.search_batch(qs, k=9)
    rep1 = ReplicatedIndex.replicate(mono, 1, use_shard_map=True)
    S, I = rep1.search_batch(qs, k=9)
    assert np.array_equal(S, S0) and np.array_equal(I, I0)


def test_delete_fans_to_all_copies_and_invalidates_plans():
    rng = np.random.default_rng(2)
    docs = unit_docs(rng)
    qs = unit_queries(rng)
    copies = [build_inner("flat", docs) for _ in range(2)]
    rep = ReplicatedIndex(copies, use_shard_map=True)
    rep.search_batch(qs, k=5)                   # builds lane-0 plan
    rep.delete([0, 7])
    ref = build_inner("flat", docs)
    ref.delete([0, 7])
    S0, I0 = ref.search_batch(qs, k=5)
    for r in range(2):
        S, I = rep.search_batch_on(r, qs, k=5)
        assert np.array_equal(S, S0) and np.array_equal(I, I0)
    rep.delete([])                              # well-typed no-op
    S, I = rep.search_batch(qs, k=5)
    assert np.array_equal(S, S0) and np.array_equal(I, I0)


def test_add_is_shared_only():
    rng = np.random.default_rng(3)
    docs = unit_docs(rng, n=12)
    sh = build_inner("flat", docs)
    rep = ReplicatedIndex.replicate(sh, 2)
    ids = rep.add([docs[0]])                    # shared inner: fine
    assert ids[0] == rep.n_docs - 1
    distinct = ReplicatedIndex([build_inner("flat", docs),
                                build_inner("flat", docs)])
    with pytest.raises(RuntimeError, match="rebuild"):
        distinct.add([docs[0]])


# ------------------------------------------------------------------ loading
def test_from_dir_distinct_copies_and_probe_split(tmp_path):
    from repro.core.persist import save_sharded
    rng = np.random.default_rng(4)
    sh = build_inner("flat", unit_docs(rng), cap=120)
    save_sharded(sh, str(tmp_path))
    qs = unit_queries(rng)
    S0, I0 = sh.search_batch(qs, k=7)
    rep = ReplicatedIndex.from_dir(str(tmp_path), n_replicas=2)
    assert rep.own_inner
    assert rep._inners[0] is not rep._inners[1]
    # auto probe width divides across lanes (a pinned width would not)
    auto = ShardedIndex(dim=DIM, backend="flat").probe_threads
    for ix in rep._inners:
        assert ix.probe_threads == max(1, auto // 2)
    for r in range(2):
        S, I = rep.search_batch_on(r, qs, k=7)
        assert np.array_equal(S, S0) and np.array_equal(I, I0)
    rep.close()
    assert all(ix.closed for ix in rep._inners)


def test_from_dir_pinned_probe_threads_survive(tmp_path):
    from repro.core.persist import load_sharded, save_sharded
    rng = np.random.default_rng(5)
    sh = ShardedIndex(dim=DIM, backend="flat", shard_max_vectors=120,
                      probe_threads=3, **KW)
    sh.add(unit_docs(rng))
    save_sharded(sh, str(tmp_path))
    assert load_sharded(str(tmp_path)).probe_threads == 3
    rep = ReplicatedIndex.from_dir(str(tmp_path), n_replicas=2)
    for ix in rep._inners:                      # pin honored, not divided
        assert ix.probe_threads == 3
    rep.close()


# -------------------------------------------------------------- no-retrace
def test_warmed_lanes_do_not_retrace():
    rng = np.random.default_rng(6)
    sh = build_inner("flat", unit_docs(rng), cap=120)
    qs = unit_queries(rng, n=4)
    rep = ReplicatedIndex.replicate(sh, 2, use_shard_map=True)
    with CompileCounter() as cold:
        rep.warm_shapes(qs, k=7)
    assert cold.count > 0, "probe is not observing compilations"
    with CompileCounter() as c:
        for r in (0, 1, 0, 1):
            rep.search_batch_on(r, qs, k=7)
    assert c.count == 0, f"{c.count} re-traces on warmed lanes"


@pytest.mark.parametrize("backend", BACKENDS)
def test_warmed_dispatch_lanes_do_not_retrace(backend):
    rng = np.random.default_rng(7)
    sh = build_inner(backend, unit_docs(rng), cap=160)
    qs = unit_queries(rng, n=4)
    rep = ReplicatedIndex.replicate(sh, 2)
    rep.warm_shapes(qs, k=7)
    rep.search_batch_on(1, qs, k=7)             # flush any stragglers
    with CompileCounter() as c:
        for r in (0, 1, 1, 0):
            rep.search_batch_on(r, qs, k=7)
    assert c.count == 0, f"{c.count} re-traces on warmed {backend} lanes"


# ------------------------------------------------------------------- engine
class VecSearcher:
    def __init__(self, index):
        self.index = index

    def encode_queries(self, q):
        return np.asarray(q, np.float32)

    def warmup(self, batch_sizes, k=10):
        if isinstance(batch_sizes, (int, np.integer)):
            batch_sizes = [batch_sizes]
        for bs in sorted(set(batch_sizes)):
            self.index.search_batch(np.zeros((bs, 5, DIM), np.float32),
                                    k=k)


def test_engine_replica_router_parity_and_stats():
    rng = np.random.default_rng(8)
    sh = build_inner("flat", unit_docs(rng), cap=120)
    qs = unit_queries(rng, n=48)
    S0, I0 = sh.search_batch(qs, k=10)
    with ServingEngine(VecSearcher(sh), max_batch=8, max_wait_ms=1.0,
                       n_replicas=3) as eng:
        futs = [eng.submit(qs[i][None]) for i in range(len(qs))]
        for i, f in enumerate(futs):
            S, I = f.result(timeout=30)
            assert np.array_equal(S[0], S0[i])
            assert np.array_equal(I[0], I0[i])
        snap = eng.stats.snapshot()
    assert sum(snap["replica_batches"].values()) == snap["batches"]
    assert set(snap["replica_batches"]) <= {0, 1, 2}


def test_engine_single_replica_unchanged():
    """n_replicas=1 must serve the index UNWRAPPED — zero perturbation
    of the long-standing single-lane pipeline."""
    rng = np.random.default_rng(9)
    sh = build_inner("flat", unit_docs(rng))
    eng = ServingEngine(VecSearcher(sh), max_batch=4, max_wait_ms=1.0)
    assert eng._handle.index is sh
    eng2 = ServingEngine(VecSearcher(sh), max_batch=4, max_wait_ms=1.0,
                         n_replicas=2)
    assert isinstance(eng2._handle.index, ReplicatedIndex)
    assert eng2._handle.index.inner is sh
    assert not eng2._handle.index.own_inner     # caller's index: not closed


def test_engine_swap_under_replicas():
    rng = np.random.default_rng(10)
    docs = unit_docs(rng)
    sh = build_inner("flat", docs)
    qs = unit_queries(rng, n=4)
    with ServingEngine(VecSearcher(sh), max_batch=4, max_wait_ms=1.0,
                       n_replicas=2) as eng:
        sh2 = build_inner("flat", docs, cap=120)
        old = eng.swap_index(sh2)
        assert old.wait_drained(timeout=5.0)
        assert isinstance(eng._handle.index, ReplicatedIndex)
        S0, I0 = sh2.search_batch(qs, k=10)
        S, I = eng.search(qs)
        assert np.array_equal(S, S0) and np.array_equal(I, I0)


# ------------------------------------------------- probe-pool thread leak
def _live_threads():
    return sum(1 for t in threading.enumerate() if t.is_alive())


def test_sharded_close_releases_probe_pool():
    rng = np.random.default_rng(11)
    sh = build_inner("flat", unit_docs(rng), cap=80)
    qs = unit_queries(rng, n=2)
    sh.search_batch(qs, k=5)                    # spin up pool workers
    assert not sh.closed
    sh.close()
    assert sh.closed
    deadline = time.time() + 5.0
    while time.time() < deadline:
        if not any(t.name.startswith("shard-probe") and t.is_alive()
                   for t in threading.enumerate()):
            break
        time.sleep(0.05)
    else:
        pytest.fail("probe pool threads survived close()")
    # a closed index still answers (degraded sequential probing)
    S, I = sh.search_batch(qs, k=5)
    assert S.shape == (2, 5)


def test_hot_swap_generations_do_not_leak_threads():
    """Satellite regression: N owned sharded generations swapped through
    the engine must not strand N probe pools — each retiring handle
    closes its index, so live threads stay bounded."""
    rng = np.random.default_rng(12)
    docs = unit_docs(rng)
    sh0 = build_inner("flat", docs, cap=80)
    qs = unit_queries(rng, n=2)
    with ServingEngine(VecSearcher(sh0), max_batch=4, max_wait_ms=1.0,
                       n_replicas=2) as eng:
        eng.search(qs)
        swapped = []
        for i in range(6):
            gen = build_inner("flat", docs, cap=80)
            gen.search_batch(qs, k=5)           # spin up its pool
            old = eng.swap_index(gen, owned=True)
            assert old.wait_drained(timeout=5.0)
            eng.search(qs)
            swapped.append(gen)
        # every retired generation's pool must be shut down
        deadline = time.time() + 5.0
        while time.time() < deadline:
            if all(g.closed for g in swapped[:-1]):
                break
            time.sleep(0.05)
        assert all(g.closed for g in swapped[:-1]), \
            "retired sharded generations left open across hot swaps"
        assert not swapped[-1].closed           # the live one serves on
    # stop() retires the final owned generation too; once every closed
    # pool's workers exit, only sh0's (caller-owned, never closed) may
    # remain — a linear-in-swaps thread count is the leak this pins
    assert swapped[-1].closed
    deadline = time.time() + 5.0
    while time.time() < deadline:
        probe_threads = sum(1 for t in threading.enumerate()
                            if t.is_alive()
                            and t.name.startswith("shard-probe"))
        if probe_threads <= sh0.probe_threads:
            break
        time.sleep(0.05)
    assert probe_threads <= sh0.probe_threads, \
        f"{probe_threads} probe threads live after {len(swapped)} swaps"
