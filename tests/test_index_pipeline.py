"""Streaming-build pipeline: pipelined flush parity, single-pass raw
counts, compact device->host transfer accounting, failure propagation.

Artifact filenames embed a random generation token (hot-swap needs
unique names), so "identical artifacts" is checked on CONTENT: payload
files compared under token-canonicalized names, manifests compared
after stripping the token from embedded filenames.
"""
import json
import os
import re

import numpy as np
import jax
import pytest

from repro.configs import get_smoke_config
from repro.core.pooling import compaction_transfer_stats
from repro.core.spec import IndexSpec, PoolingSpec
from repro.models.colbert import init_colbert
from repro.retrieval.indexer import Indexer

_TOKEN = re.compile(r"\.[0-9a-f]{8}\.npy")


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("colbertv2")
    params = init_colbert(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = rng.integers(3, cfg.trunk.vocab_size,
                        size=(90, cfg.doc_maxlen - 2)).astype(np.int32)
    return params, cfg, toks


def _indexer(params, cfg, **pool_kw):
    return Indexer(params, cfg, encode_batch=32,
                   index_spec=IndexSpec.from_config(cfg, backend="flat",
                                                    ndocs=4096),
                   pooling_spec=PoolingSpec(**pool_kw))


def _canonical_artifact(root):
    """{canonical relpath: bytes-or-normalized-json} with the random
    generation token stripped (stats.json excluded: it records build
    timings, not index content)."""
    out = {}
    for dirpath, _, files in os.walk(root):
        for name in sorted(files):
            if name == "stats.json":
                continue
            path = os.path.join(dirpath, name)
            rel = _TOKEN.sub(".npy", os.path.relpath(path, root))
            with open(path, "rb") as fh:
                blob = fh.read()
            if name.endswith(".json"):
                out[rel] = _TOKEN.sub(".npy", blob.decode())
            else:
                out[rel] = blob
    return out


def test_pipelined_flush_matches_serial(setup, tmp_path):
    params, cfg, toks = setup
    dirs, stats = {}, {}
    for pipe in (False, True):
        d = str(tmp_path / f"pipe_{pipe}")
        sharded, st = _indexer(params, cfg, method="ward", factor=2) \
            .build_streaming(toks, shard_max_vectors=512, out_dir=d,
                             pipeline=pipe)
        dirs[pipe], stats[pipe] = d, st
        assert st.pipelined is pipe
    a, b = stats[False], stats[True]
    # identical build outcome: shard layout, ids, counts, buffer peaks
    for f in ("n_docs", "n_vectors_raw", "n_vectors_stored", "n_shards",
              "peak_buffered_vectors", "max_batch_vectors"):
        assert getattr(a, f) == getattr(b, f), f
    assert a.n_shards >= 2
    ca, cb = (_canonical_artifact(dirs[p]) for p in (False, True))
    assert sorted(ca) == sorted(cb)
    for rel in ca:
        assert ca[rel] == cb[rel], f"artifact drift in {rel}"


def test_pipelined_in_memory_build_parity(setup):
    params, cfg, toks = setup
    res = {}
    for pipe in (False, True):
        sharded, st = _indexer(params, cfg, method="ward", factor=2) \
            .build_streaming(toks, shard_max_vectors=512, pipeline=pipe)
        qs = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                          (4, 8, cfg.proj_dim)), np.float32)
        res[pipe] = (st, sharded.search_batch(qs, k=5))
    sa, sb = res[False][0], res[True][0]
    assert sa.n_shards == sb.n_shards
    for ra, rb in zip(res[False][1], res[True][1]):
        np.testing.assert_array_equal(np.asarray(ra), np.asarray(rb))


def test_raw_count_single_pass_matches_reencode(setup):
    params, cfg, toks = setup
    ix = _indexer(params, cfg, method="ward", factor=2)
    _, raw = ix.encode_and_pool_counted(toks)
    # the old second corpus pass, inlined as the oracle
    import jax.numpy as jnp
    from repro.models.colbert import emit_mask_docs, prepare_doc_tokens
    t, attn = prepare_doc_tokens(jnp.asarray(toks), cfg.doc_maxlen)
    emit = emit_mask_docs(t, attn, cfg.mask_punctuation)
    assert raw == int(np.asarray(emit).sum())
    # and both build paths report it
    _, st_mono = ix.build(toks)
    _, st_stream = _indexer(params, cfg, method="ward", factor=2) \
        .build_streaming(toks, shard_max_vectors=512)
    assert st_mono.n_vectors_raw == raw
    assert st_stream.n_vectors_raw == raw


def test_compaction_transfer_bounded(setup):
    params, cfg, toks = setup
    factor = 2
    compaction_transfer_stats(reset=True)
    _indexer(params, cfg, method="ward", factor=factor).build(toks)
    ts = compaction_transfer_stats(reset=True)
    assert ts["batches"] > 0 and ts["padded_bytes"] > 0
    ratio = ts["compact_bytes"] / ts["padded_bytes"]
    # <= 1/factor + eps: each doc pools to n//f + 1 vectors, so the
    # slack is ~1 slot per doc plus the counts vector
    eps = 2.0 / cfg.doc_maxlen + 0.02
    assert ratio <= 1.0 / factor + eps, ratio


def test_flush_failure_propagates(setup, tmp_path, monkeypatch):
    params, cfg, toks = setup
    from repro.core.index import MultiVectorIndex

    def boom(self, *a, **kw):
        raise RuntimeError("disk on fire")

    monkeypatch.setattr(MultiVectorIndex, "save", boom)
    with pytest.raises(RuntimeError, match="disk on fire"):
        _indexer(params, cfg, method="ward", factor=2).build_streaming(
            toks, shard_max_vectors=512, out_dir=str(tmp_path / "boom"),
            pipeline=True)
