"""Per-kernel shape/dtype sweeps vs the pure-jnp ref oracles
(interpret=True executes the kernel bodies on CPU)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.kmeans_assign.ops import kmeans_assign
from repro.kernels.kmeans_assign.ref import kmeans_assign_ref
from repro.kernels.maxsim.ops import maxsim, maxsim_rerank
from repro.kernels.maxsim.ref import maxsim_ref, maxsim_rerank_ref
from repro.kernels.quant.ops import dequant_score
from repro.kernels.quant.ref import dequant_score_ref


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-4


# ---------------------------------------------------------------- maxsim
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("nq,lq,nd,ld,dim", [
    (3, 32, 9, 64, 128), (8, 16, 16, 128, 64), (1, 8, 5, 32, 128),
    (13, 32, 7, 256, 128),
])
def test_maxsim_sweep(nq, lq, nd, ld, dim, dtype):
    rng = np.random.default_rng(nq * ld)
    q = jnp.asarray(rng.normal(size=(nq, lq, dim)), dtype)
    d = jnp.asarray(rng.normal(size=(nd, ld, dim)), dtype)
    qm = jnp.asarray(rng.random((nq, lq)) > 0.2)
    dm = jnp.asarray(rng.random((nd, ld)) > 0.2)
    out = maxsim(q, qm, d, dm, block_q=4, block_d=4)
    ref = maxsim_ref(q, qm, d, dm)
    np.testing.assert_allclose(out, ref, rtol=tol(dtype), atol=tol(dtype)
                               * np.abs(np.asarray(ref)).max())


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("nq,lq,s,ld,dim", [
    (3, 32, 9, 64, 128), (8, 16, 5, 128, 64), (1, 8, 1, 32, 128),
])
def test_maxsim_rerank_sweep(nq, lq, s, ld, dim, dtype):
    """Gathered-candidate rerank: query i scores only its own slab d[i]."""
    rng = np.random.default_rng(nq * ld + s)
    q = jnp.asarray(rng.normal(size=(nq, lq, dim)), dtype)
    d = jnp.asarray(rng.normal(size=(nq, s, ld, dim)), dtype)
    qm = jnp.asarray(rng.random((nq, lq)) > 0.2)
    dm = jnp.asarray(rng.random((nq, s, ld)) > 0.2)
    out = maxsim_rerank(q, qm, d, dm, block_s=4)
    ref = maxsim_rerank_ref(q, qm, d, dm)
    np.testing.assert_allclose(out, ref, rtol=tol(dtype), atol=tol(dtype)
                               * np.abs(np.asarray(ref)).max())


def test_maxsim_all_docs_masked():
    q = jnp.ones((2, 4, 8), jnp.float32)
    d = jnp.ones((2, 4, 8), jnp.float32)
    qm = jnp.ones((2, 4), bool)
    dm = jnp.zeros((2, 4), bool)
    out = maxsim(q, qm, d, dm, block_q=2, block_d=2)
    assert np.allclose(np.asarray(out), 0.0)


# --------------------------------------------------------- kmeans_assign
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,k,dim", [(100, 8, 64), (257, 32, 128),
                                     (64, 5, 32)])
def test_kmeans_assign_sweep(n, k, dim, dtype):
    rng = np.random.default_rng(n + k)
    x = jnp.asarray(rng.normal(size=(n, dim)), dtype)
    c = jnp.asarray(rng.normal(size=(k, dim)), dtype)
    km = jnp.asarray(np.arange(k) < max(k - 2, 1))
    a, s = kmeans_assign(x, c, km, block_n=64)
    ar, sr = kmeans_assign_ref(x, c, km)
    assert (np.asarray(a) == np.asarray(ar)).all()
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=tol(dtype), atol=1e-2)


# ------------------------------------------------------------- quant
@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("m,dim,lq", [(100, 128, 16), (300, 64, 32)])
def test_dequant_score_sweep(m, dim, lq, bits):
    from repro.core.quantization import encode, train_codec
    rng = np.random.default_rng(m + bits)
    vecs = rng.normal(size=(m, dim)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=-1, keepdims=True)
    cents = rng.normal(size=(16, dim)).astype(np.float32)
    cents /= np.linalg.norm(cents, axis=-1, keepdims=True)
    codec = train_codec(jnp.asarray(vecs), jnp.asarray(cents), bits=bits)
    ids, words = encode(codec, jnp.asarray(vecs))
    q = jnp.asarray(rng.normal(size=(lq, dim)), jnp.float32)
    out = dequant_score(words, ids, codec.centroids, codec.values, q,
                        bits=bits, block_m=64)
    rows = jnp.take(codec.centroids, ids, axis=0)
    ref = dequant_score_ref(words, rows, codec.values, q, bits=bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_pack_unpack_roundtrip():
    from repro.core.quantization import pack_codes, unpack_codes
    rng = np.random.default_rng(0)
    for bits in (2, 4, 8):
        codes = jnp.asarray(rng.integers(0, 1 << bits, (50, 128)), jnp.int32)
        words = pack_codes(codes, bits)
        back = unpack_codes(words, bits, 128)
        assert (np.asarray(back) == np.asarray(codes)).all()


# ----------------------------------------------------- flash_attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,sq,skv,dh,causal", [
    (2, 4, 2, 128, 128, 64, True),
    (1, 8, 8, 256, 256, 128, True),
    (2, 4, 1, 128, 512, 64, False),
    (1, 4, 2, 128, 512, 64, True),      # decode-ish: q shorter than kv
    (1, 2, 2, 64, 64, 128, True),
])
def test_flash_attention_sweep(b, h, kv, sq, skv, dh, causal, dtype):
    rng = np.random.default_rng(sq + skv)
    q = jnp.asarray(rng.normal(size=(b, h, sq, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(b, kv, skv, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(b, kv, skv, dh)), dtype)
    o = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    r = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(r, np.float32),
        rtol=tol(dtype), atol=tol(dtype) * 4)


def test_flash_attention_matches_model_attention():
    """Kernel agrees with the model's chunked-attention reference path."""
    from repro.models.attention import _chunked_attn
    rng = np.random.default_rng(5)
    B, S, H, dh = 2, 256, 4, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    o_model = _chunked_attn(q, k, v, causal=True, chunk=64)
    o_kernel = flash_attention(q.transpose(0, 2, 1, 3),
                               k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3),
                               causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(o_kernel),
                               np.asarray(o_model.transpose(0, 2, 1, 3)),
                               rtol=1e-4, atol=1e-4)
