"""Batched two-stage engine: DocStore, batch==sequential parity, CRUD
edge cases, and the gathered-candidate rerank path."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.docstore import DocStore, pad_candidate_sets
from repro.core.index import MultiVectorIndex

BACKENDS = ["flat", "hnsw", "plaid"]


def unit_docs(rng, n=40, dim=16, lo=4, hi=20):
    docs = []
    for _ in range(n):
        v = rng.normal(size=(rng.integers(lo, hi), dim)).astype(np.float32)
        docs.append(v / np.linalg.norm(v, axis=-1, keepdims=True))
    return docs


def unit_queries(rng, n, lq=5, dim=16):
    q = rng.normal(size=(n, lq, dim)).astype(np.float32)
    return q / np.linalg.norm(q, axis=-1, keepdims=True)


def make_index(backend, dim=16):
    return MultiVectorIndex(dim=dim, backend=backend, doc_maxlen=24,
                            n_centroids=16, ndocs=64)


# ------------------------------------------------------------------ DocStore
def test_docstore_add_grow_padded():
    rng = np.random.default_rng(0)
    store = DocStore(dim=8, doc_maxlen=6, init_capacity=4)
    docs = unit_docs(rng, n=10, dim=8, lo=2, hi=9)
    ids = store.add(docs[:4])
    assert list(ids) == [0, 1, 2, 3]
    d, m = store.padded()
    assert d.shape[0] == 4 and d.shape[1] <= 6 and d.shape[2] == 8
    ids2 = store.add(docs[4:])          # forces amortized doubling
    assert list(ids2) == list(range(4, 10))
    d, m = store.padded()
    # width is tight: min(doc_maxlen, longest doc)
    expect_L = min(6, max(len(x) for x in docs))
    assert d.shape == (10, expect_L, 8)
    for i, doc in enumerate(docs):
        k = min(len(doc), 6)
        np.testing.assert_allclose(np.asarray(d[i, :k]), doc[:k], rtol=1e-6)
        assert int(np.asarray(m[i]).sum()) == k
        np.testing.assert_allclose(store.doc(i), doc, rtol=1e-6)


def test_docstore_delete_and_nbytes():
    store = DocStore(dim=4, doc_maxlen=8)
    store.add([np.ones((3, 4), np.float32), np.ones((5, 4), np.float32)])
    assert store.n_vectors() == 8
    store.delete([0])
    assert store.n_vectors() == 5
    assert store.nbytes(bytes_per_dim=2) == 5 * 4 * 2
    assert store.n_vectors(live_only=False) == 8


def test_docstore_empty_add():
    store = DocStore(dim=4, doc_maxlen=8)
    assert len(store.add([])) == 0
    assert store.n_docs == 0


def test_pad_candidate_sets():
    qidx = np.array([0, 0, 0, 2, 2])
    docs = np.array([5, 7, 9, 1, 3])
    cand, mask = pad_candidate_sets(qidx, docs, 3, block=4)
    assert cand.shape == (3, 4)
    assert list(cand[0][mask[0]]) == [5, 7, 9]
    assert not mask[1].any()
    assert list(cand[2][mask[2]]) == [1, 3]


# ----------------------------------------------------------- CRUD satellites
@pytest.mark.parametrize("backend", BACKENDS)
def test_empty_add_is_noop(backend):
    idx = make_index(backend)
    ids = idx.add([])                    # must not crash on any backend
    assert ids.shape == (0,)
    rng = np.random.default_rng(1)
    idx.add(unit_docs(rng))
    assert len(idx.add([])) == 0
    s, i = idx.search(unit_queries(rng, 1)[0], k=3)
    assert len(i) == 3


def test_flat_nbytes_excludes_deleted():
    idx = MultiVectorIndex(dim=8, backend="flat", doc_maxlen=16)
    idx.add([np.ones((4, 8), np.float32), np.ones((6, 8), np.float32)])
    assert idx.nbytes() == 10 * 8 * 2    # fp16 flat
    idx.delete([0])
    assert idx.nbytes() == 6 * 8 * 2     # deleted doc no longer counted
    assert idx.n_vectors() == 6


# ------------------------------------------------------ batch == sequential
@pytest.mark.parametrize("backend", BACKENDS)
def test_search_batch_matches_sequential(backend):
    rng = np.random.default_rng(2)
    idx = make_index(backend)
    idx.add(unit_docs(rng, n=50))
    qs = unit_queries(rng, 32)
    S, I = idx.search_batch(qs, k=8)
    assert S.shape == (32, 8) and I.shape == (32, 8)
    for n in range(32):
        s, i = idx.search(qs[n], k=8)
        valid = I[n] >= 0
        assert np.array_equal(I[n][valid], i), (backend, n)
        np.testing.assert_allclose(S[n][valid], s, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_delete_then_search_parity(backend):
    """Deleted ids never come back; survivor scores are unchanged."""
    rng = np.random.default_rng(3)
    idx = make_index(backend)
    idx.add(unit_docs(rng, n=50))
    qs = unit_queries(rng, 6)
    S0, I0 = idx.search_batch(qs, k=10)
    drop = {int(I0[0][0]), int(I0[1][0]), 5, 11}
    idx.delete(sorted(drop))
    S1, I1 = idx.search_batch(qs, k=10)
    assert not np.isin(I1[I1 >= 0], sorted(drop)).any()
    # survivors keep their exact-rerank scores
    for n in range(len(qs)):
        before = {int(d): float(s) for s, d in zip(S0[n], I0[n]) if d >= 0}
        for s, d in zip(S1[n], I1[n]):
            if d >= 0 and int(d) in before:
                np.testing.assert_allclose(s, before[int(d)],
                                           rtol=1e-5, atol=1e-5)


def test_plaid_prune_path_parity_and_recall():
    """Force stage-3 centroid-only pruning (ndocs < candidate count):
    batch==single parity must hold and easy queries must survive the
    prune (agreement with flat exact search on top-1)."""
    rng = np.random.default_rng(7)
    topics = rng.normal(size=(4, 16)).astype(np.float32)
    docs = []
    for i in range(60):
        v = topics[i % 4] + 0.3 * rng.normal(size=(rng.integers(6, 20), 16))
        docs.append((v / np.linalg.norm(v, axis=-1, keepdims=True))
                    .astype(np.float32))
    plaid = MultiVectorIndex(dim=16, backend="plaid", doc_maxlen=24,
                             n_centroids=32, quant_bits=4, ndocs=16)
    flat = MultiVectorIndex(dim=16, backend="flat", doc_maxlen=24)
    plaid.add(docs)
    flat.add(docs)
    qs = np.stack([docs[d][:6] for d in (3, 17, 42)])
    S, I = plaid.search_batch(qs, k=5)
    hits = 0
    for n, d in enumerate((3, 17, 42)):
        s, i = plaid.search(qs[n], k=5)
        valid = I[n] >= 0
        assert np.array_equal(I[n][valid], i)
        np.testing.assert_allclose(S[n][valid], s, rtol=1e-5)
        _, i_flat = flat.search(qs[n], k=5)
        hits += int(i_flat[0] in list(I[n][:3]))
    assert hits >= 2


def test_plaid_standalone_batch_matches_single():
    from repro.core.ivf import train_centroids
    from repro.core.plaid import (build_plaid_index, plaid_search,
                                  plaid_search_batch)
    from repro.core.quantization import train_codec
    rng = np.random.default_rng(4)
    docs = unit_docs(rng, n=40)
    flat = np.concatenate(docs)
    cents = train_centroids(flat, 16)
    codec = train_codec(jnp.asarray(flat), cents, bits=4)
    index = build_plaid_index(docs, codec, doc_maxlen=24)
    qs = unit_queries(rng, 8)
    S, I = plaid_search_batch(index, qs, k=5, ndocs=64)
    for n in range(8):
        s, i = plaid_search(index, qs[n], k=5, ndocs=64)
        valid = I[n] >= 0
        assert np.array_equal(I[n][valid], i)
        np.testing.assert_allclose(S[n][valid], s, rtol=1e-5)


def test_cascade_batch_matches_single():
    from repro.retrieval.cascade import CascadeIndex
    rng = np.random.default_rng(5)
    idx = CascadeIndex(dim=16, candidates=12, doc_maxlen=24)
    idx.add(unit_docs(rng, n=30, lo=2, hi=6), unit_docs(rng, n=30))
    qs = unit_queries(rng, 9)
    S, I = idx.search_batch(qs, k=6)
    for n in range(9):
        s, i = idx.search(qs[n], k=6)
        valid = I[n] >= 0
        assert np.array_equal(I[n][valid], i)
        np.testing.assert_allclose(S[n][valid], s, rtol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_q_mask_excludes_tokens_everywhere(backend):
    """Masked query tokens must not influence ANY stage — candidate
    probing and approx pruning included, not just the exact rerank."""
    rng = np.random.default_rng(11)
    idx = make_index(backend)
    idx.add(unit_docs(rng))
    qs = unit_queries(rng, 4)
    S0, I0 = idx.search_batch(qs, k=5)
    garbage = 100 * rng.normal(size=(4, 2, 16)).astype(np.float32)
    qs2 = np.concatenate([qs, garbage], axis=1)
    qmask = np.concatenate([np.ones((4, qs.shape[1]), bool),
                            np.zeros((4, 2), bool)], axis=1)
    S1, I1 = idx.search_batch(qs2, k=5, q_mask=qmask)
    assert np.array_equal(I0, I1), backend
    np.testing.assert_allclose(S0, S1, rtol=1e-4, atol=1e-4)


def test_empty_index_search():
    idx = MultiVectorIndex(dim=16, backend="flat", doc_maxlen=24)
    S, I = idx.search_batch(np.zeros((3, 4, 16), np.float32), k=5)
    assert (I == -1).all() and np.isneginf(S).all()
