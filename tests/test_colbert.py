"""ColBERT encoder behaviour + contrastive training sanity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models.colbert import (colbert_loss, encode_docs, encode_queries,
                                  init_colbert, prepare_doc_tokens,
                                  prepare_query_tokens, MASK_ID, Q_MARK_ID,
                                  D_MARK_ID, CLS_ID)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("colbertv2")
    params = init_colbert(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_query_expansion(setup):
    cfg, _ = setup
    toks = jnp.asarray([[100, 101, 0, 0]], jnp.int32)
    out, attn = prepare_query_tokens(toks, cfg.query_maxlen)
    out = np.asarray(out)[0]
    assert out[0] == CLS_ID and out[1] == Q_MARK_ID
    assert out[2] == 100 and out[3] == 101
    assert (out[4:] == MASK_ID).all()          # PAD -> MASK expansion
    assert np.asarray(attn).all()              # expansion tokens attend


def test_doc_markers_and_emit_mask(setup):
    cfg, params = setup
    # token 9 is punctuation (N_SPECIAL..N_SPECIAL+N_PUNCT)
    toks = jnp.asarray([[100, 9, 101, 0, 0, 0]], jnp.int32)
    v, emit = encode_docs(params, toks, cfg)
    prepared, _ = prepare_doc_tokens(toks, cfg.doc_maxlen)
    assert np.asarray(prepared)[0, 1] == D_MARK_ID
    e = np.asarray(emit)[0]
    assert e[2] and not e[3] and e[4]          # punct masked out
    assert not e[5:].any()                     # padding masked out
    # emitted vectors are unit norm, masked rows zero
    vn = np.linalg.norm(np.asarray(v)[0], axis=-1)
    np.testing.assert_allclose(vn[e], 1.0, atol=1e-4)
    assert (vn[~e] == 0).all()


def test_unit_vectors_queries(setup):
    cfg, params = setup
    toks = jnp.asarray([[100, 101, 102, 0]], jnp.int32)
    v, m = encode_queries(params, toks, cfg)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(v)[0], axis=-1), 1.0, atol=1e-4)


def test_colbert_contrastive_training_learns(setup):
    """A few steps of in-batch-negative training must beat random acc."""
    cfg, params = setup
    from repro.train.optimizer import make_optimizer
    rng = np.random.default_rng(0)
    B = 8
    # queries literally share tokens with their positive docs
    docs = rng.integers(24, cfg.trunk.vocab_size, (B, 24)).astype(np.int32)
    qs = docs[:, :4].copy()
    opt = make_optimizer("adamw", 3e-3)
    state = opt.init(params)
    accs = []
    for step in range(8):
        (loss, m), grads = jax.value_and_grad(colbert_loss, has_aux=True)(
            params, jnp.asarray(qs), jnp.asarray(docs), cfg)
        params, state = opt.update(params, grads, state)
        accs.append(float(m["acc"]))
    assert accs[-1] >= max(accs[0], 1.0 / B)
    assert np.isfinite(float(loss))


def test_pooling_preserves_doc_identity(setup):
    """Pooled doc reps should still retrieve the right doc (smoke-level
    check of the paper's core claim on an untrained encoder)."""
    from repro.core.maxsim import maxsim_scores
    from repro.core.pooling import pool_doc_embeddings
    cfg, params = setup
    rng = np.random.default_rng(1)
    docs = rng.integers(24, cfg.trunk.vocab_size, (6, 32)).astype(np.int32)
    qs = docs[:, :5].copy()
    dv, dm = encode_docs(params, jnp.asarray(docs), cfg)
    qv, qm = encode_queries(params, jnp.asarray(qs), cfg)
    base = np.asarray(maxsim_scores(qv, qm, dv, dm)).argmax(1)
    pooled, pmask = pool_doc_embeddings(dv, dm, 2, "ward")
    pool2 = np.asarray(maxsim_scores(qv, qm, pooled, pmask)).argmax(1)
    assert (base == pool2).mean() >= 0.8
