"""Fused compressed-domain rerank kernel vs the decode+maxsim oracle.

Sweeps bits x dim x token counts with hypothesis (real codecs trained on
random unit vectors, like test_kernels.test_dequant_score_sweep), pins
the BITWISE contract of the jnp reference path against the legacy
reconstruction composition (``quantization.decode`` -> jitted
``maxsim_rerank_ref``), and covers the degenerate edges (all-masked
rows, empty candidate slots, single-candidate slabs). interpret=True
executes the Pallas kernel body on CPU.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

# only the shape sweep needs hypothesis (PR 1 convention: skip, don't
# fail, in containers without it); the deterministic parity/edge tests
# below run everywhere
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.quantization import decode, encode, train_codec
from repro.kernels.maxsim.ref import maxsim_rerank_ref
from repro.kernels.maxsim_packed.ops import maxsim_packed_rerank
from repro.kernels.maxsim_packed.ref import maxsim_packed_rerank_ref

_rerank_jnp = jax.jit(maxsim_rerank_ref)


def packed_case(seed, bits, dim, nq, s, ld, lq):
    """Train a real codec on random unit vectors and encode a slab grid."""
    rng = np.random.default_rng(seed)
    m = max(nq * s * ld, 64)
    vecs = rng.normal(size=(m, dim)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=-1, keepdims=True)
    cents = rng.normal(size=(16, dim)).astype(np.float32)
    cents /= np.linalg.norm(cents, axis=-1, keepdims=True)
    codec = train_codec(jnp.asarray(vecs), jnp.asarray(cents), bits=bits)
    ids, words = encode(codec, jnp.asarray(vecs))
    n = nq * s * ld
    ids = jnp.asarray(np.asarray(ids)[:n].reshape(nq, s, ld))
    words = jnp.asarray(np.asarray(words)[:n].reshape(nq, s, ld, -1))
    dm = jnp.asarray(rng.random((nq, s, ld)) < 0.85)
    q = jnp.asarray(rng.normal(size=(nq, lq, dim)), jnp.float32)
    qm = jnp.asarray(rng.random((nq, lq)) < 0.9)
    return codec, q, qm, words, ids, dm


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10 ** 6), bits=st.sampled_from((2, 4)),
           dim=st.sampled_from((32, 64)), nq=st.integers(1, 3),
           s=st.integers(1, 9), ld=st.integers(1, 6), lq=st.integers(1, 5))
    def test_packed_kernel_matches_decode_ref(seed, bits, dim, nq, s,
                                              ld, lq):
        """Fused unpack+reconstruct+maxsim == decode-then-maxsim."""
        codec, q, qm, words, ids, dm = packed_case(seed, bits, dim, nq,
                                                   s, ld, lq)
        out = maxsim_packed_rerank(q, qm, words, ids, dm,
                                   codec.centroids, codec.values,
                                   bits=bits, block_s=4)
        ref = maxsim_packed_rerank_ref(q, qm, words, ids, dm,
                                       codec.centroids, codec.values,
                                       bits=bits)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4 * max(ld, 1))


@pytest.mark.parametrize("bits", [2, 4])
def test_packed_ref_bitwise_vs_reconstruction_path(bits):
    """The parity contract: the packed reference scores are BITWISE what
    the legacy path produces — eager ``quantization.decode`` into an f32
    slab, then the same jitted rerank oracle the CPU dispatcher uses."""
    codec, q, qm, words, ids, dm = packed_case(7, bits, 64, 2, 5, 4, 3)
    nq, s, ld, w = words.shape
    packed = maxsim_packed_rerank_ref(q, qm, words, ids, dm,
                                      codec.centroids, codec.values,
                                      bits=bits)
    d = decode(codec, ids.reshape(-1), words.reshape(-1, w))
    d = d.reshape(nq, s, ld, codec.dim)
    recon = _rerank_jnp(q, qm, d, dm)
    assert np.array_equal(
        np.asarray(packed).view(np.int32),
        np.asarray(recon).view(np.int32)), "packed scores drifted bitwise"


def test_packed_kernel_all_masked_rows():
    """Fully masked doc tokens score 0 (like the recon path), never NaN."""
    codec, q, qm, words, ids, dm = packed_case(3, 2, 32, 2, 4, 3, 2)
    dm = jnp.zeros_like(dm)
    out = maxsim_packed_rerank(q, qm, words, ids, dm,
                               codec.centroids, codec.values, bits=2)
    assert np.array_equal(np.asarray(out), np.zeros(out.shape, np.float32))
    qm0 = jnp.zeros_like(qm)
    _, _, _, _, _, dm_live = packed_case(3, 2, 32, 2, 4, 3, 2)
    out = maxsim_packed_rerank(q, qm0, words, ids, dm_live,
                               codec.centroids, codec.values, bits=2)
    assert np.array_equal(np.asarray(out), np.zeros(out.shape, np.float32))


def test_packed_kernel_single_candidate():
    """S=1 (below block_s: the wrapper pads the slab axis)."""
    codec, q, qm, words, ids, dm = packed_case(11, 4, 32, 1, 1, 2, 2)
    out = maxsim_packed_rerank(q, qm, words, ids, dm,
                               codec.centroids, codec.values,
                               bits=4, block_s=8)
    ref = maxsim_packed_rerank_ref(q, qm, words, ids, dm,
                                   codec.centroids, codec.values, bits=4)
    assert out.shape == (1, 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_packed_store_empty_candidate_rows():
    """A query whose candidate mask is all-False comes back -inf across
    the row (the store-level contract topk_with_pads turns into -1 ids)."""
    from repro.core.index import MultiVectorIndex
    from repro.core.plaid import maxsim_packed_rerank_store
    rng = np.random.default_rng(0)
    docs = [rng.normal(size=(6, 32)).astype(np.float32) for _ in range(8)]
    idx = MultiVectorIndex(dim=32, backend="plaid", n_centroids=8,
                           doc_maxlen=16)
    idx.add(docs)
    q = jnp.asarray(rng.normal(size=(2, 3, 32)), jnp.float32)
    qm = jnp.ones((2, 3), bool)
    cand = np.zeros((2, 4), np.int64)
    cmask = np.array([[True, True, False, False],
                      [False, False, False, False]])
    s = maxsim_packed_rerank_store(idx._plaid, q, qm, cand, cmask)
    s = np.asarray(s)
    assert np.isfinite(s[0, :2]).all()
    assert (s[0, 2:] == -np.inf).all() and (s[1] == -np.inf).all()
