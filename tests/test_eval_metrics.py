"""Pin the batched device metrics (repro.eval.metrics) against the
pure-numpy reference (repro.retrieval.metrics).

The integer structures — the [Nq, k] graded-gain matrix and MRR's
first-hit ranks — are pinned BITWISE against a per-query dict walk;
the float metric means get allclose (the device path sums f32 in a
different order than the reference's f64 loop).
"""
from __future__ import annotations

import numpy as np
import pytest

try:  # hypothesis gates only the sweep tests, not the fixed fixtures
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

from repro.eval import metrics as M
from repro.retrieval import metrics as R

REFERENCE = {"ndcg": R.ndcg_at_k, "recall": R.recall_at_k,
             "success": R.success_at_k, "mrr": R.mrr_at_k}


def reference_gains(ranked, qrels):
    """The dict walk the device gain matrix must reproduce bitwise."""
    out = np.zeros(ranked.shape, np.int32)
    for i, qrel in enumerate(qrels):
        for j, d in enumerate(ranked[i]):
            out[i, j] = qrel.get(int(d), 0) if int(d) >= 0 else 0
    return out


def reference_first_hits(ranked, qrels, k):
    out = np.zeros(len(qrels), np.int32)
    for i, qrel in enumerate(qrels):
        for pos, d in enumerate(ranked[i][:k], start=1):
            if qrel.get(int(d), 0) > 0:
                out[i] = pos
                break
    return out


def random_case(rng, n_queries, n_docs, k, graded=True):
    ranked = np.stack([rng.permutation(n_docs)[:k]
                       for _ in range(n_queries)]).astype(np.int64)
    qrels = []
    for _ in range(n_queries):
        n = int(rng.integers(0, min(6, n_docs) + 1))
        docs = rng.permutation(n_docs)[:n]
        hi = 4 if graded else 2          # gains in [0, hi)
        qrels.append({int(d): int(rng.integers(0, hi)) for d in docs})
    return ranked, qrels


def assert_matches_reference(ranked, qrels, k):
    np.testing.assert_array_equal(
        M.ranked_gains(ranked, qrels), reference_gains(ranked, qrels))
    np.testing.assert_array_equal(
        M.first_hit_ranks(ranked, qrels, k),
        reference_first_hits(ranked, qrels, k))
    as_lists = [list(map(int, row)) for row in ranked]
    for base, ref in REFERENCE.items():
        mine = M.metric_fn(f"{base}@{k}")(ranked, qrels)
        theirs = ref(as_lists, qrels, k)
        assert mine == pytest.approx(theirs, abs=1e-6), (base, k)


# ---------------------------------------------------------------------------
# seeded sweep (always runs)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(8))
def test_metrics_match_reference_seeded(seed):
    rng = np.random.default_rng(seed)
    n_docs = int(rng.integers(5, 60))
    k = int(rng.integers(1, 15))
    ranked, qrels = random_case(rng, int(rng.integers(1, 12)),
                                n_docs, min(k, n_docs),
                                graded=bool(seed % 2))
    assert_matches_reference(ranked, qrels, k)


# ---------------------------------------------------------------------------
# hypothesis sweep
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2**31 - 1),
           n_queries=st.integers(1, 10),
           n_docs=st.integers(2, 50),
           k=st.integers(1, 12),
           graded=st.booleans())
    @settings(max_examples=60, deadline=None)
    def test_metrics_match_reference_hypothesis(seed, n_queries, n_docs,
                                                k, graded):
        rng = np.random.default_rng(seed)
        ranked, qrels = random_case(rng, n_queries, n_docs,
                                    min(k, n_docs), graded)
        assert_matches_reference(ranked, qrels, k)
else:  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_metrics_match_reference_hypothesis():
        pass


# ---------------------------------------------------------------------------
# edge cases
# ---------------------------------------------------------------------------
def test_empty_qrels_are_skipped_not_zeroed():
    ranked = np.array([[0, 1, 2], [2, 1, 0]])
    qrels = [{}, {2: 1}]
    # query 0 is unjudged: it must not drag the mean down
    assert M.ndcg_at_k(ranked, qrels, 3) == pytest.approx(1.0)
    assert M.success_at_k(ranked, qrels, 3) == 1.0
    assert M.mrr_at_k(ranked, qrels, 3) == 1.0
    assert M.recall_at_k(ranked, qrels, 3) == 1.0
    # all-empty qrels: every metric is 0.0, not NaN
    for name in M.DEFAULT_METRICS:
        assert M.metric_fn(name)(ranked, [{}, {}]) == 0.0


def test_judged_but_all_irrelevant_counts_as_zero():
    # gain-0 judgments: ndcg/success/mrr SCORE the query (0.0), recall
    # skips it — the reference's exact convention
    ranked = np.array([[0, 1], [0, 1]])
    qrels = [{0: 0, 1: 0}, {0: 1}]
    assert M.success_at_k(ranked, qrels, 2) == pytest.approx(0.5)
    assert M.recall_at_k(ranked, qrels, 2) == pytest.approx(1.0)
    assert M.ndcg_at_k(ranked, qrels, 2) == pytest.approx(0.5)


def test_relevant_doc_outside_top_k():
    ranked = np.array([[3, 4, 5, 6, 7, 8, 9, 10, 11, 0]])
    qrels = [{0: 3}]
    assert M.success_at_k(ranked, qrels, 5) == 0.0
    assert M.recall_at_k(ranked, qrels, 5) == 0.0
    assert M.mrr_at_k(ranked, qrels, 10) == pytest.approx(0.1)
    assert M.first_hit_ranks(ranked, qrels, 5)[0] == 0
    assert M.first_hit_ranks(ranked, qrels, 10)[0] == 10


def test_k_larger_than_n_docs_with_pads():
    # search_batch pads short result rows with -1: k > n_docs must not
    # crash or let pads match anything
    ranked = np.array([[1, 0, -1, -1, -1]])
    qrels = [{0: 2, 1: 1}]
    assert_matches_reference(ranked, qrels, 5)
    assert M.recall_at_k(ranked, qrels, 5) == pytest.approx(1.0)
    g = M.ranked_gains(ranked, qrels)
    np.testing.assert_array_equal(g, [[1, 2, 0, 0, 0]])


def test_graded_vs_binary_gains_change_ndcg_only_in_order():
    # same doc set, graded qrels: ranking the grade-3 doc first beats
    # ranking it second; binary qrels are order-insensitive at full
    # recall depth
    good = np.array([[7, 8]])
    bad = np.array([[8, 7]])
    graded = [{7: 3, 8: 1}]
    assert M.ndcg_at_k(good, graded, 2) > M.ndcg_at_k(bad, graded, 2)
    assert M.ndcg_at_k(good, graded, 2) == pytest.approx(1.0)
    binary = [{7: 1, 8: 1}]
    assert M.ndcg_at_k(good, binary, 2) == \
        pytest.approx(M.ndcg_at_k(bad, binary, 2))
    assert M.recall_at_k(good, graded, 2) == \
        M.recall_at_k(bad, graded, 2) == 1.0


def test_tied_scores_resolve_by_rank_position():
    # two equally-graded docs: whichever the searcher ranked first
    # takes the better discount, and MRR takes the earlier position
    ranked = np.array([[5, 6, 1]])
    qrels = [{5: 2, 6: 2}]
    assert M.first_hit_ranks(ranked, qrels, 3)[0] == 1
    assert M.ndcg_at_k(ranked, qrels, 3) == pytest.approx(1.0)


def test_padded_qrels_packing():
    q = M.PaddedQrels.from_dicts([{3: 2, 5: 1}, {}, {0: 0}])
    assert q.ids.shape == (3, 2) and q.gains.shape == (3, 2)
    np.testing.assert_array_equal(q.judged, [True, False, True])
    np.testing.assert_array_equal(q.has_positive, [True, False, False])
    assert q.ids[1].tolist() == [-1, -1]
    assert q.gains[1].tolist() == [0, 0]
    # degenerate: no judgments anywhere keeps a non-empty R axis
    q0 = M.PaddedQrels.from_dicts([{}])
    assert q0.ids.shape == (1, 1)


def test_metric_name_parsing():
    assert M.parse_metric("ndcg@10") == ("ndcg", 10)
    assert M.parse_metric("success@5") == ("success", 5)
    for bad in ("ndcg", "ndcg@0", "nope@10", "ndcg@x", "ndcg@10@2"):
        with pytest.raises(ValueError):
            M.parse_metric(bad)
    assert M.max_k(("ndcg@10", "recall@5", "mrr@12")) == 12


def test_compute_metrics_and_rankings_matrix():
    ranked = M.rankings_matrix([[2, 0], [1]], k=4)
    np.testing.assert_array_equal(
        ranked, [[2, 0, -1, -1], [1, -1, -1, -1]])
    out = M.compute_metrics(ranked, [{2: 1}, {0: 1}],
                            ("ndcg@4", "success@4", "mrr@4"))
    assert out["success@4"] == pytest.approx(0.5)
    assert out["mrr@4"] == pytest.approx(0.5)


def test_old_metric_registry_still_reference():
    # the deprecated registry and the new name->fn resolver agree
    ranked = [[0, 1, 2, 3, 4]]
    qrels = [{1: 2, 4: 1}]
    arr = np.array(ranked)
    for name, ref in R.METRICS.items():
        assert M.metric_fn(name)(arr, qrels) == \
            pytest.approx(ref(ranked, qrels), abs=1e-6)
