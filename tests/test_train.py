"""Training substrate: optimizers, checkpoint atomicity + restart,
fault tolerance, data-pipeline determinism."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # gate: container may lack hypothesis
from hypothesis import given, settings, strategies as st

from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import (adafactor, adamw, clip_by_global_norm,
                                   cosine_schedule, global_norm,
                                   make_optimizer)
from repro.train.trainer import TrainConfig, Trainer


def quad_problem():
    params = {"w": jnp.ones((6, 3)), "b": jnp.zeros((3,))}
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(6, 3)).astype(np.float32)

    def batch():
        x = rng.normal(size=(16, 6)).astype(np.float32)
        return {"x": x, "y": x @ w_true}

    def loss_fn(p, b):
        l = jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2)
        return l, {"loss": l}

    return params, batch, loss_fn


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_descends(name):
    params, batch, loss_fn = quad_problem()
    opt = make_optimizer(name, 3e-2)
    state = opt.init(params)
    b = batch()
    l0 = float(loss_fn(params, b)[0])
    for _ in range(60):
        (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b)
        params, state = opt.update(params, g, state)
    assert float(l) < 0.2 * l0


def test_adafactor_state_is_factored():
    p = {"w": jnp.ones((64, 32))}
    state = adafactor(1e-2).init(p)
    assert state["slots"]["w"]["vr"].shape == (64,)
    assert state["slots"]["w"]["vc"].shape == (32,)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(gn), 20.0)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100, final_frac=0.1)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(100)) == pytest.approx(0.1, abs=1e-6)
    assert float(lr(55)) > float(lr(90))


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), max_to_keep=2, async_write=False)
    tree = {"a": {"b": jnp.arange(5, dtype=jnp.float32)},
            "c": [jnp.ones((2, 2)), jnp.zeros(3)]}
    for step in (10, 20, 30):
        mgr.save(step, tree, extra={"step": step})
    assert mgr.all_steps() == [20, 30]           # gc kept last 2
    step, restored, extra = mgr.restore()
    assert step == 30 and extra["step"] == 30
    np.testing.assert_array_equal(restored["a"]["b"], np.arange(5))
    assert isinstance(restored["c"], list)
    np.testing.assert_array_equal(restored["c"][0], np.ones((2, 2)))


def test_checkpoint_no_partial_publish(tmp_path):
    """A crashed write (tmp dir left behind) must not count as a
    checkpoint."""
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    os.makedirs(tmp_path / "step_99.tmp")
    assert mgr.latest_step() is None
    mgr.save(5, {"x": jnp.ones(2)})
    assert mgr.latest_step() == 5


def test_trainer_restart_resumes(tmp_path):
    params, batch, loss_fn = quad_problem()

    def batches():
        while True:
            yield batch()

    tc = TrainConfig(total_steps=20, checkpoint_every=10,
                     checkpoint_dir=str(tmp_path), lr=1e-2, log_every=5)
    t1 = Trainer(loss_fn, params, tc)
    t1.run(batches())
    # new process restarts from the checkpoint, trains further
    tc2 = TrainConfig(total_steps=30, checkpoint_every=10,
                      checkpoint_dir=str(tmp_path), lr=1e-2)
    t2 = Trainer(loss_fn, params, tc2)
    assert t2.maybe_restore() == 20
    out = t2.run(batches())
    assert out["final_step"] == 30


def test_trainer_skips_nonfinite_batch():
    params, batch, loss_fn = quad_problem()
    tc = TrainConfig(total_steps=3, lr=1e-2, skip_nonfinite=True)
    t = Trainer(loss_fn, params, tc)
    bad = batch()
    bad["y"] = np.full_like(bad["y"], np.nan)

    def batches():
        yield bad
        while True:
            yield batch()

    out = t.run(batches())
    # params survived the poisoned batch (update was skipped, not applied)
    assert np.isfinite(np.asarray(t.params["w"])).all()


# ---------------------------------------------------------------- data
def test_pipeline_determinism_and_sharding():
    from repro.data.pipeline import DataPipeline
    seen = {}
    for shard in (0, 1):
        pipe = DataPipeline(64, 4, lambda ids: {"ids": ids.copy()},
                            seed=3, shard_index=shard, shard_count=2)
        it = pipe.batches()
        seen[shard] = [tuple(next(it)["ids"]) for _ in range(4)]
    # same shard twice -> identical (deterministic restart)
    pipe = DataPipeline(64, 4, lambda ids: {"ids": ids.copy()},
                        seed=3, shard_index=0, shard_count=2)
    it = pipe.batches()
    again = [tuple(next(it)["ids"]) for _ in range(4)]
    assert again == seen[0]
    # shards are disjoint
    flat0 = {i for b in seen[0] for i in b}
    flat1 = {i for b in seen[1] for i in b}
    assert not (flat0 & flat1)


def test_pipeline_fast_forward():
    from repro.data.pipeline import DataPipeline
    pipe = DataPipeline(64, 4, lambda ids: {"ids": ids.copy()}, seed=9,
                        shard_index=0, shard_count=1)
    it = pipe.batches()
    batches = [tuple(next(it)["ids"]) for _ in range(6)]
    pipe2 = DataPipeline(64, 4, lambda ids: {"ids": ids.copy()}, seed=9,
                         shard_index=0, shard_count=1)
    it2 = pipe2.batches(start_step=3)
    assert tuple(next(it2)["ids"]) == batches[3]


@settings(max_examples=10, deadline=None)
@given(vocab=st.integers(200, 5000))
def test_tokenizer_deterministic_and_in_range(vocab):
    from repro.data.tokenizer import FIRST_WORD_ID, HashTokenizer
    tok = HashTokenizer(vocab)
    ids = tok.encode("The quick brown fox, jumps! Over the lazy dog.")
    assert ids == tok.encode("The quick brown fox, jumps! Over the lazy dog.")
    assert all(0 <= i < vocab for i in ids)
    words = [i for i in ids if i >= FIRST_WORD_ID]
    assert len(set(words)) >= 6
    # same word same id, case-insensitive
    assert tok.encode("Fox") == tok.encode("fox")


def test_corpus_queries_hit_source_doc():
    from repro.data.corpus import DatasetSpec, SyntheticRetrievalCorpus
    c = SyntheticRetrievalCorpus(DatasetSpec("x", n_docs=50, n_queries=10,
                                             n_topics=5, seed=4),
                                 vocab_size=30522)
    for q, rel in zip(c.queries, c.qrels):
        src = [d for d, r in rel.items() if r == 2]
        assert len(src) == 1
        doc_words = set(int(w) for w in c.docs[src[0]])
        assert all(int(w) in doc_words for w in q)
