"""Sharding layer: logical rules, param-spec pattern matching, and a
subprocess smoke of the real dry-run (which needs 512 host devices)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.models.layers import tree_paths
from repro.sharding.api import (constrain, lm_decode_rules,
                                lm_long_decode_rules, lm_rules,
                                mesh_context)
from repro.sharding.params import (lm_param_rules, opt_state_specs,
                                   param_specs, spec_for_path)


def test_lm_param_rules_matching():
    rules = lm_param_rules("data")
    assert spec_for_path("moe_layers/attn/wq/w", 3, rules) == \
        P(None, "data", "model")
    assert spec_for_path("dense_layers/attn/wo/w", 3, rules) == \
        P(None, "model", "data")
    assert spec_for_path("moe_layers/moe/w1", 4, rules) == \
        P(None, "model", "data", None)
    assert spec_for_path("embed/table", 2, rules) == P("model", "data")
    assert spec_for_path("final_norm/scale", 1, rules) == P(None)
    assert spec_for_path("unknown/thing", 2, rules) == P()


def test_param_specs_cover_full_tree():
    from repro.models.transformer import init_transformer
    cfg = get_smoke_config("kimi-k2-1t-a32b")
    p = jax.eval_shape(lambda k: init_transformer(k, cfg),
                       jax.random.PRNGKey(0))
    specs = param_specs(p, lm_param_rules("data"))
    flat_p = dict(tree_paths(p))
    flat_s = dict(tree_paths(specs)) if False else None
    # same tree structure
    assert jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda x: 0, p)) == \
        jax.tree_util.tree_structure(
            jax.tree_util.tree_map(lambda s: 0, specs,
                                   is_leaf=lambda x: isinstance(x, P)))
    # every spec rank matches its leaf rank or is replicated
    def check(leaf, spec):
        assert isinstance(spec, P)
        assert len(spec) in (0, len(leaf.shape))
    jax.tree_util.tree_map(check, p, specs,
                           is_leaf=lambda x: hasattr(x, "shape"))


def test_opt_state_specs_adafactor_reduced_dims():
    from repro.models.transformer import init_transformer
    from repro.train.optimizer import make_optimizer
    cfg = get_smoke_config("kimi-k2-1t-a32b")
    p = jax.eval_shape(lambda k: init_transformer(k, cfg),
                       jax.random.PRNGKey(0))
    specs = param_specs(p, lm_param_rules("data"))
    opt = make_optimizer("adafactor", 1e-3)
    o = jax.eval_shape(opt.init, p)
    o_specs = opt_state_specs(o, specs, "adafactor")
    # moe w1 [L, E, d, f] -> spec (None, model, data, None);
    # vr drops last dim, vc drops second-to-last
    slot = o_specs["slots"]["moe_layers"]["moe"]["w1"]
    assert slot["vr"] == P(None, "model", "data")
    assert slot["vc"] == P(None, "model", None)


def test_rules_consistency():
    r = lm_rules("data", attn_shard="heads")
    assert r["heads"] == "model" and r["qseq"] is None
    r2 = lm_rules("data", attn_shard="sequence")
    assert r2["heads"] is None and r2["qseq"] == "model"
    rd = lm_decode_rules("data")
    assert rd["kvseq"] == "model"
    rl = lm_long_decode_rules("data")
    assert rl["kvseq"] == ("data", "model") and rl["batch"] is None


def test_constrain_noop_without_context():
    x = jnp.ones((4, 4))
    y = constrain(x, "batch", None)
    assert y is x


def test_constrain_applies_in_context():
    mesh = jax.make_mesh((1,), ("data",))
    with mesh_context(mesh, {"batch": "data"}):
        y = jax.jit(lambda x: constrain(x, "batch", None))(jnp.ones((4, 4)))
    assert y.shape == (4, 4)


@pytest.mark.slow
def test_dryrun_subprocess_one_cell():
    """The real dry-run entry point, in a fresh process (512 host devs)."""
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", "qwen3-0.6b", "--cell", "decode_32k"]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"}, cwd="/root/repo")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "1 ok, 0 failed" in r.stdout
