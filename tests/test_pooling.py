"""Core technique tests: Ward == SciPy, k-means sanity, pooling invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # gate: container may lack hypothesis
from hypothesis import given, settings, strategies as st
from scipy.cluster.hierarchy import fcluster, linkage

from repro.core.kmeans import kmeans_cluster_batch
from repro.core.pooling import (compact_pooled, pool_doc_embeddings,
                                vector_counts)
from repro.core.ward import ward_cluster_batch


def canon(labels):
    """Canonical form of a partition labelling (first-appearance order)."""
    m, out = {}, []
    for v in labels:
        if v not in m:
            m[v] = len(m)
        out.append(m[v])
    return tuple(out)


@pytest.mark.parametrize("factor", [2, 3, 4, 6])
@pytest.mark.parametrize("seed", [0, 1])
def test_ward_matches_scipy(factor, seed):
    rng = np.random.default_rng(seed)
    B, N, d = 4, 32, 16
    x = rng.normal(size=(B, N, d)).astype(np.float32)
    mask = np.ones((B, N), bool)
    mask[1, 25:] = False
    mask[3, 10:] = False
    assign = np.asarray(ward_cluster_batch(jnp.asarray(x),
                                           jnp.asarray(mask), factor))
    for b in range(B):
        xv = x[b][mask[b]]
        xv /= np.linalg.norm(xv, axis=-1, keepdims=True)
        k = xv.shape[0] // factor + 1
        sc = fcluster(linkage(xv, method="ward"), t=k, criterion="maxclust")
        assert canon(sc) == canon(assign[b][mask[b]]), (b, factor)


def test_ward_cosine_equals_euclidean_on_unit_vectors():
    # the monotone-map equivalence the paper's method relies on
    rng = np.random.default_rng(7)
    x = rng.normal(size=(20, 8)).astype(np.float32)
    xu = x / np.linalg.norm(x, axis=-1, keepdims=True)
    a1 = np.asarray(ward_cluster_batch(jnp.asarray(x[None]),
                                       jnp.ones((1, 20), bool), 3))[0]
    a2 = np.asarray(ward_cluster_batch(jnp.asarray(3.7 * xu[None]),
                                       jnp.ones((1, 20), bool), 3))[0]
    assert canon(a1) == canon(a2)   # scaling is normalized away


@pytest.mark.parametrize("method", ["sequential", "kmeans", "ward"])
@pytest.mark.parametrize("factor", [2, 3, 4])
def test_pooling_reduces_count(method, factor):
    rng = np.random.default_rng(factor)
    B, N, d = 3, 48, 8
    x = rng.normal(size=(B, N, d)).astype(np.float32)
    mask = np.ones((B, N), bool)
    mask[0, 40:] = False
    pooled, pmask = pool_doc_embeddings(jnp.asarray(x), jnp.asarray(mask),
                                        factor, method)
    n_raw, n_pool = vector_counts(jnp.asarray(mask), pmask)
    assert n_pool <= n_raw // factor + B   # at most floor(n/f)+1 per doc
    assert n_pool >= B                      # at least one vector per doc
    # pooled vectors are unit (renormalized means)
    vecs = np.concatenate(compact_pooled(pooled, pmask))
    np.testing.assert_allclose(np.linalg.norm(vecs, axis=-1), 1.0,
                               atol=1e-4)


def test_pool_factor_one_is_identity():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(2, 16, 8)).astype(np.float32)
    mask = np.ones((2, 16), bool)
    pooled, pmask = pool_doc_embeddings(jnp.asarray(x), jnp.asarray(mask),
                                        1, "ward")
    xu = x / np.linalg.norm(x, axis=-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(pooled), xu, atol=1e-5)
    assert np.asarray(pmask).all()


def test_identical_vectors_collapse():
    """Pooling identical token vectors must keep the shared direction."""
    v = np.ones((1, 12, 8), np.float32)
    mask = np.ones((1, 12), bool)
    pooled, pmask = pool_doc_embeddings(jnp.asarray(v), jnp.asarray(mask),
                                        4, "ward")
    vecs = compact_pooled(pooled, pmask)[0]
    expect = np.ones(8) / np.sqrt(8)
    for row in vecs:
        np.testing.assert_allclose(row, expect, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(6, 40), factor=st.integers(2, 6),
       seed=st.integers(0, 10 ** 6))
def test_property_cluster_count_bound(n, factor, seed):
    """Property: every method yields <= floor(n/f)+1 clusters, >= 1."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(1, n, 8)).astype(np.float32)
    mask = np.ones((1, n), bool)
    for method in ("ward", "kmeans", "sequential"):
        pooled, pmask = pool_doc_embeddings(
            jnp.asarray(x), jnp.asarray(mask), factor, method)
        k = int(np.asarray(pmask).sum())
        if method == "sequential":
            assert k == -(-n // factor)
        else:
            assert 1 <= k <= n // factor + 1


def test_kmeans_clusters_topical_data():
    """k-means on clearly separable directions recovers the grouping."""
    rng = np.random.default_rng(3)
    centers = np.eye(4, 16, dtype=np.float32)
    x = np.repeat(centers[None], 8, axis=1).reshape(1, 32, 16)
    x += 0.01 * rng.normal(size=x.shape).astype(np.float32)
    mask = np.ones((1, 32), bool)
    # factor 10 -> k_target = 32//10 + 1 = 4 clusters = the 4 directions
    assign = np.asarray(kmeans_cluster_batch(jnp.asarray(x),
                                             jnp.asarray(mask), 10))[0]
    groups = assign.reshape(4, 8)       # tokens are blocked per direction
    assert all(len(set(groups[i])) == 1 for i in range(4))
    assert len({groups[i][0] for i in range(4)}) == 4
