"""ShardedIndex: shard-merge parity with the monolithic index, doc-id
routing, persistence dispatch, and the bounded-memory streaming build.

Parity regime: every backend's candidate stage is made exhaustive
(generous hnsw_candidates / nprobe / ndocs) and plaid shares ONE codec
across shards and with the monolithic reference — under that contract
``ShardedIndex.search_batch`` must equal the monolithic result exactly
(ids AND scores), which is the acceptance bar for the sharded engine.
"""
import os

import numpy as np
import pytest

from repro.core.index import MultiVectorIndex
from repro.core.persist import IndexFormatError, artifact_bytes, load_artifact
from repro.core.sharded import ShardedIndex

BACKENDS = ["flat", "hnsw", "plaid"]
KW = dict(doc_maxlen=24, n_centroids=16, ndocs=4096, hnsw_candidates=8192)
DIM = 16


def unit_docs(rng, n=40, dim=DIM, lo=4, hi=20):
    docs = []
    for _ in range(n):
        v = rng.normal(size=(rng.integers(lo, hi), dim)).astype(np.float32)
        docs.append(v / np.linalg.norm(v, axis=-1, keepdims=True))
    return docs


def unit_queries(rng, n=6, lq=5, dim=DIM):
    q = rng.normal(size=(n, lq, dim)).astype(np.float32)
    return q / np.linalg.norm(q, axis=-1, keepdims=True)


def build_pair(backend, docs, cap=160):
    """(sharded, monolithic) over the same corpus, one codec for plaid."""
    sharded = ShardedIndex(dim=DIM, backend=backend,
                           shard_max_vectors=cap, **KW)
    sharded.add(docs)
    mono = MultiVectorIndex(dim=DIM, backend=backend, **KW)
    if backend == "plaid":
        mono.set_codec(sharded.codec())
    mono.add(docs)
    return sharded, mono


# ------------------------------------------------------------------- parity
@pytest.mark.parametrize("backend", BACKENDS)
def test_sharded_matches_monolithic_exactly(backend):
    rng = np.random.default_rng(0)
    docs, qs = unit_docs(rng), unit_queries(rng)
    sharded, mono = build_pair(backend, docs)
    assert sharded.n_shards >= 2            # the cap actually sharded it
    assert sharded.n_docs == mono.n_docs
    assert sharded.n_vectors() == mono.n_vectors()
    S1, I1 = sharded.search_batch(qs, k=8)
    S0, I0 = mono.search_batch(qs, k=8)
    np.testing.assert_array_equal(I0, I1)
    np.testing.assert_array_equal(np.asarray(S0), np.asarray(S1))


@pytest.mark.parametrize("backend", BACKENDS)
def test_sharded_delete_parity(backend):
    rng = np.random.default_rng(1)
    docs, qs = unit_docs(rng), unit_queries(rng)
    sharded, mono = build_pair(backend, docs)
    victims = [0, 13, 25, 39]               # spread across shards
    sharded.delete(victims)
    mono.delete(victims)
    S1, I1 = sharded.search_batch(qs, k=10)
    S0, I0 = mono.search_batch(qs, k=10)
    np.testing.assert_array_equal(I0, I1)
    assert not np.isin(I1[I1 >= 0], victims).any()


def test_tie_break_order_matches_monolithic():
    """Duplicate docs across a shard boundary score identically; the
    merged top-k must order them lowest-global-id-first, like the
    monolithic engine does."""
    rng = np.random.default_rng(2)
    base = unit_docs(rng, n=6, lo=5, hi=9)
    docs = base + base                      # ids 0..5 == ids 6..11
    qs = unit_queries(rng, n=4)
    sharded = ShardedIndex(dim=DIM, backend="flat",
                           shard_max_vectors=sum(len(d) for d in base),
                           **KW)
    sharded.add(docs)
    assert sharded.n_shards == 2
    mono = MultiVectorIndex(dim=DIM, backend="flat", **KW)
    mono.add(docs)
    S1, I1 = sharded.search_batch(qs, k=12)
    S0, I0 = mono.search_batch(qs, k=12)
    np.testing.assert_array_equal(I0, I1)
    # each dup pair is adjacent with the low id first
    for row in np.asarray(I1):
        pos = {int(d): i for i, d in enumerate(row)}
        for d in range(6):
            assert pos[d] == pos[d + 6] - 1, row


def test_empty_shard_is_skipped():
    rng = np.random.default_rng(3)
    docs, qs = unit_docs(rng, n=12), unit_queries(rng)
    a = MultiVectorIndex(dim=DIM, backend="flat", **KW)
    a.add(docs[:7])
    hole = MultiVectorIndex(dim=DIM, backend="flat", **KW)
    b = MultiVectorIndex(dim=DIM, backend="flat", **KW)
    b.add(docs[7:])
    sharded = ShardedIndex.from_parts([a, hole, b], [0, 7, 7])
    mono = MultiVectorIndex(dim=DIM, backend="flat", **KW)
    mono.add(docs)
    S1, I1 = sharded.search_batch(qs, k=5)
    S0, I0 = mono.search_batch(qs, k=5)
    np.testing.assert_array_equal(I0, I1)
    np.testing.assert_array_equal(np.asarray(S0), np.asarray(S1))


@pytest.mark.parametrize("backend", BACKENDS)
def test_single_doc_and_empty_index(backend):
    rng = np.random.default_rng(4)
    qs = unit_queries(rng, n=3)
    empty = ShardedIndex(dim=DIM, backend=backend, **KW)
    S, I = empty.search_batch(qs, k=4)
    assert (I == -1).all() and np.isneginf(S).all()
    one = ShardedIndex(dim=DIM, backend=backend, shard_max_vectors=8, **KW)
    ids = one.add(unit_docs(rng, n=1, lo=5, hi=9))
    np.testing.assert_array_equal(ids, [0])
    S, I = one.search_batch(qs, k=4)
    assert (I[:, 0] == 0).all()
    assert (I[:, 1:] == -1).all()


@pytest.mark.parametrize("backend", BACKENDS)
def test_empty_input_edges_are_typed_noops(backend):
    """delete([]) / add([]) / shard_of([]) are well-typed no-ops — on
    empty AND populated sharded indexes (CRUD driven from batch
    pipelines routinely hands over empty slices)."""
    rng = np.random.default_rng(13)
    qs = unit_queries(rng, n=2)
    for ix in (ShardedIndex(dim=DIM, backend=backend, **KW),):
        owner = ix.shard_of(np.array([]))
        assert owner.shape == (0,) and owner.dtype == np.int64
        assert len(ix.add([])) == 0
        ix.delete([])                           # no raise on empty index
        ix.delete(np.array([], np.int64))
    ix = ShardedIndex(dim=DIM, backend=backend, shard_max_vectors=80, **KW)
    ix.add(unit_docs(rng, n=10))
    S0, I0 = ix.search_batch(qs, k=4)
    ids = ix.add([])
    assert ids.shape == (0,) and ids.dtype == np.int64
    ix.delete([])
    owner = ix.shard_of(np.array([], np.float64))   # dtype-agnostic
    assert owner.shape == (0,) and owner.dtype == np.int64
    assert ix.n_docs == 10
    S, I = ix.search_batch(qs, k=4)
    np.testing.assert_array_equal(I0, I)
    np.testing.assert_array_equal(np.asarray(S0), np.asarray(S))


# ------------------------------------------------------------ id routing
def test_add_spills_and_ids_are_global():
    rng = np.random.default_rng(5)
    docs = unit_docs(rng, n=30, lo=6, hi=12)
    sharded = ShardedIndex(dim=DIM, backend="flat",
                           shard_max_vectors=50, **KW)
    ids = sharded.add(docs)
    np.testing.assert_array_equal(ids, np.arange(30))
    assert sharded.n_shards >= 3
    # every shard honors the cap up to one atomic doc
    for s in sharded.shards:
        assert s.n_vectors() <= 50 + 12
    # shard_of maps ranges consistently
    owner = sharded.shard_of(np.arange(30))
    assert (np.diff(owner) >= 0).all()
    for s in range(sharded.n_shards):
        assert (owner == s).sum() == sharded.shards[s].n_docs
    with pytest.raises(IndexError):
        sharded.shard_of([30])


def test_incremental_add_continues_ids_and_matches_bulk():
    rng = np.random.default_rng(6)
    docs = unit_docs(rng, n=20, lo=6, hi=12)
    qs = unit_queries(rng)
    bulk = ShardedIndex(dim=DIM, backend="flat", shard_max_vectors=60, **KW)
    bulk.add(docs)
    inc = ShardedIndex(dim=DIM, backend="flat", shard_max_vectors=60, **KW)
    got = [inc.add(docs[i:i + 3]) for i in range(0, 20, 3)]
    np.testing.assert_array_equal(np.concatenate(got), np.arange(20))
    S0, I0 = bulk.search_batch(qs, k=6)
    S1, I1 = inc.search_batch(qs, k=6)
    np.testing.assert_array_equal(I0, I1)


# ------------------------------------------------------------ persistence
@pytest.mark.parametrize("backend", BACKENDS)
def test_sharded_save_load_roundtrip(backend, tmp_path):
    rng = np.random.default_rng(7)
    docs, qs = unit_docs(rng), unit_queries(rng)
    sharded, _ = build_pair(backend, docs)
    sharded.delete([2, 21])
    S0, I0 = sharded.search_batch(qs, k=8)
    manifest = sharded.save(tmp_path / "root")
    assert manifest["kind"] == "sharded_index"
    loaded = load_artifact(tmp_path / "root", mmap=True)
    assert isinstance(loaded, ShardedIndex)
    assert loaded.n_shards == sharded.n_shards
    assert loaded.n_docs == sharded.n_docs
    S1, I1 = loaded.search_batch(qs, k=8)
    np.testing.assert_array_equal(I0, I1)
    np.testing.assert_allclose(np.asarray(S0), np.asarray(S1),
                               rtol=1e-5, atol=1e-6)
    # root bytes == sum of shard payload bytes, and > 0
    total = artifact_bytes(tmp_path / "root")
    per_shard = sum(artifact_bytes(os.path.join(tmp_path, "root", e["dir"]))
                    for e in manifest["shards"])
    assert total == per_shard > 0


def test_load_artifact_dispatches_on_kind(tmp_path):
    rng = np.random.default_rng(8)
    docs = unit_docs(rng, n=10)
    mono = MultiVectorIndex(dim=DIM, backend="flat", **KW)
    mono.add(docs)
    mono.save(tmp_path / "mono")
    assert isinstance(load_artifact(tmp_path / "mono"), MultiVectorIndex)

    sharded = ShardedIndex(dim=DIM, backend="flat",
                           shard_max_vectors=40, **KW)
    sharded.add(docs)
    sharded.save(tmp_path / "sharded")
    assert isinstance(load_artifact(tmp_path / "sharded"), ShardedIndex)

    from repro.retrieval.cascade import CascadeIndex
    cascade = CascadeIndex(dim=DIM, doc_maxlen=24)
    cascade.add(docs[:4], docs[:4])
    cascade.save(tmp_path / "cascade")
    assert isinstance(load_artifact(tmp_path / "cascade"), CascadeIndex)
    assert isinstance(CascadeIndex.from_dir(tmp_path / "cascade"),
                      CascadeIndex)
    with pytest.raises(IndexFormatError):
        CascadeIndex.from_dir(tmp_path / "sharded")
    with pytest.raises(IndexFormatError):
        ShardedIndex.load(tmp_path / "mono")


def test_empty_sharded_roundtrip(tmp_path):
    empty = ShardedIndex(dim=DIM, backend="plaid", shard_max_vectors=64,
                         **KW)
    empty.save(tmp_path / "empty")
    loaded = load_artifact(tmp_path / "empty")
    assert isinstance(loaded, ShardedIndex)
    assert loaded.n_docs == 0 and loaded.backend == "plaid"
    assert loaded.shard_max_vectors == 64


# -------------------------------------------------------- streaming build
def test_streaming_build_bounded_and_parity(tmp_path):
    """The acceptance scenario end to end with the real encoder: a cap
    smaller than the corpus yields >=2 shards, the pooled buffer never
    exceeds cap + one encode batch, and the artifact re-serves the same
    results through Searcher.from_dir."""
    import jax
    from dataclasses import replace
    from repro.configs import get_smoke_config
    from repro.data.corpus import DATASET_SPECS, SyntheticRetrievalCorpus
    from repro.models.colbert import init_colbert
    from repro.retrieval.indexer import Indexer
    from repro.retrieval.searcher import Searcher

    cfg = get_smoke_config("colbertv2")
    params = init_colbert(jax.random.PRNGKey(0), cfg)
    spec = replace(DATASET_SPECS["scifact"], n_docs=24, n_queries=3)
    corpus = SyntheticRetrievalCorpus(spec, vocab_size=cfg.trunk.vocab_size)
    toks = corpus.doc_token_batch(cfg.doc_maxlen - 2)

    cap = 140
    indexer = Indexer(params, cfg, pool_method="ward", pool_factor=2,
                      backend="flat", encode_batch=8)
    sharded, stats = indexer.build_streaming(
        toks, shard_max_vectors=cap, out_dir=str(tmp_path / "art"))
    assert stats.n_shards >= 2
    assert stats.n_docs == 24
    assert stats.peak_buffered_vectors <= cap + stats.max_batch_vectors
    for s in sharded.shards[:-1]:
        assert s.n_vectors() <= cap + cfg.doc_maxlen
    # monolithic build over the same corpus: same docs, same vectors
    mono, mono_stats = Indexer(params, cfg, pool_method="ward",
                               pool_factor=2, backend="flat",
                               encode_batch=8).build(toks)
    assert stats.n_vectors_stored == mono_stats.n_vectors_stored
    assert stats.n_vectors_raw == mono_stats.n_vectors_raw

    q_toks = corpus.query_token_batch(cfg.query_maxlen - 2)
    served = Searcher.from_dir(params, cfg, str(tmp_path / "art"))
    assert isinstance(served.index, ShardedIndex)
    S1, I1 = served.search(q_toks, k=5)
    S0, I0 = Searcher(params, cfg, mono).search(q_toks, k=5)
    np.testing.assert_array_equal(I0, I1)
    np.testing.assert_allclose(np.asarray(S0), np.asarray(S1),
                               rtol=1e-5, atol=1e-6)
    assert len(served.index.last_probe_s) == served.index.n_shards
    # aggregated stats landed beside the root manifest
    import json
    with open(tmp_path / "art" / "stats.json") as fh:
        js = json.load(fh)
    assert js["n_shards"] == stats.n_shards
    assert js["peak_buffered_vectors"] == stats.peak_buffered_vectors
