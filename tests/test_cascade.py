"""Beyond-paper pooled-cascade retrieval: quality ~ fine index at a
fraction of the stage-1 scan cost."""
import numpy as np
import jax
import pytest

from repro.configs import get_smoke_config
from repro.data.corpus import DatasetSpec, SyntheticRetrievalCorpus
from repro.models.colbert import init_colbert
from repro.retrieval.cascade import build_cascade
from repro.retrieval.indexer import Indexer
from repro.retrieval.metrics import ndcg_at_k
from repro.retrieval.searcher import Searcher


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("colbertv2")
    params = init_colbert(jax.random.PRNGKey(0), cfg)
    spec = DatasetSpec("casc", n_docs=100, n_queries=16, n_topics=8,
                       doc_len_mean=36, doc_len_std=6, seed=13)
    corpus = SyntheticRetrievalCorpus(spec, vocab_size=cfg.trunk.vocab_size)
    return cfg, params, corpus


def test_cascade_quality_vs_flat(setup):
    cfg, params, corpus = setup
    toks = corpus.doc_token_batch(cfg.doc_maxlen - 2)
    cascade = build_cascade(params, cfg, toks, coarse_factor=6,
                            fine_factor=2, candidates=24)
    fine_idx, _ = Indexer(params, cfg, pool_method="ward", pool_factor=2,
                          backend="flat").build(toks)
    searcher = Searcher(params, cfg, fine_idx)
    q_tokens = corpus.query_token_batch(cfg.query_maxlen - 2)
    qv = searcher.encode_queries(q_tokens)

    _, ids_fine = fine_idx.search_batch(qv, k=10)
    _, ids_casc = cascade.search_batch(qv, k=10)
    n_fine = ndcg_at_k([list(r) for r in ids_fine], corpus.qrels, 10)
    n_casc = ndcg_at_k([list(r) for r in ids_casc], corpus.qrels, 10)
    # cascade quality within 10% of the fine index it reranks with
    assert n_casc >= 0.9 * n_fine, (n_casc, n_fine)
    # stage-1 scan touches ~1/3 the vectors of the fine index
    fine_vecs = sum(len(d) for d in fine_idx.docs)
    assert cascade.stage1_vectors() < 0.5 * fine_vecs


def test_cascade_crud_add(setup):
    cfg, params, corpus = setup
    toks = corpus.doc_token_batch(cfg.doc_maxlen - 2)
    cascade = build_cascade(params, cfg, toks[:80], coarse_factor=4,
                            fine_factor=2)
    coarse = Indexer(params, cfg, pool_method="ward", pool_factor=4,
                     backend="flat").encode_and_pool(toks[80:])
    fine = Indexer(params, cfg, pool_method="ward", pool_factor=2,
                   backend="flat").encode_and_pool(toks[80:])
    ids = cascade.add(coarse, fine)
    assert list(ids) == list(range(80, 100))
    searcher = Searcher(params, cfg, None)
    qv = searcher.encode_queries(corpus.query_token_batch(cfg.query_maxlen - 2)[:2])
    s, i = cascade.search(np.asarray(qv)[0], k=5)
    assert len(i) == 5
