"""Ward-pooling Pallas kernel: bitwise parity with the reference loop.

The kernel runs in interpret mode on CPU (ops.py keys on the backend),
so the sweep here exercises the exact program CI and the TPU path
share. Three pins:

  * kernel assign == ``ward_cluster_batch`` BITWISE (not canonical-
    label equal — index artifacts must not depend on the impl),
  * both == SciPy's ward dendrogram cut (the existing fixture),
  * the pooled pipeline (``pool_doc_embeddings`` + ``compact_pooled``)
    is bitwise-identical through either impl, including the device-side
    compaction path.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # hypothesis gates only the sweep tests, not the fixed fixtures
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False
from scipy.cluster.hierarchy import fcluster, linkage

from repro.core.pooling import compact_pooled, pool_doc_embeddings
from repro.core.ward import ward_cluster_batch
from repro.kernels.ward_pool import ward_assign


def canon(labels):
    m, out = {}, []
    for v in labels:
        if v not in m:
            m[v] = len(m)
        out.append(m[v])
    return tuple(out)


def _assert_bitwise(x, mask, factor):
    ref = np.asarray(ward_cluster_batch(jnp.asarray(x), jnp.asarray(mask),
                                        factor))
    ker = np.asarray(ward_assign(jnp.asarray(x), jnp.asarray(mask),
                                 factor, impl="kernel"))
    np.testing.assert_array_equal(ref, ker)
    return ref


# ---------------------------------------------------------------------------
# hypothesis sweep: N x dim x factor x masked-gap patterns
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(st.data())
    def test_kernel_matches_reference_bitwise(data):
        B = data.draw(st.integers(1, 12), label="B")
        N = data.draw(st.integers(2, 48), label="N")
        d = data.draw(st.integers(1, 40), label="d")
        factor = data.draw(st.integers(2, 6), label="factor")
        seed = data.draw(st.integers(0, 2**31 - 1), label="seed")
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(B, N, d)).astype(np.float32)
        # masked-gap patterns: contiguous tails, interior holes, all-False
        mask = rng.random((B, N)) > data.draw(
            st.sampled_from([0.0, 0.25, 0.6, 1.0]), label="gap_p")
        if data.draw(st.booleans(), label="tail_gap"):
            mask[0, N // 2:] = False
        _assert_bitwise(x, mask, factor)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(2, 5))
    def test_tie_heavy_duplicates_match_bitwise(seed, factor):
        # duplicated rows force distance ties — merge ORDER must match
        rng = np.random.default_rng(seed)
        B, N, d = 3, 24, 8
        base = rng.normal(size=(B, N // 2, d)).astype(np.float32)
        x = np.concatenate([base, base], axis=1)
        x = x[:, rng.permutation(N)]
        mask = np.ones((B, N), bool)
        _assert_bitwise(x, mask, factor)

else:  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_kernel_matches_reference_bitwise():
        pass


# ---------------------------------------------------------------------------
# the SciPy fixture, through the kernel path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("factor", [2, 3, 4, 6])
@pytest.mark.parametrize("seed", [0, 1])
def test_kernel_matches_scipy(factor, seed):
    rng = np.random.default_rng(seed)
    B, N, d = 4, 32, 16
    x = rng.normal(size=(B, N, d)).astype(np.float32)
    mask = np.ones((B, N), bool)
    mask[1, 25:] = False
    mask[3, 10:] = False
    assign = np.asarray(ward_assign(jnp.asarray(x), jnp.asarray(mask),
                                    factor, impl="kernel"))
    for b in range(B):
        xv = x[b][mask[b]]
        xv /= np.linalg.norm(xv, axis=-1, keepdims=True)
        k = xv.shape[0] // factor + 1
        sc = fcluster(linkage(xv, method="ward"), t=k, criterion="maxclust")
        assert canon(sc) == canon(assign[b][mask[b]]), (b, factor)


# ---------------------------------------------------------------------------
# edges: all-masked / single-token / n_valid <= factor / identicals
# ---------------------------------------------------------------------------
def test_edge_docs_match_bitwise():
    rng = np.random.default_rng(0)
    B, N, d = 4, 16, 8
    x = rng.normal(size=(B, N, d)).astype(np.float32)
    mask = np.ones((B, N), bool)
    mask[0, :] = False          # all-masked doc
    mask[1, 1:] = False         # single-token doc
    mask[2, 3:] = False         # n_valid (3) <= factor (4)
    for factor in (2, 4, 8):
        _assert_bitwise(x, mask, factor)


def test_identical_vectors_match_bitwise():
    # all pairwise distances zero: pure tie-break territory
    x = np.ones((2, 12, 4), np.float32)
    mask = np.ones((2, 12), bool)
    for factor in (2, 3):
        ref = _assert_bitwise(x, mask, factor)
        n_clusters = len(set(ref[0].tolist()))
        assert n_clusters == 12 // factor + 1


def test_impl_dispatch_and_validation():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 8, 4)),
                    jnp.float32)
    mask = jnp.ones((2, 8), bool)
    a_auto = np.asarray(ward_assign(x, mask, 2, impl="auto"))
    a_ref = np.asarray(ward_assign(x, mask, 2, impl="ref"))
    np.testing.assert_array_equal(a_auto, a_ref)
    with pytest.raises(ValueError):
        ward_assign(x, mask, 2, impl="fused")


# ---------------------------------------------------------------------------
# the full pooled pipeline through either impl, incl. device compaction
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("factor", [2, 3])
def test_pooled_pipeline_bitwise_identical(factor):
    rng = np.random.default_rng(5)
    B, N, d = 7, 40, 16          # B deliberately not a block_b multiple
    x = jnp.asarray(rng.normal(size=(B, N, d)), jnp.float32)
    mask = np.ones((B, N), bool)
    mask[2, 30:] = False
    mask[5, :] = False
    mask = jnp.asarray(mask)
    pk, mk = pool_doc_embeddings(x, mask, factor, "ward",
                                 ward_kernel="kernel")
    pr, mr = pool_doc_embeddings(x, mask, factor, "ward",
                                 ward_kernel="ref")
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr))
    # device-side compaction == host boolean gather, bitwise
    dev = compact_pooled(pk, mk)
    host = compact_pooled(np.asarray(pk), np.asarray(mk))
    assert len(dev) == len(host) == B
    for a, b in zip(dev, host):
        np.testing.assert_array_equal(a, b)
