"""Hypothesis sweep of the spec layer's lossless-manifest contract:
for ANY spec across backend x pool method x sharded/monolithic and
arbitrary knob values, spec -> manifest meta -> json -> spec is the
identity (the fixed-grid version lives in tests/test_spec.py)."""
import json

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.spec import (BUILTIN_POOL_METHODS, IndexSpec,  # noqa: E402
                             PoolingSpec, RetrieverSpec, ShardSpec,
                             backend_names, manifest_meta_for,
                             retriever_spec_from_manifest)


@st.composite
def retriever_specs(draw):
    """Specs varying every knob the backend's manifest persists."""
    backend = draw(st.sampled_from(backend_names()))
    pooling = PoolingSpec(
        method=draw(st.sampled_from(BUILTIN_POOL_METHODS)),
        factor=draw(st.integers(1, 8)))
    if backend == "cascade":
        index = IndexSpec(backend="cascade",
                          coarse_factor=draw(st.integers(1, 12)),
                          fine_factor=draw(st.integers(1, 6)),
                          candidates=draw(st.integers(1, 256)),
                          doc_maxlen=draw(st.integers(8, 512)))
        shard = ShardSpec()                 # cascade has no sharded layout
    else:
        index = IndexSpec(
            backend=backend,
            doc_maxlen=draw(st.integers(8, 512)),
            n_centroids=draw(st.integers(1, 1024)),
            quant_bits=draw(st.sampled_from((2, 4))),
            nprobe=draw(st.integers(1, 64)),
            t_cs=draw(st.floats(0.0, 1.0, allow_nan=False)),
            ndocs=draw(st.integers(1, 1 << 20)),
            hnsw_m=draw(st.integers(2, 64)),
            hnsw_ef_construction=draw(st.integers(8, 512)),
            hnsw_candidates=draw(st.integers(8, 1 << 16)))
        shard = ShardSpec(shard_max_vectors=draw(
            st.sampled_from((0, 64, 4096))))
    return RetrieverSpec(pooling=pooling, index=index, shard=shard)


@settings(max_examples=200, deadline=None)
@given(retriever_specs())
def test_spec_to_manifest_to_spec_identity(spec):
    meta = manifest_meta_for(spec)
    back = retriever_spec_from_manifest(json.loads(json.dumps(meta)))
    assert back.pooling == spec.pooling
    assert back.index == spec.index
    assert back.shard == spec.shard


@settings(max_examples=50, deadline=None)
@given(retriever_specs())
def test_spec_dict_roundtrip(spec):
    assert RetrieverSpec.from_dict(
        json.loads(json.dumps(spec.to_dict()))) == spec
