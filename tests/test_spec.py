"""The typed spec layer (core/spec.py): manifest round-trips, strict
unknown-key rejection, the pooling/backend registries, argparse
derivation, and the pinned public API surface of ``import repro``."""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import spec as S
from repro.core.spec import (BUILTIN_POOL_METHODS, CASCADE_PARAM_KEYS,
                             INDEX_PARAM_KEYS, IndexSpec, PoolingSpec,
                             RetrieverSpec, ServeSpec, ShardSpec,
                             add_spec_args, backend_names,
                             manifest_meta_for, pooling_methods,
                             pooling_strategy, register_pooling_strategy,
                             retriever_spec_from_manifest, spec_from_args)


# ---------------------------------------------------------------------------
# Single source of truth: spec defaults == index dataclass defaults
# ---------------------------------------------------------------------------
def test_index_spec_defaults_match_index_dataclasses():
    """A default IndexSpec must build the default index — the spec is
    the single source of truth, so drift here is a silent config fork."""
    from repro.core.index import PARAM_KEYS, MultiVectorIndex
    from repro.retrieval.cascade import CascadeIndex

    assert PARAM_KEYS == INDEX_PARAM_KEYS       # re-export, same object
    mv_defaults = {f.name: f.default
                   for f in dataclasses.fields(MultiVectorIndex)}
    spec = IndexSpec()
    for key in INDEX_PARAM_KEYS:
        assert getattr(spec, key) == mv_defaults[key], key
    cc_defaults = {f.name: f.default
                   for f in dataclasses.fields(CascadeIndex)}
    for key in CASCADE_PARAM_KEYS:
        assert getattr(spec, key) == cc_defaults[key], key


def test_persist_imports_spec_keys():
    """core/persist.py must consume the SAME key set object (it used to
    shadow its own copy; drift silently rejected valid manifests)."""
    from repro.core import persist
    from repro.core import sharded
    assert persist._PARAM_KEYS is INDEX_PARAM_KEYS
    assert sharded.SHARD_PARAM_KEYS is INDEX_PARAM_KEYS


# ---------------------------------------------------------------------------
# Manifest round-trip (fixed grid; the hypothesis sweep over arbitrary
# knob values lives in tests/test_spec_properties.py)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", backend_names())
@pytest.mark.parametrize("method", BUILTIN_POOL_METHODS)
@pytest.mark.parametrize("shard_max", [0, 64])
def test_spec_to_manifest_to_spec_identity(backend, method, shard_max):
    """spec -> manifest meta -> json -> spec is the identity for every
    persisted field (serve knobs are runtime-only by design)."""
    if backend == "cascade":
        if shard_max:                       # cascade has no sharded layout
            pytest.skip("cascade is monolithic-only")
        index = IndexSpec(backend="cascade", coarse_factor=5,
                          fine_factor=3, candidates=48, doc_maxlen=96)
    else:
        index = IndexSpec(backend=backend, doc_maxlen=40, n_centroids=17,
                          quant_bits=4, nprobe=3, t_cs=0.125, ndocs=999,
                          hnsw_m=7, hnsw_ef_construction=33,
                          hnsw_candidates=555)
    spec = RetrieverSpec(pooling=PoolingSpec(method=method, factor=3),
                         index=index,
                         shard=ShardSpec(shard_max_vectors=shard_max))
    meta = manifest_meta_for(spec)
    back = retriever_spec_from_manifest(json.loads(json.dumps(meta)))
    assert back.pooling == spec.pooling
    assert back.index == spec.index
    assert back.shard == spec.shard
    assert RetrieverSpec.from_dict(
        json.loads(json.dumps(spec.to_dict()))) == spec


# ---------------------------------------------------------------------------
# Strict validation
# ---------------------------------------------------------------------------
def test_unknown_keys_rejected():
    with pytest.raises(ValueError, match="bogus"):
        IndexSpec.from_dict({"bogus": 1})
    with pytest.raises(ValueError, match="bogus"):
        PoolingSpec.from_dict({"method": "ward", "bogus": 2})
    with pytest.raises(ValueError, match="bogus"):
        RetrieverSpec.from_dict({"bogus": {}})
    with pytest.raises(ValueError, match="bogus"):
        RetrieverSpec.from_dict({"index": {"bogus": 1}})
    with pytest.raises(ValueError, match="unknown index params"):
        IndexSpec.from_manifest_params("plaid", {"bogus": 3})
    with pytest.raises(TypeError):
        IndexSpec().replace(bogus=1)


def test_value_validation():
    with pytest.raises(ValueError):
        PoolingSpec(factor=0)
    with pytest.raises(ValueError):
        PoolingSpec(method="")
    with pytest.raises(ValueError, match="unknown backend"):
        IndexSpec(backend="faiss")
    with pytest.raises(ValueError):
        ShardSpec(shard_max_vectors=-1)
    with pytest.raises(ValueError):
        ServeSpec(max_batch=0)
    with pytest.raises(ValueError, match="sharded"):
        RetrieverSpec(index=IndexSpec(backend="cascade"),
                      shard=ShardSpec(shard_max_vectors=10))
    with pytest.raises(ValueError, match="no retriever spec"):
        retriever_spec_from_manifest({"kind": "residual_codec"})


def test_coerce_accepts_parts():
    ix = IndexSpec(backend="flat")
    assert RetrieverSpec.coerce(ix).index == ix
    pl = PoolingSpec("kmeans", 3)
    assert RetrieverSpec.coerce(pl).pooling == pl
    sh = ShardSpec(shard_max_vectors=7)
    assert RetrieverSpec.coerce(sh).shard == sh
    full = RetrieverSpec(pooling=pl)
    assert RetrieverSpec.coerce(full) is full
    with pytest.raises(TypeError):
        RetrieverSpec.coerce(42)


# ---------------------------------------------------------------------------
# Pooling strategy registry
# ---------------------------------------------------------------------------
def test_builtin_pooling_matches_pool_doc_embeddings(rng):
    """The registry's builtin strategies are the paper's pooling — the
    spec path must be bitwise identical to calling it directly."""
    from repro.core.pooling import pool_doc_embeddings
    x = rng.normal(size=(2, 12, 8)).astype(np.float32)
    mask = np.ones((2, 12), bool)
    mask[1, 9:] = False
    for method in ("sequential", "ward"):
        got_p, got_m = PoolingSpec(method=method, factor=2).apply(x, mask)
        exp_p, exp_m = pool_doc_embeddings(x, mask, 2, method)
        assert np.array_equal(np.asarray(got_p), np.asarray(exp_p))
        assert np.array_equal(np.asarray(got_m), np.asarray(exp_m))
    # factor 1 short-circuits to the identity strategy, any method name
    got_p, got_m = PoolingSpec(method="ward", factor=1).apply(x, mask)
    exp_p, exp_m = pool_doc_embeddings(x, mask, 1, "none")
    assert np.array_equal(np.asarray(got_p), np.asarray(exp_p))
    assert np.array_equal(np.asarray(got_m), np.asarray(exp_m))


def test_pooling_registry_plugs_in_custom_strategy(rng):
    """A new policy (e.g. per-doc adaptive budgets) is one registration,
    not an indexer fork."""
    name = "test-first-half"

    def first_half(x, mask, factor):
        m = np.asarray(mask, bool)
        rank = np.cumsum(m, axis=-1) - 1
        budget = np.ceil(m.sum(-1, keepdims=True) / factor)
        return np.asarray(x), m & (rank < budget)

    register_pooling_strategy(name, first_half)
    assert name in pooling_methods()
    assert pooling_strategy(name) is first_half
    x = rng.normal(size=(1, 10, 4)).astype(np.float32)
    mask = np.ones((1, 10), bool)
    _, pm = PoolingSpec(method=name, factor=2).apply(x, mask)
    assert pm.sum() == 5
    with pytest.raises(ValueError, match="already registered"):
        register_pooling_strategy(name, first_half)
    register_pooling_strategy(name, first_half, overwrite=True)
    with pytest.raises(KeyError):
        pooling_strategy("no-such-method")


# ---------------------------------------------------------------------------
# Argparse derivation
# ---------------------------------------------------------------------------
def test_add_spec_args_roundtrip():
    import argparse
    ap = argparse.ArgumentParser()
    add_spec_args(ap, ServeSpec, only=("max_batch", "max_wait_ms", "k"))
    add_spec_args(ap, PoolingSpec, prefix="pool-", defaults={"factor": 2})
    add_spec_args(ap, ShardSpec)
    args = ap.parse_args(["--max-batch", "8", "--max-wait-ms", "1.5",
                          "--pool-method", "kmeans",
                          "--shard-max-vectors", "256"])
    serve = spec_from_args(ServeSpec, args,
                           only=("max_batch", "max_wait_ms", "k"))
    assert serve == ServeSpec(max_batch=8, max_wait_ms=1.5, k=10)
    pool = spec_from_args(PoolingSpec, args, prefix="pool_")
    assert pool == PoolingSpec(method="kmeans", factor=2)
    assert spec_from_args(ShardSpec, args) == ShardSpec(
        shard_max_vectors=256)
    # defaults flow from the dataclass when the flag is omitted
    args2 = ap.parse_args([])
    assert spec_from_args(ServeSpec, args2,
                          only=("max_batch", "max_wait_ms", "k")
                          ) == ServeSpec()
    # cli=False fields never become flags
    flags = {a.dest for a in ap._actions}
    assert "poll_interval_s" not in flags
    assert "pipeline_depth" not in flags


def test_spec_from_args_overrides_win():
    import argparse
    ap = add_spec_args(argparse.ArgumentParser(), ShardSpec)
    args = ap.parse_args(["--shard-max-vectors", "32"])
    assert spec_from_args(ShardSpec, args,
                          shard_max_vectors=0) == ShardSpec()


# ---------------------------------------------------------------------------
# Public API surface
# ---------------------------------------------------------------------------
def test_public_api_surface_pinned():
    """``import repro`` exports exactly this surface; every name must
    resolve. Growing it is fine — update the pin deliberately."""
    import repro
    assert repro.__all__ == sorted([
        "Retriever", "RetrieverSpec", "PoolingSpec", "IndexSpec",
        "ShardSpec", "ServeSpec",
        "register_pooling_strategy", "pooling_methods",
        "register_backend", "backend_names",
        "Indexer", "Searcher", "ServingEngine",
        "MultiVectorIndex", "ShardedIndex", "CascadeIndex",
        "load_artifact", "IndexFormatError",
        "evaluate_pooling", "get_config", "get_smoke_config",
        "init_colbert",
        "EvalDataset", "QualitySweep", "QualityReport", "load_beir",
    ])
    for name in repro.__all__:
        assert getattr(repro, name) is not None, name
    with pytest.raises(AttributeError):
        repro.not_an_export
    assert set(repro.__all__) <= set(dir(repro))


def test_backend_registry_names():
    assert set(backend_names()) >= {"flat", "hnsw", "plaid", "cascade"}
    for b in ("flat", "hnsw", "plaid"):
        assert S.backend_info(b).artifact_kind == "multi_vector_index"
    assert S.backend_info("cascade").artifact_kind == "cascade_index"
    import repro.api  # noqa: F401 — registers the facade builders
    for b in ("flat", "hnsw", "plaid", "cascade"):
        assert S.backend_info(b).builder is not None
    with pytest.raises(KeyError):
        S.backend_info("nope")
