"""Expert-parallel (shard_map all-to-all) MoE vs the dense oracle.

Runs in a subprocess because it needs >1 host device (XLA device count is
locked at first jax init)."""
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.models.moe import init_moe, moe_dense, moe_ep
from repro.sharding.api import mesh_context, lm_rules

cfg = get_smoke_config('moonshot-v1-16b-a3b')   # E=4, top_k=2
mesh = jax.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
p = init_moe(key, cfg)
x = jax.random.normal(key, (4, 8, cfg.d_model), jnp.float32)
with mesh, mesh_context(mesh, lm_rules("data")):
    y_ref, aux_ref = moe_dense(p, x, cfg)
    y_ep, aux_ep = jax.jit(lambda p, x: moe_ep(p, x, cfg, capacity=256))(p, x)
    g = jax.jit(jax.grad(
        lambda p: moe_ep(p, x, cfg, capacity=256)[0].sum()))(p)
err = float(jnp.max(jnp.abs(y_ep - y_ref)))
assert err < 2e-3, err
assert abs(float(aux_ep) - float(aux_ref)) < 1e-5
assert np.isfinite(float(jnp.linalg.norm(g['w1'])))
print("EP_OK", err)
"""


@pytest.mark.slow
def test_moe_ep_matches_dense_oracle():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"}, cwd="/root/repo")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "EP_OK" in r.stdout
