"""Hypothesis property tests on system invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # gate: container may lack hypothesis
from hypothesis import given, settings, strategies as st

from repro.core.pooling import compact_pooled, pool_doc_embeddings
from repro.core.quantization import (decode, encode, pack_codes,
                                     train_codec, unpack_codes)
from repro.retrieval.metrics import ndcg_at_k, recall_at_k, success_at_k


@settings(max_examples=15, deadline=None)
@given(m=st.integers(20, 100), dim=st.sampled_from([16, 32, 64]),
       bits=st.sampled_from([2, 4]), seed=st.integers(0, 10 ** 6))
def test_quantization_improves_over_centroid_only(m, dim, bits, seed):
    """Residual codes must reconstruct at least as well as the bare
    centroid (the codec's whole point)."""
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(m, dim)).astype(np.float32)
    v /= np.linalg.norm(v, axis=-1, keepdims=True)
    c = rng.normal(size=(8, dim)).astype(np.float32)
    c /= np.linalg.norm(c, axis=-1, keepdims=True)
    codec = train_codec(jnp.asarray(v), jnp.asarray(c), bits=bits)
    a, w = encode(codec, jnp.asarray(v))
    rec = np.asarray(decode(codec, a, w))
    cos_rec = np.mean(np.sum(v * rec, axis=-1))
    cent = np.asarray(codec.centroids)[np.asarray(a)]
    cent /= np.linalg.norm(cent, axis=-1, keepdims=True)
    cos_cent = np.mean(np.sum(v * cent, axis=-1))
    assert cos_rec >= cos_cent - 1e-3


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 40), dim=st.sampled_from([32, 64, 128]),
       bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 10 ** 6))
def test_pack_roundtrip_property(n, dim, bits, seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 1 << bits, (n, dim)), jnp.int32)
    assert (np.asarray(unpack_codes(pack_codes(codes, bits), bits, dim))
            == np.asarray(codes)).all()


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 32), factor=st.integers(2, 5),
       seed=st.integers(0, 10 ** 6))
def test_pooled_vectors_lie_in_span_of_inputs(n, factor, seed):
    """Mean-pooled vectors are convex combinations (pre-normalization)
    of the originals: cosine to the nearest original must be high when
    vectors cluster."""
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(1, 1, 8)).astype(np.float32)
    x = base + 0.05 * rng.normal(size=(1, n, 8)).astype(np.float32)
    mask = np.ones((1, n), bool)
    pooled, pmask = pool_doc_embeddings(jnp.asarray(x), jnp.asarray(mask),
                                        factor, "ward")
    vecs = compact_pooled(pooled, pmask)[0]
    xu = x[0] / np.linalg.norm(x[0], axis=-1, keepdims=True)
    sims = vecs @ xu.T
    assert sims.max(axis=1).min() > 0.95


@settings(max_examples=20, deadline=None)
@given(k=st.integers(1, 10), seed=st.integers(0, 10 ** 6))
def test_metric_bounds_and_monotonicity(k, seed):
    rng = np.random.default_rng(seed)
    docs = list(rng.permutation(20)[:10])
    qrels = [{int(d): int(rng.integers(1, 3)) for d in
              rng.choice(20, 4, replace=False)}]
    ranked = [docs]
    for fn in (ndcg_at_k, success_at_k, recall_at_k):
        v = fn(ranked, qrels, k)
        assert 0.0 <= v <= 1.0
    # success/recall are monotone in depth (NDCG is NOT — IDCG grows too)
    for fn in (success_at_k, recall_at_k):
        assert fn(ranked, qrels, 20) >= fn(ranked, qrels, k) - 1e-12


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_maxsim_pooling_score_continuity(seed):
    """MaxSim score of a pooled doc stays within the min/max token-sim
    envelope of the original doc (means can't exceed the max)."""
    from repro.core.maxsim import maxsim_scores
    rng = np.random.default_rng(seed)
    d = rng.normal(size=(1, 16, 8)).astype(np.float32)
    d /= np.linalg.norm(d, axis=-1, keepdims=True)
    q = rng.normal(size=(1, 4, 8)).astype(np.float32)
    q /= np.linalg.norm(q, axis=-1, keepdims=True)
    mask = np.ones((1, 16), bool)
    qm = np.ones((1, 4), bool)
    s_orig = float(maxsim_scores(jnp.asarray(q), jnp.asarray(qm),
                                 jnp.asarray(d), jnp.asarray(mask))[0, 0])
    pooled, pmask = pool_doc_embeddings(jnp.asarray(d), jnp.asarray(mask),
                                        2, "ward")
    s_pool = float(maxsim_scores(jnp.asarray(q), jnp.asarray(qm),
                                 pooled, pmask)[0, 0])
    # pooling can only lower the per-query-token max (mean <= max on the
    # unit sphere up to renormalization slack)
    assert s_pool <= s_orig + 0.15 * abs(s_orig) + 0.2


def test_hnsw_recall_against_exact():
    """HNSW with generous ef recovers exact top-1 on clustered data."""
    from repro.core.hnsw import HNSW
    rng = np.random.default_rng(0)
    base = rng.normal(size=(500, 16)).astype(np.float32)
    base /= np.linalg.norm(base, axis=-1, keepdims=True)
    idx = HNSW(16, m=12, ef_construction=200)
    idx.add(base)
    hits = 0
    for i in range(20):
        q = base[i] + 0.05 * rng.normal(size=16).astype(np.float32)
        q /= np.linalg.norm(q)
        exact = int(np.argmax(base @ q))
        _, ids = idx.search(q, 5, ef=128)
        hits += int(exact in list(ids))
    assert hits >= 18
