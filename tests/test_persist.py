"""Index persistence: versioned save/load artifacts (core/persist.py).

Locks the on-disk format down from four directions:
  * round-trip parity — every backend x pool method returns identical
    results after ``save`` -> ``load(mmap=True)``, including after
    ``delete`` (whose docs must also be compacted out of the bytes);
  * corruption & versioning — torn/missing/tampered artifacts raise
    ``IndexFormatError`` instead of producing garbage results;
  * footprint honesty — ``IndexStats.index_bytes`` is the serialized
    size, and plaid-on-disk beats flat-on-disk on the same corpus;
  * cross-process — a fresh Python interpreter loads what this one
    saved (catches in-process state leaking into the format).
"""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.docstore import DocStore
from repro.core.index import MultiVectorIndex
from repro.core.persist import (FORMAT_VERSION, MANIFEST_NAME,
                                IndexFormatError, artifact_bytes,
                                load_index, serialized_nbytes)
from repro.core.pooling import compact_pooled, pool_doc_embeddings

BACKENDS = ["flat", "hnsw", "plaid"]
POOL_METHODS = ["none", "sequential", "ward"]


def unit_docs(rng, n=30, dim=16, lo=4, hi=20):
    docs = []
    for _ in range(n):
        v = rng.normal(size=(rng.integers(lo, hi), dim)).astype(np.float32)
        docs.append(v / np.linalg.norm(v, axis=-1, keepdims=True))
    return docs


def unit_queries(rng, n, lq=5, dim=16):
    q = rng.normal(size=(n, lq, dim)).astype(np.float32)
    return q / np.linalg.norm(q, axis=-1, keepdims=True)


def pooled_docs(rng, method, factor=2, **kw):
    """Random unit docs run through the paper's pooling step."""
    docs = unit_docs(rng, **kw)
    if method == "none":
        return docs
    dim = docs[0].shape[1]
    L = max(len(d) for d in docs)
    x = np.zeros((len(docs), L, dim), np.float32)
    mask = np.zeros((len(docs), L), bool)
    for i, d in enumerate(docs):
        x[i, :len(d)] = d
        mask[i, :len(d)] = True
    pooled, pmask = pool_doc_embeddings(jnp.asarray(x), jnp.asarray(mask),
                                        factor, method)
    return compact_pooled(pooled, pmask)


def make_index(backend, dim=16):
    return MultiVectorIndex(dim=dim, backend=backend, doc_maxlen=24,
                            n_centroids=16, ndocs=64)


def assert_same_results(res_a, res_b, backend):
    S0, I0 = res_a
    S1, I1 = res_b
    assert np.array_equal(np.asarray(I0), np.asarray(I1)), backend
    # fp tolerance for plaid's decode path; flat/hnsw are bit-identical
    rtol = 1e-5 if backend == "plaid" else 0.0
    np.testing.assert_allclose(np.asarray(S0), np.asarray(S1),
                               rtol=rtol, atol=1e-7)


# ------------------------------------------------------- round-trip parity
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("method", POOL_METHODS)
def test_roundtrip_parity(tmp_path, backend, method):
    rng = np.random.default_rng(0)
    docs = pooled_docs(rng, method)
    idx = make_index(backend)
    idx.add(docs)
    qs = unit_queries(rng, 6)
    before = idx.search_batch(qs, k=8)

    idx.save(tmp_path / "a")
    loaded = MultiVectorIndex.load(tmp_path / "a", mmap=True)
    assert_same_results(before, loaded.search_batch(qs, k=8), backend)

    # delete -> the saved artifact must compact the bytes out while
    # keeping ids stable and parity with the in-memory index
    drop = [0, 3, 7]
    idx.delete(drop)
    after_del = idx.search_batch(qs, k=8)
    idx.save(tmp_path / "b")
    assert artifact_bytes(tmp_path / "b") < artifact_bytes(tmp_path / "a")
    loaded2 = MultiVectorIndex.load(tmp_path / "b", mmap=True)
    res2 = loaded2.search_batch(qs, k=8)
    assert_same_results(after_del, res2, backend)
    ids = np.asarray(res2[1])
    assert not np.isin(ids[ids >= 0], drop).any()


@pytest.mark.parametrize("backend", BACKENDS)
def test_loaded_index_stays_crud_capable(tmp_path, backend):
    """mmap'd payloads are read-only — add/delete must copy-on-grow,
    not crash or corrupt the mapped file."""
    rng = np.random.default_rng(1)
    idx = make_index(backend)
    idx.add(unit_docs(rng))
    idx.save(tmp_path / "idx")
    loaded = MultiVectorIndex.load(tmp_path / "idx", mmap=True)
    new_ids = loaded.add(unit_docs(rng, n=5))
    assert list(new_ids) == list(range(30, 35))
    loaded.delete([int(new_ids[0]), 2])
    S, I = loaded.search_batch(unit_queries(rng, 3), k=10)
    assert not np.isin(np.asarray(I), [int(new_ids[0]), 2]).any()
    # the artifact on disk is untouched by post-load mutation
    again = MultiVectorIndex.load(tmp_path / "idx", mmap=True)
    assert again.n_docs == 30


def test_empty_index_roundtrip(tmp_path):
    for backend in BACKENDS:
        idx = make_index(backend)
        idx.save(tmp_path / backend)
        loaded = MultiVectorIndex.load(tmp_path / backend)
        assert loaded.n_docs == 0
        S, I = loaded.search_batch(np.zeros((2, 3, 16), np.float32), k=4)
        assert (np.asarray(I) == -1).all()


def test_docstore_from_arrays_is_zero_copy(tmp_path):
    """mmap=True must hand the DocStore the mapped file, not a copy."""
    rng = np.random.default_rng(2)
    idx = make_index("flat")
    idx.add(unit_docs(rng))
    idx.save(tmp_path / "idx")
    loaded = MultiVectorIndex.load(tmp_path / "idx", mmap=True)
    assert isinstance(loaded._store._flat, np.memmap)


def test_resave_over_existing_artifact(tmp_path):
    """Re-saving into the same directory must never clobber the
    published version mid-write: payloads get per-save filenames, the
    manifest swap commits, and stale files are swept afterwards."""
    rng = np.random.default_rng(5)
    idx = make_index("flat")
    idx.add(unit_docs(rng))
    path = tmp_path / "idx"
    m1 = idx.save(path)
    idx.delete([1, 2])
    m2 = idx.save(path)
    files1 = {e["file"] for e in m1["payloads"].values()}
    files2 = {e["file"] for e in m2["payloads"].values()}
    assert not files1 & files2          # old version never overwritten
    on_disk = {f for f in os.listdir(path) if f.endswith(".npy")}
    assert on_disk == files2            # stale version swept after commit
    qs = unit_queries(rng, 3)
    assert_same_results(idx.search_batch(qs, k=6),
                        MultiVectorIndex.load(path).search_batch(qs, k=6),
                        "flat")


# ------------------------------------------------- corruption & versioning
def _saved_flat(tmp_path, n=12):
    rng = np.random.default_rng(3)
    idx = make_index("flat")
    idx.add(unit_docs(rng, n=n))
    path = tmp_path / "idx"
    idx.save(path)
    return path


def _payload_file(path, name):
    manifest = json.loads((path / MANIFEST_NAME).read_text())
    return path / manifest["payloads"][name]["file"]


def test_missing_manifest_raises(tmp_path):
    with pytest.raises(IndexFormatError, match="manifest"):
        load_index(tmp_path)


@pytest.mark.parametrize("mmap", [True, False])
def test_truncated_payload_raises(tmp_path, mmap):
    path = _saved_flat(tmp_path)
    fp = _payload_file(path, "flat")
    with open(fp, "r+b") as fh:
        fh.truncate(os.path.getsize(fp) - 64)
    with pytest.raises(IndexFormatError, match="flat"):
        load_index(path, mmap=mmap)


def test_missing_payload_file_raises(tmp_path):
    path = _saved_flat(tmp_path)
    os.remove(_payload_file(path, "offsets"))
    with pytest.raises(IndexFormatError, match="offsets"):
        load_index(path)


@pytest.mark.parametrize("key", ["dim", "backend", "params", "payloads"])
def test_missing_manifest_key_raises(tmp_path, key):
    path = _saved_flat(tmp_path)
    mf = path / MANIFEST_NAME
    manifest = json.loads(mf.read_text())
    del manifest[key]
    mf.write_text(json.dumps(manifest))
    with pytest.raises(IndexFormatError):
        load_index(path)


def test_bumped_format_version_raises(tmp_path):
    path = _saved_flat(tmp_path)
    mf = path / MANIFEST_NAME
    manifest = json.loads(mf.read_text())
    manifest["format_version"] = FORMAT_VERSION + 1
    mf.write_text(json.dumps(manifest))
    with pytest.raises(IndexFormatError, match="format_version"):
        load_index(path)


def test_shape_tamper_raises(tmp_path):
    path = _saved_flat(tmp_path)
    mf = path / MANIFEST_NAME
    manifest = json.loads(mf.read_text())
    manifest["payloads"]["flat"]["shape"][0] += 1
    mf.write_text(json.dumps(manifest))
    with pytest.raises(IndexFormatError, match="does not match"):
        load_index(path)


# --------------------------------------------------------- footprint honesty
def test_serialized_nbytes_matches_artifact(tmp_path):
    rng = np.random.default_rng(4)
    for backend in BACKENDS:
        idx = make_index(backend)
        idx.add(unit_docs(rng))
        dry = serialized_nbytes(idx)
        manifest = idx.save(tmp_path / backend)
        assert artifact_bytes(manifest) == dry
        assert artifact_bytes(tmp_path / backend) == dry


def test_plaid_on_disk_smaller_than_flat():
    """Table 3's point, measured in serialized bytes: the 2-bit plaid
    artifact must undercut the flat f32 artifact on the same corpus at
    the same pool_factor (encoder -> ward pool -> both backends)."""
    import jax
    from repro.configs import get_smoke_config
    from repro.data.corpus import DATASET_SPECS, SyntheticRetrievalCorpus
    from repro.models.colbert import init_colbert
    from repro.retrieval.indexer import Indexer
    from dataclasses import replace

    cfg = get_smoke_config("colbertv2")
    params = init_colbert(jax.random.PRNGKey(0), cfg)
    spec = replace(DATASET_SPECS["scifact"], n_docs=32, n_queries=2)
    corpus = SyntheticRetrievalCorpus(spec, vocab_size=cfg.trunk.vocab_size)
    toks = corpus.doc_token_batch(cfg.doc_maxlen - 2)
    sizes = {}
    from repro.core.spec import IndexSpec, PoolingSpec
    for backend in ("plaid", "flat"):
        _, stats = Indexer(
            params, cfg,
            index_spec=IndexSpec.from_config(cfg, backend=backend,
                                             ndocs=64),
            pooling_spec=PoolingSpec(method="ward", factor=2)).build(toks)
        assert stats.index_bytes > 0
        sizes[backend] = stats.index_bytes
    assert sizes["plaid"] < sizes["flat"], sizes


# ------------------------------------------------------------- cross-process
def test_fresh_process_load_parity(tmp_path):
    """Save here, load in a brand-new interpreter (benchmarks/
    persist_parity.py): the CI job's check, kept in-suite too."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = os.path.join(repo, "benchmarks", "persist_parity.py")
    env = dict(os.environ,
               PYTHONPATH=os.path.join(repo, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    for phase in ("build", "verify"):
        proc = subprocess.run(
            [sys.executable, script, "--phase", phase,
             "--dir", str(tmp_path)],
            env=env, capture_output=True, text=True, timeout=600)
        assert proc.returncode == 0, (phase, proc.stdout, proc.stderr)


# --------------------------------------------------------------- properties
try:  # container may lack hypothesis (PR 1 convention: skip, don't fail)
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(m=st.integers(8, 60), bits=st.sampled_from([2, 4]),
           seed=st.integers(0, 10 ** 6))
    def test_codec_persist_roundtrip_property(tmp_path_factory, m, bits,
                                              seed):
        """encode -> save -> load -> decode == encode -> decode."""
        from repro.core.quantization import decode, encode, train_codec
        from repro.core.persist import load_codec, save_codec
        rng = np.random.default_rng(seed)
        v = rng.normal(size=(m, 16)).astype(np.float32)
        v /= np.linalg.norm(v, axis=-1, keepdims=True)
        c = rng.normal(size=(8, 16)).astype(np.float32)
        c /= np.linalg.norm(c, axis=-1, keepdims=True)
        codec = train_codec(jnp.asarray(v), jnp.asarray(c), bits=bits)
        a, w = encode(codec, jnp.asarray(v))
        path = tmp_path_factory.mktemp("codec")
        save_codec(codec, path)
        loaded = load_codec(path)
        assert loaded.bits == codec.bits
        np.testing.assert_array_equal(np.asarray(decode(loaded, a, w)),
                                      np.asarray(decode(codec, a, w)))
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_codec_persist_roundtrip_property():
        pass


# -------------------------------------------------- generation (hot swap)
def test_generation_bumps_on_every_publish(tmp_path):
    """Each save over the same directory bumps the monotonic generation
    — the signal index watchers (launch/engine.py) key hot swaps off."""
    from repro.core.persist import artifact_generation, read_manifest
    rng = np.random.default_rng(21)
    idx = make_index("flat")
    idx.add(unit_docs(rng, n=8))
    d = str(tmp_path / "gen")
    assert artifact_generation(d) == 0          # nothing published yet
    m1 = idx.save(d)
    assert m1["generation"] == 1 == artifact_generation(d)
    m2 = idx.save(d)
    assert m2["generation"] == 2 == artifact_generation(d)
    # explicit override wins (e.g. replicating a known generation)
    m9 = idx.save(d, extra_meta={"generation": 9})
    assert m9["generation"] == 9 == artifact_generation(d)
    assert read_manifest(d)["generation"] == 9
    # generation survives the round trip; payload parity unaffected
    loaded = load_index(d)
    qs = unit_queries(rng, 3)
    assert_same_results(idx.search_batch(qs, k=4),
                        loaded.search_batch(qs, k=4), "flat")


def test_generation_sharded_root_bumps(tmp_path):
    """Sharded artifacts: the ROOT manifest carries the generation the
    watcher polls (shard dirs bump independently, which is fine)."""
    from repro.core.persist import artifact_generation
    from repro.core.sharded import ShardedIndex
    rng = np.random.default_rng(22)
    sh = ShardedIndex(dim=16, backend="flat", shard_max_vectors=60,
                      doc_maxlen=24)
    sh.add(unit_docs(rng, n=12))
    d = str(tmp_path / "sharded_gen")
    sh.save(d)
    assert artifact_generation(d) == 1
    sh.save(d)
    assert artifact_generation(d) == 2


def test_generation_unreadable_dir_is_zero(tmp_path):
    from repro.core.persist import artifact_generation
    assert artifact_generation(str(tmp_path / "missing")) == 0
    bad = tmp_path / "bad"
    bad.mkdir()
    (bad / MANIFEST_NAME).write_text("{not json")
    assert artifact_generation(str(bad)) == 0
