"""QualitySweep / report / gate behaviour, on the smoke encoder.

The expensive pieces (encoder init, the sweep itself) are module-scoped
fixtures; every test reads from the same report.
"""
from __future__ import annotations

import json
import warnings

import jax
import numpy as np
import pytest

import repro
from repro.core.spec import IndexSpec, PoolingSpec, RetrieverSpec
from repro.eval import (QualityReport, QualitySweep, check_envelope,
                        check_regression, read_bench_section, run_gate,
                        synthetic_dataset, write_bench_section)
from repro.eval.sweep import relative_performance
from repro.retrieval.indexer import EncodedDocs

METRICS = ("ndcg@10", "recall@5")


@pytest.fixture(scope="module")
def setup():
    cfg = repro.get_smoke_config("colbertv2")
    params = repro.init_colbert(jax.random.PRNGKey(0), cfg)
    ds = synthetic_dataset("sweep-test", vocab_size=cfg.trunk.vocab_size,
                           doc_maxlen=cfg.doc_maxlen - 2,
                           query_maxlen=cfg.query_maxlen - 2,
                           n_docs=48, n_queries=12, seed=3)
    return params, cfg, ds


@pytest.fixture(scope="module")
def report(setup):
    params, cfg, ds = setup
    return QualitySweep(params, cfg, ds, methods=("ward", "sequential"),
                        factors=(1, 2), backends=("flat", "plaid"),
                        quant_bits=(2,), metrics=METRICS,
                        encode_batch=16).run()


def test_factor1_cell_is_baseline_exactly(report):
    """Factor 1 is the identity pool: its cell must BE the baseline —
    same absolute metrics, relative exactly 100.0, no rebuild."""
    for backend, qb in (("flat", None), ("plaid", 2)):
        base = report.baseline(backend, qb)
        for method in ("ward", "sequential"):
            c = report.cell(backend, method, 1, qb)
            assert c is not None and c.shared_baseline
            assert c.metrics == base.metrics
            assert c.n_vectors == base.n_vectors
            assert c.index_bytes == base.index_bytes
            for v in c.relative.values():
                assert v == 100.0          # bitwise, not approx


def test_pooled_cells_reduce_vectors(report):
    for backend, qb in (("flat", None), ("plaid", 2)):
        base = report.baseline(backend, qb)
        for method in ("ward", "sequential"):
            c = report.cell(backend, method, 2, qb)
            assert not c.shared_baseline
            assert 0.3 < c.vector_reduction < 0.6
            assert c.n_vectors < base.n_vectors
            for name in METRICS:
                assert c.relative[name] == pytest.approx(
                    relative_performance(c.metrics[name],
                                         base.metrics[name]))


def test_sweep_is_deterministic(setup, report):
    """Same params + dataset + grid => identical cells (what makes the
    pinned-baseline regression gate meaningful)."""
    params, cfg, ds = setup
    rep2 = QualitySweep(params, cfg, ds,
                        methods=("ward", "sequential"), factors=(1, 2),
                        backends=("flat", "plaid"), quant_bits=(2,),
                        metrics=METRICS, encode_batch=16).run()
    assert len(rep2.cells) == len(report.cells)
    for a, b in zip(report.cells, rep2.cells):
        assert (a.backend, a.method, a.factor, a.quant_bits) == \
            (b.backend, b.method, b.factor, b.quant_bits)
        assert a.metrics == b.metrics
        assert a.relative == b.relative
        assert a.n_vectors == b.n_vectors


def test_encoded_cache_matches_reencode_path(setup, report):
    """The sweep encodes once (EncodedDocs); building the same cell
    from raw tokens (re-encoding) must give identical rankings —
    the old naive evaluate path, asserted bitwise on results."""
    params, cfg, ds = setup
    spec = RetrieverSpec(pooling=PoolingSpec(method="ward", factor=2),
                         index=IndexSpec.from_config(cfg, backend="flat"))
    naive = repro.Retriever.build(params, cfg, ds.doc_tokens, spec,
                                  encode_batch=16)
    cached = repro.Retriever.build(
        params, cfg,
        EncodedDocs.encode(params, cfg, ds.doc_tokens, encode_batch=16),
        spec, encode_batch=16)
    s1, i1 = naive.search(ds.query_tokens, k=10)
    s2, i2 = cached.search(ds.query_tokens, k=10)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    m = naive.evaluate(ds, metrics=METRICS)
    assert m == report.cell("flat", "ward", 2).metrics


def test_encoded_docs_rejects_streaming():
    from repro.retrieval.indexer import Indexer
    cfg = repro.get_smoke_config("colbertv2")
    params = repro.init_colbert(jax.random.PRNGKey(0), cfg)
    enc = EncodedDocs.encode(
        params, cfg, np.zeros((4, cfg.doc_maxlen - 2), np.int32),
        encode_batch=4)
    assert enc.n_docs == 4 and enc.nbytes() > 0
    with pytest.raises(TypeError):
        Indexer(params, cfg).build_streaming(enc)


def test_report_round_trips_json_and_table(report, tmp_path):
    path = str(tmp_path / "BENCH_quality.json")
    write_bench_section(path, "quality_sweep", report)
    write_bench_section(path, "other", {"keep": 1})
    back = read_bench_section(path, "quality_sweep")
    assert isinstance(back, QualityReport)
    assert back.to_json() == report.to_json()
    assert back.cell("flat", "ward", 1).relative["ndcg@10"] == 100.0
    # merge-update preserved the sibling section
    with open(path) as fh:
        doc = json.load(fh)
    assert doc["other"] == {"keep": 1}
    # paper-style grid renders every swept cell
    table = back.markdown_table("ndcg@10", backend="flat")
    assert "| ward " in table and "f=2" in table and "100.00" in table


def test_gate_passes_then_trips_on_injected_degradation(report, tmp_path):
    path = str(tmp_path / "pin.json")
    write_bench_section(path, "quality_sweep", report)
    ok = run_gate(report, baseline_path=path)
    assert ok and ok.checked > 0

    # inject a degraded factor-2 cell: envelope AND regression trip
    bad = QualityReport.from_json(report.to_json())
    cell = bad.cell("flat", "ward", 2)
    cell.relative["ndcg@10"] = 80.0
    env = check_envelope(bad, min_relative=95.0)
    assert not env.ok and any("envelope" in f for f in env.failures)
    reg = check_regression(bad, report, tolerance=3.0)
    assert not reg.ok and any("regression" in f for f in reg.failures)
    both = run_gate(bad, baseline_path=path)
    assert not both.ok and len(both.failures) >= 2
    # a drop inside the tolerance is NOT a regression
    cell.relative["ndcg@10"] = \
        report.cell("flat", "ward", 2).relative["ndcg@10"] - 1.0
    assert check_regression(bad, report, tolerance=3.0).ok


def test_gate_empty_overlap_fails_loudly(report):
    other = QualityReport(dataset="x", n_docs=1, n_queries=1, k=10)
    assert not check_regression(other, report).ok
    assert not check_envelope(other).ok


def test_deprecated_shim_matches_sweep(setup, report):
    params, cfg, ds = setup
    from repro.data.corpus import DatasetSpec, SyntheticRetrievalCorpus
    corpus = SyntheticRetrievalCorpus(
        DatasetSpec(name="sweep-test", seed=3, n_docs=48, n_queries=12),
        vocab_size=cfg.trunk.vocab_size)
    with pytest.deprecated_call():
        rep = repro.evaluate_pooling(
            params, cfg, corpus, methods=("ward",), factors=(2,),
            backend="flat", metric_name="ndcg@10")
    assert rep.baseline_metric == pytest.approx(
        report.baseline("flat").metrics["ndcg@10"])
    c = rep.cell("ward", 2)
    assert c.relative == pytest.approx(
        report.cell("flat", "ward", 2).relative["ndcg@10"])


def test_load_beir_directory_layout(tmp_path):
    from repro.eval import load_beir
    (tmp_path / "qrels").mkdir()
    with open(tmp_path / "corpus.jsonl", "w") as fh:
        for i in range(5):
            fh.write(json.dumps({"_id": f"d{i}", "title": f"title {i}",
                                 "text": f"document body {i} alpha"})
                     + "\n")
    with open(tmp_path / "queries.jsonl", "w") as fh:
        fh.write(json.dumps({"_id": "q1", "text": "alpha one"}) + "\n")
        fh.write(json.dumps({"_id": "q2", "text": "beta two"}) + "\n")
        fh.write(json.dumps({"_id": "q3", "text": "unjudged"}) + "\n")
    with open(tmp_path / "qrels" / "test.tsv", "w") as fh:
        fh.write("query-id\tcorpus-id\tscore\n")      # header row
        fh.write("q1\td0\t2\nq1\td3\t1\nq2\td4\t1\n")
    ds = load_beir(str(tmp_path), doc_maxlen=16, query_maxlen=8)
    assert ds.n_docs == 5 and ds.n_queries == 2     # q3 dropped
    assert ds.qrels[0] == {0: 2, 3: 1} and ds.qrels[1] == {4: 1}
    assert ds.doc_tokens.shape == (5, 16)
    assert ds.query_tokens.shape == (2, 8)
    assert ds.meta["provider"] == "beir"
    # deterministic hash tokenization: same text -> same ids
    ds2 = load_beir(str(tmp_path), doc_maxlen=16, query_maxlen=8)
    np.testing.assert_array_equal(ds.doc_tokens, ds2.doc_tokens)
    # max_docs truncation drops out-of-range qrels (and emptied queries)
    ds3 = load_beir(str(tmp_path), doc_maxlen=16, query_maxlen=8,
                    max_docs=4)
    assert ds3.n_docs == 4 and ds3.n_queries == 1
    assert ds3.qrels[0] == {0: 2, 3: 1}


def test_retriever_evaluate_entry_point(setup):
    params, cfg, ds = setup
    spec = RetrieverSpec(pooling=PoolingSpec(method="none", factor=1),
                         index=IndexSpec.from_config(cfg, backend="flat"))
    r = repro.Retriever.build(params, cfg, ds.doc_tokens, spec,
                              encode_batch=16)
    out = r.evaluate(ds, metrics=("ndcg@10", "mrr@10"), k=10)
    assert set(out) == {"ndcg@10", "mrr@10"}
    assert all(0.0 <= v <= 1.0 for v in out.values())
