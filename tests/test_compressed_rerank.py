"""End-to-end compressed-domain rerank: the packed plaid path must be
indistinguishable from the pre-change reconstruction path (same ids,
bitwise scores, same tie order) — monolithic, sharded, and through the
ServingEngine with the no-retrace probe — while never materializing the
f32 reconstruction store and cutting resident doc-representation bytes.

``packed_rerank=False`` is the legacy twin: it forces the rerank stage
back through ``recon_store()`` + ``maxsim_rerank_store``.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.index import MultiVectorIndex
from repro.core.sharded import ShardedIndex

DIM = 16
KW = dict(doc_maxlen=24, n_centroids=16, ndocs=4096)


def unit_docs(rng, n=40, dim=DIM, lo=4, hi=20):
    docs = []
    for _ in range(n):
        v = rng.normal(size=(rng.integers(lo, hi), dim)).astype(np.float32)
        docs.append(v / np.linalg.norm(v, axis=-1, keepdims=True))
    return docs


def unit_queries(rng, n, lq=5, dim=DIM):
    q = rng.normal(size=(n, lq, dim)).astype(np.float32)
    return q / np.linalg.norm(q, axis=-1, keepdims=True)


def assert_bitwise(S0, I0, S1, I1):
    np.testing.assert_array_equal(I0, I1)
    assert np.array_equal(np.asarray(S0, np.float32).view(np.int32),
                          np.asarray(S1, np.float32).view(np.int32)), \
        "scores drifted bitwise between packed and reconstruction paths"


@pytest.mark.parametrize("regime", ["gather", "dense"])
@pytest.mark.parametrize("bits", [2, 4])
def test_packed_matches_recon_monolithic(bits, regime):
    """Same ids, bitwise scores, same tie order as the reconstruction
    twin — in the candidate-GATHER regime (tight ndocs budget: the
    packed slab rerank runs) and the DENSE corpus-wide regime (candidate
    width reaches n_docs: both twins share the recon-backed scan)."""
    rng = np.random.default_rng(bits)
    if regime == "gather":
        n, kw = 150, dict(doc_maxlen=24, n_centroids=32, ndocs=16)
    else:
        n, kw = 50, KW
    docs = unit_docs(rng, n=n)
    packed = MultiVectorIndex(dim=DIM, backend="plaid", quant_bits=bits,
                              **kw)
    packed.add(docs)
    legacy = MultiVectorIndex(dim=DIM, backend="plaid", quant_bits=bits,
                              packed_rerank=False, **kw)
    legacy.set_codec(packed._plaid.codec)   # identical quantization model
    legacy.add(docs)
    qs = unit_queries(rng, 8)
    S0, I0 = legacy.search_batch(qs, k=10)
    S1, I1 = packed.search_batch(qs, k=10)
    assert_bitwise(S0, I0, S1, I1)
    if regime == "gather":
        # the packed index never decoded; the legacy twin had to
        assert packed._plaid.recon is None
        assert legacy._plaid.recon is not None


@pytest.mark.parametrize("bits", [2, 4])
def test_packed_matches_recon_sharded(bits):
    """Sharded packed == monolithic reconstruction twin (exhaustive
    candidate regime, one shared codec — the parity contract)."""
    rng = np.random.default_rng(10 + bits)
    docs = unit_docs(rng, n=24)
    total = sum(len(d) for d in docs)
    cap = max(total // 3, max(len(d) for d in docs), 1)
    sharded = ShardedIndex(dim=DIM, backend="plaid", quant_bits=bits,
                           shard_max_vectors=cap, **KW)
    sharded.add(docs)
    assert sharded.n_shards >= 2
    legacy = MultiVectorIndex(dim=DIM, backend="plaid", quant_bits=bits,
                              packed_rerank=False, **KW)
    legacy.set_codec(sharded.codec())
    legacy.add(docs)
    qs = unit_queries(rng, 4)
    S0, I0 = legacy.search_batch(qs, k=8)
    S1, I1 = sharded.search_batch(qs, k=8)
    assert_bitwise(S0, I0, S1, I1)
    # flipping every shard to the legacy path must change nothing either
    for s in sharded.shards:
        s.packed_rerank = False
    S2, I2 = sharded.search_batch(qs, k=8)
    assert_bitwise(S1, I1, S2, I2)


def test_packed_prune_path_leaves_recon_unbuilt():
    """Under a tight ndocs budget (stage-3 prune engages, candidate
    width stays below corpus size) serving runs entirely in the
    compressed domain: searches, adds, deletes — recon stays None."""
    rng = np.random.default_rng(3)
    docs = unit_docs(rng, n=120)
    idx = MultiVectorIndex(dim=DIM, backend="plaid", doc_maxlen=24,
                           n_centroids=32, ndocs=16)
    idx.add(docs)
    qs = unit_queries(rng, 6)
    S, I = idx.search_batch(qs, k=5)
    assert (I >= 0).any()
    idx.add(unit_docs(rng, n=4))
    idx.delete([0, 7])
    idx.search_batch(qs, k=5)
    assert idx._plaid.recon is None, \
        "packed serving materialized the reconstruction store"


def test_device_bytes_and_nbytes_accounting():
    """Satellite: the 2-bit packed representation is >= 8x smaller than
    the f32 reconstruction view it replaces, and nbytes() no longer
    hides a resident recon cache."""
    rng = np.random.default_rng(4)
    docs = unit_docs(rng, n=60, dim=128, lo=8, hi=24)
    idx = MultiVectorIndex(dim=128, backend="plaid", doc_maxlen=32,
                           n_centroids=16, quant_bits=2, ndocs=4096)
    idx.add(docs)
    detail = idx._plaid.device_bytes_detail()
    assert detail["recon"] == 0
    assert idx.device_bytes() == sum(detail.values())
    host_before = idx.nbytes()
    idx._plaid.recon_store()                 # force the legacy residency
    detail2 = idx._plaid.device_bytes_detail()
    assert detail2["recon"] / detail["packed"] >= 8.0, detail2
    assert idx.device_bytes() > sum(detail.values())
    assert idx.nbytes() > host_before, \
        "nbytes() silently excludes the resident recon cache"


def test_indexstats_device_bytes_round_trip():
    """IndexStats carries device_bytes and it survives to_json."""
    from repro.retrieval.indexer import IndexStats
    stats = IndexStats(n_docs=2, n_vectors_raw=10, n_vectors_stored=5,
                       index_bytes=100, device_bytes=37)
    assert stats.to_json()["device_bytes"] == 37


def test_spec_rejects_unsupported_bits():
    from repro.core.spec import IndexSpec
    for bad in (0, 1, 3, 8):
        with pytest.raises(ValueError):
            IndexSpec(backend="plaid", quant_bits=bad)
    for ok in (2, 4):
        spec = IndexSpec(backend="plaid", quant_bits=ok)
        assert spec.params()["quant_bits"] == ok   # persisted losslessly


# --------------------------------------------------------------- engine
@pytest.fixture(scope="module")
def plaid_searcher():
    """Real encode -> pool -> PLAID index -> Searcher (smoke config)."""
    import jax
    from dataclasses import replace
    from repro.configs import get_smoke_config
    from repro.data.corpus import DATASET_SPECS, SyntheticRetrievalCorpus
    from repro.models.colbert import init_colbert
    from repro.retrieval.indexer import Indexer
    from repro.retrieval.searcher import Searcher

    cfg = get_smoke_config("colbertv2")
    params = init_colbert(jax.random.PRNGKey(0), cfg)
    # 44 docs / k=11 probe: deliberately DISTINCT from the 40-doc / k=9
    # shapes test_serving_engine.py's cold-probe sanity check relies on
    # compiling fresh (module-level jitted fns share one process-wide
    # cache; duplicating those shapes here would blind that probe)
    spec = replace(DATASET_SPECS["scifact"], n_docs=44, n_queries=32)
    corpus = SyntheticRetrievalCorpus(spec, vocab_size=cfg.trunk.vocab_size)
    indexer = Indexer(params, cfg, pool_method="ward", pool_factor=2,
                      backend="plaid")
    index, stats = indexer.build(corpus.doc_token_batch(cfg.doc_maxlen - 2))
    assert stats.device_bytes == index.device_bytes() > 0
    return (Searcher(params, cfg, index),
            corpus.query_token_batch(cfg.query_maxlen - 2))


def test_engine_packed_no_retrace_mixed_stream(plaid_searcher):
    """After warmup, a mixed-shape request stream over the packed plaid
    path compiles NOTHING new — warm_shapes pre-traces the packed
    candidate-width ladder exactly like the old reconstruction ladder."""
    from repro.launch.engine import CompileCounter, ServingEngine
    searcher, q_all = plaid_searcher
    with CompileCounter() as cold:
        searcher.search(q_all[:5], k=11)
    assert cold.count > 0, "compile probe is not observing compilations"
    with ServingEngine(searcher, max_batch=8, max_wait_ms=1.0, k=10) as eng:
        with CompileCounter() as c:
            futs = [eng.submit(q_all[i:i + n])
                    for i, n in [(0, 3), (3, 1), (4, 5), (9, 2), (11, 8)]]
            for fut in futs:
                fut.result(timeout=60)
        assert c.count == 0, f"{c.count} re-traces in packed engine stream"
    assert eng.stats.snapshot()["failed"] == 0
