"""Mask-aware sequential pooling + vectorized compact_pooled.

Separate from test_pooling.py on purpose: that module is gated on
``hypothesis`` (absent in some containers) and these pins must always
run — they lock the stored-vector counts the paper's Table 3 reductions
are computed from.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.pooling import (compact_pooled, pool_doc_embeddings,
                                sequential_assign)


def _gappy_mask(rng, B, N, frac_valid=0.7):
    mask = rng.random((B, N)) < frac_valid
    mask[:, 0] = True                      # at least one valid token/doc
    return mask


@pytest.mark.parametrize("factor", [2, 3, 4, 6])
def test_sequential_pooled_count_is_ceil_valid_over_factor(factor):
    """THE pin: a doc with n valid tokens stores exactly ceil(n/f)
    sequential-pooled vectors, however its punctuation gaps fall."""
    rng = np.random.default_rng(factor)
    B, N, d = 5, 48, 8
    x = rng.normal(size=(B, N, d)).astype(np.float32)
    mask = _gappy_mask(rng, B, N)
    _, pmask = pool_doc_embeddings(jnp.asarray(x), jnp.asarray(mask),
                                   factor, "sequential")
    counts = np.asarray(pmask).sum(axis=1)
    valid = mask.sum(axis=1)
    np.testing.assert_array_equal(counts, -(-valid // factor))


def test_sequential_assign_groups_span_gaps():
    """Valid tokens group by RANK: a gap inside a run must not split it."""
    mask = np.array([[1, 0, 1, 1, 0, 1, 1, 0]], bool)
    assign = np.asarray(sequential_assign(jnp.asarray(mask), 2))
    # valid ranks 0..4 -> groups [0, 0, 1, 1, 2] at the valid positions
    np.testing.assert_array_equal(assign[0][mask[0]], [0, 0, 1, 1, 2])


@pytest.mark.parametrize("factor", [2, 3])
def test_sequential_masked_equals_gapfree_equivalent(factor):
    """Pooling a gappy doc == pooling its compacted (gap-free) twin."""
    rng = np.random.default_rng(7)
    N, d = 32, 8
    x = rng.normal(size=(1, N, d)).astype(np.float32)
    mask = _gappy_mask(rng, 1, N, frac_valid=0.6)
    a = compact_pooled(*pool_doc_embeddings(
        jnp.asarray(x), jnp.asarray(mask), factor, "sequential"))[0]
    packed = np.zeros_like(x)
    nv = int(mask.sum())
    packed[0, :nv] = x[0][mask[0]]
    pmask = np.arange(N)[None, :] < nv
    b = compact_pooled(*pool_doc_embeddings(
        jnp.asarray(packed), jnp.asarray(pmask), factor, "sequential"))[0]
    np.testing.assert_allclose(a, b, atol=1e-6)


@pytest.mark.parametrize("method", ["sequential", "kmeans", "ward"])
def test_compact_pooled_matches_loop_reference(method):
    rng = np.random.default_rng(3)
    B, N, d = 4, 24, 8
    x = rng.normal(size=(B, N, d)).astype(np.float32)
    mask = _gappy_mask(rng, B, N)
    pooled, pmask = pool_doc_embeddings(jnp.asarray(x), jnp.asarray(mask),
                                        3, method)
    got = compact_pooled(pooled, pmask)
    p, m = np.asarray(pooled), np.asarray(pmask)
    want = [p[b][m[b]] for b in range(B)]
    assert len(got) == B
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_compact_pooled_edge_shapes():
    assert compact_pooled(np.zeros((0, 4, 8)), np.zeros((0, 4), bool)) == []
    out = compact_pooled(np.ones((2, 3, 4)), np.zeros((2, 3), bool))
    assert [len(o) for o in out] == [0, 0]
    one = compact_pooled(np.ones((1, 3, 4)), np.ones((1, 3), bool))
    assert len(one) == 1 and one[0].shape == (3, 4)
