"""Roofline machinery: HLO collective-bytes parser, cell builders for all
40 assigned cells (structure only, no compile), and analytic flops."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.analysis import (RooflineTerms,
                                     collective_bytes_from_hlo)


def test_collective_parser_on_real_hlo():
    """psum under shard_map produces a real all-reduce in the HLO."""
    import os
    if jax.device_count() < 2:
        # single-device: craft HLO text instead
        hlo = """
  %x = f32[1024,256] all-reduce(f32[1024,256] %p), replica_groups={}
  %y = bf16[512]{0} all-gather(bf16[256]{0} %q), dimensions={0}
  %z = f32[16,16] add(f32[16,16] %a, f32[16,16] %b)
"""
        out = collective_bytes_from_hlo(hlo)
        assert out["by_op"]["all-reduce"]["bytes"] == 1024 * 256 * 4
        assert out["by_op"]["all-gather"]["bytes"] == 512 * 2
        assert out["total"] == 1024 * 256 * 4 + 1024
        return


def test_collective_parser_ignores_non_collectives():
    hlo = "%z = f32[64,64] dot(f32[64,64] %a, f32[64,64] %b)"
    assert collective_bytes_from_hlo(hlo)["total"] == 0


def test_roofline_terms_bottleneck():
    t = RooflineTerms(arch="a", cell="c", mesh="16x16",
                      flops=197e12, hlo_bytes=819e9 * 2,
                      collective_bytes=50e9 * 0.5, model_flops=98.5e12)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(2.0)
    assert t.collective_s == pytest.approx(0.5)
    assert t.bottleneck == "memory"
    assert t.useful_flops_frac == pytest.approx(0.5)
    assert t.mfu == pytest.approx(0.25)   # (0.5s of model flops) / 2s


def test_all_40_cells_build_structurally():
    """Every assigned (arch x cell) produces coherent specs without
    lowering (ShapeDtypeStructs + matching PartitionSpec trees)."""
    from repro.configs import ASSIGNED_ARCHS
    from repro.launch.input_specs import all_cells, build_cell
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    n = 0
    for arch in ASSIGNED_ARCHS:
        for cell in all_cells(arch):
            b = build_cell(arch, cell, mesh)
            assert callable(b.fn)
            # every arg tree has a matching spec tree
            for args, specs in zip(b.args, b.in_specs):
                sa = jax.tree_util.tree_structure(
                    jax.tree_util.tree_map(lambda x: 0, args))
                from jax.sharding import PartitionSpec as P
                ss = jax.tree_util.tree_structure(
                    jax.tree_util.tree_map(
                        lambda s: 0, specs,
                        is_leaf=lambda x: isinstance(x, P)))
                assert sa == ss, (arch, cell)
            n += 1
    assert n == 40


def test_model_flops_sane():
    from repro.roofline.run import _model_flops
    # qwen3 train: ~6 * 0.66B * 1.05M tokens / 256 chips ~ 1.6e13
    f = _model_flops("qwen3-0.6b", "train_4k", 256)
    assert 1e13 < f < 1e14
    # decode is tiny by comparison
    fd = _model_flops("qwen3-0.6b", "decode_32k", 256)
    assert fd < f / 100


def test_dot_flops_parser():
    from repro.roofline.hlo_flops import dot_flops_in_hlo
    hlo = ("%d = f32[128,64] dot(f32[128,32] %a, f32[32,64] %b), "
           "lhs_contracting_dims={1}, rhs_contracting_dims={0}")
    out = dot_flops_in_hlo(hlo)
    assert out["total"] == 2 * 128 * 64 * 32
