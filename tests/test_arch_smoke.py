"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finiteness. One test per assigned arch."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_smoke_config

LM_ARCHS = ["kimi-k2-1t-a32b", "moonshot-v1-16b-a3b", "qwen2.5-14b",
            "qwen3-0.6b", "qwen1.5-0.5b"]
RECSYS_ARCHS = ["wide-deep", "deepfm", "fm", "dlrm-rm2"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    from repro.models.transformer import init_transformer, lm_loss
    from repro.train.optimizer import make_optimizer
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_transformer(key, cfg)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    def loss_fn(p):
        loss, m = lm_loss(p, toks, toks, cfg, moe_impl="dense")
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    opt = make_optimizer(cfg.optimizer, 1e-3)
    state = opt.init(params)
    params2, _ = opt.update(params, grads, state)
    l2 = float(loss_fn(params2))
    assert np.isfinite(l2)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_prefill_decode(arch):
    from repro.models.transformer import (decode_step, init_transformer,
                                          prefill)
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_transformer(key, cfg)
    B, S = 2, 8
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    hidden, cache = prefill(params, toks, cfg, max_len=S + 4,
                            moe_impl="dense")
    assert hidden.shape == (B, S, cfg.d_model)
    logits, cache = decode_step(params, toks[:, :1], cache, jnp.int32(S),
                                cfg, moe_impl="dense")
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_moe_capacity_matches_dense_when_roomy():
    """Capacity path == dense path when no token is dropped."""
    from repro.models.moe import init_moe, moe_capacity, moe_dense
    cfg = get_smoke_config("moonshot-v1-16b-a3b")
    key = jax.random.PRNGKey(2)
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model), jnp.float32)
    y_dense, _ = moe_dense(p, x, cfg)
    y_cap, _ = moe_capacity(p, x, cfg, capacity=16 * cfg.top_k)
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_dense),
                               rtol=2e-3, atol=2e-3)


def test_gnn_smoke():
    from repro.models.gnn.dimenet import (build_triplets, dimenet_forward,
                                          init_dimenet)
    cfg = get_smoke_config("dimenet")
    rng = np.random.default_rng(0)
    N, E = 10, 24
    src = rng.integers(0, N, E)
    dst = (src + 1 + rng.integers(0, N - 1, E)) % N
    ei = np.stack([src, dst]).astype(np.int32)
    t_in, t_out, t_mask = build_triplets(ei, N, cfg.triplet_cap)
    inputs = dict(
        pos=jnp.asarray(rng.normal(size=(N, 3)), jnp.float32),
        edge_index=jnp.asarray(ei), t_in=jnp.asarray(t_in),
        t_out=jnp.asarray(t_out), t_mask=jnp.asarray(t_mask),
        node_mask=jnp.ones(N, bool), edge_mask=jnp.ones(E, bool),
        z=jnp.asarray(rng.integers(1, 9, N), jnp.int32),
        graph_ids=jnp.zeros(N, jnp.int32))
    params = init_dimenet(jax.random.PRNGKey(0), cfg)
    out = dimenet_forward(params, inputs, cfg, task="graph", n_graphs=1)
    assert out.shape == (1, cfg.n_targets)
    assert np.isfinite(np.asarray(out)).all()


def test_gnn_sampler_budgets():
    from repro.models.gnn.sampler import NeighborSampler
    rng = np.random.default_rng(0)
    n, e = 200, 2000
    ei = np.stack([rng.integers(0, n, e), rng.integers(0, n, e)])
    s = NeighborSampler(ei, n, fanouts=(3, 2))
    seeds = rng.choice(n, 8, replace=False)
    nodes, sub_ei, nmask, emask = s.sample(seeds)
    assert len(nodes) == s.node_budget(8) == 8 + 24 + 48
    assert sub_ei.shape[1] == s.edge_budget(8) == 24 + 48
    # edges reference in-budget local node ids
    assert sub_ei.max() < len(nodes)
    assert (nodes[:8] == seeds).all()


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke_train_step(arch):
    from repro.models.recsys import init_recsys, recsys_loss
    from repro.train.optimizer import make_optimizer
    cfg = get_smoke_config(arch)
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    params = init_recsys(key, cfg)
    B = 16
    batch = {"sparse_ids": jnp.asarray(
        rng.integers(0, 50, (B, cfg.n_sparse, cfg.multi_hot)), jnp.int32),
        "label": jnp.asarray(rng.integers(0, 2, B), jnp.float32)}
    if cfg.n_dense:
        batch["dense"] = jnp.asarray(rng.normal(size=(B, cfg.n_dense)),
                                     jnp.float32)
    (loss, m), grads = jax.value_and_grad(
        lambda p: recsys_loss(p, batch, cfg), has_aux=True)(params)
    assert np.isfinite(float(loss))
    opt = make_optimizer("adamw", 1e-2)
    params2, _ = opt.update(params, grads, opt.init(params))
    l2, _ = recsys_loss(params2, batch, cfg)
    assert float(l2) < float(loss)      # one step on same batch improves


def test_fm_sum_square_trick_matches_naive():
    """FM identity: sum-square == explicit pairwise dots."""
    from repro.models.recsys.models import _fm_second_order
    rng = np.random.default_rng(4)
    emb = jnp.asarray(rng.normal(size=(3, 7, 5)), jnp.float32)
    fast = np.asarray(_fm_second_order(emb))
    naive = np.zeros(3)
    e = np.asarray(emb)
    for b in range(3):
        for i in range(7):
            for j in range(i + 1, 7):
                naive[b] += e[b, i] @ e[b, j]
    np.testing.assert_allclose(fast, naive, rtol=1e-5)


def test_embedding_bag_matches_manual():
    from repro.models.recsys.embedding import embedding_bag, init_tables
    rng = np.random.default_rng(5)
    p = init_tables(jax.random.PRNGKey(0), (20, 30), 6)
    ids = jnp.asarray(rng.integers(0, 20, (4, 2, 3)), jnp.int32)
    bags = np.asarray(embedding_bag(p, ids))
    t = np.asarray(p["tables"])
    for b in range(4):
        for f in range(2):
            np.testing.assert_allclose(
                bags[b, f], t[f][np.asarray(ids)[b, f]].sum(0), rtol=1e-5)


def test_all_assigned_archs_have_configs():
    for arch in ASSIGNED_ARCHS:
        cfg = get_smoke_config(arch)
        assert cfg.name


def test_flash_kernel_dispatch_parity():
    """cfg.use_flash_kernel swaps in the Pallas kernel; outputs match the
    jnp attention path (bf16 tolerance)."""
    import dataclasses
    from repro.models.transformer import forward, init_transformer
    cfg = get_smoke_config("qwen1.5-0.5b")
    cfg = dataclasses.replace(cfg, causal=True, max_seq_len=128)
    p = init_transformer(jax.random.PRNGKey(0), cfg)
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0,
                           cfg.vocab_size)
    h1, _ = forward(p, t, cfg)
    h2, _ = forward(p, t, dataclasses.replace(cfg, use_flash_kernel=True))
    err = float(jnp.max(jnp.abs(h1.astype(jnp.float32)
                                - h2.astype(jnp.float32))))
    assert err < 0.15    # bf16 end-to-end through 2 layers
