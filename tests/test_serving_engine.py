"""Concurrent serving runtime (launch/engine.py): dynamic batcher
parity, shape-bucket no-retrace, hot swap under load, and the
thread-safety contracts on ShardedIndex probe stats.

Parity regime follows the sharded contract (tests/test_sharded.py):
exhaustive candidate budgets, dim=16, so results are BITWISE equal —
np.array_equal on scores AND ids, not allclose.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.index import MultiVectorIndex
from repro.core.sharded import ShardedIndex
from repro.launch.engine import (CompileCounter, IndexHandle,
                                 ServingEngine, bucket_for, run_open_loop,
                                 shape_buckets)

DIM = 16
LQ = 5
BACKENDS = ["flat", "hnsw", "plaid"]


def unit_docs(rng, n=40, dim=DIM, lo=4, hi=20):
    docs = []
    for _ in range(n):
        v = rng.normal(size=(rng.integers(lo, hi), dim)).astype(np.float32)
        docs.append(v / np.linalg.norm(v, axis=-1, keepdims=True))
    return docs


def unit_queries(rng, n, lq=LQ, dim=DIM):
    q = rng.normal(size=(n, lq, dim)).astype(np.float32)
    return q / np.linalg.norm(q, axis=-1, keepdims=True)


def make_index(backend, sharded=False, n_docs=40, seed=0):
    """Exhaustive-candidate regime: engine results must be bitwise equal
    to direct search, so stage 1 must never prune."""
    rng = np.random.default_rng(seed)
    docs = unit_docs(rng, n=n_docs)
    kw = dict(doc_maxlen=24, n_centroids=8, nprobe=8, ndocs=4096,
              hnsw_candidates=4096)
    if sharded:
        idx = ShardedIndex(dim=DIM, backend=backend,
                           shard_max_vectors=(len(docs) // 3) * 12, **kw)
    else:
        idx = MultiVectorIndex(dim=DIM, backend=backend, **kw)
    idx.add(docs)
    return idx


class VecSearcher:
    """Minimal two-stage searcher for engine tests: 'token' arrays are
    already query vectors, so encode is the identity — engine behavior
    (coalescing, padding, swap) is isolated from the encoder."""

    def __init__(self, index):
        self.index = index

    def encode_queries(self, q):
        return np.asarray(q, np.float32)

    def warmup(self, batch_sizes, k=10):
        if isinstance(batch_sizes, (int, np.integer)):
            batch_sizes = [batch_sizes]
        for bs in sorted(set(batch_sizes)):
            self.index.search_batch(
                np.zeros((bs, LQ, DIM), np.float32), k=k)


# ---------------------------------------------------------------- buckets
def test_shape_buckets():
    assert shape_buckets(32) == [1, 2, 4, 8, 16, 32]
    assert shape_buckets(12) == [1, 2, 4, 8, 12]
    assert shape_buckets(1) == [1]
    assert bucket_for(5, [1, 2, 4, 8]) == 8
    assert bucket_for(8, [1, 2, 4, 8]) == 8
    with pytest.raises(ValueError):
        bucket_for(9, [1, 2, 4, 8])


# ---------------------------------------------------------------- parity
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("sharded", [False, True],
                         ids=["monolithic", "sharded"])
def test_engine_parity_coalesced_padded(backend, sharded):
    """Every request served through the batcher — coalesced with others,
    zero-padded to a shape bucket, split across microbatches — returns
    BITWISE the result of a direct search_batch call."""
    rng = np.random.default_rng(1)
    idx = make_index(backend, sharded=sharded)
    qs = unit_queries(rng, 30)
    S_ref, I_ref = idx.search_batch(qs, k=6)

    with ServingEngine(VecSearcher(idx), max_batch=8, max_wait_ms=2.0,
                       k=6) as eng:
        # mixed request sizes (1..5 queries), all in flight at once
        reqs, futs = [], []
        lo = 0
        sizes = [1, 3, 1, 5, 2, 4, 1, 1, 3, 2, 5, 2]
        for n in sizes:
            reqs.append((lo, n))
            futs.append(eng.submit(qs[lo:lo + n]))
            lo += n
        assert lo == 30
        for (lo, n), fut in zip(reqs, futs):
            S, I = fut.result(timeout=30)
            assert np.array_equal(S, S_ref[lo:lo + n]), (backend, lo)
            assert np.array_equal(I, I_ref[lo:lo + n]), (backend, lo)
    snap = eng.stats.snapshot()
    assert snap["served"] == 30 and snap["failed"] == 0


def test_engine_request_spans_microbatches():
    """A request bigger than max_batch is sliced across several
    microbatches and reassembled in order."""
    rng = np.random.default_rng(2)
    idx = make_index("flat")
    qs = unit_queries(rng, 20)
    S_ref, I_ref = idx.search_batch(qs, k=5)
    with ServingEngine(VecSearcher(idx), max_batch=8, max_wait_ms=1.0,
                       k=5) as eng:
        S, I = eng.submit(qs).result(timeout=30)
    assert np.array_equal(S, S_ref) and np.array_equal(I, I_ref)
    assert eng.stats.snapshot()["batches"] >= 3     # 20 queries / cap 8


def test_engine_k_switch_flush():
    """Requests with different k never share a microbatch; both ks get
    correct (bitwise) results and the flush reason is recorded."""
    rng = np.random.default_rng(3)
    idx = make_index("flat")
    qs = unit_queries(rng, 8)
    S4, I4 = idx.search_batch(qs, k=4)
    S9, I9 = idx.search_batch(qs, k=9)
    with ServingEngine(VecSearcher(idx), max_batch=8, max_wait_ms=20.0,
                       k=4) as eng:
        futs = [eng.submit(qs[i][None], k=(4 if i % 2 == 0 else 9))
                for i in range(8)]
        for i, fut in enumerate(futs):
            S, I = fut.result(timeout=30)
            Sr, Ir = (S4, I4) if i % 2 == 0 else (S9, I9)
            assert np.array_equal(S[0], Sr[i]) and np.array_equal(I[0], Ir[i])
    assert eng.stats.snapshot()["flush_reasons"]["k_switch"] >= 1


def test_engine_concurrent_submitters_parity():
    """Many threads submitting concurrently: no drops, no cross-request
    leakage, every result bitwise-correct."""
    rng = np.random.default_rng(4)
    idx = make_index("flat", n_docs=50)
    qs = unit_queries(rng, 48)
    S_ref, I_ref = idx.search_batch(qs, k=6)
    errors = []

    with ServingEngine(VecSearcher(idx), max_batch=8, max_wait_ms=1.0,
                       k=6) as eng:
        def worker(base):
            try:
                for j in range(base, base + 12, 3):
                    n = min(3, 48 - j)
                    S, I = eng.submit(qs[j:j + n]).result(timeout=30)
                    assert np.array_equal(S, S_ref[j:j + n])
                    assert np.array_equal(I, I_ref[j:j + n])
            except BaseException as e:          # noqa: BLE001
                errors.append(e)
        threads = [threading.Thread(target=worker, args=(b,))
                   for b in (0, 12, 24, 36)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert not errors
    snap = eng.stats.snapshot()
    assert snap["served"] == 48 and snap["failed"] == 0


def test_engine_interleaving_property():
    """Hypothesis: ANY interleaving of concurrent submits preserves
    per-request results vs a solo search_batch — no drops, no
    cross-request leakage, correct unpadding."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    rng = np.random.default_rng(5)
    idx = make_index("flat", n_docs=40)
    pool = unit_queries(rng, 32)
    S_ref, I_ref = idx.search_batch(pool, k=5)
    searcher = VecSearcher(idx)

    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 27), st.integers(1, 5),
                              st.integers(0, 2)),
                    min_size=1, max_size=10))
    def run(requests):
        with ServingEngine(searcher, max_batch=8, max_wait_ms=1.0,
                           k=5, warmup_on_start=False) as eng:
            futs = []
            def submit_some(rs):
                for lo, n, jitter in rs:
                    if jitter:
                        time.sleep(jitter * 1e-3)
                    futs.append((lo, n, eng.submit(pool[lo:lo + n])))
            half = len(requests) // 2
            t = threading.Thread(target=submit_some,
                                 args=(requests[half:],))
            t.start()
            submit_some(requests[:half])
            t.join()
            for lo, n, fut in futs:
                S, I = fut.result(timeout=30)
                assert S.shape == (n, 5) and I.shape == (n, 5)
                assert np.array_equal(S, S_ref[lo:lo + n])
                assert np.array_equal(I, I_ref[lo:lo + n])
            assert len(futs) == len(requests)

    run()


# --------------------------------------------------------------- hot swap
def test_index_handle_drains_before_retire():
    retired = []
    h = IndexHandle("idx", generation=1, on_retire=retired.append)
    h.acquire()
    h.acquire()
    h.retire()
    assert not retired                  # two readers still inside
    h.release()
    assert not retired
    h.release()
    assert retired == [h]               # fired exactly at drain
    assert h.wait_drained(0.1)


def test_engine_swap_index_in_flight_parity():
    """Direct swap while requests are in flight: old handle drains, new
    generation serves, zero failures, results stay bitwise-correct."""
    rng = np.random.default_rng(6)
    idx_a = make_index("flat", seed=6)
    idx_b = make_index("flat", seed=6)      # identical twin
    qs = unit_queries(rng, 32)
    S_ref, I_ref = idx_a.search_batch(qs, k=5)

    with ServingEngine(VecSearcher(idx_a), max_batch=4,
                       max_wait_ms=1.0, k=5) as eng:
        futs = [eng.submit(qs[i][None]) for i in range(16)]
        old = eng.swap_index(idx_b)
        futs += [eng.submit(qs[i][None]) for i in range(16, 32)]
        for i, fut in enumerate(futs):
            S, I = fut.result(timeout=30)
            assert np.array_equal(S[0], S_ref[i])
            assert np.array_equal(I[0], I_ref[i])
        assert old.wait_drained(timeout=10)
    snap = eng.stats.snapshot()
    assert snap["failed"] == 0 and snap["swaps"] == 1
    gens = snap["generations_seen"]
    assert all(a <= b for a, b in zip(gens, gens[1:]))
    assert eng.generation == 1


def test_engine_hot_swap_under_load(tmp_path):
    """Watcher-driven swap with concurrent traffic: republishing the
    artifact bumps the generation, the engine swaps mid-stream, and NO
    query fails or returns a wrong result."""
    from repro.core.persist import save_index

    rng = np.random.default_rng(7)
    idx = make_index("plaid", seed=7)
    qs = unit_queries(rng, 24)
    S_ref, I_ref = idx.search_batch(qs, k=5)
    d = str(tmp_path / "artifact")
    save_index(idx, d)                       # generation 1

    eng = ServingEngine(VecSearcher(idx), max_batch=8, max_wait_ms=1.0,
                        k=5, index_dir=d, poll_interval_s=0.03)
    eng.start()
    assert eng.generation == 1
    stop = threading.Event()
    errors, mismatches = [], []

    def load():
        j = 0
        while not stop.is_set():
            i = j % 24
            try:
                S, I = eng.search(qs[i][None], timeout=30)
                if not (np.array_equal(S[0], S_ref[i])
                        and np.array_equal(I[0], I_ref[i])):
                    mismatches.append(i)
            except Exception as e:           # noqa: BLE001
                errors.append(e)
            j += 1

    threads = [threading.Thread(target=load) for _ in range(3)]
    for t in threads:
        t.start()
    time.sleep(0.3)
    save_index(idx, d)                       # republish -> generation 2
    deadline = time.time() + 15
    while eng.generation < 2 and time.time() < deadline:
        time.sleep(0.02)
    time.sleep(0.3)                          # keep serving post-swap
    stop.set()
    for t in threads:
        t.join()
    eng.stop()

    assert eng.generation == 2, "hot swap not observed"
    assert not errors and not mismatches
    snap = eng.stats.snapshot()
    assert snap["failed"] == 0 and snap["swaps"] == 1
    gens = snap["generations_seen"]
    assert all(a <= b for a, b in zip(gens, gens[1:]))
    assert {1, 2} <= set(gens)               # both generations served


def test_open_loop_driver_zero_errors():
    rng = np.random.default_rng(8)
    idx = make_index("flat")
    qs = unit_queries(rng, 16)
    S_ref, I_ref = idx.search_batch(qs, k=5)
    with ServingEngine(VecSearcher(idx), max_batch=8, max_wait_ms=1.0,
                       k=5) as eng:
        row = run_open_loop(eng, qs, arrival_qps=300.0, n_queries=40,
                            k=5, collect_results=True)
    assert row["errors"] == 0
    for i, (S, I) in enumerate(row["results"]):
        j = i % 16
        assert np.array_equal(S[0], S_ref[j])
        assert np.array_equal(I[0], I_ref[j])


# ---------------------------------------------------- sharded probe stats
def test_sharded_probe_stats_per_call_and_parallel():
    """Per-call probe timings under concurrent batches (no shared-list
    races) and thread-parallel fan-out returning bitwise the sequential
    merge."""
    rng = np.random.default_rng(9)
    idx = make_index("plaid", sharded=True, n_docs=42, seed=9)
    assert idx.n_shards >= 2
    qs = unit_queries(rng, 12)

    idx.probe_threads = 1
    S_seq, I_seq, probe_seq = idx.search_batch_with_stats(qs, k=6)
    assert len(probe_seq) == idx.n_shards
    idx.probe_threads = 4
    S_par, I_par, probe_par = idx.search_batch_with_stats(qs, k=6)
    assert len(probe_par) == idx.n_shards
    assert np.array_equal(S_seq, S_par) and np.array_equal(I_seq, I_par)

    errors = []
    def worker():
        try:
            for _ in range(5):
                S, I, probe = idx.search_batch_with_stats(qs, k=6)
                assert len(probe) == idx.n_shards
                assert all(p >= 0.0 for p in probe)
                assert np.array_equal(S, S_seq)
                assert np.array_equal(I, I_seq)
        except BaseException as e:          # noqa: BLE001
            errors.append(e)
    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    # convenience snapshot still present and complete
    idx.search_batch(qs, k=6)
    assert len(idx.last_probe_s) == idx.n_shards


# ------------------------------------------------------------- no-retrace
@pytest.fixture(scope="module")
def real_searcher():
    """Real encode + flat index on a tiny corpus (flat keeps stage-2
    shapes deterministic, so the compile probe measures only the
    bucket cache)."""
    import jax
    from repro.configs import get_smoke_config
    from repro.data.corpus import DATASET_SPECS, SyntheticRetrievalCorpus
    from repro.models.colbert import init_colbert
    from repro.retrieval.indexer import Indexer
    from repro.retrieval.searcher import Searcher
    from dataclasses import replace

    cfg = get_smoke_config("colbertv2")
    params = init_colbert(jax.random.PRNGKey(0), cfg)
    spec = replace(DATASET_SPECS["scifact"], n_docs=40, n_queries=32)
    corpus = SyntheticRetrievalCorpus(spec, vocab_size=cfg.trunk.vocab_size)
    indexer = Indexer(params, cfg, pool_method="ward", pool_factor=2,
                      backend="flat")
    index, _ = indexer.build(corpus.doc_token_batch(cfg.doc_maxlen - 2))
    return (Searcher(params, cfg, index),
            corpus.query_token_batch(cfg.query_maxlen - 2))


def test_encoder_rows_bitwise_width_stable(real_searcher):
    """The parity contract's foundation: a query's encoded vectors are
    bitwise identical whatever power-of-two width its chunk padded to
    (1-wide, 8-wide, full batch) — so coalescing never changes them."""
    searcher, q_all = real_searcher
    e8 = searcher.encode_queries(q_all[:8])
    e1 = searcher.encode_queries(q_all[:1])
    e3 = searcher.encode_queries(q_all[:3])         # pads to width 4
    assert np.array_equal(e1[0], e8[0])
    assert np.array_equal(e3, e8[:3])


def test_warmup_buckets_no_retrace_mixed_stream(real_searcher):
    """Satellite regression: warming every shape bucket means a mixed
    stream of BUCKET-sized batches compiles NOTHING new (the old warmup
    warmed one size and re-jitted mid-serve on every other), and the
    encoder — which buckets internally — additionally absorbs ARBITRARY
    request sizes without a trace."""
    searcher, q_all = real_searcher
    # probe sanity: a genuinely cold shape (batch 5 / k=9, used nowhere
    # else in this module) MUST register compiles — guards against the
    # jax monitoring event going silent and the 0-assertions below
    # passing vacuously
    with CompileCounter() as cold:
        searcher.search(q_all[:5], k=9)
    assert cold.count > 0, "compile probe is not observing compilations"
    buckets = shape_buckets(8)
    searcher.warmup(buckets, k=10)
    with CompileCounter() as c:
        for bs in (4, 1, 8, 2, 4, 8, 1, 2):     # warmed bucket shapes
            S, I = searcher.search(q_all[:bs], k=10)
            assert S.shape == (bs, 10)
    assert c.count == 0, f"{c.count} re-traces despite bucketed warmup"
    with CompileCounter() as c:
        for bs in (3, 5, 6, 7):                  # odd sizes: encoder pads
            assert len(searcher.encode_queries(q_all[:bs])) == bs
    assert c.count == 0, f"{c.count} encoder re-traces at odd sizes"


def test_engine_no_retrace_after_start(real_searcher):
    """Engine-level version: after start() (which warms the buckets), a
    mixed stream of request sizes triggers zero compilations."""
    searcher, q_all = real_searcher
    with ServingEngine(searcher, max_batch=8, max_wait_ms=1.0,
                       k=10) as eng:
        with CompileCounter() as c:
            futs = [eng.submit(q_all[i:i + n])
                    for i, n in [(0, 3), (3, 1), (4, 5), (9, 2), (11, 8)]]
            for fut in futs:
                fut.result(timeout=60)
        assert c.count == 0, f"{c.count} re-traces in engine stream"
    assert eng.stats.snapshot()["failed"] == 0


def test_serve_microbatches_exact_counts(real_searcher):
    """Satellite regression: n_queries % batch_size != 0 must not wrap
    around and over-serve; per-batch sizes are reported exactly."""
    from repro.launch.serve import serve_microbatches
    searcher, q_all = real_searcher
    lat, sizes = serve_microbatches(searcher, q_all, batch_size=8,
                                    n_queries=19, k=5)
    assert sizes.sum() == 19
    assert list(sizes) == [8, 8, 3]
    assert len(lat) == 3
