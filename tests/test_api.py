"""The ``repro.Retriever`` facade: bitwise parity with the pre-redesign
``Indexer``/``Searcher``/``ServingEngine`` call paths (all backends,
monolithic + sharded + cascade), real-artifact spec round-trips, the
cascade's batched-engine conformance (no-retrace probe), and the
deprecation shims naming their spec replacements."""
import json

import numpy as np
import jax
import pytest

import repro
from repro.configs import get_smoke_config
from repro.core.persist import read_manifest
from repro.core.spec import (IndexSpec, PoolingSpec, RetrieverSpec,
                             ServeSpec, ShardSpec, manifest_meta_for,
                             retriever_spec_from_manifest)
from repro.data.corpus import DatasetSpec, SyntheticRetrievalCorpus
from repro.launch.engine import CompileCounter, ServingEngine
from repro.models.colbert import init_colbert
from repro.retrieval.cascade import build_cascade
from repro.retrieval.indexer import Indexer
from repro.retrieval.searcher import Searcher

BACKENDS = ("flat", "hnsw", "plaid")


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("colbertv2")
    params = init_colbert(jax.random.PRNGKey(0), cfg)
    spec = DatasetSpec("api", n_docs=36, n_queries=8, n_topics=4,
                       doc_len_mean=22, doc_len_std=4, seed=5)
    corpus = SyntheticRetrievalCorpus(spec, vocab_size=cfg.trunk.vocab_size)
    toks = corpus.doc_token_batch(cfg.doc_maxlen - 2)
    q = corpus.query_token_batch(cfg.query_maxlen - 2)[:4]
    return cfg, params, toks, q


def _spec(cfg, backend, factor=2, shard_max=0, **over):
    return RetrieverSpec(
        pooling=PoolingSpec(method="ward", factor=factor),
        index=IndexSpec.from_config(cfg, backend=backend, **over),
        shard=ShardSpec(shard_max_vectors=shard_max))


# ---------------------------------------------------------------------------
# Bitwise parity with the pre-redesign call paths
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_facade_parity_monolithic(setup, backend):
    cfg, params, toks, q = setup
    r = repro.Retriever.build(params, cfg, toks, _spec(cfg, backend))
    S1, I1 = r.search(q, k=5)
    idx, stats = Indexer(params, cfg, pool_method="ward", pool_factor=2,
                         backend=backend).build(toks)
    S2, I2 = Searcher(params, cfg, idx).search(q, k=5)
    assert np.array_equal(S1, S2) and np.array_equal(I1, I2)
    assert r.stats.n_vectors_stored == stats.n_vectors_stored
    assert r.stats.index_bytes == stats.index_bytes


@pytest.mark.parametrize("backend", BACKENDS)
def test_facade_parity_sharded(setup, backend):
    cfg, params, toks, q = setup
    cap = 160
    r = repro.Retriever.build(params, cfg, toks,
                              _spec(cfg, backend, shard_max=cap))
    assert r.index.n_shards > 1
    S1, I1 = r.search(q, k=5)
    idx, _ = Indexer(params, cfg, pool_method="ward", pool_factor=2,
                     backend=backend).build_streaming(
        toks, shard_max_vectors=cap)
    S2, I2 = Searcher(params, cfg, idx).search(q, k=5)
    assert np.array_equal(S1, S2) and np.array_equal(I1, I2)


def test_facade_parity_cascade(setup):
    cfg, params, toks, q = setup
    r = repro.Retriever.build(
        params, cfg, toks,
        _spec(cfg, "cascade", coarse_factor=4, fine_factor=2,
              candidates=16))
    S1, I1 = r.search(q, k=5)
    cascade = build_cascade(params, cfg, toks, coarse_factor=4,
                            fine_factor=2, candidates=16)
    qv = Searcher(params, cfg, None).encode_queries(q)
    S2, I2 = cascade.search_batch(qv, k=5)
    assert np.array_equal(S1, np.asarray(S2))
    assert np.array_equal(I1, np.asarray(I2))


@pytest.mark.parametrize("backend", ["flat", "cascade"])
def test_facade_engine_parity(setup, backend):
    """`.serve()` results == direct facade search, bitwise — cascade
    rides the same runtime as the staged backends."""
    cfg, params, toks, q = setup
    kw = (dict(coarse_factor=4, fine_factor=2, candidates=16)
          if backend == "cascade" else {})
    r = repro.Retriever.build(params, cfg, toks, _spec(cfg, backend, **kw))
    S_ref, I_ref = r.search(q, k=5)
    with r.serve(ServeSpec(max_batch=4, max_wait_ms=1.0, k=5)) as eng:
        futs = [eng.submit(q[i][None]) for i in range(len(q))]
        for i, f in enumerate(futs):
            S, I = f.result(timeout=60)
            assert np.array_equal(S[0], S_ref[i])
            assert np.array_equal(I[0], I_ref[i])


# ---------------------------------------------------------------------------
# Real artifacts: spec round-trip + load parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend,shard_max", [
    ("plaid", 0), ("flat", 160), ("cascade", 0)])
def test_real_artifact_spec_roundtrip(setup, tmp_path, backend, shard_max):
    """The manifest a REAL build writes carries exactly the meta
    ``manifest_meta_for`` predicts, and reloads to an equal spec plus
    bitwise-equal results."""
    cfg, params, toks, q = setup
    kw = (dict(coarse_factor=4, fine_factor=2, candidates=16)
          if backend == "cascade" else {})
    spec = _spec(cfg, backend, shard_max=shard_max, **kw)
    out = str(tmp_path / "idx")
    r = repro.Retriever.build(params, cfg, toks, spec, out_dir=out)
    manifest = read_manifest(out)
    expect = manifest_meta_for(spec)
    for key, val in expect.items():
        assert json.loads(json.dumps(manifest[key])) == \
            json.loads(json.dumps(val)), key
    back = retriever_spec_from_manifest(manifest)
    assert back.pooling == spec.pooling
    assert back.index == spec.index
    assert back.shard == spec.shard

    r2 = repro.Retriever.load(params, cfg, out)
    assert r2.spec.index == spec.index
    assert r2.spec.pooling == spec.pooling
    assert r2.stats.n_docs == r.stats.n_docs            # stats.json rides
    assert r2.stats.index_bytes == r.stats.index_bytes
    S1, I1 = r.search(q, k=5)
    S2, I2 = r2.search(q, k=5)
    assert np.array_equal(S1, S2) and np.array_equal(I1, I2)
    # the artifact also serves through the pre-facade entry point
    S3, I3 = Searcher.from_dir(params, cfg, out).search(q, k=5)
    assert np.array_equal(S1, S3) and np.array_equal(I1, I3)


# ---------------------------------------------------------------------------
# Cascade engine conformance: Searcher.from_dir -> ServingEngine,
# warmed buckets, zero re-traces mid-stream
# ---------------------------------------------------------------------------
def test_cascade_from_dir_engine_no_retrace(setup, tmp_path):
    cfg, params, toks, q = setup
    out = str(tmp_path / "casc")
    repro.Retriever.build(
        params, cfg, toks,
        _spec(cfg, "cascade", coarse_factor=4, fine_factor=2,
              candidates=16), out_dir=out)
    searcher = Searcher.from_dir(params, cfg, out)
    S_ref, I_ref = searcher.search(q, k=10)
    eng = ServingEngine(searcher, max_batch=4, max_wait_ms=1.0, k=10)
    with eng:
        eng.search(q[:1])               # settle any first-dispatch noise
        with CompileCounter() as c:
            for n in (1, 3, 2, 4, 1):
                idx = np.arange(n) % len(q)
                S, I = eng.search(q[idx])
                assert np.array_equal(S, S_ref[idx])
                assert np.array_equal(I, I_ref[idx])
        assert c.count == 0, f"{c.count} re-traces on warm buckets"


def test_cascade_warm_shapes_via_searcher_warmup(setup):
    cfg, params, toks, q = setup
    r = repro.Retriever.build(
        params, cfg, toks,
        _spec(cfg, "cascade", coarse_factor=4, fine_factor=2,
              candidates=16))
    assert hasattr(r.index, "warm_shapes")
    r.warmup([1, 2, 4], k=10)           # dispatches through warm_shapes
    with CompileCounter() as c:
        r.search(q[:2], k=10)
        r.search(q[:4], k=10)
    assert c.count == 0


# ---------------------------------------------------------------------------
# CRUD through the facade
# ---------------------------------------------------------------------------
def test_facade_add_delete(setup):
    cfg, params, toks, q = setup
    r = repro.Retriever.build(params, cfg, toks[:30], _spec(cfg, "hnsw"))
    assert r.stats.n_docs == 30
    ids = r.add(toks[30:])
    assert list(ids) == list(range(30, len(toks)))
    assert r.stats.n_docs == len(toks)  # CRUD invalidates cached stats
    S, I = r.search(q, k=5)
    victim = int(I[0][0])
    r.delete([victim])
    _, I2 = r.search(q[:1], k=5)
    assert victim not in I2[0].tolist()


def test_facade_add_cascade(setup):
    cfg, params, toks, q = setup
    r = repro.Retriever.build(
        params, cfg, toks[:30],
        _spec(cfg, "cascade", coarse_factor=4, fine_factor=2,
              candidates=16))
    ids = r.add(toks[30:])
    assert list(ids) == list(range(30, len(toks)))
    assert r.index.n_docs == len(toks)
    with pytest.raises(NotImplementedError):
        r.delete([0])


# ---------------------------------------------------------------------------
# Deprecation shims name the spec replacement
# ---------------------------------------------------------------------------
def test_indexer_kwargs_deprecated(setup):
    cfg, params, toks, _ = setup
    with pytest.warns(DeprecationWarning, match="IndexSpec"):
        ix = Indexer(params, cfg, backend="flat", ndocs=64)
    assert ix.index_spec.ndocs == 64    # shim still honors the knob
    with pytest.raises(TypeError):      # but not both surfaces at once
        Indexer(params, cfg, index_spec=IndexSpec(backend="flat"),
                ndocs=64)
    with pytest.raises(TypeError):
        Indexer(params, cfg, pool_method="kmeans",
                pooling_spec=PoolingSpec("ward", 2))
    with pytest.raises(ValueError, match="Retriever"):
        Indexer(params, cfg, index_spec=IndexSpec(backend="cascade"))


def test_coerce_dict_defaults_from_config(setup):
    """A dict spec's omitted sections default from cfg, same as the
    bare-spec forms — not from the class defaults."""
    import dataclasses
    cfg, params, toks, q = setup
    cfg2 = dataclasses.replace(cfg, pool_factor=2)
    got = RetrieverSpec.coerce({"index": {"backend": "flat"}}, cfg2)
    assert got.pooling == PoolingSpec(method=cfg2.pool_method, factor=2)
    assert got.index.backend == "flat"
    with pytest.raises(ValueError, match="bogus"):
        RetrieverSpec.coerce({"bogus": {}}, cfg2)


def test_searcher_encode_deprecated(setup):
    cfg, params, toks, q = setup
    s = Searcher(params, cfg, None)
    ref = s.encode_queries(q[:1])
    with pytest.warns(DeprecationWarning, match="encode_queries"):
        legacy = s.encode(q[:1])
    assert np.array_equal(ref, legacy)


# ---------------------------------------------------------------------------
# Custom pooling strategy rides the whole facade
# ---------------------------------------------------------------------------
def test_custom_pooling_strategy_through_facade(setup):
    cfg, params, toks, q = setup
    name = "api-first-half"

    def first_half(x, mask, factor):
        m = np.asarray(mask, bool)
        rank = np.cumsum(m, axis=-1) - 1
        budget = np.ceil(m.sum(-1, keepdims=True) / factor)
        return np.asarray(x), m & (rank < budget)

    repro.register_pooling_strategy(name, first_half, overwrite=True)
    r = repro.Retriever.build(
        params, cfg, toks,
        RetrieverSpec(pooling=PoolingSpec(method=name, factor=2),
                      index=IndexSpec.from_config(cfg, backend="flat")))
    baseline = repro.Retriever.build(params, cfg, toks,
                                     _spec(cfg, "flat", factor=1))
    assert 0 < r.stats.n_vectors_stored < baseline.stats.n_vectors_stored
    S, I = r.search(q, k=5)
    assert I.shape == (len(q), 5) and np.all(I >= 0)
