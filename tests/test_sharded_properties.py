"""Property tests: shard-merge parity across backend x pool method.

Documents are produced by the REAL pooling stage (random embeddings ->
pool_doc_embeddings -> compact_pooled) so every pool method's output
geometry — short docs, variable lengths, renormalized means — feeds the
sharded engine; then a 2-4 shard ShardedIndex must return exactly the
monolithic ids and scores (exhaustive-candidate regime, shared plaid
codec — the parity contract in core/sharded.py).

Gated on ``hypothesis`` (PR 1 convention: skip, don't fail, in
containers without it; CI installs it).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
import jax.numpy as jnp

from repro.core.index import MultiVectorIndex
from repro.core.pooling import compact_pooled, pool_doc_embeddings
from repro.core.sharded import ShardedIndex

# dim must satisfy the residual packer's dim % (32 / bits) == 0
DIM = 16
KW = dict(doc_maxlen=24, n_centroids=8, ndocs=4096, hnsw_candidates=8192)


def pooled_corpus(seed, n_docs, method, factor):
    rng = np.random.default_rng(seed)
    N = 20
    x = rng.normal(size=(n_docs, N, DIM)).astype(np.float32)
    lens = rng.integers(4, N + 1, size=n_docs)
    mask = np.arange(N)[None, :] < lens[:, None]
    pooled, pmask = pool_doc_embeddings(jnp.asarray(x), jnp.asarray(mask),
                                        factor, method)
    docs = compact_pooled(pooled, pmask)
    qs = rng.normal(size=(4, 5, DIM)).astype(np.float32)
    return docs, qs / np.linalg.norm(qs, axis=-1, keepdims=True)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n_docs=st.integers(6, 24),
       n_shards=st.integers(2, 4),
       backend=st.sampled_from(["flat", "hnsw", "plaid"]),
       method=st.sampled_from(["sequential", "kmeans", "ward"]),
       factor=st.sampled_from([1, 2, 4]))
def test_sharded_equals_monolithic(seed, n_docs, n_shards, backend,
                                   method, factor):
    docs, qs = pooled_corpus(seed, n_docs, method, factor)
    total = sum(len(d) for d in docs)
    cap = max(total // n_shards, max(len(d) for d in docs), 1)
    sharded = ShardedIndex(dim=DIM, backend=backend,
                           shard_max_vectors=cap, **KW)
    ids = sharded.add(docs)
    np.testing.assert_array_equal(ids, np.arange(n_docs))
    mono = MultiVectorIndex(dim=DIM, backend=backend, **KW)
    if backend == "plaid":
        mono.set_codec(sharded.codec())
    mono.add(docs)
    S1, I1 = sharded.search_batch(qs, k=min(8, n_docs))
    S0, I0 = mono.search_batch(qs, k=min(8, n_docs))
    np.testing.assert_array_equal(I0, I1)
    np.testing.assert_array_equal(np.asarray(S0), np.asarray(S1))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n_docs=st.integers(4, 16),
       backend=st.sampled_from(["flat", "plaid"]))
def test_sharded_delete_then_parity(seed, n_docs, backend):
    docs, qs = pooled_corpus(seed, n_docs, "ward", 2)
    total = sum(len(d) for d in docs)
    cap = max(total // 3, max(len(d) for d in docs), 1)
    sharded = ShardedIndex(dim=DIM, backend=backend,
                           shard_max_vectors=cap, **KW)
    sharded.add(docs)
    mono = MultiVectorIndex(dim=DIM, backend=backend, **KW)
    if backend == "plaid":
        mono.set_codec(sharded.codec())
    mono.add(docs)
    victims = list(range(0, n_docs, 3))
    sharded.delete(victims)
    mono.delete(victims)
    S1, I1 = sharded.search_batch(qs, k=n_docs)
    S0, I0 = mono.search_batch(qs, k=n_docs)
    np.testing.assert_array_equal(I0, I1)
    assert not np.isin(np.asarray(I1)[np.asarray(I1) >= 0], victims).any()
