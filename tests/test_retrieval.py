"""Retrieval-stack integration: index backends, CRUD, PLAID staged search,
metrics, and the paper's end-to-end relative-performance protocol."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.index import MultiVectorIndex
from repro.retrieval.metrics import ndcg_at_k, recall_at_k, success_at_k


def make_topical_docs(rng, dim=16, n_topics=4, n_docs=40):
    topics = rng.normal(size=(n_topics, dim)).astype(np.float32)
    docs, labels = [], []
    for i in range(n_docs):
        t = i % n_topics
        v = topics[t] + 0.3 * rng.normal(size=(rng.integers(6, 20), dim))
        v = v / np.linalg.norm(v, axis=-1, keepdims=True)
        docs.append(v.astype(np.float32))
        labels.append(t)
    return topics, docs, np.array(labels)


@pytest.mark.parametrize("backend", ["flat", "hnsw", "plaid"])
def test_index_topical_retrieval(backend):
    rng = np.random.default_rng(0)
    topics, docs, labels = make_topical_docs(rng)
    idx = MultiVectorIndex(dim=16, backend=backend, doc_maxlen=24,
                           n_centroids=16, ndocs=64)
    idx.add(docs)
    q = topics[1] + 0.2 * rng.normal(size=(5, 16))
    q = (q / np.linalg.norm(q, axis=-1, keepdims=True)).astype(np.float32)
    s, i = idx.search(q, k=8)
    top4 = [labels[d] for d in i[:4]]
    assert top4.count(1) >= 3, (backend, top4)


@pytest.mark.parametrize("backend", ["flat", "hnsw", "plaid"])
def test_index_crud(backend):
    rng = np.random.default_rng(1)
    _, docs, labels = make_topical_docs(rng)
    idx = MultiVectorIndex(dim=16, backend=backend, doc_maxlen=24,
                           n_centroids=16, ndocs=64)
    idx.add(docs[:30])
    new_ids = idx.add(docs[30:])
    assert list(new_ids) == list(range(30, 40))
    q = docs[35][:4]
    s, i = idx.search(q, k=3)
    top = int(i[0])
    idx.delete([top])
    s2, i2 = idx.search(q, k=3)
    assert top not in list(i2)


def test_plaid_stages_prune_but_find():
    """PLAID staged search must agree with flat exact search on top-1
    for easy (well-separated) queries."""
    rng = np.random.default_rng(2)
    _, docs, labels = make_topical_docs(rng, n_docs=60)
    flat = MultiVectorIndex(dim=16, backend="flat", doc_maxlen=24)
    plaid = MultiVectorIndex(dim=16, backend="plaid", doc_maxlen=24,
                             n_centroids=32, nprobe=8, ndocs=64,
                             quant_bits=4)
    flat.add(docs)
    plaid.add(docs)
    hits = 0
    for d in (3, 17, 42):
        q = docs[d][:6]
        _, i_flat = flat.search(q, k=5)
        _, i_plaid = plaid.search(q, k=5)
        hits += int(i_flat[0] in list(i_plaid[:3]))
    assert hits >= 2


def test_quantization_reconstruction():
    from repro.core.quantization import (reconstruction_error, train_codec)
    rng = np.random.default_rng(3)
    vecs = rng.normal(size=(500, 32)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=-1, keepdims=True)
    cents = rng.normal(size=(32, 32)).astype(np.float32)
    cents /= np.linalg.norm(cents, axis=-1, keepdims=True)
    cos2 = reconstruction_error(train_codec(jnp.asarray(vecs),
                                            jnp.asarray(cents), bits=2),
                                jnp.asarray(vecs))
    cos4 = reconstruction_error(train_codec(jnp.asarray(vecs),
                                            jnp.asarray(cents), bits=4),
                                jnp.asarray(vecs))
    assert float(cos4) > float(cos2) > 0.5   # more bits, better recon


# ------------------------------------------------------------- metrics
def test_metrics_known_values():
    ranked = [[1, 2, 3], [9, 8, 7]]
    qrels = [{1: 2, 3: 1}, {7: 1}]
    assert success_at_k(ranked, qrels, 1) == 0.5
    assert success_at_k(ranked, qrels, 3) == 1.0
    assert recall_at_k(ranked, qrels, 3) == 1.0
    assert recall_at_k(ranked, qrels, 1) == 0.25    # (1/2 + 0) / 2
    n = ndcg_at_k(ranked, qrels, 3)
    # query1: dcg = 3/log2(2) + 1/log2(4) = 3.5; idcg = 3 + 1/log2(3)
    q1 = 3.5 / (3 + 1 / np.log2(3))
    q2 = (1 / np.log2(4)) / 1.0
    np.testing.assert_allclose(n, (q1 + q2) / 2, rtol=1e-6)


def test_metrics_perfect_ranking_is_one():
    qrels = [{0: 2, 1: 1}]
    assert ndcg_at_k([[0, 1, 5]], qrels, 10) == pytest.approx(1.0)
    assert recall_at_k([[0, 1]], qrels, 5) == 1.0


# --------------------------------------------- end-to-end paper protocol
def test_evaluate_pooling_end_to_end():
    from repro.configs import get_smoke_config
    from repro.data.corpus import DatasetSpec, SyntheticRetrievalCorpus
    from repro.models.colbert import init_colbert
    from repro.retrieval.evaluate import evaluate_pooling
    cfg = get_smoke_config("colbertv2")
    params = init_colbert(jax.random.PRNGKey(0), cfg)
    spec = DatasetSpec("t", n_docs=60, n_queries=12, n_topics=6,
                       doc_len_mean=30, doc_len_std=5, seed=5)
    corpus = SyntheticRetrievalCorpus(spec, vocab_size=cfg.trunk.vocab_size)
    rep = evaluate_pooling(params, cfg, corpus, methods=("ward",),
                           factors=(2,), backend="flat")
    assert rep.baseline_metric > 0
    cell = rep.cell("ward", 2)
    assert cell is not None
    assert 0.35 <= cell.vector_reduction <= 0.55     # ~50% fewer vectors
    assert cell.relative > 50                        # sane relative perf


def test_indexer_vector_reduction_scaling():
    """Pooling factor f removes ~ (1 - 1/f) of vectors (paper Table 3)."""
    from repro.configs import get_smoke_config
    from repro.data.corpus import DatasetSpec, SyntheticRetrievalCorpus
    from repro.models.colbert import init_colbert
    from repro.retrieval.indexer import Indexer
    cfg = get_smoke_config("colbertv2")
    params = init_colbert(jax.random.PRNGKey(0), cfg)
    spec = DatasetSpec("t2", n_docs=40, n_queries=8, n_topics=4,
                       doc_len_mean=40, doc_len_std=4, seed=6)
    corpus = SyntheticRetrievalCorpus(spec, vocab_size=cfg.trunk.vocab_size)
    toks = corpus.doc_token_batch(cfg.doc_maxlen - 2)
    for f in (2, 3, 4):
        _, stats = Indexer(params, cfg, pool_method="ward", pool_factor=f,
                           backend="flat").build(toks)
        expect = 1 - 1 / f
        assert abs(stats.vector_reduction - expect) < 0.12, (f, stats)
