"""Property tests: replica-router parity across (backend x n_replicas x
n_shards x k).

Every replica lane — including the degenerate "all queries routed to
one lane" pattern — must return bitwise the wrapped index's results;
dead-even score ties (duplicate docs straddling shard boundaries) must
keep the monolithic tie order through the per-lane merge.

Gated on ``hypothesis`` (PR 1 convention: skip, don't fail, in
containers without it; CI installs it).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.index import MultiVectorIndex
from repro.core.replicated import ReplicatedIndex
from repro.core.sharded import ShardedIndex

DIM = 16
KW = dict(doc_maxlen=24, n_centroids=8, ndocs=4096, hnsw_candidates=8192)


def corpus(seed, n_docs):
    rng = np.random.default_rng(seed)
    docs = []
    for _ in range(n_docs):
        v = rng.normal(size=(rng.integers(3, 9), DIM)).astype(np.float32)
        docs.append(v / np.linalg.norm(v, axis=-1, keepdims=True))
    qs = rng.normal(size=(4, 5, DIM)).astype(np.float32)
    return docs, qs / np.linalg.norm(qs, axis=-1, keepdims=True)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n_docs=st.integers(6, 24),
       n_shards=st.integers(1, 4), n_replicas=st.integers(1, 3),
       backend=st.sampled_from(["flat", "hnsw", "plaid"]),
       k=st.sampled_from([1, 3, 10, 40]))
def test_every_lane_equals_wrapped_index(seed, n_docs, n_shards,
                                         n_replicas, backend, k):
    docs, qs = corpus(seed, n_docs)
    vecs = sum(len(d) for d in docs)
    cap = max(1, -(-vecs // n_shards))          # ceil: ~n_shards shards
    inner = ShardedIndex(dim=DIM, backend=backend, shard_max_vectors=cap,
                         **KW)
    inner.add(docs)
    S0, I0 = inner.search_batch(qs, k=k)
    force = backend == "flat" and (seed % 2 == 0)
    rep = ReplicatedIndex.replicate(inner, n_replicas,
                                    use_shard_map=True if force else None)
    for r in range(n_replicas):
        S, I = rep.search_batch_on(r, qs, k=k)
        assert np.array_equal(S, S0), (backend, r, k)
        assert np.array_equal(I, I0), (backend, r, k)
    # all-queries-one-replica: hammering a single non-zero lane (the
    # router's worst skew) changes nothing, run to run
    r = seed % n_replicas
    for _ in range(2):
        S, I = rep.search_batch_on(r, qs, k=k)
        assert np.array_equal(S, S0) and np.array_equal(I, I0)
    # out-of-range lane ids wrap (router modulo contract)
    S, I = rep.search_batch_on(n_replicas, qs, k=k)
    assert np.array_equal(S, S0) and np.array_equal(I, I0)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n_dups=st.integers(2, 6),
       n_replicas=st.integers(1, 3),
       backend=st.sampled_from(["flat", "hnsw", "plaid"]))
def test_dead_even_ties_keep_monolithic_order(seed, n_dups, n_replicas,
                                              backend):
    """Duplicate one doc across shard boundaries: its copies score
    EXACTLY equal, so any merge that reorders ties (or resolves them per
    lane differently) is caught here against the monolithic order."""
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(4, DIM)).astype(np.float32)
    base /= np.linalg.norm(base, axis=-1, keepdims=True)
    docs = []
    for i in range(12):
        if i % (12 // n_dups) == 0 and sum(
                1 for d in docs if d is base) < n_dups:
            docs.append(base)
        v = rng.normal(size=(rng.integers(3, 7), DIM)).astype(np.float32)
        docs.append(v / np.linalg.norm(v, axis=-1, keepdims=True))
    vecs = sum(len(d) for d in docs)
    cap = max(1, vecs // 3)                     # boundaries split the dups
    inner = ShardedIndex(dim=DIM, backend=backend, shard_max_vectors=cap,
                         **KW)
    inner.add(docs)
    qs = base[None, :3, :] + 0.0                # query = a duplicated doc
    k = min(len(docs), 10)
    S0, I0 = inner.search_batch(qs, k=k)
    rep = ReplicatedIndex.replicate(inner, n_replicas)
    for r in range(n_replicas):
        S, I = rep.search_batch_on(r, qs, k=k)
        assert np.array_equal(S, S0)
        assert np.array_equal(I, I0)
    if backend == "flat":
        forced = ReplicatedIndex.replicate(inner, n_replicas,
                                           use_shard_map=True)
        for r in range(n_replicas):
            S, I = forced.search_batch_on(r, qs, k=k)
            assert np.array_equal(S, S0)
            assert np.array_equal(I, I0)
