"""Ranking metrics (ranx-equivalent formulas, pure numpy).

The paper reports NDCG@10 (BEIR), Success@5 (LoTTe), Recall@5 (Japanese),
always as RELATIVE performance vs the unpooled baseline (100 = baseline).

These per-query Python loops are the REFERENCE implementations: the
batched device metrics in ``repro.eval.metrics`` are pinned against
them (bitwise on the integer gain/rank structures, allclose on the
float means) and are what the quality sweep actually runs.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np


def _gains(ranked_ids: Sequence[int], qrel: Dict[int, int],
           k: int) -> np.ndarray:
    return np.array([qrel.get(int(d), 0) for d in ranked_ids[:k]],
                    np.float64)


def ndcg_at_k(ranked: List[Sequence[int]], qrels: List[Dict[int, int]],
              k: int = 10) -> float:
    """Mean NDCG@k with the standard log2 discount and exponential gains."""
    vals = []
    for ids, qrel in zip(ranked, qrels):
        if not qrel:
            continue
        g = _gains(ids, qrel, k)
        disc = 1.0 / np.log2(np.arange(2, len(g) + 2))
        dcg = np.sum((2.0 ** g - 1.0) * disc)
        ideal = np.sort([r for r in qrel.values()])[::-1][:k].astype(float)
        idisc = 1.0 / np.log2(np.arange(2, len(ideal) + 2))
        idcg = np.sum((2.0 ** ideal - 1.0) * idisc)
        vals.append(dcg / idcg if idcg > 0 else 0.0)
    return float(np.mean(vals)) if vals else 0.0


def success_at_k(ranked: List[Sequence[int]], qrels: List[Dict[int, int]],
                 k: int = 5) -> float:
    """Fraction of queries with >=1 relevant doc in the top k."""
    vals = []
    for ids, qrel in zip(ranked, qrels):
        if not qrel:
            continue
        vals.append(float(any(qrel.get(int(d), 0) > 0 for d in ids[:k])))
    return float(np.mean(vals)) if vals else 0.0


def recall_at_k(ranked: List[Sequence[int]], qrels: List[Dict[int, int]],
                k: int = 5) -> float:
    """Mean fraction of relevant docs retrieved in the top k."""
    vals = []
    for ids, qrel in zip(ranked, qrels):
        rel = {d for d, r in qrel.items() if r > 0}
        if not rel:
            continue
        hit = sum(1 for d in ids[:k] if int(d) in rel)
        vals.append(hit / len(rel))
    return float(np.mean(vals)) if vals else 0.0


def mrr_at_k(ranked: List[Sequence[int]], qrels: List[Dict[int, int]],
             k: int = 10) -> float:
    """Mean reciprocal rank of the first relevant doc in the top k."""
    vals = []
    for ids, qrel in zip(ranked, qrels):
        if not qrel:
            continue
        rr = 0.0
        for pos, d in enumerate(ids[:k], start=1):
            if qrel.get(int(d), 0) > 0:
                rr = 1.0 / pos
                break
        vals.append(rr)
    return float(np.mean(vals)) if vals else 0.0


METRICS = {"ndcg@10": lambda r, q: ndcg_at_k(r, q, 10),
           "success@5": lambda r, q: success_at_k(r, q, 5),
           "recall@5": lambda r, q: recall_at_k(r, q, 5),
           "mrr@10": lambda r, q: mrr_at_k(r, q, 10)}
