from repro.retrieval.indexer import Indexer
from repro.retrieval.searcher import Searcher
from repro.retrieval.metrics import ndcg_at_k, recall_at_k, success_at_k
from repro.retrieval.evaluate import evaluate_pooling, relative_performance

__all__ = ["Indexer", "Searcher", "ndcg_at_k", "recall_at_k",
           "success_at_k", "evaluate_pooling", "relative_performance"]
