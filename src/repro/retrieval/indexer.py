"""Indexer: encode -> TOKEN POOL -> index. The paper's pipeline, end to end.

``Indexer.build`` runs the document side:
  1. encode documents in device batches with the ColBERT encoder,
  2. apply ``pool_doc_embeddings`` (the paper's technique — method +
     pooling factor are config knobs; factor 1 = the unpooled baseline),
  3. hand the per-document (compacted) vector lists to the chosen index
     backend (flat | hnsw | plaid).

Data-parallel posture: document batches are independent, so under pjit the
encode+pool step shards on the ``data`` axis; the index build consumes the
gathered host-side lists (index construction is host-bound bookkeeping).
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ColbertConfig
from repro.core.index import MultiVectorIndex
from repro.core.pooling import compact_pooled, pool_doc_embeddings
from repro.models.colbert import encode_docs


@dataclass
class IndexStats:
    n_docs: int
    n_vectors_raw: int
    n_vectors_stored: int
    index_bytes: int     # real serialized artifact size (core/persist.py)

    @property
    def vector_reduction(self) -> float:
        if self.n_vectors_raw == 0:
            return 0.0
        return 1.0 - self.n_vectors_stored / self.n_vectors_raw

    def to_json(self) -> dict:
        return dict(dataclasses.asdict(self),
                    vector_reduction=self.vector_reduction)


class Indexer:
    def __init__(self, params, cfg: ColbertConfig,
                 pool_method: Optional[str] = None,
                 pool_factor: Optional[int] = None,
                 backend: Optional[str] = None,
                 encode_batch: int = 64, **index_kw):
        self.params = params
        self.cfg = cfg
        self.pool_method = pool_method or cfg.pool_method
        self.pool_factor = (pool_factor if pool_factor is not None
                            else cfg.pool_factor)
        self.backend = backend or cfg.index_backend
        self.encode_batch = encode_batch
        self.index_kw = index_kw

    def encode_and_pool(self, doc_tokens: np.ndarray) -> List[np.ndarray]:
        """doc_tokens [N, L] -> list of per-doc pooled vector arrays."""
        out: List[np.ndarray] = []
        N = doc_tokens.shape[0]
        if N == 0:
            return out
        B = self.encode_batch
        for lo in range(0, N, B):
            chunk = doc_tokens[lo:lo + B]
            pad = B - chunk.shape[0]
            if pad:
                chunk = np.pad(chunk, ((0, pad), (0, 0)))
            v, emit = encode_docs(self.params, jnp.asarray(chunk), self.cfg)
            method = ("none" if self.pool_factor <= 1 else self.pool_method)
            pooled, pmask = pool_doc_embeddings(
                v, emit, max(self.pool_factor, 1), method)
            docs = compact_pooled(pooled, pmask)
            out.extend(docs[:B - pad] if pad else docs)
        return out

    def build(self, doc_tokens: np.ndarray,
              out_dir: Optional[str] = None):
        """Returns (MultiVectorIndex, IndexStats).

        ``out_dir`` writes the index artifact (core/persist.py) plus a
        ``stats.json`` beside its manifest, so the built index can be
        re-served by ``Searcher.from_dir`` / ``serve --index-dir``
        without re-encoding the corpus. ``index_bytes`` is always the
        *serialized* size — what the artifact occupies on disk — not
        the in-memory high-water mark.
        """
        from repro.core.persist import artifact_bytes, serialized_nbytes
        doc_vecs = self.encode_and_pool(doc_tokens)
        raw = self._raw_vector_count(doc_tokens)
        kw = dict(doc_maxlen=self.cfg.doc_maxlen,
                  n_centroids=self.cfg.n_centroids,
                  quant_bits=self.cfg.quant_bits,
                  nprobe=self.cfg.nprobe, t_cs=self.cfg.t_cs,
                  ndocs=self.cfg.ndocs)
        kw.update(self.index_kw)        # explicit kwargs override config
        index = MultiVectorIndex(dim=self.cfg.proj_dim,
                                 backend=self.backend, **kw)
        index.add(doc_vecs)
        if out_dir is not None:
            manifest = index.save(out_dir, extra_meta={
                "pool": {"method": self.pool_method,
                         "factor": self.pool_factor}})
            index_bytes = artifact_bytes(manifest)
        else:
            index_bytes = serialized_nbytes(index)
        stats = IndexStats(
            n_docs=index.n_docs,
            n_vectors_raw=raw,
            n_vectors_stored=index.n_vectors(),
            index_bytes=index_bytes,
        )
        if out_dir is not None:
            with open(os.path.join(out_dir, "stats.json"), "w") as fh:
                json.dump(stats.to_json(), fh, indent=2)
        return index, stats

    def _raw_vector_count(self, doc_tokens: np.ndarray) -> int:
        """Unpooled emitted-vector count (for Table 3 reductions)."""
        from repro.models.colbert import (emit_mask_docs,
                                          prepare_doc_tokens)
        toks, attn = prepare_doc_tokens(jnp.asarray(doc_tokens),
                                        self.cfg.doc_maxlen)
        emit = emit_mask_docs(toks, attn, self.cfg.mask_punctuation)
        return int(np.asarray(emit).sum())
