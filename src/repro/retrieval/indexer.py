"""Indexer: encode -> TOKEN POOL -> index. The paper's pipeline, end to end.

``Indexer.build`` runs the document side:
  1. encode documents in device batches with the ColBERT encoder,
  2. apply ``pool_doc_embeddings`` (the paper's technique — method +
     pooling factor are config knobs; factor 1 = the unpooled baseline),
  3. hand the per-document (compacted) vector lists to the chosen index
     backend (flat | hnsw | plaid).

``Indexer.build_streaming`` is the same pipeline with bounded host
memory: an ITERATOR of token batches is encoded+pooled batch by batch,
and the pooled buffer is flushed into a new on-disk shard whenever
``shard_max_vectors`` is hit — peak host footprint is O(shard), not
O(corpus) (the prerequisite the pooled-footprint win needs to survive
corpora bigger than RAM). Flushed shards are immediately saved and
re-opened mmap'd, so the finished ``ShardedIndex`` holds file mappings,
not buffers.

Flushing is PIPELINED by default: a single background thread runs the
host-side shard construction + save + mmap-reopen while the device
encodes the next batches, double-buffered through a depth-1 queue so
encode is never idle behind shard I/O (``IndexStats.flush_wait_s`` is
the realized stall; ``pipeline=False`` pins the serial path, which the
bench's parity gate builds against — shard order, doc ids and artifact
bytes are identical either way).

Data-parallel posture: document batches are independent, so under pjit the
encode+pool step shards on the ``data`` axis; the index build consumes the
gathered host-side lists (index construction is host-bound bookkeeping).
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import threading
import time
import warnings
from collections import deque
from dataclasses import dataclass
from typing import Iterable, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

import jax

from repro.configs.base import ColbertConfig
from repro.core.index import BACKENDS, MultiVectorIndex
from repro.core.pooling import (compact_pooled, compact_pooled_begin,
                                compact_pooled_finish)
from repro.core.spec import IndexSpec, PoolingSpec
from repro.models.colbert import encode_docs

# tiny jit'd reduction: the eager astype+sum pair costs ~2ms of op-by-op
# dispatch per batch on CPU, which serializes the encode stream
_emit_count = jax.jit(lambda emit: jnp.sum(emit.astype(jnp.int32)))


class EncodedDocs:
    """A corpus encoded ONCE, reusable across many pooling configs.

    Holds the per-encode-batch ``(vectors, emit_mask, n_real_docs)``
    triples exactly as ``Indexer.encode_and_pool_counted`` would have
    produced them in-line (same batch boundaries, same padding), so
    pooling+indexing from an ``EncodedDocs`` is bitwise identical to
    re-encoding the raw tokens — minus the encoder forward passes.

    This is what lets the quality sweep (``repro.eval.sweep``) build a
    pool_factor x method x backend grid with ONE encoder pass over the
    corpus: pass an ``EncodedDocs`` anywhere ``Retriever.build`` or
    ``Indexer.build`` takes a ``[N, L]`` token array. (Streaming builds
    keep raw tokens — their point is never materializing the corpus.)
    """

    def __init__(self, batches, n_docs: int, encode_batch: int):
        self.batches = batches      # [(v [B,N,d], emit [B,N], n_real)]
        self.n_docs = int(n_docs)
        self.encode_batch = int(encode_batch)

    @classmethod
    def encode(cls, params, cfg: ColbertConfig, doc_tokens: np.ndarray,
               encode_batch: int = 64) -> "EncodedDocs":
        """Run the document encoder over ``doc_tokens`` [N, L] with the
        Indexer's exact batching (chunks of ``encode_batch``, last
        chunk zero-padded to full width) and keep the device outputs."""
        doc_tokens = np.asarray(doc_tokens)
        N, B = doc_tokens.shape[0], int(encode_batch)
        batches = []
        for lo in range(0, N, B):
            chunk = doc_tokens[lo:lo + B]
            pad = B - chunk.shape[0]
            if pad:
                chunk = np.pad(chunk, ((0, pad), (0, 0)))
            v, emit = encode_docs(params, jnp.asarray(chunk), cfg)
            batches.append((v, emit, B - pad))
        return cls(batches, n_docs=N, encode_batch=B)

    def nbytes(self) -> int:
        """Device bytes held by the cached encodes (sweep budgeting)."""
        return sum(int(v.size) * v.dtype.itemsize
                   + int(emit.size) for v, emit, _ in self.batches)


@dataclass
class IndexStats:
    n_docs: int
    n_vectors_raw: int
    n_vectors_stored: int
    index_bytes: int     # real serialized artifact size (core/persist.py)
    # device-resident bytes of the query-time doc representation (plaid:
    # packed views + codec; 0 for backends predating the field)
    device_bytes: int = 0
    # streaming/sharded builds only (defaults keep monolithic stats stable)
    n_shards: int = 1
    peak_buffered_vectors: int = 0   # host-buffer high-water mark
    max_batch_vectors: int = 0       # largest single encode-batch yield
    # pipelined-flush trace (streaming only; zeros for monolithic /
    # serial builds keep older stats.json consumers stable)
    pipelined: bool = False
    flush_wait_s: float = 0.0        # encode-side stall behind shard I/O
    flush_busy_s: float = 0.0        # wall spent inside flush (any thread)

    @property
    def vector_reduction(self) -> float:
        if self.n_vectors_raw == 0:
            return 0.0
        return 1.0 - self.n_vectors_stored / self.n_vectors_raw

    def to_json(self) -> dict:
        return dict(dataclasses.asdict(self),
                    vector_reduction=self.vector_reduction)


class Indexer:
    def __init__(self, params, cfg: ColbertConfig,
                 pool_method: Optional[str] = None,
                 pool_factor: Optional[int] = None,
                 backend: Optional[str] = None,
                 encode_batch: int = 64,
                 index_spec: Optional[IndexSpec] = None,
                 pooling_spec: Optional[PoolingSpec] = None,
                 **index_kw):
        """The typed surface is ``index_spec``/``pooling_spec``
        (core/spec.py) — what ``repro.Retriever`` passes. The loose
        ``pool_method``/``pool_factor``/``backend`` names remain as
        equivalent shorthand; raw ``**index_kw`` construction knobs are
        DEPRECATED in favour of ``index_spec=IndexSpec(...)``.
        """
        self.params = params
        self.cfg = cfg
        if index_spec is not None and (backend is not None or index_kw):
            raise TypeError("pass either index_spec or loose "
                            "backend/**index_kw knobs, not both")
        if pooling_spec is not None and (pool_method is not None
                                         or pool_factor is not None):
            raise TypeError("pass either pooling_spec or loose "
                            "pool_method/pool_factor knobs, not both")
        if index_kw:
            warnings.warn(
                "Indexer(**index_kw) is deprecated; pass "
                "index_spec=repro.IndexSpec(...) (see repro.core.spec)",
                DeprecationWarning, stacklevel=2)
        if index_spec is None:
            index_spec = IndexSpec.from_config(
                cfg, backend=backend or cfg.index_backend, **index_kw)
        if index_spec.backend not in BACKENDS:
            raise ValueError(
                f"Indexer builds {BACKENDS} indexes; backend "
                f"{index_spec.backend!r} builds through repro.Retriever")
        if pooling_spec is None:
            pooling_spec = PoolingSpec(
                method=pool_method or cfg.pool_method,
                factor=max(int(pool_factor if pool_factor is not None
                               else cfg.pool_factor), 1))
        self.index_spec = index_spec
        self.pooling = pooling_spec
        # legacy attribute surface (serve/bench reporting reads these)
        self.pool_method = pooling_spec.method
        self.pool_factor = pooling_spec.factor
        self.backend = index_spec.backend
        self.encode_batch = encode_batch

    def _index_kw(self) -> dict:
        """Index construction knobs — ``IndexSpec.params()``, ONE
        definition for both build paths (monolithic and streaming must
        construct identical indexes)."""
        return self.index_spec.params()

    def encode_and_pool(self, doc_tokens) -> List[np.ndarray]:
        """doc_tokens [N, L] (or an :class:`EncodedDocs`) -> list of
        per-doc pooled vector arrays."""
        return self.encode_and_pool_counted(doc_tokens)[0]

    def _encoded_batches(self, doc_tokens):
        """Yield (vectors [B,N,d], emit [B,N], n_real_docs) per encode
        batch — from the encoder, or straight from an
        :class:`EncodedDocs` cache (same boundaries, same padding, so
        downstream pooling sees identical inputs either way)."""
        if isinstance(doc_tokens, EncodedDocs):
            yield from doc_tokens.batches
            return
        N, B = doc_tokens.shape[0], self.encode_batch
        for lo in range(0, N, B):
            chunk = doc_tokens[lo:lo + B]
            pad = B - chunk.shape[0]
            if pad:
                chunk = np.pad(chunk, ((0, pad), (0, 0)))
            v, emit = encode_docs(self.params, jnp.asarray(chunk), self.cfg)
            yield v, emit, B - pad

    def encode_and_pool_counted(
            self, doc_tokens
    ) -> Tuple[List[np.ndarray], int]:
        """(pooled per-doc arrays, raw emitted-vector count) from ONE
        encode pass — the emit mask each batch already computes is the
        unpooled count, so no second ``prepare_doc_tokens`` sweep over
        the corpus (the old ``_raw_vector_count``) is needed. An
        :class:`EncodedDocs` input skips the encoder entirely and pools
        the cached batches (bitwise-identical output).

        Runs a 1-deep software pipeline: batch i+1's encode+pool+compact
        is DISPATCHED before batch i's compacted rows are pulled to the
        host, so the host-side fetch/split overlaps the next batch's
        device compute (dispatch is async; only the fetch blocks). Raw
        counts stay device-resident scalars until the end for the same
        reason. Output order and bits are unaffected — batches are
        fetched strictly in order.
        """
        out: List[np.ndarray] = []
        raw_parts = []      # device scalars; materialized once at the end
        pending = None      # (compaction ticket | docs list, n real docs)

        def fetch(prev):
            ticket, keep = prev
            docs = (ticket if isinstance(ticket, list)
                    else compact_pooled_finish(ticket))
            out.extend(docs[:keep] if keep < len(docs) else docs)

        for v, emit, n_real in self._encoded_batches(doc_tokens):
            pooled, pmask = self.pooling.apply(v, emit)
            if n_real < emit.shape[0]:
                # padding rows still emit their CLS/[D] markers — drop
                # them from the raw count (and their docs below)
                emit = emit[:n_real]
            raw_parts.append(_emit_count(emit))
            if isinstance(pooled, jnp.ndarray):
                ticket = compact_pooled_begin(pooled, pmask)
            else:           # host-resident strategy output: no pipeline
                ticket = compact_pooled(pooled, pmask)
            if pending is not None:
                fetch(pending)
            pending = (ticket, n_real)
        if pending is None:
            return out, 0
        fetch(pending)
        return out, int(np.sum([np.asarray(r) for r in raw_parts]))

    def build(self, doc_tokens: np.ndarray,
              out_dir: Optional[str] = None):
        """Returns (MultiVectorIndex, IndexStats).

        ``out_dir`` writes the index artifact (core/persist.py) plus a
        ``stats.json`` beside its manifest, so the built index can be
        re-served by ``Searcher.from_dir`` / ``serve --index-dir``
        without re-encoding the corpus. ``index_bytes`` is always the
        *serialized* size — what the artifact occupies on disk — not
        the in-memory high-water mark.
        """
        from repro.core.persist import artifact_bytes, serialized_nbytes
        doc_vecs, raw = self.encode_and_pool_counted(doc_tokens)
        index = MultiVectorIndex(dim=self.cfg.proj_dim,
                                 backend=self.backend, **self._index_kw())
        index.add(doc_vecs)
        if out_dir is not None:
            manifest = index.save(out_dir, extra_meta={
                "pool": self.pooling.manifest_meta()})
            index_bytes = artifact_bytes(manifest)
        else:
            index_bytes = serialized_nbytes(index)
        stats = IndexStats(
            n_docs=index.n_docs,
            n_vectors_raw=raw,
            n_vectors_stored=index.n_vectors(),
            index_bytes=index_bytes,
            device_bytes=index.device_bytes(),
        )
        if out_dir is not None:
            with open(os.path.join(out_dir, "stats.json"), "w") as fh:
                json.dump(stats.to_json(), fh, indent=2)
        return index, stats

    # ------------------------------------------------------------- streaming
    def build_streaming(self, token_batches: Iterable[np.ndarray],
                        shard_max_vectors: int,
                        out_dir: Optional[str] = None,
                        probe_threads: int = 0,
                        pipeline: bool = True):
        """Bounded-memory build: token-batch stream -> capped shards.

        Args:
          token_batches: iterable of [n_b, L] doc-token arrays (a single
            [N, L] array is accepted and split into encode batches).
          shard_max_vectors: flush a shard once the pooled buffer holds
            at least this many vectors. Peak host memory is bounded by
            ``shard_max_vectors`` plus one encode batch's yield (docs
            are atomic; the flush check runs after each batch) — the
            realized bound is reported as
            ``IndexStats.peak_buffered_vectors``.
          probe_threads: stage-1 probe pool width for the built index
            (``ShardSpec.probe_threads``; 0 = auto). A pinned value is
            recorded in the root manifest and restored on load.
          out_dir: when given, every flushed shard is saved to
            ``out_dir/shard_XXXXX`` and REOPENED mmap'd — the buffer's
            bytes move to disk at flush, and the root manifest +
            aggregated ``stats.json`` are published at the end. Without
            it the shards stay host-resident (still capped per shard).
          pipeline: overlap shard construction/save/mmap-reopen with
            the device encode of the next batches on ONE background
            thread, double-buffered through a depth-1 handoff queue
            (at most one shard group queued + one in flight, so the
            transient host footprint adds <= 2 shard groups on top of
            the buffer bound). Groups are flushed strictly FIFO, so
            shard order, doc ids and artifact bytes are identical to
            ``pipeline=False`` — the bench gates that parity.
            ``peak_buffered_vectors`` accounting is unchanged.

        Returns (ShardedIndex, IndexStats) — stats aggregated across
        shards, ids global and contiguous in stream order.
        """
        from repro.core.persist import (_shard_dirname, artifact_bytes,
                                        finalize_sharded)
        from repro.core.sharded import ShardedIndex

        assert shard_max_vectors > 0, shard_max_vectors
        if isinstance(token_batches, EncodedDocs):
            raise TypeError(
                "build_streaming takes raw token batches — the point of "
                "the streaming path is never materializing the corpus; "
                "EncodedDocs caches feed monolithic builds only")
        if isinstance(token_batches, np.ndarray):
            arr, B = token_batches, self.encode_batch
            token_batches = (arr[lo:lo + B]
                             for lo in range(0, len(arr), B))
        sharded = ShardedIndex(dim=self.cfg.proj_dim, backend=self.backend,
                               shard_max_vectors=shard_max_vectors,
                               probe_threads=probe_threads,
                               **self._index_kw())

        buffer: "deque[np.ndarray]" = deque()
        buffered = 0
        raw = 0
        peak = 0
        max_batch = 0
        flush_wait_s = 0.0
        flush_busy_s = 0.0

        def flush(docs_group: List[np.ndarray]) -> None:
            nonlocal flush_busy_s
            t0 = time.perf_counter()
            shard = sharded._new_shard()
            shard.add(docs_group)
            if out_dir is not None:
                # bytes leave the host: save, drop, reopen memory-mapped
                sub = os.path.join(out_dir,
                                   _shard_dirname(sharded.n_shards - 1))
                shard.save(sub)
                sharded.shards[-1] = MultiVectorIndex.load(sub, mmap=True)
            flush_busy_s += time.perf_counter() - t0

        # -- single background flush lane (only this thread ever touches
        # sharded during the build, so shard numbering stays serial) --
        handoff: "queue.Queue" = queue.Queue(maxsize=1)
        failures: List[BaseException] = []

        def flush_worker() -> None:
            while True:
                group = handoff.get()
                if group is None:
                    return
                try:
                    if not failures:
                        flush(group)
                except BaseException as exc:  # surfaced by submit/join
                    failures.append(exc)

        worker = None
        if pipeline:
            worker = threading.Thread(target=flush_worker,
                                      name="indexer-flush", daemon=True)
            worker.start()

        def submit(docs_group: List[np.ndarray]) -> None:
            nonlocal flush_wait_s
            if failures:
                raise failures[0]
            if worker is None:
                flush(docs_group)
                return
            t0 = time.perf_counter()
            handoff.put(docs_group)   # blocks only when a flush backlog
            flush_wait_s += time.perf_counter() - t0

        try:
            for batch in token_batches:
                batch = np.asarray(batch)
                if batch.size == 0:
                    continue
                docs, raw_b = self.encode_and_pool_counted(batch)
                raw += raw_b
                got = sum(len(d) for d in docs)
                max_batch = max(max_batch, got)
                buffer.extend(docs)
                buffered += got
                peak = max(peak, buffered)
                while buffered >= shard_max_vectors:
                    # pop one shard's worth off the head; docs are
                    # atomic, so the first doc always goes in and the
                    # shard never splits one (O(docs-taken) per flush —
                    # no tail copy of the remaining buffer)
                    group: List[np.ndarray] = []
                    used = 0
                    while buffer:
                        nxt = used + len(buffer[0])
                        if group and nxt > shard_max_vectors:
                            break
                        group.append(buffer.popleft())
                        used = nxt
                    submit(group)
                    buffered -= used
            if buffer:
                submit(list(buffer))
                buffer.clear()
        finally:
            if worker is not None:
                handoff.put(None)
                worker.join()
        if failures:
            raise failures[0]

        if out_dir is not None:
            manifest = finalize_sharded(sharded, out_dir, extra_meta={
                "pool": self.pooling.manifest_meta()})
            index_bytes = artifact_bytes(manifest)
        else:
            from repro.core.persist import serialized_nbytes
            index_bytes = sum(serialized_nbytes(s) for s in sharded.shards)
        stats = IndexStats(
            n_docs=sharded.n_docs,
            n_vectors_raw=raw,
            n_vectors_stored=sharded.n_vectors(),
            index_bytes=index_bytes,
            device_bytes=sharded.device_bytes(),
            n_shards=sharded.n_shards,
            peak_buffered_vectors=peak,
            max_batch_vectors=max_batch,
            pipelined=bool(pipeline),
            flush_wait_s=flush_wait_s,
            flush_busy_s=flush_busy_s,
        )
        if out_dir is not None:
            with open(os.path.join(out_dir, "stats.json"), "w") as fh:
                json.dump(stats.to_json(), fh, indent=2)
        return sharded, stats
