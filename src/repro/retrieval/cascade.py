"""BEYOND-PAPER: pooled-cascade retrieval.

The paper stores ONE pooled representation per document (factor f) and
searches it directly. Observation: pooling quality degrades slowly while
cost drops linearly in f — so an aggressive pool (f=4..8) makes an
excellent *candidate generator*, and a mild pool (f=1..2) an excellent
*reranker*. The cascade stores both:

  stage 1: MaxSim over the COARSE vectors for every doc (4-8x cheaper
           than unpooled full scan) -> top-C candidates per query
  stage 2: exact MaxSim over the FINE vectors of the C candidates only.

Total vector budget: n/f_coarse + n/f_fine vs n for the unpooled index —
e.g. f=(6,2) stores 67% of the vectors but scans only ~17% per query at
full-corpus stage-1. Quality approaches the fine index (measured in
benchmarks/cascade_bench.py); this is the paper's own intuition applied
twice, composed with none of its machinery changed.

Both stages run on the batched two-stage engine: each pool level lives
in a device-resident ``DocStore`` and the whole query batch goes through
one all-pairs stage-1 matmul and one gathered stage-2 rerank — no
per-query Python loop.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.docstore import DocStore
from repro.core.maxsim import (maxsim_all_docs, maxsim_rerank,
                               topk_with_pads)


@dataclass
class CascadeIndex:
    dim: int
    coarse_factor: int = 6
    fine_factor: int = 2
    candidates: int = 32
    doc_maxlen: int = 256

    def __post_init__(self):
        self._coarse = DocStore(self.dim, self.doc_maxlen)
        self._fine = DocStore(self.dim, self.doc_maxlen)

    @property
    def n_docs(self) -> int:
        return self._coarse.n_docs

    # compat views over the stores
    @property
    def coarse_docs(self) -> List[np.ndarray]:
        return self._coarse.docs_list()

    @property
    def fine_docs(self) -> List[np.ndarray]:
        return self._fine.docs_list()

    def add(self, coarse: List[np.ndarray], fine: List[np.ndarray]):
        assert len(coarse) == len(fine)
        ids = self._coarse.add(coarse)
        self._fine.add(fine)
        return ids

    # ------------------------------------------------------------ persistence
    def save(self, path: str, extra_meta: dict = None) -> dict:
        """Write both pool levels as one artifact dir (core/persist.py)."""
        from repro.core import persist
        return persist.save_cascade(self, path, extra_meta=extra_meta)

    @classmethod
    def from_dir(cls, path: str, mmap: bool = True) -> "CascadeIndex":
        """Load via the shared kind dispatcher (core/persist.py), so the
        error for a non-cascade artifact names what the directory
        actually holds instead of failing on missing payloads."""
        from repro.core import persist
        obj = persist.load_artifact(path, mmap=mmap)
        if not isinstance(obj, cls):
            raise persist.IndexFormatError(
                f"{path!r} holds a {type(obj).__name__} artifact, not a "
                f"CascadeIndex — load it with persist.load_artifact / "
                f"Searcher.from_dir instead")
        return obj

    def warm_shapes(self, qs: np.ndarray, k: int = 10) -> None:
        """Pre-compile every executable a serving stream at this query
        batch shape can hit — the batched-engine conformance hook
        (``Searcher.warmup`` / ``ServingEngine`` call it per shape
        bucket). Unlike the staged backends, cascade shapes are
        data-INdependent given (Nq, k): stage 1 is one all-pairs matmul
        over the fixed coarse view and stage 2 gathers exactly
        ``min(max(candidates, k), n_docs)`` fine slates — so one
        organic ``search_batch`` traces everything and a mixed stream
        afterwards re-jits nothing (compile-count probe pinned in
        tests/test_api.py)."""
        if self.n_docs == 0:
            return
        self.search_batch(np.asarray(qs, np.float32), k=k)

    def search_batch(self, qs: np.ndarray, k: int = 10
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """qs [Nq, Lq, dim] -> (scores [Nq, k], ids [Nq, k]; -inf/-1 pads)."""
        qs = jnp.asarray(np.asarray(qs, np.float32))
        Nq = qs.shape[0]
        n = self._coarse.n_docs
        if n == 0:
            return (np.full((Nq, k), -np.inf, np.float32),
                    np.full((Nq, k), -1, np.int64))
        qm = jnp.ones(qs.shape[:2], bool)
        # stage 1: one all-pairs matmul over the coarse corpus view
        cd, cm = self._coarse.padded()
        s1 = maxsim_all_docs(qs, qm, cd, cm)               # [Nq, n]
        C = min(max(self.candidates, k), n)
        _, cand = jax.lax.top_k(s1, C)                     # [Nq, C]
        cand = np.asarray(cand, np.int64)
        # stage 2: gathered exact rerank over the fine vectors
        fd, fm = self._fine.gather(cand)
        s2 = maxsim_rerank(qs, qm, fd, fm)                 # [Nq, C]
        return topk_with_pads(s2, cand, k)

    def search(self, q: np.ndarray, k: int = 10
               ) -> Tuple[np.ndarray, np.ndarray]:
        """q [Lq, dim] -> (scores [<=k], ids [<=k])."""
        S, I = self.search_batch(np.asarray(q, np.float32)[None], k=k)
        valid = I[0] >= 0
        return S[0][valid], I[0][valid]

    def n_vectors(self) -> int:
        return (self._coarse.n_vectors(live_only=False)
                + self._fine.n_vectors(live_only=False))

    def stage1_vectors(self) -> int:
        """Vectors touched by a full stage-1 scan (the per-query cost)."""
        return self._coarse.n_vectors(live_only=False)


def build_cascade(indexer_params, cfg, doc_tokens: np.ndarray,
                  coarse_factor: int = 6, fine_factor: int = 2,
                  candidates: int = 32,
                  pool_method: str = "ward") -> CascadeIndex:
    """Encode once, pool twice (coarse + fine), build the cascade.
    ``pool_method`` resolves through the spec layer's strategy registry
    (core/spec.py), so registered policies work at both levels."""
    from repro.retrieval.indexer import Indexer
    coarse = Indexer(indexer_params, cfg, pool_method=pool_method,
                     pool_factor=coarse_factor,
                     backend="flat").encode_and_pool(doc_tokens)
    fine = Indexer(indexer_params, cfg, pool_method=pool_method,
                   pool_factor=fine_factor,
                   backend="flat").encode_and_pool(doc_tokens)
    idx = CascadeIndex(dim=cfg.proj_dim, coarse_factor=coarse_factor,
                       fine_factor=fine_factor, candidates=candidates,
                       doc_maxlen=cfg.doc_maxlen)
    idx.add(coarse, fine)
    return idx
