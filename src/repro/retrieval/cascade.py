"""BEYOND-PAPER: pooled-cascade retrieval.

The paper stores ONE pooled representation per document (factor f) and
searches it directly. Observation: pooling quality degrades slowly while
cost drops linearly in f — so an aggressive pool (f=4..8) makes an
excellent *candidate generator*, and a mild pool (f=1..2) an excellent
*reranker*. The cascade stores both:

  stage 1: MaxSim over the COARSE vectors for every doc (4-8x cheaper
           than unpooled full scan) -> top-C candidates
  stage 2: exact MaxSim over the FINE vectors of the C candidates only.

Total vector budget: n/f_coarse + n/f_fine vs n for the unpooled index —
e.g. f=(6,2) stores 67% of the vectors but scans only ~17% per query at
full-corpus stage-1. Quality approaches the fine index (measured in
benchmarks/cascade_bench.py); this is the paper's own intuition applied
twice, composed with none of its machinery changed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.index import _pad_docs
from repro.core.maxsim import maxsim_scores


@dataclass
class CascadeIndex:
    dim: int
    coarse_factor: int = 6
    fine_factor: int = 2
    candidates: int = 32
    doc_maxlen: int = 256

    def __post_init__(self):
        self.coarse_docs: List[np.ndarray] = []
        self.fine_docs: List[np.ndarray] = []
        self._coarse = None    # padded [N, Lc, dim]
        self._fine = None

    def add(self, coarse: List[np.ndarray], fine: List[np.ndarray]):
        assert len(coarse) == len(fine)
        self.coarse_docs.extend(coarse)
        self.fine_docs.extend(fine)
        self._coarse = self._fine = None
        return np.arange(len(self.coarse_docs) - len(coarse),
                         len(self.coarse_docs))

    def _ensure_padded(self):
        if self._coarse is None:
            lc = max(max((len(d) for d in self.coarse_docs), default=1), 1)
            lf = max(max((len(d) for d in self.fine_docs), default=1), 1)
            self._coarse = _pad_docs(self.coarse_docs, lc, self.dim)
            self._fine = _pad_docs(self.fine_docs, lf, self.dim)

    def search(self, q: np.ndarray, k: int = 10
               ) -> Tuple[np.ndarray, np.ndarray]:
        """q [Lq, dim] -> (scores [k], ids [k])."""
        self._ensure_padded()
        cd, cm = self._coarse
        qm = np.ones((1, len(q)), bool)
        s1 = np.asarray(maxsim_scores(jnp.asarray(q[None], jnp.float32),
                                      jnp.asarray(qm), jnp.asarray(cd),
                                      jnp.asarray(cm)))[0]
        cand = np.argsort(-s1)[:max(self.candidates, k)]
        fd, fm = self._fine
        s2 = np.asarray(maxsim_scores(jnp.asarray(q[None], jnp.float32),
                                      jnp.asarray(qm),
                                      jnp.asarray(fd[cand]),
                                      jnp.asarray(fm[cand])))[0]
        order = np.argsort(-s2)[:k]
        return s2[order], cand[order].astype(np.int64)

    def search_batch(self, qs: np.ndarray, k: int = 10):
        S = np.zeros((len(qs), k), np.float32)
        I = np.zeros((len(qs), k), np.int64)
        for n, q in enumerate(np.asarray(qs)):
            s, i = self.search(q, k)
            S[n, :len(s)], I[n, :len(i)] = s, i
        return S, I

    def n_vectors(self) -> int:
        return int(sum(len(d) for d in self.coarse_docs)
                   + sum(len(d) for d in self.fine_docs))

    def stage1_vectors(self) -> int:
        """Vectors touched by a full stage-1 scan (the per-query cost)."""
        return int(sum(len(d) for d in self.coarse_docs))


def build_cascade(indexer_params, cfg, doc_tokens: np.ndarray,
                  coarse_factor: int = 6, fine_factor: int = 2,
                  candidates: int = 32) -> CascadeIndex:
    """Encode once, pool twice (coarse + fine), build the cascade."""
    from repro.retrieval.indexer import Indexer
    coarse = Indexer(indexer_params, cfg, pool_method="ward",
                     pool_factor=coarse_factor,
                     backend="flat").encode_and_pool(doc_tokens)
    fine = Indexer(indexer_params, cfg, pool_method="ward",
                   pool_factor=fine_factor,
                   backend="flat").encode_and_pool(doc_tokens)
    idx = CascadeIndex(dim=cfg.proj_dim, coarse_factor=coarse_factor,
                       fine_factor=fine_factor, candidates=candidates,
                       doc_maxlen=cfg.doc_maxlen)
    idx.add(coarse, fine)
    return idx
