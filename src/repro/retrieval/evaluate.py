"""DEPRECATED shim over :mod:`repro.eval`.

The evaluation harness moved to the ``repro.eval`` subsystem:
:class:`repro.eval.QualitySweep` encodes the corpus once, shares the
unpooled baseline across cells, and drives only the public
``repro.Retriever`` facade. This module keeps the original
``evaluate_pooling`` / ``EvalReport`` surface alive for existing
callers by delegating to the sweep; new code should use
``repro.eval`` directly.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.configs.base import ColbertConfig
from repro.core.spec import IndexSpec
from repro.data.corpus import SyntheticRetrievalCorpus
from repro.eval.sweep import relative_performance  # noqa: F401  (re-export)


@dataclass
class PoolingCell:
    method: str
    factor: int
    metric: float
    relative: float               # 100 = baseline
    n_vectors: int
    vector_reduction: float       # fraction of vectors removed
    index_bytes: int


@dataclass
class EvalReport:
    dataset: str
    backend: str
    metric_name: str
    baseline_metric: float
    baseline_vectors: int
    baseline_bytes: int
    cells: List[PoolingCell] = field(default_factory=list)

    def cell(self, method: str, factor: int) -> Optional[PoolingCell]:
        for c in self.cells:
            if c.method == method and c.factor == factor:
                return c
        return None

    def table(self) -> str:
        rows = [f"{'method':12s} {'f':>2s} {'rel':>7s} {'metric':>7s} "
                f"{'vecs':>8s} {'reduct':>7s} {'bytes':>10s}"]
        rows.append(f"{'baseline':12s} {1:2d} {100.0:7.2f} "
                    f"{self.baseline_metric:7.4f} {self.baseline_vectors:8d}"
                    f" {0.0:7.1%} {self.baseline_bytes:10d}")
        for c in self.cells:
            rows.append(f"{c.method:12s} {c.factor:2d} {c.relative:7.2f} "
                        f"{c.metric:7.4f} {c.n_vectors:8d} "
                        f"{c.vector_reduction:7.1%} {c.index_bytes:10d}")
        return "\n".join(rows)


def evaluate_pooling(params, cfg: ColbertConfig,
                     corpus: SyntheticRetrievalCorpus,
                     methods: Sequence[str] = ("ward", "kmeans",
                                               "sequential"),
                     factors: Sequence[int] = (2, 3, 4, 6),
                     backend: str = "plaid",
                     metric_name: str = "ndcg@10",
                     k: int = 10, query_maxlen: Optional[int] = None,
                     **index_kw) -> EvalReport:
    """Full paper-protocol evaluation on one dataset.

    .. deprecated:: use :class:`repro.eval.QualitySweep` — same
       protocol, but the corpus is encoded once and the baseline built
       once instead of per cell.
    """
    warnings.warn(
        "repro.retrieval.evaluate.evaluate_pooling is deprecated; use "
        "repro.eval.QualitySweep (encodes the corpus once and shares "
        "the unpooled baseline across cells)",
        DeprecationWarning, stacklevel=2)
    from repro.eval.datasets import from_corpus
    from repro.eval.sweep import QualitySweep

    dataset = from_corpus(corpus, doc_maxlen=cfg.doc_maxlen - 2,
                          query_maxlen=query_maxlen
                          or (cfg.query_maxlen - 2))
    # fold loose **index_kw into a typed spec once, to resolve the
    # backend's quantization default for the sweep's grid key
    spec = IndexSpec.from_config(cfg, backend=backend, **index_kw)
    sweep = QualitySweep(params, cfg, dataset,
                         methods=methods, factors=factors,
                         backends=(backend,),
                         quant_bits=(spec.quant_bits,),
                         metrics=(metric_name,), k=k,
                         index_overrides=index_kw)
    qreport = sweep.run()
    qb = spec.quant_bits if backend in _quantized_backends() else None
    base = qreport.baseline(backend, qb)
    report = EvalReport(dataset=corpus.spec.name, backend=backend,
                        metric_name=metric_name,
                        baseline_metric=base.metrics[metric_name],
                        baseline_vectors=base.n_vectors,
                        baseline_bytes=base.index_bytes)
    for method in methods:
        for factor in factors:
            c = qreport.cell(backend, method, int(factor), qb)
            if c is None:
                continue
            report.cells.append(PoolingCell(
                method=method, factor=int(factor),
                metric=c.metrics[metric_name],
                relative=c.relative[metric_name],
                n_vectors=c.n_vectors,
                vector_reduction=c.vector_reduction,
                index_bytes=c.index_bytes))
    return report


def _quantized_backends():
    from repro.eval.sweep import QUANTIZED_BACKENDS
    return QUANTIZED_BACKENDS
