"""The paper's evaluation harness: relative performance vs unpooled.

``evaluate_pooling`` builds one index per (method, factor) cell plus the
factor-1 baseline, runs the same queries through all of them, and reports
each cell's metric as ``100 * metric / baseline_metric`` — the number every
table in the paper is made of.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ColbertConfig
from repro.core.spec import IndexSpec, PoolingSpec
from repro.data.corpus import SyntheticRetrievalCorpus
from repro.retrieval.indexer import Indexer
from repro.retrieval.metrics import METRICS
from repro.retrieval.searcher import Searcher


@dataclass
class PoolingCell:
    method: str
    factor: int
    metric: float
    relative: float               # 100 = baseline
    n_vectors: int
    vector_reduction: float       # fraction of vectors removed
    index_bytes: int


@dataclass
class EvalReport:
    dataset: str
    backend: str
    metric_name: str
    baseline_metric: float
    baseline_vectors: int
    baseline_bytes: int
    cells: List[PoolingCell] = field(default_factory=list)

    def cell(self, method: str, factor: int) -> Optional[PoolingCell]:
        for c in self.cells:
            if c.method == method and c.factor == factor:
                return c
        return None

    def table(self) -> str:
        rows = [f"{'method':12s} {'f':>2s} {'rel':>7s} {'metric':>7s} "
                f"{'vecs':>8s} {'reduct':>7s} {'bytes':>10s}"]
        rows.append(f"{'baseline':12s} {1:2d} {100.0:7.2f} "
                    f"{self.baseline_metric:7.4f} {self.baseline_vectors:8d}"
                    f" {0.0:7.1%} {self.baseline_bytes:10d}")
        for c in self.cells:
            rows.append(f"{c.method:12s} {c.factor:2d} {c.relative:7.2f} "
                        f"{c.metric:7.4f} {c.n_vectors:8d} "
                        f"{c.vector_reduction:7.1%} {c.index_bytes:10d}")
        return "\n".join(rows)


def relative_performance(metric: float, baseline: float) -> float:
    return 100.0 * metric / baseline if baseline > 0 else 0.0


def evaluate_pooling(params, cfg: ColbertConfig,
                     corpus: SyntheticRetrievalCorpus,
                     methods: Sequence[str] = ("ward", "kmeans",
                                               "sequential"),
                     factors: Sequence[int] = (2, 3, 4, 6),
                     backend: str = "plaid",
                     metric_name: str = "ndcg@10",
                     k: int = 10, query_maxlen: Optional[int] = None,
                     **index_kw) -> EvalReport:
    """Full paper-protocol evaluation on one dataset."""
    metric_fn = METRICS[metric_name]
    doc_tokens = corpus.doc_token_batch(cfg.doc_maxlen - 2)
    q_tokens = corpus.query_token_batch(query_maxlen
                                        or (cfg.query_maxlen - 2))
    # loose **index_kw stays accepted here (harness convenience) but is
    # folded into a typed IndexSpec before it reaches the Indexer
    spec = IndexSpec.from_config(cfg, backend=backend, **index_kw)

    def run(method: str, factor: int):
        idx, stats = Indexer(
            params, cfg, index_spec=spec,
            pooling_spec=PoolingSpec(method=method,
                                     factor=max(int(factor), 1)),
        ).build(doc_tokens)
        searcher = Searcher(params, cfg, idx)
        ranked = searcher.rankings(q_tokens, k=max(k, 10))
        return metric_fn(ranked, corpus.qrels), stats

    base_metric, base_stats = run("none", 1)
    report = EvalReport(dataset=corpus.spec.name, backend=backend,
                        metric_name=metric_name,
                        baseline_metric=base_metric,
                        baseline_vectors=base_stats.n_vectors_stored,
                        baseline_bytes=base_stats.index_bytes)
    for method in methods:
        for factor in factors:
            m, stats = run(method, factor)
            report.cells.append(PoolingCell(
                method=method, factor=factor, metric=m,
                relative=relative_performance(m, base_metric),
                n_vectors=stats.n_vectors_stored,
                vector_reduction=stats.vector_reduction,
                index_bytes=stats.index_bytes))
    return report
