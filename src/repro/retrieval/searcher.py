"""Searcher: query encode -> staged candidate generation -> rerank.

Query-time is UNCHANGED by token pooling (the paper's key deployment
property): the searcher is identical for pooled and unpooled indexes.

The query path is two STATELESS stages the serving runtime
(launch/engine.py) pipelines independently:

  * ``encode_queries``  [Nq, L] token ids -> [Nq, Lq, dim] vectors —
    chunks pad up to the nearest power-of-two width (capped at
    ``encode_batch``), so a mixed stream of request sizes reuses
    log-many executables and a 2-query microbatch never pays a
    64-wide encoder pass. Each output row depends only on its input
    row AND is bitwise independent of the padded width (pinned by
    tests), so a query's vectors are identical however it was
    coalesced;
  * ``search_encoded``  encoded vectors -> (scores, ids) through the
    index's batched two-stage engine.

``search``/``search_batch`` chain the two for the whole batch in one
call (one traced rerank per microbatch, no per-query loop). ``warmup``
triggers jit compilation for a batch size — or a whole LIST of shape
buckets — so serving latency percentiles exclude compile time and a
bucketed batcher never re-traces mid-stream.
"""
from __future__ import annotations

from typing import Iterable, List, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ColbertConfig
from repro.core.index import MultiVectorIndex
from repro.models.colbert import encode_queries


class Searcher:
    def __init__(self, params, cfg: ColbertConfig,
                 index, encode_batch: int = 64):
        # index: anything with the batched two-stage search interface —
        # MultiVectorIndex, ShardedIndex, or CascadeIndex
        self.params = params
        self.cfg = cfg
        self.index = index
        self.encode_batch = encode_batch

    @classmethod
    def from_dir(cls, params, cfg: ColbertConfig, path: str,
                 mmap: bool = True, encode_batch: int = 64) -> "Searcher":
        """Serve a persisted index artifact: no corpus encode, no index
        build — the document payloads stay on disk until first search.

        Dispatches on the artifact's manifest ``kind``, so monolithic
        and sharded (and cascade) index directories serve through the
        same API."""
        from repro.core.persist import load_artifact
        return cls(params, cfg, load_artifact(path, mmap=mmap),
                   encode_batch=encode_batch)

    def _encode_width(self, n: int) -> int:
        """Smallest power-of-two device width holding ``n`` queries,
        capped at ``encode_batch`` — the encoder's shape buckets."""
        w = 1
        while w < n and w < self.encode_batch:
            w <<= 1
        return min(w, self.encode_batch)

    def encode_queries(self, query_tokens: np.ndarray) -> np.ndarray:
        """[Nq, L] -> [Nq, Lq, dim] (all expansion slots emit).

        Stateless stage 1 of the serving pipeline: chunks of up to
        ``encode_batch`` queries pad to the nearest power-of-two width,
        so log-many traced shapes serve any request size, and a row's
        output never depends on what it was batched with (nor on the
        padded width — encoder rows are bitwise width-stable)."""
        out = []
        N = query_tokens.shape[0]
        B = self.encode_batch
        for lo in range(0, N, B):
            chunk = query_tokens[lo:lo + B]
            n = chunk.shape[0]
            pad = self._encode_width(n) - n
            if pad:
                chunk = np.pad(chunk, ((0, pad), (0, 0)))
            v, _ = encode_queries(self.params, jnp.asarray(chunk), self.cfg)
            v = np.asarray(v)
            out.append(v[:n] if pad else v)
        return np.concatenate(out)

    def encode(self, query_tokens: np.ndarray) -> np.ndarray:
        """DEPRECATED alias predating the stage split — use
        :meth:`encode_queries` (the name the spec-era public API,
        ``repro.Retriever``, and the serving engine pipeline use)."""
        import warnings
        warnings.warn("Searcher.encode is deprecated; use "
                      "Searcher.encode_queries", DeprecationWarning,
                      stacklevel=2)
        return self.encode_queries(query_tokens)

    def search(self, query_tokens: np.ndarray, k: int = 10
               ) -> Tuple[np.ndarray, np.ndarray]:
        """[Nq, L] raw token ids -> (scores [Nq, k], doc ids [Nq, k])."""
        return self.search_encoded(self.encode_queries(query_tokens), k=k)

    def search_encoded(self, query_vectors: np.ndarray, k: int = 10
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """Pre-encoded [Nq, Lq, dim] -> (scores [Nq, k], ids [Nq, k])."""
        return self.index.search_batch(query_vectors, k=k)

    # alias: a Searcher search is always batched
    search_batch = search

    def rankings(self, query_tokens: np.ndarray, k: int = 10
                 ) -> List[List[int]]:
        _, ids = self.search(query_tokens, k)
        return [[int(d) for d in row if d >= 0] for row in ids]

    def warmup(self, batch_sizes: Union[int, Iterable[int]],
               k: int = 10) -> None:
        """Trace/compile the serving pipeline for one or many shapes.

        Pass a single batch size (legacy) or the batcher's full list of
        shape buckets: BOTH stages compile per bucket — the encoder at
        each power-of-two width, ``search_encoded`` at every requested
        batch size — so a mixed stream of microbatch shapes served
        afterwards hits only warm executables (the no-retrace property
        tests/test_serving_engine.py pins with a compile-count probe).
        """
        if isinstance(batch_sizes, (int, np.integer)):
            batch_sizes = [int(batch_sizes)]
        sizes = sorted({int(b) for b in batch_sizes})
        if not sizes:
            return
        L = self.cfg.query_maxlen - 2
        warm = getattr(self.index, "warm_shapes", None)
        for bs in sizes:
            enc = self.encode_queries(np.ones((bs, L), np.int32))
            if warm is not None:
                # also traces the data-dependent candidate-width ladder
                # (a width first seen mid-stream would compile in-band)
                warm(enc, k=k)
            else:
                self.search_encoded(enc, k=k)
