"""Searcher: query encode -> staged candidate generation -> rerank.

Query-time is UNCHANGED by token pooling (the paper's key deployment
property): the searcher is identical for pooled and unpooled indexes.

``search``/``search_batch`` are true batch APIs: the whole query batch
is encoded in device batches and handed to the index's two-stage engine
in one call (one traced rerank per microbatch, no per-query loop).
``warmup`` triggers jit compilation at a given batch size so serving
latency percentiles exclude compile time.
"""
from __future__ import annotations

from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ColbertConfig
from repro.core.index import MultiVectorIndex
from repro.models.colbert import encode_queries


class Searcher:
    def __init__(self, params, cfg: ColbertConfig,
                 index, encode_batch: int = 64):
        # index: anything with the batched two-stage search interface —
        # MultiVectorIndex, ShardedIndex, or CascadeIndex
        self.params = params
        self.cfg = cfg
        self.index = index
        self.encode_batch = encode_batch

    @classmethod
    def from_dir(cls, params, cfg: ColbertConfig, path: str,
                 mmap: bool = True, encode_batch: int = 64) -> "Searcher":
        """Serve a persisted index artifact: no corpus encode, no index
        build — the document payloads stay on disk until first search.

        Dispatches on the artifact's manifest ``kind``, so monolithic
        and sharded (and cascade) index directories serve through the
        same API."""
        from repro.core.persist import load_artifact
        return cls(params, cfg, load_artifact(path, mmap=mmap),
                   encode_batch=encode_batch)

    def encode(self, query_tokens: np.ndarray) -> np.ndarray:
        """[Nq, L] -> [Nq, Lq, dim] (all expansion slots emit)."""
        out = []
        N = query_tokens.shape[0]
        B = self.encode_batch
        for lo in range(0, N, B):
            chunk = query_tokens[lo:lo + B]
            pad = B - chunk.shape[0]
            if pad:
                chunk = np.pad(chunk, ((0, pad), (0, 0)))
            v, _ = encode_queries(self.params, jnp.asarray(chunk), self.cfg)
            v = np.asarray(v)
            out.append(v[:B - pad] if pad else v)
        return np.concatenate(out)

    def search(self, query_tokens: np.ndarray, k: int = 10
               ) -> Tuple[np.ndarray, np.ndarray]:
        """[Nq, L] raw token ids -> (scores [Nq, k], doc ids [Nq, k])."""
        return self.search_encoded(self.encode(query_tokens), k=k)

    def search_encoded(self, query_vectors: np.ndarray, k: int = 10
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """Pre-encoded [Nq, Lq, dim] -> (scores [Nq, k], ids [Nq, k])."""
        return self.index.search_batch(query_vectors, k=k)

    # alias: a Searcher search is always batched
    search_batch = search

    def rankings(self, query_tokens: np.ndarray, k: int = 10
                 ) -> List[List[int]]:
        _, ids = self.search(query_tokens, k)
        return [[int(d) for d in row if d >= 0] for row in ids]

    def warmup(self, batch_size: int, k: int = 10) -> None:
        """Trace/compile the encode + two-stage pipeline for one shape."""
        L = self.cfg.query_maxlen - 2
        toks = np.ones((batch_size, L), np.int32)
        self.search(toks, k=k)
