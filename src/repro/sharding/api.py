"""Logical-axis sharding constraints.

Model code annotates activations with *logical* axis names
(``constrain(x, "batch", "seq", "heads", None)``); a ``MeshContext`` maps
logical names to physical mesh axes. With no active context every
annotation is a no-op, so the same model code runs single-device (tests,
smoke) and multi-pod (dry-run, production) unchanged.

Rules are per-strategy dictionaries: e.g. the LM "heads-TP" strategy maps
``heads -> model``, the sequence-parallel fallback maps ``qseq -> model``
instead (for archs whose head count does not divide the TP axis).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

_STATE = threading.local()


@dataclass
class MeshContext:
    mesh: Mesh
    rules: Dict[str, Axis] = field(default_factory=dict)

    def resolve(self, name: Optional[str]) -> Axis:
        if name is None:
            return None
        return self.rules.get(name, None)


def current_ctx() -> Optional[MeshContext]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def mesh_context(mesh: Mesh, rules: Dict[str, Axis]):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = MeshContext(mesh, dict(rules))
    try:
        yield _STATE.ctx
    finally:
        _STATE.ctx = prev


def logical_spec(*names: Optional[str]) -> P:
    ctx = current_ctx()
    if ctx is None:
        return P()
    return P(*[ctx.resolve(n) for n in names])


def constrain(x, *names: Optional[str]):
    """Apply with_sharding_constraint if a mesh context is active."""
    ctx = current_ctx()
    if ctx is None:
        return x
    assert len(names) == x.ndim, (names, x.shape)
    spec = P(*[ctx.resolve(n) for n in names])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Standard rule sets
# ---------------------------------------------------------------------------
def lm_rules(batch_axes: Axis = "data", model_axis: str = "model",
             attn_shard: str = "heads") -> Dict[str, Axis]:
    """Megatron-style TP + DP rules for LM transformers.

    ``attn_shard="sequence"`` is the fallback for head counts that do not
    divide the TP degree (e.g. qwen2.5-14b H=40 on tp=16): the query
    sequence axis is model-sharded instead and KV is replicated across TP.
    """
    rules: Dict[str, Axis] = {
        "batch": batch_axes,
        "seq": None,
        "dmodel": None,
        "ff": model_axis,
        "vocab": model_axis,
        "experts": model_axis,
        "kv": None,            # kv heads replicated across TP (kv < tp)
        "dh": None,
        "kvseq": None,
        "qseq": None,
        "heads": model_axis,
        # prefill cache emission: the cache's seq axis CAN shard over TP
        # (unlike attention's in-flight kv, which is head-sharded)
        "cacheseq": model_axis,
    }
    if attn_shard == "sequence":
        rules["heads"] = None
        rules["qseq"] = model_axis
    return rules


def lm_decode_rules(batch_axes: Axis = "data",
                    model_axis: str = "model") -> Dict[str, Axis]:
    """Decode: flash-decoding style — KV cache sequence-sharded over TP,
    queries (1 token) replicated; exact softmax combine via all-reduce."""
    return {
        "batch": batch_axes,
        "seq": None,
        "dmodel": None,
        "ff": model_axis,
        "vocab": model_axis,
        "experts": model_axis,
        "heads": None,
        "kv": None,
        "dh": None,
        "kvseq": model_axis,
        "qseq": None,
    }


def lm_long_decode_rules(batch_axes: Axis = "data",
                         model_axis: str = "model") -> Dict[str, Axis]:
    """long_500k (batch=1): the KV cache sequence axis is the ONLY big axis
    — shard it over every mesh axis (data+model combined)."""
    axes = ((batch_axes,) if isinstance(batch_axes, str)
            else tuple(batch_axes)) + (model_axis,)
    r = lm_decode_rules(batch_axes, model_axis)
    r["kvseq"] = axes
    r["batch"] = None
    return r


def gnn_rules(batch_axes: Axis = "data", model_axis: str = "model") -> Dict[str, Axis]:
    """Node tables shard on data; edge/triplet tables (the big ones) shard
    over data+model combined — DimeNet's triplet tensors dwarf everything."""
    axes = ((batch_axes,) if isinstance(batch_axes, str)
            else tuple(batch_axes)) + (model_axis,)
    return {
        "nodes": batch_axes,
        "edges": axes,
        "triplets": axes,
        "batch": batch_axes,
        "feat": None,
        "hidden": None,
    }


def recsys_rules(batch_axes: Axis = "data", model_axis: str = "model") -> Dict[str, Axis]:
    return {
        "batch": batch_axes,
        "vocab_rows": model_axis,   # embedding tables row-sharded over TP
        "embed": None,
        "feat": None,
        "candidates": batch_axes,   # retrieval_cand: 1M candidates data-sharded
    }


def serve_rules(shard_axis: str = "shard",
                replica_axis: str = "replica") -> Dict[str, Axis]:
    """Scale-out serving (launch/mesh.make_serve_mesh): the doc axis
    partitions over the shard axis inside a replica group; queries are
    replicated (every shard scores the whole microbatch, the top-k
    merge is the only collective). The batch axis maps to the replica
    axis only for router-level accounting — the engine routes whole
    microbatches to replica groups rather than splitting rows."""
    return {
        "docs": shard_axis,
        "queries": None,
        "tokens": None,
        "dim": None,
        "centroids": None,
        "batch": replica_axis,
    }


def retrieval_rules(batch_axes: Axis = "data", model_axis: str = "model") -> Dict[str, Axis]:
    axes = ((batch_axes,) if isinstance(batch_axes, str)
            else tuple(batch_axes)) + (model_axis,)
    return {
        "docs": axes,               # doc shards over EVERY axis (§Perf cell 3)
        "queries": None,            # queries replicated
        "tokens": None,
        "dim": None,
        "batch": batch_axes,
        "seq": None,
        "heads": model_axis,
        "ff": model_axis,
        "vocab": model_axis,
        "dmodel": None,
        "kv": None,
        "dh": None,
        "experts": model_axis,
        "qseq": None,
        "kvseq": None,
        "centroids": None,
    }
