from repro.sharding.api import (  # noqa: F401
    MeshContext,
    constrain,
    current_ctx,
    logical_spec,
    mesh_context,
)
