"""Parameter-tree PartitionSpecs per architecture family.

Megatron-style tensor parallelism + ZeRO/FSDP weight sharding:

  * column-parallel weights (wq/wk/wv, mlp w1/w3, lm_head): output dim on
    ``model``, input dim on the FSDP axis (``data``; + ``pod`` multi-pod).
  * row-parallel weights (wo, mlp w2): input dim on ``model``.
  * MoE experts [E, d, f]: E on ``model`` (EP == TP axis; 384/64 experts
    divide 16), d on FSDP.
  * embeddings/lm_head: vocab dim on ``model``.
  * norms/biases: replicated (tiny).
  * recsys tables [F, V, D]: V row-sharded on ``model``.
  * optimizer slots inherit the param's spec (adamw m/v) or the reduced
    spec with the averaged dim dropped (adafactor vr/vc) — ZeRO-sharded
    optimizer state by construction.

Specs are produced by matching path suffixes and padding leading ``None``s
to the leaf rank (stacked-layer leading dims stay unsharded — layers are
scanned, not sharded).
"""
from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import tree_paths

Axis = Optional[object]


def _pad(spec_tail: Tuple, rank: int) -> P:
    pad = rank - len(spec_tail)
    assert pad >= 0, (spec_tail, rank)
    return P(*([None] * pad + list(spec_tail)))


def lm_param_rules(fsdp: Axis, model: str = "model"):
    """Ordered (regex on path suffix, trailing-dims spec) rules."""
    return [
        (r"attn/wq/w$", (fsdp, model)),
        (r"attn/wk/w$", (fsdp, model)),
        (r"attn/wv/w$", (fsdp, model)),
        (r"attn/wo/w$", (model, fsdp)),
        (r"attn/w[qkv]/b$", (model,)),
        (r"attn/wo/b$", (None,)),
        (r"(q|k)_norm/scale$", (None,)),
        (r"mlp/w[13]/w$", (fsdp, model)),
        (r"mlp/w2/w$", (model, fsdp)),
        (r"mlp/w[13]/b$", (model,)),
        (r"mlp/w2/b$", (None,)),
        (r"moe/router/w$", (None, None)),
        (r"moe/w[13]$", (model, fsdp, None)),
        (r"moe/w2$", (model, None, fsdp)),
        (r"moe/shared_w[13]/w$", (fsdp, model)),
        (r"moe/shared_w2/w$", (model, fsdp)),
        (r"embed/table$", (model, fsdp)),
        (r"pos_embed/table$", (None, None)),
        (r"lm_head/w$", (fsdp, model)),
        (r"lm_head/b$", (model,)),
        (r"norm/scale$", (None,)),
        (r"norm/bias$", (None,)),
        (r"proj/w$", (None, None)),      # ColBERT head: tiny, replicated
        (r"proj/b$", (None,)),
    ]


def gnn_param_rules(fsdp: Axis, model: str = "model"):
    # DimeNet params are ~1M: replicate everything.
    return [(r".*", ())]


def recsys_param_rules(fsdp: Axis, model: str = "model"):
    return [
        (r"tables$", (None, model, None)),   # [F, V(model), D]
        (r"wide$", (None, model, None)),
        (r".*", ()),                         # MLPs tiny: replicated
    ]


def spec_for_path(path: str, rank: int, rules) -> P:
    for pat, tail in rules:
        if re.search(pat, path):
            return _pad(tuple(tail), rank)
    return P()                               # replicated fallback


def param_specs(params, rules) -> Dict[str, P]:
    """Tree of PartitionSpecs shaped like ``params`` (dict paths)."""
    flat = {p: spec_for_path(p, getattr(a, "ndim", len(a.shape)), rules)
            for p, a in tree_paths(params)}
    return _unflatten_like(params, flat)


def _unflatten_like(tree, flat: Dict[str, P]):
    def walk(node, prefix):
        if isinstance(node, dict):
            return {k: walk(v, f"{prefix}{k}/") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            out = [walk(v, f"{prefix}{i}/") for i, v in enumerate(node)]
            return type(node)(out)
        return flat[prefix[:-1]]
    return walk(tree, "")


def opt_state_specs(opt_state_shape, p_specs, optimizer: str):
    """Specs for the optimizer state pytree given the params' specs.

    adamw: m/v mirror params. adafactor: vr drops the last dim's axis,
    vc drops the second-to-last. scalars replicated.
    """
    if optimizer == "adamw":
        return {"step": P(), "m": p_specs, "v": p_specs}

    def reduce_spec(spec: P, drop_last: bool) -> P:
        lst = list(spec)
        if not lst:
            return P()
        if drop_last:
            return P(*lst[:-1])
        return P(*(lst[:-2] + lst[-1:]))

    def walk(shape_node, spec_node):
        if isinstance(shape_node, dict) and ("vr" in shape_node
                                             or "v" in shape_node):
            if "vr" in shape_node:
                return {"vr": reduce_spec(spec_node, True),
                        "vc": reduce_spec(spec_node, False)}
            return {"v": spec_node}
        if isinstance(shape_node, dict):
            return {k: walk(v, spec_node[k]) for k, v in shape_node.items()}
        if isinstance(shape_node, (list, tuple)):
            return type(shape_node)(
                walk(v, spec_node[i]) for i, v in enumerate(shape_node))
        return spec_node
    return {"step": P(), "slots": walk(opt_state_shape["slots"], p_specs)}


def to_shardings(mesh: Mesh, specs):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
