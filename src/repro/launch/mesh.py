"""Production mesh builders.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
pure data parallelism across pods (gradients all-reduce over
("pod", "data") — DCN-friendly: only one collective crosses pods).

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from typing import List

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a 1D 'data' mesh (tests/smoke)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_serve_mesh(n_replicas: int, n_shards: int):
    """The scale-out serving mesh: axes ("replica", "shard").

    Replica groups are pure throughput parallelism (each group serves
    whole microbatches); the shard axis partitions the corpus inside a
    group (core/replicated.py places index shards along it and merges
    top-k with a collective). Requires ``n_replicas * n_shards``
    devices; use :func:`serve_device_table` when the host has fewer —
    placement degrades to round-robin reuse, losing parallelism but
    never parity.
    """
    assert n_replicas >= 1 and n_shards >= 1, (n_replicas, n_shards)
    need = n_replicas * n_shards
    devs = jax.devices()
    if len(devs) < need:
        raise ValueError(f"serve mesh ({n_replicas} replicas x "
                         f"{n_shards} shards) needs {need} devices, "
                         f"host has {len(devs)}")
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs[:need]).reshape(n_replicas, n_shards),
                ("replica", "shard"))


def make_shard_mesh(devices):
    """A 1-D ("shard",) mesh over one replica group's device row — the
    mesh ``core/replicated.py`` shard_maps a group's dense scan over."""
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.asarray(list(devices)), ("shard",))


def serve_device_table(n_replicas: int, n_shards: int
                       ) -> List[List[object]]:
    """Device placement for (replica, shard) cells, tiling the local
    devices round-robin when there are fewer than ``n_replicas *
    n_shards`` — single-device hosts get the whole table on device 0
    (bitwise-identical serving, no parallelism), an 8-device host gives
    4x2 its own device per cell. ``table[r][s]`` is shard ``s`` of
    replica group ``r``."""
    assert n_replicas >= 1 and n_shards >= 1, (n_replicas, n_shards)
    devs = jax.devices()
    return [[devs[(r * n_shards + s) % len(devs)]
             for s in range(n_shards)] for r in range(n_replicas)]


def distinct_row(row) -> bool:
    """True when a replica group's device row has no reuse — the
    precondition for building a real shard mesh over it."""
    return len({d.id for d in row}) == len(row)


def batch_axes(mesh) -> object:
    """The data-parallel axis spec for this mesh ('data' or (pod, data))."""
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def fsdp_axes(mesh) -> object:
    """Weight-sharding (ZeRO) axes: same as the DP axes."""
    return batch_axes(mesh)
