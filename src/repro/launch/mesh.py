"""Production mesh builders.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
pure data parallelism across pods (gradients all-reduce over
("pod", "data") — DCN-friendly: only one collective crosses pods).

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a 1D 'data' mesh (tests/smoke)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def batch_axes(mesh) -> object:
    """The data-parallel axis spec for this mesh ('data' or (pod, data))."""
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def fsdp_axes(mesh) -> object:
    """Weight-sharding (ZeRO) axes: same as the DP axes."""
    return batch_axes(mesh)
