"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``build_cell(arch, cell, mesh)`` returns a ``CellBuild``:
  fn            — the step function to jit
  args          — tuple of pytrees of ShapeDtypeStruct (no allocation)
  in_specs      — matching pytrees of PartitionSpec
  out_specs     — pytree-prefix of PartitionSpec or None (XLA infers)
  rules         — logical-axis rules to activate (mesh_context) while
                  tracing, so the models' ``constrain`` calls resolve.
  static        — metadata (family, step kind) for reporting.

Everything here is shape bookkeeping: nothing touches device memory, which
is what lets a 1T-param config lower on a CPU container.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import (ColbertConfig, DimeNetConfig, RecsysConfig,
                                ShapeCell, TransformerConfig, shapes_for)
from repro.launch import steps as S
from repro.launch.mesh import batch_axes as mesh_batch_axes
from repro.launch.mesh import fsdp_axes as mesh_fsdp_axes
from repro.models.layers import dt
from repro.sharding import api as rules_api
from repro.sharding.params import (gnn_param_rules, lm_param_rules,
                                   opt_state_specs, param_specs,
                                   recsys_param_rules)

F32, I32, BOOL = jnp.float32, jnp.int32, jnp.bool_


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


@dataclass
class CellBuild:
    arch: str
    cell: str
    kind: str
    fn: Callable
    args: Tuple[Any, ...]
    in_specs: Tuple[Any, ...]
    out_specs: Optional[Any]
    rules: Dict[str, Any]
    note: str = ""
    donate: Tuple[int, ...] = ()   # donated arg indices (in-place buffers)


# ---------------------------------------------------------------------------
# Shared: params/opt structs + specs
# ---------------------------------------------------------------------------
def _lm_param_structs(cfg: TransformerConfig):
    from repro.models.transformer import init_transformer
    return jax.eval_shape(
        lambda k: init_transformer(k, cfg), jax.random.PRNGKey(0))


def _opt_structs(opt, params_struct):
    return jax.eval_shape(opt.init, params_struct)


def _lm_specs(cfg: TransformerConfig, mesh):
    fsdp = mesh_fsdp_axes(mesh) if cfg.fsdp_params else None
    rules = lm_param_rules(fsdp)
    p_struct = _lm_param_structs(cfg)
    return p_struct, param_specs(p_struct, rules)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------
def _lm_cell(cfg: TransformerConfig, cell: ShapeCell, mesh,
             arch: str) -> CellBuild:
    dp = mesh_batch_axes(mesh)
    p_struct, p_specs = _lm_specs(cfg, mesh)
    seq = cell.dim("seq_len")
    gb = cell.dim("global_batch")

    if cell.kind == "train":
        step, opt = S.make_lm_train_step(cfg)
        o_struct = _opt_structs(opt, p_struct)
        o_specs = opt_state_specs(o_struct, p_specs, cfg.optimizer)
        batch = {"tokens": sds((gb, seq), I32),
                 "labels": sds((gb, seq), I32)}
        b_specs = {"tokens": P(dp, None), "labels": P(dp, None)}
        return CellBuild(
            arch, cell.name, cell.kind, step,
            (p_struct, o_struct, batch), (p_specs, o_specs, b_specs),
            (p_specs, o_specs, None),
            rules_api.lm_rules(dp, attn_shard=cfg.attn_shard),
            donate=(0, 1))

    if cell.kind == "prefill":
        if cfg.unroll_scans and seq // cfg.attn_chunk > 8:
            # analysis mode: larger attention chunks keep the unrolled HLO
            # tractable (identical matmul volume, coarser tiling)
            cfg = dataclasses.replace(cfg, attn_chunk=seq // 8)
            step = S.make_lm_prefill_step(cfg)
        else:
            step = S.make_lm_prefill_step(cfg)
        batch = {"tokens": sds((gb, seq), I32)}
        b_specs = {"tokens": P(dp, None)}
        return CellBuild(
            arch, cell.name, cell.kind, step, (p_struct, batch),
            (p_specs, b_specs), None,
            rules_api.lm_rules(dp, attn_shard=cfg.attn_shard))

    # decode cells: one token against a seq_len cache
    assert cell.kind == "decode"
    step = S.make_lm_decode_step(cfg)
    cdt = dt(cfg.dtype)
    L, KV, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    cache = {"k": sds((L, gb, seq, KV, dh), cdt),
             "v": sds((L, gb, seq, KV, dh), cdt)}
    batch = {"token": sds((gb, 1), I32), "pos": sds((), I32)}
    if gb == 1:
        rules = rules_api.lm_long_decode_rules(dp)
        kv_spec = P(None, None, rules["kvseq"], None, None)
    else:
        rules = rules_api.lm_decode_rules(dp)
        kv_spec = P(None, dp, "model", None, None)
    c_specs = {"k": kv_spec, "v": kv_spec}
    b_specs = {"token": P(None if gb == 1 else dp, None), "pos": P()}
    return CellBuild(
        arch, cell.name, cell.kind, step,
        (p_struct, cache, batch), (p_specs, c_specs, b_specs), None, rules,
        donate=(1,))


# ---------------------------------------------------------------------------
# GNN cells (DimeNet)
# ---------------------------------------------------------------------------
GNN_CELL_META = {
    # cell -> (d_feat or None->atom types, n_classes/targets, task, graphs)
    "full_graph_sm": (1433, 7, "node", 1),
    "minibatch_lg": (602, 41, "node", 1),
    "ogb_products": (100, 47, "node", 1),
    "molecule": (None, 1, "graph", 128),
}


def _rup(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _gnn_counts(cell: ShapeCell, cap: int):
    if cell.name == "minibatch_lg":
        b = cell.dim("batch_nodes")
        f0, f1 = cell.dim("fanout0"), cell.dim("fanout1")
        n = b + b * f0 + b * f0 * f1
        e = b * f0 + b * f0 * f1
    elif cell.name == "molecule":
        n = cell.dim("n_nodes") * cell.dim("batch")
        e = cell.dim("n_edges") * cell.dim("batch")
    else:
        n, e = cell.dim("n_nodes"), cell.dim("n_edges")
    # pad to shard-divisible sizes (masked rows; nodes shard 16-way on
    # data, edges/triplets up to 512-way on pod x data x model)
    n, e = _rup(n, 32), _rup(e, 512)
    return n, e, e * cap


def _gnn_cell(cfg: DimeNetConfig, cell: ShapeCell, mesh,
              arch: str) -> CellBuild:
    dp = mesh_batch_axes(mesh)
    d_feat, n_cls, task, n_graphs = GNN_CELL_META[cell.name]
    cfg = dataclasses.replace(cfg, d_feat_in=d_feat or 0, n_targets=n_cls)
    N, E, T = _gnn_counts(cell, cfg.triplet_cap)

    from repro.models.gnn.dimenet import init_dimenet
    p_struct = jax.eval_shape(lambda k: init_dimenet(k, cfg),
                              jax.random.PRNGKey(0))
    p_specs = param_specs(p_struct, gnn_param_rules(None))

    step, opt = S.make_gnn_train_step(cfg, task, n_graphs)
    o_struct = _opt_structs(opt, p_struct)
    o_specs = opt_state_specs(o_struct, p_specs, cfg.optimizer)

    rules = rules_api.gnn_rules(dp)
    ep = rules["edges"]
    batch = {
        "pos": sds((N, 3), F32),
        "edge_index": sds((2, E), I32),
        "t_in": sds((T,), I32), "t_out": sds((T,), I32),
        "t_mask": sds((T,), BOOL),
        "node_mask": sds((N,), BOOL), "edge_mask": sds((E,), BOOL),
    }
    b_specs = {
        "pos": P(dp, None), "edge_index": P(None, ep),
        "t_in": P(ep), "t_out": P(ep), "t_mask": P(ep),
        "node_mask": P(dp), "edge_mask": P(ep),
    }
    if d_feat is None:
        batch["z"] = sds((N,), I32)
        b_specs["z"] = P(dp)
        batch["graph_ids"] = sds((N,), I32)
        b_specs["graph_ids"] = P(dp)
        batch["targets"] = sds((n_graphs, cfg.n_targets), F32)
        b_specs["targets"] = P(None, None)
    else:
        batch["feat"] = sds((N, d_feat), F32)
        b_specs["feat"] = P(dp, None)
        batch["targets"] = sds((N,), I32)
        b_specs["targets"] = P(dp)
    return CellBuild(
        arch, cell.name, "train", step,
        (p_struct, o_struct, batch), (p_specs, o_specs, b_specs),
        (p_specs, o_specs, None), rules,
        note=f"N={N} E={E} T={T} task={task}", donate=(0, 1))


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------
def _recsys_cell(cfg: RecsysConfig, cell: ShapeCell, mesh,
                 arch: str) -> CellBuild:
    dp = mesh_batch_axes(mesh)
    from repro.models.recsys.models import init_recsys
    p_struct = jax.eval_shape(lambda k: init_recsys(k, cfg),
                              jax.random.PRNGKey(0))
    p_specs = param_specs(p_struct, recsys_param_rules(None))
    rules = rules_api.recsys_rules(dp)
    B = cell.dim("batch")

    def mk_batch(bsz, with_label):
        b = {"sparse_ids": sds((bsz, cfg.n_sparse, cfg.multi_hot), I32)}
        s = {"sparse_ids": P(dp, None, None)}
        if cfg.n_dense:
            b["dense"] = sds((bsz, cfg.n_dense), F32)
            s["dense"] = P(dp, None)
        if with_label:
            b["label"] = sds((bsz,), F32)
            s["label"] = P(dp)
        return b, s

    if cell.kind == "train":
        step, opt = S.make_recsys_train_step(cfg)
        o_struct = _opt_structs(opt, p_struct)
        o_specs = opt_state_specs(o_struct, p_specs, cfg.optimizer)
        batch, b_specs = mk_batch(B, True)
        return CellBuild(arch, cell.name, "train", step,
                         (p_struct, o_struct, batch),
                         (p_specs, o_specs, b_specs),
                         (p_specs, o_specs, None), rules, donate=(0, 1))

    if cell.name == "retrieval_cand":
        C = cell.dim("n_candidates")
        # batch=1 request: batch axis replicated, candidate axis data-sharded
        rules = {**rules, "batch": None}
        step = S.make_recsys_retrieval_step(cfg)
        batch, b_specs = mk_batch(B, False)
        batch["candidates"] = sds((C, cfg.embed_dim), F32)
        b_specs["candidates"] = P(rules["candidates"], None)
        # batch=1: replicate the (tiny) per-request inputs
        b_specs["sparse_ids"] = P(None, None, None)
        if "dense" in b_specs:
            b_specs["dense"] = P(None, None)
        return CellBuild(arch, cell.name, "serve", step,
                         (p_struct, batch), (p_specs, b_specs), None, rules)

    step = S.make_recsys_serve_step(cfg)
    batch, b_specs = mk_batch(B, False)
    return CellBuild(arch, cell.name, "serve", step, (p_struct, batch),
                     (p_specs, b_specs), None, rules)


# ---------------------------------------------------------------------------
# ColBERT cells (the paper's own workload — extra beyond the assigned 40)
# ---------------------------------------------------------------------------
def _colbert_cell(cfg: ColbertConfig, cell: ShapeCell, mesh,
                  arch: str) -> CellBuild:
    dp = mesh_batch_axes(mesh)
    from repro.models.colbert import init_colbert
    fsdp = mesh_fsdp_axes(mesh) if cfg.trunk.fsdp_params else None
    p_struct = jax.eval_shape(lambda k: init_colbert(k, cfg),
                              jax.random.PRNGKey(0))
    # BERT vocab (30522) does not divide tp=16 -> replicate embeddings
    # (the trunk is ~110M params; embed is 23MB — replication is free)
    rules = ([(r"embed/table$", (None, None)),
              (r"lm_head/w$", (None, None)), (r"lm_head/b$", (None,))]
             + lm_param_rules(fsdp))
    p_specs = param_specs(p_struct, rules)
    rules = rules_api.retrieval_rules(dp)

    if cell.name == "index_build":
        step = S.make_colbert_index_step(cfg)
        batch = {"doc_tokens": sds((cell.dim("n_docs"),
                                    cell.dim("doc_len")), I32)}
        b_specs = {"doc_tokens": P(dp, None)}
        return CellBuild(arch, cell.name, "index", step,
                         (p_struct, batch), (p_specs, b_specs), None, rules)

    step = S.make_colbert_search_step(cfg)
    batch = {
        "q_tokens": sds((cell.dim("n_queries"), cell.dim("query_len")), I32),
        "doc_vecs": sds((cell.dim("n_docs"), cell.dim("doc_len"),
                         cfg.proj_dim), F32),
        "doc_mask": sds((cell.dim("n_docs"), cell.dim("doc_len")), BOOL),
    }
    b_specs = {"q_tokens": P(rules.get("queries"), None),
               "doc_vecs": P(dp, None, None),
               "doc_mask": P(dp, None)}
    return CellBuild(arch, cell.name, "search", step, (p_struct, batch),
                     (p_specs, b_specs), None, rules)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------
def build_cell(arch: str, cell_name: str, mesh,
               unroll: bool = False,
               layers_override: int | None = None,
               cfg_overrides: dict | None = None,
               rules_overrides: dict | None = None) -> CellBuild:
    cfg = get_config(arch)
    if cfg_overrides:
        trunk_over = {k[6:]: v for k, v in cfg_overrides.items()
                      if k.startswith("trunk.")}
        own = {k: v for k, v in cfg_overrides.items()
               if not k.startswith("trunk.")}
        if trunk_over and isinstance(cfg, ColbertConfig):
            cfg = dataclasses.replace(
                cfg, trunk=dataclasses.replace(cfg.trunk, **trunk_over))
        if own:
            cfg = dataclasses.replace(cfg, **own)
    if unroll and hasattr(cfg, "unroll_scans"):
        cfg = dataclasses.replace(cfg, unroll_scans=True)
    if unroll and isinstance(cfg, ColbertConfig):
        cfg = dataclasses.replace(
            cfg, trunk=dataclasses.replace(cfg.trunk, unroll_scans=True))
    if layers_override is not None:
        if isinstance(cfg, TransformerConfig):
            cfg = dataclasses.replace(cfg, n_layers=layers_override)
        elif isinstance(cfg, DimeNetConfig):
            cfg = dataclasses.replace(cfg, n_blocks=layers_override)
        elif isinstance(cfg, ColbertConfig):
            cfg = dataclasses.replace(cfg, trunk=dataclasses.replace(
                cfg.trunk, n_layers=layers_override))
    cells = {c.name: c for c in shapes_for(cfg)}
    cell = cells[cell_name]
    if isinstance(cfg, TransformerConfig):
        built = _lm_cell(cfg, cell, mesh, arch)
    elif isinstance(cfg, DimeNetConfig):
        built = _gnn_cell(cfg, cell, mesh, arch)
    elif isinstance(cfg, RecsysConfig):
        built = _recsys_cell(cfg, cell, mesh, arch)
    elif isinstance(cfg, ColbertConfig):
        built = _colbert_cell(cfg, cell, mesh, arch)
    else:
        raise TypeError(type(cfg))
    if rules_overrides:
        built.rules = {**built.rules, **rules_overrides}
    return built


def all_cells(arch: str):
    return [c.name for c in shapes_for(get_config(arch))]


def input_specs(arch: str, cell_name: str, mesh) -> Tuple[Any, ...]:
    """The ShapeDtypeStruct stand-ins for every model input of the cell."""
    return build_cell(arch, cell_name, mesh).args
