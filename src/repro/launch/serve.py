"""Batched retrieval serving driver — the paper's deployment shape.

    python -m repro.launch.serve --dataset scifact --pool-factor 2 \
        --backend plaid --queries 32

Builds (or loads) a token-pooled index, then serves query batches through
the staged search pipeline, reporting latency percentiles and the index
footprint. On the production mesh the doc shards live on the ``data``
axis; here it runs the same code single-host.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data.corpus import DATASET_SPECS, SyntheticRetrievalCorpus
from repro.models.colbert import init_colbert
from repro.retrieval.indexer import Indexer
from repro.retrieval.searcher import Searcher


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="scifact",
                    choices=sorted(DATASET_SPECS))
    ap.add_argument("--pool-method", default="ward",
                    choices=("ward", "kmeans", "sequential", "none"))
    ap.add_argument("--pool-factor", type=int, default=2)
    ap.add_argument("--backend", default="plaid",
                    choices=("flat", "hnsw", "plaid"))
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config("colbertv2")
    params = init_colbert(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticRetrievalCorpus(DATASET_SPECS[args.dataset],
                                      vocab_size=cfg.trunk.vocab_size)

    t0 = time.time()
    indexer = Indexer(params, cfg, pool_method=args.pool_method,
                      pool_factor=args.pool_factor, backend=args.backend)
    index, stats = indexer.build(corpus.doc_token_batch(cfg.doc_maxlen - 2))
    t_build = time.time() - t0
    print(f"index: {stats.n_docs} docs, {stats.n_vectors_stored} vectors "
          f"({stats.vector_reduction:.0%} reduction), "
          f"{stats.index_bytes / 2**20:.1f} MiB, built in {t_build:.1f}s")

    searcher = Searcher(params, cfg, index)
    q_all = corpus.query_token_batch(cfg.query_maxlen - 2)
    lat = []
    for i in range(args.queries):
        q = q_all[i % len(q_all):i % len(q_all) + 1]
        t = time.time()
        scores, ids = searcher.search(q, k=args.k)
        lat.append(time.time() - t)
    lat_ms = np.array(lat) * 1e3
    print(f"served {args.queries} queries: "
          f"p50 {np.percentile(lat_ms, 50):.1f}ms "
          f"p99 {np.percentile(lat_ms, 99):.1f}ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
