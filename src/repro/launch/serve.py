"""Batched retrieval serving driver — the paper's deployment shape.

    python -m repro.launch.serve --dataset scifact --pool-factor 2 \
        --backend plaid --queries 128 --batch-sizes 1,8,32

Builds (or loads) a token-pooled index, then serves query *microbatches*
through the staged two-stage engine: the whole microbatch is encoded and
reranked in one traced call per stage. Each batch size gets a jit warmup
pass first so the reported percentiles are steady-state; the driver
reports QPS and p50/p99 per batch size plus the index footprint. On the
production mesh the doc shards live on the ``data`` axis; here it runs
the same code single-host.

``--index-dir`` makes the index a persistent artifact (core/persist.py):
if the directory already holds a manifest the index is mmap-loaded from
it — no document encoding, no index build, restart-to-serving in the
cold-load time printed — otherwise the built index is saved there for
the next restart. Loading dispatches on the manifest kind, so the same
flag serves monolithic AND sharded artifacts.

``--shard-max-vectors N`` builds through the STREAMING path instead
(retrieval/indexer.py): token batches are encoded+pooled incrementally
and flushed to capped shards, so the build's host memory is O(shard).
Sharded serving reports the per-shard probe time alongside the usual
percentiles.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.persist import (MANIFEST_NAME, artifact_bytes,
                                load_artifact)
from repro.core.sharded import ShardedIndex
from repro.data.corpus import DATASET_SPECS, SyntheticRetrievalCorpus
from repro.models.colbert import init_colbert
from repro.retrieval.indexer import Indexer
from repro.retrieval.searcher import Searcher


def serve_microbatches(searcher: Searcher, q_tokens: np.ndarray,
                       batch_size: int, n_queries: int, k: int = 10):
    """Serve ``n_queries`` in fixed-size microbatches; returns per-batch
    latencies (seconds). The searcher is warmed up first so jit compile
    time never lands in a measured batch."""
    searcher.warmup(batch_size, k=k)
    lat = []
    served = 0
    while served < n_queries:
        # modular gather keeps every batch exactly batch_size queries
        idx = (served + np.arange(batch_size)) % len(q_tokens)
        batch = q_tokens[idx]
        t = time.time()
        searcher.search(batch, k=k)
        lat.append(time.time() - t)
        served += batch_size
    return np.array(lat)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="scifact",
                    choices=sorted(DATASET_SPECS))
    ap.add_argument("--pool-method", default="ward",
                    choices=("ward", "kmeans", "sequential", "none"))
    ap.add_argument("--pool-factor", type=int, default=2)
    ap.add_argument("--backend", default="plaid",
                    choices=("flat", "hnsw", "plaid"))
    ap.add_argument("--queries", type=int, default=128,
                    help="total queries served per batch size")
    ap.add_argument("--batch-sizes", default="1,8,32",
                    help="comma-separated microbatch sizes")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--index-dir", default=None,
                    help="artifact directory: load the index from it if "
                         "a manifest exists (skip corpus encode + build), "
                         "otherwise build and save to it")
    ap.add_argument("--shard-max-vectors", type=int, default=0,
                    help="build via the streaming path, flushing a new "
                         "shard every N pooled vectors (0 = monolithic)")
    args = ap.parse_args(argv)
    batch_sizes = [int(b) for b in args.batch_sizes.split(",") if b]
    if not batch_sizes or any(b <= 0 for b in batch_sizes):
        ap.error(f"--batch-sizes must be positive ints, got "
                 f"{args.batch_sizes!r}")

    cfg = get_smoke_config("colbertv2")
    params = init_colbert(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticRetrievalCorpus(DATASET_SPECS[args.dataset],
                                      vocab_size=cfg.trunk.vocab_size)

    have_artifact = (args.index_dir is not None and os.path.isfile(
        os.path.join(args.index_dir, MANIFEST_NAME)))
    if have_artifact:
        t0 = time.time()
        index = load_artifact(args.index_dir, mmap=True)
        t_load = time.time() - t0
        kind = (f"{index.n_shards}-shard" if isinstance(index, ShardedIndex)
                else "monolithic")
        print(f"index: loaded {args.index_dir} ({kind}) — "
              f"{index.n_docs} docs, "
              f"{artifact_bytes(args.index_dir) / 2**20:.1f} MiB on disk, "
              f"cold load {t_load * 1e3:.0f}ms (no encoder run)")
    else:
        t0 = time.time()
        indexer = Indexer(params, cfg, pool_method=args.pool_method,
                          pool_factor=args.pool_factor,
                          backend=args.backend)
        toks = corpus.doc_token_batch(cfg.doc_maxlen - 2)
        if args.shard_max_vectors > 0:
            index, stats = indexer.build_streaming(
                toks, shard_max_vectors=args.shard_max_vectors,
                out_dir=args.index_dir)
        else:
            index, stats = indexer.build(toks, out_dir=args.index_dir)
        t_build = time.time() - t0
        shard_note = (f", {stats.n_shards} shards (peak buffer "
                      f"{stats.peak_buffered_vectors} vectors)"
                      if stats.n_shards > 1 else "")
        print(f"index: {stats.n_docs} docs, "
              f"{stats.n_vectors_stored} vectors "
              f"({stats.vector_reduction:.0%} reduction), "
              f"{stats.index_bytes / 2**20:.1f} MiB on disk, "
              f"built in {t_build:.1f}s{shard_note}"
              + (f", saved to {args.index_dir}" if args.index_dir else ""))

    searcher = Searcher(params, cfg, index)
    q_all = corpus.query_token_batch(cfg.query_maxlen - 2)
    print(f"{'batch':>5s} {'batches':>7s} {'QPS':>8s} "
          f"{'p50(ms)':>8s} {'p99(ms)':>8s}")
    for bs in batch_sizes:
        lat = serve_microbatches(searcher, q_all, bs, args.queries,
                                 k=args.k)
        qps = bs * len(lat) / lat.sum()
        lat_ms = lat * 1e3
        print(f"{bs:5d} {len(lat):7d} {qps:8.1f} "
              f"{np.percentile(lat_ms, 50):8.1f} "
              f"{np.percentile(lat_ms, 99):8.1f}")
        if isinstance(index, ShardedIndex) and index.last_probe_s:
            per = "  ".join(f"s{i}={t * 1e3:.1f}ms"
                            for i, t in enumerate(index.last_probe_s))
            print(f"      per-shard probe (last batch): {per}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
