"""Retrieval serving driver — closed-loop replay AND open-loop load.

    # closed-loop (fixed microbatches, service-time percentiles):
    python -m repro.launch.serve --dataset scifact --pool-factor 2 \
        --backend plaid --queries 128 --batch-sizes 1,8,32

    # open-loop (Poisson arrivals through the ServingEngine —
    # tail latency under offered load, dynamic batching live):
    python -m repro.launch.serve --dataset scifact --pool-factor 2 \
        --backend plaid --queries 256 --arrival-qps 50,200

Closed-loop mode replays fixed-size microbatches through the staged
two-stage engine and reports QPS and p50/p99 *service* time per batch
size — exactly ``--queries`` queries are served per row (the final
partial batch is smaller; nothing is silently wrapped and over-counted).

Open-loop mode (``--arrival-qps``) is the deployment-shaped measurement:
single queries arrive with exponential inter-arrival gaps and land on
``launch/engine.py``'s ServingEngine, whose deadline batcher coalesces
them into shape-bucketed microbatches. Reported p50/p99 are end-to-end
request latency (queue wait included) — the number an SLO is written
against — plus the batcher's flush-reason and coalescing stats.

``--index-dir`` makes the index a persistent artifact (core/persist.py):
if the directory already holds a manifest the index is mmap-loaded from
it, otherwise the built index is saved there. In open-loop mode the
engine also WATCHES the directory: re-publishing the artifact (any
``save`` bumps the manifest's monotonic generation) hot-swaps the new
index in with zero dropped queries.

``--shard-max-vectors N`` builds through the STREAMING path instead
(retrieval/indexer.py): token batches are encoded+pooled incrementally
and flushed to capped shards; sharded serving reports per-shard probe
times alongside the percentiles.

The knob flags are DERIVED from the typed spec layer (core/spec.py
``add_spec_args``): --pool-method/--pool-factor come from PoolingSpec,
--max-batch/--max-wait-ms/--k from ServeSpec, --shard-max-vectors from
ShardSpec, and --backend's choices from the backend registry — which is
why ``--backend cascade`` serves the pooled-cascade through the same
engine. Builds and loads go through ``repro.Retriever``.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.api import Retriever
from repro.configs import get_smoke_config
from repro.core.persist import (MANIFEST_NAME, artifact_bytes,
                                artifact_generation)
from repro.core.sharded import ShardedIndex
from repro.core.spec import (IndexSpec, PoolingSpec, RetrieverSpec,
                             ServeSpec, ShardSpec, add_spec_args,
                             backend_names, spec_from_args)
from repro.data.corpus import DATASET_SPECS, SyntheticRetrievalCorpus
from repro.launch.engine import ServingEngine, run_open_loop
from repro.models.colbert import init_colbert
from repro.retrieval.searcher import Searcher


def serve_microbatches(searcher: Searcher, q_tokens: np.ndarray,
                       batch_size: int, n_queries: int, k: int = 10):
    """Serve EXACTLY ``n_queries`` in fixed-size microbatches; returns
    (per-batch latencies [s], per-batch served counts).

    The final batch is partial when ``n_queries % batch_size != 0`` —
    earlier versions wrapped around and silently served (and counted)
    extra queries, inflating QPS. Both the full and the remainder batch
    shapes are warmed first so jit compile time never lands in a
    measured batch.
    """
    sizes = [batch_size] * (n_queries // batch_size)
    if n_queries % batch_size:
        sizes.append(n_queries % batch_size)
    searcher.warmup(sorted(set(sizes)), k=k)
    lat = []
    served = 0
    for bs in sizes:
        # modular gather over the query pool; exactly bs queries served
        idx = (served + np.arange(bs)) % len(q_tokens)
        batch = q_tokens[idx]
        t = time.time()
        searcher.search(batch, k=k)
        lat.append(time.time() - t)
        served += bs
    assert served == n_queries, (served, n_queries)
    return np.array(lat), np.array(sizes)


def _print_probe(index) -> None:
    if isinstance(index, ShardedIndex) and index.last_probe_s:
        per = "  ".join(f"s{i}={t * 1e3:.1f}ms"
                        for i, t in enumerate(index.last_probe_s))
        print(f"      per-shard probe (last batch): {per}")


def closed_loop(searcher, index, q_all, batch_sizes, n_queries, k) -> None:
    print(f"{'batch':>5s} {'served':>7s} {'QPS':>8s} "
          f"{'p50(ms)':>8s} {'p99(ms)':>8s}")
    for bs in batch_sizes:
        lat, sizes = serve_microbatches(searcher, q_all, bs, n_queries, k=k)
        qps = sizes.sum() / lat.sum()
        lat_ms = lat * 1e3
        print(f"{bs:5d} {int(sizes.sum()):7d} {qps:8.1f} "
              f"{np.percentile(lat_ms, 50):8.1f} "
              f"{np.percentile(lat_ms, 99):8.1f}")
        _print_probe(index)


def open_loop(searcher, index, q_all, rates, n_queries,
              serve_spec: ServeSpec, index_dir, index_generation) -> None:
    print(f"{'offered':>8s} {'achieved':>8s} {'p50(ms)':>8s} "
          f"{'p99(ms)':>8s} {'coalesce':>8s} {'flushes(full/ddl)':>18s} "
          f"{'err':>4s}")
    for i, rate in enumerate(rates):
        engine = ServingEngine.from_spec(
            searcher, serve_spec.replace(warmup_on_start=(i == 0)),
            index_dir=index_dir, index_generation=index_generation)
        with engine:
            row = run_open_loop(engine, q_all, rate, n_queries,
                                k=serve_spec.k)
        snap = engine.stats.snapshot()
        fl = snap["flush_reasons"]
        print(f"{row['arrival_qps']:8.1f} {row['achieved_qps']:8.1f} "
              f"{row['latency_p50_ms']:8.1f} {row['latency_p99_ms']:8.1f} "
              f"{snap['mean_batch_size']:8.1f} "
              f"{fl['full']:8d}/{fl['deadline']:<9d} "
              f"{row['errors']:4d}")
        _print_probe(index)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="scifact",
                    choices=sorted(DATASET_SPECS))
    # typed knobs derive their flags from the spec layer (core/spec.py):
    # --pool-method/--pool-factor (PoolingSpec), --max-batch/
    # --max-wait-ms/--k (ServeSpec), --shard-max-vectors (ShardSpec) —
    # no hand-maintained duplicates of the spec defaults/choices here.
    add_spec_args(ap, PoolingSpec, prefix="pool-",
                  defaults={"factor": 2})
    ap.add_argument("--backend", default="plaid", choices=backend_names())
    ap.add_argument("--queries", type=int, default=128,
                    help="total queries served per batch size / rate")
    ap.add_argument("--batch-sizes", default="1,8,32",
                    help="comma-separated closed-loop microbatch sizes")
    ap.add_argument("--arrival-qps", default=None,
                    help="comma-separated offered loads; selects OPEN-LOOP "
                         "mode (Poisson arrivals through the ServingEngine)")
    add_spec_args(ap, ServeSpec,
                  only=("max_batch", "max_wait_ms", "k", "n_replicas"))
    ap.add_argument("--index-dir", default=None,
                    help="artifact directory: load the index from it if "
                         "a manifest exists (skip corpus encode + build), "
                         "otherwise build and save to it; in open-loop "
                         "mode the engine watches it for hot swaps")
    add_spec_args(ap, ShardSpec)
    args = ap.parse_args(argv)
    batch_sizes = [int(b) for b in args.batch_sizes.split(",") if b]
    if not batch_sizes or any(b <= 0 for b in batch_sizes):
        ap.error(f"--batch-sizes must be positive ints, got "
                 f"{args.batch_sizes!r}")
    rates = ([float(r) for r in args.arrival_qps.split(",") if r]
             if args.arrival_qps else [])
    if args.arrival_qps and (not rates or any(r <= 0 for r in rates)):
        ap.error(f"--arrival-qps must be positive, got "
                 f"{args.arrival_qps!r}")

    cfg = get_smoke_config("colbertv2")
    serve_spec = spec_from_args(
        ServeSpec, args,
        only=("max_batch", "max_wait_ms", "k", "n_replicas"))
    try:
        spec = RetrieverSpec(
            pooling=spec_from_args(PoolingSpec, args, prefix="pool_"),
            index=IndexSpec.from_config(cfg, backend=args.backend),
            shard=spec_from_args(ShardSpec, args),
            serve=serve_spec)
    except ValueError as e:             # e.g. cascade + sharded
        ap.error(str(e))
    params = init_colbert(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticRetrievalCorpus(DATASET_SPECS[args.dataset],
                                      vocab_size=cfg.trunk.vocab_size)

    have_artifact = (args.index_dir is not None and os.path.isfile(
        os.path.join(args.index_dir, MANIFEST_NAME)))
    generation = None
    if have_artifact:
        t0 = time.time()
        # generation read BEFORE the load: a racing publish leaves the
        # label stale-low and the engine watcher swaps once, redundantly
        generation = artifact_generation(args.index_dir)
        retriever = Retriever.load(params, cfg, args.index_dir,
                                   mmap=True, serve=serve_spec)
        index = retriever.index
        t_load = time.time() - t0
        kind = (f"{index.n_shards}-shard" if isinstance(index, ShardedIndex)
                else retriever.spec.index.backend)
        print(f"index: loaded {args.index_dir} ({kind}) — "
              f"{index.n_docs} docs, "
              f"{artifact_bytes(args.index_dir) / 2**20:.1f} MiB on disk, "
              f"cold load {t_load * 1e3:.0f}ms (no encoder run)")
    else:
        t0 = time.time()
        toks = corpus.doc_token_batch(cfg.doc_maxlen - 2)
        retriever = Retriever.build(params, cfg, toks, spec,
                                    out_dir=args.index_dir)
        index, stats = retriever.index, retriever.stats
        t_build = time.time() - t0
        shard_note = (f", {stats.n_shards} shards (peak buffer "
                      f"{stats.peak_buffered_vectors} vectors)"
                      if stats.n_shards > 1 else "")
        print(f"index: {stats.n_docs} docs, "
              f"{stats.n_vectors_stored} vectors "
              f"({stats.vector_reduction:.0%} reduction), "
              f"{stats.index_bytes / 2**20:.1f} MiB on disk, "
              f"built in {t_build:.1f}s{shard_note}"
              + (f", saved to {args.index_dir}" if args.index_dir else ""))
        if args.index_dir:                  # our own publish just landed
            generation = artifact_generation(args.index_dir)

    searcher = retriever.searcher
    q_all = corpus.query_token_batch(cfg.query_maxlen - 2)
    if rates:
        open_loop(searcher, index, q_all, rates, args.queries,
                  serve_spec, args.index_dir, generation)
    else:
        closed_loop(searcher, index, q_all, batch_sizes, args.queries,
                    serve_spec.k)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
