"""Jittable production step functions — the things the dry-run lowers and
the train/serve drivers run.

Every step is a pure function (params, [opt_state], batch) -> outputs with
explicit config closure; sharding comes from (a) the in_shardings the
launcher passes to jit and (b) the logical-axis ``constrain`` annotations
inside the models, resolved against the active ``mesh_context``.
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ColbertConfig, DimeNetConfig, RecsysConfig,
                                TransformerConfig)
from repro.models import transformer
from repro.models.layers import dt
from repro.train.optimizer import clip_by_global_norm, make_optimizer

# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


def make_lm_train_step(cfg: TransformerConfig, lr: float = 1e-4,
                       moe_impl: str = None) -> Tuple[Callable, object]:
    """train_step(params, opt_state, batch) -> (params, opt_state, loss).

    Microbatched grad accumulation (cfg.train_microbatches) runs inside the
    jit as a lax.scan, so XLA's scheduler overlaps microbatch i's gradient
    all-reduce with microbatch i+1's compute.
    """
    opt = make_optimizer(cfg.optimizer, lr)
    moe_impl = moe_impl or cfg.moe_impl
    n_micro = cfg.train_microbatches
    acc_dt = dt(cfg.grad_accum_dtype)

    def loss_fn(p, tokens, labels):
        loss, metrics = transformer.lm_loss(p, tokens, labels, cfg,
                                            moe_impl=moe_impl)
        return loss

    def train_step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
        else:
            B = tokens.shape[0]
            mb = B // n_micro
            tok_m = tokens.reshape(n_micro, mb, -1)
            lab_m = labels.reshape(n_micro, mb, -1)

            def body(carry, inp):
                acc_loss, acc_g = carry
                t, l = inp
                loss, g = jax.value_and_grad(loss_fn)(params, t, l)
                acc_g = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(acc_dt), acc_g, g)
                return (acc_loss + loss, acc_g), None

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero), (tok_m, lab_m),
                unroll=n_micro if cfg.unroll_scans else 1)
            loss = loss / n_micro
            grads = jax.tree_util.tree_map(
                lambda g: (g / n_micro), grads)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step, opt


def make_lm_prefill_step(cfg: TransformerConfig,
                         moe_impl: str = None) -> Callable:
    """prefill_step(params, tokens) -> (last_logits [B, V], cache)."""
    moe_impl = moe_impl or cfg.moe_impl

    def prefill_step(params, batch):
        hidden, cache = transformer.prefill(params, batch["tokens"], cfg,
                                            moe_impl=moe_impl)
        logits = transformer.logits_head(params, hidden[:, -1:, :], cfg)
        return logits[:, 0, :], cache

    return prefill_step


def make_lm_decode_step(cfg: TransformerConfig,
                        moe_impl: str = None) -> Callable:
    """serve_step(params, batch{token [B,1], pos scalar}, cache)
    -> (logits [B, V], cache). One new token vs a seq_len KV cache."""
    moe_impl = moe_impl or cfg.moe_impl

    def decode_step(params, cache, batch):
        logits, cache = transformer.decode_step(
            params, batch["token"], cache, batch["pos"], cfg,
            moe_impl=moe_impl)
        return logits[:, 0, :], cache

    return decode_step


# ---------------------------------------------------------------------------
# GNN (DimeNet)
# ---------------------------------------------------------------------------
def make_gnn_train_step(cfg: DimeNetConfig, task: str, n_graphs: int = 1,
                        lr: float = 1e-3) -> Tuple[Callable, object]:
    from repro.models.gnn.dimenet import dimenet_loss
    opt = make_optimizer(cfg.optimizer, lr)

    def train_step(params, opt_state, batch):
        inputs = {k: v for k, v in batch.items() if k != "targets"}

        def loss_fn(p):
            return dimenet_loss(p, inputs, batch["targets"], cfg,
                                task=task, n_graphs=n_graphs)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step, opt


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------
def make_recsys_train_step(cfg: RecsysConfig, lr: float = 1e-3
                           ) -> Tuple[Callable, object]:
    from repro.models.recsys.models import recsys_loss
    opt = make_optimizer(cfg.optimizer, lr)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            recsys_loss, has_aux=True)(params, batch, cfg)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step, opt


def make_recsys_serve_step(cfg: RecsysConfig) -> Callable:
    from repro.models.recsys.models import recsys_forward

    def serve_step(params, batch):
        return recsys_forward(params, batch, cfg)

    return serve_step


def make_recsys_retrieval_step(cfg: RecsysConfig, k: int = 100) -> Callable:
    from repro.models.recsys.models import score_candidates

    def retrieval_step(params, batch):
        return score_candidates(params, batch, batch["candidates"], cfg,
                                k=k)

    return retrieval_step


# ---------------------------------------------------------------------------
# ColBERT retrieval serving (the paper's own workload)
# ---------------------------------------------------------------------------
def make_colbert_index_step(cfg: ColbertConfig) -> Callable:
    """index_step(params, batch{doc_tokens}) -> (pooled vecs, pooled mask).

    encode -> TOKEN POOL, data-parallel over the doc batch: the device-side
    half of index building (the host appends to the IVF/HNSW structure).
    """
    from repro.core.pooling import pool_doc_embeddings
    from repro.models.colbert import encode_docs

    def index_step(params, batch):
        v, emit = encode_docs(params, batch["doc_tokens"], cfg)
        method = "none" if cfg.pool_factor <= 1 else cfg.pool_method
        pooled, pmask = pool_doc_embeddings(v, emit,
                                            max(cfg.pool_factor, 1), method)
        return pooled, pmask

    return index_step


def make_colbert_search_step(cfg: ColbertConfig, k: int = 10) -> Callable:
    """search_step(params, batch{q_tokens, doc_vecs, doc_mask})
    -> (scores [Nq, k], ids [Nq, k]).

    Query encode + MaxSim over the doc shard + top-k. Under SPMD with docs
    sharded on ``data``, the top-k merge is XLA's job (reduce over the
    sharded axis).
    """
    from repro.core.maxsim import maxsim_scores, maxsim_scores_blocked
    from repro.models.colbert import encode_queries

    def search_step(params, batch):
        qv, qm = encode_queries(params, batch["q_tokens"], cfg)
        if cfg.maxsim_impl == "blocked":
            # doc blocks stream through the score loop; the full
            # [Nq, Nd, Lq, Ld] similarity tensor never hits HBM
            scores = maxsim_scores_blocked(qv, qm, batch["doc_vecs"],
                                           batch["doc_mask"],
                                           block=cfg.maxsim_block,
                                           unroll=cfg.trunk.unroll_scans)
        else:
            scores = maxsim_scores(qv, qm, batch["doc_vecs"],
                                   batch["doc_mask"])
        return jax.lax.top_k(scores, k)

    return search_step
