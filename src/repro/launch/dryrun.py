import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  * ``jax.jit(step, in_shardings=...).lower(*ShapeDtypeStructs)`` —
    the SPMD partitioner must accept every sharding,
  * ``lowered.compile()`` — XLA must schedule it (sharding mismatches,
    unsupported collectives and shape errors all surface here),
  * ``compiled.memory_analysis()`` — per-device bytes (does it fit HBM),
  * ``compiled.cost_analysis()`` — FLOPs/bytes for the roofline terms,
  * collective bytes parsed from the optimized HLO (see roofline/analysis).

Usage:
    python -m repro.launch.dryrun --arch qwen3-0.6b --cell train_4k
    python -m repro.launch.dryrun --all --multi-pod --json out.json
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ASSIGNED_ARCHS
from repro.launch.input_specs import all_cells, build_cell
from repro.launch.mesh import make_production_mesh
from repro.sharding.api import mesh_context
from repro.sharding.params import to_shardings


def run_cell(arch: str, cell: str, *, multi_pod: bool = False,
             verbose: bool = True, keep_hlo: bool = False,
             unroll: bool = False, layers_override=None,
             cfg_overrides=None, rules_overrides=None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    build = build_cell(arch, cell, mesh, unroll=unroll,
                       layers_override=layers_override,
                       cfg_overrides=cfg_overrides,
                       rules_overrides=rules_overrides)
    with mesh, mesh_context(mesh, build.rules):
        in_sh = to_shardings(mesh, build.in_specs)
        out_sh = (to_shardings(mesh, build.out_specs)
                  if build.out_specs is not None else None)
        jitted = jax.jit(build.fn, in_shardings=in_sh,
                         out_shardings=out_sh,
                         donate_argnums=build.donate)
        lowered = jitted.lower(*build.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    n_dev = mesh.devices.size
    result = {
        "arch": arch, "cell": cell, "kind": build.kind,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": int(n_dev),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", 0.0)) if cost else 0.0,
        "bytes_accessed": float(cost.get("bytes accessed", 0.0))
        if cost else 0.0,
        "note": build.note,
    }
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                result[k] = int(v)
        peak = getattr(mem, "peak_memory_in_bytes", None)
        if peak is not None:
            result["peak_memory_in_bytes"] = int(peak)
    # collective bytes from the optimized HLO (roofline collective term)
    from repro.roofline.analysis import collective_bytes_from_hlo
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = collective_bytes_from_hlo(hlo)
    result["collective_bytes"] = coll["total"]
    result["collectives"] = coll["by_op"]
    if keep_hlo:
        result["hlo"] = hlo
    if verbose:
        print(f"[{arch} / {cell} @ {result['mesh']}] "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  flops={result['flops']:.3e} "
              f"bytes={result['bytes_accessed']:.3e} "
              f"collective_bytes={coll['total']:.3e}")
        if "temp_size_in_bytes" in result:
            print(f"  args={result.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"temp={result['temp_size_in_bytes']/2**30:.2f}GiB "
                  f"out={result.get('output_size_in_bytes', 0)/2**30:.2f}GiB")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--include-colbert", action="store_true",
                    help="also run the paper's own index/search cells")
    ap.add_argument("--json", default=None)
    ap.add_argument("--unroll", action="store_true",
                    help="unroll scans so cost_analysis counts every layer "
                         "(roofline analysis mode; slower compiles)")
    args = ap.parse_args(argv)

    archs = ([args.arch] if args.arch else
             ASSIGNED_ARCHS + (["colbertv2"] if args.include_colbert else []))
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results, failures = [], []
    for arch in archs:
        cells = [args.cell] if args.cell else all_cells(arch)
        for cell in cells:
            for mp in meshes:
                try:
                    results.append(run_cell(arch, cell, multi_pod=mp,
                                            unroll=args.unroll))
                except Exception as e:
                    traceback.print_exc()
                    failures.append({"arch": arch, "cell": cell,
                                     "multi_pod": mp, "error": repr(e)})
    print(f"\n=== dry-run: {len(results)} ok, {len(failures)} failed ===")
    for f in failures:
        print("FAILED:", f["arch"], f["cell"],
              "multi_pod" if f["multi_pod"] else "single_pod", f["error"])
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"results": results, "failures": failures}, fh,
                      indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
