"""Distributed training driver.

    python -m repro.launch.train --arch qwen3-0.6b --smoke --steps 50

Production posture: builds the production mesh, shards params/optimizer
with the same specs the dry-run validates, and runs the fault-tolerant
Trainer over the deterministic host-sharded pipeline. ``--smoke`` runs the
reduced config on local devices (what this CPU container can execute).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import TransformerConfig
from repro.data.pipeline import lm_batches
from repro.launch.mesh import (batch_axes, make_host_mesh,
                               make_production_mesh)
from repro.models.transformer import init_transformer, lm_loss
from repro.sharding.api import lm_rules, mesh_context
from repro.train.trainer import TrainConfig, Trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    assert isinstance(cfg, TransformerConfig), "LM driver"
    mesh = (make_host_mesh() if args.smoke
            else make_production_mesh(multi_pod=args.multi_pod))
    rules = lm_rules(batch_axes(mesh), attn_shard=cfg.attn_shard)

    params = init_transformer(jax.random.PRNGKey(0), cfg)

    def loss_fn(p, batch):
        loss, metrics = lm_loss(p, batch["tokens"], batch["labels"], cfg,
                                moe_impl="dense" if args.smoke
                                else "capacity")
        return loss, metrics

    tcfg = TrainConfig(total_steps=args.steps, lr=args.lr,
                       microbatches=args.microbatches,
                       checkpoint_dir=args.checkpoint_dir,
                       optimizer=cfg.optimizer,
                       checkpoint_every=max(args.steps // 4, 10))
    # synthetic token stream (deterministic)
    rng = np.random.default_rng(0)
    stream = rng.integers(
        0, cfg.vocab_size, args.batch * args.seq * (args.steps + 8) + 1
    ).astype(np.int32)
    batches = lm_batches(stream, args.batch, args.seq)

    with mesh, mesh_context(mesh, rules):
        trainer = Trainer(loss_fn, params, tcfg)
        if args.checkpoint_dir:
            trainer.maybe_restore()
        out = trainer.run(batches, hooks=lambda s, l, m: print(
            f"step {s}: loss {l:.4f}"))
    print(f"finished at step {out['final_step']}; "
          f"final loss {out['history'][-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
