"""Concurrent serving runtime: dynamic microbatching over the two-stage
retrieval engine, with shape-bucketed warmup and zero-downtime index
hot swap.

The paper's technique is a drop-in at indexation with no query-time
processing — so the serving path IS the deployment story. This module
turns "a script drives Searcher in a loop" into the runtime shape
ColBERTv2/PLAID-class systems actually deploy:

  * ``ServingEngine.submit`` is thread-safe and non-blocking: requests
    (1..n queries each) land on a queue and return a ``SearchFuture``.
  * A deadline-based dynamic batcher coalesces in-flight requests into
    microbatches, flushing when ``max_batch`` queries are ready or the
    OLDEST waiting request has aged ``max_wait_ms`` (per-flush reasons
    are counted: full / deadline / drain / k_switch).
  * Every coalesced batch pads up to the nearest SHAPE BUCKET
    {1, 2, 4, ..., max_batch}, all traced once at ``start()`` — a mixed
    stream of request sizes re-jits nothing (log-many executables,
    constant after warmup).
  * The two pipeline stages overlap: the batcher thread encodes batch
    N+1 while the search thread reranks batch N (encode is host+device
    bound, rerank device bound — the classic two-stage pipeline).
  * Scale-out: with ``n_replicas > 1`` the engine wraps the served
    index in replica groups (core/replicated.py) and runs one search
    lane per group — each staged microbatch routes whole to a lane
    (``search_batch_on``), so groups placed on different device rows
    rerank concurrently while results stay bitwise identical to lane 0.
  * The index is held behind a refcounted, double-buffered
    ``IndexHandle``. A watcher thread polls the artifact directory's
    monotonic ``generation`` (core/persist.py); a new generation is
    mmap-loaded and pre-warmed in the background, then swapped in
    atomically. In-flight batches finish on the old handle, which
    retires only after its last reader drains — zero dropped, zero
    failed queries across a swap.

Parity contract (pinned by tests/test_serving_engine.py): for every
request, the engine's (scores, ids) are BITWISE equal to a direct
``searcher.search(request_tokens, k)`` call — coalescing with other
requests, padding to a bucket, and hot-swapping an equivalent index
mid-stream change nothing. This holds because both stages are
row-independent AND width-stable: encoder rows are bitwise identical
at every padded power-of-two width, and MaxSim scores/top-k for row i
read only row i.
"""
from __future__ import annotations

import logging
import os
import threading
import time
from collections import deque
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

logger = logging.getLogger(__name__)


# ---------------------------------------------------------------------------
# Shape buckets
# ---------------------------------------------------------------------------
def shape_buckets(max_batch: int) -> List[int]:
    """Powers of two up to ``max_batch`` (plus ``max_batch`` itself when
    it is not a power of two): the traced-once microbatch shapes."""
    assert max_batch >= 1, max_batch
    out = []
    b = 1
    while b < max_batch:
        out.append(b)
        b <<= 1
    out.append(max_batch)
    return out


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest warm bucket that fits ``n`` queries."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"batch of {n} exceeds max bucket {buckets[-1]}")


# ---------------------------------------------------------------------------
# Compile-count probe (regression guard for the bucket cache)
# ---------------------------------------------------------------------------
_compile_events = [0]
_probe_installed = [False]
_probe_lock = threading.Lock()


def _install_probe() -> None:
    with _probe_lock:
        if _probe_installed[0]:
            return
        import jax.monitoring

        def _on_event(name, **kw):
            if name == "/jax/compilation_cache/compile_requests_use_cache":
                _compile_events[0] += 1

        jax.monitoring.register_event_listener(_on_event)
        _probe_installed[0] = True


class CompileCounter:
    """``with CompileCounter() as c: ...; c.count`` — number of XLA
    compilations the block triggered (jit cache hits don't count).
    Tests use it to pin "warm buckets => zero re-traces mid-stream"."""

    def __enter__(self) -> "CompileCounter":
        _install_probe()
        self._start = _compile_events[0]
        return self

    def __exit__(self, *exc) -> None:
        self.count = _compile_events[0] - self._start

    @property
    def so_far(self) -> int:
        return _compile_events[0] - self._start


# ---------------------------------------------------------------------------
# Futures
# ---------------------------------------------------------------------------
class SearchFuture:
    """Result slot for one submitted request (1..n queries).

    A request may span several microbatches (the batcher slices big
    requests at bucket boundaries); rows fill in as their batches
    complete and the future resolves when the last row lands.
    """

    def __init__(self, n_queries: int, k: int, submit_t: float):
        self.n_queries = n_queries
        self.k = k
        self.submit_t = submit_t
        self.done_t: Optional[float] = None
        self._scores = np.full((n_queries, k), -np.inf, np.float32)
        self._ids = np.full((n_queries, k), -1, np.int64)
        self._remaining = n_queries
        self._event = threading.Event()
        self._error: Optional[BaseException] = None
        self._lock = threading.Lock()

    # engine-side
    def _fill(self, lo: int, scores: np.ndarray, ids: np.ndarray) -> None:
        with self._lock:
            n = len(scores)
            self._scores[lo:lo + n] = scores
            self._ids[lo:lo + n] = ids
            self._remaining -= n
            if self._remaining == 0:
                self.done_t = time.perf_counter()
                self._event.set()

    def _fail(self, err: BaseException) -> None:
        with self._lock:
            self._error = err
            self.done_t = time.perf_counter()
            self._event.set()

    # caller-side
    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        if not self._event.wait(timeout):
            raise TimeoutError("search request not served in time")
        if self._error is not None:
            raise self._error
        return self._scores, self._ids

    @property
    def latency_s(self) -> float:
        assert self.done_t is not None, "not resolved yet"
        return self.done_t - self.submit_t


class _Slice:
    """Rows [lo, lo+n) of ``future`` riding in the current microbatch."""

    __slots__ = ("future", "lo", "n", "enqueue_t")

    def __init__(self, future: SearchFuture, lo: int, n: int,
                 enqueue_t: float):
        self.future = future
        self.lo = lo
        self.n = n
        self.enqueue_t = enqueue_t


# ---------------------------------------------------------------------------
# Double-buffered index handle (hot swap)
# ---------------------------------------------------------------------------
class IndexHandle:
    """Refcounted index reference: the unit the engine double-buffers.

    Readers ``acquire()`` before a batch and ``release()`` after; a
    swap ``retire()``s the old handle, which fires ``on_retire`` only
    once its reader count drains to zero — so an in-flight batch always
    finishes on the index it started with, and the old generation's
    resources are let go exactly when the last reader leaves.
    """

    def __init__(self, index, generation: int = 0,
                 on_retire: Optional[Callable[["IndexHandle"], None]] = None,
                 owned: bool = False):
        self.index = index
        self.generation = generation
        # owned=True means the ENGINE materialized this index (loaded it
        # from the watched directory) and may release its resources at
        # retirement; caller-provided indexes are never closed.
        self.owned = owned
        self._on_retire = on_retire
        self._readers = 0
        self._retired = False
        self._cond = threading.Condition()

    def acquire(self):
        with self._cond:
            self._readers += 1
            return self.index

    def release(self) -> None:
        fire = False
        with self._cond:
            self._readers -= 1
            assert self._readers >= 0
            if self._retired and self._readers == 0:
                fire = True
                self._cond.notify_all()
        if fire and self._on_retire is not None:
            self._on_retire(self)

    def retire(self) -> None:
        fire = False
        with self._cond:
            self._retired = True
            if self._readers == 0:
                fire = True
                self._cond.notify_all()
        if fire and self._on_retire is not None:
            self._on_retire(self)

    def wait_drained(self, timeout: Optional[float] = None) -> bool:
        with self._cond:
            return self._cond.wait_for(
                lambda: self._retired and self._readers == 0, timeout)


# ---------------------------------------------------------------------------
# Stats
# ---------------------------------------------------------------------------
class EngineStats:
    """Counters + samples the batcher/search threads append under a lock;
    ``snapshot()`` aggregates them for reports (BENCH_serve.json).

    Sample series are bounded sliding windows (`maxlen`), so a
    long-running engine's stats stay O(window), not O(uptime); the
    scalar counters cover the full lifetime."""

    WINDOW = 65536                          # most recent samples kept

    def __init__(self):
        self._lock = threading.Lock()
        self.submitted = 0
        self.served = 0
        self.failed = 0
        self.batches = 0
        self.flush_reasons = {"full": 0, "deadline": 0, "drain": 0,
                              "k_switch": 0}
        self.batch_sizes: deque = deque(maxlen=self.WINDOW)
        self.bucket_sizes: deque = deque(maxlen=self.WINDOW)
        self.queue_wait_s: deque = deque(maxlen=self.WINDOW)
        self.swaps = 0
        self.generations_seen: deque = deque(maxlen=self.WINDOW)
        self.replica_batches: dict = {}     # lane id -> batches served

    def record_batch(self, n_real: int, bucket: int, reason: str,
                     waits: List[float], generation: int,
                     replica: int = 0) -> None:
        with self._lock:
            self.batches += 1
            self.flush_reasons[reason] += 1
            self.batch_sizes.append(n_real)
            self.bucket_sizes.append(bucket)
            self.queue_wait_s.extend(waits)
            self.served += n_real
            self.generations_seen.append(generation)
            self.replica_batches[replica] = (
                self.replica_batches.get(replica, 0) + 1)

    def record_failed(self, n: int) -> None:
        with self._lock:
            self.failed += n

    def record_swap(self) -> None:
        with self._lock:
            self.swaps += 1

    def snapshot(self) -> dict:
        with self._lock:
            waits = np.asarray(self.queue_wait_s, np.float64)
            return {
                "submitted": self.submitted,
                "served": self.served,
                "failed": self.failed,
                "batches": self.batches,
                "flush_reasons": dict(self.flush_reasons),
                "mean_batch_size": (float(np.mean(self.batch_sizes))
                                    if self.batch_sizes else 0.0),
                "mean_bucket_size": (float(np.mean(self.bucket_sizes))
                                     if self.bucket_sizes else 0.0),
                "queue_wait_p50_ms": (float(np.percentile(waits, 50) * 1e3)
                                      if waits.size else 0.0),
                "queue_wait_p99_ms": (float(np.percentile(waits, 99) * 1e3)
                                      if waits.size else 0.0),
                "swaps": self.swaps,
                "generations_seen": list(self.generations_seen),
                "replica_batches": dict(self.replica_batches),
            }


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------
class ServingEngine:
    """Dynamic-batching, hot-swapping serving runtime over a Searcher.

    ``searcher`` provides the two stateless stages (``encode_queries``
    + an index with ``search_batch``); the engine owns threading,
    batching, shape management, and index lifecycle. The active index
    starts as ``searcher.index`` (or the artifact at ``index_dir``) and
    is thereafter owned by the engine's handle — hot swaps replace it
    without the searcher noticing.

    Use as a context manager::

        with ServingEngine(searcher, max_batch=32, max_wait_ms=2.0) as eng:
            fut = eng.submit(query_tokens)        # non-blocking
            scores, ids = fut.result()
    """

    def __init__(self, searcher, max_batch: int = 32,
                 max_wait_ms: float = 2.0, k: int = 10,
                 index_dir: Optional[str] = None,
                 poll_interval_s: float = 0.2,
                 warmup_on_start: bool = True,
                 pipeline_depth: Optional[int] = None,
                 index_generation: Optional[int] = None,
                 n_replicas: int = 1):
        self.searcher = searcher
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_ms) * 1e-3
        self.default_k = int(k)
        self.buckets = shape_buckets(self.max_batch)
        self.index_dir = index_dir
        self.poll_interval_s = float(poll_interval_s)
        self.warmup_on_start = warmup_on_start
        self.n_replicas = int(n_replicas)
        assert self.n_replicas >= 1, n_replicas

        # Gen-0 index. A caller who already loaded/built the artifact
        # passes ``index_generation`` (read when it materialized the
        # index) and ``searcher.index`` serves directly — no duplicate
        # copy. Otherwise, when watching a directory, read the
        # generation BEFORE loading, then serve the loaded copy: a
        # publish racing either window leaves the label stale-LOW, so
        # the watcher performs one redundant swap instead of silently
        # serving an old index under a new generation number forever.
        index = searcher.index
        gen = 0
        owned = False
        if index_generation is not None:
            gen = int(index_generation)
        elif index_dir is not None:
            from repro.core.persist import (IndexFormatError,
                                            artifact_generation,
                                            load_artifact)
            gen = artifact_generation(index_dir)
            if gen > 0:
                try:
                    index = load_artifact(index_dir, mmap=True)
                    owned = True
                except IndexFormatError:    # mid-publish: watcher retries
                    gen = 0
        index, owned = self._place(index, owned)
        self._handle = IndexHandle(index, generation=gen,
                                   on_retire=self._on_handle_retired,
                                   owned=owned)
        self._handle_lock = threading.Lock()

        self.stats = EngineStats()
        self._queue: deque = deque()        # of _Slice
        self._queue_cond = threading.Condition()
        self._staged: deque = deque()       # encoded batches, bounded
        self._staged_cond = threading.Condition()
        # pipeline depth: how many encoded batches may wait for the
        # search stage. 2 overlaps encode of batch N with rerank of
        # batch N-1 — a win when the host has compute headroom for
        # both stages; on <=2 cores the stages thrash each other's
        # XLA thread pools, so the default degrades to depth 1, which
        # runs BOTH stages inline on the batcher thread (no staged
        # handoff, two fewer wakeups per microbatch).
        if pipeline_depth is None:
            pipeline_depth = 2 if (os.cpu_count() or 1) >= 4 else 1
        self._staged_cap = max(int(pipeline_depth), 1)
        if self.n_replicas > 1:
            # the staged queue feeds every replica lane: it must hold at
            # least one batch per lane or lanes starve behind admission
            self._staged_cap = max(self._staged_cap, self.n_replicas)
        self._inline = self._staged_cap == 1
        self._stop = False
        self._abandon = False
        self._pending = 0       # batches popped but not yet resolved
        self._threads: List[threading.Thread] = []
        self._started = False

    @classmethod
    def from_spec(cls, searcher, spec=None, **kw) -> "ServingEngine":
        """Build an engine from a typed ``ServeSpec`` (core/spec.py) —
        the one config surface ``repro.Retriever.serve`` and the CLIs
        share. Extra ``**kw`` (``index_dir``, ``index_generation``)
        pass through to the constructor."""
        from repro.core.spec import ServeSpec
        spec = spec if spec is not None else ServeSpec()
        return cls(searcher, max_batch=spec.max_batch,
                   max_wait_ms=spec.max_wait_ms, k=spec.k,
                   poll_interval_s=spec.poll_interval_s,
                   warmup_on_start=spec.warmup_on_start,
                   pipeline_depth=spec.pipeline_depth,
                   n_replicas=getattr(spec, "n_replicas", 1), **kw)

    # ----------------------------------------------------------- placement
    def _place(self, index, owned: bool):
        """Wrap the served index in replica groups (core/replicated.py)
        when the engine routes across ``n_replicas`` lanes. Returns
        (index, owned): single-lane engines serve the index untouched;
        multi-lane engines serve a ``ReplicatedIndex`` whose wrapper the
        engine always owns (retiring it drops compiled plans; the inner
        index is only closed when the ORIGINAL was engine-loaded)."""
        if self.n_replicas == 1:
            return index, owned
        from repro.core.replicated import ReplicatedIndex
        if isinstance(index, ReplicatedIndex):
            return index, owned
        placed = ReplicatedIndex.replicate(index, self.n_replicas,
                                           own_inner=owned)
        return placed, True

    # ------------------------------------------------------------ lifecycle
    @property
    def generation(self) -> int:
        return self._handle.generation

    def start(self) -> "ServingEngine":
        assert not self._started, "engine already started"
        if self.warmup_on_start:
            self.warmup()
            if self._handle.index is not self.searcher.index:
                # __init__ loaded the served copy from index_dir:
                # searcher.warmup warmed the searcher's own index, so
                # drive the served copy's lazy caches too
                self._prewarm_index(self._handle.index)
        self._stop = False
        self._threads = [
            threading.Thread(target=self._batcher_loop,
                             name="engine-batcher", daemon=True),
        ]
        if not self._inline:
            # one search lane per replica: lane r serves its batches on
            # replica group r (search_batch_on), so groups placed on
            # different device rows rerank concurrently
            for r in range(self.n_replicas):
                self._threads.append(
                    threading.Thread(target=self._search_loop, args=(r,),
                                     name=f"engine-search-{r}",
                                     daemon=True))
        if self.index_dir is not None:
            self._threads.append(
                threading.Thread(target=self._watch_loop,
                                 name="engine-watcher", daemon=True))
        for t in self._threads:
            t.start()
        self._started = True
        return self

    def warmup(self) -> None:
        """Trace every shape bucket once — encoder widths, per-bucket
        search, and (via ``warm_shapes``) the candidate-width ladder."""
        self.searcher.warmup(self.buckets, k=self.default_k)

    def stop(self, drain: bool = True, timeout: float = 30.0) -> None:
        """Stop the engine (terminal: a watcher-loaded index's resources
        are released). ``drain=True`` serves everything already
        submitted first; ``drain=False`` abandons the backlog — pending
        requests are failed, only the in-flight batch completes."""
        if not self._started:
            return
        if drain:
            # _pending covers a batch from pop until its futures
            # resolve — without it, a batch mid-encode is invisible to
            # both the queue and staged checks and would be swept as
            # failed despite drain=True
            with self._queue_cond:
                self._queue_cond.wait_for(
                    lambda: not self._queue and self._pending == 0,
                    timeout=timeout)
        else:
            self._abandon = True        # batcher exits without draining
        self._stop = True
        with self._queue_cond:
            self._queue_cond.notify_all()
        with self._staged_cond:
            self._staged_cond.notify_all()
        for t in self._threads:
            t.join(timeout=timeout)
        err = RuntimeError("engine stopped before request was served")
        swept = list(self._queue) + [sl for staged in self._staged
                                     for sl in staged[3]]
        for sl in swept:
            sl.future._fail(err)
        if swept:                       # dropped rows count as failures
            self.stats.record_failed(sum(sl.n for sl in swept))
        self._queue.clear()
        self._staged.clear()
        self._pending = 0               # threads joined: nothing in flight
        self._started = False
        if self._handle.owned:          # release watcher-loaded resources
            self._handle.retire()

    def __enter__(self) -> "ServingEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -------------------------------------------------------------- submit
    def submit(self, query_tokens: np.ndarray,
               k: Optional[int] = None) -> SearchFuture:
        """Enqueue 1..n queries ([L] or [n, L] token ids); returns a
        ``SearchFuture``. Thread-safe, non-blocking."""
        assert self._started, "engine not started"
        q = np.asarray(query_tokens)
        if q.ndim == 1:                     # [L] -> [1, L]
            q = q[None]
        kk = self.default_k if k is None else int(k)
        now = time.perf_counter()
        fut = SearchFuture(len(q), kk, submit_t=now)
        fut._tokens = q                     # carried to the batcher
        with self._queue_cond:
            self.stats.submitted += len(q)
            self._queue.append(_Slice(fut, 0, len(q), now))
            self._queue_cond.notify_all()
        return fut

    def search(self, query_tokens: np.ndarray, k: Optional[int] = None,
               timeout: Optional[float] = 60.0
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Blocking convenience: submit + wait."""
        return self.submit(query_tokens, k=k).result(timeout=timeout)

    # ---------------------------------------------------------- hot swap
    def swap_index(self, new_index, generation: Optional[int] = None,
                   owned: bool = False) -> IndexHandle:
        """Install ``new_index`` atomically; returns the RETIRING handle
        (callers/tests can ``wait_drained`` on it). In-flight batches
        finish on the old index; new batches acquire the new one.
        ``owned=True`` (watcher loads) lets the engine release the
        index's resources when ITS handle later retires."""
        with self._handle_lock:
            old = self._handle
            gen = old.generation + 1 if generation is None else generation
            new_index, owned = self._place(new_index, owned)
            self._handle = IndexHandle(new_index, generation=gen,
                                       on_retire=self._on_handle_retired,
                                       owned=owned)
        self.stats.record_swap()
        old.retire()
        return old

    def _on_handle_retired(self, handle: IndexHandle) -> None:
        if handle.owned:                # engine-loaded: release resources
            close = getattr(handle.index, "close", None)
            if close is not None:       # e.g. ShardedIndex probe pool
                close()
        logger.info("index generation %d drained and retired",
                    handle.generation)

    def _watch_loop(self) -> None:
        """Poll ``index_dir`` for a newer generation; load + pre-warm it
        off the serving path, then swap."""
        from repro.core.persist import artifact_generation, load_artifact
        while not self._stop:
            time.sleep(self.poll_interval_s)
            try:
                gen = artifact_generation(self.index_dir)
                if gen <= self._handle.generation:
                    continue
                new_index = load_artifact(self.index_dir, mmap=True)
                # place BEFORE prewarm so every replica lane is warm the
                # moment the swap lands (swap_index's _place is then a
                # no-op on the already-wrapped index)
                new_index, owned = self._place(new_index, True)
                self._prewarm_index(new_index)
                self.swap_index(new_index, generation=gen, owned=owned)
            except Exception:               # noqa: BLE001 — keep serving
                logger.exception("hot-swap attempt failed; serving "
                                 "continues on generation %d",
                                 self._handle.generation)

    def _prewarm_index(self, index) -> None:
        """Run each bucket shape through the NEW index before it takes
        traffic: builds its padded device views / lazy caches so the
        first post-swap batch pays no cold-start latency."""
        cfg = getattr(self.searcher, "cfg", None)
        if cfg is None:                     # minimal searchers skip prewarm
            return
        L = cfg.query_maxlen - 2
        enc1 = self.searcher.encode_queries(np.ones((1, L), np.int32))
        # multi-lane engines warm EVERY replica lane (a lane that first
        # traces mid-stream would break the no-retrace contract); the
        # single-lane path keeps the long-standing per-bucket search
        warm = (getattr(index, "warm_shapes", None)
                if self.n_replicas > 1 else None)
        for b in self.buckets:
            qb = np.broadcast_to(enc1, (b,) + enc1.shape[1:])
            if warm is not None:
                warm(qb, k=self.default_k)
            else:
                index.search_batch(qb, k=self.default_k)

    # ------------------------------------------------------------- batcher
    def _pop_coalesced(self):
        """Block for the first waiting slice, then coalesce until the
        batch is full, the oldest request's deadline lapses, or the next
        request's k differs. Returns (slices, reason) or None on stop."""
        with self._queue_cond:
            if not self._queue_cond.wait_for(
                    lambda: self._queue or self._stop, timeout=0.1):
                return None
            if self._stop and (self._abandon or not self._queue):
                return None          # abandoned backlog: stop() sweeps it
            head = self._queue[0]
            # The clock starts when the batcher is actually free to
            # flush (admission control may have held it while the
            # pipeline was full): a request that already waited out its
            # deadline behind a slow batch still gets a real coalescing
            # window now — its staged batch could not have started any
            # sooner anyway, so this adds batching, not latency.
            deadline = max(head.enqueue_t, time.perf_counter()
                           - self.max_wait_s * 0.5) + self.max_wait_s
            batch: List[_Slice] = []
            total = 0
            kk = head.future.k
            reason = None
            while True:
                while self._queue and total < self.max_batch:
                    sl = self._queue[0]
                    if sl.future.k != kk:
                        reason = "k_switch"
                        break
                    room = self.max_batch - total
                    if sl.n <= room:
                        batch.append(self._queue.popleft())
                        total += sl.n
                    else:                   # split: rows [lo, lo+room)
                        part = _Slice(sl.future, sl.lo, room, sl.enqueue_t)
                        sl.lo += room
                        sl.n -= room
                        batch.append(part)
                        total += room
                if reason == "k_switch":
                    break
                if total >= self.max_batch:
                    reason = "full"
                    break
                now = time.perf_counter()
                if now >= deadline or self._stop:
                    reason = "drain" if self._stop else "deadline"
                    break
                self._queue_cond.wait(timeout=min(deadline - now, 0.05))
            if batch:
                self._pending += 1      # resolved in _batch_done
            if not self._queue:
                self._queue_cond.notify_all()   # wake stop(drain=True)
            return batch, kk, reason

    def _batch_done(self) -> None:
        with self._queue_cond:
            self._pending -= 1
            self._queue_cond.notify_all()       # wake stop(drain=True)

    def _batcher_loop(self) -> None:
        while True:
            # Admission control: coalesce ONLY when the pipeline can
            # accept the batch. While the search stage is busy, waiting
            # requests stay in the queue where late arrivals can still
            # join them — so under backlog, flushes fill toward
            # max_batch instead of staging half-full padded batches the
            # device would serve at full-bucket cost. (Single batcher
            # thread, so the room observed here cannot be stolen.)
            with self._staged_cond:
                if not self._staged_cond.wait_for(
                        lambda: len(self._staged) < self._staged_cap
                        or self._stop, timeout=0.1):
                    continue
                if self._stop and not self._queue:
                    return
            popped = self._pop_coalesced()
            if popped is None:
                if self._stop:
                    return
                continue
            batch, kk, reason = popped
            if not batch:
                continue
            try:
                toks = np.concatenate(
                    [sl.future._tokens[sl.lo:sl.lo + sl.n] for sl in batch])
                t_dequeue = time.perf_counter()
                waits = [t_dequeue - sl.enqueue_t for sl in batch]
                enc = self.searcher.encode_queries(toks)
                n = len(enc)
                bucket = bucket_for(n, self.buckets)
                if bucket > n:
                    # pad up to the warm shape by REPEATING the last
                    # real row: stage 1 candidate generation then does
                    # normal work for the pad rows (an all-zero query
                    # can blow up threshold-based probing), and row
                    # independence keeps the real rows bit-identical
                    enc = np.concatenate(
                        [enc, np.broadcast_to(enc[-1:],
                                              (bucket - n,) + enc.shape[1:])])
                staged = (enc, n, kk, batch, reason, waits)
            except BaseException as e:      # noqa: BLE001
                for sl in batch:
                    sl.future._fail(e)
                self.stats.record_failed(sum(sl.n for sl in batch))
                self._batch_done()
                continue
            if self._inline:                # depth 1: no handoff at all
                self._serve_staged(staged)
                continue
            with self._staged_cond:
                self._staged.append(staged)     # room reserved above
                self._staged_cond.notify_all()

    # -------------------------------------------------------------- search
    def _serve_staged(self, staged, replica: int = 0) -> None:
        """Run stage 2 for one encoded microbatch and resolve its
        futures (called from a search lane thread, or inline from the
        batcher at pipeline depth 1). ``replica`` picks the lane a
        routed index serves this batch on — every lane is bitwise
        identical, so routing is purely a throughput decision."""
        enc, n, kk, batch, reason, waits = staged
        try:
            with self._handle_lock:
                handle = self._handle
                index = handle.acquire()
            try:
                search_on = getattr(index, "search_batch_on", None)
                if search_on is not None:
                    S, I = search_on(replica, enc, k=kk)
                else:
                    S, I = index.search_batch(enc, k=kk)
            except BaseException as e:      # noqa: BLE001
                for sl in batch:
                    sl.future._fail(e)
                self.stats.record_failed(sum(sl.n for sl in batch))
                return
            finally:
                handle.release()
            S, I = np.asarray(S)[:n], np.asarray(I)[:n]
            lo = 0
            for sl in batch:
                sl.future._fill(sl.lo, S[lo:lo + sl.n], I[lo:lo + sl.n])
                lo += sl.n
            self.stats.record_batch(n, len(enc), reason, waits,
                                    handle.generation, replica=replica)
        finally:
            self._batch_done()

    def _search_loop(self, replica: int = 0) -> None:
        while True:
            with self._staged_cond:
                if not self._staged_cond.wait_for(
                        lambda: self._staged or self._stop, timeout=0.1):
                    continue
                if not self._staged:
                    if self._stop:
                        return
                    continue
                staged = self._staged.popleft()
                self._staged_cond.notify_all()
            self._serve_staged(staged, replica=replica)


# ---------------------------------------------------------------------------
# Open-loop load generation (Poisson arrivals)
# ---------------------------------------------------------------------------
def run_open_loop(engine: ServingEngine, q_tokens: np.ndarray,
                  arrival_qps: float, n_queries: int, k: int = 10,
                  seed: int = 0,
                  on_halfway: Optional[Callable[[], None]] = None,
                  collect_results: bool = False) -> dict:
    """Fire ``n_queries`` single-query requests at the engine with
    Poisson (exponential inter-arrival) timing and wait for all results.

    Closed-loop replay hides queueing: the next query only leaves when
    the previous returns, so reported percentiles are *service* time.
    Open-loop arrivals measure what a user sees at a given offered load
    — queue wait included — which is the number tail-latency SLOs are
    written against. Returns achieved QPS + end-to-end latency
    percentiles (batcher internals live in ``engine.stats``).

    ``on_halfway`` fires once, mid-stream — benchmarks use it to
    republish the index and exercise a hot swap under load.
    ``collect_results`` adds a ``results`` list of per-request
    ``(scores, ids)`` (None where a request errored) so callers can
    assert parity against a direct ``search_batch``.

    Arrivals are scheduled at ABSOLUTE times; the submitter sleeps to
    the next scheduled arrival and then drains every due arrival in a
    catch-up loop, so a host stall delays a burst but never lowers the
    offered rate. Latency is measured from each request's *scheduled*
    arrival, so submitter lateness counts against the tail instead of
    being coordinated-omission'd away.
    """
    rng = np.random.default_rng(seed)
    sched = np.cumsum(rng.exponential(1.0 / arrival_qps, size=n_queries))
    futs: List[Optional[SearchFuture]] = [None] * n_queries
    t0 = time.perf_counter()
    i = 0
    while i < n_queries:
        delay = (t0 + sched[i]) - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        now = time.perf_counter() - t0
        while i < n_queries and sched[i] <= now:    # catch-up burst
            # fired inside the burst loop: a stall-induced burst that
            # submits through the halfway point must not skip it
            if on_halfway is not None and i >= n_queries // 2:
                on_halfway()
                on_halfway = None
            futs[i] = engine.submit(q_tokens[i % len(q_tokens)][None],
                                    k=k)
            i += 1
    errors = 0
    lat = []
    results = []
    for i, f in enumerate(futs):
        try:
            results.append(f.result(timeout=120.0))
            lat.append(f.done_t - (t0 + sched[i]))
        except Exception:                   # noqa: BLE001
            results.append(None)
            errors += 1
    wall = time.perf_counter() - t0
    lat_ms = np.asarray(lat, np.float64) * 1e3
    out = {
        "arrival_qps": float(arrival_qps),
        "n_queries": int(n_queries),
        "errors": int(errors),
        "achieved_qps": float(len(lat) / wall) if wall > 0 else 0.0,
        "latency_p50_ms": (float(np.percentile(lat_ms, 50))
                           if lat_ms.size else 0.0),
        "latency_p99_ms": (float(np.percentile(lat_ms, 99))
                           if lat_ms.size else 0.0),
        "latency_mean_ms": (float(lat_ms.mean()) if lat_ms.size else 0.0),
    }
    if collect_results:
        out["results"] = results
    return out
