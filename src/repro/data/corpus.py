"""Synthetic topical retrieval corpora with BEIR-like relevance structure.

Offline container => no BEIR/LoTTe/MS-Marco. We synthesize corpora whose
*relative* measurements reproduce the paper's experimental geometry:

  * T latent topics, each with a Zipf-weighted private vocabulary plus a
    shared common-word pool (so token vectors within a doc are partially
    redundant — the redundancy token pooling exploits).
  * Documents sample one primary topic (+ optional secondary) and draw
    words from the mixed distribution.
  * Queries are generated FROM a source document (salient private words),
    giving graded qrels: source doc rel=2, same-topic docs rel=1.

``DATASET_SPECS`` defines several named datasets with different sizes,
doc lengths and vocab-overlap levels, mirroring the paper's small/mid BEIR
mix (scifact/scidocs/nfcorpus/fiqa/trec-covid/touche + LoTTe splits) plus
two "Japanese" analogues (different token-length statistics, doc_len=300).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

from repro.data.tokenizer import FIRST_WORD_ID


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_docs: int = 512
    n_queries: int = 64
    n_topics: int = 16
    doc_len_mean: int = 120
    doc_len_std: int = 40
    query_len: Tuple[int, int] = (4, 10)
    private_vocab: int = 400       # words per topic
    common_vocab: int = 1200       # shared pool
    common_frac: float = 0.45      # fraction of doc words from common pool
    zipf_a: float = 1.3
    secondary_topic_frac: float = 0.25
    seed: int = 0


# Named datasets standing in for the paper's evaluation mix.
DATASET_SPECS: Dict[str, DatasetSpec] = {
    # BEIR-like (small)
    "scifact": DatasetSpec("scifact", n_docs=600, n_queries=80, n_topics=20,
                           doc_len_mean=160, common_frac=0.35, seed=101),
    "scidocs": DatasetSpec("scidocs", n_docs=800, n_queries=80, n_topics=24,
                           doc_len_mean=140, common_frac=0.5, seed=102),
    "nfcorpus": DatasetSpec("nfcorpus", n_docs=500, n_queries=72,
                            n_topics=14, doc_len_mean=180,
                            common_frac=0.4, seed=103),
    "fiqa": DatasetSpec("fiqa", n_docs=900, n_queries=96, n_topics=30,
                        doc_len_mean=110, common_frac=0.55,
                        query_len=(3, 7), seed=104),
    # BEIR-like (mid, quantized-only in the paper)
    "trec-covid": DatasetSpec("trec-covid", n_docs=1200, n_queries=64,
                              n_topics=18, doc_len_mean=200,
                              common_frac=0.45, seed=105),
    "touche": DatasetSpec("touche", n_docs=1000, n_queries=64, n_topics=12,
                          doc_len_mean=220, common_frac=0.65, seed=106),
    # LoTTe-like
    "lotte-writing": DatasetSpec("lotte-writing", n_docs=900, n_queries=96,
                                 n_topics=26, doc_len_mean=100,
                                 common_frac=0.5, seed=107),
    "lotte-recreation": DatasetSpec("lotte-recreation", n_docs=900,
                                    n_queries=96, n_topics=26,
                                    doc_len_mean=90, common_frac=0.5,
                                    seed=108),
    "lotte-lifestyle": DatasetSpec("lotte-lifestyle", n_docs=900,
                                   n_queries=96, n_topics=26,
                                   doc_len_mean=95, common_frac=0.5,
                                   seed=109),
    # Japanese analogues (longer docs, denser tokenization)
    "jsquad": DatasetSpec("jsquad", n_docs=700, n_queries=80, n_topics=22,
                          doc_len_mean=240, doc_len_std=50,
                          common_frac=0.4, seed=110),
    "miracl-ja": DatasetSpec("miracl-ja", n_docs=800, n_queries=80,
                             n_topics=24, doc_len_mean=260, doc_len_std=60,
                             common_frac=0.45, seed=111),
}


class SyntheticRetrievalCorpus:
    """Token-id documents + queries + graded qrels for one DatasetSpec."""

    def __init__(self, spec: DatasetSpec, vocab_size: int = 30522):
        self.spec = spec
        rng = np.random.default_rng(spec.seed)
        nw = vocab_size - FIRST_WORD_ID
        # carve disjoint private vocabularies + a common pool out of word
        # ids; scale the pools down proportionally for small test vocabs
        need = spec.n_topics * spec.private_vocab + spec.common_vocab
        scale = min(1.0, nw / need)
        private_vocab = max(8, int(spec.private_vocab * scale))
        common_vocab = max(16, int(spec.common_vocab * scale))
        perm = rng.permutation(nw)[:spec.n_topics * private_vocab
                                   + common_vocab] + FIRST_WORD_ID
        self.common = perm[:common_vocab]
        priv = perm[common_vocab:]
        self.topics = priv.reshape(spec.n_topics, private_vocab)
        spec = DatasetSpec(**{**spec.__dict__,
                              "private_vocab": private_vocab,
                              "common_vocab": common_vocab})
        self.spec = spec
        # Zipf weights (shared shape; per-topic word identity differs)
        ranks = np.arange(1, private_vocab + 1)
        w = ranks ** (-spec.zipf_a)
        self.zipf_p = w / w.sum()
        rc = np.arange(1, spec.common_vocab + 1)
        wc = rc ** (-spec.zipf_a)
        self.zipf_c = wc / wc.sum()

        self.doc_topic = rng.integers(0, spec.n_topics, spec.n_docs)
        self.docs: List[np.ndarray] = []
        for i in range(spec.n_docs):
            L = max(16, int(rng.normal(spec.doc_len_mean, spec.doc_len_std)))
            t = self.doc_topic[i]
            n_common = int(L * spec.common_frac)
            n_priv = L - n_common
            words = [rng.choice(self.topics[t], n_priv, p=self.zipf_p),
                     rng.choice(self.common, n_common, p=self.zipf_c)]
            if rng.random() < spec.secondary_topic_frac:
                t2 = rng.integers(0, spec.n_topics)
                n2 = n_priv // 4
                words.append(rng.choice(self.topics[t2], n2, p=self.zipf_p))
            doc = np.concatenate(words)
            rng.shuffle(doc)
            self.docs.append(doc.astype(np.int32))

        # queries from source docs: salient (low-rank) private words
        self.queries: List[np.ndarray] = []
        self.qrels: List[Dict[int, int]] = []
        src_docs = rng.choice(spec.n_docs, spec.n_queries, replace=False)
        for d in src_docs:
            t = self.doc_topic[d]
            qlen = rng.integers(*spec.query_len)
            doc_words = self.docs[d]
            priv_words = doc_words[np.isin(doc_words, self.topics[t])]
            if len(priv_words) == 0:
                priv_words = self.topics[t][:8]
            q = rng.choice(priv_words, min(qlen, len(priv_words)),
                           replace=False)
            self.queries.append(q.astype(np.int32))
            rel = {int(d): 2}
            same = np.nonzero(self.doc_topic == t)[0]
            overlap_scores = []
            qset = set(int(x) for x in q)
            for s in same:
                if s == d:
                    continue
                ov = len(qset & set(int(x) for x in self.docs[s]))
                overlap_scores.append((ov, int(s)))
            overlap_scores.sort(reverse=True)
            for ov, s in overlap_scores[:10]:
                if ov > 0:
                    rel[s] = 1
            self.qrels.append(rel)

    # ------------------------------------------------------------- batching
    def doc_token_batch(self, maxlen: int) -> np.ndarray:
        out = np.zeros((len(self.docs), maxlen), np.int32)
        for i, d in enumerate(self.docs):
            k = min(len(d), maxlen)
            out[i, :k] = d[:k]
        return out

    def query_token_batch(self, maxlen: int) -> np.ndarray:
        out = np.zeros((len(self.queries), maxlen), np.int32)
        for i, q in enumerate(self.queries):
            k = min(len(q), maxlen)
            out[i, :k] = q[:k]
        return out

    def train_pairs(self, n: int, seed: int = 0):
        """(query_tokens, positive_doc_id) pairs for contrastive training."""
        rng = np.random.default_rng(seed)
        qs, ds = [], []
        for _ in range(n):
            d = int(rng.integers(0, self.spec.n_docs))
            t = self.doc_topic[d]
            doc_words = self.docs[d]
            priv = doc_words[np.isin(doc_words, self.topics[t])]
            if len(priv) == 0:
                priv = doc_words
            qlen = int(rng.integers(*self.spec.query_len))
            q = rng.choice(priv, min(qlen, len(priv)), replace=False)
            qs.append(q.astype(np.int32))
            ds.append(d)
        return qs, np.asarray(ds)
