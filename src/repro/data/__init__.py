from repro.data.tokenizer import HashTokenizer
from repro.data.corpus import SyntheticRetrievalCorpus, DATASET_SPECS
from repro.data.pipeline import DataPipeline, lm_batches

__all__ = ["HashTokenizer", "SyntheticRetrievalCorpus", "DATASET_SPECS",
           "DataPipeline", "lm_batches"]
