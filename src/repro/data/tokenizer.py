"""Deterministic hash-vocabulary tokenizer.

No pretrained vocab files exist offline, so the tokenizer maps words to ids
with a stable FNV-1a hash. Vocabulary layout (shared with models/colbert.py):

    0..7    special:  [PAD] [CLS] [SEP] [MASK] [Q] [D] [UNK] [BOS]
    8..23   punctuation bucket (ColBERT's doc skiplist masks these)
    24..V   hashed word ids

Deterministic across processes/runs — the multi-host pipeline relies on it.
"""
from __future__ import annotations

import re
from typing import List

import numpy as np

PAD_ID, CLS_ID, SEP_ID, MASK_ID, Q_MARK_ID, D_MARK_ID, UNK_ID, BOS_ID = \
    range(8)
N_SPECIAL = 8
N_PUNCT = 16
FIRST_WORD_ID = N_SPECIAL + N_PUNCT

_PUNCT = ".,;:!?()[]{}\"'`-—/\\"
_TOKEN_RE = re.compile(r"[\w]+|[^\w\s]")


def _fnv1a(s: str) -> int:
    h = 0xcbf29ce484222325
    for b in s.encode("utf-8"):
        h ^= b
        h = (h * 0x100000001b3) & 0xFFFFFFFFFFFFFFFF
    return h


class HashTokenizer:
    def __init__(self, vocab_size: int = 30522):
        assert vocab_size > FIRST_WORD_ID + 1
        self.vocab_size = vocab_size
        self.n_words = vocab_size - FIRST_WORD_ID

    def word_id(self, w: str) -> int:
        return FIRST_WORD_ID + _fnv1a(w.lower()) % self.n_words

    def punct_id(self, ch: str) -> int:
        i = _PUNCT.find(ch)
        return N_SPECIAL + (i % N_PUNCT if i >= 0 else 0)

    def encode(self, text: str, max_len: int | None = None) -> List[int]:
        ids = []
        for tok in _TOKEN_RE.findall(text):
            if tok[0].isalnum() or tok[0] == "_":
                ids.append(self.word_id(tok))
            else:
                ids.append(self.punct_id(tok[0]))
            if max_len and len(ids) >= max_len:
                break
        return ids

    def encode_batch(self, texts: List[str], max_len: int) -> np.ndarray:
        out = np.zeros((len(texts), max_len), np.int32)
        for i, t in enumerate(texts):
            ids = self.encode(t, max_len)
            out[i, :len(ids)] = ids
        return out
