"""Host-sharded deterministic data pipeline with background prefetch.

Multi-host posture: every host computes the SAME epoch permutation from the
(seed, epoch) pair and takes its ``process_index``-strided slice, so no
host-to-host coordination is needed and restarts are deterministic given
(seed, step) — the trainer checkpoints the step counter and the pipeline
fast-forwards. Prefetch is a small thread + queue to overlap host batch
assembly with device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np


class DataPipeline:
    def __init__(self, n_examples: int, batch_size: int,
                 make_batch: Callable[[np.ndarray], Dict],
                 seed: int = 0, shard_index: Optional[int] = None,
                 shard_count: Optional[int] = None, prefetch: int = 2,
                 drop_remainder: bool = True):
        """make_batch: maps example-id array [B] -> batch dict of arrays."""
        self.n = n_examples
        self.bs = batch_size
        self.make_batch = make_batch
        self.seed = seed
        self.shard_index = (shard_index if shard_index is not None
                            else jax.process_index())
        self.shard_count = (shard_count if shard_count is not None
                            else jax.process_count())
        self.prefetch = prefetch
        self.drop_remainder = drop_remainder

    def _epoch_ids(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, epoch))
        perm = rng.permutation(self.n)
        return perm[self.shard_index::self.shard_count]

    def batches(self, start_step: int = 0) -> Iterator[Dict]:
        """Infinite batch iterator, fast-forwarded to ``start_step``."""
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def produce():
            step = 0
            epoch = 0
            while not stop.is_set():
                ids = self._epoch_ids(epoch)
                nb = len(ids) // self.bs
                for b in range(nb):
                    if step >= start_step:
                        batch = self.make_batch(
                            ids[b * self.bs:(b + 1) * self.bs])
                        while not stop.is_set():
                            try:
                                q.put((step, batch), timeout=0.5)
                                break
                            except queue.Full:
                                continue
                    step += 1
                epoch += 1

        t = threading.Thread(target=produce, daemon=True)
        t.start()
        try:
            while True:
                step, batch = q.get()
                yield batch
        finally:
            stop.set()


def lm_batches(tokens: np.ndarray, batch_size: int, seq_len: int,
               seed: int = 0, start_step: int = 0) -> Iterator[Dict]:
    """Fixed-shape causal-LM batches from a flat token stream.

    tokens: [N] int32. Yields {tokens [B, S], labels [B, S]} (labels are
    tokens shifted left; last position predicts the next stream token).
    """
    n_seq = (len(tokens) - 1) // seq_len

    def make(ids):
        b_tok = np.stack([tokens[i * seq_len:(i + 1) * seq_len]
                          for i in ids])
        b_lab = np.stack([tokens[i * seq_len + 1:(i + 1) * seq_len + 1]
                          for i in ids])
        return {"tokens": b_tok.astype(np.int32),
                "labels": b_lab.astype(np.int32)}

    pipe = DataPipeline(n_seq, batch_size, make, seed=seed)
    return pipe.batches(start_step=start_step)
