"""Fault-tolerant distributed trainer.

Features (the 1000+-node posture, exercised single-device in tests):

  * gradient accumulation over microbatches (``lax.scan`` inside one jit,
    so the all-reduce of microbatch i overlaps compute of i+1 under XLA's
    latency-hiding scheduler),
  * global-norm clipping, bf16 compute / f32 params + optimizer,
  * periodic + async checkpointing via CheckpointManager,
  * crash/restart: ``run`` resumes from the latest checkpoint and
    fast-forwards the deterministic data pipeline,
  * transient-failure retry: a step that raises is retried; after
    ``max_retries`` the trainer restores the last good checkpoint and
    continues (straggler/failed-node analogue in a single-process world),
  * NaN-loss quarantine: a non-finite loss skips the update (the batch is
    effectively dropped) — standard large-run hygiene.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import Optimizer, make_optimizer


@dataclass
class TrainConfig:
    total_steps: int = 100
    microbatches: int = 1             # grad accumulation factor
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    max_retries: int = 2
    log_every: int = 10
    lr: float = 3e-4
    warmup: int = 10
    optimizer: str = "adamw"
    skip_nonfinite: bool = True


class Trainer:
    def __init__(self, loss_fn: Callable, params, tcfg: TrainConfig,
                 opt: Optional[Optimizer] = None, donate: bool = False):
        # ``donate=True`` donates (params, opt_state) buffers to the jitted
        # step (halves peak HBM in production); leave off when the caller
        # still holds references (tests, notebooks).
        """loss_fn(params, batch) -> (loss, metrics dict)."""
        from repro.train.optimizer import cosine_schedule
        self.tcfg = tcfg
        self.opt = opt or make_optimizer(
            tcfg.optimizer, cosine_schedule(tcfg.lr, tcfg.warmup,
                                            tcfg.total_steps))
        self.params = params
        self.opt_state = self.opt.init(params)
        self.step = 0
        self.ckpt = (CheckpointManager(tcfg.checkpoint_dir)
                     if tcfg.checkpoint_dir else None)
        self.loss_fn = loss_fn
        self._jit_step = jax.jit(
            self._train_step,
            donate_argnums=(0, 1) if donate else ())

    # ------------------------------------------------------------ step fn
    def _train_step(self, params, opt_state, batch):
        n_micro = self.tcfg.microbatches

        def micro_loss(p, mb):
            (loss, metrics), grads = jax.value_and_grad(
                self.loss_fn, has_aux=True)(p, mb)
            return loss, grads, metrics

        if n_micro == 1:
            loss, grads, metrics = micro_loss(params, batch)
        else:
            # split leading batch dim into microbatches and scan-accumulate
            def reshape(x):
                b = x.shape[0]
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])
            micro = jax.tree_util.tree_map(reshape, batch)

            def body(carry, mb):
                acc_loss, acc_grads = carry
                loss, grads, metrics = micro_loss(params, mb)
                acc_grads = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32),
                    acc_grads, grads)
                return (acc_loss + loss, acc_grads), metrics

            zero_grads = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), metrics = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero_grads), micro)
            loss = loss / n_micro
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)

        finite = jnp.isfinite(loss)
        new_params, new_opt_state = self.opt.update(params, grads, opt_state)
        if self.tcfg.skip_nonfinite:
            new_params = jax.tree_util.tree_map(
                lambda n, o: jnp.where(finite, n, o), new_params, params)
            new_opt_state = jax.tree_util.tree_map(
                lambda n, o: jnp.where(finite, n, o),
                new_opt_state, opt_state)
        return new_params, new_opt_state, loss, metrics

    # ------------------------------------------------------------ running
    def maybe_restore(self) -> int:
        if self.ckpt and self.ckpt.latest_step() is not None:
            step, tree, extra = self.ckpt.restore()
            self.params = jax.tree_util.tree_map(
                jnp.asarray, tree["params"])
            self.opt_state = jax.tree_util.tree_map(
                jnp.asarray, tree["opt_state"])
            self.step = step
        return self.step

    def save(self) -> None:
        if self.ckpt:
            self.ckpt.save(self.step, {"params": self.params,
                                       "opt_state": self.opt_state})

    def run(self, batches: Iterator[Dict],
            hooks: Optional[Callable] = None) -> Dict[str, Any]:
        history = []
        t0 = time.time()
        last_good = self.step
        while self.step < self.tcfg.total_steps:
            batch = next(batches)
            batch = jax.tree_util.tree_map(jnp.asarray, batch)
            retries = 0
            while True:
                try:
                    (self.params, self.opt_state, loss,
                     metrics) = self._jit_step(self.params, self.opt_state,
                                               batch)
                    break
                except Exception:                      # transient failure
                    retries += 1
                    if retries > self.tcfg.max_retries:
                        if self.ckpt and self.ckpt.latest_step() is not None:
                            self.maybe_restore()       # roll back
                            last_good = self.step
                            retries = 0
                        else:
                            raise
            self.step += 1
            if self.step % self.tcfg.log_every == 0 or \
                    self.step == self.tcfg.total_steps:
                lv = float(loss)
                history.append({"step": self.step, "loss": lv,
                                "time": time.time() - t0})
                if hooks:
                    hooks(self.step, lv, metrics)
            if self.ckpt and self.step % self.tcfg.checkpoint_every == 0:
                self.save()
                last_good = self.step
        if self.ckpt:
            self.save()
            self.ckpt.wait()
        return {"history": history, "final_step": self.step,
                "last_good": last_good}
