"""Fault-tolerant checkpointing: atomic shard writes + manifest, and
RESHARDING restore (elastic N -> M devices).

Layout per step:
    <dir>/step_<N>.tmp/            (write in progress)
        shard_<i>.npz              (flat path -> array chunks)
        manifest.json              (paths, shapes, dtypes, step, extra)
    <dir>/step_<N>/                (atomic os.replace when complete)

Arrays are written as HOST numpy (fully replicated view), so restore can
device_put onto ANY mesh/sharding — the elastic-rescale path. Writes go
through a background thread (async checkpointing: the train loop donates a
host copy and keeps stepping). A ``latest`` marker enables restart-on-crash.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.models.layers import tree_paths


def _unflatten(flat: Dict[str, np.ndarray]):
    tree: Dict[str, Any] = {}
    for path, arr in flat.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return _listify(tree)


def _listify(node):
    """Convert dict nodes whose keys are 0..n-1 back into lists."""
    if not isinstance(node, dict):
        return node
    out = {k: _listify(v) for k, v in node.items()}
    if out and all(k.isdigit() for k in out):
        idx = sorted(out, key=int)
        if idx == [str(i) for i in range(len(idx))]:
            return [out[k] for k in idx]
    return out


class CheckpointManager:
    def __init__(self, directory: str, max_to_keep: int = 3,
                 shard_mb: int = 256, async_write: bool = True):
        self.dir = directory
        self.max_to_keep = max_to_keep
        self.shard_bytes = shard_mb * 1024 * 1024
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -------------------------------------------------------------- save
    def save(self, step: int, tree, extra: Optional[Dict] = None) -> None:
        host = {p: np.asarray(jax.device_get(a))
                for p, a in tree_paths(tree)}
        if self.async_write:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host, extra or {})

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: Dict[str, np.ndarray],
               extra: Dict) -> None:
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.isdir(final):          # step already published: idempotent
            return
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "extra": extra, "entries": {},
                    "n_shards": 0}
        shard, shard_sz, shard_id = {}, 0, 0

        def flush():
            nonlocal shard, shard_sz, shard_id
            if shard:
                np.savez(os.path.join(tmp, f"shard_{shard_id}.npz"), **shard)
                shard_id += 1
                shard, shard_sz = {}, 0

        for i, (path, arr) in enumerate(sorted(host.items())):
            key = f"a{i}"
            manifest["entries"][path] = {
                "shard": shard_id, "key": key,
                "shape": list(arr.shape), "dtype": str(arr.dtype)}
            shard[key] = arr
            shard_sz += arr.nbytes
            if shard_sz >= self.shard_bytes:
                flush()
        flush()
        manifest["n_shards"] = shard_id
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, final)           # atomic publish
        with open(os.path.join(self.dir, "latest.tmp"), "w") as f:
            f.write(str(step))
        os.replace(os.path.join(self.dir, "latest.tmp"),
                   os.path.join(self.dir, "latest"))
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.max_to_keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        marker = os.path.join(self.dir, "latest")
        if os.path.exists(marker):
            with open(marker) as f:
                s = int(f.read().strip())
            if os.path.isdir(os.path.join(self.dir, f"step_{s}")):
                return s
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None,
                shardings=None) -> Tuple[int, Any, Dict]:
        """Returns (step, tree, extra). ``shardings``: optional pytree (or
        flat path->NamedSharding dict) to reshard onto the CURRENT mesh —
        the restore path is how elastic rescaling works."""
        step = step if step is not None else self.latest_step()
        assert step is not None, "no checkpoint found"
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        shards = {i: np.load(os.path.join(d, f"shard_{i}.npz"))
                  for i in range(manifest["n_shards"])}
        flat = {}
        for path, e in manifest["entries"].items():
            arr = shards[e["shard"]][e["key"]]
            if shardings is not None:
                sh = (shardings.get(path) if isinstance(shardings, dict)
                      else None)
                if sh is not None:
                    arr = jax.device_put(arr, sh)
            flat[path] = arr
        tree = _unflatten(flat)
        return step, tree, manifest.get("extra", {})
