from repro.train.optimizer import make_optimizer, Optimizer
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import Trainer, TrainConfig

__all__ = ["make_optimizer", "Optimizer", "CheckpointManager", "Trainer",
           "TrainConfig"]
