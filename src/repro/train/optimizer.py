"""Optimizers from scratch: AdamW + Adafactor, with LR schedules.

Optimizer state is a pytree shaped like (or factored from) the param tree,
so under pjit the state inherits the params' PartitionSpecs — ZeRO-style
sharded optimizer state for free (DESIGN.md §5).

Adafactor (Shazeer & Stern, 2018) keeps a FACTORED second moment — row and
column accumulators instead of a full [m, n] slot — which is what makes the
1T-param MoE config's optimizer state fit in HBM (see EXPERIMENTS.md
§Dry-run memory accounting).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------
def cosine_schedule(base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(
            jnp.pi * prog))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def constant_schedule(base_lr: float) -> Callable:
    return lambda step: jnp.asarray(base_lr, jnp.float32)


# ---------------------------------------------------------------------------
# Optimizer interface
# ---------------------------------------------------------------------------
@dataclass
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]     # (params, grads, state) -> (params, state)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: Optional[float] = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
        }

    def update(params, grads, state):
        step = state["step"] + 1
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        b1t = 1 - b1 ** step.astype(jnp.float32)
        b2t = 1 - b2 ** step.astype(jnp.float32)
        lr_t = lr_fn(step)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mh = m2 / b1t
            vh = v2 / b2t
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * \
                p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), \
                m2, v2

        flat = jax.tree_util.tree_map(upd, params, grads, state["m"],
                                      state["v"])
        params2 = jax.tree_util.tree_map(lambda t: t[0], flat,
                                         is_leaf=lambda x: isinstance(x, tuple))
        m2 = jax.tree_util.tree_map(lambda t: t[1], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
        v2 = jax.tree_util.tree_map(lambda t: t[2], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
        return params2, {"step": step, "m": m2, "v": v2}

    return Optimizer(init=init, update=update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; optional first moment off)
# ---------------------------------------------------------------------------
def adafactor(lr: Callable | float, decay: float = 0.8,
              eps: float = 1e-30, clip_threshold: float = 1.0,
              weight_decay: float = 0.0, min_dim_factored: int = 2
              ) -> Optimizer:
    lr_fn = lr if callable(lr) else constant_schedule(lr)

    def _factored(p) -> bool:
        return p.ndim >= min_dim_factored

    def init(params):
        def slot(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "slots": jax.tree_util.tree_map(
                    slot, params, is_leaf=lambda x: hasattr(x, "shape"))}

    def update(params, grads, state):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def upd(p, g, slot):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta2 * slot["vr"] + (1 - beta2) * jnp.mean(g2, -1)
                vc = beta2 * slot["vc"] + (1 - beta2) * jnp.mean(g2, -2)
                denom = jnp.maximum(jnp.mean(vr, -1, keepdims=True), eps)
                u = g * jax.lax.rsqrt(vr / denom)[..., None] \
                    * jax.lax.rsqrt(vc)[..., None, :]
                new_slot = {"vr": vr, "vc": vc}
            else:
                v = beta2 * slot["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(v)
                new_slot = {"v": v}
            # update clipping (RMS(u) <= clip_threshold)
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            delta = lr_t * u + weight_decay * lr_t * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - delta).astype(p.dtype), new_slot

        is_slot = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        pairs = jax.tree_util.tree_map(
            upd, params, grads, state["slots"],
            is_leaf=lambda x: hasattr(x, "shape"))
        params2 = jax.tree_util.tree_map(
            lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        slots2 = jax.tree_util.tree_map(
            lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        return params2, {"step": step, "slots": slots2}

    return Optimizer(init=init, update=update)


def make_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    raise ValueError(name)


def optimizer_state_bytes(params, name: str) -> int:
    """Analytic optimizer-memory accounting (EXPERIMENTS.md §Dry-run)."""
    import numpy as np
    total = 0
    for p in jax.tree_util.tree_leaves(params):
        n = int(np.prod(p.shape))
        if name == "adamw":
            total += 2 * n * 4
        else:  # adafactor factored
            if p.ndim >= 2:
                total += (int(np.prod(p.shape[:-1]))
                          + int(np.prod(p.shape[:-2] + p.shape[-1:]))) * 4
            else:
                total += n * 4
    return total
