"""Ward hierarchical clustering (the paper's best pooling method), in JAX.

The paper uses SciPy's agglomerative Ward clustering per document. SciPy's
pointer-chasing NN-chain algorithm is the wrong shape for a TPU; we instead
run the classic greedy Lance–Williams recurrence over a masked distance
matrix with fixed-shape updates:

    state: D2 [N,N] squared Ward linkage distances, sizes [N], active [N],
           assign [N] (token -> surviving cluster representative)
    loop (N-1 times, vmapped over documents):
        (i, j) = argmin over active pairs of D2
        if n_active > K_target:  merge j into i (Lance–Williams update)
        else:                    no-op (fixed trip count across the batch)

Lance–Williams for Ward (squared form, matching scipy.linkage d**2):
    D2(AB, C) = ((sA+sC) D2(A,C) + (sB+sC) D2(B,C) - sC D2(A,B)) / (sA+sB+sC)
Singleton init: D2(i, j) = ||x_i - x_j||^2.

Cosine-vs-Euclidean: the paper clusters on cosine distance; for unit vectors
||a-b||^2 = 2(1-cos), a monotone map, so the merge order is identical. Inputs
are L2-normalized before clustering (tests pin this equivalence to SciPy).

Ward is *reducible*, so the greedy merge order reproduces the NN-chain
dendrogram; cutting at K clusters equals scipy fcluster(criterion="maxclust").

PRODUCTION PATH: this module is now the REFERENCE implementation — its
full-matrix argmin per merge step is O(N^3) per document. Builds run
through ``repro.kernels.ward_pool`` (``ward_assign``), a Pallas kernel
that keeps the distance matrix in VMEM and replaces the global argmin
with lazy cached row minima (amortized O(N) selection per step),
bitwise-equal to ``ward_cluster_batch`` and ~5-7x faster per batch even
under the CPU interpreter. ``PoolingSpec.ward_kernel="ref"`` pins this
loop for A/B parity gates; tests/test_kernels_ward.py sweeps the
bitwise pin.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_INF = jnp.float32(jnp.inf)


def _init_state(x, mask):
    """x: [N, d] float32 (pre-normalized); mask: [N] bool."""
    N = x.shape[0]
    sq = jnp.sum(x * x, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    d2 = jnp.maximum(d2, 0.0)
    valid_pair = mask[:, None] & mask[None, :]
    eye = jnp.eye(N, dtype=bool)
    d2 = jnp.where(valid_pair & ~eye, d2, _INF)
    sizes = jnp.where(mask, 1, 0).astype(jnp.float32)
    assign = jnp.arange(N, dtype=jnp.int32)
    return d2, sizes, assign


def _merge_once(d2, sizes, assign, n_active, k_target):
    """One conditional merge step. All shapes static."""
    N = d2.shape[0]
    flat = jnp.argmin(d2.reshape(-1))
    i, j = flat // N, flat % N
    # canonical i < j
    i, j = jnp.minimum(i, j), jnp.maximum(i, j)
    do = (n_active > k_target) & jnp.isfinite(d2[i, j])

    si, sj = sizes[i], sizes[j]
    sc = sizes                                        # [N]
    dij = d2[i, j]
    # Lance-Williams new distances from merged (i) to every k
    denom = si + sj + sc
    new_row = ((si + sc) * d2[i] + (sj + sc) * d2[j] - sc * dij) / \
        jnp.maximum(denom, 1e-9)
    # keep +inf for inactive/self entries
    was_inf = jnp.isinf(d2[i]) | jnp.isinf(d2[j])
    new_row = jnp.where(was_inf, _INF, new_row)
    new_row = new_row.at[i].set(_INF).at[j].set(_INF)

    d2_m = d2.at[i, :].set(new_row).at[:, i].set(new_row)
    d2_m = d2_m.at[j, :].set(_INF).at[:, j].set(_INF)
    sizes_m = sizes.at[i].add(sj).at[j].set(0.0)
    assign_m = jnp.where(assign == j, i, assign)

    d2 = jnp.where(do, d2_m, d2)
    sizes = jnp.where(do, sizes_m, sizes)
    assign = jnp.where(do, assign_m, assign)
    n_active = jnp.where(do, n_active - 1, n_active)
    return d2, sizes, assign, n_active


def ward_cluster(x, mask, k_target):
    """Cluster one document's token vectors.

    Args:
      x: [N, d] float32 token vectors (will be L2-normalized).
      mask: [N] bool validity.
      k_target: scalar int32 — number of clusters to stop at.

    Returns:
      assign: [N] int32 — cluster representative index per token
              (padded tokens keep their own index; mask externally).
    """
    x = x.astype(jnp.float32)
    nrm = jnp.linalg.norm(x, axis=-1, keepdims=True)
    x = x / jnp.maximum(nrm, 1e-9)
    x = jnp.where(mask[:, None], x, 0.0)
    d2, sizes, assign = _init_state(x, mask)
    n_active = jnp.sum(mask.astype(jnp.int32))
    k_target = jnp.maximum(jnp.int32(k_target), 1)

    def body(_, state):
        d2, sizes, assign, n_active = state
        return _merge_once(d2, sizes, assign, n_active, k_target)

    N = x.shape[0]
    d2, sizes, assign, n_active = jax.lax.fori_loop(
        0, N - 1, body, (d2, sizes, assign, n_active))
    return assign


@functools.partial(jax.jit, static_argnames=("factor",))
def ward_cluster_batch(x, mask, factor: int):
    """x: [B, N, d]; mask: [B, N]. K per doc = floor(n_valid/factor) + 1.

    Returns assign [B, N] int32.
    """
    n_valid = jnp.sum(mask.astype(jnp.int32), axis=-1)
    k = n_valid // factor + 1
    return jax.vmap(ward_cluster)(x, mask, k)
