"""PLAID-style staged late-interaction search (Santhanam et al., 2022).

The index the paper composes token pooling with ("2-bit quantization and
PLAID indexing ... with the original codebase", §3.1). Four stages:

  1. **Centroid probe** — query tokens score all K centroids (one matmul);
     top-``nprobe`` centroid ids per query token are the probe set.
  2. **Candidate generation** — inverted-list gather of the vectors owned by
     probed centroids -> candidate documents.
  3. **Approximate scoring** — per candidate doc, MaxSim over its *centroid
     ids only* (no decompression), with centroid scores below ``t_cs``
     pruned to 0. Top-``ndocs`` docs survive.
  4. **Decompress + exact MaxSim** — survivors' residual codes are unpacked,
     reconstructed and scored exactly; final ranking returned.

Query hyperparameters default to the best PLAID reproduction-study settings
the paper uses (Appendix A): nprobe=8, t_cs=0.3, ndocs=8192.

Device/host split: matmul-shaped stages (1, 3, 4) are jnp; list bookkeeping
(2) is host numpy. Documents are padded to a fixed token budget so stage 4
is a single fixed-shape MaxSim batch (TPU-friendly; see kernels/maxsim).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ivf import InvertedLists, assign_vectors, build_inverted_lists
from repro.core.maxsim import maxsim_scores
from repro.core.quantization import ResidualCodec, decode, encode


@dataclass
class PLAIDIndex:
    codec: ResidualCodec
    ivf: InvertedLists
    assignments: np.ndarray      # [n_vectors] int32 centroid id per vector
    codes: np.ndarray            # [n_vectors, W] packed residual words
    vec2doc: np.ndarray          # [n_vectors] int64 doc id
    doc_offsets: np.ndarray      # [n_docs + 1] int64 into vector arrays
    doc_maxlen: int

    @property
    def n_docs(self) -> int:
        return len(self.doc_offsets) - 1

    @property
    def n_vectors(self) -> int:
        return len(self.vec2doc)

    def nbytes(self) -> int:
        """Compressed store: ids (4B) + packed codes + IVF/doc offsets."""
        return (self.assignments.nbytes + self.codes.nbytes
                + self.ivf.ids.nbytes + self.ivf.offsets.nbytes
                + self.vec2doc.nbytes + self.doc_offsets.nbytes
                + np.asarray(self.codec.centroids).nbytes)

    # ------------------------------------------------------------------ CRUD
    def add(self, doc_vectors: list) -> np.ndarray:
        """Append documents (list of [n_i, dim] arrays). Returns new doc ids."""
        new_ids = np.arange(self.n_docs, self.n_docs + len(doc_vectors))
        flat = np.concatenate([np.asarray(v, np.float32) for v in doc_vectors])
        a, w = encode(self.codec, jnp.asarray(flat))
        a, w = np.asarray(a), np.asarray(w)
        lens = np.array([len(v) for v in doc_vectors], np.int64)
        self.assignments = np.concatenate([self.assignments, a])
        self.codes = np.concatenate([self.codes, w])
        self.vec2doc = np.concatenate(
            [self.vec2doc, np.repeat(new_ids, lens)])
        self.doc_offsets = np.concatenate(
            [self.doc_offsets, self.doc_offsets[-1] + np.cumsum(lens)])
        self.ivf = build_inverted_lists(self.assignments,
                                        self.codec.n_centroids)
        return new_ids

    def delete(self, doc_ids) -> None:
        """Remove documents (compacting rebuild of the flat arrays)."""
        drop = np.isin(self.vec2doc, np.asarray(doc_ids))
        keep = ~drop
        # remap doc ids to stay dense
        lens = np.diff(self.doc_offsets)
        doc_keep = ~np.isin(np.arange(self.n_docs), np.asarray(doc_ids))
        self.assignments = self.assignments[keep]
        self.codes = self.codes[keep]
        new_lens = lens[doc_keep]
        self.doc_offsets = np.zeros(len(new_lens) + 1, np.int64)
        np.cumsum(new_lens, out=self.doc_offsets[1:])
        self.vec2doc = np.repeat(np.arange(len(new_lens)), new_lens)
        self.ivf = build_inverted_lists(self.assignments,
                                        self.codec.n_centroids)


def build_plaid_index(doc_vectors: list, codec: ResidualCodec,
                      doc_maxlen: int = 256) -> PLAIDIndex:
    """doc_vectors: list of [n_i, dim] float arrays (already pooled)."""
    lens = np.array([len(v) for v in doc_vectors], np.int64)
    flat = (np.concatenate([np.asarray(v, np.float32) for v in doc_vectors])
            if doc_vectors else np.zeros((0, codec.dim), np.float32))
    a, w = encode(codec, jnp.asarray(flat))
    a, w = np.asarray(a), np.asarray(w)
    doc_offsets = np.zeros(len(lens) + 1, np.int64)
    np.cumsum(lens, out=doc_offsets[1:])
    return PLAIDIndex(
        codec=codec,
        ivf=build_inverted_lists(a, codec.n_centroids),
        assignments=a,
        codes=w,
        vec2doc=np.repeat(np.arange(len(lens)), lens),
        doc_offsets=doc_offsets,
        doc_maxlen=doc_maxlen,
    )


# ---------------------------------------------------------------------------
# Search stages
# ---------------------------------------------------------------------------
def _centroid_scores(index: PLAIDIndex, q: np.ndarray) -> np.ndarray:
    """Stage 1: q [Lq, dim] -> centroid scores [Lq, K]."""
    return np.asarray(jnp.asarray(q, jnp.float32)
                      @ jnp.asarray(index.codec.centroids).T)


def _approx_doc_scores(index: PLAIDIndex, cs: np.ndarray,
                       cand_docs: np.ndarray, t_cs: float) -> np.ndarray:
    """Stage 3: centroid-only MaxSim per candidate doc.

    cs: [Lq, K] centroid scores; cand_docs: [C] doc ids.
    score(doc) = sum_q max over doc's centroid ids of pruned cs[q, c].
    """
    cs_pruned = np.where(cs >= t_cs, cs, 0.0)          # [Lq, K]
    scores = np.zeros(len(cand_docs), np.float32)
    for i, d in enumerate(cand_docs):
        lo, hi = index.doc_offsets[d], index.doc_offsets[d + 1]
        cids = index.assignments[lo:hi]                # centroid ids of doc d
        scores[i] = cs_pruned[:, cids].max(axis=1).sum()
    return scores


def _exact_rerank(index: PLAIDIndex, q: np.ndarray,
                  docs: np.ndarray) -> np.ndarray:
    """Stage 4: decompress survivors, fixed-shape MaxSim batch."""
    Lq, dim = q.shape
    n = len(docs)
    L = index.doc_maxlen
    dvecs = np.zeros((n, L, dim), np.float32)
    dmask = np.zeros((n, L), bool)
    for i, d in enumerate(docs):
        lo, hi = index.doc_offsets[d], index.doc_offsets[d + 1]
        rec = np.asarray(decode(index.codec,
                                jnp.asarray(index.assignments[lo:hi]),
                                jnp.asarray(index.codes[lo:hi])))
        k = min(len(rec), L)
        dvecs[i, :k] = rec[:k]
        dmask[i, :k] = True
    qm = np.ones((1, Lq), bool)
    s = maxsim_scores(jnp.asarray(q[None]), jnp.asarray(qm),
                      jnp.asarray(dvecs), jnp.asarray(dmask))
    return np.asarray(s)[0]                            # [n]


def plaid_search(index: PLAIDIndex, q: np.ndarray, k: int = 10,
                 nprobe: int = 8, t_cs: float = 0.3,
                 ndocs: int = 8192) -> Tuple[np.ndarray, np.ndarray]:
    """One query: q [Lq, dim] -> (scores [<=k], doc ids [<=k]) best-first."""
    if index.n_vectors == 0:
        return np.zeros((0,), np.float32), np.zeros((0,), np.int64)
    cs = _centroid_scores(index, q)                    # [Lq, K]
    probe = np.argsort(-cs, axis=1)[:, :nprobe]        # [Lq, nprobe]
    cand_vecs = index.ivf.lists_for(probe.reshape(-1))
    cand_docs = np.unique(index.vec2doc[cand_vecs])
    if len(cand_docs) == 0:
        return np.zeros((0,), np.float32), np.zeros((0,), np.int64)
    approx = _approx_doc_scores(index, cs, cand_docs, t_cs)
    if len(cand_docs) > ndocs:
        top = np.argsort(-approx)[:ndocs]
        cand_docs = cand_docs[top]
    exact = _exact_rerank(index, q, cand_docs)
    order = np.argsort(-exact)[:k]
    return exact[order], cand_docs[order].astype(np.int64)


def plaid_search_batch(index: PLAIDIndex, qs: np.ndarray, k: int = 10,
                       **kw) -> Tuple[np.ndarray, np.ndarray]:
    """qs [Nq, Lq, dim] -> (scores [Nq, k], ids [Nq, k]; -1 pads)."""
    S = np.full((len(qs), k), -np.inf, np.float32)
    I = np.full((len(qs), k), -1, np.int64)
    for i, q in enumerate(qs):
        s, d = plaid_search(index, np.asarray(q), k=k, **kw)
        S[i, :len(s)], I[i, :len(d)] = s, d
    return S, I
