"""PLAID-style staged late-interaction search (Santhanam et al., 2022).

The index the paper composes token pooling with ("2-bit quantization and
PLAID indexing ... with the original codebase", §3.1). Four stages, all
batched over the whole query batch:

  1. **Centroid probe** — every query token of every query scores all K
     centroids in ONE einsum; top-``nprobe`` centroid ids per token form
     the probe set.
  2. **Candidate generation** — vectorized inverted-list gather of the
     vectors owned by probed centroids -> per-query candidate documents
     (host numpy, no per-query Python loop: one repeat/unique sweep over
     the whole batch).
  3. **Approximate scoring** — per candidate doc, MaxSim over its
     *centroid ids only* (no decompression), centroid scores below
     ``t_cs`` pruned to 0; a jit-compiled scan over candidate blocks.
     Top-``ndocs`` docs per query survive.
  4. **Exact rerank** — survivors' PACKED rows (centroid ids + residual
     words) are gathered and scored in the compressed domain: the fused
     Pallas kernel (kernels/maxsim_packed) unpacks, reconstructs and
     renormalizes per VMEM tile on TPU; off-TPU the gathered rows are
     decoded eagerly and fed to the same ``maxsim_rerank`` dispatcher,
     bitwise-matching the old reconstruction path. The f32
     reconstruction ``DocStore`` is now a lazy cache built only on
     demand (corpus-wide dense scoring, debugging) — packed serving
     never materializes it.

Query hyperparameters default to the best PLAID reproduction-study settings
the paper uses (Appendix A): nprobe=8, t_cs=0.3, ndocs=8192.

Device/host split: matmul-shaped stages (1, 3, 4) are jit'd jnp/Pallas;
list bookkeeping (2) has two interchangeable implementations — the
vectorized host-numpy reference, and a fully DEVICE-RESIDENT pipeline
(``probe_kernel`` toggle) that runs stages 1-3 as ONE fixed-shape jit
program: padded per-centroid doc-list gather from ``DeviceInvertedLists``,
sort-based (query, doc) dedupe, and the fused centroid-interaction probe
(``kernels/plaid_probe``) behind a ``lax.cond`` prune — no ``np.asarray``
host hop between query encode and the final top-k. The device path is
engaged only when it is provably bitwise-equal to the host path (exact
IVF view, dense corpus-wide regime statically unreachable). Fixed shapes
throughout: candidate sets are padded to a block multiple so stage 3/4
trace once per (batch size, candidate budget) pair.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.docstore import (DocStore, pad_candidate_sets,
                                 ragged_arange)
from repro.core.ivf import (DeviceInvertedLists, InvertedLists,
                            build_device_inverted_lists,
                            build_inverted_lists)
from repro.core.maxsim import _on_tpu, maxsim_rerank, topk_with_pads
from repro.core.quantization import ResidualCodec, decode, encode

_CAND_BLOCK = 32       # candidate-axis padding granularity (jit shape reuse)
PROBE_KERNELS = ("auto", "device", "host")
# auto mode falls back to the host gather above this membership-table
# size (K * n_docs f32 elements) — the dense union matmul would
# dominate device memory; "device" forces through it.
_DEVICE_GATHER_CAP = 1 << 24


@dataclass
class PLAIDIndex:
    codec: ResidualCodec
    ivf: InvertedLists
    assignments: np.ndarray      # [n_vectors] int32 centroid id per vector
    codes: np.ndarray            # [n_vectors, W] packed residual words
    vec2doc: np.ndarray          # [n_vectors] int64 doc id
    doc_offsets: np.ndarray      # [n_docs + 1] int64 into vector arrays
    doc_maxlen: int
    recon: Optional[DocStore] = None   # decoded-vector cache, lazy
    _packed_padded: Optional[Tuple] = field(default=None, repr=False)
    _device_ivf: Optional[DeviceInvertedLists] = field(default=None,
                                                       repr=False)

    @property
    def n_docs(self) -> int:
        return len(self.doc_offsets) - 1

    @property
    def n_vectors(self) -> int:
        return len(self.vec2doc)

    def nbytes(self) -> int:
        """Resident bytes: ids (4B) + packed codes + IVF/doc offsets —
        PLUS the f32 reconstruction cache whenever it is resident.

        The recon store is re-derivable (not persisted), but a resident
        cache is real memory: hiding it here made the plaid footprint
        look 8-14x smaller than it was. On the packed rerank path it is
        simply never built, so the two numbers agree again.
        """
        total = (self.assignments.nbytes + self.codes.nbytes
                 + self.ivf.ids.nbytes + self.ivf.offsets.nbytes
                 + self.vec2doc.nbytes + self.doc_offsets.nbytes
                 + np.asarray(self.codec.centroids).nbytes)
        if self.recon is not None:
            total += self.recon.nbytes(bytes_per_dim=4, live_only=False)
        return total

    def _padded_len(self) -> int:
        """Tight padded width L = min(doc_maxlen, longest doc)."""
        lens = np.diff(self.doc_offsets)
        return int(min(self.doc_maxlen, max(lens.max(initial=0), 1)))

    def device_bytes_detail(self) -> dict:
        """Device-resident bytes of the query-time doc representation.

        ``packed``: the [n, L] centroid ids (4B) + [n, L, W] residual
        words (4B each) + [n, L] mask (1B) the compressed-domain rerank
        streams. ``codec``: centroid/cutoff/value tables. ``recon``: the
        decoded f32 view, counted only while resident — 0 under packed
        serving, which never builds it.
        """
        n = max(self.n_docs, 1)
        L = self._padded_len()
        W = self.codes.shape[1]
        return {
            "packed": n * L * (4 + 4 * W + 1),
            "codec": (np.asarray(self.codec.centroids).nbytes
                      + np.asarray(self.codec.cutoffs).nbytes
                      + np.asarray(self.codec.values).nbytes),
            "recon": (self.recon.device_nbytes()
                      if self.recon is not None else 0),
            # device IVF (candidate-generation tables), lazy like recon
            "ivf": (self._device_ivf.device_bytes()
                    if self._device_ivf is not None else 0),
        }

    def device_bytes(self) -> int:
        return sum(self.device_bytes_detail().values())

    # --------------------------------------------------------- cached views
    def _decode_docs(self, assignments, codes, lens):
        """Decode per-doc vector lists from flat code rows."""
        if len(assignments) == 0:
            return [np.zeros((0, self.codec.dim), np.float32)
                    for _ in range(len(lens))]
        rec = np.asarray(decode(self.codec, jnp.asarray(assignments),
                                jnp.asarray(codes)))
        bounds = np.zeros(len(lens) + 1, np.int64)
        np.cumsum(lens, out=bounds[1:])
        return [rec[bounds[i]:bounds[i + 1]] for i in range(len(lens))]

    def recon_store(self) -> DocStore:
        """f32 reconstruction cache, built ON FIRST USE only.

        The packed rerank path never calls this; it exists for the
        corpus-wide dense scoring path (tiny corpora, where a resident
        decoded view beats per-query decode) and for debugging.
        """
        if self.recon is None:
            self.recon = DocStore(self.codec.dim, self.doc_maxlen)
            self.recon.add(self._decode_docs(self.assignments, self.codes,
                                             np.diff(self.doc_offsets)))
        return self.recon

    def padded_packed(self) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Cached device view of the packed doc representation:
        (ids [n, L] int32, words [n, L, W] uint32, mask [n, L]) with L
        the tight width min(doc_maxlen, longest doc). This — not an f32
        rebuild — is what stage 3 and the compressed-domain stage 4
        gather from.
        """
        if self._packed_padded is None:
            n, W = self.n_docs, self.codes.shape[1]
            L = self._padded_len()
            ids = np.zeros((max(n, 1), L), np.int32)
            words = np.zeros((max(n, 1), L, W), self.codes.dtype)
            mask = np.zeros((max(n, 1), L), bool)
            if n and self.n_vectors:
                lens = np.diff(self.doc_offsets)
                kept = np.minimum(lens, L)
                rows = np.repeat(np.arange(n), kept)
                cols = ragged_arange(kept)
                src = np.repeat(self.doc_offsets[:-1], kept) + cols
                ids[rows, cols] = self.assignments[src]
                words[rows, cols] = self.codes[src]
                mask[rows, cols] = True
            self._packed_padded = (jnp.asarray(ids), jnp.asarray(words),
                                   jnp.asarray(mask))
        return self._packed_padded

    def padded_codes(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Centroid-id view + mask for stage-3 approx scoring — a slice
        of the packed view (masked slots read id 0 and are zeroed by the
        mask downstream)."""
        ids, _, mask = self.padded_packed()
        return ids, mask

    def device_ivf(self, list_cap: int = 0) -> DeviceInvertedLists:
        """Cached device IVF layout (CSR + padded unique-doc lists),
        shipped once per mutation epoch. The exact build (``list_cap=0``,
        ``overflow == 0``) is what the device candidate path gathers
        from; explicit caps bypass the cache (footprint experiments)."""
        if list_cap:
            return build_device_inverted_lists(self.ivf, self.vec2doc,
                                               self.n_docs, list_cap)
        if self._device_ivf is None:
            self._device_ivf = build_device_inverted_lists(
                self.ivf, self.vec2doc, self.n_docs)
        return self._device_ivf

    def _invalidate(self):
        self._packed_padded = None
        self._device_ivf = None

    # ------------------------------------------------------------------ CRUD
    def add(self, doc_vectors: list) -> np.ndarray:
        """Append documents (list of [n_i, dim] arrays). Returns new doc ids."""
        new_ids = np.arange(self.n_docs, self.n_docs + len(doc_vectors))
        if len(doc_vectors) == 0:
            return new_ids
        dim = self.codec.dim
        flat = np.concatenate(
            [np.asarray(v, np.float32).reshape(-1, dim)
             for v in doc_vectors])
        lens = np.array([len(v) for v in doc_vectors], np.int64)
        if len(flat):
            a, w = encode(self.codec, jnp.asarray(flat))
            a, w = np.asarray(a), np.asarray(w)
        else:
            a = np.zeros((0,), self.assignments.dtype)
            w = np.zeros((0, self.codes.shape[1]), self.codes.dtype)
        if self.recon is not None:      # keep the cache coherent if built
            self.recon.add(self._decode_docs(a, w, lens))
        self.assignments = np.concatenate([self.assignments, a])
        self.codes = np.concatenate([self.codes, w])
        self.vec2doc = np.concatenate(
            [self.vec2doc, np.repeat(new_ids, lens)])
        self.doc_offsets = np.concatenate(
            [self.doc_offsets, self.doc_offsets[-1] + np.cumsum(lens)])
        self.ivf = build_inverted_lists(self.assignments,
                                        self.codec.n_centroids)
        self._invalidate()
        return new_ids

    def delete(self, doc_ids) -> None:
        """Remove documents (compacting rebuild of the flat arrays)."""
        keep = ~np.isin(self.vec2doc, np.asarray(doc_ids))
        lens = np.diff(self.doc_offsets)
        doc_keep = ~np.isin(np.arange(self.n_docs), np.asarray(doc_ids))
        self.assignments = self.assignments[keep]
        self.codes = self.codes[keep]
        new_lens = lens[doc_keep]
        self.doc_offsets = np.zeros(len(new_lens) + 1, np.int64)
        np.cumsum(new_lens, out=self.doc_offsets[1:])
        self.vec2doc = np.repeat(np.arange(len(new_lens)), new_lens)
        self.ivf = build_inverted_lists(self.assignments,
                                        self.codec.n_centroids)
        self.recon = None            # rebuilt lazily from compacted codes
        self._invalidate()


def build_plaid_index(doc_vectors: list, codec: ResidualCodec,
                      doc_maxlen: int = 256) -> PLAIDIndex:
    """doc_vectors: list of [n_i, dim] float arrays (already pooled)."""
    lens = np.array([len(v) for v in doc_vectors], np.int64)
    flat = (np.concatenate([np.asarray(v, np.float32).reshape(-1, codec.dim)
                            for v in doc_vectors])
            if len(doc_vectors) else np.zeros((0, codec.dim), np.float32))
    if len(flat):
        a, w = encode(codec, jnp.asarray(flat))
        a, w = np.asarray(a), np.asarray(w)
    else:
        a = np.zeros((0,), np.int32)
        w = np.zeros((0, max(codec.dim * codec.bits // 32, 1)), np.uint32)
    doc_offsets = np.zeros(len(lens) + 1, np.int64)
    np.cumsum(lens, out=doc_offsets[1:])
    return PLAIDIndex(
        codec=codec,
        ivf=build_inverted_lists(a, codec.n_centroids),
        assignments=a,
        codes=w,
        vec2doc=np.repeat(np.arange(len(lens)), lens),
        doc_offsets=doc_offsets,
        doc_maxlen=doc_maxlen,
    )


# ---------------------------------------------------------------------------
# Batched search stages
# ---------------------------------------------------------------------------
def _pad_up(n: int, mult: int) -> int:
    return max(((n + mult - 1) // mult) * mult, mult)


@jax.jit
def _centroid_scores_batch(qs, centroids):
    """Stage 1: qs [Nq, Lq, dim] -> centroid scores [Nq, Lq, K]."""
    return jnp.einsum("qld,kd->qlk", qs.astype(jnp.float32),
                      centroids.astype(jnp.float32))


def _gather_candidates(index: PLAIDIndex, probe: np.ndarray,
                       live: Optional[np.ndarray] = None,
                       probe_valid: Optional[np.ndarray] = None
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Stage 2: probe [Nq, Lq, nprobe] centroid ids -> padded candidate
    doc ids [Nq, C] + validity mask [Nq, C]. Fully vectorized.
    ``probe_valid`` (same shape as ``probe``) drops masked-token probes:
    top_k over an all--inf row returns centroids 0..nprobe-1, and
    walking those lists would silently inflate the candidate sets."""
    Nq = probe.shape[0]
    K = index.ivf.n_centroids
    flat = probe.reshape(Nq, -1).astype(np.int64)
    keys = np.arange(Nq)[:, None] * K + flat
    if probe_valid is not None:
        keys = keys[probe_valid.reshape(Nq, -1)]
    # dedupe (query, centroid) pairs so each probed list is walked once
    qc = np.unique(keys)
    qi, ci = qc // K, qc % K
    starts = index.ivf.offsets[ci]
    lens = index.ivf.offsets[ci + 1] - starts
    total = int(lens.sum())
    if total == 0:
        return (np.zeros((Nq, 1), np.int64), np.zeros((Nq, 1), bool))
    # flat positions into ivf.ids for every (pair, member) without a loop
    pos = np.repeat(starts, lens) + ragged_arange(lens)
    docs = index.vec2doc[index.ivf.ids[pos]]
    qidx = np.repeat(qi, lens)
    # dedupe (query, doc) pairs -> per-query candidate sets
    qd = np.unique(qidx * np.int64(index.n_docs) + docs)
    qidx, docs = qd // index.n_docs, qd % index.n_docs
    if live is not None:
        keep = live[docs]
        qidx, docs = qidx[keep], docs[keep]
    return pad_candidate_sets(qidx, docs, Nq, block=_CAND_BLOCK)


@functools.partial(jax.jit, static_argnames=("block",))
def _approx_scores_batch(cs, codes, code_mask, cand_mask, t_cs,
                         block: int = _CAND_BLOCK):
    """Stage 3: centroid-only MaxSim for every (query, candidate) pair.

    cs: [Nq, Lq, K]; codes/code_mask: [Nq, C, L] per-candidate centroid
    ids; cand_mask: [Nq, C]. Scanned over candidate blocks to bound the
    [Nq, block, L, Lq] gather. Returns approx scores [Nq, C] (-inf on
    padded candidate slots).
    """
    Nq, C, L = codes.shape
    cs_p = jnp.where(cs >= t_cs, cs, 0.0)              # [Nq, Lq, K]
    csT = jnp.swapaxes(cs_p, 1, 2)                     # [Nq, K, Lq]
    nb = C // block
    codes_b = jnp.moveaxis(codes.reshape(Nq, nb, block, L), 1, 0)
    mask_b = jnp.moveaxis(code_mask.reshape(Nq, nb, block, L), 1, 0)

    def one(carry, args):
        cb, mb = args                                  # [Nq, block, L]
        vals = jax.vmap(lambda t, i: t[i])(csT, cb)    # [Nq, block, L, Lq]
        vals = jnp.where(mb[..., None], vals, 0.0)
        return carry, vals.max(axis=2).sum(axis=-1)    # [Nq, block]

    _, out = jax.lax.scan(one, 0, (codes_b, mask_b))   # [nb, Nq, block]
    approx = jnp.moveaxis(out, 0, 1).reshape(Nq, C)
    return jnp.where(cand_mask, approx, -jnp.inf)


def _ladder(n: int) -> int:
    """The ``pad_candidate_sets`` geometric width for a max count of n."""
    n = max(int(n), 1)
    return _CAND_BLOCK << max(int(np.ceil(np.log2(-(-n // _CAND_BLOCK)))), 0)


def _floor_ladder(n: int) -> int:
    """Largest geometric width <= n (0 if n < the smallest width)."""
    if n < _CAND_BLOCK:
        return 0
    C = _CAND_BLOCK
    while C * 2 <= n:
        C *= 2
    return C


def device_probe_plan(index: PLAIDIndex, Lq: int, nprobe: int,
                      ndocs: int, probe_kernel: str = "auto"):
    """Static decision + geometry for the device-resident candidate path.

    Returns ``(use_device, (div, k, c_score, s_out))``. The device path
    engages only when it is PROVABLY bitwise-equal to the host path:

      * the device IVF view is exact (``overflow == 0``);
      * the dense corpus-wide dispatch is statically unreachable — for
        every possible per-query candidate count, the host path's final
        padded width stays below ``n_docs`` (otherwise the host would
        switch to the corpus-scan rerank, a different program whose
        dispatch depends on runtime counts the device path cannot see
        without a host sync);
      * in "auto" mode, the padded gather stays under a memory cap.

    ``c_score`` is the static stage-2/3 width (every possible candidate
    fits), ``s_out`` the static output width (= the rerank slate width).
    """
    assert probe_kernel in PROBE_KERNELS, probe_kernel
    if probe_kernel == "host" or index.n_vectors == 0 or index.n_docs == 0:
        return False, None
    div = index.device_ivf()
    if div.overflow != 0:
        return False, None
    n_docs = index.n_docs
    k = min(nprobe, index.codec.n_centroids)
    W = max(Lq, 1) * k * div.list_cap       # padded gather slots / query
    c_score = _pad_up(min(W, n_docs), _CAND_BLOCK)
    s_out = min(c_score, _pad_up(int(ndocs), _CAND_BLOCK))
    # worst-case host output width over all data: the widest no-prune
    # gather (largest ladder value <= ndocs, capped by the gather bound)
    # vs the pruned width (ndocs block-padded, reachable only when the
    # gather ladder can exceed ndocs)
    lmax = _ladder(min(W, n_docs))
    f_prune = _pad_up(int(ndocs), _CAND_BLOCK) if lmax > ndocs else 0
    f_noprune = min(lmax, _floor_ladder(int(ndocs)))
    if max(f_prune, f_noprune) >= n_docs:
        return False, None
    if (probe_kernel != "device"
            and div.doc_member.size > _DEVICE_GATHER_CAP):
        return False, None
    return True, (div, k, c_score, s_out)


@functools.partial(jax.jit, static_argnames=("k", "t_cs", "ndocs",
                                             "c_score", "s_out", "impl"))
def _device_candidates(cs, qs, qm, doc_member, live, codes,
                       tok_mask, centroids, *, k: int, t_cs: float,
                       ndocs: int, c_score: int, s_out: int, impl: str):
    """Stages 1-3 as one device program — no host round-trip.

    Bitwise contract (pinned by tests/test_plaid_probe.py): candidate
    ids, validity, and slot order equal the host path's —

      * probe: same ``lax.top_k`` over the same (masked) centroid
        scores; masked-token probes dropped (the host bugfix twin);
      * gather/dedupe: probed-centroid one-hot rows x the 0/1
        ``doc_member`` table (one matmul; counts are small integers,
        exact in f32) -> per-query doc membership -> cumsum compaction.
        Ascending unique doc ids land in slots 0..count-1, exactly
        ``np.unique`` + ``pad_candidate_sets`` (no device sort or big
        scatter — the two primitives XLA serializes on every backend);
      * prune: the host's data-dependent decision (padded gather width
        > ndocs) is replicated on device from the counts and the same
        geometric ladder, then taken as a ``lax.cond`` — both branches
        emit the static width ``s_out``, so one executable serves the
        whole stream (no-retrace contract).
    """
    Nq = cs.shape[0]
    n_docs = live.shape[0]
    csm = jnp.where(qm[:, :, None], cs, -jnp.inf)
    _, probe = jax.lax.top_k(csm, k)                     # [Nq, Lq, k]
    flat = probe.reshape(Nq, -1)                         # [Nq, Lq*k]
    pvalid = jnp.broadcast_to(qm[:, :, None], probe.shape
                              ).reshape(Nq, -1)
    # (query, doc) set union as ONE matmul: a probed-centroid one-hot
    # row per query times the 0/1 membership table counts, exactly
    # (small integers in f32), how many probed lists own each doc
    K = doc_member.shape[0]
    probed = jnp.any(
        (flat[:, :, None] == jax.lax.broadcasted_iota(jnp.int32,
                                                      (1, 1, K), 2))
        & pvalid[:, :, None], axis=1)                    # [Nq, K]
    hits = jax.lax.dot_general(
        probed.astype(jnp.float32), doc_member,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # [Nq, n_docs]
    member = (hits > 0.0) & live[None, :]
    counts = member.sum(axis=1).astype(jnp.int32)        # [Nq]
    # compact member columns ascending via cumsum positions —
    # bit-for-bit np.unique's ascending unique ids at slots 0..cnt-1
    pos = jnp.cumsum(member, axis=1, dtype=jnp.int32) - 1
    docid = jax.lax.broadcasted_iota(jnp.int32, (Nq, n_docs), 1)
    tpos = jnp.where(member, pos, jnp.int32(c_score))    # cnt <= c_score
    cand_c = jax.vmap(lambda t, d: jnp.zeros((c_score,), jnp.int32)
                      .at[t].set(d, mode="drop"))(tpos, docid)
    mask_c = (jax.lax.broadcasted_iota(jnp.int32, (Nq, c_score), 1)
              < counts[:, None])   # pad slots read doc 0, as on host

    # the host prune decision, replicated: padded gather width > ndocs
    maxc = jnp.maximum(counts.max(), 1)
    ladder = jnp.asarray([_CAND_BLOCK << m for m in range(26)], jnp.int32)
    host_c = jnp.min(jnp.where(ladder >= maxc, ladder,
                               jnp.int32(2**31 - 1)))
    keep = min(ndocs, c_score)

    def unpruned(cand_c, mask_c):
        return cand_c[:, :s_out], mask_c[:, :s_out]

    def pruned(cand_c, mask_c):
        gcodes = jnp.take(codes, cand_c, axis=0)         # [Nq, C, L]
        gmask = jnp.take(tok_mask, cand_c, axis=0) & mask_c[:, :, None]
        if impl == "kernel":
            from repro.kernels.plaid_probe.ops import plaid_probe_scores
            approx = plaid_probe_scores(qs, qm, centroids, gcodes,
                                        gmask, mask_c, t_cs=t_cs,
                                        impl="kernel")
        else:
            approx = _approx_scores_batch(csm, gcodes, gmask, mask_c,
                                          t_cs)
        top_s, top_i = jax.lax.top_k(approx, keep)
        cand_p = jnp.take_along_axis(cand_c, top_i, axis=1)
        mask_p = jnp.isfinite(top_s)
        if keep < s_out:
            cand_p = jnp.pad(cand_p, ((0, 0), (0, s_out - keep)))
            mask_p = jnp.pad(mask_p, ((0, 0), (0, s_out - keep)))
        return cand_p, mask_p

    return jax.lax.cond(host_c > ndocs, pruned, unpruned, cand_c, mask_c)


def plaid_candidates(index: PLAIDIndex, qs: np.ndarray,
                     nprobe: int = 8, t_cs: float = 0.3,
                     ndocs: int = 8192,
                     live: Optional[np.ndarray] = None,
                     q_mask: Optional[np.ndarray] = None,
                     probe_kernel: str = "auto"
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Stages 1-3 for a query batch: qs [Nq, Lq, dim] -> survivor doc
    ids [Nq, S] + validity mask [Nq, S] (S <= ndocs block-padded).
    Masked query tokens contribute nothing to probes or approx scores.

    ``probe_kernel`` picks the stage-2/3 implementation (RUNTIME-ONLY,
    never persisted): "host" is the vectorized-numpy reference path
    (host arrays out); "device"/"auto" run the device-resident pipeline
    (device arrays out, zero host hops) whenever ``device_probe_plan``
    proves it bitwise-safe, falling back to the host path otherwise.
    """
    qs = np.asarray(qs, np.float32)
    Nq = len(qs)
    if index.n_vectors == 0:
        return np.zeros((Nq, 1), np.int64), np.zeros((Nq, 1), bool)
    use_device, geom = device_probe_plan(index, qs.shape[1], nprobe,
                                         ndocs, probe_kernel)
    cs = _centroid_scores_batch(jnp.asarray(qs, jnp.float32),
                                jnp.asarray(index.codec.centroids))
    if use_device:
        div, k, c_score, s_out = geom
        qm = (jnp.ones((Nq, qs.shape[1]), bool) if q_mask is None
              else jnp.asarray(np.asarray(q_mask, bool)))
        live_dev = (jnp.ones(index.n_docs, bool) if live is None
                    else (live if isinstance(live, jax.Array)
                          else jnp.asarray(np.asarray(live, bool))))
        codes, tok_mask = index.padded_codes()
        return _device_candidates(
            cs, jnp.asarray(qs), qm, div.doc_member,
            live_dev, codes, tok_mask, jnp.asarray(index.codec.centroids),
            k=k, t_cs=float(t_cs), ndocs=int(ndocs), c_score=c_score,
            s_out=s_out, impl="kernel" if _on_tpu() else "ref")
    if q_mask is not None:
        # masked tokens: -inf centroid scores are pruned to 0 in stage 3,
        # and their (degenerate) probe picks are dropped before the
        # gather — top_k over an all--inf row would otherwise walk
        # centroids 0..nprobe-1's lists into the candidate set
        cs = jnp.where(jnp.asarray(q_mask, bool)[:, :, None], cs, -jnp.inf)
    k = min(nprobe, index.codec.n_centroids)
    _, probe = jax.lax.top_k(cs, k)                    # [Nq, Lq, nprobe]
    probe_valid = (None if q_mask is None else np.broadcast_to(
        np.asarray(q_mask, bool)[:, :, None], (Nq, qs.shape[1], k)))
    cand, cmask = _gather_candidates(index, np.asarray(probe), live,
                                     probe_valid)
    if cand.shape[1] <= ndocs:
        return cand, cmask
    codes, tok_mask = index.padded_codes()
    idx = jnp.asarray(cand)
    approx = _approx_scores_batch(
        cs, jnp.take(codes, idx, axis=0),
        jnp.take(tok_mask, idx, axis=0) & jnp.asarray(cmask)[:, :, None],
        jnp.asarray(cmask), t_cs)
    keep = min(ndocs, cand.shape[1])           # honor the ndocs budget
    top_s, top_i = jax.lax.top_k(approx, keep)
    top_i = np.asarray(top_i)
    cand = np.take_along_axis(cand, top_i, axis=1)
    cmask = np.asarray(jnp.isfinite(top_s))
    S = _pad_up(keep, _CAND_BLOCK)             # block-pad for jit reuse
    if S > keep:
        cand = np.pad(cand, ((0, 0), (0, S - keep)))
        cmask = np.pad(cmask, ((0, 0), (0, S - keep)))
    return cand, cmask


def _decode_rows(codec: ResidualCodec, ids, words):
    """Decode gathered padded rows: ids [..., Ld], words [..., Ld, W]
    -> [..., Ld, dim] f32. Row-for-row ``quantization.decode``, so the
    result is bitwise what the reconstruction DocStore would hold."""
    shape = ids.shape
    v = decode(codec, ids.reshape(-1), words.reshape(-1, words.shape[-1]))
    return v.reshape(*shape, codec.dim)


def maxsim_packed_rerank_store(index: PLAIDIndex, q, q_mask, cand,
                               cand_mask, *, slab: int = 1024):
    """Compressed-domain stage 4: gather PACKED rows for the survivors
    and score them, never materializing an f32 reconstruction store.

    Slabbed over the candidate axis like ``maxsim_rerank_store`` (same
    slab width, same -inf/mask epilogue, so candidate padding and tie
    order are identical). On TPU the fused kernel unpacks+reconstructs
    in VMEM; off-TPU the gathered rows are decoded eagerly through
    ``quantization.decode`` — op for op the recon path's decode — and
    fed to the same ``maxsim_rerank`` dispatcher, making the scores
    bitwise-equal to the reconstruction path.
    cand/cand_mask: [Nq, C] host arrays -> scores [Nq, C] (-inf invalid).
    """
    codec = index.codec
    ids, words, tmask = index.padded_packed()
    q = jnp.asarray(q, jnp.float32)
    if not isinstance(cand, jax.Array):
        cand = np.asarray(cand, np.int64)
        cand_mask = np.asarray(cand_mask)
    parts = []
    for lo in range(0, cand.shape[1], slab):
        c = jnp.asarray(cand[:, lo:lo + slab])
        cm = jnp.asarray(cand_mask[:, lo:lo + slab])
        aw = jnp.take(ids, c, axis=0)                  # [Nq, S, Ld]
        ww = jnp.take(words, c, axis=0)                # [Nq, S, Ld, W]
        dm = jnp.take(tmask, c, axis=0) & cm[:, :, None]
        if _on_tpu():
            from repro.kernels.maxsim_packed.ops import maxsim_packed_rerank
            s = maxsim_packed_rerank(q, q_mask, ww, aw, dm,
                                     codec.centroids, codec.values,
                                     bits=codec.bits)
        else:
            s = maxsim_rerank(q, q_mask, _decode_rows(codec, aw, ww), dm)
        parts.append(jnp.where(cm, s, -jnp.inf))
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)


def plaid_search_batch(index: PLAIDIndex, qs: np.ndarray, k: int = 10,
                       nprobe: int = 8, t_cs: float = 0.3,
                       ndocs: int = 8192, probe_kernel: str = "auto"
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """True batch API: qs [Nq, Lq, dim] -> (scores [Nq, k], ids [Nq, k];
    -inf/-1 pads). One traced rerank for the whole batch."""
    qs = np.asarray(qs, np.float32)
    Nq = len(qs)
    cand, cmask = plaid_candidates(index, qs, nprobe=nprobe, t_cs=t_cs,
                                   ndocs=ndocs, probe_kernel=probe_kernel)
    # the empty-batch early exit is a host decision; keep device
    # candidates on device (rerank's -inf epilogue handles all-invalid)
    if not isinstance(cmask, jax.Array) and not cmask.any():
        return (np.full((Nq, k), -np.inf, np.float32),
                np.full((Nq, k), -1, np.int64))
    qm = jnp.ones(qs.shape[:2], bool)
    scores = maxsim_packed_rerank_store(index, qs, qm, cand, cmask)
    return topk_with_pads(scores, cand, k)


def plaid_search(index: PLAIDIndex, q: np.ndarray, k: int = 10,
                 nprobe: int = 8, t_cs: float = 0.3,
                 ndocs: int = 8192, probe_kernel: str = "auto"
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """One query: q [Lq, dim] -> (scores [<=k], doc ids [<=k]) best-first."""
    S, I = plaid_search_batch(index, np.asarray(q, np.float32)[None], k=k,
                              nprobe=nprobe, t_cs=t_cs, ndocs=ndocs,
                              probe_kernel=probe_kernel)
    valid = I[0] >= 0
    return S[0][valid], I[0][valid]
