"""ReplicatedIndex: scale-out serving over a device mesh.

The paper makes multi-vector indexes small enough to be *practical*;
this layer makes serving them *scale*: one logical index becomes
``n_replicas`` replica groups, each group placing its shards across
devices (launch/mesh.make_serve_mesh axes ("replica", "shard")), with
the serving engine's router (launch/engine.py) fanning each microbatch
to a replica lane. Three placement regimes, all bitwise-identical to
the single-device ``search_batch`` (ids + scores + tie order):

  * **Generic dispatch** (any backend): replica ``r``'s shards probe
    under their placed devices (``ShardedIndex.place``) — stage 1 stays
    host numpy, stage 2 + the per-shard local top-k run per device, and
    the merge moves only [Nq, k] blocks device-to-device (no host
    round-trip per shard; see core/sharded.py).
  * **SPMD flat scan** (flat backend, one device per live shard): the
    whole group's dense corpus scan + local top-k + merge collective is
    ONE ``shard_map`` program over a 1-D ("shard",) mesh — doc tensors
    device-put with the ``sharding.api.serve_rules`` logical-axis specs
    ("docs" -> shard axis, queries replicated), merged with a tiled
    ``all_gather`` whose axis order IS shard order, so the tie-order
    proof of the dispatch merge carries over unchanged.
  * **Degraded single-device**: fewer devices than cells — placement
    tiles round-robin (``serve_device_table``); everything still
    serves, bit-identical, with thread-level concurrency only.

Replicas may share ONE inner index object (``replicate`` — zero extra
host memory; device arrays are per-group only on the SPMD flat path) or
hold distinct copies (``from_dir`` — mmap reopens per replica, so each
group's lazy device caches commit to its own device row; host pages
stay shared via the page cache). Mutation is a serving anti-pattern
here: ``delete`` fans to every copy and drops compiled plans; ``add``
requires the shared-inner form — rebuild + hot-swap is the supported
path for index growth (the engine's watcher re-places on every swap).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import MultiVectorIndex
from repro.core.maxsim import maxsim_all_docs, topk_with_pads
from repro.core.sharded import ShardedIndex
from repro.launch.mesh import (distinct_row, make_shard_mesh,
                               serve_device_table)
from repro.sharding.api import logical_spec, mesh_context, serve_rules


def _parts(inner) -> List[Tuple[int, MultiVectorIndex]]:
    """(global doc base, shard) pairs — a monolithic index is one part."""
    if isinstance(inner, ShardedIndex):
        return list(zip(inner.doc_base, inner.shards))
    return [(0, inner)]


class _FlatPlan:
    """One replica group's flat corpus scan as a single SPMD program.

    Doc tensors are stacked [S, Ndp, Lp, dim] (every live shard padded
    to the group max — MaxSim is pad-invariant: masked tokens score
    -inf into a max, padded doc rows are live-masked to -inf) and
    device_put sharded over a 1-D ("shard",) mesh with the
    ``serve_rules`` logical specs. The program computes each shard's
    dense scores + local top-k, shifts to global ids, and merges with a
    tiled ``all_gather`` (axis order = shard order); the host epilogue
    (``topk_with_pads``) reduces the replicated [Nq, S*kk] block to the
    final [Nq, k] — identical math to the dispatch merge, one XLA
    dispatch instead of S.
    """

    def __init__(self, parts: Sequence[Tuple[int, MultiVectorIndex]],
                 row: Sequence):
        from jax.sharding import NamedSharding
        self.mesh = make_shard_mesh(row)
        self.merge_device = list(row)[0]
        S = len(parts)
        dim = parts[0][1].dim
        views = []
        for base, shard in parts:
            d, m = shard.store.padded()
            views.append((base, np.asarray(d), np.asarray(m),
                          np.asarray(shard._live(), bool)))
        Ndp = max(v[1].shape[0] for v in views)
        Lp = max(v[1].shape[1] for v in views)
        D = np.zeros((S, Ndp, Lp, dim), np.float32)
        M = np.zeros((S, Ndp, Lp), bool)
        LV = np.zeros((S, Ndp), bool)
        B = np.zeros((S,), np.int32)
        for i, (base, d, m, lv) in enumerate(views):
            D[i, :d.shape[0], :d.shape[1]] = d
            M[i, :m.shape[0], :m.shape[1]] = m
            LV[i, :lv.shape[0]] = lv
            B[i] = base
        with mesh_context(self.mesh, serve_rules()):
            specs = (logical_spec("docs", None, None, None),
                     logical_spec("docs", None, None),
                     logical_spec("docs", None),
                     logical_spec("docs"))
        self._specs = specs
        put = lambda x, sp: jax.device_put(  # noqa: E731
            x, NamedSharding(self.mesh, sp))
        self.d = put(D, specs[0])
        self.m = put(M, specs[1])
        self.live = put(LV, specs[2])
        self.base = put(B, specs[3])
        self.n_docs_padded = Ndp
        self._fns: Dict[int, object] = {}

    def _fn(self, kk: int):
        if kk in self._fns:
            return self._fns[kk]
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        sd, sm, sl, sb = self._specs

        def body(d, m, lv, b, q, qm):
            d, m, lv, b = d[0], m[0], lv[0], b[0]
            s = maxsim_all_docs(q, qm, d, m)            # [Nq, Ndp]
            s = jnp.where(lv[None, :], s, -jnp.inf)
            ts, ti = jax.lax.top_k(s, kk)
            gi = ti.astype(jnp.int32) + b
            return (jax.lax.all_gather(ts, "shard", axis=1, tiled=True),
                    jax.lax.all_gather(gi, "shard", axis=1, tiled=True))

        fn = jax.jit(shard_map(
            body, mesh=self.mesh,
            in_specs=(sd, sm, sl, sb, P(), P()),
            out_specs=(P(), P()), check_rep=False))
        self._fns[kk] = fn
        return fn

    def search(self, qs: np.ndarray, q_mask, k: int
               ) -> Tuple[np.ndarray, np.ndarray]:
        qs = jnp.asarray(np.asarray(qs, np.float32))
        qm = (jnp.ones(qs.shape[:2], bool) if q_mask is None
              else jnp.asarray(np.asarray(q_mask, bool)))
        kk = min(k, self.n_docs_padded)
        ts, gi = self._fn(kk)(self.d, self.m, self.live, self.base,
                              qs, qm)
        # outputs are mesh-replicated; pull one copy to the merge device
        # for the (single-device) final top-k epilogue
        ts = jax.device_put(ts, self.merge_device)
        return topk_with_pads(ts, np.asarray(gi), k)


class ReplicatedIndex:
    """Replica groups + device-placed shards behind one index API.

    ``search_batch`` (parity surface) routes to replica 0;
    ``search_batch_on(r, ...)`` is the router's per-lane entry — every
    replica returns bitwise-identical results, so routing is purely a
    throughput decision. Construction: ``replicate`` shares one inner
    index across groups, ``from_dir`` reopens the artifact per group
    (mmap) so each group owns its device caches, dividing the auto
    probe-thread width across lanes (``ShardSpec.probe_threads`` pins
    it explicitly).
    """

    def __init__(self, replicas: Sequence, *, own_inner: bool = False,
                 device_table: Optional[List[List]] = None,
                 use_shard_map: Optional[bool] = None):
        self._inners = list(replicas)
        assert self._inners, "need at least one replica"
        first = self._inners[0]
        for ix in self._inners[1:]:
            assert ix.backend == first.backend, "replica backend mismatch"
            assert ix.n_docs == first.n_docs, "replica corpus mismatch"
        self.n_replicas = len(self._inners)
        self.own_inner = own_inner
        # None = auto (flat backend, >=2 live shards, one device each);
        # False = dispatch only; True = force when buildable (tests)
        self.use_shard_map = use_shard_map
        self._distinct = (len({id(ix) for ix in self._inners})
                          == self.n_replicas)
        n_shards = max(len(_parts(first)), 1)
        self.device_table = (list(device_table) if device_table is not None
                             else serve_device_table(self.n_replicas,
                                                     n_shards))
        assert len(self.device_table) == self.n_replicas
        self._multi_device = len(jax.devices()) > 1
        self._plans: Dict[int, Optional[_FlatPlan]] = {}
        self._plan_lock = threading.Lock()
        self._closed = False
        self._place_all()

    # -------------------------------------------------------- construction
    @classmethod
    def replicate(cls, index, n_replicas: int = 1,
                  own_inner: bool = False, **kw) -> "ReplicatedIndex":
        """Replica groups over ONE shared inner index (no host copies).
        Per-group device placement applies only on the SPMD flat path
        (which owns its device arrays); other backends share the
        group-0 placement and scale via lane concurrency."""
        assert n_replicas >= 1, n_replicas
        return cls([index] * int(n_replicas), own_inner=own_inner, **kw)

    @classmethod
    def from_dir(cls, path: str, n_replicas: int = 1, mmap: bool = True,
                 **kw) -> "ReplicatedIndex":
        """One mmap reopen per replica group: distinct index objects
        whose lazy device caches commit to their own device rows (host
        pages shared by the page cache). Auto probe-thread width is
        divided across groups so lanes x workers never oversubscribes;
        a ``ShardSpec.probe_threads`` pin recorded in the manifest is
        honored as-is."""
        from repro.core.persist import load_artifact
        assert n_replicas >= 1, n_replicas
        reps = []
        for _ in range(int(n_replicas)):
            ix = load_artifact(path, mmap=mmap)
            if (isinstance(ix, ShardedIndex) and n_replicas > 1
                    and ix.probe_threads_cfg == 0):
                ix.set_probe_threads(
                    max(1, ix.probe_threads // int(n_replicas)))
            reps.append(ix)
        return cls(reps, own_inner=True, **kw)

    def _place_all(self) -> None:
        if not self._multi_device:
            return                      # single device: placement is moot
        placed = set()
        for r, inner in enumerate(self._inners):
            if id(inner) in placed:
                continue                # shared inner: group-0 row wins
            placed.add(id(inner))
            if isinstance(inner, ShardedIndex):
                inner.place(self.device_table[r][:inner.n_shards])

    def _ctx(self, r: int):
        """Per-lane device context for MONOLITHIC inners — only safe
        when each lane owns its copy (a shared inner's caches commit to
        one device; pinning queries elsewhere would split the args of
        one jit call across devices)."""
        if (self._multi_device and self._distinct
                and not isinstance(self._inners[r], ShardedIndex)):
            return jax.default_device(self.device_table[r][0])
        return contextlib.nullcontext()

    # ------------------------------------------------------------- topology
    @property
    def inner(self):
        return self._inners[0]

    @property
    def backend(self) -> str:
        return self._inners[0].backend

    @property
    def dim(self) -> int:
        return self._inners[0].dim

    @property
    def n_docs(self) -> int:
        return self._inners[0].n_docs

    @property
    def n_shards(self) -> int:
        return len(_parts(self._inners[0]))

    def n_vectors(self) -> int:
        return self._inners[0].n_vectors()

    def nbytes(self) -> int:
        return self._inners[0].nbytes()

    def device_bytes(self) -> int:
        seen, total = set(), 0
        for ix in self._inners:
            if id(ix) not in seen:
                seen.add(id(ix))
                total += ix.device_bytes()
        return total

    # ----------------------------------------------------------------- CRUD
    def _invalidate(self) -> None:
        with self._plan_lock:
            self._plans.clear()

    def add(self, doc_vectors) -> np.ndarray:
        if self._distinct and self.n_replicas > 1:
            raise RuntimeError(
                "add() on a multi-copy ReplicatedIndex would desync the "
                "replicas — rebuild the artifact and hot-swap instead")
        ids = self._inners[0].add(doc_vectors)
        self._invalidate()
        return ids

    def delete(self, doc_ids) -> None:
        seen = set()
        for ix in self._inners:
            if id(ix) not in seen:
                seen.add(id(ix))
                ix.delete(doc_ids)
        self._invalidate()

    def set_probe_kernel(self, probe_kernel: str) -> None:
        """Fan the runtime-only plaid candidate-path toggle to every
        distinct inner (monolithic or sharded)."""
        seen = set()
        for ix in self._inners:
            if id(ix) in seen:
                continue
            seen.add(id(ix))
            if isinstance(ix, ShardedIndex):
                ix.set_probe_kernel(probe_kernel)
            else:
                from repro.core.plaid import PROBE_KERNELS
                assert probe_kernel in PROBE_KERNELS, probe_kernel
                ix.probe_kernel = probe_kernel

    # ----------------------------------------------------------------- plans
    def _plan_for(self, r: int) -> Optional[_FlatPlan]:
        if self.backend != "flat" or self.use_shard_map is False:
            return None
        with self._plan_lock:
            if r in self._plans:
                return self._plans[r]
            inner = self._inners[r]
            pos = [i for i, (_, s) in enumerate(_parts(inner))
                   if s.n_docs > 0]
            # modulo-tile: adds can grow the shard count past the table
            tbl = self.device_table[r]
            row = [tbl[i % len(tbl)] for i in pos]
            auto_ok = len(pos) >= 2 and self._multi_device
            ok = (bool(pos) and distinct_row(row)
                  and (auto_ok or self.use_shard_map is True))
            parts = [p for p in _parts(inner) if p[1].n_docs > 0]
            plan = _FlatPlan(parts, row) if ok else None
            self._plans[r] = plan
            return plan

    # ---------------------------------------------------------------- search
    def search_batch_on(self, replica: int, qs: np.ndarray, k: int = 10,
                        q_mask: Optional[np.ndarray] = None
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """One replica lane's search — bitwise identical for every lane;
        the router picks ``replica`` for throughput, not results."""
        r = int(replica) % self.n_replicas
        plan = self._plan_for(r)
        if plan is not None:
            return plan.search(qs, q_mask, k)
        inner = self._inners[r]
        with self._ctx(r):
            return inner.search_batch(qs, k=k, q_mask=q_mask)

    def search_batch(self, qs: np.ndarray, k: int = 10,
                     q_mask: Optional[np.ndarray] = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Parity surface: identical to the wrapped index's
        ``search_batch`` (routes through lane 0)."""
        return self.search_batch_on(0, qs, k=k, q_mask=q_mask)

    def search(self, q: np.ndarray, k: int = 10
               ) -> Tuple[np.ndarray, np.ndarray]:
        S, I = self.search_batch(np.asarray(q, np.float32)[None], k=k)
        valid = I[0] >= 0
        return S[0][valid], I[0][valid]

    def warm_shapes(self, qs: np.ndarray, k: int = 10) -> None:
        """Warm EVERY lane at this batch shape: plan lanes trace their
        SPMD program + epilogue, dispatch lanes run the full per-shard
        ladder warm on their placed devices — so a router mixing lanes
        mid-stream re-traces nothing (CompileCounter contract)."""
        qs = np.asarray(qs, np.float32)
        warmed = set()
        for r in range(self.n_replicas):
            plan = self._plan_for(r)
            if plan is not None:
                plan.search(qs, None, k)
                continue
            inner = self._inners[r]
            if id(inner) in warmed:
                continue
            warmed.add(id(inner))
            with self._ctx(r):
                inner.warm_shapes(qs, k=k)

    # -------------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Drop compiled plans and (for ``own_inner`` constructions,
        e.g. watcher loads and ``from_dir``) release every distinct
        inner's resources — the hot-swap retire hook the engine calls
        so replica fleets don't strand probe pools across generations."""
        if self._closed:
            return
        self._closed = True
        self._invalidate()
        if not self.own_inner:
            return
        seen = set()
        for ix in self._inners:
            if id(ix) in seen:
                continue
            seen.add(id(ix))
            close = getattr(ix, "close", None)
            if close is not None:
                close()

    @property
    def closed(self) -> bool:
        return self._closed
