"""Batched Lloyd k-means on cosine similarity (paper pooling method #2,
also the IVF centroid trainer for the PLAID-style index).

TPU adaptation: one [B, N, K] masked similarity argmax per Lloyd step
(MXU matmul), segment-mean centroid update, fixed iteration count, padded
clusters masked — per-document K varies (floor(n/f)+1) but shapes don't.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _normalize(x, eps=1e-9):
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), eps)


def _init_centroids(x, mask, k_max):
    """Deterministic strided init: pick ~evenly spaced valid tokens."""
    N = x.shape[0]
    n_valid = jnp.maximum(jnp.sum(mask.astype(jnp.int32)), 1)
    # positions of valid tokens, padded with 0
    idx_sorted = jnp.argsort(jnp.where(mask, 0, 1), stable=True)  # valid first
    stride_pos = (jnp.arange(k_max) * n_valid) // k_max           # [k_max]
    take = idx_sorted[jnp.clip(stride_pos, 0, N - 1)]
    return x[take]                                                # [k_max, d]


def kmeans_assign_step(x, centroids, mask, k_mask):
    """One assignment: x [N,d], centroids [K,d] (unit), -> assign [N]."""
    sim = x @ centroids.T                                  # [N, K]
    sim = jnp.where(k_mask[None, :], sim, -jnp.inf)
    assign = jnp.argmax(sim, axis=-1).astype(jnp.int32)
    return jnp.where(mask, assign, 0)


def _update_centroids(x, assign, mask, centroids, k_mask):
    K = centroids.shape[0]
    w = mask.astype(x.dtype)
    sums = jax.ops.segment_sum(x * w[:, None], assign, num_segments=K)
    cnts = jax.ops.segment_sum(w, assign, num_segments=K)
    new = sums / jnp.maximum(cnts[:, None], 1e-9)
    new = _normalize(new)
    # empty clusters keep the old centroid
    keep = (cnts > 0)[:, None] & k_mask[:, None]
    return jnp.where(keep, new, centroids)


def kmeans_cluster(x, mask, k_target, k_max: int, n_iters: int = 10):
    """Cluster one document. Returns assign [N] into [0, k_max)."""
    x = _normalize(x.astype(jnp.float32))
    x = jnp.where(mask[:, None], x, 0.0)
    k_mask = jnp.arange(k_max) < jnp.maximum(k_target, 1)
    centroids = _normalize(_init_centroids(x, mask, k_max))

    def body(_, c):
        a = kmeans_assign_step(x, c, mask, k_mask)
        return _update_centroids(x, a, mask, c, k_mask)

    centroids = jax.lax.fori_loop(0, n_iters, body, centroids)
    return kmeans_assign_step(x, centroids, mask, k_mask)


@functools.partial(jax.jit, static_argnames=("factor", "n_iters"))
def kmeans_cluster_batch(x, mask, factor: int, n_iters: int = 10):
    """x: [B, N, d]; mask: [B, N] -> assign [B, N] (cluster ids < N//factor+1)."""
    N = x.shape[1]
    k_max = N // factor + 1
    n_valid = jnp.sum(mask.astype(jnp.int32), axis=-1)
    k = n_valid // factor + 1
    return jax.vmap(lambda xi, mi, ki: kmeans_cluster(
        xi, mi, ki, k_max=k_max, n_iters=n_iters))(x, mask, k)


# ---------------------------------------------------------------------------
# Flat (non-per-doc) k-means — IVF centroid training over all token vectors.
# Data-parallel friendly: the E-step/M-step stats are plain segment-sums, so
# under pjit with x sharded on the data axis XLA all-reduces the stats.
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("k", "n_iters"))
def kmeans_train(x, k: int, n_iters: int = 12, key=None):
    """x: [M, d] -> centroids [k, d] (unit-normalized)."""
    x = _normalize(x.astype(jnp.float32))
    M = x.shape[0]
    if key is None:
        key = jax.random.PRNGKey(0)
    perm = jax.random.permutation(key, M)[:k]
    c = x[perm]

    def body(_, c):
        sim = x @ c.T                                   # [M, k]
        a = jnp.argmax(sim, axis=-1)
        sums = jax.ops.segment_sum(x, a, num_segments=k)
        cnts = jax.ops.segment_sum(jnp.ones((M,), x.dtype), a, num_segments=k)
        new = _normalize(sums / jnp.maximum(cnts[:, None], 1e-9))
        return jnp.where((cnts > 0)[:, None], new, c)

    return jax.lax.fori_loop(0, n_iters, body, c)
