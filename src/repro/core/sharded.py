"""ShardedIndex: K capped MultiVectorIndex shards behind ONE logical index.

The paper shrinks the *stored* index; this module makes the stored index
scale past what one host buffer (or one build pass) can hold, the way
ColBERTv2/PLAID chunk their index construction. A ``ShardedIndex`` owns
an ordered list of ``MultiVectorIndex`` shards plus the global doc-id
partition:

  * shard ``s`` owns the contiguous global id range
    ``[doc_base[s], doc_base[s] + shards[s].n_docs)`` — ids are assigned
    in stream order, so a sharded build numbers documents exactly like
    the monolithic build it replaces;
  * ``add`` routes to the LAST shard and spills into a fresh shard when
    ``shard_max_vectors`` would be exceeded (only the last shard ever
    grows, so earlier ranges stay frozen);
  * ``delete`` maps global ids -> owning shard via the doc_base table
    (one ``searchsorted``, no per-id loop);
  * ``search_batch`` fans the batched two-stage engine out per shard —
    each shard produces its exact-MaxSim scored slate
    (``MultiVectorIndex.scored_candidates``) and immediately reduces it
    to a DEVICE-RESIDENT local top-k with global ids
    (``maxsim.topk_shard``) — then the merge concatenates the [Nq, k]
    blocks in shard order and takes one global top-k. Full-width slates
    (up to corpus-wide for dense shards) never cross the host boundary;
    only k entries per shard move, device-to-device when shards are
    placed (``place``). Per-shard top-k is lossless for the global
    top-k (a shard contributes at most k winners) and ``lax.top_k``
    orders ties by lowest position, so the merged result — ids, scores,
    AND tie order — is bit-identical to concat-then-top-k and therefore
    to the monolithic index.

Parity contract (locked by tests/test_sharded*.py + test_replicated*):
with every backend's candidate stage exhaustive (flat always;
hnsw_candidates / plaid nprobe + ndocs generous) and — for plaid — one
codec shared across shards (``MultiVectorIndex.set_codec``; the
streaming builder trains it on the first shard), ``search_batch``
returns exactly the monolithic result: same ids, same scores, same tie
order.

Shard probing fans out on a thread pool (``probe_threads``; 0 = auto =
``min(8, cpu_count)``, pinnable via ``ShardSpec.probe_threads``):
stage 1 is host-bound numpy for hnsw/plaid, so K shards probe
concurrently while the merge stays deterministic — per-shard top-k
blocks are collected back in shard order, so results are identical to
the sequential fan-out. When shards are ``place``d on devices
(core/replicated.py), each shard's stage-2 executables and lazy device
caches commit to its own device, so the fan-out is device-parallel,
not just thread-parallel. Per-shard probe times are returned per call
by ``search_batch_with_stats`` (concurrent batches each get their own
timings); ``last_probe_s`` keeps the last call's timings as a
convenience snapshot, written in one atomic assignment so a concurrent
reader never sees a half-built list.
"""
from __future__ import annotations

import contextlib
import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import BACKENDS, PARAM_KEYS, MultiVectorIndex
from repro.core.maxsim import topk_shard, topk_with_pads

# shard construction knobs forwarded verbatim to MultiVectorIndex — the
# same set the persistence manifest records (one definition for all
# three, owned by the spec layer: core/spec.py INDEX_PARAM_KEYS)
SHARD_PARAM_KEYS = PARAM_KEYS


def _resolve_probe_threads(probe_threads: int) -> int:
    """0 = auto (the historical ``min(8, cpu_count)`` default)."""
    pt = int(probe_threads)
    if pt < 0:
        raise ValueError(f"probe_threads must be >= 0 (0 = auto), "
                         f"got {probe_threads!r}")
    return pt if pt > 0 else min(8, os.cpu_count() or 1)


class ShardedIndex:
    """One logical multi-vector index over capped on-disk/in-memory shards."""

    def __init__(self, dim: int, backend: str = "plaid",
                 shard_max_vectors: int = 0, probe_threads: int = 0,
                 **index_kw):
        assert backend in BACKENDS, backend
        unknown = set(index_kw) - set(SHARD_PARAM_KEYS)
        assert not unknown, f"unknown shard params {sorted(unknown)}"
        self.dim = dim
        self.backend = backend
        self.shard_max_vectors = int(shard_max_vectors)  # 0 = uncapped
        self.index_kw: Dict = dict(index_kw)
        self.shards: List[MultiVectorIndex] = []
        self.doc_base: List[int] = []
        self.last_probe_s: List[float] = []
        # the SPEC value (0 = auto) persists through manifests; the
        # resolved worker count drives the pool
        self.probe_threads = _resolve_probe_threads(probe_threads)
        self.probe_threads_cfg = int(probe_threads)
        # per-shard jax devices (core/replicated.py ``place``); None =
        # default device for everything
        self.shard_devices: Optional[List] = None
        self._closed = False
        # created eagerly (no threads spawn until first submit) so
        # concurrent first searches can't race a lazy init; close()
        # releases the workers when the index is retired
        self._pool = ThreadPoolExecutor(
            max_workers=max(self.probe_threads, 1),
            thread_name_prefix="shard-probe")

    @classmethod
    def from_parts(cls, shards: Sequence[MultiVectorIndex],
                   doc_base: Sequence[int],
                   shard_max_vectors: int = 0,
                   probe_threads: int = 0) -> "ShardedIndex":
        """Adopt already-built shards (persistence / streaming build).

        ``doc_base`` must be the cumulative doc counts: base[0] == 0 and
        base[s+1] == base[s] + shards[s].n_docs.
        """
        assert len(shards) == len(doc_base)
        first = shards[0] if len(shards) else None
        kw = ({k: getattr(first, k) for k in SHARD_PARAM_KEYS}
              if first is not None else {})
        self = cls(dim=(first.dim if first is not None else 0),
                   backend=(first.backend if first is not None else "flat"),
                   shard_max_vectors=shard_max_vectors,
                   probe_threads=probe_threads, **kw)
        base = 0
        for s, b in zip(shards, doc_base):
            assert s.backend == self.backend and s.dim == self.dim
            assert int(b) == base, (b, base)
            base += s.n_docs
        self.shards = list(shards)
        self.doc_base = [int(b) for b in doc_base]
        return self

    # ------------------------------------------------------------- topology
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def n_docs(self) -> int:
        if not self.shards:
            return 0
        return self.doc_base[-1] + self.shards[-1].n_docs

    def n_vectors(self) -> int:
        return sum(s.n_vectors() for s in self.shards)

    def nbytes(self) -> int:
        return sum(s.nbytes() for s in self.shards)

    def device_bytes(self) -> int:
        """Device-resident bytes of the query-time doc representation,
        summed over shards (see ``MultiVectorIndex.device_bytes``)."""
        return sum(s.device_bytes() for s in self.shards)

    def shard_of(self, doc_ids: np.ndarray) -> np.ndarray:
        """Global doc ids -> owning shard index (vectorized). An empty
        id array is a well-typed no-op — an empty int array back — even
        on an empty index (the CRUD paths route nothing)."""
        ids = np.asarray(doc_ids, np.int64)
        if ids.size == 0:
            return np.zeros(ids.shape, np.int64)
        if not self.shards:
            raise IndexError("empty sharded index")
        if ids.min() < 0 or ids.max() >= self.n_docs:
            raise IndexError(f"doc id out of range [0, {self.n_docs})")
        return np.searchsorted(np.asarray(self.doc_base, np.int64), ids,
                               side="right") - 1

    def codec(self):
        """The shared plaid residual codec (None for other backends)."""
        for s in self.shards:
            if s._plaid is not None:
                return s._plaid.codec
        return None

    # ------------------------------------------------------------- placement
    def place(self, devices: Optional[Sequence]) -> None:
        """Pin shard ``s``'s stage-2 compute — and the device caches it
        builds lazily (padded stores, packed code views) — to
        ``devices[s]``. ``None`` clears placement (default device).
        The next ``warm_shapes`` traces per placed device; results are
        bitwise identical wherever shards land (the merge re-collects
        in shard order)."""
        if devices is None:
            self.shard_devices = None
            return
        devices = list(devices)
        assert len(devices) == len(self.shards), \
            (len(devices), len(self.shards))
        self.shard_devices = devices

    def _shard_device(self, i: int):
        return self.shard_devices[i] if self.shard_devices else None

    def set_probe_threads(self, probe_threads: int) -> None:
        """Re-pin the probe fan-out width after construction — e.g. the
        serving router divides the auto default across replica lanes so
        ``lanes x probe_threads`` never oversubscribes the host. Swaps
        in a fresh pool; in-flight probes finish on the old one."""
        new = _resolve_probe_threads(probe_threads)
        old = self._pool
        self._pool = ThreadPoolExecutor(
            max_workers=max(new, 1), thread_name_prefix="shard-probe")
        self.probe_threads = new
        self.probe_threads_cfg = int(probe_threads)
        old.shutdown(wait=False)

    def set_probe_kernel(self, probe_kernel: str) -> None:
        """Fan the RUNTIME-ONLY plaid candidate-path toggle to every
        shard (same non-persisted contract as ``packed_rerank``). Each
        shard's ``device_probe_plan`` still decides independently — a
        shard whose geometry fails the bitwise-safety proof keeps the
        host path."""
        from repro.core.plaid import PROBE_KERNELS
        assert probe_kernel in PROBE_KERNELS, probe_kernel
        for shard in self.shards:
            shard.probe_kernel = probe_kernel

    # ----------------------------------------------------------------- build
    def _new_shard(self) -> MultiVectorIndex:
        shard = MultiVectorIndex(dim=self.dim, backend=self.backend,
                                 **self.index_kw)
        if self.backend == "plaid":
            codec = self.codec()
            if codec is not None:       # ONE quantization model per index
                shard.set_codec(codec)
        self.doc_base.append(self.n_docs)
        self.shards.append(shard)
        if self.shard_devices is not None:      # growth drops placement
            self.shard_devices = None
        return shard

    def add(self, doc_vectors: List[np.ndarray]) -> np.ndarray:
        """Append docs; spills into new shards at ``shard_max_vectors``.

        Returns GLOBAL doc ids — contiguous, in input order, regardless
        of how the docs land on shards. An empty input is a no-op
        returning an empty id array.
        """
        doc_vectors = [np.asarray(v, np.float32).reshape(-1, self.dim)
                       for v in doc_vectors]
        out: List[np.ndarray] = []
        lens = [len(v) for v in doc_vectors]
        i = 0
        while i < len(doc_vectors):
            shard = self.shards[-1] if self.shards else self._new_shard()
            cap = self.shard_max_vectors
            if cap:
                room = cap - shard.n_vectors()
                j = i
                used = 0
                # docs are atomic: take at least one into an empty shard
                while j < len(doc_vectors) and (
                        used + lens[j] <= room or (j == i and
                                                   shard.n_docs == 0)):
                    used += lens[j]
                    j += 1
                if j == i:              # shard full: spill to a fresh one
                    self._new_shard()
                    continue
            else:
                j = len(doc_vectors)
            base = self.doc_base[-1]
            out.append(base + shard.add(doc_vectors[i:j]))
            i = j
        return (np.concatenate(out) if out else np.zeros((0,), np.int64))

    def delete(self, doc_ids) -> None:
        ids = np.asarray(doc_ids, np.int64).reshape(-1)
        if ids.size == 0:
            return
        owner = self.shard_of(ids)
        for s in np.unique(owner):
            local = ids[owner == s] - self.doc_base[s]
            self.shards[s].delete(local)

    # ------------------------------------------------------------ persistence
    def save(self, path: str, extra_meta: Optional[dict] = None) -> dict:
        """Root manifest + one artifact dir per shard (core/persist.py)."""
        from repro.core import persist
        return persist.save_sharded(self, path, extra_meta=extra_meta)

    @classmethod
    def load(cls, path: str, mmap: bool = True) -> "ShardedIndex":
        from repro.core import persist
        return persist.load_sharded(path, mmap=mmap)

    # ----------------------------------------------------------------- search
    def warm_shapes(self, qs: np.ndarray, k: int = 10) -> None:
        """Pre-compile the candidate-width ladder on every shard plus
        the per-shard device top-k (``topk_shard``) at every reachable
        slate width AND the merged top-k for this batch shape (serving
        warmup). Runs under each shard's placed device, so per-device
        executable caches fill before traffic."""
        qs = np.asarray(qs, np.float32)
        Nq = len(qs)
        for i, (base, shard) in enumerate(zip(self.doc_base, self.shards)):
            dev = self._shard_device(i)
            ctx = (jax.default_device(dev) if dev is not None
                   else contextlib.nullcontext())
            with ctx:
                shard.warm_shapes(qs, k=k)
                if shard.n_docs == 0:
                    continue
                widths, dense = shard.candidate_widths(qs)
                for C in widths:
                    topk_shard(jnp.full((Nq, C), -jnp.inf, jnp.float32),
                               np.zeros((Nq, C), np.int64), k, base)
                if dense:
                    topk_shard(jnp.full((Nq, shard.n_docs), -jnp.inf,
                                        jnp.float32), None, k, base)
        self.search_batch(qs, k=k)

    def _probe_shard(self, base: int, shard: MultiVectorIndex,
                     qs: np.ndarray, q_mask, k: int, dev=None):
        """One shard's device-resident local top-k with GLOBAL ids, plus
        its probe wall time — the unit the thread pool fans out. Under
        ``dev`` (when placed) every device array this touches — the
        shard's lazy caches included — commits to that device."""
        t0 = time.perf_counter()
        ctx = (jax.default_device(dev) if dev is not None
               else contextlib.nullcontext())
        with ctx:
            scores, cand = shard.scored_candidates(qs, q_mask)
            top_s, top_i = topk_shard(scores, cand, k, base)
        dt = time.perf_counter() - t0
        return top_s, top_i, dt

    def close(self) -> None:
        """Release the probe thread pool (idempotent). Called when a
        serving runtime retires a hot-swapped-out generation — without
        it, every swapped-in sharded index would leak its workers for
        the life of the process. A closed index still serves (the
        fan-out degrades to sequential probing)."""
        self._closed = True
        self._pool.shutdown(wait=False)

    @property
    def closed(self) -> bool:
        return self._closed

    def search_batch_with_stats(
            self, qs: np.ndarray, k: int = 10,
            q_mask: Optional[np.ndarray] = None
    ) -> Tuple[np.ndarray, np.ndarray, List[float]]:
        """``search_batch`` plus this call's per-shard probe seconds.

        Fan-out: each live shard runs candidates + exact rerank and
        reduces its scored slate to a device-local top-k with global
        ids — on the shard thread pool when more than one live shard
        and ``probe_threads > 1`` (stage 1 is host-bound numpy for
        hnsw/plaid, so shards probe concurrently), each under its
        placed device when ``place``d. Merge: the [Nq, k] blocks are
        collected IN SHARD ORDER, moved device-to-device onto the first
        live shard's device (a no-op when unplaced), concatenated along
        the candidate axis, and one shared top-k picks the global
        winners — thread scheduling can never reorder the merge, and
        per-shard top-k loses no candidate a global top-k could keep,
        so results match the sequential concat-everything fan-out
        exactly. Probe times are per-call state: concurrent batches
        each get their own list (the thread-safety contract
        ``last_probe_s`` alone could not provide).
        """
        qs = np.asarray(qs, np.float32)
        Nq = len(qs)
        live = [(base, shard, self._shard_device(i))
                for i, (base, shard) in enumerate(
                    zip(self.doc_base, self.shards)) if shard.n_docs > 0]
        if len(live) > 1 and self.probe_threads > 1 and not self._closed:
            futs = [self._pool.submit(self._probe_shard, base, shard,
                                      qs, q_mask, k, dev)
                    for base, shard, dev in live]
            parts = [f.result() for f in futs]
        else:
            parts = [self._probe_shard(base, shard, qs, q_mask, k, dev)
                     for base, shard, dev in live]
        probe_s = []
        it = iter(parts)
        for shard in self.shards:
            probe_s.append(0.0 if shard.n_docs == 0 else next(it)[2])
        if not parts:
            return (np.full((Nq, k), -np.inf, np.float32),
                    np.full((Nq, k), -1, np.int64), probe_s)
        if len(parts) == 1:
            top_s, top_i = parts[0][0], parts[0][1]
        else:
            md = live[0][2]             # merge device (None = default)
            ss = [p[0] if md is None else jax.device_put(p[0], md)
                  for p in parts]
            ii = [p[1] if md is None else jax.device_put(p[1], md)
                  for p in parts]
            top_s = jnp.concatenate(ss, axis=1)
            top_i = jnp.concatenate(ii, axis=1)
        S, I = topk_with_pads(top_s, top_i, k)
        return S, I, probe_s

    def search_batch(self, qs: np.ndarray, k: int = 10,
                     q_mask: Optional[np.ndarray] = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """qs [Nq, Lq, dim] -> (scores [Nq, k], ids [Nq, k]; -inf/-1 pads).

        See ``search_batch_with_stats`` for the fan-out/merge contract;
        this drops the probe stats, keeping only the ``last_probe_s``
        snapshot (one atomic assignment — safe to read, but concurrent
        callers needing *their* timings should use the stats variant).
        """
        S, I, probe_s = self.search_batch_with_stats(qs, k=k, q_mask=q_mask)
        self.last_probe_s = probe_s
        return S, I

    def search(self, q: np.ndarray, k: int = 10
               ) -> Tuple[np.ndarray, np.ndarray]:
        """q: [Lq, dim] -> (scores [k'], doc ids [k'])."""
        S, I = self.search_batch(np.asarray(q, np.float32)[None], k=k)
        valid = I[0] >= 0
        return S[0][valid], I[0][valid]
