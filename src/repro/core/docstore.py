"""Device-resident CSR document store for multi-vector retrieval.

Every index backend used to keep its own list-of-numpy copy of the
per-document token vectors and re-pad the whole corpus on every query.
``DocStore`` replaces that with one flat ``[capacity, dim]`` vector
tensor plus CSR doc offsets, grown by amortized doubling on ``add``, and
a *cached* padded ``[n_docs, doc_maxlen, dim]`` device view that flat
search and candidate rerank gather from without ever re-padding.

Layout:
  * ``_flat``     [capacity >= n_vectors, dim] float32 — token vectors,
                  doc-major (host mirror; the device copy is cached).
  * ``offsets``   [n_docs + 1] int64 — doc d owns rows
                  ``offsets[d]:offsets[d+1]``.
  * ``live``      [n_docs] bool — False once a doc is deleted (lazy
                  delete; storage is reclaimed only by rebuild).

The padded view is rebuilt at most once per mutation epoch and lives on
device as jnp arrays, so a batch of queries pays zero host->device
transfer for the corpus.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


def ragged_arange(counts: np.ndarray) -> np.ndarray:
    """[0..c0), [0..c1), ... concatenated: the CSR scatter index.

    e.g. counts [2, 0, 3] -> [0, 1, 0, 1, 2].
    """
    counts = np.asarray(counts)
    total = int(counts.sum())
    return np.arange(total) - np.repeat(np.cumsum(counts) - counts, counts)


class DocStore:
    def __init__(self, dim: int, doc_maxlen: int = 256,
                 init_capacity: int = 1024):
        self.dim = dim
        self.doc_maxlen = doc_maxlen
        self._flat = np.zeros((max(init_capacity, 1), dim), np.float32)
        self._n_vectors = 0
        self.offsets = np.zeros((1,), np.int64)
        self.live = np.zeros((0,), bool)
        self._padded: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None

    @classmethod
    def from_arrays(cls, flat: np.ndarray, offsets: np.ndarray,
                    live: np.ndarray, doc_maxlen: int = 256) -> "DocStore":
        """Adopt persisted arrays without copying (core/persist.py).

        ``flat`` may be a read-only memmap: reads (``padded``/``doc``)
        work in place, and any growing ``add`` copies into a fresh
        writable buffer via ``_reserve`` (capacity == n_vectors here,
        so the first non-empty add always grows). ``live`` is mutated
        by ``delete`` and must be writable.
        """
        self = cls.__new__(cls)
        self.dim = int(flat.shape[1]) if flat.ndim == 2 else 0
        self.doc_maxlen = doc_maxlen
        self._n_vectors = int(offsets[-1]) if len(offsets) else 0
        # len-0 capacity would deadlock _reserve's doubling loop
        self._flat = (flat if len(flat)
                      else np.zeros((1, max(self.dim, 1)), np.float32))
        self.offsets = np.array(offsets, np.int64)
        self.live = np.array(live, bool)
        self._padded = None
        return self

    # ------------------------------------------------------------- sizes
    @property
    def n_docs(self) -> int:
        return len(self.offsets) - 1

    def doc_lengths(self) -> np.ndarray:
        return np.diff(self.offsets)

    def n_vectors(self, live_only: bool = True) -> int:
        if not live_only:
            return self._n_vectors
        return int(self.doc_lengths()[self.live].sum())

    def nbytes(self, bytes_per_dim: int = 2, live_only: bool = True) -> int:
        """Footprint of the stored vectors (fp16 by default)."""
        return self.n_vectors(live_only) * self.dim * bytes_per_dim

    def device_nbytes(self) -> int:
        """Bytes of the padded device view ([n, L, dim] f32 + [n, L]
        mask) — computed from shapes, without materializing the view."""
        n = self.n_docs
        if n == 0:
            return 0
        lens = self.doc_lengths()
        L = int(min(self.doc_maxlen, max(lens.max(initial=0), 1)))
        return max(n, 1) * L * (self.dim * 4 + 1)

    # -------------------------------------------------------------- CRUD
    def add(self, doc_vectors: Sequence[np.ndarray]) -> np.ndarray:
        """Append docs (list of [n_i, dim]); returns their ids."""
        ids = np.arange(self.n_docs, self.n_docs + len(doc_vectors))
        if len(doc_vectors) == 0:
            return ids
        lens = np.array([len(v) for v in doc_vectors], np.int64)
        total = int(lens.sum())
        self._reserve(self._n_vectors + total)
        if total:
            flat = np.concatenate(
                [np.asarray(v, np.float32).reshape(-1, self.dim)
                 for v in doc_vectors])
            self._flat[self._n_vectors:self._n_vectors + total] = flat
        self._n_vectors += total
        self.offsets = np.concatenate(
            [self.offsets, self.offsets[-1] + np.cumsum(lens)])
        self.live = np.concatenate(
            [self.live, np.ones(len(doc_vectors), bool)])
        self._padded = None
        return ids

    def _reserve(self, n: int) -> None:
        cap = len(self._flat)
        if n <= cap:
            return
        while cap < n:
            cap *= 2
        grown = np.zeros((cap, self.dim), np.float32)
        grown[:self._n_vectors] = self._flat[:self._n_vectors]
        self._flat = grown

    def delete(self, doc_ids) -> None:
        """Lazy delete: docs stay in storage but drop out of ``live``."""
        ids = np.asarray(doc_ids, np.int64)
        self.live[ids] = False
        # padded cache stays valid — deletion is a query-time mask

    # ------------------------------------------------------------- reads
    def doc(self, i: int) -> np.ndarray:
        lo, hi = self.offsets[i], self.offsets[i + 1]
        return self._flat[lo:hi]

    def docs_list(self) -> List[np.ndarray]:
        """Per-doc arrays (all docs, deleted included) — compat view."""
        return [self.doc(i) for i in range(self.n_docs)]

    def padded(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Cached device view: ([n_docs, L, dim], [n_docs, L]) where L is
        the tightest width, min(doc_maxlen, longest doc) — pooled stores
        (short docs) should not pay doc_maxlen-wide scans."""
        if self._padded is None:
            n = self.n_docs
            lens = self.doc_lengths()
            L = int(min(self.doc_maxlen, max(lens.max(initial=0), 1)))
            out = np.zeros((max(n, 1), L, self.dim), np.float32)
            mask = np.zeros((max(n, 1), L), bool)
            if n and self._n_vectors:
                kept = np.minimum(lens, L)
                rows = np.repeat(np.arange(n), kept)
                cols = ragged_arange(kept)
                src = np.repeat(self.offsets[:-1], kept) + cols
                out[rows, cols] = self._flat[src]
                mask[rows, cols] = True
            self._padded = (jnp.asarray(out), jnp.asarray(mask))
        return self._padded

    def gather(self, cand: np.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """cand [Nq, C] doc ids -> ([Nq, C, L, dim], [Nq, C, L]) on device."""
        d, m = self.padded()
        idx = jnp.asarray(np.asarray(cand, np.int64))
        return jnp.take(d, idx, axis=0), jnp.take(m, idx, axis=0)


def pad_candidate_sets(qidx: np.ndarray, docs: np.ndarray, n_queries: int,
                       block: int = 32) -> Tuple[np.ndarray, np.ndarray]:
    """(query, doc) id pairs -> padded candidate matrix, no per-query loop.

    qidx/docs: parallel int arrays, grouped by query (stable order within
    a query is preserved). Returns (cand [Nq, C], mask [Nq, C]) with C
    rounded up to a ``block`` multiple so downstream jit shapes re-use.
    """
    counts = np.bincount(qidx, minlength=n_queries)
    C = max(int(counts.max(initial=0)), 1)
    # geometric rounding: log-many distinct C values -> log-many jit traces
    C = block << max(int(np.ceil(np.log2(-(-C // block)))), 0)
    cand = np.zeros((n_queries, C), np.int64)
    mask = np.arange(C)[None, :] < counts[:, None]
    cand[qidx, ragged_arange(counts)] = docs
    return cand, mask
