"""Index facade: Flat | HNSW | PLAID behind one add/delete/search interface.

The paper's two experimental settings map to:
  * ``hnsw``  — 16-bit unpooled/pooled vectors in a token-level HNSW graph
                (paper uses VOYAGER); stage 2 exact rerank over stored vectors.
  * ``plaid`` — 2-bit residual-quantized vectors behind IVF probing.
  * ``flat``  — exact MaxSim over everything (the oracle; small corpora only).

All three store *token* vectors grouped by document and return document ids,
so the evaluation harness is backend-agnostic. Pooling happens upstream
(retrieval/indexer.py) — the index only ever sees the (possibly pooled)
per-document vector lists. CRUD: ``add`` appends docs, ``delete`` removes
them (all backends delete lazily; compaction via rebuild).

Serving is a batched two-stage engine over a device-resident ``DocStore``:

    candidates(qs)  -> per-query candidate doc ids   (stage 1, backend-specific)
    rerank(qs, ...) -> exact MaxSim on the gathered candidates (stage 2, shared)

Stage 1 is batched centroid probing (PLAID: one einsum for the whole
batch), batched HNSW token probes with a vectorized candidate-set union,
or — for flat — the whole live corpus. Stage 2 is ONE fixed-shape MaxSim
batch per query microbatch (the Pallas ``kernels/maxsim`` op on TPU, its
jnp oracle elsewhere); no backend re-pads the corpus at query time.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.docstore import DocStore, pad_candidate_sets
from repro.core.hnsw import HNSW
from repro.core.ivf import train_centroids
from repro.core.maxsim import (maxsim_all_docs, maxsim_rerank_store,
                               topk_with_pads)
from repro.core.plaid import (PLAIDIndex, PROBE_KERNELS, build_plaid_index,
                              device_probe_plan, maxsim_packed_rerank_store,
                              plaid_candidates)
from repro.core.quantization import train_codec
from repro.core.spec import INDEX_PARAM_KEYS

BACKENDS = ("flat", "hnsw", "plaid")

# Construction knobs shared by persistence (manifest params) and sharding
# (per-shard construction). The defining copy lives in core/spec.py —
# the typed spec layer every surface (Indexer, manifests, CLI) derives
# from; this re-export keeps the long-standing import site working.
PARAM_KEYS = INDEX_PARAM_KEYS


@dataclass
class MultiVectorIndex:
    """Late-interaction index over per-document token-vector lists."""
    dim: int
    backend: str = "plaid"
    doc_maxlen: int = 256
    # PLAID params
    n_centroids: int = 256
    quant_bits: int = 2
    nprobe: int = 8
    t_cs: float = 0.3
    ndocs: int = 8192
    # HNSW params (paper Appendix A)
    hnsw_m: int = 12
    hnsw_ef_construction: int = 200
    hnsw_candidates: int = 1024    # token hits gathered before doc rerank
    # Serving toggle (not a construction param; never persisted): plaid
    # rerank straight from packed codes vs. the legacy f32 reconstruction
    # store. Both produce bitwise-identical scores — False exists for the
    # parity tests and for debugging against the decoded view.
    packed_rerank: bool = True
    # Serving toggle (RUNTIME-ONLY, never persisted — same contract as
    # ``packed_rerank``): plaid candidate generation on device
    # ("auto"/"device", see ``plaid.device_probe_plan``) vs the host
    # numpy reference ("host"). Both produce bitwise-identical slates.
    probe_kernel: str = "auto"

    # state
    deleted: set = field(default_factory=set)
    _store: Optional[DocStore] = None
    _hnsw: Optional[HNSW] = None
    _hnsw_vec2doc: Optional[np.ndarray] = None
    _plaid: Optional[PLAIDIndex] = None
    _preset_codec: Optional[object] = field(default=None, repr=False)
    _live_dev_cache: Optional[jnp.ndarray] = field(default=None, repr=False)

    def __post_init__(self):
        assert self.backend in BACKENDS, self.backend
        assert self.probe_kernel in PROBE_KERNELS, self.probe_kernel
        if self.backend != "plaid":
            self._store = DocStore(self.dim, self.doc_maxlen)

    # ------------------------------------------------------------ doc store
    @property
    def store(self) -> DocStore:
        """The DocStore dense/corpus-wide scoring reads from.

        flat/hnsw: the raw stored vectors; plaid: the decoded
        reconstruction CACHE — touching this property materializes it
        (O(corpus) decode + f32 residency), which the packed candidate
        rerank never does. Only the dense path (cand width >= n_docs;
        tiny corpora) and debug/compat views should land here.
        """
        if self.backend == "plaid":
            assert self._plaid is not None, "empty plaid index"
            return self._plaid.recon_store()
        return self._store

    @property
    def n_docs(self) -> int:
        if self.backend == "plaid":
            return self._plaid.n_docs if self._plaid is not None else 0
        return self._store.n_docs

    @property
    def docs(self) -> List[np.ndarray]:
        """Compat view: per-doc vector arrays (deleted docs included).

        NOTE: for the plaid backend these are the codec's decoded
        *reconstructions* (what rerank scores), not the raw inputs —
        the raw vectors are not retained; first access also builds the
        reconstruction store (O(corpus) decode).
        """
        if self.backend == "plaid":
            return (self.store.docs_list() if self._plaid is not None
                    else [])
        return self._store.docs_list()

    def _live(self) -> np.ndarray:
        """[n_docs] bool — True for docs that can still be returned.

        flat/hnsw read the DocStore's live mask (single source of truth,
        shared with nbytes/n_vectors); plaid keeps no raw store, so its
        liveness comes from the ``deleted`` set.
        """
        if self._store is not None:
            return self._store.live.copy()
        live = np.ones(self.n_docs, bool)
        if self.deleted:
            live[np.fromiter(self.deleted, np.int64)] = False
        return live

    def _live_dev(self) -> jnp.ndarray:
        """Device-cached live mask for the zero-hop candidate path —
        shipped once per mutation epoch instead of once per query."""
        if self._live_dev_cache is None:
            self._live_dev_cache = jnp.asarray(self._live())
        return self._live_dev_cache

    def _probe_plan(self, Lq: int):
        """The device candidate-path decision for this query length
        (see ``plaid.device_probe_plan``)."""
        if self.backend != "plaid" or self._plaid is None:
            return False, None
        return device_probe_plan(self._plaid, Lq, self.nprobe, self.ndocs,
                                 self.probe_kernel)

    # ------------------------------------------------------------------ build
    def add(self, doc_vectors: List[np.ndarray]) -> np.ndarray:
        """doc_vectors: list of [n_i, dim] unit vectors. Returns doc ids."""
        if len(doc_vectors) == 0:
            return np.zeros((0,), np.int64)     # no-op on every backend
        doc_vectors = [np.asarray(v, np.float32).reshape(-1, self.dim)
                       for v in doc_vectors]
        ids = np.arange(self.n_docs, self.n_docs + len(doc_vectors))
        if self.backend == "hnsw":
            self._store.add(doc_vectors)
            self._add_hnsw(doc_vectors, ids)
        elif self.backend == "plaid":
            self._add_plaid(doc_vectors)
        else:
            self._store.add(doc_vectors)
        self._live_dev_cache = None
        return ids

    def _add_hnsw(self, doc_vectors, ids):
        if self._hnsw is None:
            self._hnsw = HNSW(self.dim, m=self.hnsw_m,
                              ef_construction=self.hnsw_ef_construction)
            self._hnsw_vec2doc = np.zeros((0,), np.int64)
        flat = np.concatenate(doc_vectors)
        self._hnsw.add(flat)
        lens = np.array([len(v) for v in doc_vectors], np.int64)
        self._hnsw_vec2doc = np.concatenate(
            [self._hnsw_vec2doc, np.repeat(ids, lens)])

    def set_codec(self, codec) -> None:
        """Preset the plaid residual codec instead of training on the
        first ``add``. This is how shards of one logical index share ONE
        quantization model (core/sharded.py): identical centroids make
        per-shard candidate generation equivalent to monolithic probing,
        and identical reconstructions make merged scores comparable
        bit-for-bit across shards."""
        assert self.backend == "plaid", self.backend
        assert self._plaid is None, "codec must be preset before add"
        self._preset_codec = codec

    def _add_plaid(self, doc_vectors):
        if self._plaid is None:
            if self._preset_codec is not None:
                codec = self._preset_codec
            else:
                flat = np.concatenate(doc_vectors)
                k = min(self.n_centroids, len(flat))
                centroids = train_centroids(flat, k)
                codec = train_codec(jnp.asarray(flat), centroids,
                                    bits=self.quant_bits)
            self._plaid = build_plaid_index(doc_vectors, codec,
                                            self.doc_maxlen)
        else:
            self._plaid.add(doc_vectors)

    # ------------------------------------------------------------ persistence
    def save(self, path: str, extra_meta: Optional[dict] = None) -> dict:
        """Write a versioned artifact directory (core/persist.py);
        lazily-deleted docs are compacted out of the payload bytes.
        Returns the manifest."""
        from repro.core import persist
        return persist.save_index(self, path, extra_meta=extra_meta)

    @classmethod
    def load(cls, path: str, mmap: bool = True) -> "MultiVectorIndex":
        """Reconstruct an index from ``save``'s directory. With
        ``mmap=True`` the payloads stay on disk (zero-copy) until the
        first search touches them."""
        from repro.core import persist
        return persist.load_index(path, mmap=mmap)

    def delete(self, doc_ids) -> None:
        self.deleted.update(int(i) for i in doc_ids)
        if self.backend == "hnsw" and self._hnsw is not None:
            tok = np.nonzero(np.isin(self._hnsw_vec2doc,
                                     np.asarray(doc_ids)))[0]
            self._hnsw.delete(tok)
        if self._store is not None:
            self._store.delete(np.asarray(doc_ids, np.int64))
        self._live_dev_cache = None
        # plaid filters deleted ids at candidate time (compaction = rebuild)

    # ------------------------------------------------- two-stage batch engine
    def candidates(self, qs: np.ndarray,
                   q_mask: Optional[np.ndarray] = None
                   ) -> Tuple[Optional[np.ndarray], Optional[np.ndarray]]:
        """Stage 1: qs [Nq, Lq, dim] -> (cand [Nq, C], mask [Nq, C]).

        Returns ``(None, None)`` for the flat backend: every live doc is
        a candidate and rerank scores the shared corpus view directly
        (an all-pairs matmul beats an Nq-fold gather of the corpus).
        Masked query tokens (q_mask False) are excluded from probing and
        approximate scoring, matching the rerank-stage semantics.
        """
        if self.backend == "flat":
            return None, None
        if self.backend == "plaid":
            use_dev, _ = self._probe_plan(np.asarray(qs).shape[1])
            live = self._live_dev() if use_dev else self._live()
            return plaid_candidates(self._plaid, qs, nprobe=self.nprobe,
                                    t_cs=self.t_cs, ndocs=self.ndocs,
                                    live=live, q_mask=q_mask,
                                    probe_kernel=self.probe_kernel)
        return self._hnsw_candidates(qs, q_mask)

    def _hnsw_candidates(self, qs: np.ndarray, q_mask=None):
        """Batched token probes + vectorized candidate-set union."""
        Nq, Lq = qs.shape[:2]
        per_tok = max(self.hnsw_candidates // max(Lq, 1), 8)
        vec_ids = self._hnsw.probe_tokens(
            np.asarray(qs, np.float32).reshape(Nq * Lq, self.dim), per_tok)
        hit = vec_ids >= 0                                 # [Nq*Lq, per_tok]
        if q_mask is not None:     # masked tokens probe nothing
            hit &= np.asarray(q_mask, bool).reshape(Nq * Lq, 1)
        qidx = np.repeat(np.arange(Nq), Lq * per_tok)[hit.ravel()]
        docs = self._hnsw_vec2doc[vec_ids[hit]]
        qd = np.unique(qidx * np.int64(max(self.n_docs, 1)) + docs)
        qidx, docs = qd // max(self.n_docs, 1), qd % max(self.n_docs, 1)
        live = self._live()
        keep = live[docs]
        return pad_candidate_sets(qidx[keep], docs[keep], Nq)

    def rerank(self, qs: np.ndarray, cand: Optional[np.ndarray] = None,
               cand_mask: Optional[np.ndarray] = None,
               q_mask: Optional[np.ndarray] = None) -> jnp.ndarray:
        """Stage 2 (shared): exact MaxSim on gathered candidates.

        One traced fixed-shape batch per call; invalid/padded candidate
        slots come back as -inf. ``cand=None`` scores the whole live
        corpus (scores [Nq, n_docs]); otherwise scores [Nq, C].
        """
        qs = jnp.asarray(qs, jnp.float32)
        qm = (jnp.ones(qs.shape[:2], bool) if q_mask is None
              else jnp.asarray(q_mask))
        if cand is None:
            # corpus-wide dense scoring stays on the f32 view: at this
            # width the decoded store is read Nq times per batch, so the
            # one-off reconstruction cache pays for itself (tiny-corpus
            # regime — see README "Compressed-domain rerank")
            d, dm = self.store.padded()
            scores = maxsim_all_docs(qs, qm, d, dm)        # [Nq, n_docs]
            return jnp.where(jnp.asarray(self._live())[None, :],
                             scores, -jnp.inf)
        if (self.backend == "plaid" and self._plaid is not None
                and self.packed_rerank):
            return maxsim_packed_rerank_store(self._plaid, qs, qm,
                                              cand, cand_mask)
        if not isinstance(cand, np.ndarray):    # legacy store path is
            cand = np.asarray(cand, np.int64)   # host-indexed
            cand_mask = np.asarray(cand_mask)
        return maxsim_rerank_store(self.store, qs, qm, cand, cand_mask)

    def _rerank_dense(self, qs, cand, cand_mask, q_mask) -> jnp.ndarray:
        """Dense-candidate rerank: when the padded candidate width reaches
        corpus size, an Nq-fold gather repeats most of the corpus per
        query — one shared all-pairs scan + a membership mask is cheaper.
        Returns scores [Nq, n_docs] (-inf outside each query's set)."""
        scores = self.rerank(qs, None, None, q_mask)   # [Nq, n_docs]
        member = np.zeros((len(cand), self.n_docs), bool)
        rows = np.repeat(np.arange(len(cand)),
                         cand.shape[1])[np.asarray(cand_mask).ravel()]
        member[rows, cand[cand_mask]] = True
        return jnp.where(jnp.asarray(member), scores, -jnp.inf)

    def scored_candidates(self, qs: np.ndarray,
                          q_mask: Optional[np.ndarray] = None
                          ) -> Tuple[jnp.ndarray, Optional[np.ndarray]]:
        """Both stages, no top-k: the per-index *scored slate*.

        Returns ``(scores [Nq, C], cand [Nq, C] | None)`` — exact MaxSim
        for every surviving candidate, -inf on invalid slots. ``cand``
        is None when the scores are corpus-wide (ids = column index):
        the flat backend, or a candidate set grown to corpus width
        (dense rerank beats an Nq-fold gather there). This is the unit
        ``ShardedIndex`` fans out per shard before its global merge;
        ``search_batch`` is just slate -> top-k.

        Within each query row, finite slots are ordered by ascending doc
        id (column index when dense; sorted unique ids otherwise) —
        except after plaid's approximate prune (cand count > ndocs),
        which reorders survivors by approximate score. Under an
        exhaustive candidate budget, top-k tie-breaking is id-stable.
        """
        qs = np.asarray(qs, np.float32)
        cand, cand_mask = self.candidates(qs, q_mask)
        if cand is not None and cand.shape[1] >= self.n_docs:
            return self._rerank_dense(qs, cand, cand_mask, q_mask), None
        return self.rerank(qs, cand, cand_mask, q_mask), cand

    def candidate_widths(self, qs: np.ndarray
                         ) -> Tuple[List[int], bool]:
        """Slate widths a stream at this batch shape can reach.

        Returns ``(widths, dense)``: the geometric pad ladder
        {32, 64, ...} (``pad_candidate_sets``) capped by the stage-1
        candidate budget (plaid: ndocs before the prune; hnsw: the
        token-probe hit bound) plus plaid's post-prune block-padded
        width, RESTRICTED to widths below ``n_docs`` — wider sets
        dispatch to the dense corpus-wide path, whose reachability is
        the ``dense`` flag. The contract ``warm_shapes`` and the
        sharded/replicated merge warms trace against.
        """
        if self.n_docs == 0:
            return [], False
        if self.backend == "flat":
            return [], True                 # dense only
        qs = np.asarray(qs, np.float32)
        block = 32                          # pad_candidate_sets block
        if self.backend == "plaid":
            use_dev, geom = self._probe_plan(qs.shape[1])
            if use_dev:
                # device pipeline: ONE static slate width (s_out), and
                # the plan proved the dense dispatch unreachable
                return [geom[3]], False
            cap = min(self.n_docs, self.ndocs)
        else:
            Lq = max(qs.shape[1], 1)
            per_tok = max(self.hnsw_candidates // Lq, 8)
            cap = min(self.n_docs, per_tok * Lq)
        widths = set()
        C = block
        while C < cap:
            widths.add(C)
            C <<= 1
        widths.add(C)                       # first ladder value >= cap
        if self.backend == "plaid":         # post-prune width
            widths.add(-(-min(self.ndocs, self.n_docs) // block) * block)
        return (sorted(w for w in widths if w < self.n_docs),
                max(widths) >= self.n_docs)

    def warm_shapes(self, qs: np.ndarray, k: int = 10) -> None:
        """Pre-compile every executable a serving stream at this query
        batch shape can hit — including the CANDIDATE-width axis.

        ``search_batch`` shapes depend on data: stage 1 yields a padded
        candidate matrix whose width C walks ``candidate_widths`` or
        the dense corpus-wide path once C reaches ``n_docs``. A width
        first seen mid-stream costs an XLA compile (hundreds of ms on
        CPU) that lands straight in some query's tail latency. Serving
        runtimes (launch/engine.py) call this at warmup per shape
        bucket so the whole ladder is traced before traffic."""
        qs = np.asarray(qs, np.float32)
        if self.n_docs == 0:
            return
        self.search_batch(qs, k=k)          # stage-1 + one organic path
        if self.backend == "flat":
            return                          # dense only: already warm
        Nq = len(qs)
        widths, dense = self.candidate_widths(qs)
        for C in widths:
            cand = np.zeros((Nq, C), np.int64)   # doc 0: shape-only work
            mask = np.ones((Nq, C), bool)
            scores = self.rerank(qs, cand, mask)
            topk_with_pads(scores, cand, k)
        if (self.backend == "plaid" and self._plaid is not None
                and not self._probe_plan(qs.shape[1])[0]):
            # host path only: the device pipeline is ONE executable per
            # (Nq, Lq) — lax.cond traces both prune branches — so the
            # organic search above already compiled everything
            self._warm_plaid_prune(qs)
        if dense:
            # dense corpus-wide fallback is reachable (a candidate set
            # can grow to corpus width) — warm the full dense-candidate
            # path (_rerank_dense: corpus scan + membership mask), not
            # just the bare scan; when the budget caps far below n_docs,
            # skip: it would materialize the whole padded corpus for an
            # executable traffic never hits
            C = max(widths, default=32)
            scores = self._rerank_dense(qs, np.zeros((Nq, C), np.int64),
                                        np.ones((Nq, C), bool), None)
            topk_with_pads(scores, None, k)

    def _warm_plaid_prune(self, qs: np.ndarray) -> None:
        """Trace plaid's PRE-prune stage-3 shapes for this batch shape.

        When the IVF gather exceeds ``ndocs``, ``plaid_candidates``
        scores candidates centroid-only at the GATHER width — ladder
        values above ``ndocs`` — before pruning; those executables are
        not touched by the rerank ladder warm, so drive them here."""
        import jax
        from repro.core.plaid import (_approx_scores_batch,
                                      _centroid_scores_batch)
        p = self._plaid
        Nq = len(qs)
        block = 32
        cs = _centroid_scores_batch(jnp.asarray(qs, jnp.float32),
                                    jnp.asarray(p.codec.centroids))
        codes, tok_mask = p.padded_codes()
        # gather ladder: 32<<m up to the first value >= n_docs (counts
        # are capped by live docs, but the geometric pad can overshoot)
        C = block
        while True:
            if C > self.ndocs:              # prune engages above budget
                cand = jnp.zeros((Nq, C), jnp.int64)
                cmask = jnp.ones((Nq, C), bool)
                approx = _approx_scores_batch(
                    cs, jnp.take(codes, cand, axis=0),
                    jnp.take(tok_mask, cand, axis=0) & cmask[:, :, None],
                    cmask, self.t_cs)
                jax.lax.top_k(approx, min(self.ndocs, C))
            if C >= self.n_docs:
                break
            C <<= 1

    # ----------------------------------------------------------------- search
    def search_batch(self, qs: np.ndarray, k: int = 10,
                     q_mask: Optional[np.ndarray] = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """qs: [Nq, Lq, dim] -> (scores [Nq, k], ids [Nq, k]; -inf/-1 pads)."""
        qs = np.asarray(qs, np.float32)
        Nq = len(qs)
        if self.n_docs == 0:
            return (np.full((Nq, k), -np.inf, np.float32),
                    np.full((Nq, k), -1, np.int64))
        scores, cand = self.scored_candidates(qs, q_mask)
        return topk_with_pads(scores, cand, k)

    def search(self, q: np.ndarray, k: int = 10
               ) -> Tuple[np.ndarray, np.ndarray]:
        """q: [Lq, dim] query token vectors -> (scores [k'], doc ids [k'])."""
        S, I = self.search_batch(np.asarray(q, np.float32)[None], k=k)
        valid = I[0] >= 0
        return S[0][valid], I[0][valid]

    # ------------------------------------------------------------------ stats
    def n_vectors(self) -> int:
        if self.n_docs == 0:
            return 0
        if self.backend == "plaid":
            lens = np.diff(self._plaid.doc_offsets)
            return int(lens[self._live()].sum())
        lens = self._store.doc_lengths()
        return int(lens[self._live()].sum())

    def nbytes(self) -> int:
        if self.backend == "hnsw" and self._hnsw is not None:
            return self._hnsw.nbytes()
        if self.backend == "plaid" and self._plaid is not None:
            return self._plaid.nbytes()
        # flat: fp16 store, live docs only (deleted docs are reclaimable)
        return self._store.nbytes(bytes_per_dim=2, live_only=True)

    def device_bytes(self) -> int:
        """Device-resident bytes of the query-time doc representation —
        what serving actually holds in accelerator memory, as opposed to
        ``nbytes`` (the persisted/host index). plaid: packed views +
        codec tables (+ recon cache only while resident); flat/hnsw: the
        padded f32 view."""
        if self.backend == "plaid":
            return self._plaid.device_bytes() if self._plaid is not None \
                else 0
        return self._store.device_nbytes()
