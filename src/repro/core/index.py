"""Index facade: Flat | HNSW | PLAID behind one add/delete/search interface.

The paper's two experimental settings map to:
  * ``hnsw``  — 16-bit unpooled/pooled vectors in a token-level HNSW graph
                (paper uses VOYAGER); stage 2 exact rerank over stored vectors.
  * ``plaid`` — 2-bit residual-quantized vectors behind IVF probing.
  * ``flat``  — exact MaxSim over everything (the oracle; small corpora only).

All three store *token* vectors grouped by document and return document ids,
so the evaluation harness is backend-agnostic. Pooling happens upstream
(retrieval/indexer.py) — the index only ever sees the (possibly pooled)
per-document vector lists. CRUD: ``add`` appends docs, ``delete`` removes
them (HNSW deletes lazily, PLAID/Flat compact).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.hnsw import HNSW
from repro.core.ivf import train_centroids
from repro.core.maxsim import maxsim_scores
from repro.core.plaid import PLAIDIndex, build_plaid_index, plaid_search
from repro.core.quantization import train_codec

BACKENDS = ("flat", "hnsw", "plaid")


def _pad_docs(doc_vectors: List[np.ndarray], maxlen: int, dim: int):
    n = len(doc_vectors)
    out = np.zeros((n, maxlen, dim), np.float32)
    mask = np.zeros((n, maxlen), bool)
    for i, v in enumerate(doc_vectors):
        k = min(len(v), maxlen)
        out[i, :k] = v[:k]
        mask[i, :k] = True
    return out, mask


@dataclass
class MultiVectorIndex:
    """Late-interaction index over per-document token-vector lists."""
    dim: int
    backend: str = "plaid"
    doc_maxlen: int = 256
    # PLAID params
    n_centroids: int = 256
    quant_bits: int = 2
    nprobe: int = 8
    t_cs: float = 0.3
    ndocs: int = 8192
    # HNSW params (paper Appendix A)
    hnsw_m: int = 12
    hnsw_ef_construction: int = 200
    hnsw_candidates: int = 1024    # token hits gathered before doc rerank

    # state
    docs: List[np.ndarray] = field(default_factory=list)
    deleted: set = field(default_factory=set)
    _hnsw: Optional[HNSW] = None
    _hnsw_vec2doc: Optional[np.ndarray] = None
    _plaid: Optional[PLAIDIndex] = None

    def __post_init__(self):
        assert self.backend in BACKENDS, self.backend

    # ------------------------------------------------------------------ build
    def add(self, doc_vectors: List[np.ndarray]) -> np.ndarray:
        """doc_vectors: list of [n_i, dim] unit vectors. Returns doc ids."""
        doc_vectors = [np.asarray(v, np.float32) for v in doc_vectors]
        ids = np.arange(len(self.docs), len(self.docs) + len(doc_vectors))
        self.docs.extend(doc_vectors)
        if self.backend == "hnsw":
            self._add_hnsw(doc_vectors, ids)
        elif self.backend == "plaid":
            self._add_plaid(doc_vectors)
        return ids

    def _add_hnsw(self, doc_vectors, ids):
        if self._hnsw is None:
            self._hnsw = HNSW(self.dim, m=self.hnsw_m,
                              ef_construction=self.hnsw_ef_construction)
            self._hnsw_vec2doc = np.zeros((0,), np.int64)
        flat = np.concatenate(doc_vectors) if doc_vectors else \
            np.zeros((0, self.dim), np.float32)
        self._hnsw.add(flat)
        lens = np.array([len(v) for v in doc_vectors], np.int64)
        self._hnsw_vec2doc = np.concatenate(
            [self._hnsw_vec2doc, np.repeat(ids, lens)])

    def _add_plaid(self, doc_vectors):
        if self._plaid is None:
            flat = np.concatenate(doc_vectors)
            k = min(self.n_centroids, len(flat))
            centroids = train_centroids(flat, k)
            codec = train_codec(jnp.asarray(flat), centroids,
                                bits=self.quant_bits)
            self._plaid = build_plaid_index(doc_vectors, codec,
                                            self.doc_maxlen)
        else:
            self._plaid.add(doc_vectors)

    def delete(self, doc_ids) -> None:
        self.deleted.update(int(i) for i in doc_ids)
        if self.backend == "hnsw" and self._hnsw is not None:
            tok = np.nonzero(np.isin(self._hnsw_vec2doc,
                                     np.asarray(doc_ids)))[0]
            self._hnsw.delete(tok)
        # plaid/flat filter deleted ids at query time (compaction via rebuild)

    # ----------------------------------------------------------------- search
    def search(self, q: np.ndarray, k: int = 10
               ) -> Tuple[np.ndarray, np.ndarray]:
        """q: [Lq, dim] query token vectors -> (scores [k'], doc ids [k'])."""
        if self.backend == "flat":
            s, i = self._search_flat(q, k + len(self.deleted))
        elif self.backend == "hnsw":
            s, i = self._search_hnsw(q, k + len(self.deleted))
        else:
            s, i = plaid_search(self._plaid, q, k=k + len(self.deleted),
                                nprobe=self.nprobe, t_cs=self.t_cs,
                                ndocs=self.ndocs)
        if self.deleted:
            keep = ~np.isin(i, np.fromiter(self.deleted, np.int64))
            s, i = s[keep], i[keep]
        return s[:k], i[:k]

    def search_batch(self, qs: np.ndarray, k: int = 10):
        """qs: [Nq, Lq, dim] -> (scores [Nq, k], ids [Nq, k]; -1 pads)."""
        S = np.full((len(qs), k), -np.inf, np.float32)
        I = np.full((len(qs), k), -1, np.int64)
        for n, q in enumerate(np.asarray(qs)):
            s, i = self.search(q, k)
            S[n, :len(s)], I[n, :len(i)] = s, i
        return S, I

    def _search_flat(self, q, k):
        d, dm = _pad_docs(self.docs, self.doc_maxlen, self.dim)
        qm = np.ones((1, len(q)), bool)
        s = np.asarray(maxsim_scores(jnp.asarray(q[None], jnp.float32),
                                     jnp.asarray(qm), jnp.asarray(d),
                                     jnp.asarray(dm)))[0]
        order = np.argsort(-s)[:k]
        return s[order], order.astype(np.int64)

    def _search_hnsw(self, q, k):
        """Two-stage: per-query-token ANN probe -> exact doc rerank."""
        per_tok = max(self.hnsw_candidates // max(len(q), 1), 8)
        cand = set()
        for qt in np.asarray(q, np.float32):
            _, ids = self._hnsw.search(qt, per_tok)
            cand.update(int(self._hnsw_vec2doc[i]) for i in ids)
        if not cand:
            return np.zeros((0,), np.float32), np.zeros((0,), np.int64)
        cand = np.fromiter(cand, np.int64)
        docs = [self.docs[i] for i in cand]
        d, dm = _pad_docs(docs, self.doc_maxlen, self.dim)
        qm = np.ones((1, len(q)), bool)
        s = np.asarray(maxsim_scores(jnp.asarray(q[None], jnp.float32),
                                     jnp.asarray(qm), jnp.asarray(d),
                                     jnp.asarray(dm)))[0]
        order = np.argsort(-s)[:k]
        return s[order], cand[order]

    # ------------------------------------------------------------------ stats
    def n_vectors(self) -> int:
        return int(sum(len(v) for i, v in enumerate(self.docs)
                       if i not in self.deleted))

    def nbytes(self) -> int:
        if self.backend == "hnsw" and self._hnsw is not None:
            return self._hnsw.nbytes()
        if self.backend == "plaid" and self._plaid is not None:
            return self._plaid.nbytes()
        return int(sum(v.nbytes // 2 for v in self.docs))   # fp16 flat
