"""MaxSim late-interaction scoring (ColBERT):  S(q, D) = sum_i max_j q_i . d_j.

The query-time hot path the whole index feeds. jnp reference here; the
Pallas kernel (kernels/maxsim) implements the same contraction with doc-token
blocks streamed through VMEM and a running max (dispatched via
``kernels.maxsim.ops.maxsim`` when on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.sharding.api import constrain


def maxsim(q, q_mask, d, d_mask):
    """q: [Lq, dim]; d: [Ld, dim] -> scalar score."""
    sim = q @ d.T                                      # [Lq, Ld]
    sim = jnp.where(d_mask[None, :], sim, -jnp.inf)
    best = jnp.max(sim, axis=-1)
    best = jnp.where(q_mask & jnp.isfinite(best), best, 0.0)
    return jnp.sum(best)


@jax.jit
def maxsim_scores(q, q_mask, d, d_mask):
    """Score every query against every doc.

    q: [Nq, Lq, dim]; q_mask: [Nq, Lq]; d: [Nd, Ld, dim]; d_mask: [Nd, Ld]
    -> scores [Nq, Nd] float32.
    """
    q = constrain(q.astype(jnp.float32), "queries", None, None)
    d = constrain(d.astype(jnp.float32), "docs", None, None)
    sim = jnp.einsum("qld,nkd->qnlk", q, d)            # [Nq, Nd, Lq, Ld]
    sim = jnp.where(d_mask[None, :, None, :], sim, -jnp.inf)
    best = jnp.max(sim, axis=-1)                       # [Nq, Nd, Lq]
    best = jnp.where(q_mask[:, None, :] & jnp.isfinite(best), best, 0.0)
    return jnp.sum(best, axis=-1)                      # [Nq, Nd]


@functools.partial(jax.jit, static_argnames=("block", "unroll"))
def maxsim_scores_blocked(q, q_mask, d, d_mask, block: int = 256,
                          unroll: bool = False):
    """Memory-bounded variant: docs processed in blocks via lax.scan.

    Needed when Nd * Lq * Ld would blow HBM; the Pallas kernel is the fused
    version of exactly this loop. ``unroll`` is the roofline-analysis mode
    (cost_analysis counts loop bodies once).
    """
    Nd = d.shape[0]
    assert Nd % block == 0, (Nd, block)
    nb = Nd // block
    db = d.reshape(nb, block, *d.shape[1:])
    mb = d_mask.reshape(nb, block, d_mask.shape[-1])

    def one(carry, args):
        dd, mm = args
        return carry, maxsim_scores(q, q_mask, dd, mm)   # [Nq, block]

    _, out = jax.lax.scan(one, 0, (db, mb),
                          unroll=nb if unroll else 1)    # [nb, Nq, block]
    return jnp.swapaxes(out, 0, 1).reshape(q.shape[0], Nd)


def topk_docs(scores, k):
    """scores [Nq, Nd] -> (top scores [Nq,k], doc ids [Nq,k])."""
    return jax.lax.top_k(scores, k)


# ---------------------------------------------------------------------------
# Engine entry points: Pallas kernel on TPU, jnp reference elsewhere
# (interpret-mode Pallas on CPU is correctness-only; the jnp path keeps the
#  batched engine fast on hosts while tracing to the same shapes).
# ---------------------------------------------------------------------------
def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# jit once at import: the kernel ref oracle IS the CPU rerank path
from repro.kernels.maxsim.ref import maxsim_rerank_ref as _rerank_ref
_rerank_jnp = jax.jit(_rerank_ref)


_ALL_DOCS_BLOCK = 2048     # above this, block the corpus scan (HBM bound)


def maxsim_all_docs(q, q_mask, d, d_mask):
    """All-pairs scores [Nq, Nd] — flat search / shared-corpus stage.

    Large corpora go through the lax.scan-blocked variant so the
    [Nq, Nd, Lq, Ld] similarity intermediate never materializes whole.
    """
    if _on_tpu():
        from repro.kernels.maxsim.ops import maxsim as maxsim_kernel
        return maxsim_kernel(q, q_mask, d, d_mask)
    Nd = d.shape[0]
    if Nd <= _ALL_DOCS_BLOCK:
        return maxsim_scores(q, q_mask, d, d_mask)
    pad = (-Nd) % _ALL_DOCS_BLOCK
    if pad:
        d = jnp.pad(d, ((0, pad), (0, 0), (0, 0)))
        d_mask = jnp.pad(d_mask, ((0, pad), (0, 0)))
    out = maxsim_scores_blocked(q, q_mask, d, d_mask,
                                block=_ALL_DOCS_BLOCK)
    return out[:, :Nd]


def topk_with_pads(scores, cand, k: int):
    """Shared top-k epilogue for every batched search API.

    scores: [Nq, C] (-inf marks invalid slots); cand: [Nq, C] doc ids or
    None when scores are corpus-wide (ids = column index). Returns
    (scores [Nq, k] f32, ids [Nq, k] i64) padded with -inf/-1.
    """
    import numpy as np
    kk = min(k, scores.shape[1])
    top_s, top_i = jax.lax.top_k(scores, kk)
    if isinstance(cand, jax.Array):
        # device candidates: gather the winning ids on device so the
        # ONLY host transfer after encode is this [Nq, k] result
        ids_dev = jnp.take_along_axis(cand, top_i, axis=1)
        top_s, ids = np.asarray(top_s), np.asarray(ids_dev).astype(np.int64)
    else:
        top_s, top_i = np.asarray(top_s), np.asarray(top_i)
        ids = (top_i.astype(np.int64) if cand is None
               else np.take_along_axis(np.asarray(cand, np.int64), top_i,
                                       axis=1))
    ids = np.where(np.isfinite(top_s), ids, -1)
    if kk < k:
        top_s = np.pad(top_s, ((0, 0), (0, k - kk)),
                       constant_values=-np.inf)
        ids = np.pad(ids, ((0, 0), (0, k - kk)), constant_values=-1)
    return top_s.astype(np.float32), ids.astype(np.int64)


def topk_shard(scores, cand, k: int, base: int = 0):
    """Device-side per-shard top-k: the unit a sharded/replicated merge
    keeps ON DEVICE so full-width slates never cross the host boundary.

    scores: [Nq, C] (-inf marks invalid slots, device array); cand:
    [Nq, C] local doc ids (host or device) or None when scores are
    corpus-wide (ids = column index). Returns (top scores [Nq, kk] f32,
    GLOBAL ids [Nq, kk] i32) with kk = min(k, C), both device-resident
    on ``scores``' device. Ids are ``cand``-gathered (or the column
    index) shifted by ``base``; slots whose score is -inf carry a
    meaningless id — the final merge epilogue (``topk_with_pads``) maps
    non-finite slots to -1, exactly as the monolithic path does.

    Keeping per-shard top-k is lossless for a global top-k: any shard
    contributes at most k winners, and ``jax.lax.top_k`` orders ties by
    lowest position, so local-top-k-then-merge reproduces the single
    concat-then-top-k bit for bit (scores, ids, AND tie order).

    Ids are i32 on device (x64 is off by default); global doc ids past
    2**31 are out of scope for this layout.
    """
    import numpy as np
    kk = min(k, scores.shape[1])
    top_s, top_i = jax.lax.top_k(scores, kk)
    off = jnp.int32(base)
    if cand is None:
        return top_s, top_i.astype(jnp.int32) + off
    c = (cand.astype(jnp.int32) if isinstance(cand, jax.Array)
         else jnp.asarray(np.asarray(cand, np.int32)))
    return top_s, jnp.take_along_axis(c, top_i, axis=1) + off


def maxsim_rerank(q, q_mask, d, d_mask):
    """Per-query gathered-candidate scores [Nq, S] (one traced batch)."""
    if _on_tpu():
        from repro.kernels.maxsim.ops import maxsim_rerank as rerank_kernel
        return rerank_kernel(q, q_mask, d, d_mask)
    return _rerank_jnp(q, q_mask, d, d_mask)


def maxsim_rerank_store(store, q, q_mask, cand, cand_mask, *,
                        slab: int = 1024):
    """Gather candidates from ``store`` and rerank, slabbed over the
    candidate axis so the [Nq, slab, Ld, dim] gather stays bounded
    (paper-default ndocs=8192 would otherwise materialize tens of GB).
    cand/cand_mask: [Nq, C] host arrays -> scores [Nq, C] (-inf invalid).
    """
    import numpy as np
    q = jnp.asarray(q, jnp.float32)
    parts = []
    for lo in range(0, cand.shape[1], slab):
        c = cand[:, lo:lo + slab]
        cm = jnp.asarray(np.asarray(cand_mask)[:, lo:lo + slab])
        d, dm = store.gather(c)
        s = maxsim_rerank(q, q_mask, d, dm & cm[:, :, None])
        parts.append(jnp.where(cm, s, -jnp.inf))
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
