"""MaxSim late-interaction scoring (ColBERT):  S(q, D) = sum_i max_j q_i . d_j.

The query-time hot path the whole index feeds. jnp reference here; the
Pallas kernel (kernels/maxsim) implements the same contraction with doc-token
blocks streamed through VMEM and a running max (dispatched via
``kernels.maxsim.ops.maxsim`` when on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.sharding.api import constrain


def maxsim(q, q_mask, d, d_mask):
    """q: [Lq, dim]; d: [Ld, dim] -> scalar score."""
    sim = q @ d.T                                      # [Lq, Ld]
    sim = jnp.where(d_mask[None, :], sim, -jnp.inf)
    best = jnp.max(sim, axis=-1)
    best = jnp.where(q_mask & jnp.isfinite(best), best, 0.0)
    return jnp.sum(best)


@jax.jit
def maxsim_scores(q, q_mask, d, d_mask):
    """Score every query against every doc.

    q: [Nq, Lq, dim]; q_mask: [Nq, Lq]; d: [Nd, Ld, dim]; d_mask: [Nd, Ld]
    -> scores [Nq, Nd] float32.
    """
    q = constrain(q.astype(jnp.float32), "queries", None, None)
    d = constrain(d.astype(jnp.float32), "docs", None, None)
    sim = jnp.einsum("qld,nkd->qnlk", q, d)            # [Nq, Nd, Lq, Ld]
    sim = jnp.where(d_mask[None, :, None, :], sim, -jnp.inf)
    best = jnp.max(sim, axis=-1)                       # [Nq, Nd, Lq]
    best = jnp.where(q_mask[:, None, :] & jnp.isfinite(best), best, 0.0)
    return jnp.sum(best, axis=-1)                      # [Nq, Nd]


@functools.partial(jax.jit, static_argnames=("block", "unroll"))
def maxsim_scores_blocked(q, q_mask, d, d_mask, block: int = 256,
                          unroll: bool = False):
    """Memory-bounded variant: docs processed in blocks via lax.scan.

    Needed when Nd * Lq * Ld would blow HBM; the Pallas kernel is the fused
    version of exactly this loop. ``unroll`` is the roofline-analysis mode
    (cost_analysis counts loop bodies once).
    """
    Nd = d.shape[0]
    assert Nd % block == 0, (Nd, block)
    nb = Nd // block
    db = d.reshape(nb, block, *d.shape[1:])
    mb = d_mask.reshape(nb, block, d_mask.shape[-1])

    def one(carry, args):
        dd, mm = args
        return carry, maxsim_scores(q, q_mask, dd, mm)   # [Nq, block]

    _, out = jax.lax.scan(one, 0, (db, mb),
                          unroll=nb if unroll else 1)    # [nb, Nq, block]
    return jnp.swapaxes(out, 0, 1).reshape(q.shape[0], Nd)


def topk_docs(scores, k):
    """scores [Nq, Nd] -> (top scores [Nq,k], doc ids [Nq,k])."""
    return jax.lax.top_k(scores, k)
