"""Hierarchical Navigable Small World index (Malkov & Yashunin, 2018).

The paper's unquantized experiments use the VOYAGER HNSW library with
M=12, ef_construction=200 and generous query-time ef ("similar to
non-approximate search"). HNSW is a latency-bound graph walk — host-side
NumPy by design (DESIGN.md §3.6); the TPU side handles encode/pool/rerank.

Supports incremental ``add`` and lazy ``delete`` (CRUD — the paper's §5
motivation for making ColBERT HNSW-friendly via pooling).
"""
from __future__ import annotations

import heapq
import math
from typing import List, Optional

import numpy as np


class HNSW:
    def __init__(self, dim: int, m: int = 12, ef_construction: int = 200,
                 seed: int = 0):
        self.dim = dim
        self.m = m
        self.m0 = 2 * m
        self.ef_construction = ef_construction
        self.ml = 1.0 / math.log(m)
        self.rng = np.random.default_rng(seed)
        self.vectors = np.zeros((0, dim), np.float32)
        self.levels: List[int] = []
        self.graph: List[List[dict]] = []      # graph[lvl][node] -> list[int]
        self.entry: Optional[int] = None
        self.max_level = -1
        self.deleted: set = set()

    @classmethod
    def from_state(cls, dim: int, m: int, ef_construction: int,
                   vectors: np.ndarray, levels: np.ndarray,
                   edge_counts: np.ndarray, edges: np.ndarray,
                   deleted: np.ndarray, entry: int, max_level: int,
                   seed: int = 0) -> "HNSW":
        """Rebuild from persisted CSR graph state (core/persist.py).

        ``vectors`` may be a read-only memmap (search only reads it;
        ``add`` concatenates into a fresh array). The rng restarts from
        ``seed``, so level draws of post-load inserts are independent
        of the saved instance's draw history — search over the saved
        graph is unaffected.
        """
        self = cls(dim, m=m, ef_construction=ef_construction, seed=seed)
        self.vectors = np.asarray(vectors, np.float32)
        self.levels = [int(x) for x in levels]
        n = len(self.levels)
        bounds = np.zeros(edge_counts.size + 1, np.int64)
        np.cumsum(np.asarray(edge_counts).ravel(), out=bounds[1:])
        edges = np.asarray(edges, np.int64)
        self.graph = [
            [edges[bounds[lv * n + i]:bounds[lv * n + i + 1]].tolist()
             for i in range(n)]
            for lv in range(edge_counts.shape[0])]
        self.entry = None if entry < 0 else int(entry)
        self.max_level = int(max_level)
        self.deleted = set(int(i) for i in np.asarray(deleted))
        return self

    # -- distances: inner product on unit vectors (cosine) ------------------
    def _sims(self, q, ids):
        return self.vectors[ids] @ q

    def _search_layer(self, q, entry_points, ef, lvl):
        visited = set(entry_points)
        cand = []      # max-heap by sim (store -sim)
        best = []      # min-heap of (sim, id), size <= ef
        for p in entry_points:
            s = float(self.vectors[p] @ q)
            heapq.heappush(cand, (-s, p))
            heapq.heappush(best, (s, p))
        while cand:
            cs, c = heapq.heappop(cand)
            if -cs < best[0][0] and len(best) >= ef:
                break
            for nb in self.graph[lvl][c]:
                if nb in visited:
                    continue
                visited.add(nb)
                s = float(self.vectors[nb] @ q)
                if len(best) < ef or s > best[0][0]:
                    heapq.heappush(cand, (-s, nb))
                    heapq.heappush(best, (s, nb))
                    if len(best) > ef:
                        heapq.heappop(best)
        return sorted(best, reverse=True)      # [(sim, id)] best first

    def _select_neighbors(self, q, candidates, m):
        """Simple heuristic: top-m by similarity."""
        return [i for _, i in sorted(candidates, reverse=True)[:m]]

    def add(self, vecs: np.ndarray) -> np.ndarray:
        """Insert vectors; returns assigned ids."""
        vecs = np.asarray(vecs, np.float32)
        n0 = self.vectors.shape[0]
        ids = np.arange(n0, n0 + len(vecs))
        self.vectors = np.concatenate([self.vectors, vecs], axis=0)
        for vid, v in zip(ids, vecs):
            self._insert(int(vid), v)
        return ids

    def _insert(self, vid: int, v: np.ndarray):
        lvl = int(-math.log(max(self.rng.random(), 1e-12)) * self.ml)
        self.levels.append(lvl)
        while self.max_level < lvl:
            self.max_level += 1
            self.graph.append([])
        # ensure adjacency rows exist on every level
        for l in range(self.max_level + 1):
            while len(self.graph[l]) <= vid:
                self.graph[l].append([])
        if self.entry is None:
            self.entry = vid
            return
        ep = [self.entry]
        for l in range(self.max_level, lvl, -1):
            res = self._search_layer(v, ep, 1, l)
            if res:
                ep = [res[0][1]]
        for l in range(min(lvl, self.max_level), -1, -1):
            cand = self._search_layer(v, ep, self.ef_construction, l)
            m = self.m0 if l == 0 else self.m
            neigh = self._select_neighbors(v, cand, m)
            self.graph[l][vid] = list(neigh)
            for nb in neigh:
                lst = self.graph[l][nb]
                lst.append(vid)
                if len(lst) > m:
                    sims = self.vectors[lst] @ self.vectors[nb]
                    keep = np.argsort(-sims)[:m]
                    self.graph[l][nb] = [lst[i] for i in keep]
            ep = [i for _, i in cand] or ep
        if self.levels[vid] > self.levels[self.entry]:
            self.entry = vid

    def delete(self, ids):
        """Lazy delete: results filter; graph edges retained as routing."""
        self.deleted.update(int(i) for i in ids)

    def search(self, q: np.ndarray, k: int, ef: Optional[int] = None):
        """q: [dim] -> (sims [k'], ids [k'])."""
        if self.entry is None:
            return np.zeros((0,), np.float32), np.zeros((0,), np.int64)
        ef = ef or max(4 * k, 64)
        ep = [self.entry]
        for l in range(self.max_level, 0, -1):
            res = self._search_layer(q, ep, 1, l)
            if res:
                ep = [res[0][1]]
        res = self._search_layer(q, ep, max(ef, k), 0)
        res = [(s, i) for s, i in res if i not in self.deleted][:k]
        if not res:
            return np.zeros((0,), np.float32), np.zeros((0,), np.int64)
        sims, ids = zip(*res)
        return np.asarray(sims, np.float32), np.asarray(ids, np.int64)

    def search_batch(self, qs: np.ndarray, k: int, ef: Optional[int] = None):
        sims, ids = [], []
        for q in qs:
            s, i = self.search(q, k, ef)
            # pad to k
            if len(i) < k:
                s = np.pad(s, (0, k - len(s)), constant_values=-np.inf)
                i = np.pad(i, (0, k - len(i)), constant_values=-1)
            sims.append(s)
            ids.append(i)
        return np.stack(sims), np.stack(ids)

    def probe_tokens(self, qs: np.ndarray, k: int,
                     ef: Optional[int] = None) -> np.ndarray:
        """Batched token probe: qs [T, dim] -> vector ids [T, k] (-1 pad).

        The graph walk itself is inherently sequential per token
        (latency-bound pointer chasing, DESIGN.md §3.6); this batches the
        bookkeeping so callers get one fixed-shape id matrix for the
        whole query batch and never touch per-token Python results.
        """
        out = np.full((len(qs), k), -1, np.int64)
        for t, q in enumerate(np.asarray(qs, np.float32)):
            _, ids = self.search(q, k, ef)
            out[t, :len(ids)] = ids
        return out

    def nbytes(self) -> int:
        vec = self.vectors.size * 2                     # stored fp16
        edges = sum(len(r) for lvl in self.graph for r in lvl) * 4
        return vec + edges
