"""Typed spec layer: ONE config surface from build -> persist -> serve.

The paper sells token pooling as "a simple drop-in during indexation";
after four PRs the drop-in's knobs were threaded through five
uncoordinated surfaces (``ColbertConfig`` fields, ``Indexer(**index_kw)``,
the ``PARAM_KEYS`` tuple shadowed between ``core/index.py`` and
``core/persist.py``, a dozen hand-maintained argparse flags, and
``ServingEngine`` kwargs). This module is the single source of truth
they all derive from:

  * :class:`PoolingSpec`  — pooling method + factor, resolved through a
    REGISTRY of pooling strategies, so a new policy (e.g. per-document
    adaptive vector budgets, cf. "Efficient Constant-Space Multi-Vector
    Retrieval") is one ``register_pooling_strategy`` call, not an
    indexer fork.
  * :class:`IndexSpec`    — backend + construction knobs. Its
    :data:`INDEX_PARAM_KEYS` is THE definition the index, the sharded
    wrapper, and the persistence manifest all import (drift between
    shadowed copies silently rejected valid manifests).
  * :class:`ShardSpec`    — streaming-build / sharding knobs.
  * :class:`ServeSpec`    — batcher / shape-bucket / hot-swap knobs;
    ``launch/serve.py`` and ``benchmarks/serve_bench.py`` derive their
    argparse flags from it (:func:`add_spec_args`) instead of
    hand-maintaining them.
  * :class:`RetrieverSpec` — the composite the :class:`repro.Retriever`
    facade builds, persists, and serves from.

Specs are frozen dataclasses of JSON scalars: hashable, comparable by
value, and round-trip LOSSLESSLY through artifact manifests —
``retriever_spec_from_manifest(read_manifest(dir))`` reloads the exact
spec the index was built with in a fresh process
(tests/test_spec.py pins the property with hypothesis).

Backends live in a registry too (:func:`register_backend`): "cascade"
(retrieval/cascade.py) is a peer of flat/hnsw/plaid here, so every
artifact kind builds and serves through the same facade.

This module imports no index/persist/model code at module level — it is
the layer everything else depends on.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Single source of truth for index construction keys
# ---------------------------------------------------------------------------
# MultiVectorIndex construction knobs: what the persistence manifest
# records under "params", what ShardedIndex forwards to every shard, and
# what ``IndexSpec.params()`` emits. core/index.py and core/persist.py
# IMPORT this tuple (they used to shadow their own copies).
INDEX_PARAM_KEYS: Tuple[str, ...] = (
    "doc_maxlen", "n_centroids", "quant_bits", "nprobe",
    "t_cs", "ndocs", "hnsw_m", "hnsw_ef_construction",
    "hnsw_candidates")

# CascadeIndex construction knobs (its manifest records them top-level).
CASCADE_PARAM_KEYS: Tuple[str, ...] = (
    "coarse_factor", "fine_factor", "candidates", "doc_maxlen")


# ---------------------------------------------------------------------------
# Pooling strategy registry
# ---------------------------------------------------------------------------
# A pooling strategy maps per-document token embeddings to pooled slots:
#
#     strategy(x, mask, factor) -> (pooled, pooled_mask)
#
#       x:      [B, N, d] float token embeddings
#       mask:   [B, N]    bool — True where a real (emitted) token lives
#       factor: int >= 1  — the requested compression factor
#       pooled: [B, M, d] pooled vectors scattered into slots
#       pooled_mask: [B, M] bool — which slots hold a pooled vector
#
# ``compact_pooled`` (core/pooling.py) consumes the pair, so a strategy
# is free to choose M and the per-document vector budget — a per-doc
# adaptive-budget policy plugs in here without touching the indexer.
PoolingStrategy = Callable[..., Tuple[Any, Any]]

# The paper's methods, implemented by core/pooling.pool_doc_embeddings.
BUILTIN_POOL_METHODS: Tuple[str, ...] = ("none", "sequential", "kmeans",
                                         "ward")

_POOLING_REGISTRY: Dict[str, PoolingStrategy] = {}


def register_pooling_strategy(name: str, strategy: PoolingStrategy,
                              overwrite: bool = False) -> None:
    """Register a pooling policy under ``name`` so ``PoolingSpec(method=
    name)`` resolves to it everywhere (Indexer, Retriever, serve CLI)."""
    if not name or not isinstance(name, str):
        raise ValueError(f"strategy name must be a non-empty str, "
                         f"got {name!r}")
    if not overwrite and (name in BUILTIN_POOL_METHODS
                          or name in _POOLING_REGISTRY):
        raise ValueError(f"pooling strategy {name!r} already registered "
                         f"(pass overwrite=True to replace it)")
    _POOLING_REGISTRY[name] = strategy


def _builtin_strategy(method: str) -> PoolingStrategy:
    def run(x, mask, factor: int):
        from repro.core.pooling import pool_doc_embeddings
        return pool_doc_embeddings(x, mask, factor, method)
    return run


def pooling_strategy(name: str) -> PoolingStrategy:
    """Resolve a method name: registered strategies shadow builtins."""
    if name in _POOLING_REGISTRY:
        return _POOLING_REGISTRY[name]
    if name in BUILTIN_POOL_METHODS:
        return _builtin_strategy(name)
    raise KeyError(f"unknown pooling method {name!r}; known: "
                   f"{pooling_methods()}")


def pooling_methods() -> Tuple[str, ...]:
    """Builtins + registered strategies (the CLI's --pool-method choices)."""
    return BUILTIN_POOL_METHODS + tuple(
        n for n in _POOLING_REGISTRY if n not in BUILTIN_POOL_METHODS)


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class BackendInfo:
    """One retrieval backend the facade can build / persist / serve."""
    name: str
    artifact_kind: str              # manifest "kind" this backend persists as
    param_keys: Tuple[str, ...]     # IndexSpec fields that apply to it
    # facade-level builder: (params, cfg, docs, spec, out_dir) ->
    # (index, IndexStats). Filled by repro.api at import; a new backend
    # registers its own and rides Retriever/serve unchanged.
    builder: Optional[Callable] = None


_BACKEND_REGISTRY: Dict[str, BackendInfo] = {}


def register_backend(name: str, artifact_kind: str,
                     param_keys: Sequence[str],
                     builder: Optional[Callable] = None,
                     overwrite: bool = False) -> None:
    if not overwrite and name in _BACKEND_REGISTRY:
        raise ValueError(f"backend {name!r} already registered")
    _BACKEND_REGISTRY[name] = BackendInfo(
        name=name, artifact_kind=artifact_kind,
        param_keys=tuple(param_keys), builder=builder)


def backend_info(name: str) -> BackendInfo:
    if name not in _BACKEND_REGISTRY:
        raise KeyError(f"unknown backend {name!r}; known: "
                       f"{backend_names()}")
    return _BACKEND_REGISTRY[name]


def backend_names() -> Tuple[str, ...]:
    return tuple(_BACKEND_REGISTRY)


for _b in ("flat", "hnsw", "plaid"):
    register_backend(_b, "multi_vector_index", INDEX_PARAM_KEYS)
register_backend("cascade", "cascade_index", CASCADE_PARAM_KEYS)


# ---------------------------------------------------------------------------
# Spec base machinery
# ---------------------------------------------------------------------------
def _from_dict(cls, d: Dict[str, Any]):
    """Strict constructor: unknown keys are REJECTED (a typo'd knob must
    fail loudly, not silently fall back to a default)."""
    if not isinstance(d, dict):
        raise ValueError(f"{cls.__name__} expects a dict, got {type(d)}")
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - names
    if unknown:
        raise ValueError(f"unknown {cls.__name__} keys {sorted(unknown)}; "
                         f"known: {sorted(names)}")
    return cls(**d)


class _SpecBase:
    """Shared serialization for the frozen spec dataclasses."""

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]):
        return _from_dict(cls, d)

    def replace(self, **kw):
        """Frozen-friendly update; unknown keys raise (TypeError)."""
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# The specs
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PoolingSpec(_SpecBase):
    """The paper's drop-in: WHICH pooling policy, at WHAT factor.

    ``factor <= 1`` is the identity (the unpooled baseline) regardless
    of ``method`` — exactly the pre-spec ``Indexer`` semantics, so
    pooled artifacts stay bit-identical across the redesign.
    """
    method: str = field(default="ward", metadata={
        "help": "token pooling method", "choices": pooling_methods})
    factor: int = field(default=1, metadata={
        "help": "pooling factor (1 = unpooled baseline)"})
    # Ward implementation toggle (kernels/ward_pool): "auto" resolves to
    # the Pallas kernel — it is bitwise-equal to core/ward.py everywhere
    # and faster even under the CPU interpreter — "ref" pins the
    # original loop (A/B parity gates, debugging). Only meaningful for
    # method="ward"; carried but inert otherwise. RUNTIME-ONLY: never
    # persisted into manifests — both impls produce identical artifacts
    # (the bench gates it), so pinning an impl into an artifact would
    # only freeze a load-time execution choice that isn't content.
    ward_kernel: str = field(default="auto", metadata={
        "help": "ward clustering path: Pallas kernel vs core/ward.py "
                "reference", "choices": ("auto", "kernel", "ref")})

    def __post_init__(self):
        if not isinstance(self.method, str) or not self.method:
            raise ValueError(f"pooling method must be a non-empty str, "
                             f"got {self.method!r}")
        if int(self.factor) < 1:
            raise ValueError(f"pool factor must be >= 1, "
                             f"got {self.factor!r}")
        if self.ward_kernel not in ("auto", "kernel", "ref"):
            raise ValueError(f"ward_kernel must be auto|kernel|ref, "
                             f"got {self.ward_kernel!r}")

    def apply(self, x, mask):
        """Pool one encode batch: (x [B,N,d], mask [B,N]) ->
        (pooled, pooled_mask), through the strategy registry."""
        if int(self.factor) <= 1:
            return pooling_strategy("none")(x, mask, 1)
        if self.method == "ward" and "ward" not in _POOLING_REGISTRY:
            # builtin ward carries the kernel/ref toggle; a registered
            # "ward" strategy still shadows the builtin entirely
            from repro.core.pooling import pool_doc_embeddings
            return pool_doc_embeddings(x, mask, int(self.factor), "ward",
                                       ward_kernel=self.ward_kernel)
        return pooling_strategy(self.method)(x, mask, int(self.factor))

    def manifest_meta(self) -> Dict[str, Any]:
        """The ``pool`` entry artifact manifests record — the ONE
        definition every save path embeds (its inverse is
        :func:`retriever_spec_from_manifest`)."""
        # ward_kernel is deliberately ABSENT: both impls write bitwise-
        # identical artifacts, so the toggle is a runtime choice (like
        # ServeSpec), not index content — artifacts stay byte-stable
        # across impl pins and pre-kernel history.
        return {"method": self.method, "factor": int(self.factor)}


@dataclass(frozen=True)
class IndexSpec(_SpecBase):
    """Backend + construction knobs — the single source of truth that
    replaced ``Indexer._index_kw``, ``index.PARAM_KEYS``, and
    ``persist._PARAM_KEYS``. Field defaults are pinned equal to the
    ``MultiVectorIndex`` / ``CascadeIndex`` dataclass defaults by
    tests/test_spec.py, so a default spec builds the default index."""
    backend: str = field(default="plaid", metadata={
        "help": "index backend", "choices": backend_names})
    doc_maxlen: int = 256
    # PLAID
    n_centroids: int = 256
    quant_bits: int = 2
    nprobe: int = 8
    t_cs: float = 0.3
    ndocs: int = 8192
    # HNSW (paper Appendix A)
    hnsw_m: int = 12
    hnsw_ef_construction: int = 200
    hnsw_candidates: int = 1024
    # cascade (beyond-paper; retrieval/cascade.py)
    coarse_factor: int = 6
    fine_factor: int = 2
    candidates: int = 32

    def __post_init__(self):
        if self.backend not in _BACKEND_REGISTRY:
            raise ValueError(f"unknown backend {self.backend!r}; known: "
                             f"{backend_names()}")
        # the packed rerank kernels unpack 32/bits codes per word in
        # fixed-width lanes; only the codec widths they compile for are
        # legal index configurations
        if int(self.quant_bits) not in (2, 4):
            raise ValueError(f"quant_bits must be 2 or 4, got "
                             f"{self.quant_bits!r}")

    @property
    def artifact_kind(self) -> str:
        return backend_info(self.backend).artifact_kind

    def params(self) -> Dict[str, Any]:
        """The construction kwargs for this backend's index class —
        exactly what the persistence manifest records."""
        return {k: getattr(self, k)
                for k in backend_info(self.backend).param_keys}

    def generic_params(self) -> Dict[str, Any]:
        """The :data:`INDEX_PARAM_KEYS` values regardless of backend —
        what a cascade manifest additionally records so
        spec -> manifest -> spec stays a true identity."""
        return {k: getattr(self, k) for k in INDEX_PARAM_KEYS}

    @classmethod
    def from_config(cls, cfg, backend: Optional[str] = None,
                    **overrides) -> "IndexSpec":
        """Lift the retrieval knobs off a ``ColbertConfig``; explicit
        overrides win (the old ``Indexer(**index_kw)`` precedence)."""
        base = dict(backend=backend or cfg.index_backend,
                    doc_maxlen=cfg.doc_maxlen,
                    n_centroids=cfg.n_centroids,
                    quant_bits=cfg.quant_bits,
                    nprobe=cfg.nprobe, t_cs=cfg.t_cs, ndocs=cfg.ndocs)
        base.update(overrides)
        return _from_dict(cls, base)

    @classmethod
    def from_manifest_params(cls, backend: str,
                             params: Dict[str, Any]) -> "IndexSpec":
        """Rebuild from a manifest's ``params`` table. Unknown keys are
        rejected (format drift must not load as garbage); missing keys
        take spec defaults (older artifacts recorded a subset)."""
        unknown = set(params) - set(INDEX_PARAM_KEYS)
        if unknown:
            raise ValueError(f"unknown index params {sorted(unknown)}")
        return cls(backend=backend, **params)


@dataclass(frozen=True)
class ShardSpec(_SpecBase):
    """Streaming-build / sharded-layout knobs (core/sharded.py)."""
    shard_max_vectors: int = field(default=0, metadata={
        "help": "build via the streaming path, flushing a new shard "
                "every N pooled vectors (0 = monolithic)"})
    probe_threads: int = field(default=0, metadata={
        "help": "stage-1 probe workers per sharded index "
                "(0 = auto: min(8, cores); replica routing divides the "
                "auto width across lanes)"})

    def __post_init__(self):
        if int(self.shard_max_vectors) < 0:
            raise ValueError(f"shard_max_vectors must be >= 0, got "
                             f"{self.shard_max_vectors!r}")
        if int(self.probe_threads) < 0:
            raise ValueError(f"probe_threads must be >= 0, got "
                             f"{self.probe_threads!r}")

    @property
    def sharded(self) -> bool:
        return int(self.shard_max_vectors) > 0


@dataclass(frozen=True)
class ServeSpec(_SpecBase):
    """Serving-runtime knobs (launch/engine.py ServingEngine): dynamic
    batcher, shape buckets, and hot-swap watcher. Runtime-only — never
    persisted into artifacts."""
    max_batch: int = field(default=32, metadata={
        "help": "engine coalescing cap / largest shape bucket"})
    max_wait_ms: float = field(default=2.0, metadata={
        "help": "engine batcher flush deadline"})
    k: int = field(default=10, metadata={
        "help": "results returned per query"})
    poll_interval_s: float = field(default=0.2, metadata={
        "cli": False, "help": "index-dir hot-swap poll interval"})
    pipeline_depth: Optional[int] = field(default=None, metadata={
        "cli": False,
        "help": "encode/search overlap depth (None = auto by cores)"})
    warmup_on_start: bool = field(default=True, metadata={
        "cli": False, "help": "trace all shape buckets at start()"})
    n_replicas: int = field(default=1, metadata={
        "help": "replica groups the engine routes microbatches across "
                "(core/replicated.py; 1 = single-lane serving)"})

    def __post_init__(self):
        if int(self.max_batch) < 1:
            raise ValueError(f"max_batch must be >= 1, got "
                             f"{self.max_batch!r}")
        if int(self.n_replicas) < 1:
            raise ValueError(f"n_replicas must be >= 1, got "
                             f"{self.n_replicas!r}")


@dataclass(frozen=True)
class RetrieverSpec(_SpecBase):
    """The whole pipeline, typed: pool -> index -> shard -> serve.
    What ``repro.Retriever.build`` consumes and artifacts round-trip."""
    pooling: PoolingSpec = field(default_factory=PoolingSpec)
    index: IndexSpec = field(default_factory=IndexSpec)
    shard: ShardSpec = field(default_factory=ShardSpec)
    serve: ServeSpec = field(default_factory=ServeSpec)

    def __post_init__(self):
        if self.shard.sharded and self.index.backend == "cascade":
            raise ValueError("cascade indexes have no sharded layout "
                             "(shard_max_vectors must be 0)")

    def to_dict(self) -> Dict[str, Any]:
        return {"pooling": self.pooling.to_dict(),
                "index": self.index.to_dict(),
                "shard": self.shard.to_dict(),
                "serve": self.serve.to_dict()}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RetrieverSpec":
        if not isinstance(d, dict):
            raise ValueError(f"RetrieverSpec expects a dict, got {type(d)}")
        unknown = set(d) - {"pooling", "index", "shard", "serve"}
        if unknown:
            raise ValueError(f"unknown RetrieverSpec keys {sorted(unknown)}")
        return cls(
            pooling=PoolingSpec.from_dict(d.get("pooling", {})),
            index=IndexSpec.from_dict(d.get("index", {})),
            shard=ShardSpec.from_dict(d.get("shard", {})),
            serve=ServeSpec.from_dict(d.get("serve", {})))

    @classmethod
    def from_config(cls, cfg, **index_overrides) -> "RetrieverSpec":
        return cls(pooling=PoolingSpec(method=cfg.pool_method,
                                       factor=max(int(cfg.pool_factor), 1)),
                   index=IndexSpec.from_config(cfg, **index_overrides))

    @classmethod
    def coerce(cls, spec, cfg=None) -> "RetrieverSpec":
        """Accept a RetrieverSpec, a bare IndexSpec/PoolingSpec/ShardSpec
        (other parts defaulted from ``cfg``), a dict, or None."""
        if spec is None:
            return cls.from_config(cfg) if cfg is not None else cls()
        if isinstance(spec, cls):
            return spec
        base = cls.from_config(cfg) if cfg is not None else cls()
        if isinstance(spec, IndexSpec):
            return base.replace(index=spec)
        if isinstance(spec, PoolingSpec):
            return base.replace(pooling=spec)
        if isinstance(spec, ShardSpec):
            return base.replace(shard=spec)
        if isinstance(spec, dict):
            full = cls.from_dict(spec)      # validates all sections
            # sections the dict omits default from cfg, same as the
            # bare-spec forms above — not from the class defaults
            return base.replace(**{name: getattr(full, name)
                                   for name in ("pooling", "index",
                                                "shard", "serve")
                                   if name in spec})
        raise TypeError(f"cannot coerce {type(spec).__name__} to "
                        f"RetrieverSpec")


# ---------------------------------------------------------------------------
# Manifest round-trip
# ---------------------------------------------------------------------------
def manifest_meta_for(spec: RetrieverSpec) -> Dict[str, Any]:
    """The spec-relevant subset of the manifest meta the save paths
    write for ``spec`` — the inverse of
    :func:`retriever_spec_from_manifest`. tests/test_spec.py pins both
    the pure round-trip (hypothesis) and, in tests/test_api.py, that
    REAL artifacts written by ``Retriever.build`` carry exactly these
    entries."""
    meta: Dict[str, Any] = {
        "kind": spec.index.artifact_kind,
        "pool": spec.pooling.manifest_meta(),
    }
    if spec.index.backend == "cascade":
        meta.update({k: getattr(spec.index, k)
                     for k in CASCADE_PARAM_KEYS})
        # the generic knobs don't drive a cascade build, but recording
        # them keeps spec -> manifest -> spec a true identity
        meta["params"] = spec.index.generic_params()
    else:
        meta["backend"] = spec.index.backend
        meta["params"] = spec.index.params()
        if spec.shard.sharded:
            meta["kind"] = "sharded_index"
            meta["shard_max_vectors"] = int(spec.shard.shard_max_vectors)
            # auto (0) is the long-standing default: written only when
            # pinned, so pre-existing artifacts hash/compare unchanged
            if int(spec.shard.probe_threads) > 0:
                meta["probe_threads"] = int(spec.shard.probe_threads)
    return meta


def retriever_spec_from_manifest(manifest: Dict[str, Any],
                                 serve: Optional[ServeSpec] = None
                                 ) -> RetrieverSpec:
    """Rebuild the build-time spec from an artifact manifest.

    Serving knobs are runtime-only (never persisted), so ``serve``
    comes back default unless the caller provides one.
    """
    kind = manifest.get("kind")
    pool_meta = manifest.get("pool")
    pooling = (PoolingSpec.from_dict(pool_meta) if pool_meta
               else PoolingSpec())
    shard = ShardSpec()
    if kind == "cascade_index":
        index = IndexSpec.from_manifest_params(
            "cascade", dict(manifest.get("params", {}))).replace(**{
                k: manifest[k] for k in CASCADE_PARAM_KEYS
                if k in manifest})
    elif kind in ("multi_vector_index", "sharded_index"):
        index = IndexSpec.from_manifest_params(
            manifest.get("backend", "plaid"),
            dict(manifest.get("params", {})))
        if kind == "sharded_index":
            shard = ShardSpec(
                shard_max_vectors=int(manifest.get("shard_max_vectors", 0)),
                probe_threads=int(manifest.get("probe_threads", 0)))
    else:
        raise ValueError(f"manifest kind {kind!r} carries no retriever "
                         f"spec")
    return RetrieverSpec(pooling=pooling, index=index, shard=shard,
                         serve=serve or ServeSpec())


# ---------------------------------------------------------------------------
# Argparse derivation: flags FROM the spec, not beside it
# ---------------------------------------------------------------------------
def add_spec_args(parser, spec_cls, prefix: str = "",
                  defaults: Optional[Dict[str, Any]] = None,
                  only: Optional[Sequence[str]] = None):
    """Add one ``--flag`` per CLI-eligible field of ``spec_cls``.

    Flag name = ``--{prefix}{field}`` with underscores dashed; type and
    default come from the dataclass, help/choices from field metadata
    (``choices`` may be a callable so registry growth shows up).
    ``defaults`` overrides per-call defaults (e.g. serve.py's
    ``--pool-factor 2``); ``only`` restricts to a subset. Parse back
    with :func:`spec_from_args`.
    """
    defaults = defaults or {}
    for f in dataclasses.fields(spec_cls):
        if f.metadata.get("cli") is False:
            continue
        if only is not None and f.name not in only:
            continue
        default = defaults.get(f.name, f.default)
        kw: Dict[str, Any] = {
            "default": default,
            "help": f.metadata.get("help", f.name)
            + f" (default: {default})",
        }
        choices = f.metadata.get("choices")
        if callable(choices):
            choices = choices()
        if choices:
            kw["choices"] = choices
        if not isinstance(default, bool) and isinstance(
                default, (int, float, str)):
            kw["type"] = type(default)
        flag = "--" + (prefix + f.name).replace("_", "-")
        parser.add_argument(flag, **kw)
    return parser


def spec_from_args(spec_cls, args, prefix: str = "",
                   only: Optional[Sequence[str]] = None, **overrides):
    """Collect a spec back out of parsed args (inverse of
    :func:`add_spec_args`); fields without a matching arg keep their
    defaults, explicit ``overrides`` win."""
    kw: Dict[str, Any] = {}
    for f in dataclasses.fields(spec_cls):
        if f.metadata.get("cli") is False:
            continue
        if only is not None and f.name not in only:
            continue
        attr = (prefix + f.name).replace("-", "_")
        if hasattr(args, attr):
            kw[f.name] = getattr(args, attr)
    kw.update(overrides)
    return spec_cls(**kw)
