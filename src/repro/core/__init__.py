"""The paper's contribution: token pooling + the index stack it plugs into."""
from repro.core.pooling import (METHODS, compact_pooled, pool_doc_embeddings,
                                vector_counts)
from repro.core.maxsim import maxsim_scores, maxsim_scores_blocked, topk_docs
from repro.core.index import MultiVectorIndex
from repro.core.sharded import ShardedIndex
from repro.core.persist import (IndexFormatError, artifact_bytes,
                                load_artifact, load_index, load_sharded,
                                save_index, save_sharded)

__all__ = [
    "METHODS", "compact_pooled", "pool_doc_embeddings", "vector_counts",
    "maxsim_scores", "maxsim_scores_blocked", "topk_docs",
    "MultiVectorIndex", "ShardedIndex",
    "IndexFormatError", "artifact_bytes", "load_artifact", "load_index",
    "load_sharded", "save_index", "save_sharded",
]
