"""IVF coarse quantizer: centroid training + inverted lists (CSR layout).

The same k-means substrate the paper's pooling uses, applied at corpus scale:
token vectors are assigned to K coarse centroids; the inverted lists map each
centroid to the vector ids it owns. This is the candidate-generation stage of
the PLAID pipeline (centroid probe -> inverted-list gather).

Centroid training is data-parallel friendly: ``kmeans_train``'s E/M steps are
segment-sums, so under pjit with the vector axis sharded on ``data`` the
statistics all-reduce automatically. List construction is a host-side sort
(it is an index-build artifact, not a hot path).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import kmeans_train


@dataclass
class InvertedLists:
    """CSR inverted file: vectors of centroid c are ids[offsets[c]:offsets[c+1]]."""
    offsets: np.ndarray          # [K + 1] int64
    ids: np.ndarray              # [n_vectors] int64 (vector ids, centroid-major)

    @property
    def n_centroids(self) -> int:
        return len(self.offsets) - 1

    def list_for(self, c: int) -> np.ndarray:
        return self.ids[self.offsets[c]:self.offsets[c + 1]]

    def lists_for(self, cs) -> np.ndarray:
        """Sorted unique ids for several centroids — one repeat/
        ragged-arange gather over all requested lists (no per-centroid
        Python loop), the same sweep ``plaid._gather_candidates`` runs
        batch-wide."""
        from repro.core.docstore import ragged_arange
        cs = np.unique(np.asarray(cs, np.int64))
        starts = self.offsets[cs]
        lens = self.offsets[cs + 1] - starts
        if int(lens.sum()) == 0:
            return np.zeros((0,), np.int64)
        pos = np.repeat(starts, lens) + ragged_arange(lens)
        return np.unique(self.ids[pos])


@dataclass
class DeviceInvertedLists:
    """Device-resident IVF for the zero-host-hop candidate path.

    Two views, both shipped to device ONCE at build/load:

      * the raw CSR (``offsets``/``ids`` — vector ids, centroid-major),
        the bitwise source of truth, kept for segment-style consumers;
      * a padded per-centroid UNIQUE-doc view (``doc_lists`` [K, Lmax]
        int32, pad slots holding the SENTINEL ``n_docs`` so one
        extended-live gather covers validity and liveness at once, plus
        ``doc_valid`` [K, Lmax] for host-side introspection) that turns
        stage 2's ragged list walk into one fixed-shape ``take`` — each
        row holds that centroid's owner docs ascending, exactly what the
        host path's (query, doc) dedupe would keep from that list;
      * a dense 0/1 membership matrix (``doc_member`` [K, n_docs] f32,
        derived from the SAME kept entries) that turns the batch-wide
        set union into one matmul — probed-centroid one-hot rows times
        this table count, exactly (small integers in f32), how many
        probed lists own each doc. The matmul hits the MXU/BLAS instead
        of the scatter/sort primitives accelerator backends serialize.

    ``list_cap`` bounds Lmax; rows longer than the cap are truncated
    and the dropped entry count lands in ``overflow``. ``overflow == 0``
    is the exactness contract the device candidate path requires — a
    capped build is a recall-trading footprint knob and callers must
    check the accounting before trusting parity.
    """
    offsets: jnp.ndarray         # [K + 1] int32 CSR into ``ids``
    ids: jnp.ndarray             # [n_vectors] int32 vector ids
    doc_lists: jnp.ndarray       # [K, Lmax] int32 unique doc ids (padded)
    doc_valid: jnp.ndarray       # [K, Lmax] bool
    doc_member: jnp.ndarray      # [K, n_docs] f32 0/1 centroid->doc owner
    list_cap: int                # Lmax actually used
    overflow: int                # entries truncated by the cap (0 = exact)
    n_docs: int = field(default=0)

    @property
    def n_centroids(self) -> int:
        return self.doc_lists.shape[0]

    def device_bytes(self) -> int:
        return (self.offsets.nbytes + self.ids.nbytes
                + self.doc_lists.nbytes + self.doc_valid.nbytes
                + self.doc_member.nbytes)


def build_device_inverted_lists(ivf: InvertedLists, vec2doc: np.ndarray,
                                n_docs: int, list_cap: int = 0
                                ) -> DeviceInvertedLists:
    """Host-side build of the device IVF layout (shipped once).

    ``list_cap=0`` sizes Lmax to the longest unique-doc list (exact;
    ``overflow == 0``); a positive cap truncates longer lists, keeping
    each list's lowest doc ids, and accounts the drops in ``overflow``.
    """
    from repro.core.docstore import ragged_arange
    K = ivf.n_centroids
    lens = np.diff(ivf.offsets)
    cent = np.repeat(np.arange(K, dtype=np.int64), lens)
    docs = np.asarray(vec2doc, np.int64)[ivf.ids]
    # unique (centroid, doc) pairs, sorted => per-centroid ascending docs
    cd = np.unique(cent * np.int64(max(n_docs, 1)) + docs)
    ci, di = cd // max(n_docs, 1), cd % max(n_docs, 1)
    counts = np.bincount(ci, minlength=K)
    full = int(counts.max(initial=0))
    cap = full if list_cap <= 0 else min(int(list_cap), full)
    cap = max(cap, 1)
    kept = np.minimum(counts, cap)
    overflow = int((counts - kept).sum())
    group_starts = np.zeros(K, np.int64)
    np.cumsum(counts[:-1], out=group_starts[1:])
    pos = np.repeat(group_starts, kept) + ragged_arange(kept)
    doc_lists = np.full((K, cap), n_docs, np.int32)   # sentinel pads
    doc_valid = np.zeros((K, cap), bool)
    rows = np.repeat(np.arange(K), kept)
    cols = ragged_arange(kept)
    doc_lists[rows, cols] = di[pos]
    doc_valid[rows, cols] = True
    doc_member = np.zeros((K, max(n_docs, 1)), np.float32)
    doc_member[rows, di[pos]] = 1.0        # same kept entries, densely
    return DeviceInvertedLists(
        offsets=jnp.asarray(ivf.offsets, jnp.int32),
        ids=jnp.asarray(ivf.ids, jnp.int32),
        doc_lists=jnp.asarray(doc_lists),
        doc_valid=jnp.asarray(doc_valid),
        doc_member=jnp.asarray(doc_member),
        list_cap=cap, overflow=overflow, n_docs=int(n_docs))


def train_centroids(vectors, n_centroids: int, n_iters: int = 12,
                    seed: int = 0) -> jnp.ndarray:
    """vectors [M, dim] -> unit centroids [K, dim] (cosine k-means)."""
    import jax
    return kmeans_train(jnp.asarray(vectors, jnp.float32), k=n_centroids,
                        n_iters=n_iters, key=jax.random.PRNGKey(seed))


def assign_vectors(vectors, centroids) -> np.ndarray:
    """Nearest (max cosine) centroid per vector -> [M] int32."""
    v = jnp.asarray(vectors, jnp.float32)
    v = v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-9)
    return np.asarray(jnp.argmax(v @ jnp.asarray(centroids).T, axis=-1),
                      np.int32)


def build_inverted_lists(assign: np.ndarray, n_centroids: int) -> InvertedLists:
    assign = np.asarray(assign)
    order = np.argsort(assign, kind="stable").astype(np.int64)
    counts = np.bincount(assign, minlength=n_centroids)
    offsets = np.zeros(n_centroids + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    return InvertedLists(offsets=offsets, ids=order)
