"""IVF coarse quantizer: centroid training + inverted lists (CSR layout).

The same k-means substrate the paper's pooling uses, applied at corpus scale:
token vectors are assigned to K coarse centroids; the inverted lists map each
centroid to the vector ids it owns. This is the candidate-generation stage of
the PLAID pipeline (centroid probe -> inverted-list gather).

Centroid training is data-parallel friendly: ``kmeans_train``'s E/M steps are
segment-sums, so under pjit with the vector axis sharded on ``data`` the
statistics all-reduce automatically. List construction is a host-side sort
(it is an index-build artifact, not a hot path).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import kmeans_train


@dataclass
class InvertedLists:
    """CSR inverted file: vectors of centroid c are ids[offsets[c]:offsets[c+1]]."""
    offsets: np.ndarray          # [K + 1] int64
    ids: np.ndarray              # [n_vectors] int64 (vector ids, centroid-major)

    @property
    def n_centroids(self) -> int:
        return len(self.offsets) - 1

    def list_for(self, c: int) -> np.ndarray:
        return self.ids[self.offsets[c]:self.offsets[c + 1]]

    def lists_for(self, cs) -> np.ndarray:
        """Concatenated ids for several centroids (deduplicated)."""
        parts = [self.list_for(int(c)) for c in np.unique(np.asarray(cs))]
        if not parts:
            return np.zeros((0,), np.int64)
        return np.unique(np.concatenate(parts))


def train_centroids(vectors, n_centroids: int, n_iters: int = 12,
                    seed: int = 0) -> jnp.ndarray:
    """vectors [M, dim] -> unit centroids [K, dim] (cosine k-means)."""
    import jax
    return kmeans_train(jnp.asarray(vectors, jnp.float32), k=n_centroids,
                        n_iters=n_iters, key=jax.random.PRNGKey(seed))


def assign_vectors(vectors, centroids) -> np.ndarray:
    """Nearest (max cosine) centroid per vector -> [M] int32."""
    v = jnp.asarray(vectors, jnp.float32)
    v = v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-9)
    return np.asarray(jnp.argmax(v @ jnp.asarray(centroids).T, axis=-1),
                      np.int32)


def build_inverted_lists(assign: np.ndarray, n_centroids: int) -> InvertedLists:
    assign = np.asarray(assign)
    order = np.argsort(assign, kind="stable").astype(np.int64)
    counts = np.bincount(assign, minlength=n_centroids)
    offsets = np.zeros(n_centroids + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    return InvertedLists(offsets=offsets, ids=order)
