"""Versioned on-disk index artifacts: zero-copy save/load for every backend.

The paper's contribution is a smaller *stored* index (50-75% fewer
vectors) — this module is where "stored" becomes a measurable directory
on disk, mirroring ColBERTv2/PLAID practice (the residual-compressed
on-disk index is the primary artifact) and the disk-budget framing of
"Efficient Constant-Space Multi-Vector Retrieval". An artifact is:

    index_dir/
      manifest.json     format_version, kind, backend, params, and a
                        payload table {name: file, dtype, shape, bytes}
      <payload>.npy     raw numpy arrays, one file per tensor

Design rules:
  * The manifest is the single source of truth. Every payload it names
    must exist with exactly the recorded dtype/shape/bytes; a missing
    manifest key, a truncated file, or an unknown ``format_version``
    raises :class:`IndexFormatError` — never garbage search results.
  * ``load(..., mmap=True)`` maps payloads with
    ``np.load(mmap_mode="r")``: loading is O(manifest), and a loaded
    index pays no decode or copy cost until first search (PLAID's
    reconstruction store stays lazy; the flat/HNSW padded device view
    is built on first query, gathering straight from the mapped file).
  * Save COMPACTS lazily-deleted documents out of the payloads while
    preserving doc ids: dead docs become zero-length spans and their
    liveness lands in the ``live`` payload, so a loaded index returns
    bit-identical results to the in-memory one — deletions included —
    while their vectors/codes stop costing bytes.
  * Arrays that search mutates in place (the ``live`` mask) are loaded
    as writable copies; everything heavy stays mapped read-only.
    Mutating APIs (``add``) copy-on-grow, so a loaded index remains
    fully CRUD-capable.
"""
from __future__ import annotations

import itertools
import json
import os
import uuid
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

# the ONE definition of the manifest "params" key set (core/spec.py);
# persist used to shadow its own copy of core/index.PARAM_KEYS — drift
# between the two silently rejected valid manifests
from repro.core.spec import INDEX_PARAM_KEYS as _PARAM_KEYS

FORMAT_VERSION = 1
MANIFEST_NAME = "manifest.json"

# payloads small enough (and mutation-prone enough) to always copy off
# the mapped file; everything else stays zero-copy under mmap=True
_ALWAYS_COPY = ("live", "coarse_live", "fine_live")


class IndexFormatError(Exception):
    """Artifact on disk cannot be read safely by this code version."""


# ---------------------------------------------------------------------------
# Manifest + payload I/O
# ---------------------------------------------------------------------------
def _require(mapping: Dict[str, Any], key: str, where: str) -> Any:
    if key not in mapping:
        raise IndexFormatError(f"missing required key {key!r} in {where}")
    return mapping[key]


def artifact_generation(path: str) -> int:
    """Monotonic publish counter of the artifact at ``path``.

    Every ``write_artifact`` over the same directory bumps it, so a
    serving process can poll this (O(one small json read)) to notice a
    rebuilt index and hot-swap it in (launch/engine.py). Returns 0 when
    no readable manifest exists — generations start at 1.
    """
    try:
        with open(os.path.join(path, MANIFEST_NAME)) as fh:
            return int(json.load(fh).get("generation", 0))
    except (OSError, ValueError, json.JSONDecodeError):
        return 0


def write_artifact(path: str, meta: Dict[str, Any],
                   payloads: Dict[str, np.ndarray]) -> Dict[str, Any]:
    """Write payload .npy files + manifest.json; returns the manifest.

    Crash-safe including re-saves over an existing artifact: payloads
    land under per-save unique filenames (an existing version's files
    are never overwritten), the manifest rename is the single commit
    point, and files the new manifest doesn't reference are deleted
    only after it is published. A crash at any point leaves the
    previously-published version fully loadable (plus, at worst, some
    orphaned payload files the next successful save sweeps up).

    Each publish carries a monotonic ``generation`` (previous
    generation in the directory + 1, or an explicit value passed in
    ``meta``): index watchers key hot swaps off it, and the manifest
    rename above is what makes a generation flip atomic.
    """
    os.makedirs(path, exist_ok=True)
    generation = int(meta.get("generation",
                              artifact_generation(path) + 1))
    token = uuid.uuid4().hex[:8]
    table = {}
    for name, arr in payloads.items():
        arr = np.ascontiguousarray(arr)
        fn = f"{name}.{token}.npy"
        tmp = os.path.join(path, fn + ".tmp")
        with open(tmp, "wb") as fh:
            np.save(fh, arr)
        os.replace(tmp, os.path.join(path, fn))
        table[name] = {"file": fn, "dtype": str(arr.dtype),
                       "shape": list(arr.shape), "bytes": int(arr.nbytes)}
    manifest = dict(meta)
    manifest["format_version"] = FORMAT_VERSION
    manifest["generation"] = generation
    manifest["payloads"] = table
    tmp = os.path.join(path, MANIFEST_NAME + ".tmp")
    with open(tmp, "w") as fh:
        json.dump(manifest, fh, indent=2, sort_keys=True)
    os.replace(tmp, os.path.join(path, MANIFEST_NAME))  # atomic publish
    live_files = {e["file"] for e in table.values()}
    for fn in os.listdir(path):                         # GC stale versions
        if ((fn.endswith(".npy") or fn.endswith(".tmp"))
                and fn not in live_files):
            try:
                os.remove(os.path.join(path, fn))
            except OSError:
                pass                     # a racing reader may hold it open
    return manifest


def read_manifest(path: str) -> Dict[str, Any]:
    """Load + validate manifest.json (version gate, required keys)."""
    mf = os.path.join(path, MANIFEST_NAME)
    if not os.path.isfile(mf):
        raise IndexFormatError(f"no {MANIFEST_NAME} in {path!r} — not an "
                               f"index artifact directory")
    try:
        with open(mf) as fh:
            manifest = json.load(fh)
    except (json.JSONDecodeError, OSError) as e:
        raise IndexFormatError(f"unreadable manifest in {path!r}: {e}")
    ver = _require(manifest, "format_version", mf)
    if ver != FORMAT_VERSION:
        raise IndexFormatError(
            f"format_version {ver!r} not supported (this reader handles "
            f"{FORMAT_VERSION}); re-save the index with the matching code")
    _require(manifest, "kind", mf)
    _require(manifest, "payloads", mf)
    return manifest


def load_payloads(path: str, manifest: Dict[str, Any],
                  mmap: bool = True) -> Dict[str, np.ndarray]:
    """Materialize every payload named by the manifest, validated against
    its recorded dtype/shape/bytes. mmap=True maps files read-only."""
    out: Dict[str, np.ndarray] = {}
    for name, entry in manifest["payloads"].items():
        for key in ("file", "dtype", "shape", "bytes"):
            _require(entry, key, f"payload {name!r}")
        fp = os.path.join(path, entry["file"])
        if not os.path.isfile(fp):
            raise IndexFormatError(f"payload {name!r}: file "
                                   f"{entry['file']!r} is missing")
        mode = "r" if (mmap and name not in _ALWAYS_COPY) else None
        try:
            arr = np.load(fp, mmap_mode=mode)
        except (ValueError, OSError) as e:
            raise IndexFormatError(
                f"payload {name!r}: corrupt or truncated file "
                f"{entry['file']!r} ({e})")
        if (list(arr.shape) != list(entry["shape"])
                or str(arr.dtype) != entry["dtype"]
                or int(arr.nbytes) != int(entry["bytes"])):
            raise IndexFormatError(
                f"payload {name!r}: on-disk {arr.dtype}{list(arr.shape)} "
                f"does not match manifest "
                f"{entry['dtype']}{entry['shape']}")
        if name in _ALWAYS_COPY:
            arr = np.array(arr)         # small + mutated in place
        out[name] = arr
    return out


def artifact_bytes(path_or_manifest) -> int:
    """Real serialized payload size (sum of bytes from the manifest).

    Sharded artifacts record each shard's payload bytes in the root
    manifest at save time, so sizing a K-shard index stays O(manifest)
    — no walk of the shard directories."""
    manifest = (path_or_manifest if isinstance(path_or_manifest, dict)
                else read_manifest(path_or_manifest))
    total = sum(int(e["bytes"]) for e in manifest["payloads"].values())
    if manifest.get("kind") == "sharded_index":
        total += sum(int(_require(e, "bytes", "shard entry"))
                     for e in _require(manifest, "shards", "sharded root"))
    return total


# ---------------------------------------------------------------------------
# DocStore <-> payloads (compacting: dead docs keep ids, lose bytes)
# ---------------------------------------------------------------------------
def _compact_spans(live: np.ndarray, lens: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Shared deletion-compaction arithmetic: per-vector keep mask +
    rebuilt CSR offsets where dead docs become zero-length spans (doc
    ids stay stable, dead docs' rows stop costing bytes)."""
    rows_keep = np.repeat(np.asarray(live, bool), lens)
    new_lens = np.where(live, lens, 0)
    offsets = np.zeros(len(new_lens) + 1, np.int64)
    np.cumsum(new_lens, out=offsets[1:])
    return rows_keep, offsets


def _docstore_payloads(store, prefix: str = "") -> Dict[str, np.ndarray]:
    rows_keep, offsets = _compact_spans(store.live, store.doc_lengths())
    flat = store._flat[:store._n_vectors][rows_keep]
    return {f"{prefix}flat": np.asarray(flat, np.float32),
            f"{prefix}offsets": offsets,
            f"{prefix}live": np.asarray(store.live, bool)}


def _docstore_from(payloads: Dict[str, np.ndarray], prefix: str,
                   doc_maxlen: int):
    from repro.core.docstore import DocStore
    return DocStore.from_arrays(payloads[f"{prefix}flat"],
                                payloads[f"{prefix}offsets"],
                                payloads[f"{prefix}live"],
                                doc_maxlen=doc_maxlen)


# ---------------------------------------------------------------------------
# Residual codec <-> payloads
# ---------------------------------------------------------------------------
def codec_payloads(codec) -> Dict[str, np.ndarray]:
    return {"codec_centroids": np.asarray(codec.centroids, np.float32),
            "codec_cutoffs": np.asarray(codec.cutoffs, np.float32),
            "codec_values": np.asarray(codec.values, np.float32)}


def codec_from_payloads(payloads: Dict[str, np.ndarray], bits: int):
    from repro.core.quantization import ResidualCodec
    return ResidualCodec(
        centroids=jnp.asarray(payloads["codec_centroids"]),
        cutoffs=jnp.asarray(payloads["codec_cutoffs"]),
        values=jnp.asarray(payloads["codec_values"]),
        bits=int(bits))


def save_codec(codec, path: str) -> Dict[str, Any]:
    """Stand-alone codec artifact (also embedded in plaid artifacts)."""
    return write_artifact(path, {"kind": "residual_codec",
                                 "bits": int(codec.bits)},
                          codec_payloads(codec))


def load_codec(path: str, mmap: bool = True):
    manifest = read_manifest(path)
    if manifest["kind"] != "residual_codec":
        raise IndexFormatError(f"expected kind 'residual_codec', found "
                               f"{manifest['kind']!r}")
    payloads = load_payloads(path, manifest, mmap=mmap)
    for name in ("codec_centroids", "codec_cutoffs", "codec_values"):
        _require(payloads, name, "codec artifact")
    return codec_from_payloads(payloads, _require(manifest, "bits", path))


# ---------------------------------------------------------------------------
# MultiVectorIndex <-> artifact
# ---------------------------------------------------------------------------


def index_payloads(index) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
    """(meta, payloads) for a MultiVectorIndex — the exact bytes
    ``save_index`` would write (used for dry-run footprint sizing)."""
    meta: Dict[str, Any] = {
        "kind": "multi_vector_index",
        "backend": index.backend,
        "dim": int(index.dim),
        "n_docs": int(index.n_docs),
        "params": {k: getattr(index, k) for k in _PARAM_KEYS},
    }
    payloads: Dict[str, np.ndarray] = {}
    if index.backend in ("flat", "hnsw"):
        payloads.update(_docstore_payloads(index._store))
        if index.backend == "hnsw" and index._hnsw is not None:
            payloads.update(_hnsw_payloads(index))
            meta["hnsw"] = {"entry": (-1 if index._hnsw.entry is None
                                      else int(index._hnsw.entry)),
                            "max_level": int(index._hnsw.max_level)}
    elif index._plaid is not None:
        meta["codec_bits"] = int(index._plaid.codec.bits)
        payloads.update(_plaid_payloads(index))
    return meta, payloads


def _hnsw_payloads(index) -> Dict[str, np.ndarray]:
    """Graph state in CSR form. Lazily-deleted token nodes keep their
    vectors and edges (they are routing waypoints — dropping them would
    change graph topology and break loaded-vs-in-memory parity); only
    the *document* store sheds deleted docs' bytes."""
    h = index._hnsw
    n = len(h.levels)
    counts = np.zeros((len(h.graph), n), np.int64)
    for lv, rows in enumerate(h.graph):
        counts[lv, :len(rows)] = [len(r) for r in rows]
    edges = np.fromiter(
        itertools.chain.from_iterable(r for rows in h.graph for r in rows),
        np.int64, count=int(counts.sum()))
    deleted = np.fromiter(sorted(h.deleted), np.int64, count=len(h.deleted))
    return {"hnsw_vectors": np.asarray(h.vectors, np.float32),
            "hnsw_levels": np.asarray(h.levels, np.int64),
            "hnsw_edge_counts": counts,
            "hnsw_edges": edges,
            "hnsw_deleted": deleted,
            "hnsw_vec2doc": np.asarray(index._hnsw_vec2doc, np.int64)}


def _plaid_payloads(index) -> Dict[str, np.ndarray]:
    """Compacted PLAID stack: IVF lists + codec + packed residuals.
    Deleted docs' code rows are dropped; their ids survive as
    zero-length spans flagged dead in ``live``."""
    from repro.core.ivf import build_inverted_lists
    p = index._plaid
    live = index._live()
    rows_keep, doc_offsets = _compact_spans(live, np.diff(p.doc_offsets))
    assignments = np.asarray(p.assignments[rows_keep])
    codes = np.asarray(p.codes[rows_keep])
    ivf = build_inverted_lists(assignments, p.codec.n_centroids)
    out = codec_payloads(p.codec)
    out.update({"assignments": assignments,
                "codes": codes,
                "vec2doc": np.repeat(np.arange(index.n_docs),
                                     np.diff(doc_offsets)),
                "doc_offsets": doc_offsets,
                "ivf_ids": ivf.ids,
                "ivf_offsets": ivf.offsets,
                "live": np.asarray(live, bool)})
    return out


def serialized_nbytes(index) -> int:
    """Bytes ``save_index`` would put on disk — the honest footprint
    number (``IndexStats.index_bytes``), without writing anything."""
    # nbytes is stride-independent: it already equals the contiguous
    # serialized size, so no ascontiguousarray copy is needed here
    from repro.retrieval.cascade import CascadeIndex
    if isinstance(index, CascadeIndex):     # two-level stores
        _, payloads = cascade_payloads(index)
    else:
        _, payloads = index_payloads(index)
    return sum(int(a.nbytes) for a in payloads.values())


def save_index(index, path: str,
               extra_meta: Optional[Dict[str, Any]] = None
               ) -> Dict[str, Any]:
    """Write a MultiVectorIndex artifact directory; returns the manifest."""
    meta, payloads = index_payloads(index)
    if extra_meta:
        meta.update(extra_meta)
    return write_artifact(path, meta, payloads)


def load_index(path: str, mmap: bool = True):
    """Reconstruct a MultiVectorIndex from an artifact directory."""
    from repro.core.index import MultiVectorIndex

    manifest = read_manifest(path)
    if manifest["kind"] != "multi_vector_index":
        raise IndexFormatError(f"expected kind 'multi_vector_index', "
                               f"found {manifest['kind']!r}")
    backend = _require(manifest, "backend", path)
    dim = int(_require(manifest, "dim", path))
    params = dict(_require(manifest, "params", path))
    unknown = set(params) - set(_PARAM_KEYS)
    if unknown:
        raise IndexFormatError(f"unknown index params {sorted(unknown)}")
    index = MultiVectorIndex(dim=dim, backend=backend, **params)
    payloads = load_payloads(path, manifest, mmap=mmap)
    if not payloads:                    # empty index: nothing was stored
        return index
    if backend in ("flat", "hnsw"):
        index._store = _docstore_from(payloads, "", index.doc_maxlen)
        index.deleted = set(np.nonzero(~index._store.live)[0].tolist())
        if backend == "hnsw" and "hnsw_vectors" in payloads:
            index._hnsw = _hnsw_from(index, payloads, manifest)
            index._hnsw_vec2doc = payloads["hnsw_vec2doc"]
    else:
        _plaid_from(index, payloads, manifest)
    return index


def _hnsw_from(index, payloads, manifest):
    from repro.core.hnsw import HNSW
    h_meta = _require(manifest, "hnsw", "hnsw artifact")
    return HNSW.from_state(
        dim=index.dim, m=index.hnsw_m,
        ef_construction=index.hnsw_ef_construction,
        vectors=payloads["hnsw_vectors"],
        levels=payloads["hnsw_levels"],
        edge_counts=payloads["hnsw_edge_counts"],
        edges=payloads["hnsw_edges"],
        deleted=payloads["hnsw_deleted"],
        entry=int(_require(h_meta, "entry", "hnsw meta")),
        max_level=int(_require(h_meta, "max_level", "hnsw meta")))


def _plaid_from(index, payloads, manifest):
    from repro.core.ivf import InvertedLists
    from repro.core.plaid import PLAIDIndex
    for name in ("assignments", "codes", "vec2doc", "doc_offsets",
                 "ivf_ids", "ivf_offsets", "live"):
        _require(payloads, name, "plaid artifact")
    codec = codec_from_payloads(
        payloads, _require(manifest, "codec_bits", "plaid artifact"))
    index._plaid = PLAIDIndex(
        codec=codec,
        ivf=InvertedLists(offsets=payloads["ivf_offsets"],
                          ids=payloads["ivf_ids"]),
        assignments=payloads["assignments"],
        codes=payloads["codes"],
        vec2doc=payloads["vec2doc"],
        doc_offsets=payloads["doc_offsets"],
        doc_maxlen=index.doc_maxlen)
    index.deleted = set(np.nonzero(~payloads["live"])[0].tolist())


# ---------------------------------------------------------------------------
# ShardedIndex <-> artifact (root manifest + per-shard artifact dirs)
# ---------------------------------------------------------------------------
def _shard_dirname(i: int) -> str:
    return f"shard_{i:05d}"


def finalize_sharded(sharded, path: str,
                     extra_meta: Optional[Dict[str, Any]] = None
                     ) -> Dict[str, Any]:
    """Publish the ROOT manifest over already-written shard artifacts.

    The streaming builder saves each shard the moment it is flushed
    (bounded host memory); this records the shard table — dir, id base,
    doc count, payload bytes — and commits it atomically, so a crash
    mid-build leaves shard dirs but never a root manifest pointing at
    missing shards. ``save_sharded`` = write every shard, then this.
    """
    entries = []
    for i, shard in enumerate(sharded.shards):
        name = _shard_dirname(i)
        sub = os.path.join(path, name)
        m = read_manifest(sub)          # validates the shard artifact
        if m["kind"] != "multi_vector_index":
            raise IndexFormatError(
                f"shard dir {name!r} holds kind {m['kind']!r}, expected "
                f"'multi_vector_index'")
        entries.append({"dir": name, "base": int(sharded.doc_base[i]),
                        "n_docs": int(shard.n_docs),
                        "bytes": artifact_bytes(m)})
    meta: Dict[str, Any] = {
        "kind": "sharded_index",
        "backend": sharded.backend,
        "dim": int(sharded.dim),
        "n_docs": int(sharded.n_docs),
        "shard_max_vectors": int(sharded.shard_max_vectors),
        "params": dict(sharded.index_kw),
        "shards": entries,
    }
    # a PINNED probe width is part of the layout contract (auto stays
    # unrecorded so pre-existing artifacts round-trip byte-identically)
    if int(getattr(sharded, "probe_threads_cfg", 0)) > 0:
        meta["probe_threads"] = int(sharded.probe_threads_cfg)
    if extra_meta:
        meta.update(extra_meta)
    return write_artifact(path, meta, {})


def save_sharded(sharded, path: str,
                 extra_meta: Optional[Dict[str, Any]] = None
                 ) -> Dict[str, Any]:
    """Write every shard's artifact dir, then the root manifest."""
    for i, shard in enumerate(sharded.shards):
        save_index(shard, os.path.join(path, _shard_dirname(i)))
    return finalize_sharded(sharded, path, extra_meta=extra_meta)


def load_sharded(path: str, mmap: bool = True):
    """Reconstruct a ShardedIndex; each shard mmap-loads lazily, so a
    K-shard cold load is K manifest parses, zero payload reads."""
    from repro.core.sharded import ShardedIndex

    manifest = read_manifest(path)
    if manifest["kind"] != "sharded_index":
        raise IndexFormatError(f"expected kind 'sharded_index', found "
                               f"{manifest['kind']!r}")
    if not manifest.get("shards"):      # empty logical index round-trips
        return ShardedIndex(
            dim=int(_require(manifest, "dim", path)),
            backend=_require(manifest, "backend", path),
            shard_max_vectors=int(manifest.get("shard_max_vectors", 0)),
            probe_threads=int(manifest.get("probe_threads", 0)),
            **dict(manifest.get("params", {})))
    shards, bases = [], []
    base = 0
    for e in _require(manifest, "shards", path):
        for key in ("dir", "base", "n_docs"):
            _require(e, key, "shard entry")
        shard = load_index(os.path.join(path, e["dir"]), mmap=mmap)
        if int(e["base"]) != base or shard.n_docs != int(e["n_docs"]):
            raise IndexFormatError(
                f"shard {e['dir']!r}: doc range [{e['base']}, "
                f"{e['base']}+{e['n_docs']}) disagrees with loaded shard "
                f"({base} docs seen, {shard.n_docs} in shard)")
        shards.append(shard)
        bases.append(base)
        base += shard.n_docs
    out = ShardedIndex.from_parts(
        shards, bases,
        shard_max_vectors=int(manifest.get("shard_max_vectors", 0)),
        probe_threads=int(manifest.get("probe_threads", 0)))
    return out


# ---------------------------------------------------------------------------
# Kind dispatch: one entry point for any artifact directory
# ---------------------------------------------------------------------------
def load_artifact(path: str, mmap: bool = True):
    """Load whatever index artifact lives at ``path``, dispatching on the
    manifest ``kind`` — monolithic, sharded, cascade, or bare codec.
    The transparent loader behind ``Searcher.from_dir`` and
    ``serve --index-dir``: callers need not know how the index was built."""
    kind = read_manifest(path)["kind"]
    if kind == "multi_vector_index":
        return load_index(path, mmap=mmap)
    if kind == "sharded_index":
        return load_sharded(path, mmap=mmap)
    if kind == "cascade_index":
        return load_cascade(path, mmap=mmap)
    if kind == "residual_codec":
        return load_codec(path, mmap=mmap)
    raise IndexFormatError(f"unknown artifact kind {kind!r} at {path!r}")


# ---------------------------------------------------------------------------
# CascadeIndex <-> artifact
# ---------------------------------------------------------------------------
def cascade_payloads(cascade) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
    """(meta, payloads) for a CascadeIndex — the exact bytes
    ``save_cascade`` would write (also used for footprint sizing)."""
    meta = {"kind": "cascade_index",
            "dim": int(cascade.dim),
            "coarse_factor": int(cascade.coarse_factor),
            "fine_factor": int(cascade.fine_factor),
            "candidates": int(cascade.candidates),
            "doc_maxlen": int(cascade.doc_maxlen)}
    payloads = _docstore_payloads(cascade._coarse, "coarse_")
    payloads.update(_docstore_payloads(cascade._fine, "fine_"))
    return meta, payloads


def save_cascade(cascade, path: str,
                 extra_meta: Optional[Dict[str, Any]] = None
                 ) -> Dict[str, Any]:
    meta, payloads = cascade_payloads(cascade)
    if extra_meta:
        meta.update(extra_meta)
    return write_artifact(path, meta, payloads)


def load_cascade(path: str, mmap: bool = True):
    from repro.retrieval.cascade import CascadeIndex
    manifest = read_manifest(path)
    if manifest["kind"] != "cascade_index":
        raise IndexFormatError(f"expected kind 'cascade_index', found "
                               f"{manifest['kind']!r}")
    cascade = CascadeIndex(
        dim=int(_require(manifest, "dim", path)),
        coarse_factor=int(_require(manifest, "coarse_factor", path)),
        fine_factor=int(_require(manifest, "fine_factor", path)),
        candidates=int(_require(manifest, "candidates", path)),
        doc_maxlen=int(_require(manifest, "doc_maxlen", path)))
    payloads = load_payloads(path, manifest, mmap=mmap)
    cascade._coarse = _docstore_from(payloads, "coarse_",
                                     cascade.doc_maxlen)
    cascade._fine = _docstore_from(payloads, "fine_", cascade.doc_maxlen)
    return cascade
