"""PLAID/ColBERTv2-style residual quantization (paper §3.1 "2-bit
quantization ... performed with the original codebase").

Every token vector v is stored as:
    centroid id  (int32 -> the IVF coarse quantizer)
  + per-dimension b-bit bucket code of the residual r = v - c[id]

Bucket cutoffs are residual quantiles (2^b buckets per dimension), bucket
reconstruction values are the per-bucket means — matching the ColBERTv2
codec. Codes are bit-packed, 16 codes per int32 word at b=2.

All encode/decode paths are jnp (jit-able, shardable); the fused
dequant+score Pallas kernel lives in kernels/quant.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.quant.ref import unpack_ref


@dataclass
class ResidualCodec:
    centroids: jnp.ndarray      # [K, dim] unit vectors
    cutoffs: jnp.ndarray        # [dim, 2^b - 1] bucket boundaries
    values: jnp.ndarray         # [dim, 2^b] reconstruction values
    bits: int

    @property
    def dim(self):
        return self.centroids.shape[1]

    @property
    def n_centroids(self):
        return self.centroids.shape[0]


def train_codec(vectors, centroids, bits: int = 2,
                sample: int = 65536, seed: int = 0) -> ResidualCodec:
    """Fit bucket cutoffs/values from (a sample of) residuals.

    vectors: [M, dim]; centroids: [K, dim].
    """
    vectors = jnp.asarray(vectors, jnp.float32)
    centroids = jnp.asarray(centroids, jnp.float32)
    M = vectors.shape[0]
    if M > sample:
        idx = jax.random.permutation(jax.random.PRNGKey(seed), M)[:sample]
        vectors = vectors[idx]
    assign = jnp.argmax(vectors @ centroids.T, axis=-1)
    res = vectors - centroids[assign]                       # [m, dim]
    nb = 1 << bits
    qs = jnp.arange(1, nb) / nb                             # 2^b - 1 quantiles
    cutoffs = jnp.quantile(res, qs, axis=0).T               # [dim, nb-1]
    # bucket values = mean of residuals falling in the bucket
    codes = _bucketize(res, cutoffs)                        # [m, dim]
    dim = res.shape[1]
    flat_seg = codes + (jnp.arange(dim)[None, :] * nb)
    sums = jax.ops.segment_sum(res.T.reshape(-1),
                               flat_seg.T.reshape(-1),
                               num_segments=dim * nb)
    cnts = jax.ops.segment_sum(jnp.ones_like(res.T.reshape(-1)),
                               flat_seg.T.reshape(-1),
                               num_segments=dim * nb)
    values = (sums / jnp.maximum(cnts, 1.0)).reshape(dim, nb)
    return ResidualCodec(centroids=centroids, cutoffs=cutoffs,
                         values=values, bits=bits)


def _bucketize(res, cutoffs):
    """res: [M, dim]; cutoffs: [dim, nb-1] -> codes [M, dim] int32."""
    # code = number of cutoffs strictly below the value
    return jnp.sum(res[:, :, None] > cutoffs[None, :, :], axis=-1) \
        .astype(jnp.int32)


# ---------------------------------------------------------------------------
# Bit packing: codes [M, dim] (b bits each) <-> words [M, dim*b/32] int32
# ---------------------------------------------------------------------------
def _codes_per_word(bits):
    assert 32 % bits == 0
    return 32 // bits


@functools.partial(jax.jit, static_argnames=("bits",))
def pack_codes(codes, bits: int):
    M, dim = codes.shape
    cpw = _codes_per_word(bits)
    assert dim % cpw == 0, (dim, cpw)
    c = codes.reshape(M, dim // cpw, cpw).astype(jnp.uint32)
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * bits)
    words = jnp.sum(c << shifts[None, None, :], axis=-1)
    return words.astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("bits", "dim"))
def unpack_codes(words, bits: int, dim: int):
    return unpack_ref(words, bits, dim)


# ---------------------------------------------------------------------------
# Encode / decode
# ---------------------------------------------------------------------------
def encode(codec: ResidualCodec, vectors):
    """vectors [M, dim] -> (centroid ids [M], packed words [M, W])."""
    vectors = jnp.asarray(vectors, jnp.float32)
    assign = jnp.argmax(vectors @ codec.centroids.T, axis=-1).astype(jnp.int32)
    res = vectors - codec.centroids[assign]
    codes = _bucketize(res, codec.cutoffs)
    return assign, pack_codes(codes, codec.bits)


def decode(codec: ResidualCodec, assign, words):
    """-> reconstructed vectors [M, dim] (unit-renormalized)."""
    dim = codec.dim
    codes = unpack_codes(words, codec.bits, dim)       # [M, dim]
    res = codec.values[jnp.arange(dim)[None, :], codes]  # [M, dim]
    v = codec.centroids[assign] + res
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-9)


def reconstruction_error(codec: ResidualCodec, vectors):
    a, w = encode(codec, vectors)
    rec = decode(codec, a, w)
    vn = vectors / jnp.maximum(
        jnp.linalg.norm(vectors, axis=-1, keepdims=True), 1e-9)
    return jnp.mean(jnp.sum(vn * rec, axis=-1))        # mean cosine


def storage_bytes(n_vectors: int, dim: int, bits: int) -> int:
    """Bytes for the compressed store: ids (4B) + packed codes."""
    return n_vectors * (4 + dim * bits // 8)
