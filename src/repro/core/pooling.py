"""TOKEN POOLING — the paper's contribution (§2), as a drop-in indexing step.

Given per-document token embeddings, group them with one of three clustering
methods and replace each group by its (re-normalized) mean:

  * ``sequential`` — pool runs of ``factor`` consecutive tokens (paper baseline)
  * ``kmeans``     — cosine k-means, K = floor(n/factor) + 1
  * ``ward``       — hierarchical Ward clustering (paper's best method)

No training, no query-time change: this runs between the encoder and the
index. ``pool_factor=1`` or method ``none`` is the identity (the unpooled
baseline every paper table is normalized against).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.kmeans import kmeans_cluster_batch
from repro.core.ward import ward_cluster_batch

METHODS = ("none", "sequential", "kmeans", "ward")


def sequential_assign(mask, factor: int):
    """Mask-aware run grouping: the g-th VALID token joins group
    ``g // factor``. mask: [B, N] -> assign [B, N] int32.

    Grouping by valid-token rank (``cumsum(mask) - 1``) rather than raw
    position means punctuation-masked gaps don't split a run: a doc with
    n valid tokens pools to exactly ``ceil(n / factor)`` vectors instead
    of one per partially-covered position block. Masked positions get an
    arbitrary (weight-zero) group id.
    """
    rank = jnp.cumsum(mask.astype(jnp.int32), axis=-1) - 1
    return (jnp.maximum(rank, 0) // factor).astype(jnp.int32)


def _mean_pool_by_assign(x, mask, assign, num_segments: int,
                         renormalize: bool = True):
    """Segment-mean x by assign per document.

    x: [B, N, d]; mask: [B, N]; assign: [B, N] ids in [0, num_segments).
    Returns pooled [B, num_segments, d], pooled_mask [B, num_segments].
    """
    w = mask.astype(jnp.float32)

    def one(xi, wi, ai):
        sums = jax.ops.segment_sum(xi * wi[:, None], ai,
                                   num_segments=num_segments)
        cnts = jax.ops.segment_sum(wi, ai, num_segments=num_segments)
        mean = sums / jnp.maximum(cnts[:, None], 1e-9)
        if renormalize:
            nrm = jnp.linalg.norm(mean, axis=-1, keepdims=True)
            mean = mean / jnp.maximum(nrm, 1e-9)
        return mean * (cnts > 0)[:, None], cnts > 0

    return jax.vmap(one)(x.astype(jnp.float32), w, assign)


@functools.partial(jax.jit, static_argnames=("factor", "method",
                                             "renormalize"))
def pool_doc_embeddings(x, mask, factor: int, method: str = "ward",
                        renormalize: bool = True
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pool token vectors (the paper's indexing-time compression step).

    Args:
      x: [B, N, d] token embeddings.
      mask: [B, N] bool — True for real tokens.
      factor: the POOLING FACTOR (2 -> 50% fewer vectors, 3 -> 66%, ...).
      method: none | sequential | kmeans | ward.

    Returns:
      pooled: [B, N, d] — pooled vectors scattered into slots (zero rows
              where no cluster lives); compact host-side for storage.
      pooled_mask: [B, N] bool — which slots hold a pooled vector.
    """
    assert method in METHODS, method
    B, N, d = x.shape
    if method == "none" or factor <= 1:
        xo = x.astype(jnp.float32)
        if renormalize:
            xo = xo / jnp.maximum(
                jnp.linalg.norm(xo, axis=-1, keepdims=True), 1e-9)
        return jnp.where(mask[..., None], xo, 0.0), mask

    if method == "sequential":
        assign = sequential_assign(mask, factor)
        nseg = (N + factor - 1) // factor
        pooled, pmask = _mean_pool_by_assign(x, mask, assign, nseg,
                                             renormalize)
        pad = N - nseg
        pooled = jnp.pad(pooled, ((0, 0), (0, pad), (0, 0)))
        pmask = jnp.pad(pmask, ((0, 0), (0, pad)))
        return pooled, pmask

    if method == "kmeans":
        assign = kmeans_cluster_batch(x, mask, factor)
        k_max = N // factor + 1
        pooled, pmask = _mean_pool_by_assign(x, mask, assign, k_max,
                                             renormalize)
        pad = N - k_max
        pooled = jnp.pad(pooled, ((0, 0), (0, pad), (0, 0)))
        pmask = jnp.pad(pmask, ((0, 0), (0, pad)))
        return pooled, pmask

    # ward: assign ids live in [0, N) (representative token index)
    assign = ward_cluster_batch(x, mask, factor)
    pooled, pmask = _mean_pool_by_assign(x, mask, assign, N, renormalize)
    return pooled, pmask


def compact_pooled(pooled, pooled_mask):
    """Host-side: drop empty slots -> list of [n_i, d] numpy arrays.

    One device->host transfer and ONE boolean gather over the whole
    batch; the per-doc arrays are ``np.split`` views on the cumulative
    counts (no per-doc fancy-index loop).
    """
    import numpy as np
    pooled = np.asarray(pooled)
    pooled_mask = np.asarray(pooled_mask).astype(bool)
    if pooled.shape[0] == 0:
        return []
    counts = pooled_mask.sum(axis=1)
    flat = pooled[pooled_mask]                    # [sum(counts), d]
    return np.split(flat, np.cumsum(counts[:-1]))


def vector_counts(mask, pooled_mask):
    """(original vector count, pooled vector count) per batch — Table 3."""
    return (int(jnp.sum(mask.astype(jnp.int32))),
            int(jnp.sum(pooled_mask.astype(jnp.int32))))
