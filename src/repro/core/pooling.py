"""TOKEN POOLING — the paper's contribution (§2), as a drop-in indexing step.

Given per-document token embeddings, group them with one of three clustering
methods and replace each group by its (re-normalized) mean:

  * ``sequential`` — pool runs of ``factor`` consecutive tokens (paper baseline)
  * ``kmeans``     — cosine k-means, K = floor(n/factor) + 1
  * ``ward``       — hierarchical Ward clustering (paper's best method)

No training, no query-time change: this runs between the encoder and the
index. ``pool_factor=1`` or method ``none`` is the identity (the unpooled
baseline every paper table is normalized against).

The ward path dispatches through ``kernels/ward_pool`` (Pallas merge-loop
kernel, bitwise-equal to ``core/ward.py``; ``ward_kernel="ref"`` pins the
original loop), and ``compact_pooled`` compacts ON DEVICE first — a
validity-sort moves the pooled rows doc-major to the front so the
device->host transfer is ``sum(counts)`` rows + a counts vector,
~1/factor of the padded ``[B, N, d]`` tensor
(``compaction_transfer_stats`` reports the measured ratio).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.kmeans import kmeans_cluster_batch
from repro.core.ward import ward_cluster_batch

METHODS = ("none", "sequential", "kmeans", "ward")


def sequential_assign(mask, factor: int):
    """Mask-aware run grouping: the g-th VALID token joins group
    ``g // factor``. mask: [B, N] -> assign [B, N] int32.

    Grouping by valid-token rank (``cumsum(mask) - 1``) rather than raw
    position means punctuation-masked gaps don't split a run: a doc with
    n valid tokens pools to exactly ``ceil(n / factor)`` vectors instead
    of one per partially-covered position block. Masked positions get an
    arbitrary (weight-zero) group id.
    """
    rank = jnp.cumsum(mask.astype(jnp.int32), axis=-1) - 1
    return (jnp.maximum(rank, 0) // factor).astype(jnp.int32)


def _mean_pool_by_assign(x, mask, assign, num_segments: int,
                         renormalize: bool = True):
    """Segment-mean x by assign per document.

    x: [B, N, d]; mask: [B, N]; assign: [B, N] ids in [0, num_segments).
    Returns pooled [B, num_segments, d], pooled_mask [B, num_segments].
    """
    w = mask.astype(jnp.float32)

    def one(xi, wi, ai):
        sums = jax.ops.segment_sum(xi * wi[:, None], ai,
                                   num_segments=num_segments)
        cnts = jax.ops.segment_sum(wi, ai, num_segments=num_segments)
        mean = sums / jnp.maximum(cnts[:, None], 1e-9)
        if renormalize:
            nrm = jnp.linalg.norm(mean, axis=-1, keepdims=True)
            mean = mean / jnp.maximum(nrm, 1e-9)
        return mean * (cnts > 0)[:, None], cnts > 0

    return jax.vmap(one)(x.astype(jnp.float32), w, assign)


@functools.partial(jax.jit, static_argnames=("factor", "method",
                                             "renormalize", "ward_kernel"))
def pool_doc_embeddings(x, mask, factor: int, method: str = "ward",
                        renormalize: bool = True,
                        ward_kernel: str = "auto"
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pool token vectors (the paper's indexing-time compression step).

    Args:
      x: [B, N, d] token embeddings.
      mask: [B, N] bool — True for real tokens.
      factor: the POOLING FACTOR (2 -> 50% fewer vectors, 3 -> 66%, ...).
      method: none | sequential | kmeans | ward.
      ward_kernel: ward implementation — "auto"/"kernel" = the Pallas
        merge-loop kernel (kernels/ward_pool), "ref" = core/ward.py's
        loop. Bitwise-identical outputs either way.

    Returns:
      pooled: [B, N, d] — pooled vectors scattered into slots (zero rows
              where no cluster lives); compact host-side for storage.
      pooled_mask: [B, N] bool — which slots hold a pooled vector.
    """
    assert method in METHODS, method
    B, N, d = x.shape
    if method == "none" or factor <= 1:
        xo = x.astype(jnp.float32)
        if renormalize:
            xo = xo / jnp.maximum(
                jnp.linalg.norm(xo, axis=-1, keepdims=True), 1e-9)
        return jnp.where(mask[..., None], xo, 0.0), mask

    if method == "sequential":
        assign = sequential_assign(mask, factor)
        nseg = (N + factor - 1) // factor
        pooled, pmask = _mean_pool_by_assign(x, mask, assign, nseg,
                                             renormalize)
        pad = N - nseg
        pooled = jnp.pad(pooled, ((0, 0), (0, pad), (0, 0)))
        pmask = jnp.pad(pmask, ((0, 0), (0, pad)))
        return pooled, pmask

    if method == "kmeans":
        assign = kmeans_cluster_batch(x, mask, factor)
        k_max = N // factor + 1
        pooled, pmask = _mean_pool_by_assign(x, mask, assign, k_max,
                                             renormalize)
        pad = N - k_max
        pooled = jnp.pad(pooled, ((0, 0), (0, pad), (0, 0)))
        pmask = jnp.pad(pmask, ((0, 0), (0, pad)))
        return pooled, pmask

    # ward: assign ids live in [0, N) (representative token index)
    if ward_kernel == "ref":
        assign = ward_cluster_batch(x, mask, factor)
    else:
        from repro.kernels.ward_pool.ops import ward_assign
        assign = ward_assign(x, mask, factor, impl=ward_kernel)
    pooled, pmask = _mean_pool_by_assign(x, mask, assign, N, renormalize)
    return pooled, pmask


# device->host compaction traffic, cumulative across compact_pooled
# calls: padded = the [B, N, d] tensor the pre-kernel path shipped,
# compact = what the validity-sorted path actually moves (rows+counts).
_TRANSFER_STATS = {"padded_bytes": 0, "compact_bytes": 0, "batches": 0}


def compaction_transfer_stats(reset: bool = False) -> dict:
    """Cumulative compaction transfer accounting (the bench's
    <= 1/factor + eps gate reads this)."""
    out = dict(_TRANSFER_STATS)
    if reset:
        for k in _TRANSFER_STATS:
            _TRANSFER_STATS[k] = 0
    return out


@jax.jit
def _compact_device(pooled, pooled_mask):
    """Validity-sort pooled slots doc-major-valid-first so the host
    only pulls ``sum(counts)`` rows. The sort key is the flat slot
    index biased by B*N for empty slots — distinct integers, so the
    order is deterministic and equals the boolean-gather order."""
    B, N, d = pooled.shape
    flat_mask = pooled_mask.reshape(-1)
    idx = jnp.arange(B * N, dtype=jnp.int32)
    order = jnp.argsort(jnp.where(flat_mask, idx, idx + B * N))
    flat = pooled.reshape(B * N, d)[order]
    counts = jnp.sum(pooled_mask.astype(jnp.int32), axis=1)
    return flat, counts


def compact_pooled_begin(pooled, pooled_mask):
    """Dispatch the device-side compaction WITHOUT blocking: returns an
    opaque ticket for :func:`compact_pooled_finish`. Lets a caller
    overlap batch i's host fetch with batch i+1's device compute
    (``Indexer.encode_and_pool_counted`` runs a 1-deep pipeline)."""
    flat, counts = _compact_device(pooled, pooled_mask)
    return (flat, counts, pooled.shape, pooled.dtype)


def compact_pooled_finish(ticket):
    """Materialize a :func:`compact_pooled_begin` ticket on the host:
    only ``sum(counts)`` rows + the [B] counts vector cross."""
    import numpy as np
    flat, counts_dev, shape, dtype = ticket
    counts = np.asarray(counts_dev)
    total = int(counts.sum())
    host = np.asarray(flat[:total])               # the only row transfer
    B, N, d = shape
    _TRANSFER_STATS["padded_bytes"] += (
        B * N * d * np.dtype(dtype).itemsize)
    _TRANSFER_STATS["compact_bytes"] += host.nbytes + counts.nbytes
    _TRANSFER_STATS["batches"] += 1
    return np.split(host, np.cumsum(counts[:-1]))


def compact_pooled(pooled, pooled_mask):
    """Drop empty slots -> list of [n_i, d] numpy arrays.

    Device inputs take the compact-transfer path: slots are sorted by
    validity ON DEVICE and only the ``sum(counts)`` leading rows cross
    to the host (plus the [B] counts vector) — ~1/factor of the padded
    tensor's bytes. Host (numpy) inputs keep the single boolean gather.
    Both paths return bitwise-identical arrays; the per-doc arrays are
    ``np.split`` views on the cumulative counts either way.
    """
    import numpy as np
    if pooled.shape[0] == 0:
        return []
    if isinstance(pooled, jax.Array) and isinstance(pooled_mask,
                                                    jax.Array):
        return compact_pooled_finish(
            compact_pooled_begin(pooled, pooled_mask))
    pooled = np.asarray(pooled)
    pooled_mask = np.asarray(pooled_mask).astype(bool)
    counts = pooled_mask.sum(axis=1)
    flat = pooled[pooled_mask]                    # [sum(counts), d]
    return np.split(flat, np.cumsum(counts[:-1]))


def vector_counts(mask, pooled_mask):
    """(original vector count, pooled vector count) per batch — Table 3."""
    return (int(jnp.sum(mask.astype(jnp.int32))),
            int(jnp.sum(pooled_mask.astype(jnp.int32))))
