import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

DOC = """Hillclimb harness: run a named VARIANT of a cell and diff its
roofline terms against the baseline.

    python -m repro.roofline.hillclimb --arch kimi-k2-1t-a32b \
        --cell train_4k --variant seqpar

Each variant is a (cfg_overrides, rules_overrides) pair — a hypothesis
about what moves the dominant term, applied without touching model code.
"""

import argparse
import json
import sys

from repro.launch.dryrun import run_cell

# variant -> dict(cfg=..., rules=..., note=...)
VARIANTS = {
    # Megatron-style sequence parallelism: residual-stream activations
    # sharded on seq over the TP axis (all-gather before attn/mlp,
    # reduce-scatter after) — targets activation memory + HBM traffic.
    "seqpar": dict(
        rules={"seq": "model"},
        note="residual stream seq-sharded over TP"),
    # smaller grad-accum microbatches: less live activation per microbatch
    "micro16": dict(cfg={"train_microbatches": 16},
                    note="16 grad-accum microbatches"),
    "micro2": dict(cfg={"train_microbatches": 2},
                   note="2 grad-accum microbatches"),
    # bigger attention chunks for prefill (fewer scan steps, same flops)
    "chunk4k": dict(cfg={"attn_chunk": 4096}, note="attn chunks 4096"),
    "chunk8k": dict(cfg={"attn_chunk": 8192}, note="attn chunks 8192"),
    # decode: bf16 -> f32 cache would double memory; try keeping scores
    # bf16 end to end (dtype experiment)
    "nopremat": dict(cfg={"remat": False}, note="remat off"),
    # GNN: shard edge/triplet tables on data only (model axis free for
    # feature dim), vs the data+model default
    "gnn_dataonly": dict(
        rules={"edges": "data", "triplets": "data"},
        note="edge/triplet tables sharded on data only"),
    # rows on data, FEATURE dim on model: irregular gathers only
    # all-gather over data (operand [E, h/16] instead of [E, h])
    "gnn_hshard": dict(
        rules={"edges": "data", "triplets": "data", "hidden": "model"},
        note="edge rows on data, feature dim on model"),
    # RecSys: shard embedding tables on the FIELD axis instead of rows
    "recsys_fieldshard": dict(
        rules={"vocab_rows": None},
        param_rules="field",
        note="tables sharded by field, rows replicated"),
    # MoE: expert-parallel all-to-all dispatch (shard_map) instead of the
    # capacity-buffer scatter the partitioner turns into all-reduces
    "moe_ep": dict(cfg={"moe_impl": "ep"},
                   note="EP all-to-all dispatch via shard_map"),
    "moe_ep_micro2": dict(cfg={"moe_impl": "ep", "train_microbatches": 2},
                          note="EP dispatch + 2 microbatches"),
    # ColBERT search: streamed doc blocks, no materialized score tensor
    "maxsim_blocked": dict(cfg={"maxsim_impl": "blocked"},
                           note="blocked MaxSim (no [Nq,Nd,Lq,Ld] in HBM)"),
    "maxsim_blocked_2k": dict(cfg={"maxsim_impl": "blocked",
                                   "maxsim_block": 2048},
                              note="blocked MaxSim, 2048-doc blocks"),
    # ColBERT search: shard the query batch over data for the encoder
    # (baseline encodes every query on every chip), all-gather the tiny
    # [Nq, Lq, 128] result before MaxSim
    "qshard": dict(rules={"queries": "data"},
                   note="query encode sharded over data"),
    "qshard_blocked": dict(rules={"queries": "data"},
                           cfg={"maxsim_impl": "blocked"},
                           note="query-sharded encode + blocked MaxSim"),
    # ColBERT search: shard the doc set over BOTH mesh axes (baseline
    # leaves the model axis idle -> 16/256 of the machine works)
    "docs2d": dict(rules={"docs": ("data", "model")},
                   note="docs sharded over data x model"),
    "docs2d_blocked": dict(rules={"docs": ("data", "model")},
                           cfg={"maxsim_impl": "blocked",
                                "maxsim_block": 256},
                           note="docs over both axes + blocked MaxSim"),
    "docs2d_blocked_qshard": dict(
        rules={"docs": ("data", "model"), "queries": "data"},
        cfg={"maxsim_impl": "blocked", "maxsim_block": 256},
        note="docs 2d + blocked + query-sharded encode"),
}


def run_variant(arch: str, cell: str, variant: str, *, unroll_L=(2, 4),
                full_L: int | None = None, multi_pod=False) -> dict:
    spec = VARIANTS[variant]
    kw = dict(cfg_overrides=spec.get("cfg"),
              rules_overrides=spec.get("rules"))
    out = {"variant": variant, "note": spec.get("note", "")}
    # memory/compile check (scanned)
    r = run_cell(arch, cell, multi_pod=multi_pod, verbose=False, **kw)
    out["scanned"] = {k: r.get(k) for k in
                      ("compile_s", "argument_size_in_bytes",
                       "temp_size_in_bytes", "flops", "bytes_accessed",
                       "collective_bytes")}
    # cost extrapolation (unrolled at two layer counts)
    if full_L and full_L > max(unroll_L):
        a = run_cell(arch, cell, unroll=True, layers_override=unroll_L[0],
                     verbose=False, **kw)
        b = run_cell(arch, cell, unroll=True, layers_override=unroll_L[1],
                     verbose=False, **kw)
        span = unroll_L[1] - unroll_L[0]
        ex = {}
        for key in ("flops", "bytes_accessed", "collective_bytes"):
            per_l = (b[key] - a[key]) / span
            ex[key] = max(a[key] + (full_L - unroll_L[0]) * per_l, 0.0)
        out["extrapolated"] = ex
    else:
        c = run_cell(arch, cell, unroll=True, verbose=False, **kw)
        out["extrapolated"] = {k: c[k] for k in
                               ("flops", "bytes_accessed",
                                "collective_bytes")}
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", required=True)
    ap.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--full-layers", type=int, default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    out = run_variant(args.arch, args.cell, args.variant,
                      full_L=args.full_layers)
    print(json.dumps(out, indent=1))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
