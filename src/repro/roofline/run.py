import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

DOC = """Roofline runner: baseline every (arch x shape) cell.

Per cell:
  1. scanned compile, single-pod + multi-pod  -> proves sharding coherence,
     memory_analysis (does it fit 16 GiB HBM).
  2. unrolled compiles at L in {2, 4} (single-pod) -> flops / bytes /
     collective bytes, linearly extrapolated to the full layer count
     (XLA cost_analysis counts while bodies ONCE; unrolled small-L runs
     measure the exact per-layer marginal, which is constant by
     construction for scanned stacks).
  3. three roofline terms + MODEL_FLOPS (analytic 6ND/2ND) + bottleneck.

Emits JSON (for EXPERIMENTS.md) and a markdown table.

    python -m repro.roofline.run --arch qwen3-0.6b --json roofline.json
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import (ColbertConfig, DimeNetConfig, RecsysConfig,
                                TransformerConfig)
from repro.launch.dryrun import run_cell
from repro.launch.input_specs import all_cells
from repro.roofline import hw
from repro.roofline.analysis import (HEADER, RooflineTerms,
                                     from_dryrun)


def _full_layers(cfg) -> int:
    if isinstance(cfg, TransformerConfig):
        return cfg.n_layers
    if isinstance(cfg, DimeNetConfig):
        return cfg.n_blocks
    if isinstance(cfg, ColbertConfig):
        return cfg.trunk.n_layers
    return 0


def _model_flops(arch: str, cell: str, n_chips: int) -> float:
    """Analytic useful flops per chip for the cell (6ND train / 2ND fwd,
    plus exact attention-matmul terms)."""
    cfg = get_config(arch)
    if isinstance(cfg, TransformerConfig):
        from repro.configs.base import LM_SHAPES
        c = {s.name: s for s in LM_SHAPES}[cell]
        seq, gb = c.dim("seq_len"), c.dim("global_batch")
        n_act = cfg.active_param_count()
        L, Hd = cfg.n_layers, cfg.n_heads * cfg.d_head
        if c.kind == "train":
            toks = seq * gb
            attn = 4 * toks * (seq / 2) * Hd * L        # qk+av, causal
            return (6 * n_act * toks + 3 * attn) / n_chips
        if c.kind == "prefill":
            toks = seq * gb
            attn = 4 * toks * (seq / 2) * Hd * L
            return (2 * n_act * toks + attn) / n_chips
        # decode: 1 token/seq against seq-length cache
        attn = 4 * gb * seq * Hd * L
        return (2 * n_act * gb + attn) / n_chips
    if isinstance(cfg, RecsysConfig):
        # MLP-dominated: count MLP + interaction flops analytically
        from repro.configs.base import RECSYS_SHAPES
        c = {s.name: s for s in RECSYS_SHAPES}[cell]
        B = c.dim("batch")
        D = cfg.embed_dim
        f = 0
        dims = None
        if cfg.kind == "dlrm":
            seqs = [(cfg.n_dense,) + tuple(cfg.bot_mlp_dims)]
            n_emb = cfg.n_sparse + 1
            d_top = n_emb * (n_emb - 1) // 2 + cfg.bot_mlp_dims[-1]
            seqs.append((d_top,) + tuple(cfg.top_mlp_dims))
        elif cfg.kind in ("wide_deep", "deepfm"):
            d_in = cfg.n_sparse * D + cfg.n_dense
            seqs = [(d_in,) + tuple(cfg.mlp_dims) + (1,)]
        else:
            seqs = []
        for seq_dims in seqs:
            for a, b in zip(seq_dims[:-1], seq_dims[1:]):
                f += 2 * a * b
        f += 4 * cfg.n_sparse * D                        # fm/interaction-ish
        mult = 3 if c.kind == "train" else 1
        total = mult * f * B
        if cell == "retrieval_cand":
            total += 2 * c.dim("n_candidates") * D * B
        return total / n_chips
    if isinstance(cfg, ColbertConfig):
        from repro.configs.base import COLBERT_SHAPES
        c = {s.name: s for s in COLBERT_SHAPES}[cell]
        n_trunk = cfg.trunk.param_count() + cfg.trunk.d_model * cfg.proj_dim
        if cell == "index_build":
            toks = c.dim("n_docs") * c.dim("doc_len")
            return 2 * n_trunk * toks / n_chips
        # search: query encode + MaxSim over the sharded doc set
        q_toks = c.dim("n_queries") * cfg.query_maxlen
        maxsim = (2 * c.dim("n_queries") * cfg.query_maxlen
                  * c.dim("n_docs") * c.dim("doc_len") * cfg.proj_dim)
        return (2 * n_trunk * q_toks + maxsim) / n_chips
    if isinstance(cfg, DimeNetConfig):
        from repro.launch.input_specs import GNN_CELL_META, _gnn_counts
        from repro.configs.base import GNN_SHAPES
        c = {s.name: s for s in GNN_SHAPES}[cell]
        N, E, T = _gnn_counts(c, cfg.triplet_cap)
        h, nb = cfg.d_hidden, cfg.n_bilinear
        per_edge = 6 * h * h * cfg.n_blocks              # msg MLPs
        per_trip = 2 * nb * h * h * cfg.n_blocks         # bilinear einsum
        fwd = E * per_edge + T * per_trip + N * 2 * h * h
        return 3 * fwd / n_chips                         # train
    return 0.0


def analyse_cell(arch: str, cell: str, *, skip_multipod: bool = False,
                 verbose: bool = True) -> dict:
    cfg = get_config(arch)
    L_full = _full_layers(cfg)
    out = {"arch": arch, "cell": cell}

    # 1. scanned compiles (the §Dry-run deliverable)
    r1 = run_cell(arch, cell, multi_pod=False, verbose=False)
    out["single_pod"] = r1
    if not skip_multipod:
        r2 = run_cell(arch, cell, multi_pod=True, verbose=False)
        out["multi_pod"] = {k: v for k, v in r2.items()
                            if k not in ("collectives",)}

    # 2. unrolled cost extrapolation
    if L_full > 4:
        a = run_cell(arch, cell, unroll=True, layers_override=2,
                     verbose=False)
        b = run_cell(arch, cell, unroll=True, layers_override=4,
                     verbose=False)
        def extrap(key):
            per_layer = (b[key] - a[key]) / 2.0
            base = a[key] - 2.0 * per_layer
            return max(base + L_full * per_layer, 0.0)
        flops = extrap("flops")
        byts = extrap("bytes_accessed")
        coll = extrap("collective_bytes")
        out["extrapolated"] = {"L": L_full, "flops": flops, "bytes": byts,
                               "collective_bytes": coll,
                               "L2": {k: a[k] for k in
                                      ("flops", "bytes_accessed",
                                       "collective_bytes")},
                               "L4": {k: b[k] for k in
                                      ("flops", "bytes_accessed",
                                       "collective_bytes")}}
    else:
        c = run_cell(arch, cell, unroll=True, verbose=False)
        flops, byts, coll = (c["flops"], c["bytes_accessed"],
                             c["collective_bytes"])
        out["extrapolated"] = {"L": L_full, "flops": flops, "bytes": byts,
                               "collective_bytes": coll}

    n_chips = r1["n_devices"]
    terms = RooflineTerms(
        arch=arch, cell=cell, mesh=r1["mesh"], flops=flops, hlo_bytes=byts,
        collective_bytes=coll,
        model_flops=_model_flops(arch, cell, n_chips))
    out["terms"] = {
        "compute_s": terms.compute_s, "memory_s": terms.memory_s,
        "collective_s": terms.collective_s, "bottleneck": terms.bottleneck,
        "model_flops": terms.model_flops,
        "useful_flops_frac": terms.useful_flops_frac, "mfu": terms.mfu,
        "step_time_s": terms.step_time_s,
    }
    if verbose:
        print(terms.row(), flush=True)
    return out


def run_packed_rerank(args) -> int:
    """``--kernel packed_rerank``: roofline rows for the fused
    compressed-domain rerank kernel vs the reconstruction baseline."""
    from repro.roofline.packed import packed_rerank_report
    shape = None
    if args.rerank_shape:
        keys = ("nq", "lq", "s", "ld", "dim", "k_centroids")
        vals = [int(v) for v in args.rerank_shape.split(",")]
        shape = dict(zip(keys, vals))
    bits = tuple(int(b) for b in args.bits.split(",") if b)
    report = packed_rerank_report(shape, bits_list=bits)
    print(HEADER, flush=True)
    for row in report["rows"]:
        print(row.pop("terms").row(), flush=True)
    for row in report["rows"]:
        if row["bits"] is not None:
            print(f"  bits={row['bits']}: "
                  f"{row['doc_bytes_per_token']} B/token vs "
                  f"{report['rows'][0]['doc_bytes_per_token']} B/token "
                  f"recon ({row['doc_bytes_ratio_vs_recon']:.1f}x), "
                  f"stream ratio {row['bytes_ratio_vs_recon']:.1f}x")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=1)
    return 0


def run_plaid_probe(args) -> int:
    """``--kernel plaid_probe``: roofline rows for the device-resident
    candidate pipeline vs the host-gather (PCIe hop) baseline."""
    from repro.roofline.probe import plaid_probe_report
    shape = None
    if args.probe_shape:
        keys = ("nq", "lq", "k_centroids", "nprobe", "lmax", "c", "ld",
                "dim")
        vals = [int(v) for v in args.probe_shape.split(",")]
        shape = dict(zip(keys, vals))
    report = plaid_probe_report(shape)
    print(HEADER, flush=True)
    for row in report["rows"]:
        print(row.pop("terms").row(), flush=True)
    host, dev = report["rows"]
    print(f"  host hop: {host['host_hop_bytes']} B "
          f"({host['host_hop_s'] * 1e6:.1f} us PCIe) per batch; "
          f"device fused total {dev['total_s'] * 1e6:.1f} us vs host "
          f"{host['total_s'] * 1e6:.1f} us "
          f"({dev['speedup_vs_host']:.2f}x)")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=1)
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--skip-multipod", action="store_true")
    ap.add_argument("--kernel", default=None,
                    choices=("packed_rerank", "plaid_probe"),
                    help="analyse a hand-written kernel instead of the "
                         "(arch x cell) dry-run grid")
    ap.add_argument("--bits", default="2,4",
                    help="packed_rerank: codec widths to price")
    ap.add_argument("--rerank-shape", default=None,
                    help="packed_rerank: nq,lq,s,ld,dim,k_centroids")
    ap.add_argument("--probe-shape", default=None,
                    help="plaid_probe: nq,lq,k_centroids,nprobe,lmax,"
                         "c,ld,dim")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    if args.kernel == "packed_rerank":
        return run_packed_rerank(args)
    if args.kernel == "plaid_probe":
        return run_plaid_probe(args)

    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    print(HEADER, flush=True)
    results, failures = [], []
    for arch in archs:
        for cell in ([args.cell] if args.cell else all_cells(arch)):
            try:
                results.append(analyse_cell(
                    arch, cell, skip_multipod=args.skip_multipod))
            except Exception as e:
                traceback.print_exc()
                failures.append({"arch": arch, "cell": cell,
                                 "error": repr(e)})
    print(f"\n{len(results)} cells analysed, {len(failures)} failed")
    for f in failures:
        print("FAILED:", f)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"results": results, "failures": failures}, fh,
                      indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
