"""Roofline terms for the device-resident PLAID candidate pipeline.

The fused probe kernel (kernels/plaid_probe) plus the device IVF gather
(core/ivf.DeviceInvertedLists) replace the host candidate generator:
stage 1's centroid scores stay on device, stage 2 becomes a fixed-shape
padded-list gather + sort-based dedupe, and stage 3 re-derives each
candidate token's centroid score with a one-hot MXU matmul instead of a
host-orchestrated vmap gather. What the host path paid in PCIe hops
(probe ids down, candidate ids back up) the device path pays in decode
flops — this module prices that trade with the same three-term model as
the other kernel cells:

    python -m repro.roofline.run --kernel plaid_probe --json out.json

FLOPs are analytic (the one-hot matmul inside the Pallas body never
shows up in XLA cost_analysis of the wrapper); sort cost is modeled as
the bitonic-network bound XLA lowers ``jnp.sort`` to on accelerator
backends.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

from repro.roofline.analysis import RooflineTerms

# representative serving cell: 8 queries x 32 tokens probing nprobe=8 of
# 2^12 centroids whose unique-doc lists pad to 256; candidates padded to
# 4096 docs of 64 pooled tokens at the paper's dim=128
DEFAULT_SHAPE = dict(nq=8, lq=32, k_centroids=4096, nprobe=8, lmax=256,
                     c=4096, ld=64, dim=128)

# effective per-direction host<->device bandwidth for the hop pricing
# (PCIe gen4 x16 less protocol overhead — the transfers are small, so
# latency-bound in practice; this is deliberately optimistic for host)
PCIE_GBPS = 20.0
# effective np.unique throughput on the (query, doc) key sweep — int64
# comparison sort with cache-missing gathers; measured on the serving
# host class, single core (the probe pool parallelizes across shards,
# not within one)
HOST_SORT_KEYS_PER_S = 5e7


def probe_flops(nq, lq, k_centroids, dim) -> float:
    """Stage 1: q [nq, lq, dim] @ centroids^T [dim, K]."""
    return 2.0 * nq * lq * k_centroids * dim


def gather_bytes(nq, lq, nprobe, lmax) -> int:
    """Stage 2 device gather: padded doc-list rows + validity."""
    return nq * lq * nprobe * lmax * (4 + 1)


def dedupe_flops(nq, lq, nprobe, lmax) -> float:
    """Two bitonic sorts over the W padded slots per query
    (~W log^2 W compare-exchange each)."""
    w = max(lq * nprobe * lmax, 2)
    lg = math.log2(w)
    return 2.0 * nq * w * lg * lg


def onehot_decode_flops(nq, c, ld, k_centroids, lq) -> float:
    """Stage 3 in-kernel: one-hot [C*L, K] @ csp^T [K, Lq] per query —
    the MXU-shaped substitute for the host vmap gather."""
    return 2.0 * nq * c * ld * k_centroids * lq


def reduce_flops(nq, c, ld, lq) -> float:
    """Masked max over doc tokens + sum over query tokens + top-k."""
    return 2.0 * nq * c * ld * lq


def device_stream_bytes(nq, lq, k_centroids, nprobe, lmax, c, ld,
                        dim) -> int:
    """HBM traffic of the fused pipeline: queries + centroid table in,
    gathered lists + candidate code rows streamed, slate out."""
    return (nq * lq * (dim * 4 + 1)            # queries + mask
            + k_centroids * dim * 4            # centroid table
            + gather_bytes(nq, lq, nprobe, lmax)
            + nq * c * ld * (4 + 1)            # candidate code rows + mask
            + nq * c * (4 + 1))                # slate ids + validity out


def host_hop_bytes(nq, lq, nprobe, c) -> int:
    """PCIe bytes the host path moves per batch: probe ids D2H, then the
    deduped candidate matrix H2D (int64 + bool, ``pad_candidate_sets``)."""
    return nq * lq * nprobe * 4 + nq * c * (8 + 1)


def plaid_probe_report(shape: Optional[Dict[str, int]] = None) -> Dict:
    """Roofline rows for the device pipeline vs the host-hop baseline."""
    sh = dict(DEFAULT_SHAPE)
    if shape:
        sh.update(shape)
    nq, lq, kc = sh["nq"], sh["lq"], sh["k_centroids"]
    nprobe, lmax, c, ld, dim = (sh["nprobe"], sh["lmax"], sh["c"],
                                sh["ld"], sh["dim"])

    rows: List[Dict] = []
    # host baseline: device matmuls (stage 1 + stage 3 vmap gather view)
    # plus the two PCIe hops and a host-side sort the device never pays
    h_fl = {
        "probe": probe_flops(nq, lq, kc, dim),
        "approx_gather": reduce_flops(nq, c, ld, lq),
        "reduce": reduce_flops(nq, c, ld, lq),
    }
    h_bytes = (nq * lq * (dim * 4 + 1) + kc * dim * 4
               + nq * c * ld * (4 + 1) + nq * c * (4 + 1))
    hop = host_hop_bytes(nq, lq, nprobe, c)
    h_terms = RooflineTerms(
        arch="plaid_probe_host", cell="host_gather", mesh="1chip",
        flops=sum(h_fl.values()), hlo_bytes=float(h_bytes),
        collective_bytes=0.0)
    hop_s = hop / (PCIE_GBPS * 1e9)
    # the host work the device path deletes: np.unique over every
    # (query, doc) key the walked lists produce, serialized with the
    # device (the gather can't start until the probe ids land on host)
    sort_s = (nq * lq * nprobe * lmax) / HOST_SORT_KEYS_PER_S
    host_side_s = hop_s + sort_s
    rows.append({
        "kernel": "plaid_probe_host", "flop_terms": h_fl,
        "flops": sum(h_fl.values()), "stream_bytes": h_bytes,
        "host_hop_bytes": hop, "host_hop_s": hop_s,
        "host_sort_s": sort_s,
        "compute_s": h_terms.compute_s, "memory_s": h_terms.memory_s,
        "total_s": max(h_terms.compute_s, h_terms.memory_s) + host_side_s,
        "bottleneck": "host" if host_side_s > max(h_terms.compute_s,
                                                  h_terms.memory_s)
        else h_terms.bottleneck,
        "terms": h_terms,
    })
    d_fl = {
        "probe": probe_flops(nq, lq, kc, dim),
        "dedupe_sort": dedupe_flops(nq, lq, nprobe, lmax),
        "onehot_decode": onehot_decode_flops(nq, c, ld, kc, lq),
        "reduce": reduce_flops(nq, c, ld, lq),
    }
    d_bytes = device_stream_bytes(nq, lq, kc, nprobe, lmax, c, ld, dim)
    d_terms = RooflineTerms(
        arch="plaid_probe_dev", cell="fused_kernel", mesh="1chip",
        flops=sum(d_fl.values()), hlo_bytes=float(d_bytes),
        collective_bytes=0.0)
    rows.append({
        "kernel": "plaid_probe_dev", "flop_terms": d_fl,
        "flops": sum(d_fl.values()), "stream_bytes": d_bytes,
        "host_hop_bytes": 0, "host_hop_s": 0.0,
        "compute_s": d_terms.compute_s, "memory_s": d_terms.memory_s,
        "total_s": max(d_terms.compute_s, d_terms.memory_s),
        "bottleneck": d_terms.bottleneck,
        "terms": d_terms,
    })
    rows[1]["speedup_vs_host"] = (rows[0]["total_s"]
                                  / max(rows[1]["total_s"], 1e-30))
    return {"shape": sh, "rows": rows}
