"""Independent flop counter: parse dot-general ops out of optimized HLO.

Cross-checks XLA's cost_analysis (which undercounts while-loop bodies —
they are counted ONCE regardless of trip count). In analysis mode all
scans are unrolled, so summing every dot in the module is exact for
matmul flops (elementwise flops are negligible for these workloads).

Handles: plain `dot(...)` ops and dots inside fusion computations (each
fusion is called once per op that references it — we count call sites).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE = r"(?:f64|f32|f16|bf16|f8e4m3|f8e5m2|s32|u32|s8|u8|pred)"
_SHAPE = rf"{_DTYPE}\[([0-9,]*)\]"

_DOT_RE = re.compile(
    rf"%?[\w.\-]+ = {_SHAPE}[^=]*? dot\(([^)]*)\)(.*)$")
_DIMS_RE = re.compile(
    r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(
    r"lhs_batch_dims=\{([0-9,]*)\}")
_OPERAND_SHAPE_RE = re.compile(rf"{_DTYPE}\[([0-9,]*)\]")


def _dims(s: str):
    return [int(x) for x in s.split(",") if x] if s else []


def dot_flops_in_hlo(hlo_text: str) -> Dict:
    """Sum 2*M*N*K flops over every dot in the module.

    Returns {"total": flops, "by_shape": {shape_sig: (count, flops)}}.
    """
    total = 0.0
    by_shape = defaultdict(lambda: [0, 0.0])
    for line in hlo_text.splitlines():
        s = line.strip()
        if " dot(" not in s:
            continue
        m = _DOT_RE.search(s)
        if not m:
            continue
        out_dims = _dims(m.group(1))
        rest = m.group(3)
        cm = _DIMS_RE.search(rest)
        contract = _dims(cm.group(1)) if cm else []
        # operand shapes appear in the operand list annotations; fall back
        # to: flops = 2 * prod(out_dims) * prod(contract sizes of lhs).
        ops = _OPERAND_SHAPE_RE.findall(m.group(2))
        k = 1
        if ops:
            lhs = _dims(ops[0])
            for c in contract:
                if c < len(lhs):
                    k *= lhs[c]
        n_out = 1
        for d in out_dims:
            n_out *= d
        fl = 2.0 * n_out * k
        total += fl
        sig = f"out[{','.join(map(str, out_dims))}]xk{k}"
        by_shape[sig][0] += 1
        by_shape[sig][1] += fl
    top = sorted(by_shape.items(), key=lambda kv: -kv[1][1])[:12]
    return {"total": total,
            "top": [{"shape": s, "count": c, "flops": f}
                    for s, (c, f) in top]}
