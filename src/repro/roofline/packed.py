"""Roofline terms for the compressed-domain rerank kernel.

The fused packed kernel (kernels/maxsim_packed) streams the PACKED doc
representation — a 4-byte centroid id, W uint32 residual words and a
1-byte mask per token — instead of the f32 reconstruction the legacy
rerank stage read (dim*4 + 1 bytes per token). Per-chip HBM traffic for
the doc operand drops by ~(dim*4) / (4 + 4*W); the decode work moves
on-chip as a one-hot gather matmul plus an in-register where-chain.

This module prices both paths with the same three-term model
``roofline/analysis.py`` applies to the dry-run cells, so the bytes
ratio and the bottleneck flip (memory -> compute) land in the familiar
report format:

    python -m repro.roofline.run --kernel packed_rerank --json out.json

FLOPs are analytic (the Pallas body's one-hot decode matmul never shows
up in XLA cost_analysis of the wrapper); when XLA cost_analysis of the
jitted jnp REFERENCE path is available it is recorded per row as a
cross-check (``xla_ref_flops``), never substituted.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.roofline.analysis import RooflineTerms

# representative serving slab: 8 queries x 1024 rerank candidates of 64
# pooled doc tokens at the paper's dim=128 / 2^12-centroid codec
DEFAULT_SHAPE = dict(nq=8, lq=32, s=1024, ld=64, dim=128, k_centroids=4096)


def words_per_token(dim: int, bits: int) -> int:
    """uint32 residual words per doc token (32/bits codes per word)."""
    lanes = 32 // bits
    return (dim + lanes - 1) // lanes


def packed_doc_bytes_per_token(dim: int, bits: int) -> int:
    """id (int32) + packed residual words + mask byte — the per-token
    HBM cost of the compressed-domain doc operand (plaid
    ``device_bytes_detail()['packed']`` uses the same formula)."""
    return 4 + 4 * words_per_token(dim, bits) + 1


def recon_doc_bytes_per_token(dim: int) -> int:
    """f32 vector + mask byte — what the reconstruction store streamed."""
    return dim * 4 + 1


def _common_bytes(nq, lq, s, ld, dim) -> int:
    """Operands both paths stream identically: queries + query mask in,
    score slab out."""
    return nq * lq * (dim * 4 + 1) + nq * s * 4


def packed_stream_bytes(nq, lq, s, ld, dim, k_centroids, bits) -> int:
    codec = k_centroids * dim * 4 + dim * (1 << bits) * 4
    return (nq * s * ld * packed_doc_bytes_per_token(dim, bits)
            + codec + _common_bytes(nq, lq, s, ld, dim))


def recon_stream_bytes(nq, lq, s, ld, dim) -> int:
    return (nq * s * ld * recon_doc_bytes_per_token(dim)
            + _common_bytes(nq, lq, s, ld, dim))


def packed_flops(nq, lq, s, ld, dim, k_centroids, bits) -> Dict[str, float]:
    """Analytic flop terms of the fused kernel body.

    decode   one-hot gather matmul [M, K] @ [K, dim], M = nq*s*ld
    unpack   where-chain over 2^bits value planes + shift/mask ops
    renorm   square, sum, rsqrt, scale over [M, dim]
    maxsim   the scoring matmul [lq, dim] @ [dim, M] per query
    reduce   masked max over doc tokens + sum over query tokens
    """
    m = nq * s * ld
    return {
        "decode": 2.0 * m * k_centroids * dim,
        "unpack": float((1 << bits) + 3) * m * dim,
        "renorm": 4.0 * m * dim,
        "maxsim": 2.0 * nq * lq * s * ld * dim,
        "reduce": 2.0 * nq * lq * s * ld,
    }


def recon_flops(nq, lq, s, ld, dim) -> Dict[str, float]:
    """The legacy path's query-time flops: decode happened at build time
    (that is exactly the trade — HBM bytes for on-chip decode work)."""
    return {
        "maxsim": 2.0 * nq * lq * s * ld * dim,
        "reduce": 2.0 * nq * lq * s * ld,
    }


def _xla_ref_flops(nq, lq, s, ld, dim, bits) -> Optional[float]:
    """cost_analysis of the jitted jnp reference path (cross-check only;
    returns None wherever the API or a backend detail gets in the way)."""
    try:
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.core.quantization import train_codec
        from repro.kernels.maxsim_packed.ref import maxsim_packed_rerank_ref

        rng = np.random.default_rng(0)
        vecs = rng.normal(size=(64, dim)).astype(np.float32)
        vecs /= np.linalg.norm(vecs, axis=-1, keepdims=True)
        cents = rng.normal(size=(16, dim)).astype(np.float32)
        codec = train_codec(jnp.asarray(vecs), jnp.asarray(cents),
                            bits=bits)
        w = words_per_token(dim, bits)
        args = (jnp.zeros((nq, lq, dim), jnp.float32),
                jnp.ones((nq, lq), bool),
                jnp.zeros((nq, s, ld, w), jnp.uint32),
                jnp.zeros((nq, s, ld), jnp.int32),
                jnp.ones((nq, s, ld), bool),
                codec.centroids, codec.values)
        lowered = jax.jit(maxsim_packed_rerank_ref,
                          static_argnames=("bits",)).lower(*args, bits=bits)
        cost = lowered.compile().cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        f = float(cost.get("flops", 0.0))
        return f if f > 0 else None
    except Exception:
        return None


def packed_rerank_report(shape: Optional[Dict[str, int]] = None,
                         bits_list=(2, 4),
                         cross_check: bool = True) -> Dict:
    """Roofline rows for the packed kernel at each codec width plus the
    reconstruction-path baseline it replaced."""
    sh = dict(DEFAULT_SHAPE)
    if shape:
        sh.update(shape)
    nq, lq, s, ld = sh["nq"], sh["lq"], sh["s"], sh["ld"]
    dim, kc = sh["dim"], sh["k_centroids"]

    rows: List[Dict] = []
    r_bytes = recon_stream_bytes(nq, lq, s, ld, dim)
    r_fl = recon_flops(nq, lq, s, ld, dim)
    recon_terms = RooflineTerms(
        arch="maxsim_recon", cell="f32_store", mesh="1chip",
        flops=sum(r_fl.values()), hlo_bytes=float(r_bytes),
        collective_bytes=0.0)
    rows.append({
        "kernel": "maxsim_recon", "bits": None,
        "doc_bytes_per_token": recon_doc_bytes_per_token(dim),
        "stream_bytes": r_bytes, "flop_terms": r_fl,
        "flops": sum(r_fl.values()),
        "compute_s": recon_terms.compute_s,
        "memory_s": recon_terms.memory_s,
        "bottleneck": recon_terms.bottleneck,
        "bytes_ratio_vs_recon": 1.0,
        "terms": recon_terms,
    })
    for bits in bits_list:
        b = packed_stream_bytes(nq, lq, s, ld, dim, kc, bits)
        fl = packed_flops(nq, lq, s, ld, dim, kc, bits)
        terms = RooflineTerms(
            arch="maxsim_packed", cell=f"bits={bits}", mesh="1chip",
            flops=sum(fl.values()), hlo_bytes=float(b),
            collective_bytes=0.0)
        row = {
            "kernel": "maxsim_packed", "bits": bits,
            "doc_bytes_per_token": packed_doc_bytes_per_token(dim, bits),
            "stream_bytes": b, "flop_terms": fl,
            "flops": sum(fl.values()),
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "bottleneck": terms.bottleneck,
            "bytes_ratio_vs_recon": r_bytes / b,
            "doc_bytes_ratio_vs_recon": (recon_doc_bytes_per_token(dim)
                                         / packed_doc_bytes_per_token(
                                             dim, bits)),
            "terms": terms,
        }
        if cross_check:
            # tiny shape: the cross-check pins op accounting, not scale
            row["xla_ref_flops_small"] = _xla_ref_flops(
                2, 4, 8, 6, dim, bits)
        rows.append(row)
    return {"shape": sh, "rows": rows}
