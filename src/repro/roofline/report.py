"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the runner JSON.

    PYTHONPATH=src python -m repro.roofline.report roofline_baseline.json
"""
from __future__ import annotations

import json
import sys

from repro.roofline import hw

GIB = 2 ** 30


def _mem_line(r: dict) -> str:
    args = r.get("argument_size_in_bytes", 0) / GIB
    temp = r.get("temp_size_in_bytes", 0) / GIB
    out = r.get("output_size_in_bytes", 0) / GIB
    tot = args + temp
    fits = "yes" if tot <= hw.HBM_BYTES / GIB else "**NO**"
    return f"{args:7.2f} | {temp:7.2f} | {out:7.2f} | {fits}"


def dryrun_table(results: list) -> str:
    rows = ["| arch | cell | mesh | compile_s | args GiB | temp GiB | "
            "out GiB | fits 16GiB |",
            "|---|---|---|---|---|---|---|---|"]
    for r in results:
        for key in ("single_pod", "multi_pod"):
            if key not in r:
                continue
            d = r[key]
            rows.append(
                f"| {r['arch']} | {r['cell']} | {d['mesh']} | "
                f"{d['compile_s']:.0f} | {_mem_line(d)} |")
    return "\n".join(rows)


def roofline_table(results: list) -> str:
    rows = ["| arch | cell | compute_s | memory_s | collective_s | "
            "bottleneck | useful/HLO | MFU@roof |",
            "|---|---|---|---|---|---|---|---|"]
    for r in results:
        t = r["terms"]
        rows.append(
            f"| {r['arch']} | {r['cell']} | {t['compute_s']:.2e} | "
            f"{t['memory_s']:.2e} | {t['collective_s']:.2e} | "
            f"**{t['bottleneck']}** | {t['useful_flops_frac']:.1%} | "
            f"{t['mfu']:.1%} |")
    return "\n".join(rows)


def collective_summary(results: list) -> str:
    rows = ["| arch | cell | all-reduce | all-gather | reduce-scatter | "
            "all-to-all | permute |", "|---|---|---|---|---|---|---|"]
    for r in results:
        c = r.get("single_pod", {}).get("collectives", {})

        def fmt(op):
            e = c.get(op)
            return f"{e['bytes']/2**20:.0f}M x{e['count']}" if e else "-"
        rows.append(
            f"| {r['arch']} | {r['cell']} | {fmt('all-reduce')} | "
            f"{fmt('all-gather')} | {fmt('reduce-scatter')} | "
            f"{fmt('all-to-all')} | {fmt('collective-permute')} |")
    return "\n".join(rows)


def main(argv=None):
    path = (argv or sys.argv[1:])[0]
    with open(path) as f:
        data = json.load(f)
    results = data["results"]
    print("## Dry-run matrix\n")
    print(dryrun_table(results))
    print("\n## Roofline terms (single-pod, 256 chips)\n")
    print(roofline_table(results))
    print("\n## Collective traffic per step (single-pod)\n")
    print(collective_summary(results))
    if data.get("failures"):
        print("\n## Failures\n")
        for f_ in data["failures"]:
            print("-", f_)
    return 0


if __name__ == "__main__":
    sys.exit(main())
