"""Three-term roofline from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

``cost_analysis()`` supplies HLO_FLOPs and HLO_bytes. Collective bytes are
NOT in cost_analysis — we parse the optimized HLO text and sum the operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.

Notes on semantics:
  * cost_analysis flops/bytes are PER-PROGRAM (one SPMD replica executes
    the partitioned program), so terms divide by chips only when the HLO
    is the unpartitioned module; XLA's SPMD pipeline reports the
    *partitioned* program — i.e. already per-chip. We therefore treat
    flops/bytes as per-chip and do NOT divide again (validated in tests
    against hand-counted matmuls).
  * collective bytes summed from the partitioned HLO are per-chip traffic.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.roofline import hw

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all typed shapes in an HLO result-type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict:
    """Sum result sizes of every collective op in (optimized) HLO text.

    Returns {"total": bytes, "by_op": {op: {"count": n, "bytes": b}}}.
    Fusion-internal lines are skipped (collectives are never fused).
    """
    by_op = {op: {"count": 0, "bytes": 0} for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        # HLO: "%name = TYPE[shape] all-reduce(...)" or "... all-reduce-start"
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(?:-start|-done)?\(", s)
        if not m:
            continue
        if m.group(0).find("-done(") >= 0:
            continue                      # count start, not done
        shape_str, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        by_op[op]["count"] += 1
        by_op[op]["bytes"] += b
    total = sum(v["bytes"] for v in by_op.values())
    return {"total": total,
            "by_op": {k: v for k, v in by_op.items() if v["count"]}}


@dataclass
class RooflineTerms:
    arch: str
    cell: str
    mesh: str
    flops: float                  # per-chip HLO flops
    hlo_bytes: float              # per-chip HBM traffic
    collective_bytes: float       # per-chip collective traffic
    model_flops: float = 0.0      # 6*N*D useful flops (whole step, per chip)

    @property
    def compute_s(self) -> float:
        return self.flops / hw.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / hw.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / hw.ICI_LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline-optimistic step time: max of the three terms (perfect
        overlap of compute, HBM and ICI)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline-optimistic step time."""
        if self.step_time_s == 0:
            return 0.0
        return (self.model_flops / hw.PEAK_FLOPS_BF16) / self.step_time_s

    def row(self) -> str:
        return (f"{self.arch:22s} {self.cell:14s} {self.mesh:9s} "
                f"{self.compute_s:9.4f} {self.memory_s:9.4f} "
                f"{self.collective_s:9.4f} {self.bottleneck:10s} "
                f"{self.useful_flops_frac:6.1%} {self.mfu:6.1%}")


HEADER = (f"{'arch':22s} {'cell':14s} {'mesh':9s} {'compute_s':>9s} "
          f"{'memory_s':>9s} {'collect_s':>9s} {'bottleneck':10s} "
          f"{'useful':>6s} {'mfu':>6s}")


def model_flops_lm(cfg, cell_kind: str, n_tokens: int, n_chips: int,
                   seq_len: int = 0, batch: int = 0) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (fwd-only), N = active params;
    plus exact attention term 12*L*H*dh*S per token (causal halves it).
    Returned PER CHIP."""
    n_active = cfg.active_param_count()
    per_tok = (6 if cell_kind == "train" else 2) * n_active
    attn = 0
    if seq_len:
        mult = 6 if cell_kind == "train" else 2
        # qk^T + av: 2 matmuls of S x dh per head per token, causal ~ S/2
        eff_s = seq_len / 2 if cfg.causal else seq_len
        attn = mult * 2 * cfg.n_layers * cfg.n_heads * cfg.d_head * eff_s
    return (per_tok + attn) * n_tokens / n_chips


def model_flops_decode(cfg, batch: int, seq_len: int, n_chips: int) -> float:
    """One decode step: 2*N_active per token + cache attention reads."""
    n_active = cfg.active_param_count()
    attn = 2 * 2 * cfg.n_layers * cfg.n_heads * cfg.d_head * seq_len
    return (2 * n_active + attn) * batch / n_chips


def from_dryrun(result: Dict, model_flops: float = 0.0) -> RooflineTerms:
    return RooflineTerms(
        arch=result["arch"], cell=result["cell"], mesh=result["mesh"],
        flops=result["flops"], hlo_bytes=result["bytes_accessed"],
        collective_bytes=result["collective_bytes"],
        model_flops=model_flops)
