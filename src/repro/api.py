"""``repro.Retriever`` — the spec-driven facade over the whole pipeline.

The paper's pitch is "a simple drop-in during indexation with any
ColBERT-like model". This module makes the drop-in ONE object driven by
ONE typed spec (core/spec.py): build -> persist -> serve without
touching the five layers underneath::

    import repro

    spec = repro.RetrieverSpec(
        pooling=repro.PoolingSpec(method="ward", factor=2),
        index=repro.IndexSpec.from_config(cfg, backend="plaid"))
    r = repro.Retriever.build(params, cfg, doc_tokens, spec,
                              out_dir="idx")       # encode+pool+index+save
    scores, ids = r.search(query_tokens, k=10)

    r2 = repro.Retriever.load(params, cfg, "idx")  # fresh process, mmap
    assert r2.spec.index == spec.index             # spec round-trips
    with r2.serve() as engine:                     # concurrent runtime
        fut = engine.submit(query_tokens[0])

Every backend in the registry — flat, hnsw, plaid, AND the
beyond-paper cascade — builds through the same entry point and serves
through the same batched engine; results are bitwise equal to the
pre-facade ``Indexer``/``Searcher``/``ServingEngine`` call paths
(tests/test_api.py pins all of it), which remain available underneath.

A new backend is a ``register_backend(name, kind, keys, builder)`` call:
the builder receives ``(params, cfg, docs, spec, out_dir)`` and returns
``(index, IndexStats)``; persistence dispatch rides the manifest
``kind`` it writes.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Iterable, List, Optional, Tuple, Union

import numpy as np

from repro.core.spec import (CASCADE_PARAM_KEYS, INDEX_PARAM_KEYS,
                             IndexSpec, PoolingSpec, RetrieverSpec,
                             ServeSpec, backend_info, register_backend,
                             retriever_spec_from_manifest)
from repro.retrieval.indexer import Indexer, IndexStats
from repro.retrieval.searcher import Searcher


def _as_token_array(docs):
    """Monolithic builds take one [N, L] token array; accept an
    iterator of batches too (the streaming input shape) and pass an
    :class:`EncodedDocs` cache (corpus encoded once, pooled many ways —
    the quality sweep's input) straight through."""
    from repro.retrieval.indexer import EncodedDocs
    if isinstance(docs, (np.ndarray, EncodedDocs)):
        return docs
    return np.concatenate([np.asarray(b) for b in docs])


def _write_stats(out_dir: str, stats: IndexStats) -> None:
    with open(os.path.join(out_dir, "stats.json"), "w") as fh:
        json.dump(stats.to_json(), fh, indent=2)


def _spec_extra_meta(spec: RetrieverSpec) -> dict:
    """The spec-carrying manifest entries for a save through the facade
    — derived from the SAME helpers ``manifest_meta_for`` uses, so the
    round-trip contract has one definition (core/spec.py)."""
    extra = {"pool": spec.pooling.manifest_meta()}
    if spec.index.backend == "cascade":
        # generic knobs don't drive the cascade build, but the full
        # spec must round-trip through the manifest
        extra["params"] = spec.index.generic_params()
    return extra


# ---------------------------------------------------------------------------
# Registry builders
# ---------------------------------------------------------------------------
def _build_multi_vector(params, cfg, docs, spec: RetrieverSpec,
                        out_dir: Optional[str]):
    """flat | hnsw | plaid, monolithic or streaming-sharded."""
    indexer = Indexer(params, cfg, index_spec=spec.index,
                      pooling_spec=spec.pooling)
    if spec.shard.sharded:
        return indexer.build_streaming(
            docs, shard_max_vectors=int(spec.shard.shard_max_vectors),
            out_dir=out_dir,
            probe_threads=int(spec.shard.probe_threads))
    return indexer.build(_as_token_array(docs), out_dir=out_dir)


def _build_cascade(params, cfg, docs, spec: RetrieverSpec,
                   out_dir: Optional[str]):
    """Encode once, pool twice (coarse + fine), store both levels."""
    from repro.core import persist
    from repro.retrieval.cascade import CascadeIndex

    docs = _as_token_array(docs)
    ix = spec.index
    flat = IndexSpec.from_config(cfg, backend="flat",
                                 doc_maxlen=ix.doc_maxlen)

    def pool(factor: int):
        return Indexer(params, cfg, index_spec=flat,
                       pooling_spec=spec.pooling.replace(
                           factor=max(int(factor), 1)))

    coarse_ix = pool(ix.coarse_factor)
    index = CascadeIndex(dim=cfg.proj_dim, coarse_factor=ix.coarse_factor,
                         fine_factor=ix.fine_factor,
                         candidates=ix.candidates,
                         doc_maxlen=ix.doc_maxlen)
    coarse_docs, raw = coarse_ix.encode_and_pool_counted(docs)
    index.add(coarse_docs, pool(ix.fine_factor).encode_and_pool(docs))
    if out_dir is not None:
        manifest = index.save(out_dir, extra_meta=_spec_extra_meta(spec))
        index_bytes = persist.artifact_bytes(manifest)
    else:
        index_bytes = persist.serialized_nbytes(index)
    stats = IndexStats(n_docs=index.n_docs, n_vectors_raw=raw,
                       n_vectors_stored=index.n_vectors(),
                       index_bytes=index_bytes)
    if out_dir is not None:
        _write_stats(out_dir, stats)
    return index, stats


# (Re)register the stock backends WITH their facade builders — spec.py
# registered the names/kinds/keys import-free; this module owns the
# build recipes.
for _b in ("flat", "hnsw", "plaid"):
    register_backend(_b, "multi_vector_index", INDEX_PARAM_KEYS,
                     builder=_build_multi_vector, overwrite=True)
register_backend("cascade", "cascade_index", CASCADE_PARAM_KEYS,
                 builder=_build_cascade, overwrite=True)


# ---------------------------------------------------------------------------
# The facade
# ---------------------------------------------------------------------------
class Retriever:
    """One object from corpus to serving: the stable public API.

    Construction:
      * :meth:`build`  — encode + pool + index (+ save) from a typed
        :class:`~repro.core.spec.RetrieverSpec`;
      * :meth:`load`   — mmap an artifact directory; the build-time
        spec is reconstructed from the manifest.

    Query side: :meth:`search` / :meth:`search_batch` /
    :meth:`rankings` (bitwise equal to the underlying
    ``Searcher``/``MultiVectorIndex`` paths), :meth:`serve` for the
    concurrent runtime, :attr:`stats` for footprint numbers, and
    :meth:`add` / :meth:`delete` for CRUD.
    """

    def __init__(self, params, cfg, index, spec=None,
                 stats: Optional[IndexStats] = None,
                 encode_batch: int = 64):
        self.params = params
        self.cfg = cfg
        self.spec = RetrieverSpec.coerce(spec, cfg)
        self.encode_batch = int(encode_batch)
        self.searcher = Searcher(params, cfg, index,
                                 encode_batch=encode_batch)
        self._stats = stats

    # ------------------------------------------------------------ lifecycle
    @classmethod
    def build(cls, params, cfg, docs, spec=None,
              out_dir: Optional[str] = None,
              encode_batch: int = 64) -> "Retriever":
        """Encode ``docs`` (one [N, L] token array, or an iterator of
        token batches when ``spec.shard`` streams), pool them per
        ``spec.pooling``, build ``spec.index.backend``'s index, and —
        with ``out_dir`` — publish the artifact + ``stats.json``.

        ``spec`` may be a full :class:`RetrieverSpec`, a bare
        :class:`IndexSpec`/:class:`PoolingSpec`/:class:`ShardSpec`
        (the rest defaults from ``cfg``), or None (all from ``cfg``).
        """
        spec = RetrieverSpec.coerce(spec, cfg)
        info = backend_info(spec.index.backend)
        if info.builder is None:
            raise ValueError(f"backend {spec.index.backend!r} has no "
                             f"registered builder")
        index, stats = info.builder(params, cfg, docs, spec, out_dir)
        return cls(params, cfg, index, spec, stats=stats,
                   encode_batch=encode_batch)

    @classmethod
    def load(cls, params, cfg, path: str, mmap: bool = True,
             serve: Optional[ServeSpec] = None,
             encode_batch: int = 64) -> "Retriever":
        """Serve a persisted artifact directory (any kind — monolithic,
        sharded, cascade): no corpus encode, no index build, payloads
        stay on disk until first search. The spec the index was built
        with comes back off the manifest (``r.spec``); serving knobs
        are runtime-only, so pass ``serve`` to override the default."""
        from repro.core import persist
        manifest = persist.read_manifest(path)
        try:
            spec = retriever_spec_from_manifest(manifest, serve=serve)
        except ValueError as e:
            raise persist.IndexFormatError(str(e))
        index = persist.load_artifact(path, mmap=mmap)
        stats = cls._load_stats(path)
        return cls(params, cfg, index, spec, stats=stats,
                   encode_batch=encode_batch)

    @staticmethod
    def _load_stats(path: str) -> Optional[IndexStats]:
        sp = os.path.join(path, "stats.json")
        if not os.path.isfile(sp):
            return None
        try:
            with open(sp) as fh:
                d = json.load(fh)
        except (OSError, json.JSONDecodeError):
            return None
        known = {f.name for f in dataclasses.fields(IndexStats)}
        return IndexStats(**{k: v for k, v in d.items() if k in known})

    def save(self, out_dir: str) -> dict:
        """Publish the current index as an artifact (re-saves bump the
        manifest generation, so a serving engine watching ``out_dir``
        hot-swaps it in). Returns the manifest."""
        manifest = self.index.save(out_dir,
                                   extra_meta=_spec_extra_meta(self.spec))
        if self._stats is not None:
            _write_stats(out_dir, self._stats)
        return manifest

    # ---------------------------------------------------------------- query
    @property
    def index(self):
        return self.searcher.index

    def search(self, query_tokens: np.ndarray, k: int = 10
               ) -> Tuple[np.ndarray, np.ndarray]:
        """[Nq, L] raw token ids -> (scores [Nq, k], doc ids [Nq, k])."""
        return self.searcher.search(query_tokens, k=k)

    # a Retriever search is always batched (same alias the Searcher has)
    search_batch = search

    def rankings(self, query_tokens: np.ndarray, k: int = 10
                 ) -> List[List[int]]:
        return self.searcher.rankings(query_tokens, k=k)

    def evaluate(self, dataset, metrics=("ndcg@10",), k: int = 10):
        """Score this retriever against an evaluation dataset.

        ``dataset`` is a :class:`repro.eval.datasets.EvalDataset`
        (synthetic or BEIR-loaded); ``metrics`` are ``"<name>@<k>"``
        strings (``ndcg``/``recall``/``success``/``mrr``). Runs ONE
        batched search at depth ``max(k, metric ks)`` and feeds the
        ``[Nq, k]`` ranked-id matrix straight into the batched device
        metrics (``repro.eval.metrics``). Returns ``{name: value}``.
        """
        from repro.eval.metrics import compute_metrics, max_k
        depth = max(int(k), max_k(metrics))
        _, ids = self.search(dataset.query_tokens, k=depth)
        return compute_metrics(ids, dataset.qrels, metrics)

    def warmup(self, batch_sizes: Union[int, Iterable[int]],
               k: int = 10) -> None:
        self.searcher.warmup(batch_sizes, k=k)

    def serve(self, spec: Optional[ServeSpec] = None,
              index_dir: Optional[str] = None,
              index_generation: Optional[int] = None):
        """The concurrent serving runtime (launch/engine.py) over this
        retriever, configured by ``spec`` (default: the build spec's
        ``serve`` block). Use as a context manager; pass ``index_dir``
        to watch an artifact directory for hot swaps."""
        from repro.launch.engine import ServingEngine
        return ServingEngine.from_spec(
            self.searcher, spec or self.spec.serve, index_dir=index_dir,
            index_generation=index_generation)

    # ----------------------------------------------------------------- CRUD
    def _encode_pool(self, doc_tokens: np.ndarray,
                     factor: int) -> List[np.ndarray]:
        ix = self.spec.index
        enc_spec = (ix if ix.backend != "cascade"
                    else IndexSpec.from_config(self.cfg, backend="flat",
                                               doc_maxlen=ix.doc_maxlen))
        return Indexer(self.params, self.cfg, index_spec=enc_spec,
                       pooling_spec=self.spec.pooling.replace(
                           factor=max(int(factor), 1)),
                       encode_batch=self.encode_batch
                       ).encode_and_pool(doc_tokens)

    def add(self, doc_tokens: np.ndarray) -> np.ndarray:
        """Encode + pool + append new documents; returns their doc ids
        (cascade pools each new doc at both levels)."""
        toks = _as_token_array(doc_tokens)
        ix = self.spec.index
        self._stats = None              # CRUD invalidates cached stats
        if ix.backend == "cascade":
            return self.index.add(
                self._encode_pool(toks, ix.coarse_factor),
                self._encode_pool(toks, ix.fine_factor))
        return self.index.add(
            self._encode_pool(toks, self.spec.pooling.factor))

    def delete(self, doc_ids) -> None:
        fn = getattr(self.index, "delete", None)
        if fn is None:
            raise NotImplementedError(
                f"{type(self.index).__name__} does not support delete")
        self._stats = None              # CRUD invalidates cached stats
        fn(doc_ids)

    # ----------------------------------------------------------------- stats
    @property
    def stats(self) -> IndexStats:
        """Build-time stats when available (also loaded back off the
        artifact's ``stats.json``); otherwise synthesized from the live
        index (raw count unknown after a bare load -> 0)."""
        if self._stats is None:
            from repro.core import persist
            index = self.index
            if hasattr(index, "shards"):
                nbytes = sum(persist.serialized_nbytes(s)
                             for s in index.shards)
            else:
                nbytes = persist.serialized_nbytes(index)
            self._stats = IndexStats(
                n_docs=int(index.n_docs), n_vectors_raw=0,
                n_vectors_stored=int(index.n_vectors()),
                index_bytes=int(nbytes),
                n_shards=int(getattr(index, "n_shards", 1)))
        return self._stats
