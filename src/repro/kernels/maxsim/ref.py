"""Pure-jnp oracle for the blocked MaxSim kernel."""
from __future__ import annotations

import jax.numpy as jnp


def maxsim_ref(q, q_mask, d, d_mask):
    """q: [Nq, Lq, dim]; d: [Nd, Ld, dim]; masks True=valid.

    Returns scores [Nq, Nd] f32: sum over valid query tokens of the max
    similarity over valid doc tokens.
    """
    qf = q.astype(jnp.float32)
    df = d.astype(jnp.float32)
    sim = jnp.einsum("qld,nkd->qnlk", qf, df)
    sim = jnp.where(d_mask[None, :, None, :], sim, -jnp.inf)
    best = jnp.max(sim, axis=-1)
    best = jnp.where(q_mask[:, None, :] & jnp.isfinite(best), best, 0.0)
    return jnp.sum(best, axis=-1)


def maxsim_rerank_ref(q, q_mask, d, d_mask):
    """Per-query candidate rerank: each query scores only its own docs.

    q: [Nq, Lq, dim]; d: [Nq, S, Ld, dim]; masks True=valid.
    Returns scores [Nq, S] f32.
    """
    qf = q.astype(jnp.float32)
    df = d.astype(jnp.float32)
    sim = jnp.einsum("qld,qskd->qslk", qf, df)
    sim = jnp.where(d_mask[:, :, None, :], sim, -jnp.inf)
    best = jnp.max(sim, axis=-1)                       # [Nq, S, Lq]
    best = jnp.where(q_mask[:, None, :] & jnp.isfinite(best), best, 0.0)
    return jnp.sum(best, axis=-1)                      # [Nq, S]
