"""jit'd public wrapper for the MaxSim kernel: pads to block multiples,
dispatches to the Pallas kernel (interpret=True off-TPU), unpads."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.maxsim.kernel import maxsim_pallas, maxsim_rerank_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, axis, mult, value=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("block_q", "block_d"))
def maxsim(q, q_mask, d, d_mask, *, block_q: int = 8, block_d: int = 8):
    """Late-interaction scores [Nq, Nd] via the Pallas kernel."""
    Nq, Nd = q.shape[0], d.shape[0]
    q = _pad_to(q, 0, block_q)
    q_mask = _pad_to(q_mask, 0, block_q)
    d = _pad_to(d, 0, block_d)
    d_mask = _pad_to(d_mask, 0, block_d)
    out = maxsim_pallas(q, q_mask, d, d_mask, block_q=block_q,
                        block_d=block_d, interpret=not _on_tpu())
    return out[:Nq, :Nd]


@functools.partial(jax.jit, static_argnames=("block_s",))
def maxsim_rerank(q, q_mask, d, d_mask, *, block_s: int = 8):
    """Per-query candidate scores [Nq, S]: d is a per-query gather
    [Nq, S, Ld, dim] and query i only scores slab d[i]."""
    S = d.shape[1]
    d = _pad_to(d, 1, block_s)
    d_mask = _pad_to(d_mask, 1, block_s)
    out = maxsim_rerank_pallas(q, q_mask, d, d_mask, block_s=block_s,
                               interpret=not _on_tpu())
    return out[:, :S]
