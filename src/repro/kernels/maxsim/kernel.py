"""Blocked MaxSim Pallas TPU kernel.

Tiling: grid over (query blocks, doc blocks). Each program holds
``block_q`` queries x ``block_d`` docs in VMEM, flattens tokens into one
MXU matmul [BQ*Lq, dim] x [dim, BD*Ld], applies the doc-token validity
mask, and reduces max-over-doc-tokens / sum-over-query-tokens in VREGs.

VMEM budget per program (f32):
  q tile  BQ*Lq*dim            e.g. 8*32*128*4   = 128 KiB
  d tile  BD*Ld*dim            e.g. 8*256*128*4  =   1 MiB
  sim     BQ*Lq*BD*Ld          e.g. 256*2048*4   =   2 MiB
well under the ~16 MiB/core VMEM of TPU v5e. Token dims are padded to
multiples of 128 lanes by the wrapper (ops.py), so MXU tiles are aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _maxsim_kernel(q_ref, qm_ref, d_ref, dm_ref, o_ref):
    BQ, Lq, dim = q_ref.shape
    BD, Ld, _ = d_ref.shape
    q = q_ref[...].astype(jnp.float32).reshape(BQ * Lq, dim)
    d = d_ref[...].astype(jnp.float32).reshape(BD * Ld, dim)
    sim = jax.lax.dot_general(q, d, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    sim = sim.reshape(BQ, Lq, BD, Ld)
    dm = dm_ref[...].reshape(1, 1, BD, Ld)
    sim = jnp.where(dm, sim, -jnp.inf)
    best = jnp.max(sim, axis=-1)                     # [BQ, Lq, BD]
    qm = qm_ref[...].reshape(BQ, Lq, 1)
    best = jnp.where(qm & jnp.isfinite(best), best, 0.0)
    o_ref[...] = jnp.sum(best, axis=1)               # [BQ, BD]


@functools.partial(jax.jit,
                   static_argnames=("block_q", "block_d", "interpret"))
def maxsim_pallas(q, q_mask, d, d_mask, *, block_q: int = 8,
                  block_d: int = 8, interpret: bool = False):
    """q: [Nq, Lq, dim]; d: [Nd, Ld, dim] -> scores [Nq, Nd] f32.

    Nq % block_q == 0 and Nd % block_d == 0 (wrapper pads).
    """
    Nq, Lq, dim = q.shape
    Nd, Ld, _ = d.shape
    assert Nq % block_q == 0 and Nd % block_d == 0, (Nq, Nd)
    grid = (Nq // block_q, Nd // block_d)
    return pl.pallas_call(
        _maxsim_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, Lq, dim), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_q, Lq), lambda i, j: (i, 0)),
            pl.BlockSpec((block_d, Ld, dim), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((block_d, Ld), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Nq, Nd), jnp.float32),
        interpret=interpret,
    )(q, q_mask, d, d_mask)


def _maxsim_rerank_kernel(q_ref, qm_ref, d_ref, dm_ref, o_ref):
    """One query block x one slab of that query's own candidates."""
    _, Lq, dim = q_ref.shape
    _, BS, Ld, _ = d_ref.shape
    q = q_ref[0].astype(jnp.float32)                 # [Lq, dim]
    d = d_ref[0].astype(jnp.float32).reshape(BS * Ld, dim)
    sim = jax.lax.dot_general(q, d, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    sim = sim.reshape(Lq, BS, Ld)
    dm = dm_ref[0].reshape(1, BS, Ld)
    sim = jnp.where(dm, sim, -jnp.inf)
    best = jnp.max(sim, axis=-1)                     # [Lq, BS]
    qm = qm_ref[0].reshape(Lq, 1)
    best = jnp.where(qm & jnp.isfinite(best), best, 0.0)
    o_ref[0] = jnp.sum(best, axis=0)                 # [BS]


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def maxsim_rerank_pallas(q, q_mask, d, d_mask, *, block_s: int = 8,
                         interpret: bool = False):
    """Gathered-candidate rerank: q [Nq, Lq, dim]; d [Nq, S, Ld, dim]
    -> scores [Nq, S] f32. S % block_s == 0 (wrapper pads).

    Grid runs (query, candidate-slab); each program re-uses the one
    query tile against a ``block_s``-doc slab of its candidate gather,
    the same flatten-matmul/VREG-reduce scheme as ``_maxsim_kernel``.
    """
    Nq, Lq, dim = q.shape
    _, S, Ld, _ = d.shape
    assert S % block_s == 0, (S, block_s)
    grid = (Nq, S // block_s)
    return pl.pallas_call(
        _maxsim_rerank_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Lq, dim), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, Lq), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_s, Ld, dim), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, block_s, Ld), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_s), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Nq, S), jnp.float32),
        interpret=interpret,
    )(q, q_mask, d, d_mask)
