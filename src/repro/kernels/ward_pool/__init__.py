"""Pallas Ward-pooling kernel (indexing fast path).

``ward_assign`` is the public entry: same contract as
``repro.core.ward.ward_cluster_batch`` (which stays as the bitwise
reference, see ``ref.py``) with an ``impl`` toggle that
``PoolingSpec.ward_kernel`` threads through the build pipeline.
"""
from repro.kernels.ward_pool.ops import ward_assign  # noqa: F401
from repro.kernels.ward_pool.ref import ward_assign_ref  # noqa: F401
