"""Batched agglomerative-Ward Pallas kernel: the indexing fast path.

One program clusters a block of ``block_b`` documents with the whole
merge loop fused in-register: the per-doc ``[N, N]`` squared-distance
matrix lives in VMEM for the lifetime of the program (N = doc_maxlen,
so ~``block_b * N^2 * 4`` bytes — 8 x 256^2 x 4 = 2 MiB at the
production shape, comfortably under the ~16 MiB/core of TPU v5e), and
every Lance-Williams row update is a masked elementwise pass over rows
already resident — no HBM round-trip per merge step.

Why this is fast where ``core/ward.py`` is not: the reference spends
each of its N-1 steps on a full ``[N, N]`` reshape-argmin (O(N^2) reads
per merge, O(N^3) per doc). This kernel replaces the global argmin with
ANDERBERG-STYLE LAZY ROW MINIMA: ``lb[b, i]`` caches a lower bound on
row i's minimum, and because Ward's linkage is REDUCIBLE (merging A,B
never decreases d2(AB, C) below min(d2(A,C), d2(B,C)) for the winning
pair), stale cached minima are always valid lower bounds. Selecting the
next merge is argmin over the N-vector ``lb`` plus a short
verify-by-rescan loop (recompute one row's true min until the chosen
row's bound is tight) — amortized O(N) per step instead of O(N^2),
with the fp-safety net ``lb = min(lb, new_row)`` after every update so
a bound can never sit above the true row minimum.

Bitwise parity with the reference is load-bearing (index artifacts must
not depend on which path built them), so the tie-breaking is reproduced
exactly: the reference takes ``argmin(d2.reshape(-1))`` = the first
row-major occurrence of the global minimum. Here that is (first row
whose verified min equals the global min — argmin over ``lb`` returns
the first — then first column at that min via the
min-over-masked-iota trick in ``_row_min_first_arg``). Merges the
reference would skip (k reached, or only +inf distances left) are
folded through ``do`` by writing the ORIGINAL row values back, so the
scatters need no full-matrix ``where(do, ...)`` copy and no-op steps
are bitwise no-ops.

Everything is plain vector/matrix jnp inside the kernel body, so
``interpret=True`` (the CPU path ``ops.py`` selects off-TPU) lowers to
the same fused XLA loop and keeps the ~7x win over the reference on
CPU as well.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Python float, NOT jnp.float32(inf): a module-level device array would
# be captured as a kernel constant, which pallas_call rejects.
_INF = float("inf")


def _row_min_first_arg(rows, N: int):
    """Min + FIRST-occurrence argmin over the last axis of [bb, N] rows
    (matches the reference's row-major flat-argmin tie-break)."""
    m = jnp.min(rows, axis=-1, keepdims=True)
    iota = jax.lax.broadcasted_iota(jnp.int32, rows.shape, 1)
    a = jnp.min(jnp.where(rows == m, iota, N), axis=-1)
    return m[:, 0], a.astype(jnp.int32)


def ward_merge_block(x, mask, k_target, n_steps):
    """Cluster a [bb, N, d] block: assign [bb, N] int32 (representative
    token index per cluster), bitwise == ``ward_cluster_batch``.

    ``k_target`` is per-doc [bb]; ``n_steps`` is a scalar trip count
    (max over the block of ``n_valid - k``). Steps past a doc's own
    merge budget are ``do``-folded no-ops, so a block-level trip count
    is exact, not approximate.
    """
    bb, N, d = x.shape
    barange = jnp.arange(bb)
    sq = jnp.sum(x * x, axis=-1)
    d2 = sq[:, :, None] + sq[:, None, :] - 2.0 * jnp.einsum(
        "bnd,bmd->bnm", x, x)
    d2 = jnp.maximum(d2, 0.0)
    valid = mask[:, :, None] & mask[:, None, :]
    eye = jnp.eye(N, dtype=bool)[None]
    d2 = jnp.where(valid & ~eye, d2, _INF)
    sizes = jnp.where(mask, 1, 0).astype(jnp.float32)
    assign = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[None],
                              (bb, N))
    n_active = jnp.sum(mask.astype(jnp.int32), axis=-1)
    lb = jnp.min(d2, axis=-1)            # true row minima at init
    lane = jax.lax.broadcasted_iota(jnp.int32, (bb, N), 1)

    def select(d2, lb):
        """Verified global min (i, j, dij, lb') with the reference's
        tie-breaks: argmin(lb) is the candidate row; rescan its true
        row min; accept only when bound == truth (reducibility
        guarantees termination — each rescan tightens one bound)."""

        def cond(state):
            _, _, _, _, ok = state
            return ~jnp.all(ok)

        def body(state):
            lb, _, _, _, _ = state
            i = jnp.argmin(lb, axis=-1).astype(jnp.int32)
            row = jnp.take_along_axis(d2, i[:, None, None], axis=1)[:, 0]
            rm, ja = _row_min_first_arg(row, N)
            cur = jnp.take_along_axis(lb, i[:, None], axis=1)[:, 0]
            ok = (rm == cur) | (jnp.isinf(rm) & jnp.isinf(cur))
            lb = lb.at[barange, i].set(rm)
            return lb, i, ja, rm, ok

        i0 = jnp.zeros(bb, jnp.int32)
        state = (lb, i0, i0, jnp.zeros(bb, jnp.float32),
                 jnp.zeros(bb, bool))
        lb, i, j, dij, _ = jax.lax.while_loop(cond, body, state)
        return i, j, dij, lb

    def step(_, state):
        d2, lb, sizes, assign, n_active = state
        i, j, dij, lb = select(d2, lb)
        i, j = jnp.minimum(i, j), jnp.maximum(i, j)
        do = (n_active > k_target) & jnp.isfinite(dij)
        d2i = jnp.take_along_axis(d2, i[:, None, None], axis=1)[:, 0]
        d2j = jnp.take_along_axis(d2, j[:, None, None], axis=1)[:, 0]
        si = jnp.take_along_axis(sizes, i[:, None], axis=1)
        sj = jnp.take_along_axis(sizes, j[:, None], axis=1)
        sc = sizes
        denom = si + sj + sc
        # Lance-Williams (squared Ward form), same guard as the ref
        new_row = ((si + sc) * d2i + (sj + sc) * d2j
                   - sc * dij[:, None]) / jnp.maximum(denom, 1e-9)
        was_inf = jnp.isinf(d2i) | jnp.isinf(d2j)
        oh_i = lane == i[:, None]
        oh_j = lane == j[:, None]
        new_row = jnp.where(was_inf | oh_i | oh_j, _INF, new_row)
        # do-folding: a skipped merge writes the original rows back
        row_i = jnp.where(do[:, None], new_row, d2i)
        row_j = jnp.where(do[:, None], _INF, d2j)
        d2 = d2.at[barange, i, :].set(row_i)
        d2 = d2.at[barange, :, i].set(row_i)
        d2 = d2.at[barange, j, :].set(row_j)
        d2 = d2.at[barange, :, j].set(row_j)
        # bounds: other rows may only have gained the new column as
        # their minimum; row i is recomputed exactly; row j retires
        lb = jnp.where(do[:, None], jnp.minimum(lb, new_row), lb)
        lb_i = jnp.where(do, jnp.min(new_row, axis=-1),
                         jnp.take_along_axis(lb, i[:, None], axis=1)[:, 0])
        lb_j = jnp.where(do, _INF,
                         jnp.take_along_axis(lb, j[:, None], axis=1)[:, 0])
        lb = lb.at[barange, i].set(lb_i)
        lb = lb.at[barange, j].set(lb_j)
        sizes = jnp.where(do[:, None],
                          jnp.where(oh_i, si + sj,
                                    jnp.where(oh_j, 0.0, sizes)), sizes)
        assign = jnp.where(do[:, None] & (assign == j[:, None]),
                           i[:, None], assign)
        n_active = jnp.where(do, n_active - 1, n_active)
        return d2, lb, sizes, assign, n_active

    state = (d2, lb, sizes, assign, n_active)
    state = jax.lax.fori_loop(0, n_steps, step, state)
    return state[3]


def _ward_pool_kernel(x_ref, mask_ref, k_ref, steps_ref, o_ref):
    """One program = one block of docs; the whole merge loop runs on
    VMEM-resident state."""
    x = x_ref[...]
    mask = mask_ref[...]
    k = k_ref[...]
    n_steps = jnp.max(steps_ref[...])
    o_ref[...] = ward_merge_block(x, mask, k, n_steps)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def ward_pool_pallas(x, mask, k, steps, *, block_b: int = 8,
                     interpret: bool = False):
    """Pallas dispatch: grid over doc blocks (B must be a multiple of
    ``block_b`` — ``ops.ward_assign`` pads with masked docs).

    Args:
      x: [B, N, d] f32 unit token vectors (masked rows zero).
      mask: [B, N] bool emit mask.
      k: [B] int32 per-doc cluster target (``n_valid // factor + 1``).
      steps: [B] int32 per-doc merge budget (``max(n_valid - k, 0)``);
        each program runs its block's max and do-folds the rest.
    Returns assign [B, N] int32.
    """
    B, N, d = x.shape
    assert B % block_b == 0, (B, block_b)
    grid = (B // block_b,)
    return pl.pallas_call(
        _ward_pool_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, N, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, N), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_b, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.int32),
        interpret=interpret,
    )(x, mask, k, steps)
