"""jit'd public wrapper for the Ward-pooling kernel: normalizes inputs
the same way the reference does, pads the doc batch to a block
multiple with fully-masked docs, dispatches to the Pallas kernel
(interpret=True off-TPU), and unpads.

``impl`` resolution (what ``PoolingSpec.ward_kernel`` carries):
  * ``"auto"``   — the kernel path (it is bitwise-equal to the
    reference everywhere and faster even under the CPU interpreter, so
    auto means ON; ``"ref"`` exists for A/B parity gates and debugging).
  * ``"kernel"`` — force the Pallas path.
  * ``"ref"``    — force ``core/ward.py``'s ``ward_cluster_batch``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.maxsim.ops import _on_tpu, _pad_to
from repro.kernels.ward_pool.kernel import ward_pool_pallas
from repro.kernels.ward_pool.ref import ward_assign_ref

WARD_IMPLS = ("auto", "kernel", "ref")


def resolve_impl(impl: str) -> str:
    """'auto'|'kernel'|'ref' -> 'kernel'|'ref'."""
    if impl not in WARD_IMPLS:
        raise ValueError(f"ward impl must be one of {WARD_IMPLS}, "
                         f"got {impl!r}")
    return "kernel" if impl == "auto" else impl


@functools.partial(jax.jit, static_argnames=("factor", "block_b"))
def _ward_assign_kernel(x, mask, factor: int, block_b: int = 8):
    B, N, d = x.shape
    x = x.astype(jnp.float32)
    # same per-token normalization as ward_cluster's _init_state
    nrm = jnp.linalg.norm(x, axis=-1, keepdims=True)
    x = x / jnp.maximum(nrm, 1e-9)
    x = jnp.where(mask[..., None], x, 0.0)
    xp = _pad_to(x, 0, block_b)
    mp = _pad_to(mask, 0, block_b)        # padded docs are all-masked
    n_valid = jnp.sum(mp.astype(jnp.int32), axis=-1)
    k = jnp.maximum(n_valid // factor + 1, 1)
    steps = jnp.maximum(n_valid - k, 0)
    out = ward_pool_pallas(xp, mp, k, steps, block_b=block_b,
                           interpret=not _on_tpu())
    return out[:B]


def ward_assign(x, mask, factor: int, *, impl: str = "auto",
                block_b: int = 8):
    """Batched Ward cluster assignments, reference-bitwise.

    x [B, N, d], mask [B, N] -> assign [B, N] int32 where each valid
    token's id is its cluster's representative (lowest) token index —
    the exact contract of ``ward_cluster_batch``.
    """
    if resolve_impl(impl) == "ref":
        return ward_assign_ref(x, mask, factor)
    return _ward_assign_kernel(x, mask, int(factor), block_b)
