"""Reference path for the Ward-pooling kernel.

The oracle is the existing ``core/ward.py`` implementation — the
per-doc full-matrix argmin loop that tests/test_pooling.py pins against
SciPy's ``linkage(method="ward")``. The Pallas kernel in this package
must match it BITWISE (same merge order under ties, same handling of
masked / degenerate docs); tests/test_kernels_ward.py sweeps the pin.
"""
from __future__ import annotations

from repro.core.ward import ward_cluster_batch


def ward_assign_ref(x, mask, factor: int):
    """[B, N, d] x [B, N] -> [B, N] int32 cluster ids (representative
    token index), exactly ``ward_cluster_batch``."""
    return ward_cluster_batch(x, mask, factor)
