from repro.kernels.maxsim_packed.ops import maxsim_packed_rerank

__all__ = ["maxsim_packed_rerank"]
