"""jit'd public wrapper for the compressed-domain rerank kernel: pads the
candidate axis to a block multiple, dispatches to the Pallas kernel
(interpret=True off-TPU), unpads."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.maxsim.ops import _on_tpu, _pad_to
from repro.kernels.maxsim_packed.kernel import maxsim_packed_rerank_pallas


@functools.partial(jax.jit, static_argnames=("bits", "block_s"))
def maxsim_packed_rerank(q, q_mask, words, ids, d_mask, centroids, values,
                         *, bits: int = 2, block_s: int = 8):
    """Per-query candidate scores [Nq, S] straight from packed codes.

    words [Nq, S, Ld, W] packed residual words; ids [Nq, S, Ld] centroid
    ids; d_mask [Nq, S, Ld] token validity — the per-query gathers of the
    plaid packed views; centroids [K, dim] / values [dim, 2^bits] are the
    codec tables. Query i scores only its own slab words[i]."""
    S = words.shape[1]
    words = _pad_to(words, 1, block_s)
    ids = _pad_to(ids, 1, block_s)
    d_mask = _pad_to(d_mask, 1, block_s)
    out = maxsim_packed_rerank_pallas(
        jnp.asarray(q, jnp.float32), q_mask, words.astype(jnp.uint32),
        ids.astype(jnp.int32), d_mask,
        jnp.asarray(centroids, jnp.float32),
        jnp.asarray(values, jnp.float32),
        bits=bits, block_s=block_s, interpret=not _on_tpu())
    return out[:, :S]
