"""Pure-jnp oracle for the fused compressed-domain rerank kernel.

Literally the composition ``quantization.decode`` -> ``maxsim_rerank_ref``
op for op (same unpack, same bucket/centroid gathers, same
``v / max(||v||, 1e-9)`` renormalize, same rerank einsum), so — jitted or
eager — it reproduces the reconstruction-path scores BITWISE on CPU CI.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.maxsim.ref import maxsim_rerank_ref
from repro.kernels.quant.ref import unpack_ref


def decode_rows_ref(words, ids, centroids, values, bits: int):
    """Flat decode: words [M, W], ids [M] -> [M, dim] unit reconstructions.

    The exact op sequence of ``core.quantization.decode`` (which itself
    delegates its unpack here via ``unpack_ref``).
    """
    dim = centroids.shape[1]
    codes = unpack_ref(words, bits, dim)                    # [M, dim]
    res = values[jnp.arange(dim)[None, :], codes]           # [M, dim]
    v = centroids[ids] + res
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-9)


def maxsim_packed_rerank_ref(q, q_mask, words, ids, d_mask, centroids,
                             values, *, bits: int):
    """q [Nq, Lq, dim]; words [Nq, S, Ld, W]; ids [Nq, S, Ld];
    d_mask [Nq, S, Ld] -> scores [Nq, S] f32.

    Masked slots decode to garbage rows, exactly like padded slots in the
    reconstruction DocStore decode to zero rows — both are forced to
    -inf before the max, so the scores are identical either way.
    """
    Nq, S, Ld, W = words.shape
    dim = centroids.shape[1]
    v = decode_rows_ref(words.reshape(-1, W), ids.reshape(-1),
                        centroids, values, bits)
    d = v.reshape(Nq, S, Ld, dim)
    return maxsim_rerank_ref(q, q_mask, d, d_mask)
