"""Fused compressed-domain MaxSim rerank Pallas TPU kernel.

The PLAID stage-4 rerank without the f32 reconstruction store: each
program streams one candidate slab's PACKED residual words + centroid
ids into VMEM, reconstructs the token vectors in-register
(``kernels/quant.unpack_reconstruct`` — the shared packed-scoring
primitive), and runs the masked max-over-doc-tokens /
sum-over-query-tokens reduction in the same pass. HBM traffic per
candidate token drops from ``dim*4`` reconstruction bytes to
``4 + W*4`` code bytes (~14x at dim=128, b=2) while the MXU work is
unchanged — the kernel moves toward the bandwidth bound (see
``repro.roofline.packed``).

The centroid-row gather happens INSIDE the tile as a one-hot MXU matmul
(codes -> [M, K] select plane -> [M, dim] rows): Mosaic has no cheap
dynamic gather from a [K, dim] VMEM table, but K is small (<= 256) so
the extra matmul is a few percent of the scoring matmul and keeps the
per-token HBM stream at id+codes bytes. The [K, dim] table and the
[dim, 2^b] value plane stay VMEM-resident across the whole grid.

Grid/tiling mirrors ``kernels/maxsim.maxsim_rerank_pallas``: one program
per (query, candidate slab); VMEM high-water at the defaults
(block_s=8, Ld=256, dim=128, K=256) is ~6 MiB — comfortably under the
~16 MiB/core of TPU v5e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quant.kernel import unpack_reconstruct


def _maxsim_packed_rerank_kernel(q_ref, qm_ref, w_ref, id_ref, dm_ref,
                                 c_ref, v_ref, o_ref, *, bits: int):
    """One query x one slab of its own candidates, scored from codes."""
    _, Lq, dim = q_ref.shape
    _, BS, Ld, W = w_ref.shape
    K = c_ref.shape[0]
    M = BS * Ld
    words = w_ref[0].reshape(M, W)
    ids = id_ref[0].reshape(M, 1)
    # centroid rows via one-hot MXU matmul (no gather unit involvement)
    onehot = (ids == jax.lax.broadcasted_iota(jnp.int32, (M, K), 1)
              ).astype(jnp.float32)
    rows = jax.lax.dot_general(onehot, c_ref[...].astype(jnp.float32),
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    d = unpack_reconstruct(words, rows, v_ref[...], bits=bits)  # [M, dim]
    q = q_ref[0].astype(jnp.float32)                            # [Lq, dim]
    sim = jax.lax.dot_general(q, d, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    sim = sim.reshape(Lq, BS, Ld)
    dm = dm_ref[0].reshape(1, BS, Ld)
    sim = jnp.where(dm, sim, -jnp.inf)
    best = jnp.max(sim, axis=-1)                     # [Lq, BS]
    qm = qm_ref[0].reshape(Lq, 1)
    best = jnp.where(qm & jnp.isfinite(best), best, 0.0)
    o_ref[0] = jnp.sum(best, axis=0)                 # [BS]


@functools.partial(jax.jit, static_argnames=("bits", "block_s", "interpret"))
def maxsim_packed_rerank_pallas(q, q_mask, words, ids, d_mask, centroids,
                                values, *, bits: int = 2, block_s: int = 8,
                                interpret: bool = False):
    """q [Nq, Lq, dim]; words [Nq, S, Ld, W] uint32 packed codes;
    ids [Nq, S, Ld] int32 centroid ids; d_mask [Nq, S, Ld];
    centroids [K, dim]; values [dim, 2^bits]
    -> scores [Nq, S] f32. S % block_s == 0 (wrapper pads)."""
    Nq, Lq, dim = q.shape
    _, S, Ld, W = words.shape
    K = centroids.shape[0]
    assert S % block_s == 0, (S, block_s)
    grid = (Nq, S // block_s)
    kernel = functools.partial(_maxsim_packed_rerank_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Lq, dim), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, Lq), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_s, Ld, W), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, block_s, Ld), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_s, Ld), lambda i, j: (i, j, 0)),
            pl.BlockSpec((K, dim), lambda i, j: (0, 0)),
            pl.BlockSpec((dim, 1 << bits), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_s), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Nq, S), jnp.float32),
        interpret=interpret,
    )(q, q_mask, words, ids, d_mask, centroids, values)
