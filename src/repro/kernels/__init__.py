"""Pallas TPU kernels for the compute hot-spots of the retrieval stack.

Each kernel ships kernel.py (pl.pallas_call + BlockSpec tiling), ops.py
(jit'd public wrapper, interpret=True off-TPU) and ref.py (pure-jnp
oracle the tests sweep shapes/dtypes against).
"""
from repro.kernels.maxsim.ops import maxsim
from repro.kernels.maxsim_packed.ops import maxsim_packed_rerank
from repro.kernels.kmeans_assign.ops import kmeans_assign
from repro.kernels.quant.ops import dequant_score
from repro.kernels.flash_attention.ops import flash_attention

__all__ = ["maxsim", "maxsim_packed_rerank", "kmeans_assign",
           "dequant_score", "flash_attention"]
