"""jnp reference for the fused probe kernel — the bitwise oracle.

Delegates to the exact stage-1/stage-3 functions the host candidate
path runs (``core.plaid._centroid_scores_batch`` +
``_approx_scores_batch``), so "kernel == ref" IS "kernel == host path"
for the approximate scores, with no second implementation to drift.
"""
from __future__ import annotations

import jax.numpy as jnp


def plaid_probe_ref(q, q_mask, centroids, codes, code_mask, cand_mask,
                    *, t_cs: float):
    """Same contract as ``kernel.plaid_probe_pallas`` (no block padding
    required): -> approx scores [Nq, C] f32, -inf on invalid slots."""
    from repro.core.plaid import (_approx_scores_batch,
                                  _centroid_scores_batch)
    cs = _centroid_scores_batch(jnp.asarray(q, jnp.float32),
                                jnp.asarray(centroids))
    cs = jnp.where(jnp.asarray(q_mask, bool)[:, :, None], cs, -jnp.inf)
    return _approx_scores_batch(cs, codes, code_mask, cand_mask, t_cs)
