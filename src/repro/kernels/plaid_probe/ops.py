"""Dispatcher for the fused centroid-interaction probe op.

``impl``:
  * ``"auto"``   — Pallas kernel on TPU, jnp reference elsewhere (the
                   serving default: interpret-mode Pallas on CPU is
                   correctness-only and would tank QPS).
  * ``"kernel"`` — force the Pallas kernel (interpret off-TPU; parity
                   tests and benches).
  * ``"ref"``    — force the jnp reference.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.maxsim.ops import _on_tpu, _pad_to
from repro.kernels.plaid_probe.kernel import plaid_probe_pallas
from repro.kernels.plaid_probe.ref import plaid_probe_ref

PROBE_IMPLS = ("auto", "kernel", "ref")


def plaid_probe_scores(q, q_mask, centroids, codes, code_mask, cand_mask,
                       *, t_cs: float, impl: str = "auto",
                       block_c: int = 8):
    """Approx (centroid-only, t_cs-pruned) MaxSim for gathered candidate
    code rows: q [Nq, Lq, dim]; codes/code_mask [Nq, C, L]; cand_mask
    [Nq, C] -> scores [Nq, C] f32 (-inf invalid)."""
    assert impl in PROBE_IMPLS, impl
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return plaid_probe_ref(q, q_mask, centroids, codes, code_mask,
                               cand_mask, t_cs=t_cs)
    C = codes.shape[1]
    codes = _pad_to(codes.astype(jnp.int32), 1, block_c)
    code_mask = _pad_to(code_mask, 1, block_c)
    cand_mask = _pad_to(cand_mask, 1, block_c)
    out = plaid_probe_pallas(
        jnp.asarray(q, jnp.float32), jnp.asarray(q_mask, bool),
        jnp.asarray(centroids, jnp.float32), codes, code_mask, cand_mask,
        t_cs=float(t_cs), block_c=block_c, interpret=not _on_tpu())
    return out[:, :C]
