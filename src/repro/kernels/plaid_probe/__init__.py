from repro.kernels.plaid_probe.ops import plaid_probe_scores  # noqa: F401
