"""Fused PLAID centroid-interaction Pallas TPU kernel (stages 1 + 3).

Candidate generation's matmul-shaped stages in one pass: each program
scores ONE query's tokens against the whole centroid table
(``q @ centroids^T`` on the MXU) and immediately runs the threshold-
pruned centroid-only MaxSim over one VMEM tile of its candidate code
rows — the approximate scores PLAID prunes with, straight from packed
centroid ids, without ever materializing the host path's
``[Nq, block, L, Lq]`` gathered-score intermediate in HBM.

The per-token centroid-score lookup is a one-hot MXU matmul, the same
gather-free idiom as ``kernels/maxsim_packed``: codes -> [M, K] select
plane -> [M, Lq] pruned scores. Every row of the select plane has
exactly one 1.0 (ids live in [0, K)), so the contraction reproduces the
reference's ``csT[code]`` gather bit for bit. The [K, dim] centroid
table stays VMEM-resident across the whole grid; per-candidate HBM
traffic drops to the code bytes (4B/token + mask) — see
``repro.roofline.probe``.

Grid/tiling mirrors the packed rerank kernel: one program per
(query, candidate tile); VMEM high-water at the defaults (block_c=8,
L=256, K=256, Lq=32, dim=128) is ~2.5 MiB — far under ~16 MiB/core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _plaid_probe_kernel(q_ref, qm_ref, c_ref, code_ref, cm_ref, vm_ref,
                        o_ref, *, t_cs: float):
    """One query x one tile of its own candidates, scored centroid-only."""
    _, Lq, dim = q_ref.shape
    _, BC, L = code_ref.shape
    K = c_ref.shape[0]
    # stage 1: all centroid interactions for this query's tokens
    q = q_ref[0].astype(jnp.float32)                       # [Lq, dim]
    cs = jax.lax.dot_general(q, c_ref[...].astype(jnp.float32),
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Lq, K]
    qm = qm_ref[0].reshape(Lq, 1)
    cs = jnp.where(qm, cs, -jnp.inf)       # masked tokens contribute 0
    csp = jnp.where(cs >= t_cs, cs, 0.0)   # t_cs prune (-inf < t_cs)
    # stage 3: per-token centroid-score lookup as a one-hot MXU matmul
    M = BC * L
    codes = code_ref[0].reshape(M, 1)
    onehot = (codes == jax.lax.broadcasted_iota(jnp.int32, (M, K), 1)
              ).astype(jnp.float32)
    vals = jax.lax.dot_general(onehot, csp, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    vals = vals.reshape(BC, L, Lq)
    vals = jnp.where(cm_ref[0][..., None], vals, 0.0)
    score = vals.max(axis=1).sum(axis=-1)                  # [BC]
    o_ref[0] = jnp.where(vm_ref[0], score, -jnp.inf)


@functools.partial(jax.jit,
                   static_argnames=("t_cs", "block_c", "interpret"))
def plaid_probe_pallas(q, q_mask, centroids, codes, code_mask, cand_mask,
                       *, t_cs: float, block_c: int = 8,
                       interpret: bool = False):
    """q [Nq, Lq, dim]; centroids [K, dim]; codes [Nq, C, L] int32
    per-candidate centroid ids; code_mask [Nq, C, L]; cand_mask [Nq, C]
    -> approx scores [Nq, C] f32 (-inf on invalid candidate slots).
    C % block_c == 0 (wrapper pads)."""
    Nq, Lq, dim = q.shape
    _, C, L = codes.shape
    K = centroids.shape[0]
    assert C % block_c == 0, (C, block_c)
    grid = (Nq, C // block_c)
    kernel = functools.partial(_plaid_probe_kernel, t_cs=t_cs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Lq, dim), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, Lq), lambda i, j: (i, 0)),
            pl.BlockSpec((K, dim), lambda i, j: (0, 0)),
            pl.BlockSpec((1, block_c, L), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_c, L), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_c), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Nq, C), jnp.float32),
        interpret=interpret,
    )(q, q_mask, centroids, codes, code_mask, cand_mask)
