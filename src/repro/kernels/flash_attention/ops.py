"""jit'd wrapper: [B, H, S, dh]-layout flash attention with GQA."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 512):
    """q: [B, H, Sq, dh]; k, v: [B, KV, Skv, dh] (H % KV == 0).

    Returns o [B, H, Sq, dh]. Sequence lengths are padded to block
    multiples internally (padded kv columns are masked by the causal/len
    logic only through padding with -inf-producing zero keys is unsafe, so
    we require Skv % block_k == 0 upstream for production shapes and pad
    only q here).
    """
    B, H, Sq, dh = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    bq = min(block_q, Sq) if Sq % block_q else block_q
    if Sq % bq:
        bq = Sq  # small odd sequence: single q block
    bk = min(block_k, Skv) if Skv % block_k else block_k
    if Skv % bk:
        bk = Skv
    o = flash_attention_pallas(
        q.reshape(B * H, Sq, dh), k.reshape(B * KV, Skv, dh),
        v.reshape(B * KV, Skv, dh), causal=causal, block_q=bq,
        block_k=bk, interpret=not _on_tpu())
    return o.reshape(B, H, Sq, dh)
