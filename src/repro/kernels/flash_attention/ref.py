"""Pure-jnp oracle for the flash-attention kernel (GQA-aware)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool):
    """q: [B, H, Sq, dh]; k, v: [B, KV, Skv, dh] with H % KV == 0.

    Returns o [B, H, Sq, dh] in q.dtype (f32 softmax internally).
    """
    B, H, Sq, dh = q.shape
    KV = k.shape[1]
    G = H // KV
    kf = jnp.repeat(k, G, axis=1)
    vf = jnp.repeat(v, G, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) * scale
    if causal:
        Skv = k.shape[2]
        mask = (jnp.arange(Sq)[:, None] + (Skv - Sq)
                >= jnp.arange(Skv)[None, :])
        s = jnp.where(mask[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", w, vf.astype(jnp.float32))
    return o.astype(q.dtype)
