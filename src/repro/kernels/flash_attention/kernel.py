"""FlashAttention-style online-softmax Pallas TPU kernel (fwd), GQA-aware.

Grid: (batch*q_heads, q blocks, kv blocks) with the kv axis innermost and
sequential ("arbitrary"); running max / denominator / accumulator live in
VMEM scratch and the output block is written once on the last kv step.

GQA: q is laid out [B*H, Sq, dh] and k/v [B*KV, Skv, dh]; the k/v BlockSpec
index maps program bh -> bh // group, so grouped query heads stream the
same kv tile (no materialized repeat).

Causal: kv blocks fully above the diagonal are skipped with pl.when (the
compute is masked AND the flops never issue — matches the exact-FLOPs
chunked reference in models/attention.py).

Block sizes default to (128, 512): q tile 128x128 f32 = 64 KiB, kv tile
512x128x2 = 256 KiB, scores 128x512 f32 = 256 KiB — comfortably inside
v5e VMEM with double-buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; accept either
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, scale: float, block_q: int, block_k: int,
                  q_offset: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: query block rows span [q_offset + iq*Bq, ... +Bq); kv block
    # cols span [ik*Bk, ... +Bk). Skip blocks entirely above the diagonal.
    q_start = iq * block_q + q_offset
    k_start = ik * block_k

    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # [Bq, dh]
        k = k_ref[0].astype(jnp.float32)                # [Bk, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0)
            cols = k_start + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]                             # [Bq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(s <= NEG_INF, 0.0, p)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(p.astype(v_ref.dtype), v_ref[0],
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    if causal:
        # skip kv blocks entirely above the causal diagonal
        pl.when(q_start + block_q - 1 >= k_start)(_compute)
    else:
        _compute()

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 512,
                           interpret: bool = False):
    """q: [BH, Sq, dh]; k, v: [BKV, Skv, dh]; BH % BKV == 0.

    Returns o [BH, Sq, dh]. Sq % block_q == 0, Skv % block_k == 0.
    For decode-style use (Sq < Skv) the causal diagonal is anchored
    bottom-right (q row i attends to kv cols <= Skv - Sq + i).
    """
    BH, Sq, dh = q.shape
    BKV, Skv, _ = k.shape
    assert BH % BKV == 0
    group = BH // BKV
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv)
    grid = (BH, Sq // block_q, Skv // block_k)
    scale = 1.0 / (dh ** 0.5)
    q_offset = Skv - Sq                    # causal anchor
    kernel = functools.partial(
        _flash_kernel, causal=causal, scale=scale, block_q=block_q,
        block_k=block_k, q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, iq, ik, g=group: (bh // g, ik, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, iq, ik, g=group: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
