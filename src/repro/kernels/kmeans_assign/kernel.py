"""Fused similarity + argmax k-means assignment (Pallas TPU).

The pooling/IVF hot loop: X·Cᵀ then a masked argmax per row, fused so the
[N, K] similarity matrix never round-trips HBM. Centroids stay resident in
VMEM across the whole grid (their BlockSpec index is constant); x streams
through in ``block_n`` row tiles.

Argmax is computed in-kernel with the iota-min trick (smallest index among
maxima, matching jnp.argmax semantics exactly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(x_ref, c_ref, km_ref, a_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)               # [BN, dim]
    c = c_ref[...].astype(jnp.float32)               # [K, dim]
    sim = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    km = km_ref[...].reshape(1, -1)                  # [1, K]
    sim = jnp.where(km, sim, -jnp.inf)
    best = jnp.max(sim, axis=-1)                     # [BN]
    K = sim.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, sim.shape, 1)
    idx = jnp.min(jnp.where(sim == best[:, None], iota, K), axis=-1)
    a_ref[...] = idx.astype(jnp.int32)
    s_ref[...] = best


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign_pallas(x, centroids, k_mask, *, block_n: int = 256,
                         interpret: bool = False):
    """x: [N, dim]; centroids: [K, dim]; k_mask: [K] bool.

    Returns (assign [N] int32, best_sim [N] f32). N % block_n == 0.
    """
    N, dim = x.shape
    K = centroids.shape[0]
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, dim), lambda i: (i, 0)),
            pl.BlockSpec((K, dim), lambda i: (0, 0)),    # resident
            pl.BlockSpec((K,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((N,), jnp.float32),
        ],
        interpret=interpret,
    )(x, centroids, k_mask)
