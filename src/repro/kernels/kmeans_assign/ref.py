"""Pure-jnp oracle for the fused k-means assignment kernel."""
from __future__ import annotations

import jax.numpy as jnp


def kmeans_assign_ref(x, centroids, k_mask):
    """x: [N, dim]; centroids: [K, dim]; k_mask: [K] valid clusters.

    Returns (assign [N] int32, best_sim [N] f32) — argmax cosine over the
    masked centroid set (first index wins ties, matching jnp.argmax).
    """
    sim = x.astype(jnp.float32) @ centroids.astype(jnp.float32).T
    sim = jnp.where(k_mask[None, :], sim, -jnp.inf)
    return (jnp.argmax(sim, axis=-1).astype(jnp.int32),
            jnp.max(sim, axis=-1))
