"""jit'd wrapper for the fused k-means assignment kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.kmeans_assign.kernel import kmeans_assign_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_n",))
def kmeans_assign(x, centroids, k_mask=None, *, block_n: int = 256):
    """x [N, dim] vs centroids [K, dim] -> (assign [N] i32, best [N] f32)."""
    N = x.shape[0]
    K = centroids.shape[0]
    if k_mask is None:
        k_mask = jnp.ones((K,), bool)
    pad = (-N) % block_n
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    a, s = kmeans_assign_pallas(x, centroids, k_mask, block_n=block_n,
                                interpret=not _on_tpu())
    return a[:N], s[:N]
