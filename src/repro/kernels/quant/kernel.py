"""Fused 2-bit dequantize + score Pallas TPU kernel.

PLAID stage-4 hot path: candidate token vectors live as packed residual
codes; the kernel unpacks (integer shifts on int32 words), reconstructs
(centroid row + bucket value), renormalizes, and scores against the query
block — all in VMEM, so the decompressed [M, dim] tensor never hits HBM.

The per-dimension bucket lookup values[dim, 2^b] is done WITHOUT a gather:
2-bit codes select among 4 broadcast value planes via a where-chain —
pure VPU selects, no scatter/gather unit involvement.

``unpack_reconstruct`` is THE in-tile packed-scoring primitive: both this
kernel and the fused compressed-domain maxsim rerank kernel
(kernels/maxsim_packed) build on it, and its arithmetic mirrors
``core.quantization.decode`` op for op (same normalize formula), so the
Pallas paths and the jnp reference paths reconstruct identical vectors
up to float evaluation order.

Tiling: grid over M blocks; values plane + query block resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def unpack_reconstruct(words, rows, vals, *, bits: int):
    """In-tile unpack + reconstruct + renormalize (the shared primitive).

    words: [M, W] uint32 packed b-bit codes; rows: [M, dim] pre-gathered
    centroid rows; vals: [dim, 2^bits] bucket values.
    Returns [M, dim] f32 unit-renormalized reconstructions.
    """
    M, W = words.shape
    dim = rows.shape[1]
    cpw = 32 // bits
    # unpack: [M, W, cpw] -> [M, dim] (little-endian lanes, as pack_codes)
    shifts = (jax.lax.broadcasted_iota(jnp.uint32, (1, 1, cpw), 2)
              * jnp.uint32(bits))
    mask = jnp.uint32((1 << bits) - 1)
    codes = ((words[:, :, None] >> shifts) & mask).reshape(M, dim)
    # bucket values via where-chain over the 2^bits planes
    res = jnp.zeros((M, dim), jnp.float32)
    for b in range(1 << bits):
        res = jnp.where(codes == b, vals[:, b][None, :], res)
    v = rows.astype(jnp.float32) + res
    nrm = jnp.sqrt(jnp.sum(v * v, axis=-1, keepdims=True))
    return v / jnp.maximum(nrm, 1e-9)


def _dequant_score_kernel(w_ref, c_ref, v_ref, q_ref, o_ref, *, bits: int):
    v = unpack_reconstruct(w_ref[...], c_ref[...], v_ref[...], bits=bits)
    q = q_ref[...].astype(jnp.float32)                  # [Lq, dim]
    o_ref[...] = jax.lax.dot_general(v, q, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits", "block_m", "interpret"))
def dequant_score_pallas(words, centroid_rows, values, q, *, bits: int = 2,
                         block_m: int = 256, interpret: bool = False):
    """words [M, W]; centroid_rows [M, dim]; values [dim, 2^b]; q [Lq, dim]
    -> sims [M, Lq] f32. M % block_m == 0 (wrapper pads)."""
    M, W = words.shape
    dim = centroid_rows.shape[1]
    Lq = q.shape[0]
    assert M % block_m == 0
    kernel = functools.partial(_dequant_score_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=(M // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, W), lambda i: (i, 0)),
            pl.BlockSpec((block_m, dim), lambda i: (i, 0)),
            pl.BlockSpec((dim, 1 << bits), lambda i: (0, 0)),
            pl.BlockSpec((Lq, dim), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, Lq), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, Lq), jnp.float32),
        interpret=interpret,
    )(words, centroid_rows, values, q)
