"""jit'd wrapper: fused dequantize+score for PLAID candidate reranking."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quant.kernel import dequant_score_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("bits", "block_m"))
def dequant_score(words, centroid_ids, centroids, values, q, *,
                  bits: int = 2, block_m: int = 256):
    """Fused candidate scoring.

    words [M, W] packed codes; centroid_ids [M] int32; centroids [K, dim];
    values [dim, 2^b]; q [Lq, dim]. Returns sims [M, Lq] f32.

    The centroid row gather happens outside the kernel (one take, cheap);
    unpack + reconstruct + normalize + score fuse inside.
    """
    rows = jnp.take(centroids, centroid_ids, axis=0)
    M = words.shape[0]
    pad = (-M) % block_m
    if pad:
        words = jnp.pad(words, ((0, pad), (0, 0)))
        rows = jnp.pad(rows, ((0, pad), (0, 0)))
    out = dequant_score_pallas(words, rows, values, q, bits=bits,
                               block_m=block_m, interpret=not _on_tpu())
    return out[:M]
