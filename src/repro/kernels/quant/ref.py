"""Pure-jnp oracle for the fused dequantize-and-score kernel."""
from __future__ import annotations

import jax.numpy as jnp


def unpack_ref(words, bits: int, dim: int):
    """words [M, W] uint32 -> codes [M, dim] int32 (little-endian lanes)."""
    cpw = 32 // bits
    shifts = jnp.arange(cpw, dtype=jnp.uint32) * bits
    mask = jnp.uint32((1 << bits) - 1)
    c = (words[:, :, None] >> shifts[None, None, :]) & mask
    return c.reshape(words.shape[0], dim).astype(jnp.int32)


def dequant_score_ref(words, centroid_rows, values, q, bits: int):
    """Reconstruct 2-bit residual-coded vectors and MaxSim-score them.

    words: [M, W] packed codes; centroid_rows: [M, dim] (pre-gathered
    coarse centroids); values: [dim, 2^bits] bucket reconstruction values;
    q: [Lq, dim] query tokens.

    Returns sims [M, Lq] f32 of *unit-renormalized* reconstructions vs q.
    """
    dim = centroid_rows.shape[1]
    codes = unpack_ref(words, bits, dim)                    # [M, dim]
    res = values[jnp.arange(dim)[None, :], codes]           # [M, dim]
    v = centroid_rows.astype(jnp.float32) + res.astype(jnp.float32)
    v = v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-9)
    return v @ q.astype(jnp.float32).T                      # [M, Lq]
