"""Token pooling for multi-vector retrieval — JAX/Pallas reproduction.

Public API. The stable surface is the spec-driven facade::

    import repro

    spec = repro.RetrieverSpec(pooling=repro.PoolingSpec("ward", 2),
                               index=repro.IndexSpec(backend="plaid"))
    r = repro.Retriever.build(params, cfg, doc_tokens, spec, out_dir="idx")
    scores, ids = r.search(query_tokens, k=10)
    r2 = repro.Retriever.load(params, cfg, "idx")     # fresh process
    with r2.serve() as engine:                        # concurrent runtime
        fut = engine.submit(query_tokens[0])

``__all__`` is the pinned public surface (tests/test_spec.py guards
it); attributes resolve lazily so ``import repro`` stays cheap until a
heavy subsystem (encoder, engine) is actually touched.
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    # facade + specs (the stable surface)
    "Retriever": "repro.api",
    "RetrieverSpec": "repro.core.spec",
    "PoolingSpec": "repro.core.spec",
    "IndexSpec": "repro.core.spec",
    "ShardSpec": "repro.core.spec",
    "ServeSpec": "repro.core.spec",
    # registries (extension points)
    "register_pooling_strategy": "repro.core.spec",
    "pooling_methods": "repro.core.spec",
    "register_backend": "repro.core.spec",
    "backend_names": "repro.core.spec",
    # the layers underneath (still public, reached through the facade)
    "Indexer": "repro.retrieval.indexer",
    "Searcher": "repro.retrieval.searcher",
    "ServingEngine": "repro.launch.engine",
    "MultiVectorIndex": "repro.core.index",
    "ShardedIndex": "repro.core.sharded",
    "CascadeIndex": "repro.retrieval.cascade",
    # persistence + evaluation + configs
    "load_artifact": "repro.core.persist",
    "IndexFormatError": "repro.core.persist",
    "evaluate_pooling": "repro.retrieval.evaluate",   # deprecated shim
    "EvalDataset": "repro.eval.datasets",
    "QualitySweep": "repro.eval.sweep",
    "QualityReport": "repro.eval.report",
    "load_beir": "repro.eval.datasets",
    "get_config": "repro.configs",
    "get_smoke_config": "repro.configs",
    "init_colbert": "repro.models.colbert",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    target = _EXPORTS.get(name)
    if target is None:
        raise AttributeError(f"module 'repro' has no attribute {name!r}")
    value = getattr(importlib.import_module(target), name)
    globals()[name] = value          # cache: resolve each name once
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
