"""``QualitySweep`` — the paper's evaluation protocol, grid-wise and
without redundant work.

The old ``retrieval/evaluate.evaluate_pooling`` re-encoded the corpus
and rebuilt the factor-1 baseline for EVERY (method, factor) cell — an
O(cells) multiplier on the most expensive step. The sweep:

  1. encodes the corpus ONCE (``EncodedDocs`` caches the device
     outputs with the Indexer's exact batch boundaries, so pooled
     indexes are bitwise identical to the re-encode path);
  2. builds the unpooled baseline ONCE per (backend, quant_bits) and
     shares its ranking/metrics across every factor-1 cell and every
     relative computation under that key;
  3. drives ONLY the public ``repro.Retriever`` facade — every cell is
     built and scored through the same entry points a user calls, so
     the numbers gate what the API actually serves.

Output is a :class:`~repro.eval.report.QualityReport` (JSON +
paper-style markdown), which ``repro.eval.gate`` checks against the
paper envelope and a pinned baseline file.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Sequence, Tuple

from repro.eval.datasets import EvalDataset
from repro.eval.metrics import DEFAULT_METRICS, compute_metrics, max_k
from repro.eval.report import (QualityBaseline, QualityCell,
                               QualityReport, baseline_key)

QUANTIZED_BACKENDS = ("plaid",)     # quant_bits sweeps apply here only


def relative_performance(metric: float, baseline: float) -> float:
    """The paper's headline number: 100 = the unpooled baseline.

    The ratio is formed FIRST so ``metric == baseline`` gives exactly
    100.0 (x/x == 1.0 in IEEE for finite nonzero x) — the factor-1
    invariant the tests pin bitwise.
    """
    return 100.0 * (metric / baseline) if baseline > 0 else 0.0


class QualitySweep:
    """Sweep pool_factor x pooling method x backend x quant_bits over
    one dataset, scoring every cell through ``repro.Retriever``.

    ``factors`` may include 1: factor-1 cells are the baseline by
    construction (``PoolingSpec`` short-circuits factor<=1 to the
    identity), so they REUSE the baseline's metrics/stats instead of
    rebuilding — their relative value is exactly 100.0.
    """

    def __init__(self, params, cfg, dataset: EvalDataset,
                 methods: Sequence[str] = ("ward", "sequential"),
                 factors: Sequence[int] = (1, 2, 3, 4),
                 backends: Sequence[str] = ("flat", "plaid"),
                 quant_bits: Sequence[int] = (2,),
                 metrics: Sequence[str] = DEFAULT_METRICS,
                 k: int = 10,
                 encode_batch: int = 64,
                 index_overrides: Optional[Dict] = None):
        self.params = params
        self.cfg = cfg
        self.dataset = dataset
        self.methods = tuple(methods)
        self.factors = tuple(int(f) for f in factors)
        self.backends = tuple(backends)
        self.quant_bits = tuple(int(b) for b in quant_bits)
        self.metrics = tuple(metrics)
        self.k = int(k)
        self.encode_batch = int(encode_batch)
        self.index_overrides = dict(index_overrides or {})
        if not self.methods or not self.factors or not self.backends:
            raise ValueError("methods, factors and backends must each "
                             "be non-empty")

    # ------------------------------------------------------------------
    def _index_spec(self, backend: str, quant_bits: Optional[int]):
        from repro.core.spec import IndexSpec
        over = dict(self.index_overrides)
        if quant_bits is not None:
            over["quant_bits"] = int(quant_bits)
        return IndexSpec.from_config(self.cfg, backend=backend, **over)

    def _build(self, docs, backend: str, quant_bits: Optional[int],
               method: str, factor: int):
        import repro
        from repro.core.spec import PoolingSpec, RetrieverSpec
        spec = RetrieverSpec(
            pooling=PoolingSpec(method=method if factor > 1 else "none",
                                factor=max(int(factor), 1)),
            index=self._index_spec(backend, quant_bits))
        return repro.Retriever.build(self.params, self.cfg, docs, spec,
                                     encode_batch=self.encode_batch)

    def _evaluate(self, retriever) -> Dict[str, float]:
        return retriever.evaluate(self.dataset, metrics=self.metrics,
                                  k=self.k)

    # ------------------------------------------------------------------
    def run(self, verbose: bool = False,
            encoded=None) -> QualityReport:
        """Execute the grid. ``encoded`` lets callers share one
        ``EncodedDocs`` cache across several sweeps of the same corpus
        (the table benchmarks sweep one dataset per backend)."""
        from repro.retrieval.indexer import EncodedDocs
        t0 = time.time()
        if encoded is None:
            encoded = EncodedDocs.encode(self.params, self.cfg,
                                         self.dataset.doc_tokens,
                                         self.encode_batch)
        report = QualityReport(
            dataset=self.dataset.name,
            n_docs=self.dataset.n_docs,
            n_queries=self.dataset.n_queries,
            k=max(self.k, max_k(self.metrics)),
            meta={
                "methods": list(self.methods),
                "factors": list(self.factors),
                "backends": list(self.backends),
                "quant_bits": list(self.quant_bits),
                "metrics": list(self.metrics),
                "encode_batch": self.encode_batch,
                "index_overrides": dict(self.index_overrides),
                "dataset_meta": {k: v
                                 for k, v in self.dataset.meta.items()
                                 if isinstance(v, (str, int, float,
                                                   bool))},
            })

        for backend in self.backends:
            bits_grid: Tuple[Optional[int], ...] = (
                self.quant_bits if backend in QUANTIZED_BACKENDS
                else (None,))
            for qb in bits_grid:
                key = baseline_key(backend, qb)
                base_r = self._build(encoded, backend, qb, "none", 1)
                base_metrics = self._evaluate(base_r)
                base_stats = base_r.stats
                report.baselines[key] = QualityBaseline(
                    backend=backend, quant_bits=qb,
                    metrics=dict(base_metrics),
                    n_vectors=base_stats.n_vectors_stored,
                    index_bytes=base_stats.index_bytes)
                if verbose:
                    print(f"[{self.dataset.name}] baseline {key}: "
                          + " ".join(f"{m}={v:.4f}"
                                     for m, v in base_metrics.items()))
                for method in self.methods:
                    for factor in self.factors:
                        if factor <= 1:
                            # factor 1 IS the baseline (identity pool):
                            # share its ranking instead of rebuilding
                            cell = QualityCell(
                                backend=backend, method=method,
                                factor=1, quant_bits=qb,
                                metrics=dict(base_metrics),
                                relative={
                                    m: relative_performance(v, v)
                                    for m, v in base_metrics.items()},
                                n_vectors=base_stats.n_vectors_stored,
                                vector_reduction=0.0,
                                index_bytes=base_stats.index_bytes,
                                shared_baseline=True)
                        else:
                            r = self._build(encoded, backend, qb,
                                            method, factor)
                            m = self._evaluate(r)
                            stats = r.stats
                            cell = QualityCell(
                                backend=backend, method=method,
                                factor=factor, quant_bits=qb,
                                metrics=dict(m),
                                relative={
                                    n: relative_performance(
                                        v, base_metrics[n])
                                    for n, v in m.items()},
                                n_vectors=stats.n_vectors_stored,
                                vector_reduction=stats.vector_reduction,
                                index_bytes=stats.index_bytes)
                        report.cells.append(cell)
                        if verbose:
                            rel = cell.relative.get(self.metrics[0], 0.0)
                            print(f"  {key} {method} f={cell.factor}: "
                                  f"rel {rel:.2f} "
                                  f"({cell.vector_reduction:.1%} fewer "
                                  f"vectors)")
        report.meta["wall_s"] = round(time.time() - t0, 3)
        return report
