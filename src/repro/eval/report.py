"""Quality-report artifact: the paper-style relative-performance table
as data.

A :class:`QualityReport` is the output of one ``QualitySweep`` run over
one dataset: per-configuration :class:`QualityCell`s (absolute metric
values, RELATIVE values vs the unpooled baseline — the number every
table in the paper is made of — and footprint stats), plus the
baselines themselves. It round-trips losslessly through JSON (->
``BENCH_quality.json``, next to the BENCH_* perf artifacts) and renders
the paper's method x factor grid as markdown
(:meth:`QualityReport.markdown_table`).

``BENCH_quality.json`` is one file with named sections (the sweep grid,
table1..table4), merge-updated by :func:`write_bench_section` so the
table benchmarks and the sweep all land beside each other.
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional

BENCH_QUALITY_FILE = "BENCH_quality.json"
SCHEMA_VERSION = 1


def baseline_key(backend: str, quant_bits: Optional[int]) -> str:
    """One baseline per (backend, quantization) — pooling factors under
    the same key share it."""
    return backend if quant_bits is None else f"{backend}@{quant_bits}b"


@dataclass
class QualityCell:
    """One point of the grid: (backend, method, factor, quant_bits)."""
    backend: str
    method: str
    factor: int
    quant_bits: Optional[int]              # None for unquantized backends
    metrics: Dict[str, float]              # name -> absolute value
    relative: Dict[str, float]             # name -> 100 * v / baseline
    n_vectors: int
    vector_reduction: float                # fraction of vectors removed
    index_bytes: int
    shared_baseline: bool = False          # factor-1 cell reusing baseline

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "QualityCell":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


@dataclass
class QualityBaseline:
    """The unpooled (factor-1) reference a backend's cells divide by."""
    backend: str
    quant_bits: Optional[int]
    metrics: Dict[str, float]
    n_vectors: int
    index_bytes: int

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "QualityBaseline":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


@dataclass
class QualityReport:
    dataset: str
    n_docs: int
    n_queries: int
    k: int
    baselines: Dict[str, QualityBaseline] = field(default_factory=dict)
    cells: List[QualityCell] = field(default_factory=list)
    meta: Dict[str, object] = field(default_factory=dict)

    # ----------------------------------------------------------- lookup
    def cell(self, backend: str, method: str, factor: int,
             quant_bits: Optional[int] = None) -> Optional[QualityCell]:
        for c in self.cells:
            if (c.backend == backend and c.method == method
                    and c.factor == int(factor)
                    and c.quant_bits == quant_bits):
                return c
        return None

    def baseline(self, backend: str,
                 quant_bits: Optional[int] = None
                 ) -> Optional[QualityBaseline]:
        return self.baselines.get(baseline_key(backend, quant_bits))

    # ------------------------------------------------------ round trip
    def to_json(self) -> dict:
        return {
            "schema": SCHEMA_VERSION,
            "dataset": self.dataset,
            "n_docs": self.n_docs,
            "n_queries": self.n_queries,
            "k": self.k,
            "baselines": {k: b.to_json()
                          for k, b in self.baselines.items()},
            "cells": [c.to_json() for c in self.cells],
            "meta": self.meta,
        }

    @classmethod
    def from_json(cls, d: dict) -> "QualityReport":
        return cls(
            dataset=d["dataset"], n_docs=int(d["n_docs"]),
            n_queries=int(d["n_queries"]), k=int(d["k"]),
            baselines={k: QualityBaseline.from_json(b)
                       for k, b in d.get("baselines", {}).items()},
            cells=[QualityCell.from_json(c) for c in d.get("cells", [])],
            meta=dict(d.get("meta", {})))

    # -------------------------------------------------------- rendering
    def markdown_table(self, metric: str = "ndcg@10",
                       backend: Optional[str] = None,
                       quant_bits: Optional[int] = None) -> str:
        """The paper's relative-performance grid (100 = unpooled):
        one row per pooling method, one column per factor."""
        cells = [c for c in self.cells
                 if metric in c.relative
                 and (backend is None or c.backend == backend)
                 and c.quant_bits == quant_bits]
        if not cells:
            return f"(no {metric} cells)"
        methods, factors = [], []
        for c in cells:
            if c.method not in methods:
                methods.append(c.method)
            if c.factor not in factors:
                factors.append(c.factor)
        factors.sort()
        tag = backend or "all"
        if quant_bits is not None:
            tag += f" {quant_bits}-bit"
        lines = [f"| method ({tag}, rel. {metric}) | "
                 + " | ".join(f"f={f}" for f in factors) + " |",
                 "|" + "---|" * (len(factors) + 1)]
        for m in methods:
            row = [f"| {m} "]
            for f in factors:
                c = next((c for c in cells
                          if c.method == m and c.factor == f), None)
                row.append(f"| {c.relative[metric]:.2f} " if c else "| — ")
            lines.append("".join(row) + "|")
        return "\n".join(lines)

    def summary(self, metric: str = "ndcg@10") -> str:
        """Plain-text cell dump (benchmark verbose output)."""
        rows = [f"{'backend':10s} {'method':12s} {'f':>2s} {'bits':>4s} "
                f"{'rel':>7s} {'abs':>7s} {'vecs':>8s} {'reduct':>7s}"]
        for key, b in sorted(self.baselines.items()):
            base = b.metrics.get(metric, 0.0)
            rows.append(f"{key:10s} {'baseline':12s} {1:2d} {'':>4s} "
                        f"{100.0:7.2f} {base:7.4f} {b.n_vectors:8d} "
                        f"{0.0:7.1%}")
        for c in self.cells:
            if metric not in c.relative:
                continue
            bits = "" if c.quant_bits is None else str(c.quant_bits)
            rows.append(f"{c.backend:10s} {c.method:12s} {c.factor:2d} "
                        f"{bits:>4s} {c.relative[metric]:7.2f} "
                        f"{c.metrics[metric]:7.4f} {c.n_vectors:8d} "
                        f"{c.vector_reduction:7.1%}")
        return "\n".join(rows)


# ---------------------------------------------------------------------------
# BENCH_quality.json sections
# ---------------------------------------------------------------------------
def write_bench_section(path: str, section: str, payload) -> dict:
    """Merge ``payload`` (a QualityReport, a dict of them, or plain
    JSON data) into ``path`` under ``section``, preserving the other
    sections — table1..table4 and the sweep share one artifact."""
    def enc(x):
        if isinstance(x, QualityReport):
            return x.to_json()
        if isinstance(x, dict):
            return {k: enc(v) for k, v in x.items()}
        return x

    doc = {}
    if os.path.isfile(path):
        try:
            with open(path) as fh:
                doc = json.load(fh)
        except (OSError, json.JSONDecodeError):
            doc = {}
    if not isinstance(doc, dict):
        doc = {}
    doc["schema"] = SCHEMA_VERSION
    doc[section] = enc(payload)
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return doc


def read_bench_section(path: str, section: str):
    """Load one section back; QualityReport-shaped sections decode to
    :class:`QualityReport` (the gate's baseline input)."""
    with open(path) as fh:
        doc = json.load(fh)
    if section not in doc:
        raise KeyError(f"{path} has no section {section!r}; found "
                       f"{sorted(k for k in doc if k != 'schema')}")
    data = doc[section]
    if isinstance(data, dict) and "cells" in data and "dataset" in data:
        return QualityReport.from_json(data)
    return data
