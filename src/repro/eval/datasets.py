"""Evaluation datasets: doc token ids + query token ids + graded qrels.

One abstraction (:class:`EvalDataset`) with two providers:

  * :func:`synthetic_dataset` — wraps ``data/corpus.py``'s
    :class:`SyntheticRetrievalCorpus` (the offline stand-ins for the
    paper's BEIR/LoTTe/Japanese mix);
  * :func:`load_beir` — the standard BEIR directory layout
    (``corpus.jsonl`` + ``queries.jsonl`` + ``qrels/<split>.tsv``), so
    a real downloaded corpus drops into the same sweep unchanged: text
    is tokenized with the repo's deterministic
    :class:`~repro.data.tokenizer.HashTokenizer` (or any pretrained
    tokenizer passed as ``tokenize=``), string doc ids map to dense
    integer rows, and the qrels come back as the same graded
    per-query dicts the synthetic provider emits.

A dataset is plain data — token matrices and qrel dicts — so the sweep
and :meth:`repro.Retriever.evaluate` never care where it came from.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.data.corpus import (DATASET_SPECS, DatasetSpec,
                               SyntheticRetrievalCorpus)


@dataclass
class EvalDataset:
    """Graded-relevance retrieval evaluation data, tokenized.

    ``qrels[i]`` maps doc id (row index into ``doc_tokens``) to a
    graded relevance for query i — the structure every metric in
    ``repro.eval.metrics`` consumes.
    """
    name: str
    doc_tokens: np.ndarray                 # [N, L] int32
    query_tokens: np.ndarray               # [Nq, Lq] int32
    qrels: List[Dict[int, int]]
    meta: Dict[str, object] = field(default_factory=dict)

    def __post_init__(self):
        self.doc_tokens = np.asarray(self.doc_tokens, np.int32)
        self.query_tokens = np.asarray(self.query_tokens, np.int32)
        if self.query_tokens.shape[0] != len(self.qrels):
            raise ValueError(
                f"{self.query_tokens.shape[0]} queries but "
                f"{len(self.qrels)} qrel entries")
        n = self.doc_tokens.shape[0]
        for i, q in enumerate(self.qrels):
            for d in q:
                if not 0 <= int(d) < n:
                    raise ValueError(f"qrel {i} references doc {d} "
                                     f"outside [0, {n})")

    @property
    def n_docs(self) -> int:
        return int(self.doc_tokens.shape[0])

    @property
    def n_queries(self) -> int:
        return int(self.query_tokens.shape[0])

    def padded_qrels(self):
        from repro.eval.metrics import PaddedQrels
        return PaddedQrels.from_dicts(self.qrels)


# ---------------------------------------------------------------------------
# Provider: synthetic corpora (the offline default)
# ---------------------------------------------------------------------------
def synthetic_dataset(spec: Union[str, DatasetSpec],
                      vocab_size: int,
                      doc_maxlen: int,
                      query_maxlen: int,
                      n_docs: Optional[int] = None,
                      n_queries: Optional[int] = None,
                      seed: Optional[int] = None) -> EvalDataset:
    """An :class:`EvalDataset` from a named ``DATASET_SPECS`` entry or
    an explicit :class:`DatasetSpec`; ``n_docs``/``n_queries``/``seed``
    override the spec (benchmark wall-time scaling). A name not in
    ``DATASET_SPECS`` makes a fresh default-parameter spec — handy for
    throwaway smoke corpora."""
    if isinstance(spec, str):
        spec = DATASET_SPECS.get(spec) or DatasetSpec(name=spec)
    over = {}
    if n_docs is not None:
        over["n_docs"] = int(n_docs)
    if n_queries is not None:
        over["n_queries"] = int(n_queries)
    if seed is not None:
        over["seed"] = int(seed)
    if over:
        from dataclasses import replace
        spec = replace(spec, **over)
    corpus = SyntheticRetrievalCorpus(spec, vocab_size=vocab_size)
    return from_corpus(corpus, doc_maxlen, query_maxlen)


def from_corpus(corpus: SyntheticRetrievalCorpus, doc_maxlen: int,
                query_maxlen: int) -> EvalDataset:
    """Wrap an already-constructed synthetic corpus (the old
    ``evaluate_pooling`` input shape)."""
    return EvalDataset(
        name=corpus.spec.name,
        doc_tokens=corpus.doc_token_batch(doc_maxlen),
        query_tokens=corpus.query_token_batch(query_maxlen),
        qrels=[dict(q) for q in corpus.qrels],
        meta={"provider": "synthetic", "seed": corpus.spec.seed,
              "n_topics": corpus.spec.n_topics})


# ---------------------------------------------------------------------------
# Provider: BEIR directory layout
# ---------------------------------------------------------------------------
def _read_jsonl(path: str):
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                yield json.loads(line)


def load_beir(root: str, doc_maxlen: int, query_maxlen: int,
              split: str = "test",
              tokenize: Optional[Callable[[str, int],
                                          Sequence[int]]] = None,
              vocab_size: int = 30522,
              max_docs: Optional[int] = None,
              name: Optional[str] = None) -> EvalDataset:
    """Load a BEIR-format dataset directory.

    Expected layout (what ``beir.util.download_and_unzip`` produces)::

        root/corpus.jsonl     {"_id": str, "title": str, "text": str}
        root/queries.jsonl    {"_id": str, "text": str}
        root/qrels/<split>.tsv   query-id <TAB> corpus-id <TAB> score

    Only queries that appear in the qrels file are kept (the BEIR
    convention — unjudged queries score nothing). ``tokenize(text,
    max_len) -> token ids`` defaults to the repo's deterministic
    :class:`HashTokenizer`; pass a pretrained tokenizer's encode for a
    real model. ``max_docs`` truncates the corpus for smoke runs —
    qrels pointing past the cut are dropped (and queries left with no
    judgments dropped with them).
    """
    corpus_path = os.path.join(root, "corpus.jsonl")
    queries_path = os.path.join(root, "queries.jsonl")
    qrels_path = os.path.join(root, "qrels", f"{split}.tsv")
    for p in (corpus_path, queries_path, qrels_path):
        if not os.path.isfile(p):
            raise FileNotFoundError(f"BEIR layout missing {p}")

    if tokenize is None:
        from repro.data.tokenizer import HashTokenizer
        tok = HashTokenizer(vocab_size=vocab_size)
        tokenize = tok.encode

    doc_row: Dict[str, int] = {}
    doc_ids_list: List[np.ndarray] = []
    for rec in _read_jsonl(corpus_path):
        if max_docs is not None and len(doc_ids_list) >= max_docs:
            break
        text = " ".join(t for t in (rec.get("title", ""),
                                    rec.get("text", "")) if t)
        doc_row[str(rec["_id"])] = len(doc_ids_list)
        doc_ids_list.append(np.asarray(tokenize(text, doc_maxlen),
                                       np.int32))

    # qrels: query-id -> {doc row: graded score}
    per_query: Dict[str, Dict[int, int]] = {}
    with open(qrels_path) as fh:
        for ln, line in enumerate(fh):
            parts = line.rstrip("\n").split("\t")
            if len(parts) < 3 or (ln == 0 and parts[-1].lower()
                                  in ("score", "rel", "relevance")):
                continue                        # header / blank
            qid, did, score = parts[0], parts[1], parts[2]
            row = doc_row.get(did)
            if row is None:                     # doc beyond max_docs cut
                continue
            per_query.setdefault(qid, {})[row] = int(float(score))

    q_tokens: List[np.ndarray] = []
    qrels: List[Dict[int, int]] = []
    kept_qids: List[str] = []
    for rec in _read_jsonl(queries_path):
        qid = str(rec["_id"])
        judged = per_query.get(qid)
        if not judged:
            continue
        q_tokens.append(np.asarray(tokenize(rec["text"], query_maxlen),
                                   np.int32))
        qrels.append(judged)
        kept_qids.append(qid)
    if not q_tokens:
        raise ValueError(f"no judged queries in {qrels_path}")

    def pad(rows: List[np.ndarray], width: int) -> np.ndarray:
        out = np.zeros((len(rows), width), np.int32)
        for i, r in enumerate(rows):
            k = min(len(r), width)
            out[i, :k] = r[:k]
        return out

    return EvalDataset(
        name=name or os.path.basename(os.path.normpath(root)),
        doc_tokens=pad(doc_ids_list, doc_maxlen),
        query_tokens=pad(q_tokens, query_maxlen),
        qrels=qrels,
        meta={"provider": "beir", "split": split, "root": root,
              "query_ids": kept_qids})
