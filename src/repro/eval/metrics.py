"""Batched, jit-friendly ranking metrics over ``[Nq, k]`` ranked-id
matrices — the shape ``search_batch`` hands back off the device.

The seed's ``retrieval/metrics.py`` loops queries in Python and looks
ranked ids up in per-query dicts; fine for 64 queries, hopeless next to
a serving engine that answers thousands per second. This module keeps
those pure-numpy formulas as THE reference (tests pin against them) and
reimplements each metric as one vectorized program:

  * qrels are packed once into a :class:`PaddedQrels` pair of
    ``[Nq, R]`` id/gain matrices (pad id -1, pad gain 0 — a pad can
    match a ranked -1 pad but contributes zero gain, so padding is
    harmless by construction);
  * the per-(query, rank) relevance lookup is a jitted equality-matmul
    (``ranked[:, :, None] == ids[:, None, :]`` contracted against the
    gain matrix) — integer work, bitwise-equal to the dict lookups;
  * the metric itself (nDCG@k / Recall@k / Success@k / MRR@k) is a
    masked vectorized reduction over the resulting gain matrix.

Every metric returns the mean over *scored* queries only, matching the
reference's skip conventions exactly: nDCG/Success/MRR skip queries
with an EMPTY qrel dict (a judged-all-irrelevant query still scores 0),
Recall skips queries with no positive-gain entry.

Metric names parse as ``"<metric>@<k>"`` (``metric_fn("ndcg@10")``), so
a sweep config names its metrics as strings, the way the paper's tables
do (NDCG@10 for BEIR, Success@5 for LoTTe, Recall@5 for the Japanese
suite, plus MRR@10).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

METRIC_NAMES = ("ndcg", "recall", "success", "mrr")

# the sweep's default metric set: the paper's three + MRR@10
DEFAULT_METRICS = ("ndcg@10", "recall@5", "success@5", "mrr@10")


# ---------------------------------------------------------------------------
# Qrel packing
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PaddedQrels:
    """Graded qrels as fixed-shape matrices the jitted metrics consume.

    ``ids[i]`` holds query i's judged doc ids (pad -1), ``gains[i]``
    the graded relevance of each (pad 0). ``judged[i]`` is True when
    query i has ANY judgment — the reference metrics' skip mask.
    """
    ids: np.ndarray        # [Nq, R] int32, pad = -1
    gains: np.ndarray      # [Nq, R] int32, pad = 0
    judged: np.ndarray     # [Nq] bool — at least one qrel entry

    @classmethod
    def from_dicts(cls, qrels: Sequence[Dict[int, int]]) -> "PaddedQrels":
        R = max((len(q) for q in qrels), default=0)
        R = max(R, 1)                       # keep shapes non-degenerate
        n = len(qrels)
        ids = np.full((n, R), -1, np.int32)
        gains = np.zeros((n, R), np.int32)
        judged = np.zeros(n, bool)
        for i, q in enumerate(qrels):
            judged[i] = len(q) > 0
            for j, (d, g) in enumerate(q.items()):
                ids[i, j] = int(d)
                gains[i, j] = int(g)
        return cls(ids=ids, gains=gains, judged=judged)

    @classmethod
    def coerce(cls, qrels) -> "PaddedQrels":
        if isinstance(qrels, cls):
            return qrels
        return cls.from_dicts(qrels)

    @property
    def n_queries(self) -> int:
        return self.ids.shape[0]

    @property
    def has_positive(self) -> np.ndarray:
        """[Nq] bool — any positive-gain judgment (Recall's skip mask)."""
        return (self.gains > 0).any(axis=1)


# ---------------------------------------------------------------------------
# Device kernels
# ---------------------------------------------------------------------------
@jax.jit
def _gain_matrix(ranked, qids, qgains):
    """[Nq, k] int32 gain of each ranked doc (0 when unjudged).

    Pure integer work: equality match of ranked ids against each
    query's judged ids, contracted with the gain matrix. A ranked pad
    (-1) can only match a qrel pad (-1), whose gain is 0 — so pads
    contribute nothing on either side. Bitwise-equal to the reference's
    ``qrel.get(int(d), 0)`` loop.
    """
    match = ranked[:, :, None] == qids[:, None, :]
    return jnp.sum(jnp.where(match, qgains[:, None, :], 0), axis=-1)


@functools.partial(jax.jit, static_argnames=("k",))
def _ndcg_device(ranked, qids, qgains, k: int):
    """Per-query nDCG@k values [Nq] f32 (0 where IDCG == 0)."""
    g = _gain_matrix(ranked[:, :k], qids, qgains).astype(jnp.float32)
    kk = g.shape[1]
    disc = 1.0 / jnp.log2(jnp.arange(2, kk + 2, dtype=jnp.float32))
    dcg = jnp.sum((jnp.exp2(g) - 1.0) * disc[None, :], axis=1)
    ideal = -jnp.sort(-qgains.astype(jnp.float32), axis=1)[:, :k]
    ik = ideal.shape[1]
    idisc = 1.0 / jnp.log2(jnp.arange(2, ik + 2, dtype=jnp.float32))
    idcg = jnp.sum((jnp.exp2(ideal) - 1.0) * idisc[None, :], axis=1)
    return jnp.where(idcg > 0, dcg / jnp.maximum(idcg, 1e-30), 0.0)


@functools.partial(jax.jit, static_argnames=("k",))
def _recall_device(ranked, qids, qgains, k: int):
    """Per-query Recall@k [Nq] f32 (0 where no positive judgment)."""
    g = _gain_matrix(ranked[:, :k], qids, qgains)
    hits = jnp.sum((g > 0).astype(jnp.int32), axis=1)
    n_rel = jnp.sum((qgains > 0).astype(jnp.int32), axis=1)
    return jnp.where(n_rel > 0,
                     hits.astype(jnp.float32)
                     / jnp.maximum(n_rel, 1).astype(jnp.float32), 0.0)


@functools.partial(jax.jit, static_argnames=("k",))
def _success_device(ranked, qids, qgains, k: int):
    """Per-query Success@k [Nq] f32 — 1.0 iff a positive doc ranks."""
    g = _gain_matrix(ranked[:, :k], qids, qgains)
    return jnp.any(g > 0, axis=1).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("k",))
def _first_hit_rank(ranked, qids, qgains, k: int):
    """[Nq] int32 — 1-based rank of the first positive-gain doc in the
    top k, 0 when none ranks. The integer core of MRR (bitwise-pinned
    in tests; the reciprocal is the only float step)."""
    g = _gain_matrix(ranked[:, :k], qids, qgains)
    kk = g.shape[1]
    pos = jnp.arange(1, kk + 1, dtype=jnp.int32)
    ranks = jnp.where(g > 0, pos[None, :], kk + 1)
    first = jnp.min(ranks, axis=1)
    return jnp.where(first > kk, 0, first)


@functools.partial(jax.jit, static_argnames=("k",))
def _mrr_device(ranked, qids, qgains, k: int):
    first = _first_hit_rank(ranked, qids, qgains, k)
    return jnp.where(first > 0,
                     1.0 / jnp.maximum(first, 1).astype(jnp.float32), 0.0)


_DEVICE_FNS = {"ndcg": _ndcg_device, "recall": _recall_device,
               "success": _success_device, "mrr": _mrr_device}


# ---------------------------------------------------------------------------
# Public surface
# ---------------------------------------------------------------------------
def ranked_gains(ranked_ids, qrels) -> np.ndarray:
    """[Nq, k] int32 graded gain of every ranked doc — the device
    relevance lookup on its own (tests pin it bitwise against the
    reference's per-query dict walk)."""
    q = PaddedQrels.coerce(qrels)
    ranked = jnp.asarray(np.asarray(ranked_ids), jnp.int32)
    return np.asarray(_gain_matrix(ranked, jnp.asarray(q.ids),
                                   jnp.asarray(q.gains)))


def first_hit_ranks(ranked_ids, qrels, k: int = 10) -> np.ndarray:
    """[Nq] int32 1-based rank of each query's first relevant hit in
    the top k (0 = miss) — MRR's integer core."""
    q = PaddedQrels.coerce(qrels)
    ranked = jnp.asarray(np.asarray(ranked_ids), jnp.int32)
    return np.asarray(_first_hit_rank(ranked, jnp.asarray(q.ids),
                                      jnp.asarray(q.gains), k))


def per_query_values(name: str, ranked_ids, qrels,
                     k: int) -> Tuple[np.ndarray, np.ndarray]:
    """(values [Nq] f32, scored [Nq] bool) for one metric — the device
    computation plus the reference's skip mask, before averaging."""
    if name not in _DEVICE_FNS:
        raise KeyError(f"unknown metric {name!r}; known: {METRIC_NAMES}")
    q = PaddedQrels.coerce(qrels)
    ranked = jnp.asarray(np.asarray(ranked_ids), jnp.int32)
    vals = np.asarray(_DEVICE_FNS[name](ranked, jnp.asarray(q.ids),
                                        jnp.asarray(q.gains), int(k)))
    scored = q.has_positive if name == "recall" else q.judged
    return vals, scored


def _mean_scored(vals: np.ndarray, scored: np.ndarray) -> float:
    if not scored.any():
        return 0.0
    return float(np.mean(vals[scored].astype(np.float64)))


def ndcg_at_k(ranked_ids, qrels, k: int = 10) -> float:
    """Mean nDCG@k (log2 discount, exponential gains) over judged
    queries, from a [Nq, >=k] ranked-id matrix (-1 pads ignored)."""
    return _mean_scored(*per_query_values("ndcg", ranked_ids, qrels, k))


def recall_at_k(ranked_ids, qrels, k: int = 5) -> float:
    """Mean fraction of each query's positive docs in the top k."""
    return _mean_scored(*per_query_values("recall", ranked_ids, qrels, k))


def success_at_k(ranked_ids, qrels, k: int = 5) -> float:
    """Fraction of judged queries with >= 1 positive doc in the top k."""
    return _mean_scored(*per_query_values("success", ranked_ids, qrels, k))


def mrr_at_k(ranked_ids, qrels, k: int = 10) -> float:
    """Mean reciprocal rank of the first positive doc in the top k."""
    return _mean_scored(*per_query_values("mrr", ranked_ids, qrels, k))


def parse_metric(name: str) -> Tuple[str, int]:
    """``"ndcg@10"`` -> ``("ndcg", 10)`` with validation."""
    try:
        base, k = name.split("@")
        k = int(k)
    except ValueError:
        raise ValueError(f"metric name must look like 'ndcg@10', "
                         f"got {name!r}")
    if base not in METRIC_NAMES or k < 1:
        raise ValueError(f"unknown metric {name!r}; known bases: "
                         f"{METRIC_NAMES}")
    return base, k


def metric_fn(name: str):
    """Resolve ``"<metric>@<k>"`` to ``fn(ranked_ids, qrels) -> float``."""
    base, k = parse_metric(name)
    def run(ranked_ids, qrels, _base=base, _k=k):
        return _mean_scored(
            *per_query_values(_base, ranked_ids, qrels, _k))
    run.__name__ = name.replace("@", "_at_")
    return run


def compute_metrics(ranked_ids, qrels,
                    names: Sequence[str]) -> Dict[str, float]:
    """All requested metrics from ONE ranked-id matrix; the qrels are
    packed once and the [Nq, k] matrix is reused across metrics."""
    q = PaddedQrels.coerce(qrels)
    return {name: metric_fn(name)(ranked_ids, q) for name in names}


def max_k(names: Sequence[str]) -> int:
    """The ranked depth one search must return to score all ``names``."""
    return max((parse_metric(n)[1] for n in names), default=10)


def rankings_matrix(rankings: List[Sequence[int]], k: int) -> np.ndarray:
    """Ragged per-query id lists -> the [Nq, k] -1-padded matrix the
    batched metrics consume (the inverse of ``Searcher.rankings``)."""
    out = np.full((len(rankings), k), -1, np.int64)
    for i, row in enumerate(rankings):
        row = list(row)[:k]
        out[i, :len(row)] = row
    return out
