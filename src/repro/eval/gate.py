"""Regression gate: does pooling still sit inside the paper's envelope?

Two checks, both over a :class:`~repro.eval.report.QualityReport`:

  * :func:`check_envelope` — the paper's quality claim as an
    assertion: factor-2 pooling keeps >= ``min_relative`` (default 95)
    of the unpooled metric ("50% reduction with virtually no
    degradation"; factors 3-4 sit inside ~5%). Any cell of the checked
    (method, factor) set below its floor is a failure.
  * :func:`check_regression` — cell-by-cell comparison against a
    PINNED baseline report (a committed ``BENCH_quality.json``
    section): a cell whose relative metric drops more than
    ``tolerance`` points below the pinned value fails. The tolerance
    absorbs cross-machine float drift; on the box that wrote the pin,
    the sweep is deterministic and reproduces it exactly.

``run_gate`` combines both into one :class:`GateResult`; the
``quality-smoke`` CI job fails on ``ok == False``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.eval.report import QualityReport, read_bench_section

# The paper's envelope, by pooling factor: relative nDCG@10 floors.
# Factor 2 is the headline claim ("virtually no performance
# degradation"); 3 and 4 are the "<5% of performance" regime with a
# small allowance for the synthetic-corpus stand-ins.
PAPER_ENVELOPE = {2: 95.0, 3: 92.0, 4: 90.0}


@dataclass
class GateResult:
    ok: bool
    failures: List[str] = field(default_factory=list)
    checked: int = 0

    def __bool__(self) -> bool:
        return self.ok

    def summary(self) -> str:
        head = ("PASS" if self.ok else "FAIL") + \
            f" ({self.checked} checks"
        head += ")" if self.ok else f", {len(self.failures)} failures)"
        return "\n".join([head] + [f"  - {f}" for f in self.failures])


def check_envelope(report: QualityReport, metric: str = "ndcg@10",
                   envelope: Optional[dict] = None,
                   methods: Optional[Sequence[str]] = None,
                   min_relative: Optional[float] = None
                   ) -> GateResult:
    """Fail any cell whose relative ``metric`` falls below the paper
    envelope for its factor. ``methods`` restricts the check (the
    envelope is the paper's claim about hierarchical pooling; a CI
    smoke may gate ward only). ``min_relative`` overrides the factor-2
    floor alone — the headline gate."""
    env = dict(envelope if envelope is not None else PAPER_ENVELOPE)
    if min_relative is not None:
        env[2] = float(min_relative)
    failures, checked = [], 0
    for c in report.cells:
        if c.factor not in env or metric not in c.relative:
            continue
        if methods is not None and c.method not in methods:
            continue
        checked += 1
        floor = float(env[c.factor])
        if c.relative[metric] < floor:
            failures.append(
                f"envelope: {report.dataset} {c.backend} {c.method} "
                f"f={c.factor}"
                + (f" {c.quant_bits}b" if c.quant_bits else "")
                + f" relative {metric} {c.relative[metric]:.2f} "
                  f"< floor {floor:.2f}")
    if checked == 0:
        failures.append(f"envelope: no cells to check (metric "
                        f"{metric!r}, factors {sorted(env)})")
    return GateResult(ok=not failures, failures=failures, checked=checked)


def check_regression(report: QualityReport, pinned: QualityReport,
                     metric: str = "ndcg@10",
                     tolerance: float = 2.0) -> GateResult:
    """Fail any cell whose relative ``metric`` sits more than
    ``tolerance`` points BELOW the pinned report's value for the same
    (backend, method, factor, quant_bits). Cells absent from the pin
    are skipped (a grown grid is not a regression); improvements never
    fail."""
    failures, checked = [], 0
    for c in report.cells:
        p = pinned.cell(c.backend, c.method, c.factor, c.quant_bits)
        if p is None or metric not in c.relative \
                or metric not in p.relative:
            continue
        checked += 1
        drop = p.relative[metric] - c.relative[metric]
        if drop > float(tolerance):
            failures.append(
                f"regression: {report.dataset} {c.backend} {c.method} "
                f"f={c.factor}"
                + (f" {c.quant_bits}b" if c.quant_bits else "")
                + f" relative {metric} {c.relative[metric]:.2f} vs "
                  f"pinned {p.relative[metric]:.2f} "
                  f"(drop {drop:.2f} > tol {tolerance:.2f})")
    if checked == 0:
        failures.append("regression: no overlapping cells between the "
                        "report and the pinned baseline")
    return GateResult(ok=not failures, failures=failures, checked=checked)


def run_gate(report: QualityReport, metric: str = "ndcg@10",
             baseline_path: Optional[str] = None,
             baseline_section: str = "quality_sweep",
             envelope: Optional[dict] = None,
             methods: Optional[Sequence[str]] = None,
             min_relative: Optional[float] = None,
             tolerance: float = 2.0) -> GateResult:
    """Envelope check + (when ``baseline_path`` names a pinned
    ``BENCH_quality.json``) the regression check, folded into one
    result."""
    res = check_envelope(report, metric=metric, envelope=envelope,
                         methods=methods, min_relative=min_relative)
    failures, checked = list(res.failures), res.checked
    if baseline_path is not None:
        pinned = read_bench_section(baseline_path, baseline_section)
        if not isinstance(pinned, QualityReport):
            raise ValueError(
                f"{baseline_path}:{baseline_section} is not a quality "
                f"report")
        reg = check_regression(report, pinned, metric=metric,
                               tolerance=tolerance)
        failures.extend(reg.failures)
        checked += reg.checked
    return GateResult(ok=not failures, failures=failures, checked=checked)
