"""Quality-evaluation subsystem.

BEIR-style datasets (:mod:`repro.eval.datasets`), batched
device-friendly metrics (:mod:`repro.eval.metrics`), the grid sweep
driving the public ``repro.Retriever`` facade
(:mod:`repro.eval.sweep`), the JSON/markdown report artifact
(:mod:`repro.eval.report`) and the paper-envelope regression gate
(:mod:`repro.eval.gate`).
"""
from repro.eval.datasets import (EvalDataset, from_corpus, load_beir,
                                 synthetic_dataset)
from repro.eval.gate import (GateResult, PAPER_ENVELOPE, check_envelope,
                             check_regression, run_gate)
from repro.eval.metrics import (DEFAULT_METRICS, PaddedQrels,
                                compute_metrics, first_hit_ranks,
                                metric_fn, mrr_at_k, ndcg_at_k,
                                parse_metric, ranked_gains,
                                rankings_matrix, recall_at_k,
                                success_at_k)
from repro.eval.report import (BENCH_QUALITY_FILE, QualityBaseline,
                               QualityCell, QualityReport,
                               read_bench_section, write_bench_section)
from repro.eval.sweep import (QualitySweep, relative_performance)

__all__ = [
    "BENCH_QUALITY_FILE",
    "DEFAULT_METRICS",
    "EvalDataset",
    "GateResult",
    "PAPER_ENVELOPE",
    "PaddedQrels",
    "QualityBaseline",
    "QualityCell",
    "QualityReport",
    "QualitySweep",
    "check_envelope",
    "check_regression",
    "compute_metrics",
    "first_hit_ranks",
    "from_corpus",
    "load_beir",
    "metric_fn",
    "mrr_at_k",
    "ndcg_at_k",
    "parse_metric",
    "ranked_gains",
    "rankings_matrix",
    "read_bench_section",
    "recall_at_k",
    "relative_performance",
    "run_gate",
    "success_at_k",
    "synthetic_dataset",
    "write_bench_section",
]
