"""DimeNet [arXiv:2003.03123]: n_blocks=6 d_hidden=128 n_bilinear=8
n_spherical=7 n_radial=6."""
from repro.configs.base import DimeNetConfig

CONFIG = DimeNetConfig(
    name="dimenet",
    n_blocks=6,
    d_hidden=128,
    n_bilinear=8,
    n_spherical=7,
    n_radial=6,
)

SMOKE = DimeNetConfig(
    name="dimenet-smoke",
    n_blocks=2,
    d_hidden=32,
    n_bilinear=4,
    n_spherical=3,
    n_radial=4,
    triplet_cap=4,
)
