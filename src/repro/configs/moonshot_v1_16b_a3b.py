"""kimi/moonlight 16B-A3B MoE [hf:moonshotai/Moonlight-16B-A3B; hf].

48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6.
"""
from repro.configs.base import TransformerConfig

CONFIG = TransformerConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    moe=True,
    n_experts=64,
    top_k=6,
    moe_d_ff=1408,
    rope_theta=1_000_000.0,
    attn_shard="heads",           # 16 % 16 == 0
    optimizer="adamw",
    train_microbatches=4,
)

SMOKE = TransformerConfig(
    name="moonshot-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=80,
    vocab_size=512,
    moe=True,
    n_experts=4,
    top_k=2,
    moe_d_ff=80,
    remat=False,
    attn_full_threshold=4096,
    max_seq_len=128,
)
