"""Factorization Machine [ICDM'10 Rendle]: n_sparse=39 embed_dim=10,
pairwise <v_i, v_j> x_i x_j via the O(nk) sum-square trick."""
from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="fm",
    kind="fm",
    n_sparse=39,
    embed_dim=10,
    interaction="fm-2way",
    vocab_sizes=tuple([1_000_000] * 39),
)

SMOKE = RecsysConfig(
    name="fm-smoke",
    kind="fm",
    n_sparse=5,
    embed_dim=6,
    interaction="fm-2way",
    vocab_sizes=tuple([100] * 5),
)
