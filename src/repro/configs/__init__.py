"""Architecture registry: ``get_config(arch)`` / ``get_smoke_config(arch)``.

The 10 assigned architectures plus the paper's own ColBERT configs.
"""
from __future__ import annotations

import importlib

_MODULES = {
    # LM family (5)
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "qwen1.5-0.5b": "repro.configs.qwen1_5_0_5b",
    # GNN (1)
    "dimenet": "repro.configs.dimenet",
    # RecSys (4)
    "wide-deep": "repro.configs.wide_deep",
    "deepfm": "repro.configs.deepfm",
    "fm": "repro.configs.fm",
    "dlrm-rm2": "repro.configs.dlrm_rm2",
    # The paper's own models (extra cells, not part of the assigned 40)
    "colbertv2": "repro.configs.colbertv2",
}

ASSIGNED_ARCHS = [
    "kimi-k2-1t-a32b", "moonshot-v1-16b-a3b", "qwen2.5-14b",
    "qwen3-0.6b", "qwen1.5-0.5b",
    "dimenet",
    "wide-deep", "deepfm", "fm", "dlrm-rm2",
]

ALL_ARCHS = ASSIGNED_ARCHS + ["colbertv2"]


def get_config(arch: str):
    mod = importlib.import_module(_MODULES[arch])
    return mod.CONFIG


def get_smoke_config(arch: str):
    mod = importlib.import_module(_MODULES[arch])
    return mod.SMOKE


def get_ja_config():
    mod = importlib.import_module(_MODULES["colbertv2"])
    return mod.JA_CONFIG
