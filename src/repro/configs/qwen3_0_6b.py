"""Qwen3-0.6B dense, qk_norm, GQA [hf:Qwen/Qwen3 family; hf].

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.
"""
from repro.configs.base import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen3-0.6b",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    attn_shard="heads",           # 16 % 16 == 0
    optimizer="adamw",
)

SMOKE = TransformerConfig(
    name="qwen3-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=512,
    qk_norm=True,
    remat=False,
    attn_full_threshold=4096,
    max_seq_len=128,
)
