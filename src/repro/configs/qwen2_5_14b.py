"""Qwen2.5-14B dense, GQA, QKV bias [hf:Qwen/Qwen2.5 family; hf].

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
H=40 does not divide tp=16 -> sequence-parallel attention sharding.
"""
from repro.configs.base import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen2.5-14b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    attn_shard="sequence",        # 40 % 16 != 0
    optimizer="adamw",
    train_microbatches=4,
)

SMOKE = TransformerConfig(
    name="qwen2.5-smoke",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    qkv_bias=True,
    remat=False,
    attn_full_threshold=4096,
    max_seq_len=128,
)
