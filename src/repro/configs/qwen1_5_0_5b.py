"""Qwen1.5-0.5B dense, QKV bias, MHA [hf:Qwen/Qwen1.5-0.5B; hf].

24L d_model=1024 16H (kv=16 -> MHA) d_ff=2816 vocab=151936.
"""
from repro.configs.base import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen1.5-0.5b",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    attn_shard="heads",
    optimizer="adamw",
)

SMOKE = TransformerConfig(
    name="qwen1.5-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=88,
    vocab_size=512,
    qkv_bias=True,
    remat=False,
    attn_full_threshold=4096,
    max_seq_len=128,
)
