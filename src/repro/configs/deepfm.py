"""DeepFM [arXiv:1703.04247]: n_sparse=39 embed_dim=10 mlp=400-400-400
interaction=fm."""
from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="deepfm",
    kind="deepfm",
    n_sparse=39,
    embed_dim=10,
    mlp_dims=(400, 400, 400),
    interaction="fm",
    vocab_sizes=tuple([1_000_000] * 39),
)

SMOKE = RecsysConfig(
    name="deepfm-smoke",
    kind="deepfm",
    n_sparse=5,
    embed_dim=6,
    mlp_dims=(24, 24),
    interaction="fm",
    vocab_sizes=tuple([100] * 5),
)
