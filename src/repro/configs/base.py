"""Config dataclasses for every architecture family in the framework.

Configs are plain frozen dataclasses — hashable so they can be closed over by
jitted functions, serializable to dicts for checkpoints/manifests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple


def asdict(cfg) -> dict:
    return dataclasses.asdict(cfg)


# ---------------------------------------------------------------------------
# LM transformers (dense + MoE) — also the ColBERT encoder trunk
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                    # 0 -> d_model // n_heads

    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                  # per-expert FFN width (d_ff if 0)
    n_shared_experts: int = 0
    first_dense_layers: int = 0        # leading dense layers before MoE stack
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01      # load-balance loss coefficient
    moe_impl: str = "capacity"         # "capacity" | "ep" (shard_map
                                       # all-to-all) | "dense" (oracle)

    # --- attention flavour ---
    causal: bool = True
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    pos_emb: str = "rope"              # "rope" | "learned" | "none"
    attn_chunk: int = 1024             # kv/q chunk for online-softmax attention
    attn_full_threshold: int = 2048    # use plain attention below this seq len
    use_flash_kernel: bool = False     # dispatch the Pallas kernel (TPU;
                                       # interpret=True on CPU — slow, tests only)

    # --- mlp / norm ---
    gated_mlp: bool = True             # SwiGLU-style
    act: str = "silu"
    norm: str = "rmsnorm"              # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- execution ---
    max_seq_len: int = 32768
    dtype: str = "bfloat16"            # compute dtype
    param_dtype: str = "float32"
    scan_layers: bool = True
    remat: bool = True
    logits_chunk: int = 1024           # seq-chunking of the xent loss

    # --- sharding hints ---
    attn_shard: str = "heads"          # "heads" | "sequence" (when H % tp != 0)
    optimizer: str = "adamw"           # "adamw" | "adafactor"
    fsdp_params: bool = True           # ZeRO-3: shard weights on data axis too
    train_microbatches: int = 1        # grad-accumulation inside train_step
    grad_accum_dtype: str = "float32"  # bf16 halves the accumulator for 1T
    # Dry-run analysis mode: fully unroll lax.scan loops so XLA
    # cost_analysis counts every iteration (while-loop bodies are otherwise
    # counted ONCE — roofline flops would be ~L x under-reported).
    unroll_scans: bool = False

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.moe and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        """Analytic parameter count (used by roofline MODEL_FLOPS)."""
        d, dh, H, KV = self.d_model, self.d_head, self.n_heads, self.n_kv_heads
        attn = d * (H * dh) * 2 + d * (KV * dh) * 2          # q,o + k,v
        if self.qkv_bias:
            attn += (H + 2 * KV) * dh
        dense_ffn = d * self.d_ff * (3 if self.gated_mlp else 2)
        n_moe = max(self.n_layers - self.first_dense_layers, 0) if self.moe else 0
        n_dense = self.n_layers - n_moe
        total = n_dense * (attn + dense_ffn)
        if self.moe:
            expert = d * self.moe_d_ff * (3 if self.gated_mlp else 2)
            router = d * self.n_experts
            shared = self.n_shared_experts * expert
            total += n_moe * (attn + self.n_experts * expert + router + shared)
        total += 2 * self.n_layers * d                        # norms
        total += self.vocab_size * d                          # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d                      # lm head
        if self.pos_emb == "learned":
            total += self.max_seq_len * d
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top_k + shared only)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        expert = d * self.moe_d_ff * (3 if self.gated_mlp else 2)
        n_moe = max(self.n_layers - self.first_dense_layers, 0)
        inactive = n_moe * (self.n_experts - self.top_k) * expert
        return self.param_count() - inactive


# ---------------------------------------------------------------------------
# ColBERT retrieval head on top of a TransformerConfig trunk
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ColbertConfig:
    name: str
    trunk: TransformerConfig
    proj_dim: int = 128
    doc_maxlen: int = 256
    query_maxlen: int = 32
    mask_punctuation: bool = True
    # Token pooling (the paper's technique) applied at indexing time:
    pool_method: str = "ward"          # "ward" | "kmeans" | "sequential" | "none"
    pool_factor: int = 1               # 1 = no pooling
    # Index backend
    index_backend: str = "plaid"       # "flat" | "hnsw" | "plaid"
    quant_bits: int = 2                # PLAID residual bits (2 or 4)
    n_centroids: int = 256             # IVF centroids
    nprobe: int = 8
    t_cs: float = 0.3                  # centroid score pruning threshold
    ndocs: int = 8192                  # candidate docs fed to decompression
    maxsim_impl: str = "einsum"        # "einsum" | "blocked" (serving path)
    maxsim_block: int = 512            # docs per block in the blocked path


# ---------------------------------------------------------------------------
# GNN — DimeNet
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class DimeNetConfig:
    name: str
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    d_feat_in: int = 0                 # input node feature dim (0 = atom types)
    n_targets: int = 1
    cutoff: float = 5.0
    envelope_exponent: int = 5
    n_atom_types: int = 95
    # triplet budget per edge (TPU fixed shapes): n_triplets = n_edges * triplet_cap
    triplet_cap: int = 8
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    optimizer: str = "adamw"
    unroll_scans: bool = False         # analysis mode (see TransformerConfig)


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                          # "wide_deep" | "deepfm" | "fm" | "dlrm"
    n_sparse: int
    embed_dim: int
    n_dense: int = 0
    vocab_sizes: Tuple[int, ...] = ()  # per-field table rows; filled by configs
    mlp_dims: Tuple[int, ...] = ()
    bot_mlp_dims: Tuple[int, ...] = ()
    top_mlp_dims: Tuple[int, ...] = ()
    interaction: str = "dot"           # "dot" | "fm" | "fm-2way" | "concat"
    multi_hot: int = 1                 # ids per sparse field (EmbeddingBag bag size)
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    optimizer: str = "adamw"

    def __post_init__(self):
        if not self.vocab_sizes:
            object.__setattr__(
                self, "vocab_sizes", tuple([1_000_000] * self.n_sparse)
            )
        assert len(self.vocab_sizes) == self.n_sparse


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeCell:
    """One (input-shape x step-kind) cell of the dry-run matrix."""
    name: str
    kind: str                          # train | prefill | decode | serve | ...
    dims: Tuple[Tuple[str, int], ...]  # ordered (name, value) pairs

    def dim(self, key: str) -> int:
        for k, v in self.dims:
            if k == key:
                return v
        raise KeyError(key)

    def get(self, key: str, default=None):
        for k, v in self.dims:
            if k == key:
                return v
        return default


LM_SHAPES = (
    ShapeCell("train_4k", "train", (("seq_len", 4096), ("global_batch", 256))),
    ShapeCell("prefill_32k", "prefill", (("seq_len", 32768), ("global_batch", 32))),
    ShapeCell("decode_32k", "decode", (("seq_len", 32768), ("global_batch", 128))),
    ShapeCell("long_500k", "decode", (("seq_len", 524288), ("global_batch", 1))),
)

GNN_SHAPES = (
    ShapeCell("full_graph_sm", "train",
              (("n_nodes", 2708), ("n_edges", 10556), ("d_feat", 1433))),
    ShapeCell("minibatch_lg", "train",
              (("n_nodes", 232965), ("n_edges", 114615892),
               ("batch_nodes", 1024), ("fanout0", 15), ("fanout1", 10))),
    ShapeCell("ogb_products", "train",
              (("n_nodes", 2449029), ("n_edges", 61859140), ("d_feat", 100))),
    ShapeCell("molecule", "train",
              (("n_nodes", 30), ("n_edges", 64), ("batch", 128))),
)

RECSYS_SHAPES = (
    ShapeCell("train_batch", "train", (("batch", 65536),)),
    ShapeCell("serve_p99", "serve", (("batch", 512),)),
    ShapeCell("serve_bulk", "serve", (("batch", 262144),)),
    ShapeCell("retrieval_cand", "serve", (("batch", 1), ("n_candidates", 1_000_000))),
)

# ColBERT's own (extra, beyond the 40 assigned cells)
COLBERT_SHAPES = (
    ShapeCell("index_build", "index", (("n_docs", 4096), ("doc_len", 256))),
    ShapeCell("search", "search",
              (("n_queries", 64), ("query_len", 32),
               ("n_docs", 65536), ("doc_len", 256))),
)


def shapes_for(cfg) -> Tuple[ShapeCell, ...]:
    if isinstance(cfg, TransformerConfig):
        return LM_SHAPES
    if isinstance(cfg, DimeNetConfig):
        return GNN_SHAPES
    if isinstance(cfg, RecsysConfig):
        return RECSYS_SHAPES
    if isinstance(cfg, ColbertConfig):
        return COLBERT_SHAPES
    raise TypeError(type(cfg))
