"""ColBERTv2 — the paper's own model [arXiv:2112.01488].

BERT-base trunk (12L/768/12H, learned positions, post-GELU MLP) + 128-d
linear projection; doc_maxlen=256, query_maxlen=32 (paper Appendix A).
JaColBERTv2 analogue (`jacolbertv2`): same trunk, doc_maxlen=300 — the
"second model / second language" generality axis of paper §4.4.
"""
from repro.configs.base import ColbertConfig, TransformerConfig

TRUNK = TransformerConfig(
    name="colbertv2-trunk",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=30522,
    causal=False,
    pos_emb="learned",
    gated_mlp=False,
    act="gelu",
    norm="layernorm",
    norm_eps=1e-12,
    max_seq_len=512,
    attn_shard="heads",
    attn_full_threshold=4096,
)

CONFIG = ColbertConfig(
    name="colbertv2",
    trunk=TRUNK,
    proj_dim=128,
    doc_maxlen=256,
    query_maxlen=32,
)

JA_TRUNK = TransformerConfig(
    name="jacolbertv2-trunk",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=32768,
    causal=False,
    pos_emb="learned",
    gated_mlp=False,
    act="gelu",
    norm="layernorm",
    norm_eps=1e-12,
    max_seq_len=512,
    attn_shard="heads",
    attn_full_threshold=4096,
)

JA_CONFIG = ColbertConfig(
    name="jacolbertv2",
    trunk=JA_TRUNK,
    proj_dim=128,
    doc_maxlen=300,
    query_maxlen=32,
)

SMOKE_TRUNK = TransformerConfig(
    name="colbert-smoke-trunk",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=1024,
    causal=False,
    pos_emb="learned",
    gated_mlp=False,
    act="gelu",
    norm="layernorm",
    remat=False,
    max_seq_len=64,
    attn_full_threshold=4096,
)

SMOKE = ColbertConfig(
    name="colbert-smoke",
    trunk=SMOKE_TRUNK,
    proj_dim=32,
    doc_maxlen=48,
    query_maxlen=8,
    n_centroids=32,
)
