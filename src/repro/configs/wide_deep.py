"""Wide & Deep [arXiv:1606.07792]: n_sparse=40 embed_dim=32
mlp=1024-512-256 interaction=concat."""
from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="wide-deep",
    kind="wide_deep",
    n_sparse=40,
    embed_dim=32,
    mlp_dims=(1024, 512, 256),
    interaction="concat",
    vocab_sizes=tuple([1_000_000] * 40),
)

SMOKE = RecsysConfig(
    name="wide-deep-smoke",
    kind="wide_deep",
    n_sparse=6,
    embed_dim=8,
    mlp_dims=(32, 16),
    interaction="concat",
    vocab_sizes=tuple([100] * 6),
)
