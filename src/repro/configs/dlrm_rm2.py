"""DLRM RM2 [arXiv:1906.00091]: n_dense=13 n_sparse=26 embed_dim=64
bot_mlp=13-512-256-64 top_mlp=512-512-256-1 interaction=dot."""
from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(
    name="dlrm-rm2",
    kind="dlrm",
    n_dense=13,
    n_sparse=26,
    embed_dim=64,
    bot_mlp_dims=(512, 256, 64),
    top_mlp_dims=(512, 512, 256, 1),
    interaction="dot",
    vocab_sizes=tuple([1_000_000] * 26),
)

SMOKE = RecsysConfig(
    name="dlrm-smoke",
    kind="dlrm",
    n_dense=4,
    n_sparse=5,
    embed_dim=8,
    bot_mlp_dims=(16, 8),
    top_mlp_dims=(16, 8, 1),
    interaction="dot",
    vocab_sizes=tuple([100] * 5),
)
