"""Kimi K2 — trillion-param MoE [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840, MoE 384e top-8.
"""
from repro.configs.base import TransformerConfig

CONFIG = TransformerConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    moe=True,
    n_experts=384,
    top_k=8,
    moe_d_ff=2048,
    rope_theta=1_000_000.0,
    attn_shard="heads",           # 64 % 16 == 0
    optimizer="adafactor",        # 1T params: factored 2nd moment or bust
    param_dtype="bfloat16",       # 1T f32 = 4TB; bf16 halves it (see DESIGN.md)
    train_microbatches=8,         # 256-batch as 8 x 32 grad-accum microbatches
    grad_accum_dtype="bfloat16",  # f32 accumulator alone would be 16GB/chip
)

# Reduced config for CPU smoke tests (same family: MoE + GQA)
SMOKE = TransformerConfig(
    name="kimi-k2-smoke",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=512,
    moe=True,
    n_experts=8,
    top_k=2,
    moe_d_ff=96,
    scan_layers=True,
    remat=False,
    attn_full_threshold=4096,
    max_seq_len=128,
)
