"""Fanout neighbor sampler (GraphSAGE-style) for the ``minibatch_lg`` cell.

Host-side: samples a fixed-fanout k-hop subgraph around a seed batch from a
CSR adjacency, emitting FIXED-SHAPE padded node/edge/triplet tensors so the
device step compiles once. This is a real sampler, not a stub — the
232k-node / 114M-edge cell is trained through it.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


class NeighborSampler:
    def __init__(self, edge_index: np.ndarray, n_nodes: int,
                 fanouts: Sequence[int], seed: int = 0):
        """edge_index: [2, E] (src, dst) — sampling walks dst -> src."""
        src, dst = np.asarray(edge_index[0]), np.asarray(edge_index[1])
        order = np.argsort(dst, kind="stable")
        self.nbr = src[order].astype(np.int64)
        counts = np.bincount(dst, minlength=n_nodes)
        self.offsets = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(counts, out=self.offsets[1:])
        self.n_nodes = n_nodes
        self.fanouts = tuple(fanouts)
        self.rng = np.random.default_rng(seed)

    def node_budget(self, batch_nodes: int) -> int:
        n = batch_nodes
        total = n
        for f in self.fanouts:
            n = n * f
            total += n
        return total

    def edge_budget(self, batch_nodes: int) -> int:
        n = batch_nodes
        total = 0
        for f in self.fanouts:
            total += n * f
            n = n * f
        return total

    def sample(self, seeds: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Returns (nodes [n_budget], edge_index [2, e_budget],
        node_mask, edge_mask). ``nodes`` are ORIGINAL graph ids; edges use
        LOCAL (subgraph) indices. Padded entries masked False.
        """
        seeds = np.asarray(seeds, np.int64)
        B = len(seeds)
        n_budget = self.node_budget(B)
        e_budget = self.edge_budget(B)
        nodes = np.zeros(n_budget, np.int64)
        node_mask = np.zeros(n_budget, bool)
        nodes[:B] = seeds
        node_mask[:B] = True
        e_src = np.zeros(e_budget, np.int32)
        e_dst = np.zeros(e_budget, np.int32)
        e_mask = np.zeros(e_budget, bool)

        frontier_lo, frontier_hi = 0, B   # local index range of current layer
        n_ptr, e_ptr = B, 0
        for f in self.fanouts:
            layer = np.arange(frontier_lo, frontier_hi)
            for local in layer:
                if not node_mask[local]:
                    n_ptr += f
                    e_ptr += f
                    continue
                g = nodes[local]
                lo, hi = self.offsets[g], self.offsets[g + 1]
                deg = hi - lo
                if deg > 0:
                    pick = self.rng.integers(lo, hi, size=f)
                    nb = self.nbr[pick]
                    k = f
                    nodes[n_ptr:n_ptr + k] = nb
                    node_mask[n_ptr:n_ptr + k] = True
                    e_src[e_ptr:e_ptr + k] = np.arange(n_ptr, n_ptr + k)
                    e_dst[e_ptr:e_ptr + k] = local
                    e_mask[e_ptr:e_ptr + k] = True
                n_ptr += f
                e_ptr += f
            frontier_lo, frontier_hi = frontier_hi, n_ptr
        return nodes, np.stack([e_src, e_dst]), node_mask, e_mask
