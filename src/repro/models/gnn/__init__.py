from repro.models.gnn.dimenet import (dimenet_forward, init_dimenet,
                                      build_triplets)
from repro.models.gnn.sampler import NeighborSampler

__all__ = ["dimenet_forward", "init_dimenet", "build_triplets",
           "NeighborSampler"]
