"""DimeNet (Gasteiger et al., 2020 [arXiv:2003.03123]): directional message
passing with radial (Bessel) and spherical (Bessel x Legendre) bases.

Kernel regime: *triplet gather* — messages live on directed edges (j->i) and
are updated from incoming edges (k->j) with an angular basis over the
(k,j,i) triplet. Not expressible as plain SpMM; implemented as gathers over
an edge-index plus ``jax.ops.segment_sum`` scatters (the JAX-native
message-passing idiom — JAX sparse is BCOO-only, so this IS the system).

TPU fixed shapes: the triplet list is precomputed host-side with a
``triplet_cap`` incoming edges per edge (padded + masked), so every step is
a dense gather/scatter of static shape.

Two task heads:
  * ``graph``  — per-atom energy contributions summed per molecule
    (the paper's QM9 setting; ``molecule`` shape cell).
  * ``node``   — per-node class logits (citation/products shape cells,
    which carry node features instead of atom types; see DESIGN.md
    §Arch-applicability for this adaptation — DimeNet needs geometry, so
    those cells supply a synthetic deterministic layout as positions).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import act_fn, dense, dt, init_dense, trunc_normal
from repro.sharding.api import constrain


# ---------------------------------------------------------------------------
# Basis functions
# ---------------------------------------------------------------------------
def spherical_bessel_roots(n_spherical: int, n_radial: int) -> np.ndarray:
    """Roots z_{l,n} of the spherical Bessel j_l, computed once on host."""
    from scipy.optimize import brentq
    from scipy.special import spherical_jn
    roots = np.zeros((n_spherical, n_radial))
    for l in range(n_spherical):
        # bracket roots by scanning; j_l's n-th root is near (n + l/2) * pi
        grid = np.linspace(l + 1e-3, (n_radial + l + 2) * np.pi, 4096)
        vals = spherical_jn(l, grid)
        found = []
        for a, b, va, vb in zip(grid[:-1], grid[1:], vals[:-1], vals[1:]):
            if va * vb < 0:
                found.append(brentq(lambda x: spherical_jn(l, x), a, b))
            if len(found) == n_radial:
                break
        roots[l] = found[:n_radial]
    return roots


def envelope(x, p: int = 5):
    """Smooth polynomial cutoff u(x) on [0, 1] (DimeNet eq. 8)."""
    a = -(p + 1) * (p + 2) / 2.0
    b = p * (p + 2.0)
    c = -p * (p + 1) / 2.0
    u = 1.0 / jnp.maximum(x, 1e-9) + a * x ** (p - 1) + b * x ** p \
        + c * x ** (p + 1)
    return jnp.where(x < 1.0, u, 0.0)


def radial_basis(d, n_radial: int, cutoff: float, p: int = 5):
    """Bessel RBF e_n(d) = sqrt(2/c) sin(n pi d / c) / d * u(d/c). [E, n]"""
    x = d / cutoff                                   # [E]
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    env = envelope(x, p)                             # [E] (includes 1/x)
    return (np.sqrt(2.0 / cutoff) * env[:, None]
            * jnp.sin(n[None, :] * jnp.pi * x[:, None]))


def _spherical_jn(l_max: int, x):
    """j_0..j_{l_max-1} via upward recurrence. x: [...] -> [..., l_max]."""
    x = jnp.maximum(x, 1e-6)
    j0 = jnp.sin(x) / x
    out = [j0]
    if l_max > 1:
        j1 = jnp.sin(x) / x ** 2 - jnp.cos(x) / x
        out.append(j1)
        for l in range(1, l_max - 1):
            out.append((2 * l + 1) / x * out[l] - out[l - 1])
    return jnp.stack(out, axis=-1)


def _legendre(l_max: int, z):
    """P_0..P_{l_max-1}(z) via recurrence. z: [...] -> [..., l_max]."""
    out = [jnp.ones_like(z)]
    if l_max > 1:
        out.append(z)
        for l in range(1, l_max - 1):
            out.append(((2 * l + 1) * z * out[l] - l * out[l - 1]) / (l + 1))
    return jnp.stack(out, axis=-1)


def spherical_basis(d, angle, roots, cutoff: float, p: int = 5):
    """a_{ln}(d, angle): [T, n_spherical * n_radial].

    d: [T] distance of the (k->j) edge; angle: [T] angle at j.
    roots: [n_spherical, n_radial] numpy constants.
    """
    from scipy.special import spherical_jn
    L, N = roots.shape
    x = d / cutoff                                   # [T]
    env = envelope(x, p) * jnp.maximum(x, 1e-9)      # drop the 1/x pole
    # j_l(z_ln * x): [T, L, N]
    arg = x[:, None, None] * jnp.asarray(roots, jnp.float32)[None]
    jl = jnp.stack([_spherical_jn(L, arg[:, l, :])[..., l]
                    for l in range(L)], axis=1)      # [T, L, N]
    # normalization sqrt(2 / (c^3 j_{l+1}(z_ln)^2))
    norm = np.sqrt(2.0 / (cutoff ** 3
                          * spherical_jn(np.arange(L)[:, None] + 1,
                                         roots) ** 2))
    yl = _legendre(L, jnp.cos(angle))                # [T, L]
    yl = yl * np.sqrt((2 * np.arange(L) + 1) / (4 * np.pi))
    out = (jl * jnp.asarray(norm, jnp.float32)[None]
           * yl[:, :, None] * env[:, None, None])
    return out.reshape(d.shape[0], L * N)


# ---------------------------------------------------------------------------
# Triplet construction (host-side, index-build artifact)
# ---------------------------------------------------------------------------
def build_triplets(edge_index: np.ndarray, n_nodes: int, cap: int):
    """For each edge e=(j->i), list up to ``cap`` incoming edges (k->j), k!=i.

    Returns (t_in [E*cap] edge ids (k->j), t_out [E*cap] edge ids (j->i),
    t_mask [E*cap]). Padded entries point at edge 0 with mask False.
    """
    src, dst = np.asarray(edge_index[0]), np.asarray(edge_index[1])
    E = len(src)
    # incoming edge lists per node (CSR over dst)
    order = np.argsort(dst, kind="stable")
    counts = np.bincount(dst, minlength=n_nodes)
    offsets = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    t_in = np.zeros((E, cap), np.int32)
    t_mask = np.zeros((E, cap), bool)
    for e in range(E):
        j, i = src[e], dst[e]
        inc = order[offsets[j]:offsets[j + 1]]         # edges (k -> j)
        inc = inc[src[inc] != i][:cap]                 # drop backtrack k==i
        t_in[e, :len(inc)] = inc
        t_mask[e, :len(inc)] = True
    t_out = np.repeat(np.arange(E, dtype=np.int32), cap)
    return t_in.reshape(-1), t_out, t_mask.reshape(-1)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def init_dimenet(key, cfg):
    h, nb = cfg.d_hidden, cfg.n_bilinear
    n_rbf = cfg.n_radial
    n_sbf = cfg.n_spherical * cfg.n_radial
    ks = jax.random.split(key, 8 + cfg.n_blocks)
    dtype = dt(cfg.param_dtype)
    p = {
        "rbf_proj": init_dense(ks[1], n_rbf, h, dtype=dtype),
        "edge_mlp": init_dense(ks[2], 3 * h, h, bias=True, dtype=dtype),
        "out_init": init_dense(ks[3], h, h, bias=True, dtype=dtype),
    }
    if cfg.d_feat_in:
        p["feat_proj"] = init_dense(ks[0], cfg.d_feat_in, h, dtype=dtype)
    else:
        p["atom_embed"] = {"table": trunc_normal(
            ks[0], (cfg.n_atom_types, h), dtype=dtype)}
    blocks = []
    for b in range(cfg.n_blocks):
        bk = jax.random.split(ks[4 + b], 8)
        blocks.append({
            "rbf_gate": init_dense(bk[0], n_rbf, h, dtype=dtype),
            "sbf_proj": init_dense(bk[1], n_sbf, nb, dtype=dtype),
            "msg_pre": init_dense(bk[2], h, h, bias=True, dtype=dtype),
            "bilinear": (jax.random.normal(bk[3], (nb, h, h), jnp.float32)
                         / np.sqrt(h)).astype(dtype),
            "msg_post": init_dense(bk[4], h, h, bias=True, dtype=dtype),
            "res1": init_dense(bk[5], h, h, bias=True, dtype=dtype),
            "res2": init_dense(bk[6], h, h, bias=True, dtype=dtype),
            "out": init_dense(bk[7], h, h, bias=True, dtype=dtype),
        })
    # stacked for scan
    p["blocks"] = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *blocks)
    p["head1"] = init_dense(ks[-2], h, h, bias=True, dtype=dtype)
    p["head2"] = init_dense(ks[-1], h, cfg.n_targets, bias=True, dtype=dtype)
    return p


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def _geometry(pos, edge_index, t_in, t_out):
    """Distances per edge and angles per triplet from positions."""
    src, dst = edge_index[0], edge_index[1]
    rel = pos[dst] - pos[src]                        # [E, 3] j -> i
    d = jnp.linalg.norm(rel, axis=-1)                # [E]
    # angle at j between (k->j) and (j->i): vectors -rel[in] and rel[out]
    v1 = -rel[t_in]                                  # j -> k
    v2 = rel[t_out]                                  # j -> i
    cos = jnp.sum(v1 * v2, -1) / jnp.maximum(
        jnp.linalg.norm(v1, axis=-1) * jnp.linalg.norm(v2, axis=-1), 1e-9)
    angle = jnp.arccos(jnp.clip(cos, -1.0, 1.0))
    return d, angle


@functools.partial(jax.jit,
                   static_argnames=("cfg", "task", "n_graphs"))
def dimenet_forward(params, inputs, cfg, *, task="graph", n_graphs=1):
    """inputs: dict with
         pos [N,3], edge_index [2,E], t_in/t_out/t_mask [T],
         node_mask [N], edge_mask [E],
         and (z [N] int  |  feat [N, d_feat]),
         graph_ids [N] (for task="graph" batched molecules).
    Returns per-graph energies [n_graphs, targets] or node logits [N, t].
    """
    cdt = dt(cfg.dtype)
    act = act_fn("silu")
    pos = inputs["pos"].astype(jnp.float32)
    ei = inputs["edge_index"]
    t_in, t_out = inputs["t_in"], inputs["t_out"]
    t_mask = inputs["t_mask"]
    e_mask = inputs["edge_mask"]
    src, dst = ei[0], ei[1]

    d, angle = _geometry(pos, ei, t_in, t_out)
    rbf = radial_basis(d, cfg.n_radial, cfg.cutoff,
                       cfg.envelope_exponent).astype(cdt)     # [E, nr]
    roots = spherical_bessel_roots(cfg.n_spherical, cfg.n_radial)
    sbf = spherical_basis(d[t_in], angle, roots, cfg.cutoff,
                          cfg.envelope_exponent).astype(cdt)  # [T, ns*nr]
    rbf = constrain(rbf, "edges", None)
    sbf = constrain(sbf, "triplets", None)

    # node embeddings
    if "feat" in inputs:
        hN = act(dense(params["feat_proj"], inputs["feat"].astype(cdt)))
    else:
        hN = jnp.take(params["atom_embed"]["table"].astype(cdt),
                      inputs["z"], axis=0)
    hN = constrain(hN, "nodes", "hidden")

    # initial edge messages
    rbf_h = dense(params["rbf_proj"], rbf)
    m = act(dense(params["edge_mlp"],
                  jnp.concatenate([hN[src], hN[dst], rbf_h], -1)))
    m = m * e_mask[:, None].astype(cdt)
    m = constrain(m, "edges", "hidden")

    E = m.shape[0]

    cap = t_in.shape[0] // E

    def block(m, bp):
        # directional message update via triplet gather + bilinear SBF
        pre = act(dense(bp["msg_pre"], m))                    # [E, h]
        sb = dense(bp["sbf_proj"], sbf)                       # [T, nb]
        gathered = pre[t_in] * t_mask[:, None].astype(cdt)    # [T, h]
        gathered = constrain(gathered, "triplets", "hidden")
        # bilinear contraction sum_b sb[:,b] * (gathered @ W[b]) — looped
        # over the (small) bilinear dim so no [T, nb*h] intermediate is
        # ever materialized (T can be ~500M on ogb_products).
        W = bp["bilinear"].astype(cdt)                        # [nb, h, h]
        tprod = jnp.zeros_like(gathered)
        for b in range(W.shape[0]):
            tprod = tprod + sb[:, b:b + 1] * (gathered @ W[b])
        tprod = constrain(tprod, "triplets", "hidden")
        # t_out is repeat(arange(E), cap) BY CONSTRUCTION (build_triplets),
        # so the triplet->edge reduction is a regular reshape+sum — the
        # SPMD partitioner keeps it sharded on E (an arbitrary-index
        # scatter would be replicated to a full [E, h] per device).
        agg = jnp.sum(tprod.reshape(E, cap, -1), axis=1)
        agg = constrain(agg, "edges", "hidden")
        gate = dense(bp["rbf_gate"], rbf)                     # [E, h]
        m2 = act(dense(bp["msg_post"], m * gate + agg))
        m2 = m + m2                                           # residual
        m2 = m2 + act(dense(bp["res2"], act(dense(bp["res1"], m2))))
        m2 = m2 * e_mask[:, None].astype(cdt)
        out_e = dense(bp["out"], m2)                          # [E, h]
        return m2, out_e

    # remat: recompute triplet tensors in backward instead of saving
    # [n_blocks, T, h] intermediates (T ~ 495M on ogb_products)
    m, outs = jax.lax.scan(jax.checkpoint(block), m, params["blocks"],
                           unroll=cfg.n_blocks if getattr(
                               cfg, "unroll_scans", False) else 1)
    edge_out = dense(params["out_init"], m) + jnp.sum(outs, axis=0)
    edge_out = constrain(edge_out, "edges", "hidden")

    # per-edge -> per-node scatter (message direction: into dst)
    N = hN.shape[0]
    node_out = jax.ops.segment_sum(
        edge_out * e_mask[:, None].astype(cdt), dst, num_segments=N)
    node_out = constrain(node_out, "nodes", "hidden")
    node_out = dense(params["head2"],
                     act(dense(params["head1"], node_out)))
    node_out = node_out * inputs["node_mask"][:, None].astype(cdt)

    if task == "node":
        return node_out.astype(jnp.float32)                  # [N, targets]
    gids = inputs.get("graph_ids", jnp.zeros((N,), jnp.int32))
    return jax.ops.segment_sum(node_out.astype(jnp.float32), gids,
                               num_segments=n_graphs)        # [G, targets]


def dimenet_loss(params, inputs, targets, cfg, *, task="graph", n_graphs=1):
    """MSE on energies (graph) or softmax xent on labels (node)."""
    out = dimenet_forward(params, inputs, cfg, task=task, n_graphs=n_graphs)
    if task == "graph":
        return jnp.mean((out - targets) ** 2)
    logp = jax.nn.log_softmax(out, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[:, None], 1)[:, 0]
    w = inputs["node_mask"].astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
