"""Dense FFN: SwiGLU-style gated or plain 2-layer MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import act_fn, dense, init_dense
from repro.sharding.api import constrain


def init_mlp(key, d_model, d_ff, gated=True, bias=False, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {
        "w1": init_dense(ks[0], d_model, d_ff, bias=bias, dtype=dtype),
        "w2": init_dense(ks[1], d_ff, d_model, bias=bias, dtype=dtype),
    }
    if gated:
        p["w3"] = init_dense(ks[2], d_model, d_ff, bias=bias, dtype=dtype)
    return p


def mlp(p, x, act="silu", gated=True):
    h = dense(p["w1"], x)
    h = constrain(h, "batch", "seq", "ff")
    h = act_fn(act)(h)
    if gated:
        g = dense(p["w3"], x)
        g = constrain(g, "batch", "seq", "ff")
        h = h * g
    y = dense(p["w2"], h)
    return constrain(y, "batch", "seq", "dmodel")
