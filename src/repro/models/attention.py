"""Attention: GQA + RoPE + qk-norm + QKV-bias, three execution paths.

1. ``full``      — materialized scores, for short sequences / smoke tests.
2. ``chunked``   — online-softmax over KV chunks (FlashAttention recurrence in
                   pure jnp); causal variant unrolls over query chunks so each
                   query chunk only visits KV chunks at-or-below the diagonal
                   (exact FLOPs — no above-diagonal chunk pair is computed).
3. ``decode``    — one new token vs a KV cache; exact two-pass softmax that the
                   SPMD partitioner turns into flash-decoding style partial
                   max/sum all-reduces when the cache is sequence-sharded.

TP layout: for train/prefill, KV heads are repeated up to the full head count
so the head axis (H) shards cleanly over the TP mesh axis even when
KV < tp (kimi kv=8, tp=16). GQA still pays off — smaller wk/wv
projections — and the repeat is a free broadcast on TPU. Decode keeps the
grouped [KV, G] layout (repeating a 500k-token cache 8x would be absurd);
there the cache *sequence* axis is the sharded one.

For head counts that do not divide tp (qwen2.5-14b H=40), the sharding rules
switch to sequence parallelism ("qseq" -> model) and heads stay unsharded —
see ``sharding.api.lm_rules``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense, init_rmsnorm, rmsnorm
from repro.sharding.api import constrain


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(d_head: int, theta: float):
    half = d_head // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, dh]; positions: [S] (broadcast over leading dims)."""
    dh = x.shape[-1]
    assert dh % 2 == 0, "RoPE requires even head dim"
    freqs = rope_freqs(dh, theta)                            # [dh/2]
    ang = positions.astype(jnp.float32)[..., None] * freqs   # [S, dh/2]
    cos = jnp.cos(ang)[..., None, :]                         # [S, 1, dh/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def init_attention(key, cfg, dtype=jnp.float32):
    d, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d, H * dh, bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_dense(ks[1], d, KV * dh, bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_dense(ks[2], d, KV * dh, bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_dense(ks[3], H * dh, d, bias=False, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(dh, dtype)
        p["k_norm"] = init_rmsnorm(dh, dtype)
    return p


def _project_qkv(p, x, cfg, positions):
    """Returns q [B,S,H,dh], k,v [B,S,KV,dh] with RoPE/qk-norm applied."""
    B, S, _ = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = dense(p["wq"], x).reshape(B, S, H, dh)
    k = dense(p["wk"], x).reshape(B, S, KV, dh)
    v = dense(p["wv"], x).reshape(B, S, KV, dh)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k, n_rep):
    """[B,S,KV,dh] -> [B,S,KV*n_rep,dh] (head-major repeat, matches grouped)."""
    if n_rep == 1:
        return k
    B, S, KV, dh = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, n_rep, dh))
    return k.reshape(B, S, KV * n_rep, dh)


# ---------------------------------------------------------------------------
# Full attention (short sequences / masked encoder) — MHA layout
# ---------------------------------------------------------------------------
def _full_attn(q, k, v, *, causal, pad_mask=None, q_offset=0):
    """q,k,v: [B,S,H,dh]; pad_mask [B,Skv] True=valid. -> [B,Sq,H,dh]"""
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    s = jnp.einsum("bqhd,bshd->bhqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    Sq, Skv = q.shape[1], k.shape[1]
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Skv)
        cm = qpos[:, None] >= kpos[None, :]
        s = jnp.where(cm[None, None], s, -jnp.inf)
    if pad_mask is not None:
        s = jnp.where(pad_mask[:, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)   # fully-masked (padded) query rows
    o = jnp.einsum("bhqs,bshd->bqhd", w.astype(v.dtype), v)
    return o


# ---------------------------------------------------------------------------
# Chunked online-softmax attention — MHA layout
# ---------------------------------------------------------------------------
def _attn_over_kv_chunks(qc, k, v, *, n_chunks, chunk, causal, q_start,
                         unroll=False):
    """Online softmax over KV chunks for one query chunk.

    qc: [B,Cq,H,dh]; k,v: [B, n_chunks*chunk, H, dh]. -> [B,Cq,H,dh]
    """
    B, Cq, H, dh = qc.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    kc = k.reshape(B, n_chunks, chunk, H, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, H, dh).transpose(1, 0, 2, 3, 4)

    def step(carry, inp):
        m, l, acc = carry
        ci, kci, vci = inp
        s = jnp.einsum("bqhd,bshd->bhqs", qc, kci,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jnp.arange(Cq)
            kpos = ci * chunk + jnp.arange(chunk)
            s = jnp.where((qpos[:, None] >= kpos[None, :])[None, None],
                          s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        pmat = jnp.exp(s - m_safe[..., None])
        pmat = jnp.where(jnp.isneginf(s), 0.0, pmat)
        alpha = jnp.exp(m - m_new)
        alpha = jnp.where(jnp.isneginf(m), 0.0, alpha)
        l_new = l * alpha + jnp.sum(pmat, axis=-1)
        pv = jnp.einsum("bhqs,bshd->bhqd", pmat.astype(vci.dtype), vci)
        acc_new = acc * alpha[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Cq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Cq), jnp.float32)
    a0 = jnp.zeros((B, H, Cq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc),
        unroll=n_chunks if unroll else 1)
    l = jnp.where(l == 0.0, 1.0, l)
    o = acc / l[..., None]
    return o.transpose(0, 2, 1, 3).astype(qc.dtype)       # [B,Cq,H,dh]


def _chunked_attn(q, k, v, *, causal, chunk, unroll=False):
    """Exact-FLOPs chunked attention (see module docstring)."""
    B, S = q.shape[0], q.shape[1]
    assert S % chunk == 0, (S, chunk)
    nq = S // chunk
    if not causal:
        return _attn_over_kv_chunks(q, k, v, n_chunks=nq, chunk=chunk,
                                    causal=False, q_start=0, unroll=unroll)
    outs = []
    for i in range(nq):
        qc = jax.lax.slice_in_dim(q, i * chunk, (i + 1) * chunk, axis=1)
        kv_end = (i + 1) * chunk
        ki = jax.lax.slice_in_dim(k, 0, kv_end, axis=1)
        vi = jax.lax.slice_in_dim(v, 0, kv_end, axis=1)
        outs.append(_attn_over_kv_chunks(
            qc, ki, vi, n_chunks=i + 1, chunk=chunk, causal=True,
            q_start=i * chunk, unroll=unroll))
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# Public forward (train / prefill)
# ---------------------------------------------------------------------------
def attention_forward(p, x, cfg, *, positions=None, pad_mask=None,
                      return_kv=False):
    """x: [B, S, d_model]. Returns y [B, S, d_model] (and (k, v) if asked)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q, k, v = _project_qkv(p, x, cfg, positions)
    kv_out = (k, v)
    kf = _repeat_kv(k, cfg.q_per_kv)
    vf = _repeat_kv(v, cfg.q_per_kv)
    q = constrain(q, "batch", "qseq", "heads", None)
    kf = constrain(kf, "batch", "kvseq", "heads", None)
    vf = constrain(vf, "batch", "kvseq", "heads", None)

    use_full = (S <= cfg.attn_full_threshold or S % cfg.attn_chunk != 0
                or pad_mask is not None)
    if cfg.use_flash_kernel and pad_mask is None and cfg.causal:
        from repro.kernels.flash_attention.ops import flash_attention
        o = flash_attention(q.transpose(0, 2, 1, 3),
                            k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=True)
        o = o.transpose(0, 2, 1, 3)
    elif use_full:
        o = _full_attn(q, kf, vf, causal=cfg.causal, pad_mask=pad_mask)
    else:
        o = _chunked_attn(q, kf, vf, causal=cfg.causal, chunk=cfg.attn_chunk,
                          unroll=cfg.unroll_scans)
    o = o.reshape(B, S, cfg.n_heads * cfg.d_head)
    o = constrain(o, "batch", "qseq", "heads")
    y = dense(p["wo"], o)
    y = constrain(y, "batch", "seq", "dmodel")
    if return_kv:
        return y, kv_out
    return y


# ---------------------------------------------------------------------------
# Decode (one token vs KV cache) — grouped GQA layout, cache seq-sharded
# ---------------------------------------------------------------------------
def attention_decode(p, x, cfg, cache_k, cache_v, pos):
    """x: [B, 1, d]; cache_[kv]: [B, S_max, KV, dh]; pos: scalar int32 —
    number of valid cache entries (the new token is written at ``pos``).

    Returns (y [B,1,d], new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    KV, G, dh = cfg.n_kv_heads, cfg.q_per_kv, cfg.d_head
    positions = jnp.full((1,), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)
    q = q.reshape(B, 1, KV, G, dh)

    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k_new.astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v_new.astype(cache_v.dtype), (0, pos, 0, 0))
    cache_k = constrain(cache_k, "batch", "kvseq", "kv", None)
    cache_v = constrain(cache_v, "batch", "kvseq", "kv", None)

    S = cache_k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, cache_k,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
    # Exact two-pass softmax: reductions over the (possibly sequence-sharded)
    # cache axis become two small all-reduces under SPMD (flash-decoding).
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    e = jnp.where(jnp.isneginf(s), 0.0, e)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    w = e / denom
    o = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(cache_v.dtype), cache_v)
    o = o.reshape(B, 1, cfg.n_heads * dh)
    y = dense(p["wo"], o)
    y = constrain(y, "batch", "seq", "dmodel")
    return y, cache_k, cache_v
