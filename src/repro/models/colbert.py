"""ColBERT encoder (Khattab & Zaharia, 2020): late-interaction over BERT.

Wraps any bidirectional ``TransformerConfig`` trunk with the ColBERT head:

  * ``[Q]``/``[D]`` marker token inserted after [CLS] (query vs document).
  * Queries are *expanded*: padded to ``query_maxlen`` with [MASK] tokens
    that DO attend and DO emit vectors (ColBERT's query augmentation).
  * Linear projection d_model -> proj_dim (128), L2-normalized.
  * Document punctuation tokens are masked out of the stored vector set.

Training: in-batch-negative contrastive loss over MaxSim scores — the
standard ColBERTv2-style objective (without distillation, which needs a
teacher we don't have offline).

Token pooling (the paper) happens downstream of ``encode_docs`` — this
module never changes, exactly the paper's "no architectural change" claim.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dt, init_dense
from repro.models.transformer import forward, init_transformer
from repro.sharding.api import constrain

# Special token ids (see data/tokenizer.py — shared vocabulary layout)
PAD_ID, CLS_ID, SEP_ID, MASK_ID, Q_MARK_ID, D_MARK_ID = 0, 1, 2, 3, 4, 5
N_SPECIAL = 8          # ids < N_SPECIAL are special
N_PUNCT = 16           # ids in [N_SPECIAL, N_SPECIAL + N_PUNCT) are punctuation


def init_colbert(key, cfg):
    """cfg: ColbertConfig. Returns {trunk, proj} param tree."""
    k1, k2 = jax.random.split(key)
    return {
        "trunk": init_transformer(k1, cfg.trunk),
        "proj": init_dense(k2, cfg.trunk.d_model, cfg.proj_dim,
                           dtype=dt(cfg.trunk.param_dtype)),
    }


def _encode(params, tokens, cfg, pad_mask):
    """tokens [B, L] -> unit vectors [B, L, proj_dim]."""
    hidden, _ = forward(params["trunk"], tokens, cfg.trunk,
                        pad_mask=pad_mask)
    v = dense(params["proj"], hidden).astype(jnp.float32)
    v = v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-9)
    return constrain(v, "batch", "seq", None)


def prepare_query_tokens(tokens, query_maxlen: int):
    """[B, L] raw token ids -> ([B, Lq] with [CLS][Q]...[MASK] expansion,
    attention pad-mask (all True — MASK expansion tokens attend))."""
    B, L = tokens.shape
    body = tokens[:, :query_maxlen - 2]
    out = jnp.full((B, query_maxlen), MASK_ID, jnp.int32)
    out = out.at[:, 0].set(CLS_ID).at[:, 1].set(Q_MARK_ID)
    body_len = query_maxlen - 2
    pad = body_len - body.shape[1]
    body = jnp.pad(body, ((0, 0), (0, max(pad, 0))))[:, :body_len]
    # query augmentation: PAD slots become MASK (attended, vector-emitting)
    body = jnp.where(body == PAD_ID, MASK_ID, body)
    out = jax.lax.dynamic_update_slice(out, body.astype(jnp.int32), (0, 2))
    return out, jnp.ones((B, query_maxlen), bool)


def prepare_doc_tokens(tokens, doc_maxlen: int):
    """[B, L] raw ids -> ([B, Ld] with [CLS][D] prefix, pad mask)."""
    B, L = tokens.shape
    body = tokens[:, :doc_maxlen - 2]
    pad = (doc_maxlen - 2) - body.shape[1]
    body = jnp.pad(body, ((0, 0), (0, max(pad, 0))))
    out = jnp.concatenate(
        [jnp.full((B, 1), CLS_ID, jnp.int32),
         jnp.full((B, 1), D_MARK_ID, jnp.int32),
         body.astype(jnp.int32)], axis=1)
    return out, out != PAD_ID


def emit_mask_docs(tokens, pad_mask, mask_punctuation: bool):
    """Which doc positions emit stored vectors: real, non-punct tokens
    (+ CLS/D markers, matching ColBERT's skiplist behaviour)."""
    m = pad_mask
    if mask_punctuation:
        punct = (tokens >= N_SPECIAL) & (tokens < N_SPECIAL + N_PUNCT)
        m = m & ~punct
    return m


@functools.partial(jax.jit, static_argnames=("cfg",))
def encode_queries(params, tokens, cfg):
    """Raw query token ids [B, L] -> ([B, Lq, dim] unit vectors, emit mask).

    Every expanded slot emits (ColBERT scores all Lq query vectors)."""
    toks, attn = prepare_query_tokens(tokens, cfg.query_maxlen)
    v = _encode(params, toks, cfg, attn)
    return v, jnp.ones(toks.shape, bool)


@functools.partial(jax.jit, static_argnames=("cfg",))
def encode_docs(params, tokens, cfg):
    """Raw doc token ids [B, L] -> ([B, Ld, dim] unit vectors, emit mask)."""
    toks, attn = prepare_doc_tokens(tokens, cfg.doc_maxlen)
    v = _encode(params, toks, cfg, attn)
    emit = emit_mask_docs(toks, attn, cfg.mask_punctuation)
    return jnp.where(emit[..., None], v, 0.0), emit


# ---------------------------------------------------------------------------
# Training objective: in-batch-negative contrastive MaxSim
# ---------------------------------------------------------------------------
def colbert_loss(params, q_tokens, d_tokens, cfg):
    """q_tokens [B, Lq0], d_tokens [B, Ld0]; positives on the diagonal.

    Returns (loss, metrics). Uses full [B, B] in-batch score matrix.
    """
    qv, qm = encode_queries(params, q_tokens, cfg)
    dv, dm = encode_docs(params, d_tokens, cfg)
    # scores [B, B]: query i vs doc j
    sim = jnp.einsum("qld,nkd->qnlk", qv, dv)
    sim = jnp.where(dm[None, :, None, :], sim, -jnp.inf)
    best = jnp.max(sim, axis=-1)
    best = jnp.where(qm[:, None, :] & jnp.isfinite(best), best, 0.0)
    scores = jnp.sum(best, axis=-1)                    # [B, B]
    labels = jnp.arange(scores.shape[0])
    logp = jax.nn.log_softmax(scores, axis=-1)
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))
    acc = jnp.mean((jnp.argmax(scores, -1) == labels).astype(jnp.float32))
    return loss, {"loss": loss, "acc": acc}


def colbert_train_step(params, opt_state, q_tokens, d_tokens, cfg, opt):
    """One contrastive training step (used by examples/train_colbert.py)."""
    (loss, metrics), grads = jax.value_and_grad(
        colbert_loss, has_aux=True)(params, q_tokens, d_tokens, cfg)
    params, opt_state = opt.update(params, grads, opt_state)
    return params, opt_state, metrics
