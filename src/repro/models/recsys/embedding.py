"""EmbeddingBag for huge sparse tables — the recsys hot path.

JAX has no native ``nn.EmbeddingBag`` and no CSR/CSC sparse; this module IS
that substrate: ``jnp.take`` over stacked per-field tables + a segment/axis
reduction over the multi-hot bag, with the table rows **row-sharded over the
``model`` mesh axis** (the standard sharding for 10^6–10^9-row tables — the
gather over a row-sharded operand becomes a partial gather + all-reduce
under SPMD, which is exactly the DLRM all-to-all-equivalent pattern).

Layout: all ``n_sparse`` fields share one stacked table [F, V, D] (fields
with smaller vocabularies are padded to V rows); lookups take
ids [B, F, M] (M = multi-hot bag size) -> bags [B, F, D] via sum/mean.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import trunc_normal
from repro.sharding.api import constrain


def init_tables(key, vocab_sizes, embed_dim: int, dtype=jnp.float32):
    """Stacked tables [F, V_max, D]; per-field rows >= vocab are never hit
    (ids are generated mod vocab) but keep the stack rectangular."""
    F = len(vocab_sizes)
    V = max(vocab_sizes)
    std = 1.0 / float(embed_dim) ** 0.5
    return {"tables": trunc_normal(key, (F, V, embed_dim),
                                   std=std).astype(dtype)}


def embedding_bag(params, ids, *, mode: str = "sum", dtype=None):
    """ids: [B, F, M] int32 -> bags [B, F, D].

    The gather is expressed per-field (take along the row axis) so the row
    sharding of ``tables`` [F, V(model-sharded), D] is preserved; the bag
    reduction is a plain sum/mean over M.
    """
    t = params["tables"]
    if dtype is not None:
        t = t.astype(dtype)
    t = constrain(t, None, "vocab_rows", None)
    B, F, M = ids.shape
    # [B, F, M, D]: gather rows of each field's table
    gathered = jax.vmap(lambda tab, idx: jnp.take(tab, idx, axis=0),
                        in_axes=(0, 1), out_axes=1)(t, ids)
    if mode == "sum":
        bags = jnp.sum(gathered, axis=2)
    elif mode == "mean":
        bags = jnp.mean(gathered, axis=2)
    else:
        raise ValueError(mode)
    return constrain(bags, "batch", None, "embed")


def embedding_bag_ragged(params, flat_ids, segment_ids, n_bags: int,
                         field_ids=None, dtype=None):
    """Ragged variant: flat_ids [NNZ], segment_ids [NNZ] -> bags [n_bags, D].

    For true multi-hot workloads with variable bag sizes (CSR offsets flattened
    host-side). field_ids selects the table per id (defaults to field 0).
    """
    t = params["tables"]
    if dtype is not None:
        t = t.astype(dtype)
    if field_ids is None:
        rows = jnp.take(t[0], flat_ids, axis=0)
    else:
        V = t.shape[1]
        rows = jnp.take(t.reshape(-1, t.shape[-1]),
                        field_ids * V + flat_ids, axis=0)
    return jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
