"""RecSys architectures: Wide&Deep, DeepFM, FM, DLRM-RM2.

Shared anatomy: huge sparse embedding tables (see embedding.py) -> feature
interaction (dot | FM sum-square | concat) -> small dense MLP -> CTR logit.

FM 2-way interactions use the O(n*k) sum-square identity (Rendle, ICDM'10):
    sum_{i<j} <v_i, v_j> x_i x_j = 1/2 * [ (sum_i v_i)^2 - sum_i v_i^2 ]
so the pairwise term never materializes the [F, F] matrix.

``score_candidates`` is the retrieval_cand cell: one user's tower output
dotted against 10^6 candidate item embeddings — a single [1, D] x [D, C]
matmul + top-k (never a loop), candidate axis data-sharded.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import act_fn, dense, dt, init_dense, trunc_normal
from repro.models.recsys.embedding import embedding_bag, init_tables
from repro.sharding.api import constrain


def _init_mlp_stack(key, d_in, dims, dtype):
    ks = jax.random.split(key, len(dims))
    layers = []
    for k, d_out in zip(ks, dims):
        layers.append(init_dense(k, d_in, d_out, bias=True, dtype=dtype))
        d_in = d_out
    return layers


def _mlp_stack(layers, x, act="relu", last_linear=True):
    a = act_fn(act)
    for i, p in enumerate(layers):
        x = dense(p, x)
        if i < len(layers) - 1 or not last_linear:
            x = a(x)
    return x


def init_recsys(key, cfg):
    dtype = dt(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    D = cfg.embed_dim
    p = {"tables": init_tables(ks[0], cfg.vocab_sizes, D, dtype)["tables"]}
    # linear (1st-order / wide) weights: one scalar weight per sparse row
    p["wide"] = init_tables(ks[1], cfg.vocab_sizes, 1, dtype)["tables"]
    p["bias"] = jnp.zeros((), dtype)

    if cfg.kind == "dlrm":
        p["bot_mlp"] = _init_mlp_stack(ks[2], cfg.n_dense,
                                       cfg.bot_mlp_dims, dtype)
        n_emb = cfg.n_sparse + 1                       # + bottom-MLP vector
        n_pairs = n_emb * (n_emb - 1) // 2
        d_top = n_pairs + cfg.bot_mlp_dims[-1]
        p["top_mlp"] = _init_mlp_stack(ks[3], d_top, cfg.top_mlp_dims, dtype)
    elif cfg.kind in ("wide_deep", "deepfm"):
        d_in = cfg.n_sparse * D + cfg.n_dense
        p["deep_mlp"] = _init_mlp_stack(ks[2], d_in, cfg.mlp_dims + (1,),
                                        dtype)
        if cfg.n_dense:
            p["dense_lin"] = init_dense(ks[4], cfg.n_dense, 1, bias=False,
                                        dtype=dtype)
    elif cfg.kind == "fm":
        if cfg.n_dense:
            p["dense_lin"] = init_dense(ks[4], cfg.n_dense, 1, bias=False,
                                        dtype=dtype)
    else:
        raise ValueError(cfg.kind)
    return p


def _fm_second_order(emb):
    """emb: [B, F, D] -> [B] via the sum-square trick (O(F*D))."""
    s = jnp.sum(emb, axis=1)                          # [B, D]
    ss = jnp.sum(emb * emb, axis=1)                   # [B, D]
    return 0.5 * jnp.sum(s * s - ss, axis=-1)


def _dot_interaction(vecs):
    """vecs: [B, n, D] -> lower-triangle pairwise dots [B, n(n-1)/2]."""
    n = vecs.shape[1]
    g = jnp.einsum("bnd,bmd->bnm", vecs, vecs)        # [B, n, n]
    iu = jnp.triu_indices(n, k=1)
    return g[:, iu[0], iu[1]]


@functools.partial(jax.jit, static_argnames=("cfg",))
def recsys_forward(params, batch, cfg):
    """batch: {sparse_ids [B, F, M] int32, dense [B, n_dense] f32 (opt)}
    -> CTR logits [B]."""
    cdt = dt(cfg.dtype)
    ids = batch["sparse_ids"]
    B = ids.shape[0]
    emb = embedding_bag({"tables": params["tables"]}, ids, dtype=cdt)
    emb = constrain(emb, "batch", None, "embed")      # [B, F, D]
    # first-order term (all models)
    wide = embedding_bag({"tables": params["wide"]}, ids, dtype=cdt)
    logit = jnp.sum(wide, axis=(1, 2)) + params["bias"].astype(cdt)

    dense_x = batch.get("dense")
    if dense_x is not None:
        dense_x = dense_x.astype(cdt)

    if cfg.kind == "fm":
        logit = logit + _fm_second_order(emb)
        if dense_x is not None and "dense_lin" in params:
            logit = logit + dense(params["dense_lin"], dense_x)[:, 0]
    elif cfg.kind == "deepfm":
        logit = logit + _fm_second_order(emb)
        flat = emb.reshape(B, -1)
        if dense_x is not None:
            flat = jnp.concatenate([flat, dense_x], -1)
        logit = logit + _mlp_stack(params["deep_mlp"], flat)[:, 0]
    elif cfg.kind == "wide_deep":
        flat = emb.reshape(B, -1)                     # interaction=concat
        if dense_x is not None:
            flat = jnp.concatenate([flat, dense_x], -1)
        logit = logit + _mlp_stack(params["deep_mlp"], flat)[:, 0]
    elif cfg.kind == "dlrm":
        bot = _mlp_stack(params["bot_mlp"], dense_x, last_linear=False)
        vecs = jnp.concatenate([bot[:, None, :], emb], axis=1)
        inter = _dot_interaction(vecs)                # [B, pairs]
        top_in = jnp.concatenate([bot, inter], -1)
        logit = logit + _mlp_stack(params["top_mlp"], top_in)[:, 0]
    return constrain(logit.astype(jnp.float32), "batch")


def recsys_loss(params, batch, cfg):
    """Binary cross-entropy on CTR labels [B] in {0,1}."""
    logits = recsys_forward(params, batch, cfg)
    y = batch["label"].astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return loss, {"loss": loss,
                  "auc_proxy": jnp.mean((logits > 0) == (y > 0.5))}


@functools.partial(jax.jit, static_argnames=("cfg", "k"))
def score_candidates(params, batch, candidates, cfg, k: int = 100):
    """retrieval_cand cell: user context vs [C, D] candidate embeddings.

    The user tower reuses the model's embedding bags (mean over fields) as
    the query vector; scoring is one matmul over the data-sharded candidate
    axis + a device top-k (per-shard top-k then global merge under SPMD).
    """
    cdt = dt(cfg.dtype)
    emb = embedding_bag({"tables": params["tables"]},
                        batch["sparse_ids"], dtype=cdt)     # [B, F, D]
    user = jnp.mean(emb, axis=1)                            # [B, D]
    cand = constrain(candidates.astype(cdt), "candidates", None)
    scores = user @ cand.T                                  # [B, C]
    scores = constrain(scores, "batch", "candidates")
    return jax.lax.top_k(scores.astype(jnp.float32), k)
