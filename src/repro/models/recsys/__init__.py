from repro.models.recsys.embedding import embedding_bag, init_tables
from repro.models.recsys.models import (init_recsys, recsys_forward,
                                        recsys_loss, score_candidates)

__all__ = ["embedding_bag", "init_tables", "init_recsys", "recsys_forward",
           "recsys_loss", "score_candidates"]
