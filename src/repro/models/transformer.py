"""TransformerLM trunk: causal LM and bidirectional encoder, scan-over-layers.

Layers are stored *stacked* (leading layer axis) and applied with
``jax.lax.scan`` so the compiled HLO contains one layer body regardless of
depth — essential to keep 61-layer / 1T-param dry-run compiles tractable.
MoE models with ``first_dense_layers > 0`` hold two stacks (dense prefix +
MoE suffix), each scanned.

Step functions:
  * ``forward``      — hidden states (encoder use / ColBERT trunk)
  * ``lm_loss``      — causal LM loss with seq-chunked vocab projection
  * ``prefill``      — forward + populated KV cache
  * ``decode_step``  — one token against the cache (serve_step)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.attention import (attention_decode, attention_forward,
                                    init_attention)
from repro.models.layers import (dense, dt, embed, init_dense, init_embed,
                                 init_norm, norm)
from repro.models.mlp import init_mlp, mlp
from repro.models.moe import init_moe, moe_apply
from repro.sharding.api import constrain


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_layer(key, cfg, is_moe, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "mlp_norm": init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if is_moe:
        p["moe"] = init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp,
                            dtype=dtype)
    return p


def init_transformer(key, cfg):
    dtype = dt(cfg.param_dtype)
    n_moe = max(cfg.n_layers - cfg.first_dense_layers, 0) if cfg.moe else 0
    n_dense = cfg.n_layers - n_moe
    ks = jax.random.split(key, 4)
    params = {"embed": init_embed(ks[0], cfg.vocab_size, cfg.d_model,
                                  dtype=dtype)}
    if cfg.pos_emb == "learned":
        params["pos_embed"] = init_embed(
            jax.random.fold_in(ks[0], 7), cfg.max_seq_len, cfg.d_model,
            dtype=dtype)
    if n_dense > 0:
        lk = jax.random.split(ks[1], n_dense)
        params["dense_layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, False, dtype))(lk)
    if n_moe > 0:
        lk = jax.random.split(ks[2], n_moe)
        params["moe_layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, True, dtype))(lk)
    params["final_norm"] = init_norm(cfg.norm, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(
            ks[3], cfg.d_model, cfg.vocab_size, dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def _block(x, lp, cfg, *, is_moe, moe_impl, positions, pad_mask):
    h = norm(cfg.norm, lp["attn_norm"], x, cfg.norm_eps)
    h = attention_forward(lp["attn"], h, cfg, positions=positions,
                          pad_mask=pad_mask)
    x = x + h
    h = norm(cfg.norm, lp["mlp_norm"], x, cfg.norm_eps)
    if is_moe:
        h, aux = moe_apply(lp["moe"], h, cfg, impl=moe_impl)
    else:
        h = mlp(lp["mlp"], h, cfg.act, cfg.gated_mlp)
        aux = jnp.zeros((), jnp.float32)
    # layer-boundary resharding point: under sequence parallelism
    # ("seq" -> model) the residual stream lives seq-sharded between
    # layers and XLA all-gathers/reduce-scatters around attn+mlp.
    x = constrain(x + h, "batch", "seq", "dmodel")
    return x, aux


def _scan_stack(x, stack, cfg, *, is_moe, moe_impl, positions, pad_mask):
    block = functools.partial(_block, cfg=cfg, is_moe=is_moe,
                              moe_impl=moe_impl, positions=positions,
                              pad_mask=pad_mask)
    if cfg.remat:
        block = jax.checkpoint(block)

    def body(carry, lp):
        x, aux = carry
        x, a = block(x, lp)
        return (x, aux + a), None

    n = jax.tree_util.tree_leaves(stack)[0].shape[0]
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stack,
                               unroll=n if cfg.unroll_scans else 1)
    return x, aux


# ---------------------------------------------------------------------------
# Forward (hidden states)
# ---------------------------------------------------------------------------
def forward(params, tokens, cfg, *, pad_mask=None, positions=None,
            moe_impl="capacity"):
    """tokens: [B, S] int32 -> hidden [B, S, d_model], aux_loss scalar."""
    cdt = dt(cfg.dtype)
    x = embed(params["embed"], tokens, dtype=cdt)
    if positions is None:
        positions = jnp.arange(tokens.shape[1])
    if cfg.pos_emb == "learned":
        x = x + embed(params["pos_embed"], positions, dtype=cdt)
    x = constrain(x, "batch", "seq", "dmodel")
    aux = jnp.zeros((), jnp.float32)
    if "dense_layers" in params:
        x, a = _scan_stack(x, params["dense_layers"], cfg, is_moe=False,
                           moe_impl=moe_impl, positions=positions,
                           pad_mask=pad_mask)
        aux += a
    if "moe_layers" in params:
        x, a = _scan_stack(x, params["moe_layers"], cfg, is_moe=True,
                           moe_impl=moe_impl, positions=positions,
                           pad_mask=pad_mask)
        aux += a
    x = norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    return x, aux


def logits_head(params, hidden, cfg):
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(hidden.dtype)
        lg = hidden @ w.T
    else:
        lg = dense(params["lm_head"], hidden)
    return constrain(lg, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Loss (seq-chunked vocab projection)
# ---------------------------------------------------------------------------
def lm_loss(params, tokens, labels, cfg, *, loss_mask=None,
            moe_impl="capacity"):
    """Causal-LM cross entropy. tokens/labels: [B, S] (labels pre-shifted).

    The [B, S, V] logits tensor is never fully materialized: the head
    projection + xent run over sequence chunks inside a scan.
    """
    hidden, aux = forward(params, tokens, cfg, moe_impl=moe_impl)
    B, S, d = hidden.shape
    chunk = min(cfg.logits_chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    hc = hidden.reshape(B, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
    if loss_mask is None:
        loss_mask = jnp.ones_like(labels, jnp.float32)
    mc = loss_mask.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        tot, cnt = carry
        h, lab, msk = inp
        lg = logits_head(params, h, cfg).astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, lab[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * msk
        return (tot + nll.sum(), cnt + msk.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc, mc), unroll=n_chunks if cfg.unroll_scans else 1)
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss + aux, {"xent": loss, "aux": aux, "tokens": cnt}


# ---------------------------------------------------------------------------
# KV cache + prefill + decode
# ---------------------------------------------------------------------------
def init_cache(cfg, batch, max_len, dtype=None):
    dtype = dtype or dt(cfg.dtype)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _stacked_layers(params, cfg):
    """Concatenate dense+moe stacks into one per-layer iterable view.

    Returns list of (stack_params, is_moe, n_layers) segments in order.
    """
    segs = []
    if "dense_layers" in params:
        n = jax.tree_util.tree_leaves(params["dense_layers"])[0].shape[0]
        segs.append((params["dense_layers"], False, n))
    if "moe_layers" in params:
        n = jax.tree_util.tree_leaves(params["moe_layers"])[0].shape[0]
        segs.append((params["moe_layers"], True, n))
    return segs


def prefill(params, tokens, cfg, *, max_len=None, moe_impl="capacity"):
    """Encode a prompt, returning (hidden, cache filled up to S).

    Cache is produced by re-running the per-layer kv projections inside the
    scan, emitted as stacked ys.
    """
    cdt = dt(cfg.dtype)
    B, S = tokens.shape
    max_len = max_len or S
    x = embed(params["embed"], tokens, dtype=cdt)
    positions = jnp.arange(S)
    if cfg.pos_emb == "learned":
        x = x + embed(params["pos_embed"], positions, dtype=cdt)
    x = constrain(x, "batch", "seq", "dmodel")

    def seg_body(x, lp, is_moe):
        h = norm(cfg.norm, lp["attn_norm"], x, cfg.norm_eps)
        h, (k, v) = attention_forward(lp["attn"], h, cfg, positions=positions,
                                      return_kv=True)
        x = x + h
        h2 = norm(cfg.norm, lp["mlp_norm"], x, cfg.norm_eps)
        if is_moe:
            h2, _ = moe_apply(lp["moe"], h2, cfg, impl=moe_impl)
        else:
            h2 = mlp(lp["mlp"], h2, cfg.act, cfg.gated_mlp)
        x = x + h2
        if max_len > S:
            pad = [(0, 0), (0, max_len - S), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        k = constrain(k, "batch", "cacheseq", "kv", None)
        v = constrain(v, "batch", "cacheseq", "kv", None)
        return x, (k.astype(cdt), v.astype(cdt))

    ks, vs = [], []
    for stack, is_moe, _n in _stacked_layers(params, cfg):
        body = functools.partial(seg_body, is_moe=is_moe)
        if cfg.remat:
            body = jax.checkpoint(body)

        def scan_fn(x, lp):
            x, kv = body(x, lp)
            return x, kv

        n = jax.tree_util.tree_leaves(stack)[0].shape[0]
        x, (k_seg, v_seg) = jax.lax.scan(
            scan_fn, x, stack, unroll=n if cfg.unroll_scans else 1)
        ks.append(k_seg)
        vs.append(v_seg)
    cache = {"k": jnp.concatenate(ks, axis=0), "v": jnp.concatenate(vs, axis=0)}
    x = norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    return x, cache


def decode_step(params, token, cache, pos, cfg, *, moe_impl="capacity"):
    """token: [B, 1] int32; cache: stacked {k,v} [L,B,Smax,KV,dh]; pos scalar.

    Returns (logits [B, 1, V], new cache).
    """
    cdt = dt(cfg.dtype)
    x = embed(params["embed"], token, dtype=cdt)
    if cfg.pos_emb == "learned":
        x = x + embed(params["pos_embed"], jnp.full((1,), pos), dtype=cdt)
    x = constrain(x, "batch", "seq", "dmodel")

    layer_off = 0
    new_k, new_v = [], []
    for stack, is_moe, n in _stacked_layers(params, cfg):
        ck = jax.lax.dynamic_slice_in_dim(cache["k"], layer_off, n, axis=0)
        cv = jax.lax.dynamic_slice_in_dim(cache["v"], layer_off, n, axis=0)

        def body(x, inp, is_moe=is_moe):
            lp, k_l, v_l = inp
            h = norm(cfg.norm, lp["attn_norm"], x, cfg.norm_eps)
            h, k_l, v_l = attention_decode(lp["attn"], h, cfg, k_l, v_l, pos)
            x = x + h
            h2 = norm(cfg.norm, lp["mlp_norm"], x, cfg.norm_eps)
            if is_moe:
                h2, _ = moe_apply(lp["moe"], h2, cfg, impl=moe_impl)
            else:
                h2 = mlp(lp["mlp"], h2, cfg.act, cfg.gated_mlp)
            return x + h2, (k_l, v_l)

        x, (k_seg, v_seg) = jax.lax.scan(
            body, x, (stack, ck, cv), unroll=n if cfg.unroll_scans else 1)
        new_k.append(k_seg)
        new_v.append(v_seg)
        layer_off += n
    cache = {"k": jnp.concatenate(new_k, axis=0),
             "v": jnp.concatenate(new_v, axis=0)}
    x = norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    logits = logits_head(params, x, cfg)
    return logits, cache
