"""Mixture-of-Experts FFN with top-k routing.

Two execution paths:

* ``moe_dense``     — every expert runs on every token, outputs combined with
                      the sparsified router weights. Exact, O(E) compute —
                      used in reduced-config smoke tests and as the oracle the
                      capacity path is tested against (capacity -> inf).
* ``moe_capacity``  — GShard/Switch-style capacity dispatch via sort-based
                      position assignment + scatter into a [E, C, d] buffer,
                      batched expert einsum, gather-combine. Memory O(T·k),
                      not O(T·E·C): per-expert slot positions are computed by
                      a stable argsort over assignments (no [T*k, E] one-hot
                      cumsum).

Expert parallelism: experts are sharded on the ``model`` (TP) mesh axis by
annotating the expert-stacked weights with PartitionSpec("model", ...); the
SPMD partitioner turns the dispatch scatter + batched einsum + combine into
an all-to-all/all-reduce schedule. The dispatch math only involves [T*k]
index vectors, which partition cleanly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import act_fn, init_dense
from repro.sharding.api import constrain


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def init_moe(key, cfg, dtype=jnp.float32):
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    std1 = 1.0 / jnp.sqrt(d)
    std2 = 1.0 / jnp.sqrt(f)
    p = {
        "router": init_dense(ks[0], d, E, bias=False, dtype=jnp.float32),
        "w1": (jax.random.normal(ks[1], (E, d, f), jnp.float32) * std1).astype(dtype),
        "w2": (jax.random.normal(ks[2], (E, f, d), jnp.float32) * std2).astype(dtype),
    }
    if cfg.gated_mlp:
        p["w3"] = (jax.random.normal(ks[3], (E, d, f), jnp.float32) * std1).astype(dtype)
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared_w1"] = init_dense(ks[4], d, fs, dtype=dtype)
        p["shared_w2"] = init_dense(jax.random.fold_in(ks[4], 1), fs, d, dtype=dtype)
        if cfg.gated_mlp:
            p["shared_w3"] = init_dense(jax.random.fold_in(ks[4], 2), d, fs, dtype=dtype)
    return p


def _router(p, x2d, cfg):
    """x2d: [T, d] -> (weights [T,k], ids [T,k], aux_loss scalar)."""
    logits = (x2d.astype(jnp.float32) @ p["router"]["w"])          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.top_k)                 # [T, k]
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch-style load-balance auxiliary loss
    E = cfg.n_experts
    me = jnp.mean(probs, axis=0)                                   # [E]
    ce = jnp.mean(
        (jax.nn.one_hot(ids, E, dtype=jnp.float32).sum(axis=1)), axis=0)
    aux = E * jnp.sum(me * ce) * cfg.router_aux_loss
    return weights, ids, aux


def _expert_ffn(p, h, cfg):
    """h: [E, C, d] -> [E, C, d] batched across experts."""
    a = jnp.einsum("ecd,edf->ecf", h, p["w1"].astype(h.dtype))
    a = constrain(a, "experts", None, None)
    a = act_fn(cfg.act)(a)
    if cfg.gated_mlp:
        g = jnp.einsum("ecd,edf->ecf", h, p["w3"].astype(h.dtype))
        g = constrain(g, "experts", None, None)
        a = a * g
    out = jnp.einsum("ecf,efd->ecd", a, p["w2"].astype(h.dtype))
    return constrain(out, "experts", None, None)


def _shared_ffn(p, x2d, cfg):
    h = x2d @ p["shared_w1"]["w"].astype(x2d.dtype)
    h = act_fn(cfg.act)(h)
    if cfg.gated_mlp:
        h = h * (x2d @ p["shared_w3"]["w"].astype(x2d.dtype))
    return h @ p["shared_w2"]["w"].astype(x2d.dtype)


# ---------------------------------------------------------------------------
# Dense (oracle / smoke) path
# ---------------------------------------------------------------------------
def moe_dense(p, x, cfg):
    """x: [B, S, d]. Runs every expert on every token."""
    B, S, d = x.shape
    x2d = x.reshape(-1, d)
    weights, ids, aux = _router(p, x2d, cfg)
    E = cfg.n_experts
    # combine weights as a dense [T, E] matrix (zero off the top-k)
    comb = jnp.zeros((x2d.shape[0], E), x2d.dtype)
    comb = comb.at[jnp.arange(x2d.shape[0])[:, None], ids].set(
        weights.astype(x2d.dtype))
    h = jnp.einsum("td,edf->tef", x2d, p["w1"].astype(x2d.dtype))
    h = act_fn(cfg.act)(h)
    if cfg.gated_mlp:
        h = h * jnp.einsum("td,edf->tef", x2d, p["w3"].astype(x2d.dtype))
    y_all = jnp.einsum("tef,efd->ted", h, p["w2"].astype(x2d.dtype))
    y = jnp.einsum("ted,te->td", y_all, comb)
    if cfg.n_shared_experts:
        y = y + _shared_ffn(p, x2d, cfg)
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Capacity (production) path
# ---------------------------------------------------------------------------
def _positions_in_expert(ids_flat, n_experts):
    """pos[i] = |{j < i : ids[j] == ids[i]}| via stable sort (O(N log N) mem-lean,
    instead of a [N, E] one-hot cumsum)."""
    N = ids_flat.shape[0]
    order = jnp.argsort(ids_flat, stable=True)
    sorted_ids = ids_flat[order]
    idx = jnp.arange(N, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]])
    seg_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, idx, 0))
    pos_sorted = idx - seg_start
    pos = jnp.zeros((N,), jnp.int32).at[order].set(pos_sorted)
    return pos


def moe_capacity(p, x, cfg, capacity=None):
    """x: [B, S, d]. Capacity-dispatch MoE; tokens over capacity are dropped
    (standard Switch semantics — their expert contribution is zero, residual
    stream still carries them)."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    x2d = x.reshape(T, d)
    weights, ids, aux = _router(p, x2d, cfg)

    if capacity is None:
        capacity = int(max(8, round(T * k / E * cfg.capacity_factor)))
    C = capacity

    ids_flat = ids.reshape(-1)                               # [T*k]
    w_flat = weights.reshape(-1)
    pos = _positions_in_expert(ids_flat, E)                  # [T*k]
    keep = pos < C

    # scatter tokens into [E*C, d]; dropped assignments go out-of-range (drop)
    slot = jnp.where(keep, ids_flat * C + pos, E * C)
    token_idx = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    buf = jnp.zeros((E * C, d), x2d.dtype)
    buf = buf.at[slot].add(x2d[token_idx], mode="drop")
    buf = buf.reshape(E, C, d)
    buf = constrain(buf, "experts", None, None)

    out_buf = _expert_ffn(p, buf, cfg).reshape(E * C, d)

    # gather back per assignment, weight, combine over the k slots
    safe_slot = jnp.where(keep, slot, 0)
    y_assign = out_buf[safe_slot] * (w_flat * keep).astype(out_buf.dtype)[:, None]
    y = y_assign.reshape(T, k, d).sum(axis=1)
    if cfg.n_shared_experts:
        y = y + _shared_ffn(p, x2d, cfg)
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Expert-parallel (EP) path: shard_map + all_to_all token routing
# ---------------------------------------------------------------------------
def moe_ep(p, x, cfg, capacity=None):
    """Expert-parallel MoE: tokens are ROUTED to the expert's owner shard
    with lax.all_to_all instead of scatter-adding into a global [E, C, d]
    capacity buffer (which the SPMD partitioner realizes as giant
    all-reduces over the data axis — measured 49 GiB/layer on the 1T
    config). Requires an active mesh_context whose mesh carries a
    ``model`` axis that divides n_experts; falls back to capacity
    dispatch otherwise.

    Collective cost per layer: 2 all_to_alls of [T_loc*k, d] tokens
    (+ the FSDP weight all-gather), vs all-reduces of [E, C, d].
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.sharding.api import current_ctx

    ctx = current_ctx()
    if ctx is None or "model" not in ctx.mesh.axis_names:
        return moe_capacity(p, x, cfg, capacity)
    mesh = ctx.mesh
    model_axis = "model"
    data_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    n_shards = mesh.shape[model_axis]
    E, k = cfg.n_experts, cfg.top_k
    assert E % n_shards == 0, (E, n_shards)
    E_loc = E // n_shards
    B, S, d = x.shape
    T = B * S
    # per-device token count: batch is sharded over the data axes
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    T_loc = T // n_data
    # send capacity per (src shard -> dst shard) lane; k assignments per
    # token spread over n_shards lanes on average
    cap_send = capacity or int(max(8, round(
        T_loc * k / n_shards * cfg.capacity_factor)))
    C_loc = int(max(8, round(T_loc * n_data * k / E
                             * cfg.capacity_factor)))

    def local(router_w, w1, w2, w3, xl):
        # xl: [B_loc, S, d] local tokens; weights arrive as local shards:
        # w1 [E_loc, d/fsdp, f] -> all-gather the FSDP dim
        if data_axes:
            w1 = jax.lax.all_gather(w1, data_axes, axis=1, tiled=True)
            w2 = jax.lax.all_gather(w2, data_axes, axis=2, tiled=True)
            if w3 is not None:
                w3 = jax.lax.all_gather(w3, data_axes, axis=1, tiled=True)
        x2d = xl.reshape(-1, d)                          # [T_loc, d]
        logits = x2d.astype(jnp.float32) @ router_w
        probs = jax.nn.softmax(logits, axis=-1)
        weights, ids = jax.lax.top_k(probs, k)           # [T_loc, k]
        weights = weights / jnp.sum(weights, -1, keepdims=True)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(ids, E, dtype=jnp.float32).sum(1), 0)
        aux = E * jnp.sum(jax.lax.pmean(me, data_axes + (model_axis,))
                          * jax.lax.pmean(ce, data_axes + (model_axis,))
                          ) * cfg.router_aux_loss

        ids_f = ids.reshape(-1)                          # [T_loc*k]
        w_f = weights.reshape(-1).astype(x2d.dtype)
        dst = ids_f // E_loc                             # target shard
        # slot within the (dst) send lane
        lane_pos = _positions_in_expert(dst, n_shards)
        keep = lane_pos < cap_send
        slot = jnp.where(keep, dst * cap_send + lane_pos,
                         n_shards * cap_send)
        tok = jnp.repeat(jnp.arange(T_loc, dtype=jnp.int32), k)
        send = jnp.zeros((n_shards * cap_send, d), x2d.dtype)
        send = send.at[slot].add(x2d[tok], mode="drop")
        send_eid = jnp.full((n_shards * cap_send,), -1, jnp.int32)
        send_eid = send_eid.at[slot].set(ids_f % E_loc, mode="drop")
        send = send.reshape(n_shards, cap_send, d)
        send_eid = send_eid.reshape(n_shards, cap_send)
        # exchange over the model axis
        recv = jax.lax.all_to_all(send, model_axis, 0, 0, tiled=False)
        recv_eid = jax.lax.all_to_all(send_eid, model_axis, 0, 0,
                                      tiled=False)
        rv = recv.reshape(-1, d)                         # [S*cap_send, d]
        re = recv_eid.reshape(-1)
        # local expert dispatch
        valid = re >= 0
        pos = _positions_in_expert(jnp.where(valid, re, E_loc), E_loc + 1)
        keep2 = valid & (pos < C_loc)
        slot2 = jnp.where(keep2, re * C_loc + pos, E_loc * C_loc)
        buf = jnp.zeros((E_loc * C_loc, d), x2d.dtype)
        buf = buf.at[slot2].add(rv, mode="drop")
        h = buf.reshape(E_loc, C_loc, d)
        a = jnp.einsum("ecd,edf->ecf", h, w1.astype(h.dtype))
        a = act_fn(cfg.act)(a)
        if w3 is not None:
            a = a * jnp.einsum("ecd,edf->ecf", h, w3.astype(h.dtype))
        out = jnp.einsum("ecf,efd->ecd", a, w2.astype(h.dtype))
        out = out.reshape(E_loc * C_loc, d)
        # gather back per received slot, return to sender
        back = jnp.where(keep2[:, None], out[jnp.where(keep2, slot2, 0)],
                         0.0)
        back = back.reshape(n_shards, cap_send, d)
        ret = jax.lax.all_to_all(back, model_axis, 0, 0, tiled=False)
        ret = ret.reshape(-1, d)                         # [n_shards*cap, d]
        safe = jnp.where(keep, slot, 0)
        y_asn = jnp.where(keep[:, None], ret[safe], 0.0) \
            * w_f[:, None]
        y = jax.ops.segment_sum(y_asn, tok, num_segments=T_loc)
        return y.reshape(xl.shape).astype(xl.dtype), aux

    dp = P(data_axes if len(data_axes) > 1 else (data_axes[0]
                                                 if data_axes else None))
    x_spec = P(dp[0] if data_axes else None, None, None)
    w1_spec = P(model_axis, dp[0] if data_axes else None, None)
    w2_spec = P(model_axis, None, dp[0] if data_axes else None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(None, None), w1_spec, w2_spec,
                  w1_spec if cfg.gated_mlp else P(None), x_spec),
        out_specs=(x_spec, P()),
        check_rep=False)
    w3 = p.get("w3") if cfg.gated_mlp else None
    y, aux = fn(p["router"]["w"], p["w1"], p["w2"], w3, x)
    if cfg.n_shared_experts:
        x2d = x.reshape(-1, d)
        y = y + _shared_ffn(p, x2d, cfg).reshape(x.shape)
    return y, aux


def moe_apply(p, x, cfg, impl="capacity"):
    if impl == "dense":
        return moe_dense(p, x, cfg)
    if impl == "ep":
        return moe_ep(p, x, cfg)
    return moe_capacity(p, x, cfg)
