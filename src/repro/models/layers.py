"""Minimal functional layer substrate (no flax): param trees + apply fns.

Parameters are nested dicts of jnp arrays. Every layer exposes
``init_<layer>(key, ...) -> params`` and a pure ``<layer>(params, x, ...)``.
Compute dtype is controlled by the caller (params stay in param_dtype,
activations are cast on entry).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "float16": jnp.float16,
}


def dt(name: str):
    return DTYPES[name]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def trunc_normal(key, shape, std=0.02, dtype=jnp.float32):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def lecun_normal(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------
def init_dense(key, d_in, d_out, bias=False, std=None, dtype=jnp.float32):
    kw, kb = jax.random.split(key)
    std = std if std is not None else 1.0 / np.sqrt(d_in)
    p = {"w": (jax.random.normal(kw, (d_in, d_out), jnp.float32) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, dtype=None):
    dtype = dtype if dtype is not None else x.dtype
    w = p["w"].astype(dtype)
    y = x.astype(dtype) @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_rmsnorm(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def init_norm(kind, d, dtype=jnp.float32):
    return init_rmsnorm(d, dtype) if kind == "rmsnorm" else init_layernorm(d, dtype)


def norm(kind, p, x, eps=1e-6):
    return rmsnorm(p, x, eps) if kind == "rmsnorm" else layernorm(p, x, eps)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------
def init_embed(key, vocab, d, std=0.02, dtype=jnp.float32):
    return {"table": trunc_normal(key, (vocab, d), std, dtype)}


def embed(p, ids, dtype=None):
    t = p["table"]
    if dtype is not None:
        t = t.astype(dtype)
    return jnp.take(t, ids, axis=0)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------
ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
}


def act_fn(name):
    return ACTS[name]


# ---------------------------------------------------------------------------
# Tree utilities
# ---------------------------------------------------------------------------
def tree_size(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def tree_paths(tree):
    """Yield ('a/b/c', leaf) pairs for a nested dict/list pytree."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        out.append(("/".join(parts), leaf))
    return out
