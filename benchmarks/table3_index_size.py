"""Paper Table 3: vector count & index size vs pooling factor.

Dense single-vector (16-bit HNSW) vs PLAID-indexed ColBERT at pooling
factors 1/2/3/4/6, on the trec-covid analogue at the encoder's doc_maxlen
(paper: 256-token truncation; our bench encoder: 128). Footprint numbers
come straight from the ``QualitySweep`` cells (built through the
``repro.Retriever`` facade — no direct Indexer calls), so the size table
and the quality tables describe the very same indexes. Lands in the
``table3`` section of ``BENCH_quality.json``.
"""
from __future__ import annotations

from benchmarks.common import bench_encoder
from repro.eval import (BENCH_QUALITY_FILE, QualitySweep,
                        synthetic_dataset, write_bench_section)

FACTORS = (1, 2, 3, 4, 6)
BACKEND = "plaid"
BITS = 2


def run(verbose: bool = True, out: str = BENCH_QUALITY_FILE):
    params, cfg = bench_encoder(verbose=verbose)
    ds = synthetic_dataset("trec-covid", vocab_size=cfg.trunk.vocab_size,
                           doc_maxlen=cfg.doc_maxlen - 2,
                           query_maxlen=cfg.query_maxlen - 2,
                           n_docs=300, n_queries=16)
    rep = QualitySweep(params, cfg, ds, methods=("ward",),
                       factors=FACTORS, backends=(BACKEND,),
                       quant_bits=(BITS,), metrics=("ndcg@10",)).run()

    print("\nTable 3 — vector count & index size")
    # dense single-vector baseline: one 16-bit vector per doc in HNSW
    dense_bytes = ds.n_docs * cfg.proj_dim * 2
    print(f"{'16-bit dense single-vector':32s} {ds.n_docs:>9d} vecs "
          f"{dense_bytes/2**20:8.2f} MiB")

    sizes = {"dense_bytes": dense_bytes}
    for factor in FACTORS:
        c = rep.cell(BACKEND, "ward", factor, BITS)
        label = (f"{BITS}-bit PLAID (no pooling)" if factor == 1
                 else f"{BITS}-bit PLAID pool {factor}")
        print(f"{label:32s} {c.n_vectors:>9d} vecs "
              f"{c.index_bytes/2**20:8.2f} MiB "
              f"({c.vector_reduction:5.1%} fewer vectors)")
        sizes[str(factor)] = {"n_vectors": c.n_vectors,
                              "index_bytes": c.index_bytes,
                              "vector_reduction": c.vector_reduction}
    write_bench_section(out, "table3",
                        {"report": rep, "sizes": sizes,
                         "backend": BACKEND, "quant_bits": BITS})
    return {"report": rep, "sizes": sizes}


if __name__ == "__main__":
    run()
