"""Paper Table 3: vector count & index size vs pooling factor.

Dense single-vector (16-bit HNSW) vs PLAID-indexed ColBERT at pooling
factors 1/2/3/4/6, on the trec-covid analogue at the encoder's doc_maxlen
(paper: 256-token truncation; our bench encoder: 128)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_encoder, small_spec
from repro.data.corpus import SyntheticRetrievalCorpus
from repro.retrieval.indexer import Indexer


def run(verbose: bool = True):
    params, cfg = bench_encoder(verbose=verbose)
    corpus = SyntheticRetrievalCorpus(small_spec("trec-covid", 300, 16),
                                      vocab_size=cfg.trunk.vocab_size)
    toks = corpus.doc_token_batch(cfg.doc_maxlen - 2)

    print("\nTable 3 — vector count & index size")
    # dense single-vector baseline: one 16-bit vector per doc in HNSW
    n_docs = toks.shape[0]
    dense_bytes = n_docs * cfg.proj_dim * 2
    print(f"{'16-bit dense single-vector':32s} {n_docs:>9d} vecs "
          f"{dense_bytes/2**20:8.2f} MiB")

    out = {"dense": dense_bytes}
    for factor in (1, 2, 3, 4, 6):
        idx, stats = Indexer(params, cfg, pool_method="ward",
                             pool_factor=factor, backend="plaid").build(toks)
        label = ("2-bit PLAID (no pooling)" if factor == 1
                 else f"2-bit PLAID pool {factor}")
        print(f"{label:32s} {stats.n_vectors_stored:>9d} vecs "
              f"{stats.index_bytes/2**20:8.2f} MiB "
              f"({stats.vector_reduction:5.1%} fewer vectors)")
        out[factor] = (stats.n_vectors_stored, stats.index_bytes)
    return out


if __name__ == "__main__":
    run()
