"""Index-build benchmark: monolithic vs streaming, kernel vs reference.

    PYTHONPATH=src python benchmarks/index_bench.py --docs 300 \
        --shard-max-vectors 2048 --out BENCH_index.json

For every pool method x pool factor cell this builds the SAME corpus
several ways and measures

  * ``docs_per_s`` / ``vectors_per_s`` — build throughput (encode +
    pool + index construction, and for streaming also the per-shard
    artifact writes),
  * ``peak_heap_bytes``   — tracemalloc peak of the build phase (numpy
    buffers route through the Python allocator, so this captures the
    host-side high-water mark the streaming path exists to bound; jax
    device buffers are outside tracemalloc, identical for both modes),
  * ``peak_buffered_vectors`` — the streaming builder's own pooled-
    buffer high-water mark (IndexStats),
  * ``transfer_ratio`` — device->host compaction bytes over the padded
    [B, N, d] bytes the pre-kernel path shipped
    (``core.pooling.compaction_transfer_stats``),
  * ``flush_wait_s`` / ``flush_busy_s`` — the pipelined streaming
    build's encode-stall and shard-I/O wall (IndexStats).

Modes per cell: ``monolithic`` and ``streaming-sharded`` are the
serial builds on the REFERENCE ward path (comparable against pre-kernel
history rows); ward cells with factor > 1 additionally run
``monolithic-kernel`` (Pallas ward_pool path) and
``streaming-pipelined`` (kernel path + background flush thread).

ASSERTED acceptance bounds:
  * streaming with a cap below the corpus -> >= 2 shards, pooled buffer
    within ``cap + max_batch_vectors`` (docs are atomic; flush check
    runs once per encode batch),
  * kernel cells: search results of the kernel-built monolithic index
    bitwise == the reference-built one, and the pipelined+kernel
    streaming ARTIFACT content-identical (generation tokens
    canonicalized out) to the serial+reference one — assignments,
    shard layout, doc ids, and payload bytes all pinned,
  * kernel cells: compaction transfer <= 1/factor + eps of padded
    bytes,
  * with ``--assert-pipeline``: pipelined streaming no slower than
    0.95x serial, and encode stalls behind shard I/O under 5% of the
    build (the CI gate).
"""
from __future__ import annotations

import argparse
import json
import os
import re
import resource
import shutil
import tempfile
import time
import tracemalloc
from dataclasses import replace

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.pooling import compaction_transfer_stats
from repro.data.corpus import DATASET_SPECS, SyntheticRetrievalCorpus
from repro.models.colbert import init_colbert
from repro.retrieval.indexer import Indexer

_TOKEN = re.compile(r"\.[0-9a-f]{8}\.npy")


def _measured(fn):
    """(result, wall seconds, tracemalloc peak bytes) for one build."""
    tracemalloc.start()
    tracemalloc.reset_peak()
    t0 = time.time()
    out = fn()
    dt = time.time() - t0
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return out, dt, peak


def _canonical_artifact(root: str) -> dict:
    """Artifact content keyed by token-stripped relpath (filenames embed
    a random generation token; content must not differ)."""
    out = {}
    for dirpath, _, files in os.walk(root):
        for name in sorted(files):
            if name == "stats.json":    # build timings, not content
                continue
            path = os.path.join(dirpath, name)
            rel = _TOKEN.sub(".npy", os.path.relpath(path, root))
            with open(path, "rb") as fh:
                blob = fh.read()
            out[rel] = (_TOKEN.sub(".npy", blob.decode())
                        if name.endswith(".json") else blob)
    return out


def _assert_same_artifact(dir_a: str, dir_b: str, what: str) -> None:
    ca, cb = _canonical_artifact(dir_a), _canonical_artifact(dir_b)
    assert sorted(ca) == sorted(cb), (
        f"{what}: artifact layout drift {sorted(set(ca) ^ set(cb))}")
    for rel in ca:
        assert ca[rel] == cb[rel], f"{what}: content drift in {rel}"


def bench_cell(params, cfg, toks, method: str, factor: int, backend: str,
               cap: int, out_root: str, encode_batch: int,
               assert_pipeline: bool = False):
    def make_indexer(ward_kernel: str = "ref"):
        from repro.core.spec import IndexSpec, PoolingSpec
        return Indexer(
            params, cfg, encode_batch=encode_batch,
            index_spec=IndexSpec.from_config(cfg, backend=backend,
                                             ndocs=4096),
            pooling_spec=PoolingSpec(method=method,
                                     factor=max(factor, 1),
                                     ward_kernel=ward_kernel))

    kernel_cell = method == "ward" and factor > 1

    # warm the encoder + pooling traces (both impls for kernel cells) so
    # jit compile lands in no measurement
    make_indexer("ref").encode_and_pool(toks[:encode_batch])
    if kernel_cell:
        make_indexer("kernel").encode_and_pool(toks[:encode_batch])

    (mono_ix, mono_stats), mono_s, mono_peak = _measured(
        lambda: make_indexer("ref").build(toks))

    rows = []

    def row(mode, stats, secs, peak, ward_kernel="ref", extra=None):
        r = {
            "method": method, "factor": factor, "backend": backend,
            "mode": mode, "ward_kernel": ward_kernel,
            "n_docs": stats.n_docs, "n_shards": stats.n_shards,
            "n_vectors_stored": stats.n_vectors_stored,
            "docs_per_s": stats.n_docs / max(secs, 1e-9),
            "vectors_per_s": stats.n_vectors_stored / max(secs, 1e-9),
            "build_s": secs,
            "peak_heap_bytes": int(peak),
            "peak_buffered_vectors": stats.peak_buffered_vectors,
            "index_bytes": stats.index_bytes,
            "flush_wait_s": stats.flush_wait_s,
            "flush_busy_s": stats.flush_busy_s,
        }
        r.update(extra or {})
        rows.append(r)
        return r

    row("monolithic", mono_stats, mono_s, mono_peak)

    kern_s = None
    if kernel_cell:
        compaction_transfer_stats(reset=True)
        (kern_ix, kern_stats), kern_s, kern_peak = _measured(
            lambda: make_indexer("kernel").build(toks))
        ts = compaction_transfer_stats(reset=True)
        ratio = ts["compact_bytes"] / max(ts["padded_bytes"], 1)
        # ---- gate: compaction ships <= 1/factor + eps of padded bytes
        eps = 2.0 / cfg.doc_maxlen + 0.02
        assert ratio <= 1.0 / factor + eps, (
            f"compaction transfer ratio {ratio:.3f} above "
            f"1/{factor} + {eps:.3f}")
        # ---- gate: kernel-built index searches bitwise like the ref's
        rng = np.random.default_rng(0)
        qs = rng.normal(size=(8, 8, cfg.proj_dim)).astype(np.float32)
        for ra, rb in zip(mono_ix.search_batch(qs, k=10),
                          kern_ix.search_batch(qs, k=10)):
            assert (np.asarray(ra) == np.asarray(rb)).all(), (
                "kernel-vs-reference search parity mismatch")
        assert kern_stats.n_vectors_stored == mono_stats.n_vectors_stored
        row("monolithic-kernel", kern_stats, kern_s, kern_peak,
            ward_kernel="kernel", extra={"transfer_ratio": ratio})

    # cap is a ceiling: higher pool factors shrink the corpus, so keep
    # the cap below ~1/3 of the stored vectors or the cell can't shard
    cap = min(cap, max(mono_stats.n_vectors_stored // 3, 1))
    art = os.path.join(out_root, f"{method}_f{factor}")
    (sharded, st), stream_s, stream_peak = _measured(
        lambda: make_indexer("ref").build_streaming(
            toks, shard_max_vectors=cap, out_dir=art, pipeline=False))

    # ---- acceptance bound: bounded host buffer, real sharding ----
    assert st.n_shards >= 2, (
        f"cap {cap} did not shard a {st.n_vectors_stored}-vector corpus")
    bound = cap + st.max_batch_vectors
    assert st.peak_buffered_vectors <= bound, (
        f"streaming buffer {st.peak_buffered_vectors} exceeded "
        f"cap+batch bound {bound}")
    assert st.n_vectors_stored == mono_stats.n_vectors_stored
    row("streaming-sharded", st, stream_s, stream_peak)

    if kernel_cell:
        art_pipe = os.path.join(out_root, f"{method}_f{factor}_pipe")
        (_, stp), pipe_s, pipe_peak = _measured(
            lambda: make_indexer("kernel").build_streaming(
                toks, shard_max_vectors=cap, out_dir=art_pipe,
                pipeline=True))
        # ---- gate: pipelined+kernel artifact == serial+reference ----
        _assert_same_artifact(art, art_pipe,
                              f"{method} f={factor} pipelined streaming")
        assert stp.peak_buffered_vectors == st.peak_buffered_vectors
        row("streaming-pipelined", stp, pipe_s, pipe_peak,
            ward_kernel="kernel")
        if assert_pipeline:
            # (b) pipelined must not lose to serial (5% noise floor) and
            # encode must not sit idle behind shard I/O
            assert pipe_s <= stream_s / 0.95, (
                f"pipelined streaming {pipe_s:.3f}s slower than serial "
                f"{stream_s:.3f}s")
            assert stp.flush_wait_s <= 0.05 * pipe_s, (
                f"encode stalled {stp.flush_wait_s:.3f}s behind shard "
                f"I/O in a {pipe_s:.3f}s build")

    for r in rows:
        print(f"{method:10s} f={factor} {r['mode']:19s} "
              f"{r['docs_per_s']:7.1f} docs/s {r['vectors_per_s']:9.0f} "
              f"vec/s  peak-heap {r['peak_heap_bytes'] / 2**20:7.1f} MiB"
              + (f"  shards={r['n_shards']} "
                 f"buf<={r['peak_buffered_vectors']}"
                 if r["mode"].startswith("streaming") else ""))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="scifact")
    ap.add_argument("--docs", type=int, default=300)
    ap.add_argument("--methods", default="ward,sequential")
    ap.add_argument("--pool-factors", default="1,2,4")
    ap.add_argument("--backend", default="flat",
                    help="index backend under the build (flat isolates "
                         "encode+pool+store cost; plaid adds codec train)")
    ap.add_argument("--shard-max-vectors", type=int, default=2048)
    ap.add_argument("--encode-batch", type=int, default=32)
    ap.add_argument("--assert-pipeline", action="store_true",
                    help="fail if the pipelined streaming build is "
                         "slower than serial or encode stalls on I/O")
    ap.add_argument("--keep-dir", default=None)
    ap.add_argument("--out", default="BENCH_index.json")
    args = ap.parse_args(argv)
    methods = [m for m in args.methods.split(",") if m]
    factors = [int(f) for f in args.pool_factors.split(",") if f]

    cfg = get_smoke_config("colbertv2")
    params = init_colbert(jax.random.PRNGKey(0), cfg)
    spec = replace(DATASET_SPECS[args.dataset], n_docs=args.docs)
    corpus = SyntheticRetrievalCorpus(spec, vocab_size=cfg.trunk.vocab_size)
    toks = corpus.doc_token_batch(cfg.doc_maxlen - 2)

    out_root = args.keep_dir or tempfile.mkdtemp(prefix="index_bench_")
    try:
        results = []
        for m in methods:
            for f in factors:
                results += bench_cell(params, cfg, toks, m, f,
                                      args.backend, args.shard_max_vectors,
                                      out_root, args.encode_batch,
                                      assert_pipeline=args.assert_pipeline)
    finally:
        if args.keep_dir is None:
            shutil.rmtree(out_root, ignore_errors=True)

    out = {"dataset": args.dataset, "n_docs": args.docs,
           "backend": args.backend,
           "shard_max_vectors": args.shard_max_vectors,
           "ru_maxrss_kb": resource.getrusage(resource.RUSAGE_SELF)
                                   .ru_maxrss,
           "results": results}
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)
    print(f"\nwrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
