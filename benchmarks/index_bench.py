"""Index-build benchmark: monolithic vs streaming-sharded, per method x factor.

    PYTHONPATH=src python benchmarks/index_bench.py --docs 300 \
        --shard-max-vectors 2048 --out BENCH_index.json

For every pool method x pool factor cell this builds the SAME corpus two
ways and measures

  * ``docs_per_s`` / ``vectors_per_s`` — build throughput (encode +
    pool + index construction, and for streaming also the per-shard
    artifact writes),
  * ``peak_heap_bytes``   — tracemalloc peak of the build phase (numpy
    buffers route through the Python allocator, so this captures the
    host-side high-water mark the streaming path exists to bound; jax
    device buffers are outside tracemalloc, identical for both modes),
  * ``peak_buffered_vectors`` — the streaming builder's own pooled-
    buffer high-water mark (IndexStats),

and ASSERTS the acceptance bound: a streaming build with a cap smaller
than the corpus must produce >= 2 shards and keep its pooled buffer
within ``cap + max_batch_vectors`` (docs are atomic and the flush check
runs once per encode batch — that slack is the contract, see
``Indexer.build_streaming``). Results land in ``BENCH_index.json``;
the README's "Scaling past RAM" table is generated from a run of this.
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import shutil
import tempfile
import time
import tracemalloc
from dataclasses import replace

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data.corpus import DATASET_SPECS, SyntheticRetrievalCorpus
from repro.models.colbert import init_colbert
from repro.retrieval.indexer import Indexer


def _measured(fn):
    """(result, wall seconds, tracemalloc peak bytes) for one build."""
    tracemalloc.start()
    tracemalloc.reset_peak()
    t0 = time.time()
    out = fn()
    dt = time.time() - t0
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return out, dt, peak


def bench_cell(params, cfg, toks, method: str, factor: int, backend: str,
               cap: int, out_root: str, encode_batch: int):
    def make_indexer():
        from repro.core.spec import IndexSpec, PoolingSpec
        return Indexer(
            params, cfg, encode_batch=encode_batch,
            index_spec=IndexSpec.from_config(cfg, backend=backend,
                                             ndocs=4096),
            pooling_spec=PoolingSpec(method=method,
                                     factor=max(factor, 1)))

    # warm the encoder trace so jit compile lands in neither measurement
    make_indexer().encode_and_pool(toks[:encode_batch])

    (_, mono_stats), mono_s, mono_peak = _measured(
        lambda: make_indexer().build(toks))

    # cap is a ceiling: higher pool factors shrink the corpus, so keep
    # the cap below ~1/3 of the stored vectors or the cell can't shard
    cap = min(cap, max(mono_stats.n_vectors_stored // 3, 1))
    art = os.path.join(out_root, f"{method}_f{factor}")
    (sharded, st), stream_s, stream_peak = _measured(
        lambda: make_indexer().build_streaming(
            toks, shard_max_vectors=cap, out_dir=art))

    # ---- acceptance bound: bounded host buffer, real sharding ----
    assert st.n_shards >= 2, (
        f"cap {cap} did not shard a {st.n_vectors_stored}-vector corpus")
    bound = cap + st.max_batch_vectors
    assert st.peak_buffered_vectors <= bound, (
        f"streaming buffer {st.peak_buffered_vectors} exceeded "
        f"cap+batch bound {bound}")
    assert st.n_vectors_stored == mono_stats.n_vectors_stored

    def row(mode, stats, secs, peak):
        return {
            "method": method, "factor": factor, "backend": backend,
            "mode": mode,
            "n_docs": stats.n_docs, "n_shards": stats.n_shards,
            "n_vectors_stored": stats.n_vectors_stored,
            "docs_per_s": stats.n_docs / max(secs, 1e-9),
            "vectors_per_s": stats.n_vectors_stored / max(secs, 1e-9),
            "build_s": secs,
            "peak_heap_bytes": int(peak),
            "peak_buffered_vectors": stats.peak_buffered_vectors,
            "index_bytes": stats.index_bytes,
        }

    rows = [row("monolithic", mono_stats, mono_s, mono_peak),
            row("streaming-sharded", st, stream_s, stream_peak)]
    for r in rows:
        print(f"{method:10s} f={factor} {r['mode']:18s} "
              f"{r['docs_per_s']:7.1f} docs/s {r['vectors_per_s']:9.0f} "
              f"vec/s  peak-heap {r['peak_heap_bytes'] / 2**20:7.1f} MiB"
              + (f"  shards={r['n_shards']} "
                 f"buf<={r['peak_buffered_vectors']}"
                 if r["mode"] != "monolithic" else ""))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="scifact")
    ap.add_argument("--docs", type=int, default=300)
    ap.add_argument("--methods", default="ward,sequential")
    ap.add_argument("--pool-factors", default="1,2,4")
    ap.add_argument("--backend", default="flat",
                    help="index backend under the build (flat isolates "
                         "encode+pool+store cost; plaid adds codec train)")
    ap.add_argument("--shard-max-vectors", type=int, default=2048)
    ap.add_argument("--encode-batch", type=int, default=32)
    ap.add_argument("--keep-dir", default=None)
    ap.add_argument("--out", default="BENCH_index.json")
    args = ap.parse_args(argv)
    methods = [m for m in args.methods.split(",") if m]
    factors = [int(f) for f in args.pool_factors.split(",") if f]

    cfg = get_smoke_config("colbertv2")
    params = init_colbert(jax.random.PRNGKey(0), cfg)
    spec = replace(DATASET_SPECS[args.dataset], n_docs=args.docs)
    corpus = SyntheticRetrievalCorpus(spec, vocab_size=cfg.trunk.vocab_size)
    toks = corpus.doc_token_batch(cfg.doc_maxlen - 2)

    out_root = args.keep_dir or tempfile.mkdtemp(prefix="index_bench_")
    try:
        results = []
        for m in methods:
            for f in factors:
                results += bench_cell(params, cfg, toks, m, f,
                                      args.backend, args.shard_max_vectors,
                                      out_root, args.encode_batch)
    finally:
        if args.keep_dir is None:
            shutil.rmtree(out_root, ignore_errors=True)

    out = {"dataset": args.dataset, "n_docs": args.docs,
           "backend": args.backend,
           "shard_max_vectors": args.shard_max_vectors,
           "ru_maxrss_kb": resource.getrusage(resource.RUSAGE_SELF)
                                   .ru_maxrss,
           "results": results}
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)
    print(f"\nwrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
