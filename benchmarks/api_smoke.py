"""Public-API smoke: the whole lifecycle purely through ``repro.Retriever``.

    # process 1: build + save through the facade
    PYTHONPATH=src python benchmarks/api_smoke.py --phase build --dir api_artifacts
    # process 2 (FRESH interpreter): reload, search, serve, verify
    PYTHONPATH=src python benchmarks/api_smoke.py --phase serve --dir api_artifacts

The ``api-surface-smoke`` CI job runs the two phases as separate steps,
so everything the facade promises is exercised across a process
boundary — no in-process state (module caches, object identity, jit
caches) can paper over a broken artifact or spec round-trip:

  * build phase: one tiny index per cell (monolithic plaid, sharded
    flat, cascade) built and saved ONLY via ``repro.Retriever.build``,
    plus the expected search results, computed through the facade.
  * serve phase: each cell is (a) reloaded via ``repro.Retriever.load``
    — the manifest must reconstruct an EQUAL spec — and searched, (b)
    loaded via the direct ``Searcher.from_dir`` path, and (c) served
    through ``retriever.serve()``'s concurrent engine; all three must
    be BITWISE equal to the build-phase results.

Exits non-zero on any mismatch.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import numpy as np

import repro
from repro.core.spec import (IndexSpec, PoolingSpec, RetrieverSpec,
                             ServeSpec, ShardSpec)
from repro.data.corpus import DatasetSpec, SyntheticRetrievalCorpus

CELLS = {
    "plaid_mono": dict(backend="plaid", shard_max=0),
    "flat_sharded": dict(backend="flat", shard_max=256),
    "cascade": dict(backend="cascade", shard_max=0),
}
K = 5


def setup():
    cfg = repro.get_smoke_config("colbertv2")
    params = repro.init_colbert(jax.random.PRNGKey(0), cfg)
    spec = DatasetSpec("api-smoke", n_docs=60, n_queries=8, n_topics=4,
                       doc_len_mean=24, doc_len_std=4, seed=17)
    corpus = SyntheticRetrievalCorpus(spec, vocab_size=cfg.trunk.vocab_size)
    toks = corpus.doc_token_batch(cfg.doc_maxlen - 2)
    q = corpus.query_token_batch(cfg.query_maxlen - 2)
    return cfg, params, toks, q


def cell_spec(cfg, backend: str, shard_max: int) -> RetrieverSpec:
    extra = (dict(coarse_factor=4, fine_factor=2, candidates=16)
             if backend == "cascade" else {})
    return RetrieverSpec(
        pooling=PoolingSpec(method="ward", factor=2),
        index=IndexSpec.from_config(cfg, backend=backend, **extra),
        shard=ShardSpec(shard_max_vectors=shard_max))


def phase_build(root: str) -> int:
    cfg, params, toks, q = setup()
    for name, cell in CELLS.items():
        out = os.path.join(root, name)
        spec = cell_spec(cfg, cell["backend"], cell["shard_max"])
        r = repro.Retriever.build(params, cfg, toks, spec, out_dir=out)
        S, I = r.search(q, k=K)
        np.savez(os.path.join(root, f"{name}.expected.npz"),
                 scores=np.asarray(S), ids=np.asarray(I))
        with open(os.path.join(root, f"{name}.spec.json"), "w") as fh:
            json.dump(spec.to_dict(), fh, indent=2)
        print(f"built {name}: {r.stats.n_docs} docs, "
              f"{r.stats.n_vectors_stored} vectors "
              f"({r.stats.vector_reduction:.0%} reduction) -> {out}")
    return 0


def phase_serve(root: str) -> int:
    from repro.retrieval.searcher import Searcher

    cfg, params, _, q = setup()
    failures = 0
    for name, cell in CELLS.items():
        out = os.path.join(root, name)
        exp = np.load(os.path.join(root, f"{name}.expected.npz"))
        with open(os.path.join(root, f"{name}.spec.json")) as fh:
            built_spec = RetrieverSpec.from_dict(json.load(fh))

        r = repro.Retriever.load(params, cfg, out)
        ok_spec = (r.spec.index == built_spec.index
                   and r.spec.pooling == built_spec.pooling
                   and r.spec.shard == built_spec.shard)
        S1, I1 = r.search(q, k=K)
        ok_load = (np.array_equal(S1, exp["scores"])
                   and np.array_equal(I1, exp["ids"]))

        S2, I2 = Searcher.from_dir(params, cfg, out).search(q, k=K)
        ok_direct = (np.array_equal(S2, exp["scores"])
                     and np.array_equal(I2, exp["ids"]))

        ok_engine = True
        with r.serve(ServeSpec(max_batch=4, max_wait_ms=1.0, k=K)) as eng:
            futs = [eng.submit(q[i][None]) for i in range(len(q))]
            for i, f in enumerate(futs):
                S, I = f.result(timeout=120)
                ok_engine &= (np.array_equal(S[0], exp["scores"][i])
                              and np.array_equal(I[0], exp["ids"][i]))

        ok = ok_spec and ok_load and ok_direct and ok_engine
        failures += not ok
        print(f"{name}: spec={'ok' if ok_spec else 'MISMATCH'} "
              f"facade={'ok' if ok_load else 'MISMATCH'} "
              f"direct-searcher={'ok' if ok_direct else 'MISMATCH'} "
              f"engine={'ok' if ok_engine else 'MISMATCH'}")
    if failures:
        print(f"FAILED: {failures} cell(s) broke fresh-process parity")
        return 1
    print("api-surface smoke: all cells bitwise-equal across the "
          "process boundary")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--phase", choices=("build", "serve"), required=True)
    ap.add_argument("--dir", default="api_artifacts")
    args = ap.parse_args(argv)
    os.makedirs(args.dir, exist_ok=True)
    return (phase_build if args.phase == "build"
            else phase_serve)(args.dir)


if __name__ == "__main__":
    sys.exit(main())
