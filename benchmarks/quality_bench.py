"""Quality sweep + regression gate, as a CLI.

    # full synthetic sweep -> BENCH_quality.json section "quality_sweep"
    PYTHONPATH=src python -m benchmarks.quality_bench

    # CI smoke: small grid, paper-envelope assertion, gate vs a pinned
    # baseline file, non-zero exit on failure
    PYTHONPATH=src python -m benchmarks.quality_bench --smoke \
        --assert-envelope --baseline benchmarks/quality_baseline.json

    # refresh the pinned baseline after a deliberate change
    PYTHONPATH=src python -m benchmarks.quality_bench --smoke \
        --write-baseline benchmarks/quality_baseline.json

A real BEIR corpus drops in via ``--beir <dir>`` (the standard
``corpus.jsonl`` / ``queries.jsonl`` / ``qrels/<split>.tsv`` layout).
"""
from __future__ import annotations

import argparse

from repro.eval import (BENCH_QUALITY_FILE, QualitySweep, load_beir,
                        run_gate, synthetic_dataset,
                        write_bench_section)

SECTION = "quality_sweep"
# the CI smoke grid: both pooling families x the factors the paper
# headlines x both backend families, small corpus for wall-time
SMOKE = dict(dataset="scifact", n_docs=120, n_queries=20,
             methods=("ward", "sequential"), factors=(1, 2, 4),
             backends=("flat", "plaid"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="scifact")
    ap.add_argument("--beir", default=None, metavar="DIR",
                    help="BEIR-format dataset directory (overrides "
                         "--dataset)")
    ap.add_argument("--split", default="test")
    ap.add_argument("--docs", type=int, default=200)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--methods", nargs="+",
                    default=["ward", "sequential"])
    ap.add_argument("--factors", nargs="+", type=int,
                    default=[1, 2, 3, 4])
    ap.add_argument("--backends", nargs="+",
                    default=["flat", "plaid"])
    ap.add_argument("--quant-bits", nargs="+", type=int, default=[2])
    ap.add_argument("--metrics", nargs="+",
                    default=["ndcg@10", "recall@5", "success@5",
                             "mrr@10"])
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--ja", action="store_true",
                    help="use the Japanese-analogue bench encoder")
    ap.add_argument("--out", default=BENCH_QUALITY_FILE)
    ap.add_argument("--smoke", action="store_true",
                    help="CI grid: ward/sequential x f 1/2/4 x "
                         "flat/plaid on a small corpus")
    ap.add_argument("--assert-envelope", action="store_true",
                    help="fail (exit 1) when a cell drops below the "
                         "paper envelope")
    ap.add_argument("--min-relative", type=float, default=95.0,
                    help="factor-2 relative floor for the envelope "
                         "gate (default: paper's 95)")
    ap.add_argument("--gate-methods", nargs="+", default=None,
                    help="restrict the envelope gate to these pooling "
                         "methods (default: all swept)")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="pinned BENCH_quality.json to gate "
                         "regressions against")
    ap.add_argument("--tolerance", type=float, default=3.0,
                    help="allowed relative-point drop vs the pinned "
                         "baseline (cross-box float drift)")
    ap.add_argument("--write-baseline", default=None, metavar="FILE",
                    help="also write the report to FILE (refresh the "
                         "pin)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.dataset = SMOKE["dataset"]
        args.docs, args.queries = SMOKE["n_docs"], SMOKE["n_queries"]
        args.methods = list(SMOKE["methods"])
        args.factors = list(SMOKE["factors"])
        args.backends = list(SMOKE["backends"])

    from benchmarks.common import bench_encoder
    params, cfg = bench_encoder(ja=args.ja, verbose=False)
    if args.beir:
        dataset = load_beir(args.beir, doc_maxlen=cfg.doc_maxlen - 2,
                            query_maxlen=cfg.query_maxlen - 2,
                            split=args.split,
                            vocab_size=cfg.trunk.vocab_size,
                            max_docs=args.docs or None)
    else:
        dataset = synthetic_dataset(
            args.dataset, vocab_size=cfg.trunk.vocab_size,
            doc_maxlen=cfg.doc_maxlen - 2,
            query_maxlen=cfg.query_maxlen - 2,
            n_docs=args.docs, n_queries=args.queries)

    report = QualitySweep(
        params, cfg, dataset, methods=args.methods,
        factors=args.factors, backends=args.backends,
        quant_bits=args.quant_bits, metrics=args.metrics,
        k=args.k).run(verbose=True)

    print()
    print(report.summary(args.metrics[0]))
    for backend in args.backends:
        for qb in (args.quant_bits if backend == "plaid" else [None]):
            print()
            print(report.markdown_table(args.metrics[0],
                                        backend=backend,
                                        quant_bits=qb))
    write_bench_section(args.out, SECTION, report)
    print(f"\nwrote section {SECTION!r} -> {args.out}")
    if args.write_baseline:
        write_bench_section(args.write_baseline, SECTION, report)
        print(f"pinned baseline -> {args.write_baseline}")

    if args.assert_envelope or args.baseline:
        gate = run_gate(
            report, metric=args.metrics[0],
            baseline_path=args.baseline,
            baseline_section=SECTION,
            methods=args.gate_methods,
            min_relative=args.min_relative if args.assert_envelope
            else None,
            tolerance=args.tolerance)
        print(f"\ngate: {gate.summary()}")
        if not gate.ok:
            return 1
    return 0


def run(verbose: bool = True):
    """Orchestrator entry point (benchmarks.run)."""
    return main([])


if __name__ == "__main__":
    raise SystemExit(main())
