"""Paper Table 4: second model / second language (JaColBERTv2 analogue).

Hierarchical pooling on the Japanese-analogue corpora (longer docs,
doc_maxlen=160 vs 128, different vocab), 2-bit PLAID, Recall@5."""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_encoder, small_spec
from repro.data.corpus import SyntheticRetrievalCorpus
from repro.retrieval.evaluate import evaluate_pooling

DATASETS = ["jsquad", "miracl-ja"]
FACTORS = (2, 3, 4, 6)


def run(verbose: bool = True):
    params, cfg = bench_encoder(ja=True, verbose=verbose)
    rows = {}
    for name in DATASETS:
        corpus = SyntheticRetrievalCorpus(small_spec(name, 160, 20),
                                          vocab_size=cfg.trunk.vocab_size)
        rep = evaluate_pooling(params, cfg, corpus, methods=("ward",),
                               factors=FACTORS, backend="plaid",
                               metric_name="recall@5")
        rows[name] = rep

    print("\nTable 4 — hierarchical pooling, second model (JA analogue), "
          "relative Recall@5, 2-bit PLAID")
    print(f"{'f':>3s}" + "".join(f"{d:>12s}" for d in DATASETS)
          + f"{'avg':>10s}")
    out = {}
    for f in FACTORS:
        vals = [rows[d].cell("ward", f).relative for d in DATASETS]
        out[f] = np.mean(vals)
        print(f"{f:3d}" + "".join(f"{v:12.2f}" for v in vals)
              + f"{np.mean(vals):10.2f}")
    return {"rows": rows, "avg": out}


if __name__ == "__main__":
    run()
