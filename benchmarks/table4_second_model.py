"""Paper Table 4: second model / second language (JaColBERTv2 analogue).

Hierarchical pooling on the Japanese-analogue corpora (longer docs,
doc_maxlen=160 vs 128, different vocab), 2-bit PLAID, Recall@5 —
swept through ``repro.eval.QualitySweep`` and the ``repro.Retriever``
facade; lands in the ``table4`` section of ``BENCH_quality.json``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_encoder
from repro.eval import (BENCH_QUALITY_FILE, QualitySweep,
                        synthetic_dataset, write_bench_section)

DATASETS = ["jsquad", "miracl-ja"]
FACTORS = (1, 2, 3, 4, 6)
BACKEND = "plaid"
BITS = 2
METRIC = "recall@5"


def run(verbose: bool = True, out: str = BENCH_QUALITY_FILE):
    params, cfg = bench_encoder(ja=True, verbose=verbose)
    reports = {}
    for name in DATASETS:
        ds = synthetic_dataset(name, vocab_size=cfg.trunk.vocab_size,
                               doc_maxlen=cfg.doc_maxlen - 2,
                               query_maxlen=cfg.query_maxlen - 2,
                               n_docs=160, n_queries=20)
        reports[name] = QualitySweep(
            params, cfg, ds, methods=("ward",), factors=FACTORS,
            backends=(BACKEND,), quant_bits=(BITS,),
            metrics=(METRIC,)).run()

    print("\nTable 4 — hierarchical pooling, second model (JA analogue), "
          "relative Recall@5, 2-bit PLAID")
    print(f"{'f':>3s}" + "".join(f"{d:>12s}" for d in DATASETS)
          + f"{'avg':>10s}")
    avg = {}
    for f in FACTORS:
        if f == 1:
            continue
        vals = [reports[d].cell(BACKEND, "ward", f, BITS)
                .relative[METRIC] for d in DATASETS]
        avg[str(f)] = float(np.mean(vals))
        print(f"{f:3d}" + "".join(f"{v:12.2f}" for v in vals)
              + f"{np.mean(vals):10.2f}")
    write_bench_section(out, "table4",
                        {"reports": reports, "avg_relative": avg,
                         "backend": BACKEND, "quant_bits": BITS,
                         "metric": METRIC})
    return {"rows": reports, "avg": avg}


if __name__ == "__main__":
    run()
