"""Scale-out serving benchmark: QPS vs replica count on a large corpus.

    PYTHONPATH=src python benchmarks/scale_bench.py --docs 1000000

Builds a synthetic unit-vector corpus straight into a ShardedIndex
(identity encoder — the corpus IS the pooled vectors, so a million-doc
build costs index construction, not a transformer forward), then for
each replica count serves the SAME index through the engine's replica
router (launch/engine.py ``n_replicas``) and records:

  * saturation QPS — a closed burst of single-query requests through
    the dynamic batcher, wall-clock timed: the capacity number the
    replica-scaling headline (``speedup_vs_1``) is computed from;
  * an open-loop Poisson run offered at ``--load-frac`` of that
    measured capacity: achieved QPS + end-to-end p50/p99 — the
    "bounded p99 at high utilization" evidence, per replica count;
  * a bitwise parity audit: every open-loop result AND every replica
    lane's direct ``search_batch_on`` checked against the wrapped
    index's ``search_batch`` (ids + scores).

Honesty fields: ``host_cores`` and ``n_devices`` are recorded because
replica scaling is bounded by physical parallelism — on a 1-core box
every lane shares one execution stream and speedup_vs_1 ~ 1.0 by
construction. The CI ``scale-smoke`` job runs this with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (+ single-thread
eigen) on a multi-core runner and gates ``--min-speedup`` there.

``--assert-parity`` exits non-zero on any mismatch or failed query;
``--min-speedup S`` additionally requires QPS(max replicas) >=
S x QPS(1).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core.sharded import ShardedIndex
from repro.core.spec import ServeSpec, add_spec_args
from repro.launch.engine import ServingEngine, run_open_loop


class VectorSearcher:
    """Identity-encoder searcher: 'query tokens' are already [n, Lq, dim]
    unit vectors, so the bench measures the serving/index layers, not a
    transformer forward."""

    def __init__(self, index):
        self.index = index

    def encode_queries(self, q):
        return np.asarray(q, np.float32)

    def warmup(self, batch_sizes, k=10):
        if isinstance(batch_sizes, (int, np.integer)):
            batch_sizes = [batch_sizes]
        lq, dim = self._qshape
        for bs in sorted(set(batch_sizes)):
            self.index.search_batch(
                np.zeros((bs, lq, dim), np.float32), k=k)


def unit(rng, shape):
    v = rng.normal(size=shape).astype(np.float32)
    return v / np.linalg.norm(v, axis=-1, keepdims=True)


def build_corpus(args):
    """Chunked adds: peak host memory is one chunk of docs plus the
    index itself, never the whole corpus as a python list."""
    rng = np.random.default_rng(args.seed)
    kw = dict(doc_maxlen=args.doc_len,
              n_centroids=args.n_centroids, nprobe=args.nprobe,
              ndocs=args.ndocs)
    index = ShardedIndex(dim=args.dim, backend=args.backend,
                         shard_max_vectors=args.shard_max_vectors,
                         **(kw if args.backend == "plaid"
                            else dict(doc_maxlen=args.doc_len)))
    t0 = time.time()
    chunk = args.build_chunk
    added = 0
    while added < args.docs:
        n = min(chunk, args.docs - added)
        # fixed doc length: the corpus is synthetic; ragged lengths only
        # slow construction without changing what scaling is measured
        vecs = unit(rng, (n, args.doc_len, args.dim))
        index.add(list(vecs))
        added += n
        if added % (chunk * 8) == 0 or added == args.docs:
            print(f"  built {added}/{args.docs} docs "
                  f"({index.n_shards} shards, {time.time() - t0:.0f}s)",
                  flush=True)
    return index, time.time() - t0


def lane_parity(index, wrapped, qs, k):
    """Every replica lane vs the wrapped index's own search_batch."""
    S0, I0 = index.search_batch(qs, k=k)
    bad = 0
    n_lanes = getattr(wrapped, "n_replicas", 1)
    for r in range(n_lanes):
        S, I = (wrapped.search_batch_on(r, qs, k=k)
                if hasattr(wrapped, "search_batch_on")
                else wrapped.search_batch(qs, k=k))
        if not (np.array_equal(np.asarray(S), np.asarray(S0))
                and np.array_equal(np.asarray(I), np.asarray(I0))):
            bad += 1
    return bad, (S0, I0)


def saturation_qps(engine, qs, n_queries, k):
    """Closed burst: submit everything, wall-clock the drain."""
    t0 = time.perf_counter()
    futs = [engine.submit(qs[i % len(qs)][None], k=k)
            for i in range(n_queries)]
    errors = 0
    for f in futs:
        try:
            f.result(timeout=300.0)
        except Exception:               # noqa: BLE001
            errors += 1
    wall = time.perf_counter() - t0
    return (n_queries - errors) / wall if wall > 0 else 0.0, errors


def scale_cell(index, qs, n_replicas, args, refs):
    searcher = VectorSearcher(index)
    searcher._qshape = qs.shape[1:]
    engine = ServingEngine(searcher, max_batch=args.max_batch,
                           max_wait_ms=args.max_wait_ms, k=args.k,
                           warmup_on_start=False, n_replicas=n_replicas)
    # warm every lane at every bucket shape BEFORE timing (the engine's
    # default warmup path needs an encoder config; the identity searcher
    # warms through the placed index directly)
    served = engine._handle.index
    for b in engine.buckets:
        warm = getattr(served, "warm_shapes", None)
        if warm is not None:
            warm(np.broadcast_to(qs[:1], (b,) + qs.shape[1:]), k=args.k)
        else:
            served.search_batch(
                np.broadcast_to(qs[:1], (b,) + qs.shape[1:]), k=args.k)
    mismatched_lanes, (S_ref, I_ref) = lane_parity(index, served, qs,
                                                   args.k)
    with engine:
        qps_sat, sat_errors = saturation_qps(engine, qs,
                                             args.queries, args.k)
        rate = max(args.load_frac * qps_sat, 1.0)
        ol = run_open_loop(engine, qs, rate, args.queries, k=args.k,
                           seed=args.seed, collect_results=True)
        snap = engine.stats.snapshot()
    results = ol.pop("results")
    ol_mismatches = 0
    for i, res in enumerate(results):
        if res is None:
            continue
        S, I = res
        j = i % len(qs)
        if not (np.array_equal(S[0], S_ref[j])
                and np.array_equal(I[0], I_ref[j])):
            ol_mismatches += 1
    row = {
        "n_replicas": n_replicas,
        "qps_saturated": qps_sat,
        "saturation_errors": sat_errors,
        "open_loop": ol,
        "lane_parity_mismatches": mismatched_lanes,
        "open_loop_parity_mismatches": ol_mismatches,
        "replica_batches": snap["replica_batches"],
        "mean_batch_size": snap["mean_batch_size"],
    }
    refs[n_replicas] = qps_sat
    return row


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--docs", type=int, default=1_000_000)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--doc-len", type=int, default=4,
                    help="pooled vectors per doc (the paper's pooled "
                         "regime: a few vectors, not hundreds)")
    ap.add_argument("--backend", default="plaid",
                    choices=["flat", "plaid"],
                    help="plaid bounds per-query cost by the candidate "
                         "budget at any corpus size; flat is the "
                         "shard_map SPMD path (small corpora)")
    ap.add_argument("--shard-max-vectors", type=int, default=0,
                    help="0 = auto: ~8 shards over the corpus")
    ap.add_argument("--n-centroids", type=int, default=256)
    ap.add_argument("--nprobe", type=int, default=4)
    ap.add_argument("--ndocs", type=int, default=512,
                    help="plaid candidate budget (caps stage-2 cost)")
    ap.add_argument("--build-chunk", type=int, default=20_000)
    ap.add_argument("--replicas", default="1,2,4")
    ap.add_argument("--queries", type=int, default=256,
                    help="requests per saturation burst / open-loop run")
    ap.add_argument("--query-pool", type=int, default=64)
    ap.add_argument("--lq", type=int, default=8)
    ap.add_argument("--load-frac", type=float, default=0.7,
                    help="open-loop offered load as a fraction of the "
                         "cell's measured saturation QPS")
    ap.add_argument("--seed", type=int, default=0)
    add_spec_args(ap, ServeSpec, only=("max_batch", "max_wait_ms", "k"))
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="gate: QPS(max replicas) >= S x QPS(1)")
    ap.add_argument("--assert-parity", action="store_true")
    ap.add_argument("--out", default="BENCH_scale.json")
    args = ap.parse_args(argv)
    replicas = sorted({int(r) for r in args.replicas.split(",") if r})

    if args.shard_max_vectors == 0:
        args.shard_max_vectors = max(1, args.docs * args.doc_len // 8)

    import jax
    print(f"building {args.docs} docs x {args.doc_len} vectors "
          f"({args.backend})...", flush=True)
    index, build_s = build_corpus(args)
    rng = np.random.default_rng(args.seed + 1)
    qs = unit(rng, (args.query_pool, args.lq, args.dim))

    cells, refs = [], {}
    for n in replicas:
        print(f"replica cell n={n}...", flush=True)
        cells.append(scale_cell(index, qs, n, args, refs))
        c = cells[-1]
        print(f"  qps_sat={c['qps_saturated']:.1f} "
              f"p99={c['open_loop']['latency_p99_ms']:.1f}ms "
              f"lane_mismatch={c['lane_parity_mismatches']} "
              f"ol_mismatch={c['open_loop_parity_mismatches']}",
              flush=True)

    top = max(replicas)
    speedup = (refs[top] / refs[1]
               if 1 in refs and top != 1 and refs[1] > 0 else 1.0)
    out = {
        "host_cores": os.cpu_count(),
        "n_devices": len(jax.devices()),
        "docs": args.docs,
        "vectors": index.n_vectors(),
        "n_shards": index.n_shards,
        "backend": args.backend,
        "dim": args.dim,
        "build_s": build_s,
        "k": args.k,
        "max_batch": args.max_batch,
        "load_frac": args.load_frac,
        "cells": cells,
        "speedup_vs_1": {"n_replicas": top, "qps_ratio": speedup},
    }
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)
    print(f"wrote {args.out}; speedup({top} vs 1) = {speedup:.2f}x "
          f"on {os.cpu_count()} cores / {len(jax.devices())} devices")

    failures = []
    mism = sum(c["lane_parity_mismatches"]
               + c["open_loop_parity_mismatches"] for c in cells)
    errs = sum(c["saturation_errors"] + c["open_loop"]["errors"]
               for c in cells)
    if args.assert_parity and (mism or errs):
        failures.append(f"parity mismatches={mism} errors={errs}")
    if args.min_speedup is not None and speedup < args.min_speedup:
        failures.append(f"speedup {speedup:.2f}x < required "
                        f"{args.min_speedup:.2f}x")
    if failures:
        print("SCALE BENCH FAILED: " + "; ".join(failures))
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
