"""Kernel micro-benchmarks: wall-time of the jnp reference vs the Pallas
kernel in interpret mode is NOT meaningful on CPU (interpret mode is a
Python-level simulator), so this reports (a) the jnp reference wall time
as the CPU datapoint and (b) the kernel's VMEM working-set & arithmetic
intensity — the numbers that matter for the TPU target."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.maxsim import maxsim_scores, maxsim_scores_blocked
from repro.roofline import hw


def _time(f, *args, n=2):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / n


def run(verbose: bool = True):
    rng = np.random.default_rng(0)
    print("\nKernel analysis (TPU v5e target)")
    rows = []
    for (nq, lq, nd, ld, dim, bq, bd) in [
            (16, 32, 2048, 256, 128, 8, 8),
            (32, 32, 8192, 256, 128, 8, 16)]:
        q = jnp.asarray(rng.normal(size=(nq, lq, dim)), jnp.float32)
        d = jnp.asarray(rng.normal(size=(nd, ld, dim)), jnp.float32)
        qm = jnp.ones((nq, lq), bool)
        dm = jnp.ones((nd, ld), bool)
        # blocked path: the big shapes would materialize a [Nq,Nd,Lq,Ld]
        # tensor (tens of GB) through the einsum reference
        t = _time(lambda a, b, c, e: maxsim_scores_blocked(
            a, b, c, e, block=512), q, qm, d, dm)
        flops = 2 * nq * lq * nd * ld * dim
        vmem = (bq * lq * dim + bd * ld * dim + bq * lq * bd * ld) * 4
        ai = flops / (q.nbytes + d.nbytes + nq * nd * 4)
        tpu_roof = flops / hw.PEAK_FLOPS_BF16
        print(f"maxsim q{nq}x{lq} d{nd}x{ld}: jnp-cpu {t*1e3:7.1f}ms | "
              f"kernel tile VMEM {vmem/2**20:5.2f}MiB, AI {ai:6.1f} "
              f"flop/B, v5e compute-roof {tpu_roof*1e6:6.1f}us")
        rows.append({"shape": (nq, lq, nd, ld, dim), "cpu_ms": t * 1e3,
                     "vmem_mb": vmem / 2**20, "ai": ai})
    rows += run_plaid_probe(rng)
    return rows


def run_plaid_probe(rng):
    """Fused centroid-interaction probe cell (kernels/plaid_probe): jnp
    reference wall time on CPU + the kernel tile's VMEM working set and
    arithmetic intensity for the TPU target."""
    from repro.kernels.plaid_probe.ops import plaid_probe_scores

    rows = []
    for (nq, lq, c, l, k, dim, bc) in [
            (8, 32, 1024, 64, 4096, 128, 8),
            (16, 32, 4096, 64, 4096, 128, 8)]:
        q = jnp.asarray(rng.normal(size=(nq, lq, dim)), jnp.float32)
        qm = jnp.ones((nq, lq), bool)
        cents = jnp.asarray(rng.normal(size=(k, dim)), jnp.float32)
        codes = jnp.asarray(rng.integers(0, k, size=(nq, c, l)), jnp.int32)
        cm = jnp.ones((nq, c, l), bool)
        vm = jnp.ones((nq, c), bool)
        t = _time(lambda *a: plaid_probe_scores(*a, t_cs=0.3, impl="ref"),
                  q, qm, cents, codes, cm, vm)
        # per-tile: q + centroid table + cs [lq, k] + one-hot [bc*l, k]
        vmem = (lq * dim + k * dim + lq * k + bc * l * k + bc * l * lq) * 4
        flops = 2.0 * nq * (lq * k * dim + c * l * k * lq)
        ai = flops / (q.nbytes + cents.nbytes + codes.nbytes + nq * c * 4)
        tpu_roof = flops / hw.PEAK_FLOPS_BF16
        print(f"plaid_probe q{nq}x{lq} c{c}x{l} K{k}: "
              f"jnp-cpu {t*1e3:7.1f}ms | kernel tile VMEM "
              f"{vmem/2**20:5.2f}MiB, AI {ai:6.1f} flop/B, "
              f"v5e compute-roof {tpu_roof*1e6:6.1f}us")
        rows.append({"kernel": "plaid_probe",
                     "shape": (nq, lq, c, l, k, dim), "cpu_ms": t * 1e3,
                     "vmem_mb": vmem / 2**20, "ai": ai})
    return rows


if __name__ == "__main__":
    run()
